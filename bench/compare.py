#!/usr/bin/env python3
"""Diff two sets of BENCH_*.json perf-trajectory files.

Usage:
    bench/compare.py BASELINE_DIR CURRENT_DIR [--threshold 0.10] [--check]

Both directories hold files written by `cargo bench --bench trajectory`
(schema ``hitgnn-bench-v1``: ``{schema, area, git_rev, quick, benches:
[{title, measurements: [{name, median_s, ...}], derived: [...]}]}``).
Measurements are matched by (file name, bench title, measurement name);
for each match the median-seconds delta is printed. With ``--check`` the
exit status is non-zero if any matched measurement regressed (slowed
down) by more than ``--threshold`` (fractional, default 0.10 = +10%).

Entries present on only one side are reported as added/removed, never as
regressions — a new bench must not fail the gate that would have
recorded its first baseline.

stdlib only; no third-party dependencies.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def load_medians(path: Path) -> dict[tuple[str, str], float]:
    """(bench title, measurement name) -> median seconds for one file."""
    doc = json.loads(path.read_text())
    if doc.get("schema") != "hitgnn-bench-v1":
        raise SystemExit(f"{path}: unsupported schema {doc.get('schema')!r}")
    out: dict[tuple[str, str], float] = {}
    for bench in doc.get("benches", []):
        title = bench.get("title", "?")
        for m in bench.get("measurements", []):
            out[(title, m["name"])] = float(m["median_s"])
    return out


def fmt_secs(s: float) -> str:
    if s < 1e-6:
        return f"{s * 1e9:.1f} ns"
    if s < 1e-3:
        return f"{s * 1e6:.1f} us"
    if s < 1.0:
        return f"{s * 1e3:.2f} ms"
    return f"{s:.3f} s"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", type=Path, help="directory with baseline BENCH_*.json")
    ap.add_argument("current", type=Path, help="directory with current BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="fractional slowdown that counts as a regression (default 0.10)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="exit non-zero if any measurement regressed past the threshold",
    )
    args = ap.parse_args()

    base_files = {p.name: p for p in sorted(args.baseline.glob("BENCH_*.json"))}
    cur_files = {p.name: p for p in sorted(args.current.glob("BENCH_*.json"))}
    if not cur_files:
        raise SystemExit(f"no BENCH_*.json files in {args.current}")

    regressions: list[str] = []
    for name in sorted(set(base_files) | set(cur_files)):
        if name not in base_files:
            print(f"{name}: new file (no baseline) — skipped")
            continue
        if name not in cur_files:
            print(f"{name}: missing from current run")
            continue
        base = load_medians(base_files[name])
        cur = load_medians(cur_files[name])
        print(f"\n== {name} (threshold +{args.threshold * 100:.0f}%) ==")
        width = max((len(f"{t} / {m}") for t, m in (set(base) | set(cur))), default=20)
        for key in sorted(set(base) | set(cur)):
            label = f"{key[0]} / {key[1]}"
            if key not in base:
                print(f"  {label:<{width}}  {'—':>10} -> {fmt_secs(cur[key]):>10}  (new)")
                continue
            if key not in cur:
                print(f"  {label:<{width}}  {fmt_secs(base[key]):>10} -> {'—':>10}  (removed)")
                continue
            b, c = base[key], cur[key]
            delta = (c - b) / b if b > 0 else 0.0
            marker = ""
            if delta > args.threshold:
                marker = "  REGRESSION"
                regressions.append(f"{name}: {label}: {fmt_secs(b)} -> {fmt_secs(c)} ({delta:+.1%})")
            elif delta < -args.threshold:
                marker = "  improved"
            print(
                f"  {label:<{width}}  {fmt_secs(b):>10} -> {fmt_secs(c):>10}  ({delta:+.1%}){marker}"
            )

    if regressions:
        print(f"\n{len(regressions)} regression(s) past the threshold:", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        if args.check:
            return 1
    else:
        print("\nno regressions past the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
