#!/usr/bin/env bash
# Run the full bench matrix and collect the machine-readable perf
# trajectory (BENCH_*.json) for this checkout.
#
# Usage:
#   bench/run_all.sh [out-dir]          # full run (default out: bench/out)
#   HITGNN_BENCH_QUICK=1 bench/run_all.sh   # CI smoke scale
#
# The trajectory runner (benches/trajectory.rs) writes BENCH_host.json,
# BENCH_kernels.json and BENCH_tune.json into $HITGNN_BENCH_OUT; the
# remaining benches print their human-readable tables to stdout. Diff two
# trajectory sets with bench/compare.py.
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:-bench/out}"
mkdir -p "$OUT"
export HITGNN_BENCH_OUT="$OUT"

echo "== trajectory (BENCH_*.json -> $OUT) =="
(cd rust && cargo bench --bench trajectory)

echo "== table/figure benches (stdout) =="
for bench in micro_host e2e_execution fig7_dse_sweep fig8_scalability \
             table5_resource table6_cross_platform table7_ablation \
             ablation_design; do
  echo "---- $bench ----"
  (cd rust && cargo bench --bench "$bench")
done

echo "BENCH_*.json written to $OUT:"
ls -l "$OUT"/BENCH_*.json
