#!/usr/bin/env bash
# Profile-guided-optimization recipe for the hitgnn crate.
#
# Three phases:
#   1. build instrumented (-Cprofile-generate) and run the trajectory
#      bench as the training workload,
#   2. merge the raw profiles with llvm-profdata,
#   3. rebuild optimized against the merged profile (-Cprofile-use).
#
# Usage: bench/run_pgo.sh [profile-dir]   (default: bench/pgo-data)
#
# Requires llvm-profdata — from the rustup toolchain's llvm-tools
# (`rustup component add llvm-tools`) or the system LLVM. The trajectory
# bench is the profiling workload because it exercises the full hot path:
# sampling, gather, scheduling, the blocked kernels and the epoch loop.
set -euo pipefail
cd "$(dirname "$0")/.."

PGO_DIR="$(pwd)/${1:-bench/pgo-data}"
rm -rf "$PGO_DIR"
mkdir -p "$PGO_DIR"

# locate llvm-profdata: toolchain llvm-tools first, then PATH
SYSROOT="$(rustc --print sysroot)"
PROFDATA="$(find "$SYSROOT" -name llvm-profdata -type f 2>/dev/null | head -n1 || true)"
if [ -z "$PROFDATA" ]; then
  PROFDATA="$(command -v llvm-profdata || true)"
fi
if [ -z "$PROFDATA" ]; then
  echo "error: llvm-profdata not found — run 'rustup component add llvm-tools'" >&2
  exit 1
fi

echo "== 1/3: instrumented build + profiling run =="
(
  cd rust
  RUSTFLAGS="-Cprofile-generate=$PGO_DIR" cargo bench --bench trajectory
)

echo "== 2/3: merging profiles =="
"$PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"/*.profraw

echo "== 3/3: optimized rebuild =="
(
  cd rust
  RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata" cargo build --release
)

echo "PGO build done (profile: $PGO_DIR/merged.profdata)."
echo "Run benches against it with the same RUSTFLAGS, e.g.:"
echo "  RUSTFLAGS=\"-Cprofile-use=$PGO_DIR/merged.profdata\" bench/run_all.sh"
