//! Design-space exploration walkthrough (paper §6, Fig 7 + Table 5).
//!
//!     cargo run --release --example dse_explore [--model sage] [--fpgas 4]
//!
//! Runs the Algorithm-4 sweep, prints the throughput surface, the chosen
//! optimum, and the Table-5 comparison between the DSE pick and the
//! "maximise aggregation parallelism" intuition.

use hitgnn::dse::{paper_dse_workloads, DseEngine};
use hitgnn::perf::PlatformSpec;
use hitgnn::util::cli::Args;
use hitgnn::util::stats::si;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.str("model", "sage");
    let p: usize = args.num("fpgas", 4)?;
    args.finish()?;

    let mut spec = PlatformSpec::paper_4fpga();
    spec.num_fpgas = p;
    let mut engine = DseEngine::new(spec);
    engine.m_step = 32;
    let workloads = paper_dse_workloads(if model == "sage" { 2.0 } else { 1.0 });

    let res = engine.explore(&workloads)?;
    println!(
        "swept {} feasible design points (n ≤ {}, m ≤ {} per die)",
        res.grid.len(),
        res.n_max,
        res.m_max
    );
    println!(
        "optimum: FPGA-level (n={}, m={}) → {} NVTPS estimated",
        res.best.n_fpga,
        res.best.m_fpga,
        si(res.best.throughput)
    );
    let u = res.best.utilization;
    println!(
        "utilization: DSP {:.0}% LUT {:.0}% URAM {:.0}% BRAM {:.0}%",
        u.dsp * 100.0,
        u.lut * 100.0,
        u.uram * 100.0,
        u.bram * 100.0
    );

    // the Table-5 lesson: maximising aggregation parallelism is NOT optimal
    let intuitive = engine.evaluate_fpga_config(16, 1024, &workloads)?;
    println!(
        "\n'maximise aggregation' intuition (16,1024): {} NVTPS — the DSE \
         pick is {:.1}% faster because the optimized aggregate kernel has \
         shifted the bottleneck to feature update (§7.3)",
        si(intuitive.throughput),
        (res.best.throughput / intuitive.throughput - 1.0) * 100.0
    );
    Ok(())
}
