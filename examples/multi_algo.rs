//! All three synchronous GNN training algorithms (DistDGL, PaGraph, P3)
//! through the same framework — the paper's central generality claim.
//!
//!     make artifacts && cargo run --release --example multi_algo
//!
//! For each algorithm: run real training on a scaled dataset (execution
//! path) and report measured β plus the full-scale analytic projection,
//! showing how the preprocessing strategy (Table 1) changes the
//! communication profile while the coordinator stays identical.

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::graph::datasets;
use hitgnn::partition::Algorithm;
use hitgnn::perf::experiments::{build_workload, measure_host, BEST_DIE};
use hitgnn::perf::{PlatformModel, PlatformSpec};
use hitgnn::util::bench::Table;
use hitgnn::util::cli::Args;
use hitgnn::util::stats::si;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dataset = args.str("dataset", "tiny");
    let shift: u32 = args.num("scale-shift", 0)?;
    args.finish()?;

    let mut t = Table::new(&[
        "algorithm",
        "loss e0 -> e2",
        "measured beta",
        "f2f bytes",
        "projected NVTPS (4 U250s)",
    ]);

    for algo in Algorithm::ALL {
        // --- execution path: real training -----------------------------
        let cfg = TrainConfig {
            dataset: dataset.clone(),
            model: "gcn".into(),
            algo,
            num_fpgas: 2,
            epochs: 3,
            scale_shift: shift,
            seed: 11,
            max_iterations: Some(10),
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        let report = trainer.run()?;
        trainer.shutdown();

        // --- analytic projection at paper scale --------------------------
        let spec = datasets::lookup(&dataset)?;
        let host = measure_host(&spec, algo, "gcn", 4, shift.max(4).min(7), 4, 3)?;
        let w = build_workload(&spec, algo, "gcn", &host, 4, true, true);
        let est = PlatformModel::new(PlatformSpec::paper_4fpga(), BEST_DIE).epoch(&w);

        let e0 = report.epochs.first().unwrap();
        t.row(&[
            algo.name().to_string(),
            format!("{:.3} -> {:.3}", e0.mean_loss, report.last_loss()),
            format!("{:.3}", e0.beta),
            si(e0.f2f_bytes as f64),
            si(est.nvtps),
        ]);
    }
    t.print();
    println!(
        "\nnote: P3 shows β≈1/p on the execution path (dim-slice store) but the \
         projection models its real dataflow (slice-local aggregation + layer-1 \
         all-to-all, Listing 3)."
    );
    Ok(())
}
