//! Quickstart: the Table-2-style user API end to end on the tiny dataset.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Mirrors the paper's Listing 1: load a graph, pick a synchronous
//! training algorithm and a GNN model, let the framework generate the
//! design (DSE → accelerator config, software generator → host program),
//! then train and save the model.

use hitgnn::api::HitGnn;
use hitgnn::partition::Algorithm;
use hitgnn::store::CachePolicy;
use hitgnn::tune::AutoTuneMode;

fn main() -> anyhow::Result<()> {
    // --- Design phase (Listing 1 lines 1–22) ---------------------------
    // Depth is one line of user code: fanouts() sets L and the per-layer
    // fanouts (input-side hop first, DESIGN.md §Mini-batch wire format) —
    // here a 3-layer GraphSAGE-style recipe scaled to the tiny dataset.
    let design = HitGnn::new()
        .load_input_graph("tiny", 0)          // LoadInputGraph()
        .graph_partition(Algorithm::DistDgl)  // Graph_Partition()
        .feature_storing(CachePolicy::Lfu, 0.2) // Feature_Storing(policy, ratio)
        .gnn_computation("gcn")               // GNN_Computation('GCN')
        .gnn_parameters(3, 128)               // GNN_Parameters(L=3, hidden)
        .fanouts(&[3, 2, 2])                  // per-layer fanouts (sets L)
        .fpga_metadata(hitgnn::fpga::U250)    // FPGA_Metadata()
        .platform_metadata(2, 16.0, 205.0)    // Platform_Metadata()
        .auto_tune(AutoTuneMode::On)          // DESIGN.md §Adaptive control
        .seed(7)
        .generate_design()?; // Generate_Design()

    let (n, m) = design.fpga_parallelism();
    println!(
        "generated design: accelerator (n={n}, m={m}) per FPGA, \
         estimated {} NVTPS at full scale",
        hitgnn::util::stats::si(design.estimated_nvtps)
    );

    // --- Runtime phase (Listing 1 lines 24–28) ---------------------------
    // the host program trains the 3-layer model end to end on the
    // reference executor (the entry is synthesized from the fanouts)
    let report = design.start_training(3)?; // Start_training(epochs=3)
    for e in &report.epochs {
        // the closed-loop controller logs one decision per epoch
        let tune = e
            .tune
            .as_ref()
            .and_then(|t| t.req_str("action").ok().map(|a| format!(" [tune: {a}]")))
            .unwrap_or_default();
        println!(
            "epoch {}: loss {:.4} ({} iterations, {:.2}s){tune}",
            e.epoch, e.mean_loss, e.iterations, e.wall_seconds
        );
    }
    let first = report.epochs.first().unwrap().mean_loss;
    let last = report.last_loss();
    anyhow::ensure!(last < first, "training should reduce the loss");
    println!("loss {first:.4} -> {last:.4} ✓");

    design.save_model("/tmp/hitgnn_quickstart_model.json")?; // Save_model()
    println!("model saved to /tmp/hitgnn_quickstart_model.json");
    Ok(())
}
