//! Scalability study (paper §7.6 / Fig 8): project the training throughput
//! from 1 to 16 FPGAs and find where CPU memory bandwidth becomes the
//! limit (205 GB/s ÷ 16 GB/s PCIe ≈ 12.8 concurrent fetchers). Then a
//! *measured* host-pipeline sweep: epoch wall-clock over host-threads ×
//! prefetch-depth on the bundled synthetic dataset.
//!
//!     cargo run --release --example scalability [--shift 6] [--skip-host]

use hitgnn::coordinator::Trainer;
use hitgnn::perf::experiments::fig8;
use hitgnn::util::bench::Table;
use hitgnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let shift: u32 = args.num("shift", 6)?;
    let skip_host = args.flag("skip-host");
    args.finish()?;

    let counts = [1usize, 2, 4, 6, 8, 10, 12, 14, 16];
    println!("measuring host statistics (shift {shift}) and projecting...");
    let series = fig8(&counts, shift, 6)?;

    println!("\nspeedup over 1 FPGA (ogbn-products, GraphSAGE):\n");
    println!("{:>9} | {}", "FPGAs", "0        4        8        12       16");
    println!("{:->9}-+{:-<42}", "", "");
    for (algo, speedups) in &series {
        for (p, s) in counts.iter().zip(speedups) {
            let bar = "█".repeat((s * 2.5).round() as usize);
            println!("{:>9} | {bar} {s:.2}x  ({}x{p})", algo.name(), p);
        }
        println!("{:->9}-+{:-<42}", "", "");
    }

    // the knee: marginal speedup per added FPGA before/after saturation
    for (algo, s) in &series {
        let idx8 = counts.iter().position(|&p| p == 8).unwrap();
        let idx16 = counts.iter().position(|&p| p == 16).unwrap();
        let early = (s[idx8] - s[0]) / 7.0;
        let late = (s[idx16] - s[idx8]) / 8.0;
        println!(
            "{}: marginal speedup {:.2}/FPGA below 8, {:.2}/FPGA from 8→16 \
             (CPU memory bandwidth saturates at ≈12.8 FPGAs)",
            algo.name(),
            early,
            late
        );
    }

    if !skip_host {
        host_pipeline_sweep();
    }
    Ok(())
}

/// Measured host-pipeline scalability: epoch wall-clock for host-threads
/// × prefetch-depth at 4 simulated FPGAs. (1, 1) reproduces the seed's
/// serial coordinator. Uses the same canonical measurement as the
/// micro_host bench (`Trainer::pipeline_bench_epoch_wall`) so the numbers
/// stay comparable.
fn host_pipeline_sweep() {
    println!("\nmeasured host pipeline (tiny, 4 FPGAs, epoch wall seconds):\n");
    let mut table = Table::new(&["host-threads", "D=1", "D=2", "D=3"]);
    let mut serial = None;
    for ht in [1usize, 2, 4] {
        let mut cells = vec![ht.to_string()];
        for d in [1usize, 2, 3] {
            // degrade gracefully (e.g. pjrt build without artifacts):
            // the analytic projection above is still useful on its own
            let wall = match Trainer::pipeline_bench_epoch_wall(ht, d) {
                Ok(w) => w,
                Err(e) => {
                    println!("measured sweep skipped: {e:#}");
                    return;
                }
            };
            if (ht, d) == (1, 1) {
                serial = Some(wall);
            }
            match serial {
                Some(s) if wall > 0.0 => cells.push(format!("{wall:.4} ({:.2}x)", s / wall)),
                _ => cells.push(format!("{wall:.4}")),
            }
        }
        table.row(&cells);
    }
    table.print();
    println!("(speedups relative to the serial host path: 1 thread, depth 1)");
}
