//! Scalability study (paper §7.6 / Fig 8): project the training throughput
//! from 1 to 16 FPGAs and find where CPU memory bandwidth becomes the
//! limit (205 GB/s ÷ 16 GB/s PCIe ≈ 12.8 concurrent fetchers).
//!
//!     cargo run --release --example scalability [--shift 6]

use hitgnn::perf::experiments::fig8;
use hitgnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let shift: u32 = args.num("shift", 6)?;
    args.finish()?;

    let counts = [1usize, 2, 4, 6, 8, 10, 12, 14, 16];
    println!("measuring host statistics (shift {shift}) and projecting...");
    let series = fig8(&counts, shift, 6)?;

    println!("\nspeedup over 1 FPGA (ogbn-products, GraphSAGE):\n");
    println!("{:>9} | {}", "FPGAs", "0        4        8        12       16");
    println!("{:->9}-+{:-<42}", "", "");
    for (algo, speedups) in &series {
        for (p, s) in counts.iter().zip(speedups) {
            let bar = "█".repeat((s * 2.5).round() as usize);
            println!("{:>9} | {bar} {s:.2}x  ({}x{p})", algo.name(), p);
        }
        println!("{:->9}-+{:-<42}", "", "");
    }

    // the knee: marginal speedup per added FPGA before/after saturation
    for (algo, s) in &series {
        let idx8 = counts.iter().position(|&p| p == 8).unwrap();
        let idx16 = counts.iter().position(|&p| p == 16).unwrap();
        let early = (s[idx8] - s[0]) / 7.0;
        let late = (s[idx16] - s[idx8]) / 8.0;
        println!(
            "{}: marginal speedup {:.2}/FPGA below 8, {:.2}/FPGA from 8→16 \
             (CPU memory bandwidth saturates at ≈12.8 FPGAs)",
            algo.name(),
            early,
            late
        );
    }
    Ok(())
}
