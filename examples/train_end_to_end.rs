//! End-to-end training driver — the repo's headline validation run.
//!
//!     make artifacts && cargo run --release --example train_end_to_end
//!
//! Trains a 2-layer GCN on a 1/16-scale ogbn-products instance (≈153k
//! vertices, ≈7.7M directed edges after symmetrisation) across 4 simulated
//! FPGAs for several hundred synchronous iterations, logging the loss
//! curve, measured β, per-stage host times and the final train accuracy.
//! All compute flows through the AOT-compiled Pallas/JAX artifacts on the
//! PJRT CPU client; Python is not involved. The recorded run lives in
//! EXPERIMENTS.md §End-to-end.
//!
//! Flags: --dataset --model --fanouts --epochs --fpgas --scale-shift
//!        --report <file>

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::util::cli::Args;
use hitgnn::util::stats::si;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cfg = TrainConfig {
        dataset: args.str("dataset", "ogbn-products"),
        model: args.str("model", "gcn"),
        // e.g. --fanouts 15,10,5 trains the 3-layer DistDGL recipe on the
        // reference executor; default = the dataset artifact's depth
        fanouts: args
            .opt_str("fanouts")
            .map(|s| hitgnn::sampling::parse_fanouts(&s))
            .transpose()?,
        num_fpgas: args.num("fpgas", 4)?,
        epochs: args.num("epochs", 10)?,
        lr: args.num("lr", 0.1)?,
        momentum: 0.9,
        scale_shift: args.num("scale-shift", 4)?,
        seed: args.num("seed", 42)?,
        max_iterations: args
            .opt_str("max-iterations")
            .map(|s| s.parse())
            .transpose()?,
        ..TrainConfig::default()
    };
    let report_path = args.opt_str("report");
    args.finish()?;

    println!(
        "== HitGNN end-to-end: {} / {} / DistDGL on {} simulated FPGAs ==",
        cfg.dataset, cfg.model, cfg.num_fpgas
    );
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run()?;

    println!("\nloss curve (per-epoch mean):");
    for e in &report.epochs {
        let bar_len = (e.mean_loss * 12.0).min(60.0) as usize;
        println!(
            "  epoch {:>3}  loss {:>7.4}  {}  ({} iters, {:.1}s, exec-NVTPS {})",
            e.epoch,
            e.mean_loss,
            "#".repeat(bar_len),
            e.iterations,
            e.wall_seconds,
            si(e.nvtps),
        );
    }

    let first = report.epochs.first().unwrap().mean_loss;
    let last = report.last_loss();
    anyhow::ensure!(
        last < first * 0.8,
        "expected ≥20% loss reduction, got {first:.4} -> {last:.4}"
    );

    let acc = trainer.evaluate(8)?;
    let m0 = &report.epochs[0];
    println!("\nsummary:");
    println!("  loss: {first:.4} -> {last:.4}");
    println!("  train accuracy (8 fresh batches): {acc:.3}");
    println!(
        "  measured β {:.3} | traffic local {} / host {} / f2f {}",
        m0.beta,
        si(m0.local_bytes as f64),
        si(m0.host_bytes as f64),
        si(m0.f2f_bytes as f64)
    );
    println!(
        "  host time per epoch-0: sample {:.2}s gather {:.2}s execute {:.2}s sync {:.2}s",
        m0.sample_seconds, m0.gather_seconds, m0.execute_seconds, m0.sync_seconds
    );
    println!(
        "  measured mean batch shape [v_0..v_L a_1..a_L] = {:?}",
        report.mean_shape.iter().map(|x| x.round()).collect::<Vec<_>>()
    );

    if let Some(path) = report_path {
        report.save(std::path::Path::new(&path))?;
        println!("report written to {path}");
    }
    trainer.shutdown();
    Ok(())
}
