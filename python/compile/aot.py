"""AOT export: lower every (model × dataset-dims) train step + predict to
HLO **text** and write artifacts/manifest.json for the Rust runtime.

HLO text — NOT `lowered.compile()` / proto `.serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Depth: `--fanouts 15,10,5` builds L-layer artifacts (one idx/w input pair
per layer; DESIGN.md §Mini-batch wire format order — input-side hop
first). `--k1/--k2` remain as 2-layer aliases. A 3-layer SAGE tiny
artifact is exported alongside the tiny pair, mirroring the Rust builtin
manifest. `--model gat|gin` (via `--models`) export tiny artifacts only,
again mirroring the builtin manifest's zoo coverage.

Run from python/:  python -m compile.aot --out-dir ../artifacts
`make artifacts` is a no-op if the outputs are newer than the inputs.
"""

import argparse
import hashlib
import json
import os
import sys

import jax

from .model import (
    MODEL_NAMES,
    ModelDims,
    batch_order,
    example_args,
    init_params,
    make_predict,
    make_train_step,
    param_order,
)

# Mirror of the Rust dataset registry (graph/datasets.rs — Table 4 dims).
DATASETS = {
    "reddit": dict(f0=602, f1=128, f2=41),
    "yelp": dict(f0=300, f1=128, f2=100),
    "amazon": dict(f0=200, f1=128, f2=107),
    "ogbn-products": dict(f0=100, f1=128, f2=47),
}

# Small dims for runtime integration tests / quickstart.
TINY = dict(f0=32, f1=16, f2=8)

MODELS = list(MODEL_NAMES)

# gat/gin ship tiny-only artifacts (mirrors the Rust builtin manifest:
# the Table-4 dataset sweep stays gcn/sage).
TINY_ONLY_MODELS = {"gat", "gin"}


def feature_widths(d, layers):
    """[f0, f1 × (L-1), f2] — one width per level."""
    return [d["f0"]] + [d["f1"]] * (layers - 1) + [d["f2"]]


def to_hlo_text(fn, specs) -> str:
    """jitted fn + example shapes -> HLO text via stablehlo."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_name(kind: str, model: str, dataset: str, layers: int = 2) -> str:
    base = f"{kind}_{model}_{dataset.replace('-', '_')}"
    return base if layers == 2 else f"{base}_l{layers}"


def dims_dict(dims: ModelDims):
    """Manifest dims: the depth-L keys, plus the legacy 2-layer keys so
    older runtimes keep parsing default-depth artifacts."""
    d = {
        "b": dims.b,
        "fanouts": list(dims.fanouts),
        "caps": list(dims.caps),
        "f": list(dims.f),
    }
    if dims.layers == 2:
        d.update(k1=dims.k1, k2=dims.k2, v1_cap=dims.v1_cap, v0_cap=dims.v0_cap,
                 f0=dims.f0, f1=dims.f1, f2=dims.f2)
    return d


def export_entry(kind, model, dataset, dims: ModelDims, out_dir):
    fn = make_train_step(model, dims) if kind == "train" else make_predict(model, dims)
    specs = example_args(model, dims)
    text = to_hlo_text(fn, specs)
    name = entry_name(kind, model, dataset, dims.layers)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    pnames = param_order(model, dims.layers)
    params = init_params(model, dims)
    outputs = ["loss"] + [f"grad_{n}" for n in pnames] if kind == "train" else ["logits"]
    return {
        "name": name,
        "kind": kind,
        "model": model,
        "dataset": dataset,
        "file": fname,
        "dims": dims_dict(dims),
        "params": [{"name": n, "shape": list(params[n].shape)} for n in pnames],
        "inputs": pnames + batch_order(dims.layers),
        "outputs": outputs,
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }


def parse_fanouts(text):
    try:
        fanouts = [int(t.strip()) for t in text.split(",")]
    except ValueError as e:
        raise SystemExit(f"--fanouts '{text}': {e}")
    if not fanouts or any(k < 1 for k in fanouts):
        raise SystemExit(f"--fanouts '{text}': every fanout must be >= 1")
    return fanouts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=256,
                    help="target capacity B of the execution-path artifacts")
    ap.add_argument("--fanouts", default=None,
                    help="per-layer fanouts, input-side hop first "
                         "(e.g. 15,10,5); default 10,5")
    ap.add_argument("--k1", type=int, default=10,
                    help="legacy 2-layer alias: layer-1 fanout")
    ap.add_argument("--k2", type=int, default=5,
                    help="legacy 2-layer alias: layer-2 fanout")
    ap.add_argument("--datasets", default="all",
                    help="comma list or 'all' or 'tiny-only'")
    ap.add_argument("--models", default=",".join(MODELS),
                    help="comma list out of " + "|".join(MODEL_NAMES)
                         + " (gat/gin export tiny artifacts only)")
    ap.add_argument("--no-tiny", action="store_true",
                    help="skip the tiny test artifacts (incl. the 3-layer one)")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    fanouts = parse_fanouts(args.fanouts) if args.fanouts else [args.k1, args.k2]
    models = [m.strip() for m in args.models.split(",") if m.strip()]
    for m in models:
        if m not in MODEL_NAMES:
            raise SystemExit(
                f"unknown model '{m}', expected one of {'|'.join(MODEL_NAMES)}"
            )
    if args.datasets == "all":
        datasets = list(DATASETS)
    elif args.datasets == "tiny-only":
        datasets = []
    else:
        datasets = [d.strip() for d in args.datasets.split(",")]

    entries = []
    for model in models:
        for ds in (datasets if model not in TINY_ONLY_MODELS else []):
            f = DATASETS[ds]
            dims = ModelDims.from_fanouts(args.batch, fanouts,
                                          feature_widths(f, len(fanouts)))
            for kind in ("train", "predict"):
                e = export_entry(kind, model, ds, dims, args.out_dir)
                entries.append(e)
                print(f"wrote {e['file']}", file=sys.stderr)
        if not args.no_tiny:
            dims = ModelDims.from_fanouts(32, (3, 2), feature_widths(TINY, 2))
            for kind in ("train", "predict"):
                e = export_entry(kind, model, "tiny", dims, args.out_dir)
                entries.append(e)
                print(f"wrote {e['file']}", file=sys.stderr)
    if not args.no_tiny and "sage" in models:
        # 3-layer SAGE tiny artifact (mirrors the Rust builtin manifest)
        dims = ModelDims.from_fanouts(32, (3, 2, 2), feature_widths(TINY, 3))
        for kind in ("train", "predict"):
            e = export_entry(kind, "sage", "tiny", dims, args.out_dir)
            entries.append(e)
            print(f"wrote {e['file']}", file=sys.stderr)

    manifest = {
        "version": 1,
        "jax": jax.__version__,
        "batch": {"b": args.batch, "fanouts": fanouts},
        "entries": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest: {len(entries)} entries -> {args.out_dir}/manifest.json",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
