"""Layer-1 Pallas kernels (build-time only; AOT-lowered into the HLO
artifacts the Rust runtime executes)."""

from .aggregate import aggregate, aggregate_pallas, pick_block
from .update import matmul, matmul_pallas, update

__all__ = [
    "aggregate",
    "aggregate_pallas",
    "matmul",
    "matmul_pallas",
    "pick_block",
    "update",
]
