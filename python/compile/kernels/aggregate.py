"""L1 Pallas kernel: feature aggregation (the paper's scatter-gather
aggregate kernel, §5.3, re-expressed for the TPU memory hierarchy).

The FPGA design streams edges through `n` scatter-gather PEs with a BRAM
result buffer. On TPU-shaped hardware the same insight — keep the random
access on-chip — becomes a *fixed-degree weighted gather-sum*: fanout
sampling already produces fixed-K neighbor lists, so aggregation is

    out[r, :] = sum_k  w[r, k] * feat[idx[r, k], :]

tiled over (row-block × feature-column-block) with the feature tile
resident in VMEM. `interpret=True` everywhere: the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU numbers are estimated analytically
(DESIGN.md §Hardware-Adaptation).

The backward pass is supplied via `jax.custom_vjp`: d_feat is the
transposed scatter-add (the same hardware structure the FPGA uses in the
backward direction) and d_w a row-wise dot — both lower into the single
AOT HLO module.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (>= 1)."""
    if dim <= target:
        return dim
    for b in range(target, 0, -1):
        if dim % b == 0:
            return b
    return 1


def _aggregate_kernel(feat_ref, idx_ref, w_ref, o_ref):
    """One feature-column tile of the weighted gather-sum."""
    feat = feat_ref[...]        # [Vin, bc]   feature column tile (VMEM)
    idx = idx_ref[...]          # [Vout, K]
    w = w_ref[...]              # [Vout, K]
    g = jnp.take(feat, idx, axis=0)      # [Vout, K, bc] VMEM-local gather
    o_ref[...] = jnp.einsum("rk,rkc->rc", w, g, preferred_element_type=o_ref.dtype)


def aggregate_pallas(feat, idx, w, *, block_cols: int = 128):
    """Weighted gather-sum: feat [Vin,F] x idx,w [Vout,K] -> [Vout,F].

    Grid over feature-column tiles only: each step keeps one [Vin, bc]
    feature tile resident (≤ 16896×128×4 ≈ 8.6 MB — inside a TPU core's
    VMEM) and produces the full [Vout, bc] output column. This is the
    HBM→VMEM schedule replacing the paper's DDR-burst + BRAM result
    buffer, and it touches `feat` exactly once overall. (An earlier
    (row×col) grid re-sliced the feature tile per row block, which the
    interpret-mode lowering materialised as a copy per grid step —
    see EXPERIMENTS.md §Perf.)
    """
    vout, k = idx.shape
    vin, f = feat.shape
    assert w.shape == (vout, k), (w.shape, idx.shape)
    bc = pick_block(f, block_cols)
    grid = (f // bc,)
    return pl.pallas_call(
        _aggregate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((vin, bc), lambda c: (0, c)),
            pl.BlockSpec((vout, k), lambda c: (0, 0)),
            pl.BlockSpec((vout, k), lambda c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((vout, bc), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((vout, f), feat.dtype),
        interpret=True,
    )(feat, idx, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def aggregate(feat, idx, w):
    """Differentiable weighted gather-sum aggregation."""
    return aggregate_pallas(feat, idx, w)


def _aggregate_fwd(feat, idx, w):
    return aggregate_pallas(feat, idx, w), (feat, idx, w)


def _aggregate_bwd(res, ct):
    feat, idx, w = res
    # d_feat: transpose of the gather = scatter-add over neighbor slots
    d_feat = jnp.zeros_like(feat).at[idx].add(w[..., None] * ct[:, None, :])
    # d_w[r,k] = <ct[r,:], feat[idx[r,k],:]>
    d_w = jnp.einsum("rc,rkc->rk", ct, jnp.take(feat, idx, axis=0))
    return d_feat, None, d_w


aggregate.defvjp(_aggregate_fwd, _aggregate_bwd)
