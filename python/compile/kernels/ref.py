"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal. Every kernel must match its reference to float32 tolerance across
the shape/dtype sweep in python/tests/."""

import jax.numpy as jnp


def aggregate_ref(feat, idx, w):
    """out[r] = sum_k w[r,k] * feat[idx[r,k]] — no tiling, no pallas."""
    g = jnp.take(feat, idx, axis=0)          # [Vout, K, F]
    return jnp.einsum("rk,rkf->rf", w, g)


def matmul_ref(x, w):
    return x @ w


def update_ref(x, w, b):
    return x @ w + b[None, :]


def aggregate_grads_ref(feat, idx, w, ct):
    """Analytic VJP of aggregate (for gradient tests)."""
    d_feat = jnp.zeros_like(feat).at[idx].add(w[..., None] * ct[:, None, :])
    d_w = jnp.einsum("rc,rkc->rk", ct, jnp.take(feat, idx, axis=0))
    return d_feat, d_w
