"""L1 Pallas kernel: feature update (the paper's systolic-array MLP
kernel, §5.3, mapped to MXU-shaped tiled matmul).

The FPGA update kernel is an `m`-PE systolic array computing h·W. The TPU
analogue is a (bm × bn) output-tiled matmul with the full contraction
dimension resident per tile (f <= 602 everywhere in the paper, so a K-loop
is unnecessary and the MXU sees one [bm, K] x [K, bn] contraction per
tile). Tiles default to 128x128 — the MXU systolic array shape.

`matmul` carries a custom VJP so both grad GEMMs (ct @ W^T and x^T @ ct)
run through the same kernel, mirroring how the FPGA reuses its update
array in the backward pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .aggregate import pick_block


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def matmul_pallas(x, w, *, block_m: int = 128, block_n: int = 128):
    """x [M,K] @ w [K,N] -> [M,N], output-tiled for the MXU."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = pick_block(m, block_m)
    bn = pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def matmul(x, w):
    """Differentiable tiled matmul (the update kernel's GEMM core)."""
    return matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, ct):
    x, w = res
    d_x = matmul_pallas(ct, w.T)
    d_w = matmul_pallas(x.T, ct)
    return d_x, d_w


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def update(x, w, b):
    """The paper's Update(): linear transform + bias (activation applied
    by the model so XLA can fuse it with the surrounding ops)."""
    return matmul(x, w) + b[None, :]
