"""L2: the model zoo (GCN, GraphSAGE, GAT, GIN) as JAX forward/backward
train steps over the fixed-shape padded mini-batch wire format
(DESIGN.md §Mini-batch wire format), calling the L1 Pallas kernels.

Depth is a first-class parameter: an L-layer model consumes one
(idx, w) pair per layer. Levels are numbered 0..L (level L = targets,
level 0 = input features); ``fanouts[l-1]`` is the layer-l fanout with
the input-side hop first — the order is documented once in DESIGN.md.
The Rust sampler emits, per batch:

    feat0    [caps[0], f0] f32            level-0 features (host-gathered)
    idx{l}   [caps[l], fanouts[l-1]+1] i32  positions into level l-1 rows;
                                            col 0 = self   (l = 1..L)
    w{l}a    [caps[l], fanouts[l-1]+1] f32  aggregation weights (0 = pad)
    labels   [b] i32
    mask     [b] f32                      1 for real targets, 0 for padding

GCN uses the full (k+1)-wide weighted sum (self edge included in w by the
sampler, symmetric normalisation). GraphSAGE splits self and neighbors:
the neighbor mean flows through W_nbr, the self row through W_self —
equivalent to the concat formulation but keeps one kernel API. GAT
(single-head, GATv1) and GIN-ε receive *unit* wire weights (the Rust
sampler's ``WeightMode::Unit`` — w marks real vs padding only): GAT
computes per-edge attention from the transformed features and
softmaxes over each ragged neighbor list; GIN sums neighbors, adds
``(1+ε)·self``, and updates through a 2-layer MLP. The semantics here
are the forward-parity reference for the Rust ``model_ops`` stages
(``rust/src/runtime/model_ops.rs``), which are cross-checked against
their own scalar oracle and finite differences.

`train_step` = masked softmax cross-entropy + gradients in one jitted
function; this is the module that gets AOT-lowered per (model, dims).
"""

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import aggregate, matmul, update

# Canonical model names (mirrors rust runtime::MODEL_NAMES).
MODEL_NAMES = ("gcn", "sage", "gat", "gin")

# LeakyReLU slope of the GAT attention logits (the GAT paper's 0.2;
# mirrors rust model_ops::LEAKY_SLOPE).
LEAKY_SLOPE = 0.2


@dataclass(frozen=True)
class ModelDims:
    """Static shapes of one artifact (must match the Rust sampler config).

    ``fanouts``/``caps``/``f`` are per-layer/per-level tuples as in the
    Rust ``ArtifactDims`` (caps[L] == b; f[0] input width, f[L] classes).
    """

    b: int
    fanouts: Tuple[int, ...]
    caps: Tuple[int, ...]
    f: Tuple[int, ...]

    @property
    def layers(self) -> int:
        return len(self.fanouts)

    # -- legacy 2-layer accessors (tests, older tooling) -------------------
    @property
    def k1(self) -> int:
        return self.fanouts[0]

    @property
    def k2(self) -> int:
        return self.fanouts[1]

    @property
    def v1_cap(self) -> int:
        return self.caps[1]

    @property
    def v0_cap(self) -> int:
        return self.caps[0]

    @property
    def f0(self) -> int:
        return self.f[0]

    @property
    def f1(self) -> int:
        return self.f[1]

    @property
    def f2(self) -> int:
        return self.f[-1]

    @staticmethod
    def from_fanouts(b: int, fanouts, f) -> "ModelDims":
        """Depth-L constructor: capacities follow the wire-format
        recurrence caps[l-1] = caps[l]·(fanouts[l-1]+1)."""
        fanouts = tuple(fanouts)
        f = tuple(f)
        assert len(f) == len(fanouts) + 1, "need one feature width per level"
        assert fanouts and all(k >= 1 for k in fanouts), fanouts
        caps = [0] * (len(fanouts) + 1)
        caps[len(fanouts)] = b
        for l in range(len(fanouts), 0, -1):
            caps[l - 1] = caps[l] * (fanouts[l - 1] + 1)
        return ModelDims(b, fanouts, tuple(caps), f)

    @staticmethod
    def from_batch(b: int, k1: int, k2: int, f0: int, f1: int, f2: int) -> "ModelDims":
        """Legacy 2-layer constructor."""
        return ModelDims.from_fanouts(b, (k1, k2), (f0, f1, f2))


# ---------------------------------------------------------------------------
# parameter initialisation
# ---------------------------------------------------------------------------

def _glorot(key, shape):
    fan_in, fan_out = shape
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def init_params(model: str, dims: ModelDims, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Deterministic parameter pytree (dict, insertion-ordered)."""
    key = jax.random.PRNGKey(seed)
    L = dims.layers
    if model == "gcn":
        ks = jax.random.split(key, L)
        params = {}
        for l in range(1, L + 1):
            params[f"w{l}"] = _glorot(ks[l - 1], (dims.f[l - 1], dims.f[l]))
            params[f"b{l}"] = jnp.zeros((dims.f[l],), jnp.float32)
        return params
    if model == "sage":
        ks = jax.random.split(key, 2 * L)
        params = {}
        for l in range(1, L + 1):
            params[f"w{l}_self"] = _glorot(ks[2 * (l - 1)], (dims.f[l - 1], dims.f[l]))
            params[f"w{l}_nbr"] = _glorot(ks[2 * (l - 1) + 1], (dims.f[l - 1], dims.f[l]))
            params[f"b{l}"] = jnp.zeros((dims.f[l],), jnp.float32)
        return params
    if model == "gat":
        # rank-1 tensors (attention vectors, bias) start at zero, same as
        # the Rust ParamSet::init convention
        ks = jax.random.split(key, L)
        params = {}
        for l in range(1, L + 1):
            params[f"w{l}"] = _glorot(ks[l - 1], (dims.f[l - 1], dims.f[l]))
            params[f"a{l}_self"] = jnp.zeros((dims.f[l],), jnp.float32)
            params[f"a{l}_nbr"] = jnp.zeros((dims.f[l],), jnp.float32)
            params[f"b{l}"] = jnp.zeros((dims.f[l],), jnp.float32)
        return params
    if model == "gin":
        ks = jax.random.split(key, 2 * L)
        params = {}
        for l in range(1, L + 1):
            params[f"w{l}_1"] = _glorot(ks[2 * (l - 1)], (dims.f[l - 1], dims.f[l]))
            params[f"b{l}_1"] = jnp.zeros((dims.f[l],), jnp.float32)
            params[f"w{l}_2"] = _glorot(ks[2 * (l - 1) + 1], (dims.f[l], dims.f[l]))
            params[f"b{l}_2"] = jnp.zeros((dims.f[l],), jnp.float32)
            params[f"eps{l}"] = jnp.zeros((1,), jnp.float32)  # GIN-0 at step 0
        return params
    raise ValueError(f"unknown model '{model}', expected one of {'|'.join(MODEL_NAMES)}")


def param_order(model: str, layers: int = 2) -> List[str]:
    """Canonical flat ordering used by the AOT artifact interface."""
    names: List[str] = []
    for l in range(1, layers + 1):
        if model == "gcn":
            names += [f"w{l}", f"b{l}"]
        elif model == "sage":
            names += [f"w{l}_self", f"w{l}_nbr", f"b{l}"]
        elif model == "gat":
            names += [f"w{l}", f"a{l}_self", f"a{l}_nbr", f"b{l}"]
        elif model == "gin":
            names += [f"w{l}_1", f"b{l}_1", f"w{l}_2", f"b{l}_2", f"eps{l}"]
        else:
            raise ValueError(
                f"unknown model '{model}', expected one of {'|'.join(MODEL_NAMES)}"
            )
    return names


def batch_order(layers: int = 2) -> List[str]:
    """Flat batch-input ordering: feat0, per-layer (idx, w) from the
    input side up, labels, mask."""
    names = ["feat0"]
    for l in range(1, layers + 1):
        names += [f"idx{l}", f"w{l}a"]
    return names + ["labels", "mask"]


# Legacy alias: the 2-layer batch order (older tests/tools import this).
BATCH_ORDER = batch_order(2)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _gcn_layer(h, idx, w, wmat, bias, act):
    agg = aggregate(h, idx, w)            # Â·H over the sampled block
    out = update(agg, wmat, bias)         # (Â·H)·W + b
    return act(out)


def gcn_forward(params, batch) -> jnp.ndarray:
    """L-layer GCN → logits [b, f[L]] (L inferred from the params)."""
    L = len(params) // 2
    h = batch["feat0"]
    for l in range(1, L + 1):
        act = jax.nn.relu if l < L else (lambda x: x)
        h = _gcn_layer(h, batch[f"idx{l}"], batch[f"w{l}a"],
                       params[f"w{l}"], params[f"b{l}"], act)
    return h


def _sage_layer(h, idx, w, w_self, w_nbr, bias, act):
    # neighbor mean: zero the self column (col 0) of the weights
    w_n = w.at[:, 0].set(0.0)
    nbr = aggregate(h, idx, w_n)
    self_rows = jnp.take(h, idx[:, 0], axis=0)
    out = matmul(self_rows, w_self) + matmul(nbr, w_nbr) + bias[None, :]
    return act(out)


def sage_forward(params, batch) -> jnp.ndarray:
    """L-layer GraphSAGE-mean → logits [b, f[L]]."""
    L = len(params) // 3
    h = batch["feat0"]
    for l in range(1, L + 1):
        act = jax.nn.relu if l < L else (lambda x: x)
        h = _sage_layer(h, batch[f"idx{l}"], batch[f"w{l}a"],
                        params[f"w{l}_self"], params[f"w{l}_nbr"],
                        params[f"b{l}"], act)
    return h


def _gat_layer(h, idx, w, wmat, a_self, a_nbr, bias, act):
    # single-head GATv1 over the padded block: transform every below-level
    # row once, score per vertex, softmax the LeakyReLU'd logits over each
    # ragged (w != 0) neighbor list. Wire weights are the padding mask
    # only (WeightMode::Unit) — attention replaces fixed normalisation.
    ht = matmul(h, wmat)
    sself = ht @ a_self                       # [below]
    snbr = ht @ a_nbr
    logits = sself[idx[:, 0]][:, None] + snbr[idx]
    logits = jnp.where(logits > 0.0, logits, LEAKY_SLOPE * logits)
    real = w != 0.0
    masked = jnp.where(real, logits, -jnp.inf)
    m = jnp.max(masked, axis=1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)    # all-padding rows
    e = jnp.where(real, jnp.exp(masked - m), 0.0)
    denom = e.sum(axis=1, keepdims=True)
    alpha = jnp.where(denom > 0.0, e / jnp.maximum(denom, 1e-38), 0.0)
    out = aggregate(ht, idx, alpha) + bias[None, :]
    return act(out)


def gat_forward(params, batch) -> jnp.ndarray:
    """L-layer single-head GAT → logits [b, f[L]]."""
    L = len(params) // 4
    h = batch["feat0"]
    for l in range(1, L + 1):
        act = jax.nn.relu if l < L else (lambda x: x)
        h = _gat_layer(h, batch[f"idx{l}"], batch[f"w{l}a"],
                       params[f"w{l}"], params[f"a{l}_self"],
                       params[f"a{l}_nbr"], params[f"b{l}"], act)
    return h


def _gin_layer(h, idx, w, w1, b1, w2, b2, eps, act):
    # injective sum: neighbors (cols 1..k) plus (1+eps)·self, then the
    # 2-layer MLP update (relu inside the MLP, act between GNN layers)
    w_n = w.at[:, 0].set(0.0)
    s = aggregate(h, idx, w_n)
    self_rows = jnp.take(h, idx[:, 0], axis=0)
    s = s + (1.0 + eps[0]) * self_rows
    h1 = jax.nn.relu(update(s, w1, b1))
    return act(update(h1, w2, b2))


def gin_forward(params, batch) -> jnp.ndarray:
    """L-layer GIN-ε → logits [b, f[L]]."""
    L = len(params) // 5
    h = batch["feat0"]
    for l in range(1, L + 1):
        act = jax.nn.relu if l < L else (lambda x: x)
        h = _gin_layer(h, batch[f"idx{l}"], batch[f"w{l}a"],
                       params[f"w{l}_1"], params[f"b{l}_1"],
                       params[f"w{l}_2"], params[f"b{l}_2"],
                       params[f"eps{l}"], act)
    return h


FORWARD = {
    "gcn": gcn_forward,
    "sage": sage_forward,
    "gat": gat_forward,
    "gin": gin_forward,
}


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------

def loss_fn(params, batch, model: str, num_classes: int) -> jnp.ndarray:
    """Masked mean softmax cross-entropy over the real targets."""
    logits = FORWARD[model](params, batch)
    onehot = jax.nn.one_hot(batch["labels"], num_classes, dtype=jnp.float32)
    ce = -(onehot * jax.nn.log_softmax(logits, axis=-1)).sum(axis=-1)
    mask = batch["mask"]
    return (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_train_step(model: str, dims: ModelDims):
    """Flat-signature train step for AOT lowering:
    (*params, feat0, idx1, w1a, .., idxL, wLa, labels, mask)
    -> (loss, *grads).
    """
    names = param_order(model, dims.layers)
    border = batch_order(dims.layers)

    def train_step(*args):
        params = dict(zip(names, args[: len(names)]))
        fvals = args[len(names):]
        batch = dict(zip(border, fvals))
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, model, dims.f[-1])
        )(params)
        return (loss,) + tuple(grads[n] for n in names)

    return train_step


def make_predict(model: str, dims: ModelDims):
    """Flat-signature inference: (*params, feat0..mask) -> (logits,)."""
    names = param_order(model, dims.layers)
    border = batch_order(dims.layers)

    def predict(*args):
        params = dict(zip(names, args[: len(names)]))
        batch = dict(zip(border, args[len(names):]))
        logits = FORWARD[model](params, batch)
        # keep labels/mask alive in the jaxpr so the lowered artifact has
        # the same input arity as the train step (jax.jit prunes unused
        # parameters otherwise and the Rust caller feeds a fixed list)
        keep = 0.0 * (batch["mask"].sum() + batch["labels"].sum().astype(logits.dtype))
        return (logits + keep,)

    return predict


def example_args(model: str, dims: ModelDims):
    """ShapeDtypeStructs in the artifact's flat input order."""
    s = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    params = init_params(model, dims)
    specs = [s(params[n].shape, f32) for n in param_order(model, dims.layers)]
    specs.append(s((dims.caps[0], dims.f[0]), f32))          # feat0
    for l in range(1, dims.layers + 1):
        rows, k = dims.caps[l], dims.fanouts[l - 1] + 1
        specs.append(s((rows, k), i32))                      # idx{l}
        specs.append(s((rows, k), f32))                      # w{l}a
    specs.append(s((dims.b,), i32))                          # labels
    specs.append(s((dims.b,), f32))                          # mask
    return specs
