"""L2: the paper's GNN models (GCN, GraphSAGE) as JAX forward/backward
train steps over the fixed-shape padded mini-batch wire format
(DESIGN.md §Mini-batch wire format), calling the L1 Pallas kernels.

The Rust sampler emits, per batch:

    feat0  [v0_cap, f0] f32   layer-0 features (gathered by the host)
    idx1   [v1_cap, k1+1] i32 positions into feat0 rows; col 0 = self
    w1     [v1_cap, k1+1] f32 aggregation weights (0 = padding)
    idx2   [b, k2+1] i32      positions into layer-1 rows; col 0 = self
    w2     [b, k2+1] f32
    labels [b] i32
    mask   [b] f32            1 for real targets, 0 for padding

GCN uses the full (k+1)-wide weighted sum (self edge included in w by the
sampler, symmetric normalisation). GraphSAGE splits self and neighbors:
the neighbor mean flows through W_nbr, the self row through W_self —
equivalent to the concat formulation but keeps one kernel API.

`train_step` = masked softmax cross-entropy + gradients in one jitted
function; this is the module that gets AOT-lowered per (model, dims).
"""

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import aggregate, matmul, update


@dataclass(frozen=True)
class ModelDims:
    """Static shapes of one artifact (must match the Rust sampler config)."""

    b: int
    k1: int
    k2: int
    v1_cap: int
    v0_cap: int
    f0: int
    f1: int
    f2: int

    @staticmethod
    def from_batch(b: int, k1: int, k2: int, f0: int, f1: int, f2: int) -> "ModelDims":
        v1_cap = b * (k2 + 1)
        v0_cap = v1_cap * (k1 + 1)
        return ModelDims(b, k1, k2, v1_cap, v0_cap, f0, f1, f2)


# ---------------------------------------------------------------------------
# parameter initialisation
# ---------------------------------------------------------------------------

def _glorot(key, shape):
    fan_in, fan_out = shape
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def init_params(model: str, dims: ModelDims, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Deterministic parameter pytree (dict, insertion-ordered)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    f0, f1, f2 = dims.f0, dims.f1, dims.f2
    if model == "gcn":
        return {
            "w1": _glorot(ks[0], (f0, f1)),
            "b1": jnp.zeros((f1,), jnp.float32),
            "w2": _glorot(ks[1], (f1, f2)),
            "b2": jnp.zeros((f2,), jnp.float32),
        }
    if model == "sage":
        return {
            "w1_self": _glorot(ks[0], (f0, f1)),
            "w1_nbr": _glorot(ks[1], (f0, f1)),
            "b1": jnp.zeros((f1,), jnp.float32),
            "w2_self": _glorot(ks[2], (f1, f2)),
            "w2_nbr": _glorot(ks[3], (f1, f2)),
            "b2": jnp.zeros((f2,), jnp.float32),
        }
    raise ValueError(f"unknown model '{model}' (gcn|sage)")


def param_order(model: str) -> List[str]:
    """Canonical flat ordering used by the AOT artifact interface."""
    if model == "gcn":
        return ["w1", "b1", "w2", "b2"]
    if model == "sage":
        return ["w1_self", "w1_nbr", "b1", "w2_self", "w2_nbr", "b2"]
    raise ValueError(model)


BATCH_ORDER = ["feat0", "idx1", "w1a", "idx2", "w2a", "labels", "mask"]


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _gcn_layer(h, idx, w, wmat, bias, act):
    agg = aggregate(h, idx, w)            # Â·H over the sampled block
    out = update(agg, wmat, bias)         # (Â·H)·W + b
    return act(out)


def gcn_forward(params, batch) -> jnp.ndarray:
    """2-layer GCN → logits [b, f2]."""
    h1 = _gcn_layer(batch["feat0"], batch["idx1"], batch["w1a"],
                    params["w1"], params["b1"], jax.nn.relu)
    logits = _gcn_layer(h1, batch["idx2"], batch["w2a"],
                        params["w2"], params["b2"], lambda x: x)
    return logits


def _sage_layer(h, idx, w, w_self, w_nbr, bias, act):
    # neighbor mean: zero the self column (col 0) of the weights
    w_n = w.at[:, 0].set(0.0)
    nbr = aggregate(h, idx, w_n)
    self_rows = jnp.take(h, idx[:, 0], axis=0)
    out = matmul(self_rows, w_self) + matmul(nbr, w_nbr) + bias[None, :]
    return act(out)


def sage_forward(params, batch) -> jnp.ndarray:
    """2-layer GraphSAGE-mean → logits [b, f2]."""
    h1 = _sage_layer(batch["feat0"], batch["idx1"], batch["w1a"],
                     params["w1_self"], params["w1_nbr"], params["b1"], jax.nn.relu)
    logits = _sage_layer(h1, batch["idx2"], batch["w2a"],
                         params["w2_self"], params["w2_nbr"], params["b2"],
                         lambda x: x)
    return logits


FORWARD = {"gcn": gcn_forward, "sage": sage_forward}


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------

def loss_fn(params, batch, model: str, num_classes: int) -> jnp.ndarray:
    """Masked mean softmax cross-entropy over the real targets."""
    logits = FORWARD[model](params, batch)
    onehot = jax.nn.one_hot(batch["labels"], num_classes, dtype=jnp.float32)
    ce = -(onehot * jax.nn.log_softmax(logits, axis=-1)).sum(axis=-1)
    mask = batch["mask"]
    return (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_train_step(model: str, dims: ModelDims):
    """Flat-signature train step for AOT lowering:
    (*params, feat0, idx1, w1a, idx2, w2a, labels, mask) -> (loss, *grads).
    """
    names = param_order(model)

    def train_step(*args):
        params = dict(zip(names, args[: len(names)]))
        fvals = args[len(names):]
        batch = dict(zip(BATCH_ORDER, fvals))
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, model, dims.f2)
        )(params)
        return (loss,) + tuple(grads[n] for n in names)

    return train_step


def make_predict(model: str, dims: ModelDims):
    """Flat-signature inference: (*params, feat0..mask) -> (logits,)."""
    names = param_order(model)

    def predict(*args):
        params = dict(zip(names, args[: len(names)]))
        batch = dict(zip(BATCH_ORDER, args[len(names):]))
        logits = FORWARD[model](params, batch)
        # keep labels/mask alive in the jaxpr so the lowered artifact has
        # the same input arity as the train step (jax.jit prunes unused
        # parameters otherwise and the Rust caller feeds a fixed list)
        keep = 0.0 * (batch["mask"].sum() + batch["labels"].sum().astype(logits.dtype))
        return (logits + keep,)

    return predict


def example_args(model: str, dims: ModelDims):
    """ShapeDtypeStructs in the artifact's flat input order."""
    s = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    params = init_params(model, dims)
    specs = [s(params[n].shape, f32) for n in param_order(model)]
    specs += [
        s((dims.v0_cap, dims.f0), f32),           # feat0
        s((dims.v1_cap, dims.k1 + 1), i32),       # idx1
        s((dims.v1_cap, dims.k1 + 1), f32),       # w1a
        s((dims.b, dims.k2 + 1), i32),            # idx2
        s((dims.b, dims.k2 + 1), f32),            # w2a
        s((dims.b,), i32),                        # labels
        s((dims.b,), f32),                        # mask
    ]
    return specs
