"""AOT export sanity: manifest schema, artifact files, HLO text shape.
Uses tiny dims only to stay fast."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--datasets", "tiny-only", "--models", "gcn,sage"],
        cwd=ROOT, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    return out


def test_manifest_schema(tiny_artifacts):
    with open(tiny_artifacts / "manifest.json") as f:
        m = json.load(f)
    assert m["version"] == 1
    names = {e["name"] for e in m["entries"]}
    assert names == {"train_gcn_tiny", "predict_gcn_tiny",
                     "train_sage_tiny", "predict_sage_tiny",
                     "train_sage_tiny_l3", "predict_sage_tiny_l3"}
    for e in m["entries"]:
        assert (tiny_artifacts / e["file"]).exists()
        d = e["dims"]
        # depth-L recurrence: caps[L] == b, caps[l-1] = caps[l]·(k_l+1)
        L = len(d["fanouts"])
        assert len(d["caps"]) == L + 1 and len(d["f"]) == L + 1
        assert d["caps"][L] == d["b"]
        for l in range(L, 0, -1):
            assert d["caps"][l - 1] == d["caps"][l] * (d["fanouts"][l - 1] + 1)
        if L == 2:
            # legacy keys remain for older runtimes
            assert d["v1_cap"] == d["b"] * (d["k2"] + 1)
            assert d["v0_cap"] == d["v1_cap"] * (d["k1"] + 1)
        # wire order: feat0, per-layer (idx, w), labels, mask
        tail = ["feat0"]
        for l in range(1, L + 1):
            tail += [f"idx{l}", f"w{l}a"]
        tail += ["labels", "mask"]
        assert e["inputs"][-len(tail):] == tail
        if e["kind"] == "train":
            assert e["outputs"][0] == "loss"
            assert len(e["outputs"]) == 1 + len(e["params"])
        else:
            assert e["outputs"] == ["logits"]


def test_hlo_text_is_parseable_hlo(tiny_artifacts):
    with open(tiny_artifacts / "manifest.json") as f:
        m = json.load(f)
    for e in m["entries"]:
        text = (tiny_artifacts / e["file"]).read_text()
        assert text.startswith("HloModule"), e["file"]
        assert "ENTRY" in text
        # tuple return (return_tuple=True) so the rust side can unpack
        assert "tuple" in text.lower()


def test_gcn_param_shapes_in_manifest(tiny_artifacts):
    with open(tiny_artifacts / "manifest.json") as f:
        m = json.load(f)
    e = next(x for x in m["entries"] if x["name"] == "train_gcn_tiny")
    shapes = {p["name"]: p["shape"] for p in e["params"]}
    assert shapes == {"w1": [32, 16], "b1": [16], "w2": [16, 8], "b2": [8]}


def test_three_layer_sage_param_shapes(tiny_artifacts):
    with open(tiny_artifacts / "manifest.json") as f:
        m = json.load(f)
    e = next(x for x in m["entries"] if x["name"] == "train_sage_tiny_l3")
    assert e["dims"]["fanouts"] == [3, 2, 2]
    shapes = {p["name"]: p["shape"] for p in e["params"]}
    assert shapes == {
        "w1_self": [32, 16], "w1_nbr": [32, 16], "b1": [16],
        "w2_self": [16, 16], "w2_nbr": [16, 16], "b2": [16],
        "w3_self": [16, 8], "w3_nbr": [16, 8], "b3": [8],
    }
