"""L1 kernel correctness: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes with hypothesis. This is the CORE build-time
correctness signal — if these fail, the AOT artifacts are wrong."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, aggregate_pallas, matmul, matmul_pallas, pick_block
from compile.kernels.ref import aggregate_grads_ref, aggregate_ref, matmul_ref, update_ref
from compile.kernels.update import update


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


# ---------------------------------------------------------------------------
# aggregate kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    vin=st.integers(4, 200),
    vout=st.integers(1, 96),
    k=st.integers(1, 12),
    f=st.integers(1, 160),
    seed=st.integers(0, 2**31 - 1),
)
def test_aggregate_matches_ref_hypothesis(vin, vout, k, f, seed):
    kf, ki, kw = jax.random.split(jax.random.PRNGKey(seed), 3)
    feat = jax.random.normal(kf, (vin, f), dtype=jnp.float32)
    idx = jax.random.randint(ki, (vout, k), 0, vin, dtype=jnp.int32)
    w = jax.random.normal(kw, (vout, k), dtype=jnp.float32)
    got = aggregate_pallas(feat, idx, w)
    want = aggregate_ref(feat, idx, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("vin,vout,k,f", [
    (16, 8, 4, 32),        # block-aligned
    (100, 33, 11, 41),     # prime-ish dims exercise pick_block
    (1, 1, 1, 1),          # degenerate
    (512, 128, 26, 602),   # reddit-like layer-1 shape
])
def test_aggregate_fixed_shapes(vin, vout, k, f):
    feat = rand(1, (vin, f))
    idx = jax.random.randint(jax.random.PRNGKey(2), (vout, k), 0, vin, dtype=jnp.int32)
    w = rand(3, (vout, k))
    np.testing.assert_allclose(
        aggregate_pallas(feat, idx, w), aggregate_ref(feat, idx, w),
        rtol=2e-5, atol=2e-5)


def test_aggregate_zero_weights_ignore_indices():
    # padding rows carry idx=0, w=0 — they must contribute nothing
    feat = rand(1, (10, 8))
    idx = jnp.zeros((4, 3), jnp.int32)
    w = jnp.zeros((4, 3), jnp.float32)
    out = aggregate_pallas(feat, idx, w)
    np.testing.assert_array_equal(out, jnp.zeros((4, 8)))


def test_aggregate_duplicate_indices_accumulate():
    feat = jnp.ones((4, 2), jnp.float32)
    idx = jnp.array([[1, 1, 1]], jnp.int32)
    w = jnp.array([[0.5, 0.25, 0.25]], jnp.float32)
    np.testing.assert_allclose(aggregate_pallas(feat, idx, w), jnp.ones((1, 2)))


def test_aggregate_grads_match_ref():
    feat = rand(5, (20, 12))
    idx = jax.random.randint(jax.random.PRNGKey(6), (7, 4), 0, 20, dtype=jnp.int32)
    w = rand(7, (7, 4))

    def f_feat(x):
        return (aggregate(x, idx, w) ** 2).sum()

    def f_w(x):
        return (aggregate(feat, idx, x) ** 2).sum()

    ct = 2.0 * aggregate_ref(feat, idx, w)
    d_feat_ref, d_w_ref = aggregate_grads_ref(feat, idx, w, ct)
    np.testing.assert_allclose(jax.grad(f_feat)(feat), d_feat_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(jax.grad(f_w)(w), d_w_ref, rtol=1e-4, atol=1e-4)


def test_aggregate_grad_finite_difference():
    feat = rand(8, (6, 3)).astype(jnp.float64)
    idx = jnp.array([[0, 2], [4, 4], [1, 5]], jnp.int32)
    w = rand(9, (3, 2)).astype(jnp.float64)

    def loss(w_):
        return (aggregate_ref(feat, idx, w_) ** 3).sum()  # analytic path

    def loss_pallas(w_):
        return (aggregate(feat.astype(jnp.float32), idx, w_.astype(jnp.float32)) ** 3).sum()

    g_ref = jax.grad(loss)(w)
    g_pallas = jax.grad(loss_pallas)(w.astype(jnp.float32))
    np.testing.assert_allclose(g_pallas, g_ref.astype(jnp.float32), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# matmul / update kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 96),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_hypothesis(m, k, n, seed):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(ka, (m, k), dtype=jnp.float32)
    w = jax.random.normal(kb, (k, n), dtype=jnp.float32)
    np.testing.assert_allclose(
        matmul_pallas(x, w), matmul_ref(x, w), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,k,n", [(128, 602, 128), (256, 128, 47), (1, 1, 1), (33, 7, 13)])
def test_matmul_fixed_shapes(m, k, n):
    x, w = rand(1, (m, k)), rand(2, (k, n))
    np.testing.assert_allclose(matmul_pallas(x, w), matmul_ref(x, w), rtol=2e-4, atol=2e-4)


def test_update_adds_bias():
    x, w = rand(1, (8, 4)), rand(2, (4, 6))
    b = rand(3, (6,))
    np.testing.assert_allclose(update(x, w, b), update_ref(x, w, b), rtol=2e-5, atol=2e-5)


def test_matmul_grads():
    x, w = rand(4, (9, 5)), rand(5, (5, 7))

    def f(x_, w_):
        return (matmul(x_, w_) ** 2).sum()

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    ct = 2.0 * matmul_ref(x, w)
    np.testing.assert_allclose(gx, ct @ w.T, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, x.T @ ct, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------

@given(dim=st.integers(1, 4096), target=st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_pick_block_divides_and_bounded(dim, target):
    b = pick_block(dim, target)
    assert 1 <= b <= max(dim, 1)
    assert dim % b == 0
    assert b <= target or dim <= target
