"""L2 model correctness: forward shapes, pure-jnp cross-check, gradient
finite differences, masking semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import aggregate_ref, update_ref
from compile.model import (
    BATCH_ORDER,
    ModelDims,
    batch_order,
    example_args,
    gcn_forward,
    init_params,
    loss_fn,
    make_predict,
    make_train_step,
    param_order,
    sage_forward,
)

DIMS = ModelDims.from_batch(8, 3, 2, 12, 10, 5)


def rand_batch(dims: ModelDims, seed=0, n_real=None):
    """Random but structurally valid batch (self col 0, in-range indices)
    at any depth L."""
    rng = np.random.default_rng(seed)
    n_real = dims.b if n_real is None else n_real
    batch = {"feat0": jnp.asarray(
        rng.normal(size=(dims.caps[0], dims.f[0])).astype(np.float32))}
    for l in range(1, dims.layers + 1):
        rows, k = dims.caps[l], dims.fanouts[l - 1] + 1
        idx = rng.integers(0, dims.caps[l - 1], size=(rows, k)).astype(np.int32)
        idx[:, 0] = np.arange(rows) % dims.caps[l - 1]  # self column
        w = rng.uniform(0.1, 1.0, size=idx.shape).astype(np.float32)
        batch[f"idx{l}"] = jnp.asarray(idx)
        batch[f"w{l}a"] = jnp.asarray(w)
    labels = rng.integers(0, dims.f[-1], size=(dims.b,)).astype(np.int32)
    mask = np.zeros((dims.b,), np.float32)
    mask[:n_real] = 1.0
    batch["labels"] = jnp.asarray(labels)
    batch["mask"] = jnp.asarray(mask)
    return batch


def gcn_forward_ref(params, batch):
    """Forward with the oracle kernels only."""
    a1 = aggregate_ref(batch["feat0"], batch["idx1"], batch["w1a"])
    h1 = jax.nn.relu(update_ref(a1, params["w1"], params["b1"]))
    a2 = aggregate_ref(h1, batch["idx2"], batch["w2a"])
    return update_ref(a2, params["w2"], params["b2"])


@pytest.mark.parametrize("model,fwd", [("gcn", gcn_forward), ("sage", sage_forward)])
def test_forward_shapes(model, fwd):
    params = init_params(model, DIMS, seed=1)
    batch = rand_batch(DIMS)
    logits = fwd(params, batch)
    assert logits.shape == (DIMS.b, DIMS.f2)
    assert jnp.isfinite(logits).all()


def test_gcn_matches_pure_jnp_reference():
    params = init_params("gcn", DIMS, seed=2)
    batch = rand_batch(DIMS, seed=3)
    np.testing.assert_allclose(
        gcn_forward(params, batch), gcn_forward_ref(params, batch),
        rtol=5e-4, atol=5e-4)


def test_sage_self_column_is_excluded_from_neighbor_mean():
    # if all neighbor weights are zero, SAGE output depends only on self
    params = init_params("sage", DIMS, seed=4)
    batch = rand_batch(DIMS, seed=5)
    batch["w1a"] = batch["w1a"].at[:, 1:].set(0.0)
    batch["w2a"] = batch["w2a"].at[:, 1:].set(0.0)
    out = sage_forward(params, batch)
    # recompute with a pure self-path reference
    self1 = jnp.take(batch["feat0"], batch["idx1"][:, 0], axis=0)
    h1 = jax.nn.relu(self1 @ params["w1_self"] + params["b1"])
    self2 = jnp.take(h1, batch["idx2"][:, 0], axis=0)
    want = self2 @ params["w2_self"] + params["b2"]
    np.testing.assert_allclose(out, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_loss_is_finite_and_masked(model):
    params = init_params(model, DIMS, seed=6)
    full = rand_batch(DIMS, seed=7, n_real=DIMS.b)
    half = rand_batch(DIMS, seed=7, n_real=4)
    l_full = loss_fn(params, full, model, DIMS.f2)
    l_half = loss_fn(params, half, model, DIMS.f2)
    assert jnp.isfinite(l_full) and jnp.isfinite(l_half)
    # masked loss must equal the mean over only the real rows
    logits = (gcn_forward if model == "gcn" else sage_forward)(params, half)
    oh = jax.nn.one_hot(half["labels"], DIMS.f2)
    ce = -(oh * jax.nn.log_softmax(logits)).sum(-1)
    want = ce[:4].mean()
    np.testing.assert_allclose(l_half, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_train_step_outputs_and_grad_shapes(model):
    params = init_params(model, DIMS, seed=8)
    batch = rand_batch(DIMS, seed=9)
    step = make_train_step(model, DIMS)
    names = param_order(model)
    flat = [params[n] for n in names] + [batch[k] for k in BATCH_ORDER]
    out = step(*flat)
    assert len(out) == 1 + len(names)
    loss = out[0]
    assert loss.shape == () and jnp.isfinite(loss)
    for n, g in zip(names, out[1:]):
        assert g.shape == params[n].shape, n
        assert jnp.isfinite(g).all(), n


def test_gcn_gradient_finite_difference():
    params = init_params("gcn", DIMS, seed=10)
    batch = rand_batch(DIMS, seed=11)
    loss = lambda p: loss_fn(p, batch, "gcn", DIMS.f2)
    grads = jax.grad(loss)(params)
    # probe a few coordinates of w2 with central differences
    eps = 1e-3
    rng = np.random.default_rng(0)
    for _ in range(5):
        i = rng.integers(0, DIMS.f1)
        j = rng.integers(0, DIMS.f2)
        pp = {k: v.copy() for k, v in params.items()}
        pp["w2"] = pp["w2"].at[i, j].add(eps)
        pm = {k: v.copy() for k, v in params.items()}
        pm["w2"] = pm["w2"].at[i, j].add(-eps)
        fd = (loss(pp) - loss(pm)) / (2 * eps)
        np.testing.assert_allclose(grads["w2"][i, j], fd, rtol=5e-2, atol=1e-4)


def test_training_reduces_loss_on_fixed_batch():
    # a few SGD steps on one batch must reduce the loss (sanity that the
    # gradients point downhill end to end through both pallas kernels)
    model = "gcn"
    params = init_params(model, DIMS, seed=12)
    batch = rand_batch(DIMS, seed=13)
    loss = lambda p: loss_fn(p, batch, model, DIMS.f2)
    l0 = float(loss(params))
    lr = 0.5
    for _ in range(10):
        g = jax.grad(loss)(params)
        params = {k: v - lr * g[k] for k, v in params.items()}
    l1 = float(loss(params))
    assert l1 < l0 * 0.9, f"loss did not decrease: {l0} -> {l1}"


def test_example_args_match_flat_signature():
    for model in ("gcn", "sage"):
        specs = example_args(model, DIMS)
        names = param_order(model)
        assert len(specs) == len(names) + len(BATCH_ORDER)
        assert specs[len(names)].shape == (DIMS.v0_cap, DIMS.f0)
        # predict runs on the specs' shapes
        step = make_predict(model, DIMS)
        params = init_params(model, DIMS)
        batch = rand_batch(DIMS)
        flat = [params[n] for n in names] + [batch[k] for k in BATCH_ORDER]
        (logits,) = step(*flat)
        assert logits.shape == (DIMS.b, DIMS.f2)


DIMS3 = ModelDims.from_fanouts(6, (2, 2, 2), (9, 7, 7, 4))


@pytest.mark.parametrize("model,fwd", [("gcn", gcn_forward), ("sage", sage_forward)])
def test_three_layer_forward_shapes(model, fwd):
    params = init_params(model, DIMS3, seed=20)
    batch = rand_batch(DIMS3, seed=21)
    logits = fwd(params, batch)
    assert logits.shape == (DIMS3.b, DIMS3.f[-1])
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_three_layer_train_step_and_grad_shapes(model):
    params = init_params(model, DIMS3, seed=22)
    batch = rand_batch(DIMS3, seed=23)
    step = make_train_step(model, DIMS3)
    names = param_order(model, DIMS3.layers)
    flat = [params[n] for n in names] + [batch[k] for k in batch_order(DIMS3.layers)]
    out = step(*flat)
    assert len(out) == 1 + len(names)
    assert jnp.isfinite(out[0])
    for n, g in zip(names, out[1:]):
        assert g.shape == params[n].shape, n
        assert jnp.isfinite(g).all(), n


def test_three_layer_gcn_gradient_finite_difference():
    params = init_params("gcn", DIMS3, seed=24)
    batch = rand_batch(DIMS3, seed=25)
    loss = lambda p: loss_fn(p, batch, "gcn", DIMS3.f[-1])
    grads = jax.grad(loss)(params)
    eps = 1e-3
    rng = np.random.default_rng(1)
    for name in ("w1", "w2", "w3"):
        i = rng.integers(0, params[name].shape[0])
        j = rng.integers(0, params[name].shape[1])
        pp = {k: v.copy() for k, v in params.items()}
        pp[name] = pp[name].at[i, j].add(eps)
        pm = {k: v.copy() for k, v in params.items()}
        pm[name] = pm[name].at[i, j].add(-eps)
        fd = (loss(pp) - loss(pm)) / (2 * eps)
        np.testing.assert_allclose(grads[name][i, j], fd, rtol=5e-2, atol=1e-4)


def test_batch_order_and_dims_recurrence():
    assert batch_order(2) == BATCH_ORDER
    assert batch_order(3) == ["feat0", "idx1", "w1a", "idx2", "w2a",
                              "idx3", "w3a", "labels", "mask"]
    assert DIMS3.caps == (6 * 3 * 3 * 3, 6 * 3 * 3, 6 * 3, 6)
    assert param_order("sage", 3)[-1] == "b3"
    # the 2-layer legacy accessors still line up
    assert DIMS.v1_cap == DIMS.b * (DIMS.k2 + 1)
    assert DIMS.v0_cap == DIMS.v1_cap * (DIMS.k1 + 1)
