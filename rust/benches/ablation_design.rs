//! Design-choice ablations beyond the paper's Table 7:
//!
//! 1. **Sampling overlap** (Eq. 5's `max(t_sampling, t_GNN)` vs a serial
//!    host): quantifies why the paper overlaps sampling with compute.
//! 2. **Prefetching** (the paper's §8 future-work extension): hiding the
//!    host feature fetch behind compute — projected at 4 and 16 FPGAs,
//!    where the paper expects it to "relieve the stress on the CPU memory
//!    bandwidth", plus the measured effect on the real execution path.

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::graph::datasets;
use hitgnn::partition::Algorithm;
use hitgnn::perf::experiments::{build_workload, measure_host, BEST_DIE};
use hitgnn::perf::{PlatformModel, PlatformSpec};
use hitgnn::util::bench::{env_knob, Table};
use hitgnn::util::stats::si;

fn main() {
    let shift = env_knob("HITGNN_BENCH_SHIFT", 5, 6) as u32;

    // ---- 1. sampling overlap (analytic, Eq. 5) -------------------------
    let spec = datasets::lookup("ogbn-products").unwrap();
    let host = measure_host(&spec, Algorithm::DistDgl, "sage", 4, shift, 6, 31).unwrap();
    let model = PlatformModel::new(PlatformSpec::paper_4fpga(), BEST_DIE);
    let w = build_workload(&spec, Algorithm::DistDgl, "sage", &host, 4, true, true);
    let overlapped = model.epoch(&w);
    // serial host: sampling adds to, instead of overlapping, the batch time
    let mut w_serial = w.clone();
    w_serial.sampling_s_per_batch = 0.0;
    let mut serial = model.epoch(&w_serial);
    serial.epoch_s += w.sampling_s_per_batch
        * w.batches_per_part.iter().sum::<usize>() as f64
        / 4.0;
    serial.nvtps = overlapped.nvtps * overlapped.epoch_s / serial.epoch_s;

    println!("\n=== ablation 1: sampling overlapped vs serial (Eq. 5) ===");
    let mut t = Table::new(&["host model", "epoch (s)", "NVTPS"]);
    t.row(&["overlapped (paper)".into(), format!("{:.2}", overlapped.epoch_s), si(overlapped.nvtps)]);
    t.row(&["serial".into(), format!("{:.2}", serial.epoch_s), si(serial.nvtps)]);
    t.print();
    assert!(overlapped.epoch_s <= serial.epoch_s);

    // ---- 2. prefetching (§8) --------------------------------------------
    println!("\n=== ablation 2: §8 data prefetching (projected) ===");
    let mut t = Table::new(&["platform", "prefetch", "per-batch (ms)", "NVTPS"]);
    for p in [4usize, 16] {
        let mut plat = PlatformSpec::paper_4fpga();
        plat.num_fpgas = p;
        let model = PlatformModel::new(plat, BEST_DIE);
        let host = measure_host(&spec, Algorithm::DistDgl, "sage", 4, shift, 6, 31).unwrap();
        let mut w = build_workload(&spec, Algorithm::DistDgl, "sage", &host, 4, true, true);
        // re-shape batch distribution for p FPGAs
        let per = (w.batches_per_part.iter().sum::<usize>() / p).max(1);
        w.batches_per_part = vec![per; p];
        for prefetch in [false, true] {
            w.prefetch = prefetch;
            let est = model.epoch(&w);
            t.row(&[
                format!("{p} FPGAs"),
                if prefetch { "on".into() } else { "off".into() },
                format!("{:.2}", est.batch_gnn_s * 1e3),
                si(est.nvtps),
            ]);
        }
    }
    t.print();

    // prefetch must help MORE at 16 FPGAs (saturated host fetch) — the
    // paper's stated motivation
    let gain = |p: usize| {
        let mut plat = PlatformSpec::paper_4fpga();
        plat.num_fpgas = p;
        let model = PlatformModel::new(plat, BEST_DIE);
        let host = measure_host(&spec, Algorithm::DistDgl, "sage", 4, shift, 6, 31).unwrap();
        let mut w = build_workload(&spec, Algorithm::DistDgl, "sage", &host, 4, true, true);
        let per = (w.batches_per_part.iter().sum::<usize>() / p).max(1);
        w.batches_per_part = vec![per; p];
        let off = model.epoch(&w).nvtps;
        w.prefetch = true;
        model.epoch(&w).nvtps / off
    };
    let (g4, g16) = (gain(4), gain(16));
    println!("\nprefetch gain: {:.2}x at p=4, {:.2}x at p=16", g4, g16);
    assert!(g16 >= g4 * 0.99, "prefetch should matter most when host fetch saturates");

    // ---- 3. prefetching on the real execution path ----------------------
    println!("\n=== ablation 3: prefetch on the real PJRT path (tiny, 2 workers) ===");
    let mut t = Table::new(&["prefetch", "epoch wall (s)", "loss after 2 epochs"]);
    for prefetch in [false, true] {
        let cfg = TrainConfig {
            dataset: "tiny".into(),
            model: "gcn".into(),
            num_fpgas: 2,
            epochs: 2,
            scale_shift: 0,
            seed: 3,
            prefetch,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg).expect("trainer");
        let report = trainer.run().expect("train");
        t.row(&[
            if prefetch { "on".into() } else { "off".into() },
            format!("{:.3}", report.epochs.iter().map(|e| e.wall_seconds).sum::<f64>()),
            format!("{:.4}", report.last_loss()),
        ]);
        trainer.shutdown();
    }
    t.print();
    println!("(numerics are identical: prefetching only reorders host work)");
}
