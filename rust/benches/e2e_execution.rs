//! End-to-end execution-path benchmark: the real pipeline (sample →
//! gather → PJRT train step → gradient sync → SGD) on scaled datasets.
//! This is CPU-PJRT wall clock — NOT the FPGA projection (that's the
//! platform model's job); it demonstrates that the L3 host path keeps the
//! workers fed and reports the per-stage breakdown used by §Perf.

use hitgnn::coordinator::{TrainConfig, Trainer};
use hitgnn::partition::Algorithm;
use hitgnn::util::bench::{self, Table};
use hitgnn::util::stats::si;

fn main() {
    let quick = bench::quick();
    let mut t = Table::new(&[
        "dataset",
        "model",
        "iters",
        "wall (s)",
        "NVTPS (CPU exec)",
        "sample (s)",
        "gather (s)",
        "execute (s)",
        "beta",
    ]);
    let cells: Vec<(&str, &str, u32, usize)> = if quick {
        vec![("tiny", "gcn", 0, 8)]
    } else {
        vec![
            ("tiny", "gcn", 0, 16),
            ("ogbn-products", "gcn", 4, 8),
            ("ogbn-products", "sage", 4, 8),
            ("yelp", "gcn", 4, 8),
        ]
    };
    println!("\n=== e2e execution path (real PJRT workers, 4 simulated FPGAs) ===");
    for (dataset, model, shift, iters) in cells {
        let cfg = TrainConfig {
            dataset: dataset.into(),
            model: model.into(),
            algo: Algorithm::DistDgl,
            num_fpgas: if dataset == "tiny" { 2 } else { 4 },
            epochs: 1,
            scale_shift: shift,
            seed: 7,
            max_iterations: Some(iters),
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg).expect("trainer (run `make artifacts`)");
        let report = trainer.run().expect("epoch");
        let m = &report.epochs[0];
        t.row(&[
            dataset.to_string(),
            model.to_uppercase(),
            m.iterations.to_string(),
            format!("{:.2}", m.wall_seconds),
            si(m.nvtps),
            format!("{:.2}", m.sample_seconds),
            format!("{:.2}", m.gather_seconds),
            format!("{:.2}", m.execute_seconds),
            format!("{:.3}", m.beta),
        ]);
        trainer.shutdown();
    }
    t.print();
    println!(
        "\nnote: execute is CPU-PJRT time across workers; on the modeled U250s \
         the same batches take ~5-8 ms (see table6 bench)."
    );
}
