//! Fig 7: the DSE engine's sweep of the (n, m) design space for
//! GraphSAGE, averaged over the four datasets — printed as a grid of
//! estimated NVTPS (the paper shows this as a surface plot).

use hitgnn::dse::{paper_dse_workloads, DseEngine};
use hitgnn::perf::PlatformSpec;
use hitgnn::util::bench::{self, Table};
use hitgnn::util::stats::si;

fn main() {
    let mut engine = DseEngine::new(PlatformSpec::paper_4fpga());
    // per-die m granularity for the printed grid (coarser under
    // HITGNN_BENCH_QUICK: same optimum region, far fewer points)
    engine.m_step = if bench::quick() { 128 } else { 32 };
    let workloads = paper_dse_workloads(2.0);
    let res = engine.explore(&workloads).expect("sweep");

    println!("\n=== Fig 7: DSE sweep (GraphSAGE, 4-dataset average) ===");
    println!(
        "search space: n ≤ {} per die, m ≤ {} per die; {} feasible points\n",
        res.n_max,
        res.m_max,
        res.grid.len()
    );

    // grid: rows = n (FPGA-level), cols = m (FPGA-level)
    let mut ns: Vec<u32> = res.grid.iter().map(|p| p.n_fpga).collect();
    ns.sort_unstable();
    ns.dedup();
    let mut ms: Vec<u32> = res.grid.iter().map(|p| p.m_fpga).collect();
    ms.sort_unstable();
    ms.dedup();
    // cap printed columns for readability
    let shown_ms: Vec<u32> = ms
        .iter()
        .copied()
        .filter(|m| m % 256 == 0 || *m == *ms.last().unwrap() || *m == ms[0])
        .collect();

    let mut headers = vec!["n \\ m".to_string()];
    headers.extend(shown_ms.iter().map(|m| m.to_string()));
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&headers_ref);
    for &n in &ns {
        let mut row = vec![n.to_string()];
        for &m in &shown_ms {
            let cell = res
                .grid
                .iter()
                .find(|p| p.n_fpga == n && p.m_fpga == m)
                .map(|p| si(p.throughput))
                .unwrap_or_else(|| "-".into());
            row.push(cell);
        }
        t.row(&row);
    }
    t.print();

    println!(
        "\nbest: (n,m) = ({}, {}) @ {} NVTPS  [paper: (8,2048) @ 97.0 M]",
        res.best.n_fpga,
        res.best.m_fpga,
        si(res.best.throughput)
    );
    // Fig 7 shape: the optimum invests heavily in update parallelism; it
    // must not sit at maximal aggregation parallelism (the paper's
    // headline observation about (8,2048) vs (16,1024)).
    assert!(
        res.best.n_fpga < ns[ns.len() - 1] || ns.len() == 1,
        "best design should not need maximal aggregation parallelism"
    );
}
