//! Fig 8: scalability — speedup over one FPGA as the platform grows to 16
//! FPGAs, per algorithm (ogbn-products, GraphSAGE). β is re-measured at
//! every p because partitioning into more parts lowers locality.
//!
//! Paper: near-linear scaling to 16 FPGAs, limited by CPU memory
//! bandwidth (205/16 ≈ 12.8 concurrent PCIe fetchers).

use hitgnn::perf::experiments::fig8;
use hitgnn::util::bench::{env_knob, Table};

fn main() {
    // quick mode halves the measured graph once more; the scaling *shape*
    // (and so the asserts below) is preserved — only β moves slightly
    let shift = env_knob("HITGNN_BENCH_SHIFT", 5, 6) as u32;
    let counts = [1usize, 2, 4, 8, 12, 16];
    eprintln!("measuring β per FPGA count at shift {shift}...");
    let series = fig8(&counts, shift, 6).expect("fig8");

    println!("\n=== Fig 8: scalability (speedup vs 1 FPGA, ogbn-products GSG) ===");
    let mut headers = vec!["algorithm".to_string()];
    headers.extend(counts.iter().map(|p| format!("p={p}")));
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&href);
    for (algo, speedups) in &series {
        let mut row = vec![algo.name().to_string()];
        row.extend(speedups.iter().map(|s| format!("{s:.2}x")));
        t.row(&row);
    }
    t.print();

    for (algo, s) in &series {
        // monotone non-decreasing up to 16
        for w in s.windows(2) {
            assert!(w[1] >= w[0] * 0.98, "{}: speedup regressed: {s:?}", algo.name());
        }
        // near-linear at p=4 (≥3x), clearly sublinear marginal gain at 16
        let idx4 = counts.iter().position(|&p| p == 4).unwrap();
        assert!(s[idx4] > 2.8, "{}: poor 4-FPGA scaling: {s:?}", algo.name());
        let idx8 = counts.iter().position(|&p| p == 8).unwrap();
        let idx16 = counts.iter().position(|&p| p == 16).unwrap();
        let marginal_8_16 = (s[idx16] - s[idx8]) / (16.0 - 8.0);
        let marginal_1_8 = (s[idx8] - s[0]) / 7.0;
        assert!(
            marginal_8_16 <= marginal_1_8 * 1.05,
            "{}: expected CPU-bandwidth-limited tail: {s:?}",
            algo.name()
        );
    }
    println!("\nshape check OK: monotone, ≥2.8x at p=4, diminishing marginal gain past 8");
}
