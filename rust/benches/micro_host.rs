//! Host-side micro benchmarks: the components on the coordinator's
//! critical path (sampler, partitioner, scheduler, feature gather, JSON).
//! These feed the §Perf analysis in EXPERIMENTS.md: sampling must outpace
//! the simulated-FPGA batch time for Eq. 5 to be compute-bound.

use hitgnn::comm::{CommConfig, FeatureService};
use hitgnn::coordinator::Trainer;
use hitgnn::fpga::parse_fleet;
use hitgnn::fpga::timing::{BatchShape, ModelCost};
use hitgnn::graph::datasets;
use hitgnn::partition::{preprocess, Algorithm};
use hitgnn::perf::experiments::{measure_host_policy, table7_fleet};
use hitgnn::perf::{FleetModel, Workload};
use hitgnn::sampling::{FanoutConfig, Sampler, WeightMode};
use hitgnn::sched::{SchedMode, TwoStageScheduler};
use hitgnn::store::CachePolicy;
use hitgnn::util::bench::{black_box, env_knob, Bench, Table};
use hitgnn::util::json::Json;
use hitgnn::util::rng::Rng;

fn main() {
    let mut b = Bench::new("micro_host");

    // --- dataset build (R-MAT + CSR) -----------------------------------
    let shift = env_knob("HITGNN_BENCH_SHIFT", 5, 6) as u32;
    let spec = datasets::lookup("ogbn-products").unwrap();
    let m = b
        .measure(&format!("build ogbn-products shift={shift} (R-MAT+CSR)"), |i| {
            black_box(spec.build(shift, i as u64))
        })
        .median_s;
    let data = spec.build(shift, 17);
    b.throughput("  edge construction", data.graph.num_edges() as f64, m, "edges");

    // --- partitioner ----------------------------------------------------
    let m = b
        .measure("LDG multi-constraint partition p=4", |i| {
            black_box(preprocess(Algorithm::DistDgl, &data, 4, 0.2, i as u64))
        })
        .median_s;
    b.throughput("  partitioning", data.graph.num_vertices() as f64, m, "vertices");

    // --- sampler (the Eq. 5 critical path) ------------------------------
    let pre = preprocess(Algorithm::DistDgl, &data, 4, 0.2, 17);
    let cfg = FanoutConfig::new(1024, &[25, 10]);
    let mut sampler = Sampler::new(cfg, WeightMode::GcnNorm, data.graph.num_vertices(), 3);
    let targets: Vec<u32> = pre.train_parts[0]
        .iter()
        .copied()
        .take(1024)
        .collect();
    let ms = b
        .measure("sample B=1024 fanout 25/10", |i| {
            // vary seq so every repetition samples a distinct batch (the
            // keyed RNG would otherwise replay identical neighbor picks)
            black_box(sampler.sample(&data, &targets, 0, i))
        })
        .median_s;
    let mb = sampler.sample(&data, &targets, 0, 0);
    b.throughput("  sampling", mb.vertices_traversed() as f64, ms, "vertices");
    println!(
        "  (per-batch sampling {:.2} ms vs paper-model FPGA batch ≈ 5–8 ms → sampling overlaps)",
        ms * 1e3
    );

    // --- feature gather --------------------------------------------------
    let svc = FeatureService::new(&data.features, CommConfig::default());
    let mg = b
        .measure("gather feat0 (v0 x 100 f32)", |_| {
            black_box(svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0))
        })
        .median_s;
    b.throughput(
        "  gather",
        (mb.n[0] * data.features.bytes_per_vertex()) as f64,
        mg,
        "bytes",
    );

    // --- scheduler --------------------------------------------------------
    b.measure("two-stage scheduler: 10k-batch epoch plan (p=16)", |_| {
        let mut s = TwoStageScheduler::new(16, true);
        let counts: Vec<usize> = (0..16).map(|i| 600 + i * 5).collect();
        black_box(s.plan_epoch(&counts))
    });

    // --- json (manifest-sized) ---------------------------------------------
    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = manifest_text {
        b.measure("parse artifacts/manifest.json", |_| {
            black_box(Json::parse(&text).unwrap())
        });
    }

    // --- prng ---------------------------------------------------------------
    b.measure("xoshiro256** 1M draws", |i| {
        let mut r = Rng::new(i as u64);
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc = acc.wrapping_add(r.next_u64());
        }
        acc
    });

    b.finish();

    kernel_sweep();
    model_sweep();
    cache_policy_sweep();
    scheduler_sweep();
    depth_sweep();
    pipeline_sweep();
}

/// Kernel sweep (ISSUE 5 + ISSUE 7 acceptance): per-batch
/// reference-executor train-step latency — scalar oracle vs the blocked
/// portable path vs the AVX2+FMA SIMD tier — at the default 2-layer
/// [25, 10] and 3-layer [9, 5, 4] fanout shapes (B=256, real sampled
/// batches on the bundled tiny dataset). The dispatcher resolves to SIMD
/// by default where supported, so each column pins the tier explicitly
/// via `kernels::set_tier`. Asserts blocked ≥ 2× scalar and (where
/// AVX2+FMA is detected) SIMD ≥ 1.5× blocked, then reports the
/// steady-state allocation counts (0 with the pooled hot path; measured
/// exactly when built with `--features alloc-count`).
fn kernel_sweep() {
    use hitgnn::coordinator::params::ParamSet;
    use hitgnn::runtime::kernels::{self, Tier};
    use hitgnn::runtime::manifest::synth_entry;
    use hitgnn::runtime::{BatchBuffers, RefModel};

    println!("\n=== bench: kernel sweep (scalar vs blocked vs SIMD reference executor) ===");
    let data = datasets::lookup("tiny").unwrap().build(0, 17);
    let pre = preprocess(Algorithm::DistDgl, &data, 2, 0.2, 17);
    let svc = FeatureService::new(&data.features, CommConfig::default());
    let b_size = 256usize;
    // the resolved tier honors both CPU detection and HITGNN_NO_SIMD
    let entry_tier = kernels::active_tier();
    let simd = entry_tier == Tier::Avx2Fma;
    let cases: [(&str, Vec<usize>); 2] =
        [("L=2 [25,10]", vec![25, 10]), ("L=3 [9,5,4]", vec![9, 5, 4])];
    let mut t =
        Table::new(&["shape", "scalar (ms)", "blocked (ms)", "simd (ms)", "simd/blocked"]);
    for (label, fanouts) in cases {
        let entry = synth_entry(
            std::path::Path::new("/tmp"),
            "train",
            "gcn",
            "tiny",
            b_size,
            &fanouts,
            data.spec.dims,
        );
        let mut model = RefModel::new(&entry).expect("reference model");
        let params = ParamSet::init(&entry, 7).data;
        let cfg = FanoutConfig::new(b_size, &fanouts);
        cfg.validate().expect("bench fanouts");
        let mut sampler = Sampler::new(cfg, WeightMode::GcnNorm, data.graph.num_vertices(), 3);
        let take = pre.train_parts[0].len().min(b_size);
        let targets: Vec<u32> = pre.train_parts[0][..take].to_vec();
        let mb = sampler.sample(&data, &targets, 0, 0);
        let (feat0, _) = svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0);
        let batch = BatchBuffers::from_minibatch(&mb, feat0, entry.dims.f0());

        let mut bench = Bench::new(&format!("kernels {label}"));
        let scalar_s = bench
            .measure("scalar train_step", |_| {
                black_box(model.train_step_scalar(&params, &batch).unwrap())
            })
            .median_s;
        assert!(kernels::set_tier(Tier::Blocked), "blocked tier always available");
        let blocked_s = bench
            .measure("blocked train_step", |_| {
                black_box(model.train_step(&params, &batch).unwrap())
            })
            .median_s;
        let simd_s = if simd {
            assert!(kernels::set_tier(Tier::Avx2Fma), "detected SIMD tier refused");
            Some(
                bench
                    .measure("simd train_step", |_| {
                        black_box(model.train_step(&params, &batch).unwrap())
                    })
                    .median_s,
            )
        } else {
            None
        };
        bench.finish();
        let speedup = scalar_s / blocked_s;
        let simd_ratio = simd_s.map(|s| blocked_s / s);
        t.row(&[
            label.to_string(),
            format!("{:.3}", scalar_s * 1e3),
            format!("{:.3}", blocked_s * 1e3),
            simd_s.map_or("n/a".into(), |s| format!("{:.3}", s * 1e3)),
            simd_ratio.map_or("n/a".into(), |r| format!("{r:.2}x")),
        ]);
        assert!(
            speedup >= 2.0,
            "{label}: blocked executor must be ≥2x the scalar path (got {speedup:.2}x)"
        );
        if let Some(r) = simd_ratio {
            assert!(
                r >= 1.5,
                "{label}: SIMD tier must be ≥1.5x the blocked path (got {r:.2}x)"
            );
        }
    }
    // restore whatever tier the process entered with
    assert!(kernels::set_tier(entry_tier));
    t.print();
    println!("  blocked ≥2x scalar on every shape ✓");
    if simd {
        println!("  AVX2+FMA tier ≥1.5x blocked on every shape ✓");
    } else {
        println!("  SIMD column skipped (AVX2+FMA unavailable or HITGNN_NO_SIMD set)");
    }
    alloc_report(&data, &pre);
    println!("=== end bench: kernel sweep ===");
}

/// Model-zoo sweep (ISSUE 8 acceptance): per-batch reference-executor
/// train-step latency across the four architectures at one matched shape
/// (B=256, fanouts [25, 10], tiny feature widths, real sampled batches
/// under each model's own weight mode), next to the §6.2 modeled FPGA
/// batch time priced with each model's [`ModelCost`]. Asserts the
/// attention model's modeled batch sits strictly above matched-shape GCN
/// — the edge-score term must be visible to the scheduler and DSE.
fn model_sweep() {
    use hitgnn::coordinator::params::ParamSet;
    use hitgnn::fpga::timing::TimingModel;
    use hitgnn::runtime::manifest::synth_entry;
    use hitgnn::runtime::{BatchBuffers, RefModel, MODEL_NAMES};

    println!("\n=== bench: model-zoo sweep (matched shape, per-batch train step) ===");
    let data = datasets::lookup("tiny").unwrap().build(0, 17);
    let pre = preprocess(Algorithm::DistDgl, &data, 2, 0.2, 17);
    let svc = FeatureService::new(&data.features, CommConfig::default());
    let b_size = 256usize;
    let fanouts = vec![25usize, 10];
    let gd = data.spec.dims;
    let widths = [gd.f0 as f64, gd.f1 as f64, gd.f2 as f64];
    let timing = TimingModel::new(hitgnn::fpga::U250, hitgnn::fpga::DEFAULT_DIE, 16.0);
    let shape = BatchShape::nominal(b_size as f64, &[25.0, 10.0], &widths);
    let gcn_modeled = timing.batch(&shape, 0.75, ModelCost::GCN).gnn_s;
    let mut t = Table::new(&[
        "model",
        "train step (ms)",
        "modeled FPGA batch (ms)",
        "vs gcn model",
    ]);
    for model_name in MODEL_NAMES {
        let entry = synth_entry(
            std::path::Path::new("/tmp"),
            "train",
            model_name,
            "tiny",
            b_size,
            &fanouts,
            gd,
        );
        let mut model = RefModel::new(&entry).expect("reference model");
        let params = ParamSet::init(&entry, 7).data;
        let cfg = FanoutConfig::new(b_size, &fanouts);
        let mode = WeightMode::for_model(model_name).expect("zoo weight mode");
        let mut sampler = Sampler::new(cfg, mode, data.graph.num_vertices(), 3);
        let take = pre.train_parts[0].len().min(b_size);
        let targets: Vec<u32> = pre.train_parts[0][..take].to_vec();
        let mb = sampler.sample(&data, &targets, 0, 0);
        let (feat0, _) = svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0);
        let batch = BatchBuffers::from_minibatch(&mb, feat0, entry.dims.f0());
        let mut bench = Bench::new(&format!("model {model_name}"));
        let step_s = bench
            .measure("train_step", |_| {
                black_box(model.train_step(&params, &batch).unwrap())
            })
            .median_s;
        bench.finish();
        let cost = ModelCost::for_model(model_name).expect("zoo cost");
        let modeled = timing.batch(&shape, 0.75, cost).gnn_s;
        if model_name == "gat" {
            assert!(
                modeled > gcn_modeled,
                "attention modeled batch must exceed matched-shape gcn \
                 ({modeled} !> {gcn_modeled})"
            );
        }
        t.row(&[
            model_name.to_string(),
            format!("{:.3}", step_s * 1e3),
            format!("{:.3}", modeled * 1e3),
            format!("{:.2}x", modeled / gcn_modeled),
        ]);
    }
    t.print();
    println!("  attention modeled batch strictly above matched-shape gcn ✓");
    println!("=== end bench: model-zoo sweep ===");
}

/// Sampler+gather steady-state allocation count, measured through the
/// counting global allocator when built with `--features alloc-count`
/// (same canonical protocol as `tests/alloc_steady_state.rs` — see
/// `comm::audit_sampler_gather_allocs`).
#[cfg(feature = "alloc-count")]
fn alloc_report(data: &hitgnn::graph::Dataset, pre: &hitgnn::partition::Preprocessed) {
    let take = pre.train_parts[0].len().min(128);
    let targets = &pre.train_parts[0][..take];
    let iters = 32usize;
    let allocs = hitgnn::comm::audit_sampler_gather_allocs(
        data,
        pre.stores[0].as_ref(),
        pre.vertex_part.as_deref(),
        FanoutConfig::new(128, &[10, 5]),
        targets,
        5,
        4,
        iters,
    );
    println!(
        "  sampler+gather steady-state allocations/iteration: {} ({allocs} over {iters} iters)",
        allocs as f64 / iters as f64
    );
    assert_eq!(allocs, 0, "sampler+gather steady state must be allocation-free");
    // ISSUE 7 + ISSUE 8: the whole iteration, gradients and fused sync
    // included, for every model-zoo architecture
    let iters = 16usize;
    for model in hitgnn::runtime::MODEL_NAMES {
        let allocs = hitgnn::coordinator::audit::audit_full_iteration_allocs(model, 2, 4, iters);
        println!(
            "  {model} full-iteration steady-state allocations/iteration: {} \
             ({allocs} over {iters} iters)",
            allocs as f64 / iters as f64
        );
        assert_eq!(
            allocs, 0,
            "{model}: full training iteration steady state must be allocation-free"
        );
    }
}

#[cfg(not(feature = "alloc-count"))]
fn alloc_report(_data: &hitgnn::graph::Dataset, _pre: &hitgnn::partition::Preprocessed) {
    println!(
        "  sampler+gather / full-iteration steady-state allocations: rebuild with \
         --features alloc-count to measure (asserted 0 in tests/alloc_steady_state.rs)"
    );
}

/// Scheduler sweep (ISSUE 3 acceptance): simulated epoch makespan-seconds
/// on heterogeneous fleets under {no WB, batch-count WB, cost-aware WB}.
/// The fleets mix full U250s with half/quarter-populated cards; the batch
/// profiles have the stage-2 tails where assignment policy matters
/// (batch-count hands extras to idle devices in index order — i.e. to the
/// slow cards first on the `u250-half:2,u250:2` fleet — while cost-aware
/// assignment picks the least-estimated-finish-time device). Asserts the
/// cost-aware makespan is strictly below batch-count on every profile.
fn scheduler_sweep() {
    println!("\n=== bench: scheduler sweep (heterogeneous fleets, modeled makespan-seconds) ===");
    let spec = datasets::lookup("ogbn-products").unwrap();
    let shape = BatchShape::nominal(
        1024.0,
        &[25.0, 10.0],
        &[spec.dims.f0 as f64, spec.dims.f1 as f64, spec.dims.f2 as f64],
    );
    let base_w = |batches_per_part: Vec<usize>, wb: bool| Workload {
        shape: shape.clone(),
        beta: 0.75,
        cost: ModelCost::GCN,
        sampling_s_per_batch: 2e-3,
        batches_per_part,
        workload_balancing: wb,
        direct_host_fetch: true,
        extra_pcie_bytes_per_batch: 0.0,
        prefetch: false,
        disk_gbs: 0.0,
        disk_miss_frac: 0.0,
    };
    // (fleet, per-partition batch counts): tail-heavy profiles — the long
    // partitions live on *fast* devices, so stage 2 has extras to place
    let cases: [(&str, Vec<usize>); 2] = [
        ("u250-half:2,u250:2", vec![6, 6, 20, 6]),
        ("u250:2,u250-quarter:2", vec![20, 20, 6, 6]),
    ];
    let mut t = Table::new(&[
        "fleet",
        "batches/part",
        "no WB (s)",
        "batch-count WB (s)",
        "cost WB (s)",
        "cost vs batch-count",
    ]);
    for (fleet_spec, counts) in cases {
        let fm = FleetModel::new(parse_fleet(fleet_spec).unwrap(), 205.0);
        let off = fm.epoch(&base_w(counts.clone(), false), SchedMode::BatchCount);
        let bc = fm.epoch(&base_w(counts.clone(), true), SchedMode::BatchCount);
        let ca = fm.epoch(&base_w(counts.clone(), true), SchedMode::Cost);
        assert!(
            ca.makespan_seconds < bc.makespan_seconds,
            "{fleet_spec}: cost-aware WB must strictly reduce makespan-seconds \
             (cost {} !< batch-count {})",
            ca.makespan_seconds,
            bc.makespan_seconds
        );
        assert!(
            ca.makespan_seconds <= off.makespan_seconds,
            "{fleet_spec}: cost-aware WB worse than no WB"
        );
        t.row(&[
            fleet_spec.to_string(),
            format!("{counts:?}"),
            format!("{:.4}", off.makespan_seconds),
            format!("{:.4}", bc.makespan_seconds),
            format!("{:.4}", ca.makespan_seconds),
            format!("{:+.2}%", (ca.makespan_seconds / bc.makespan_seconds - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!("  cost-aware WB strictly below batch-count WB on every fleet ✓");

    // Table-7 experiment path on the half fleet: measured host statistics
    // (β, dedup, sampling) per dataset, engineered tail profile
    let shift = env_knob("HITGNN_BENCH_SHIFT", 5, 6) as u32;
    let n_batches = env_knob("HITGNN_BENCH_BATCHES", 8, 4);
    let fleet = parse_fleet("u250-half:2,u250:2").unwrap();
    let profile = [6usize, 6, 20, 6];
    let rows =
        table7_fleet(&fleet, 205.0, shift, n_batches, Some(&profile[..])).expect("table7_fleet");
    let mut t = Table::new(&[
        "Data-Model",
        "no WB (s)",
        "batch-count WB (s)",
        "cost WB (s)",
        "cost gain",
    ]);
    let mut strict = 0usize;
    for r in &rows {
        if r.makespan_cost_s < r.makespan_batch_s {
            strict += 1;
        }
        t.row(&[
            format!("{}-{}", r.dataset, r.model.to_uppercase()),
            format!("{:.4}", r.makespan_base_s),
            format!("{:.4}", r.makespan_batch_s),
            format!("{:.4}", r.makespan_cost_s),
            format!("{:.2}%", r.cost_gain_pct()),
        ]);
    }
    t.print();
    assert_eq!(
        strict,
        rows.len(),
        "cost-aware WB must strictly reduce makespan-seconds on every measured row"
    );
    println!("=== end bench: scheduler sweep ===");
}

/// Cache-policy sweep (ISSUE 2 acceptance): per-epoch measured β for the
/// static PaGraph cache vs the dynamic LFU/hotness and sliding-window
/// policies at equal `cache_ratio`, on the Table-4 datasets. Batches are
/// keyed by (seed, epoch, batch) only, so the comparison is paired: epoch
/// 0 is identical across policies and later epochs isolate the
/// re-ranking. Asserts the LFU policy ends strictly above static PaGraph
/// on at least two datasets.
fn cache_policy_sweep() {
    let shift = env_knob("HITGNN_BENCH_SHIFT", 5, 6) as u32;
    let n_batches = env_knob("HITGNN_BENCH_BATCHES", 24, 12);
    let epochs = 3usize;
    let ratio = 0.1f64;
    println!(
        "\n=== bench: cache-policy sweep (PaGraph partitioning, cache_ratio {ratio}, shift {shift}, {n_batches} batches x {epochs} epochs) ==="
    );
    let mut t = Table::new(&["dataset", "policy", "beta per epoch", "final beta", "vs static"]);
    let mut lfu_strict_wins = 0usize;
    for spec in &datasets::REGISTRY {
        let mut static_beta = f64::NAN;
        for policy in CachePolicy::ALL {
            let h = measure_host_policy(
                spec, Algorithm::PaGraph, "gcn", 4, shift, n_batches, 17, policy, ratio, epochs,
            )
            .expect("measure_host_policy");
            if policy == CachePolicy::Static {
                static_beta = h.beta;
            }
            let delta = if policy == CachePolicy::Static {
                "-".to_string()
            } else {
                format!("{:+.4}", h.beta - static_beta)
            };
            if policy == CachePolicy::Lfu && h.beta > static_beta {
                lfu_strict_wins += 1;
            }
            t.row(&[
                spec.key.to_string(),
                policy.name().to_string(),
                h.beta_epochs.iter().map(|b| format!("{b:.4}")).collect::<Vec<_>>().join(" → "),
                format!("{:.4}", h.beta),
                delta,
            ]);
        }
    }
    t.print();
    assert!(
        lfu_strict_wins >= 2,
        "LFU must beat static PaGraph β on ≥2 Table-4 datasets (won on {lfu_strict_wins})"
    );
    println!(
        "  LFU/hotness strictly above static PaGraph on {lfu_strict_wins}/{} datasets ✓",
        datasets::REGISTRY.len()
    );
    println!("=== end bench: cache-policy sweep ===");
}

/// Depth sweep (ISSUE 4): sampling cost and modeled per-batch FPGA time
/// at L ∈ {2, 3} holding per-batch work roughly equal — [25, 10] gives a
/// level-0 capacity of B·11·26 = 286·B rows, [9, 5, 4] gives
/// B·5·6·10 = 300·B rows — so the comparison isolates *depth*, not
/// volume. Depth is thereby visible in the experiment drivers: deeper
/// models pay one more aggregate/update stage in the §6.2 model and one
/// more dedup pass in the sampler.
fn depth_sweep() {
    let shift = env_knob("HITGNN_BENCH_SHIFT", 5, 6) as u32;
    println!("\n=== bench: depth sweep (equal per-batch work, ogbn-products shift {shift}) ===");
    let spec = datasets::lookup("ogbn-products").unwrap();
    let data = spec.build(shift, 17);
    let pre = preprocess(Algorithm::DistDgl, &data, 4, 0.2, 17);
    let widths2 = [spec.dims.f0 as f64, spec.dims.f1 as f64, spec.dims.f2 as f64];
    let widths3 =
        [spec.dims.f0 as f64, spec.dims.f1 as f64, spec.dims.f1 as f64, spec.dims.f2 as f64];
    let cases: [(&str, Vec<usize>, &[f64]); 2] = [
        ("L=2 [25,10]", vec![25, 10], &widths2),
        ("L=3 [9,5,4]", vec![9, 5, 4], &widths3),
    ];
    let timing = hitgnn::fpga::timing::TimingModel::new(
        hitgnn::fpga::U250,
        hitgnn::fpga::DEFAULT_DIE,
        16.0,
    );
    let mut t = Table::new(&[
        "depth",
        "v0_cap",
        "sample (ms)",
        "verts/batch",
        "modeled FPGA batch (ms)",
    ]);
    for (label, fanouts, widths) in cases {
        let cfg = FanoutConfig::new(1024, &fanouts);
        cfg.validate().expect("bench fanouts");
        let dims = cfg.dims();
        let mut sampler =
            Sampler::new(cfg, WeightMode::GcnNorm, data.graph.num_vertices(), 3);
        let targets: Vec<u32> = pre.train_parts[0].iter().copied().take(1024).collect();
        let mut bench = Bench::new("depth");
        let ms = bench
            .measure(&format!("sample {label}"), |i| {
                black_box(sampler.sample(&data, &targets, 0, i))
            })
            .median_s;
        let mb = sampler.sample(&data, &targets, 0, 0);
        let fanouts_f: Vec<f64> = fanouts.iter().map(|&k| k as f64).collect();
        let shape = BatchShape::nominal(1024.0, &fanouts_f, widths);
        let gnn_s = timing.batch(&shape, 0.75, ModelCost::GCN).gnn_s;
        assert!(gnn_s > 0.0);
        t.row(&[
            label.to_string(),
            dims.v0_cap().to_string(),
            format!("{:.2}", ms * 1e3),
            mb.vertices_traversed().to_string(),
            format!("{:.3}", gnn_s * 1e3),
        ]);
    }
    t.print();
    println!("=== end bench: depth sweep ===");
}

/// Host-pipeline benchmark (ISSUE 1 acceptance): epoch wall-clock over a
/// host-threads × prefetch-depth grid on the bundled synthetic dataset,
/// 4 simulated FPGAs. (1, 1) is the seed's serial path; the headline
/// comparison is (4, 2) vs (1, 1).
fn pipeline_sweep() {
    println!("\n=== bench: host pipeline (tiny, 4 FPGAs, full epoch) ===");
    let serial = match Trainer::pipeline_bench_epoch_wall(1, 1) {
        Ok(s) => s,
        Err(e) => {
            println!("  skipped: {e:#}");
            return;
        }
    };
    let mut t = Table::new(&["host-threads", "prefetch-depth", "epoch wall (s)", "speedup"]);
    let mut headline = 0.0f64;
    for ht in [1usize, 2, 4] {
        for d in [1usize, 2, 3] {
            if (ht, d) == (1, 1) {
                t.row(&["1".into(), "1".into(), format!("{serial:.4}"), "1.00x (serial baseline)".into()]);
                continue;
            }
            match Trainer::pipeline_bench_epoch_wall(ht, d) {
                Ok(s) => {
                    let speedup = serial / s;
                    if (ht, d) == (4, 2) {
                        headline = speedup;
                    }
                    t.row(&[
                        ht.to_string(),
                        d.to_string(),
                        format!("{s:.4}"),
                        format!("{speedup:.2}x"),
                    ]);
                }
                Err(e) => t.row(&[ht.to_string(), d.to_string(), format!("error: {e:#}"), "-".into()]),
            }
        }
    }
    t.print();
    if headline > 0.0 {
        println!(
            "  headline: --host-threads 4 --prefetch-depth 2 → {headline:.2}x over the serial path"
        );
    }
    println!("=== end bench: host pipeline ===");
}
