//! Host-side micro benchmarks: the components on the coordinator's
//! critical path (sampler, partitioner, scheduler, feature gather, JSON).
//! These feed the §Perf analysis in EXPERIMENTS.md: sampling must outpace
//! the simulated-FPGA batch time for Eq. 5 to be compute-bound.

use hitgnn::comm::{CommConfig, FeatureService};
use hitgnn::coordinator::Trainer;
use hitgnn::graph::datasets;
use hitgnn::partition::{preprocess, Algorithm};
use hitgnn::perf::experiments::measure_host_policy;
use hitgnn::sampling::{FanoutConfig, Sampler, WeightMode};
use hitgnn::sched::TwoStageScheduler;
use hitgnn::store::CachePolicy;
use hitgnn::util::bench::{black_box, Bench, Table};
use hitgnn::util::json::Json;
use hitgnn::util::rng::Rng;

fn main() {
    let mut b = Bench::new("micro_host");

    // --- dataset build (R-MAT + CSR) -----------------------------------
    let spec = datasets::lookup("ogbn-products").unwrap();
    let m = b
        .measure("build ogbn-products shift=5 (R-MAT+CSR)", |i| {
            black_box(spec.build(5, i as u64))
        })
        .median_s;
    let data = spec.build(5, 17);
    b.throughput("  edge construction", data.graph.num_edges() as f64, m, "edges");

    // --- partitioner ----------------------------------------------------
    let m = b
        .measure("LDG multi-constraint partition p=4", |i| {
            black_box(preprocess(Algorithm::DistDgl, &data, 4, 0.2, i as u64))
        })
        .median_s;
    b.throughput("  partitioning", data.graph.num_vertices() as f64, m, "vertices");

    // --- sampler (the Eq. 5 critical path) ------------------------------
    let pre = preprocess(Algorithm::DistDgl, &data, 4, 0.2, 17);
    let cfg = FanoutConfig { batch_size: 1024, k1: 25, k2: 10 };
    let mut sampler = Sampler::new(cfg, WeightMode::GcnNorm, data.graph.num_vertices(), 3);
    let targets: Vec<u32> = pre.train_parts[0]
        .iter()
        .copied()
        .take(1024)
        .collect();
    let ms = b
        .measure("sample B=1024 fanout 25/10", |i| {
            // vary seq so every repetition samples a distinct batch (the
            // keyed RNG would otherwise replay identical neighbor picks)
            black_box(sampler.sample(&data, &targets, 0, i))
        })
        .median_s;
    let mb = sampler.sample(&data, &targets, 0, 0);
    b.throughput("  sampling", mb.vertices_traversed() as f64, ms, "vertices");
    println!(
        "  (per-batch sampling {:.2} ms vs paper-model FPGA batch ≈ 5–8 ms → sampling overlaps)",
        ms * 1e3
    );

    // --- feature gather --------------------------------------------------
    let svc = FeatureService::new(&data.features, CommConfig::default());
    let mg = b
        .measure("gather feat0 (v0 x 100 f32)", |_| {
            black_box(svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0))
        })
        .median_s;
    b.throughput(
        "  gather",
        (mb.n_v0 * data.features.bytes_per_vertex()) as f64,
        mg,
        "bytes",
    );

    // --- scheduler --------------------------------------------------------
    b.measure("two-stage scheduler: 10k-batch epoch plan (p=16)", |_| {
        let mut s = TwoStageScheduler::new(16, true);
        let counts: Vec<usize> = (0..16).map(|i| 600 + i * 5).collect();
        black_box(s.plan_epoch(&counts))
    });

    // --- json (manifest-sized) ---------------------------------------------
    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = manifest_text {
        b.measure("parse artifacts/manifest.json", |_| {
            black_box(Json::parse(&text).unwrap())
        });
    }

    // --- prng ---------------------------------------------------------------
    b.measure("xoshiro256** 1M draws", |i| {
        let mut r = Rng::new(i as u64);
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc = acc.wrapping_add(r.next_u64());
        }
        acc
    });

    b.finish();

    cache_policy_sweep();
    pipeline_sweep();
}

/// Cache-policy sweep (ISSUE 2 acceptance): per-epoch measured β for the
/// static PaGraph cache vs the dynamic LFU/hotness and sliding-window
/// policies at equal `cache_ratio`, on the Table-4 datasets. Batches are
/// keyed by (seed, epoch, batch) only, so the comparison is paired: epoch
/// 0 is identical across policies and later epochs isolate the
/// re-ranking. Asserts the LFU policy ends strictly above static PaGraph
/// on at least two datasets.
fn cache_policy_sweep() {
    let shift: u32 = std::env::var("HITGNN_BENCH_SHIFT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    let n_batches: usize = std::env::var("HITGNN_BENCH_BATCHES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let epochs = 3usize;
    let ratio = 0.1f64;
    println!(
        "\n=== bench: cache-policy sweep (PaGraph partitioning, cache_ratio {ratio}, shift {shift}, {n_batches} batches x {epochs} epochs) ==="
    );
    let mut t = Table::new(&["dataset", "policy", "beta per epoch", "final beta", "vs static"]);
    let mut lfu_strict_wins = 0usize;
    for spec in &datasets::REGISTRY {
        let mut static_beta = f64::NAN;
        for policy in CachePolicy::ALL {
            let h = measure_host_policy(
                spec, Algorithm::PaGraph, "gcn", 4, shift, n_batches, 17, policy, ratio, epochs,
            )
            .expect("measure_host_policy");
            if policy == CachePolicy::Static {
                static_beta = h.beta;
            }
            let delta = if policy == CachePolicy::Static {
                "-".to_string()
            } else {
                format!("{:+.4}", h.beta - static_beta)
            };
            if policy == CachePolicy::Lfu && h.beta > static_beta {
                lfu_strict_wins += 1;
            }
            t.row(&[
                spec.key.to_string(),
                policy.name().to_string(),
                h.beta_epochs.iter().map(|b| format!("{b:.4}")).collect::<Vec<_>>().join(" → "),
                format!("{:.4}", h.beta),
                delta,
            ]);
        }
    }
    t.print();
    assert!(
        lfu_strict_wins >= 2,
        "LFU must beat static PaGraph β on ≥2 Table-4 datasets (won on {lfu_strict_wins})"
    );
    println!(
        "  LFU/hotness strictly above static PaGraph on {lfu_strict_wins}/{} datasets ✓",
        datasets::REGISTRY.len()
    );
    println!("=== end bench: cache-policy sweep ===");
}

/// Host-pipeline benchmark (ISSUE 1 acceptance): epoch wall-clock over a
/// host-threads × prefetch-depth grid on the bundled synthetic dataset,
/// 4 simulated FPGAs. (1, 1) is the seed's serial path; the headline
/// comparison is (4, 2) vs (1, 1).
fn pipeline_sweep() {
    println!("\n=== bench: host pipeline (tiny, 4 FPGAs, full epoch) ===");
    let serial = match Trainer::pipeline_bench_epoch_wall(1, 1) {
        Ok(s) => s,
        Err(e) => {
            println!("  skipped: {e:#}");
            return;
        }
    };
    let mut t = Table::new(&["host-threads", "prefetch-depth", "epoch wall (s)", "speedup"]);
    let mut headline = 0.0f64;
    for ht in [1usize, 2, 4] {
        for d in [1usize, 2, 3] {
            if (ht, d) == (1, 1) {
                t.row(&["1".into(), "1".into(), format!("{serial:.4}"), "1.00x (serial baseline)".into()]);
                continue;
            }
            match Trainer::pipeline_bench_epoch_wall(ht, d) {
                Ok(s) => {
                    let speedup = serial / s;
                    if (ht, d) == (4, 2) {
                        headline = speedup;
                    }
                    t.row(&[
                        ht.to_string(),
                        d.to_string(),
                        format!("{s:.4}"),
                        format!("{speedup:.2}x"),
                    ]);
                }
                Err(e) => t.row(&[ht.to_string(), d.to_string(), format!("error: {e:#}"), "-".into()]),
            }
        }
    }
    t.print();
    if headline > 0.0 {
        println!(
            "  headline: --host-threads 4 --prefetch-depth 2 → {headline:.2}x over the serial path"
        );
    }
    println!("=== end bench: host pipeline ===");
}
