//! Table 5: resource utilization & estimated throughput of the two
//! saturating accelerator configurations, FPGA-level (8,2048) vs
//! (16,1024), GraphSAGE, averaged over the four datasets.
//!
//! Paper values: (8,2048): LUT 72% DSP 90% URAM 48% BRAM 40%, 97.0 M
//! NVTPS; (16,1024): LUT 65% DSP 56% URAM 34% BRAM 28%, 92.6 M NVTPS.

use hitgnn::dse::{paper_dse_workloads, DseEngine};
use hitgnn::perf::PlatformSpec;
use hitgnn::util::bench::{self, Table};
use hitgnn::util::stats::si;

fn main() {
    if bench::quick() {
        // nothing to shrink: two analytic design-point evaluations
        println!("(HITGNN_BENCH_QUICK: analytic bench, already smoke-scale)");
    }
    let engine = DseEngine::new(PlatformSpec::paper_4fpga());
    let workloads = paper_dse_workloads(2.0); // GraphSAGE
    let configs = [(8u32, 2048u32), (16u32, 1024u32)];

    println!("\n=== Table 5: resource utilization and parallelism ===");
    let mut t = Table::new(&[
        "Parallelism (n,m)",
        "LUTs",
        "DSPs",
        "URAM",
        "BRAM",
        "Est. Throughput (NVTPS)",
    ]);
    let mut points = Vec::new();
    for (n, m) in configs {
        let p = engine
            .evaluate_fpga_config(n, m, &workloads)
            .expect("config must be feasible");
        t.row(&[
            format!("({n},{m})"),
            format!("{:.0}%", p.utilization.lut * 100.0),
            format!("{:.0}%", p.utilization.dsp * 100.0),
            format!("{:.0}%", p.utilization.uram * 100.0),
            format!("{:.0}%", p.utilization.bram * 100.0),
            si(p.throughput),
        ]);
        points.push(p);
    }
    t.print();
    println!(
        "\npaper: (8,2048) 72/90/48/40% @ 97.0 M — (16,1024) 65/56/34/28% @ 92.6 M"
    );
    assert!(
        points[0].throughput > points[1].throughput,
        "(8,2048) must out-perform (16,1024) as in the paper"
    );
    println!(
        "shape check OK: (8,2048) beats (16,1024) by {:.1}%",
        (points[0].throughput / points[1].throughput - 1.0) * 100.0
    );
}
