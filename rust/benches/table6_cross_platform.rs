//! Table 6: cross-platform comparison — epoch time, throughput (NVTPS)
//! and bandwidth efficiency for DistDGL / PaGraph / P3 × {GCN, GraphSAGE}
//! × 4 datasets, 4 GPUs (analytic baseline) vs 4 FPGAs (HitGNN).
//!
//! Host-side statistics (β, partition shares, dedup, sampling time) are
//! measured with the real partitioner + sampler on scaled graphs
//! (HITGNN_BENCH_SHIFT, default 4 = 1/16 scale); the platform model runs
//! at full scale. Accept: *shape* — who wins, by roughly what factor.
//! Paper geo-mean speedups: DistDGL 2.11×, PaGraph 2.28×, P3 2.34×;
//! BW-efficiency ratios 13.4× / 14.6× / 14.9×.

use hitgnn::partition::Algorithm;
use hitgnn::perf::experiments::{table6, CrossPlatformRow};
use hitgnn::util::bench::{env_knob, Table};
use hitgnn::util::stats::{geo_mean, si};

fn main() {
    let shift = env_knob("HITGNN_BENCH_SHIFT", 4, 6) as u32;
    let n_batches = env_knob("HITGNN_BENCH_BATCHES", 8, 4);
    eprintln!("measuring host statistics at shift {shift} ({n_batches} batches/cell)...");
    let rows = table6(4, shift, n_batches).expect("table6");

    println!("\n=== Table 6: cross-platform comparison (4 GPUs vs 4 FPGAs) ===");
    for algo in Algorithm::ALL {
        let sub: Vec<&CrossPlatformRow> = rows.iter().filter(|r| r.algo == algo).collect();
        println!("\n--- {} ---", algo.name());
        let mut t = Table::new(&[
            "dataset",
            "model",
            "epoch GPU (s)",
            "epoch Ours (s)",
            "NVTPS GPU",
            "NVTPS Ours",
            "BWeff GPU",
            "BWeff Ours",
            "speedup",
        ]);
        for r in &sub {
            t.row(&[
                r.dataset.to_string(),
                r.model.to_uppercase(),
                format!("{:.2}", r.gpu.epoch_s),
                format!("{:.2}", r.ours.epoch_s),
                si(r.gpu.nvtps),
                si(r.ours.nvtps),
                si(r.gpu.bw_efficiency),
                si(r.ours.bw_efficiency),
                format!("{:.2}x", r.ours.nvtps / r.gpu.nvtps),
            ]);
        }
        t.print();
        let g_gpu = geo_mean(&sub.iter().map(|r| r.gpu.nvtps).collect::<Vec<_>>());
        let g_ours = geo_mean(&sub.iter().map(|r| r.ours.nvtps).collect::<Vec<_>>());
        let e_gpu = geo_mean(&sub.iter().map(|r| r.gpu.bw_efficiency).collect::<Vec<_>>());
        let e_ours = geo_mean(&sub.iter().map(|r| r.ours.bw_efficiency).collect::<Vec<_>>());
        println!(
            "geo-mean: NVTPS {} vs {} (speedup {:.2}x) | BW-eff {} vs {} ({:.1}x)",
            si(g_gpu),
            si(g_ours),
            g_ours / g_gpu,
            si(e_gpu),
            si(e_ours),
            e_ours / e_gpu
        );
        let paper = match algo {
            Algorithm::DistDgl => (2.11, 13.4),
            Algorithm::PaGraph => (2.28, 14.6),
            Algorithm::P3 => (2.34, 14.9),
        };
        println!("paper:    speedup {:.2}x | BW-eff {:.1}x", paper.0, paper.1);
        // shape assertions: HitGNN wins on every row, and the BW-eff ratio
        // exceeds the raw speedup by the platform bandwidth ratio
        for r in &sub {
            assert!(
                r.ours.nvtps > r.gpu.nvtps,
                "{} {} {}: FPGA should win",
                algo.name(),
                r.model,
                r.dataset
            );
        }
    }
    // max single-cell claims (abstract: up to 4.26x speedup, 27.21x BW-eff)
    let max_speedup = rows
        .iter()
        .map(|r| r.ours.nvtps / r.gpu.nvtps)
        .fold(f64::MIN, f64::max);
    let max_bweff = rows
        .iter()
        .map(|r| r.ours.bw_efficiency / r.gpu.bw_efficiency)
        .fold(f64::MIN, f64::max);
    println!(
        "\nmax single-cell: speedup {max_speedup:.2}x (paper ≤4.26x), \
         BW-eff {max_bweff:.2}x (paper ≤27.21x)"
    );
}
