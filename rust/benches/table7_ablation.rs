//! Table 7: throughput improvement due to the WB (two-stage scheduling)
//! and DC (direct host fetch) optimizations — DistDGL, 4 FPGAs.
//!
//! Paper: WB+DC delivers 51–66% total improvement over the baseline.

use hitgnn::perf::experiments::table7_with_policy;
use hitgnn::store::CachePolicy;
use hitgnn::util::bench::{env_knob, Table};
use hitgnn::util::stats::si;

fn main() {
    let shift = env_knob("HITGNN_BENCH_SHIFT", 4, 6) as u32;
    let n_batches = env_knob("HITGNN_BENCH_BATCHES", 8, 4);
    // β is measured per epoch under the selected feature-store policy;
    // the steady-state value parameterises Eq. 7 (paper config = static).
    let policy = std::env::var("HITGNN_CACHE_POLICY")
        .ok()
        .map(|s| CachePolicy::parse(&s).expect("HITGNN_CACHE_POLICY"))
        .unwrap_or(CachePolicy::Static);
    let epochs = if policy.is_dynamic() { 3 } else { 1 };
    eprintln!("measuring host statistics at shift {shift} (cache policy {})...", policy.name());
    let rows = table7_with_policy(4, shift, n_batches, policy, 0.2, epochs).expect("table7");

    println!(
        "\n=== Table 7: throughput improvement due to optimizations (DistDGL, {} store) ===",
        policy.name()
    );
    let mut t = Table::new(&["Data-Model", "Baseline", "WB", "WB+DC", "Speedup"]);
    for r in &rows {
        let abbrev = match r.dataset {
            "reddit" => "RD",
            "yelp" => "YP",
            "amazon" => "AM",
            "ogbn-products" => "PR",
            other => other,
        };
        t.row(&[
            format!("{}-{}", abbrev, r.model.to_uppercase()),
            si(r.baseline),
            si(r.wb),
            si(r.wb_dc),
            format!("{:.0}%", r.speedup_pct()),
        ]);
    }
    t.print();
    println!("\npaper speedups: RD 63/55%, YP 65/52%, AM 64/51%, PR 66/54% (GCN/GSG)");

    for r in &rows {
        assert!(r.wb >= r.baseline, "WB must not hurt: {r:?}");
        assert!(r.wb_dc > r.wb, "DC must help under DistDGL: {r:?}");
        assert!(
            r.speedup_pct() > 10.0,
            "combined optimizations should be substantial: {r:?}"
        );
    }
    println!("shape check OK: WB ≤ WB+DC on every row, all speedups > 10%");
}
