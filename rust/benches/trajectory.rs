//! Perf-trajectory harness: the canonical machine-readable benchmark run.
//!
//! Emits four `hitgnn-bench-v1` JSON files (into `HITGNN_BENCH_OUT`,
//! default the working directory; see `bench/compare.py` for diffing):
//!
//! - `BENCH_host.json`    — host-pipeline epoch wall clock over the
//!   (host-threads × prefetch-depth) grid, plus measured NVTPS.
//! - `BENCH_kernels.json` — scalar vs blocked vs AVX2+FMA SIMD
//!   reference-executor train-step latency at L ∈ {2, 3} (the SIMD rows
//!   appear only where the tier is available and not disabled via
//!   `HITGNN_NO_SIMD`).
//! - `BENCH_sync.json`    — the gradient-sync tail: serial
//!   `average_grads` + `Sgd::step` baseline vs the fused
//!   `GradReducer::reduce` + `Sgd::step_fused` path at 1 and N reduction
//!   threads, on a ~1M-element synthetic parameter set, plus the
//!   pooled-vs-unpooled (`--no-pool`) gradient-buffer ablation.
//! - `BENCH_models.json`  — the model-zoo sweep: measured end-to-end
//!   NVTPS per architecture (gcn, sage, gat, gin) on the tiny dataset at
//!   the headline pipeline configuration, tagged with the resolved kernel
//!   tier so trajectory diffs can tell a zoo regression from a dispatch
//!   change.
//! - `BENCH_io.json`      — the out-of-core storage trajectory: mmap-pack
//!   vs in-memory epoch wall clock (numerics bit-identical, asserted),
//!   plus the DRAM-tier policy sweep (static / lfu / window at a fixed
//!   `--dram-ratio`) with per-epoch DRAM hit rates and disk bytes, so
//!   trajectory diffs can tell a tiering regression from a pipeline one.
//! - `BENCH_tune.json`    — the closed-loop auto-tune acceptance sweep: a
//!   hand-swept static (host-threads × prefetch-depth × sched) grid on a
//!   `u250:2,u250-half:2` fleet vs an 8-epoch `--auto-tune on` trajectory
//!   starting from the worst corner (1, 1, batch-count). The tuner's own
//!   objective (`epoch_s = wall + modeled makespan`, crate::tune) scores
//!   both sides; `converged_1_05` records whether the trajectory reached
//!   ≤ 1.05× the best static configuration.
//!
//! - `BENCH_robustness.json` — the fault-tolerance trajectory: epoch
//!   wall with `--checkpoint-dir` on vs off (snapshot overhead, absolute
//!   `checkpoint_seconds` included), and a degraded `u250:2,u250-half:2`
//!   fleet (one board lost mid-epoch via `--fault-plan`) vs healthy —
//!   modeled makespan, wall clock, and the quarantine/reassignment
//!   counters. Same-plan determinism is asserted inline.
//!
//! `HITGNN_BENCH_QUICK` shrinks every section to CI smoke scale.

use hitgnn::coordinator::{EpochMetrics, TrainConfig, Trainer};
use hitgnn::fpga::parse_fleet;
use hitgnn::partition::Algorithm;
use hitgnn::sched::SchedMode;
use hitgnn::tune::AutoTuneMode;
use hitgnn::util::bench::{self, black_box, Bench, BenchSuite};
use hitgnn::util::json::Json;

fn main() {
    let out = bench::out_dir();
    host_suite(&out).expect("host suite");
    kernels_suite(&out).expect("kernels suite");
    models_suite(&out).expect("models suite");
    sync_suite(&out).expect("sync suite");
    io_suite(&out).expect("io suite");
    tune_suite(&out).expect("tune suite");
    robustness_suite(&out).expect("robustness suite");
}

/// BENCH_host.json: pipeline epoch wall over the knob grid. The wall
/// clock is measured inside the trainer (epoch 1 of 2, setup excluded)
/// via `Trainer::pipeline_bench_epoch_wall`, so samples are recorded
/// rather than re-timed here; the helper's warm-up epoch replaces the
/// harness warmup.
fn host_suite(out: &std::path::Path) -> anyhow::Result<()> {
    let mut suite = BenchSuite::new("host");
    let mut b = Bench::new("host_pipeline");
    let grid: &[(usize, usize)] =
        if bench::quick() { &[(1, 1), (4, 2)] } else { &[(1, 1), (2, 2), (4, 2)] };
    for &(ht, pd) in grid {
        let mut samples = Vec::with_capacity(b.iters());
        for _ in 0..b.iters() {
            samples.push(Trainer::pipeline_bench_epoch_wall(ht, pd)?);
        }
        b.record(&format!("epoch_wall ht={ht} pd={pd}"), &samples);
    }

    // measured NVTPS at the headline configuration
    let cfg = TrainConfig {
        dataset: "tiny".into(),
        model: "gcn".into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 4,
        epochs: 2,
        scale_shift: 0,
        seed: 11,
        host_threads: 4,
        prefetch_depth: 2,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run()?;
    let m = report.epochs.last().expect("two epochs");
    b.throughput(
        "NVTPS (tiny, ht=4 pd=2)",
        m.vertices_traversed as f64,
        m.wall_seconds,
        "vertices",
    );
    trainer.shutdown();

    suite.add(&b);
    b.finish();
    suite.write(out)?;
    Ok(())
}

/// BENCH_kernels.json: scalar vs blocked vs SIMD reference-executor
/// train step (same protocol as the micro_host kernel sweep, minus the
/// assertions — this file is for trajectory diffing, not acceptance).
/// The dispatcher resolves to SIMD by default where supported, so each
/// column pins the tier explicitly via `kernels::set_tier`.
fn kernels_suite(out: &std::path::Path) -> anyhow::Result<()> {
    use hitgnn::comm::{CommConfig, FeatureService};
    use hitgnn::coordinator::params::ParamSet;
    use hitgnn::graph::datasets;
    use hitgnn::partition::preprocess;
    use hitgnn::runtime::kernels::{self, Tier};
    use hitgnn::runtime::manifest::synth_entry;
    use hitgnn::runtime::{BatchBuffers, RefModel};
    use hitgnn::sampling::{FanoutConfig, Sampler, WeightMode};

    let mut suite = BenchSuite::new("kernels");
    let data = datasets::lookup("tiny")?.build(0, 17);
    let pre = preprocess(Algorithm::DistDgl, &data, 2, 0.2, 17);
    let svc = FeatureService::new(&data.features, CommConfig::default());
    let b_size = 256usize;
    // the resolved tier honors both CPU detection and HITGNN_NO_SIMD
    let entry_tier = kernels::active_tier();
    let simd = entry_tier == Tier::Avx2Fma;
    suite.extra(
        "kernel_dispatch",
        Json::obj(vec![
            ("resolved_tier", Json::str(entry_tier.name())),
            ("simd_column", Json::Bool(simd)),
        ]),
    );
    let cases: Vec<(&str, Vec<usize>)> = if bench::quick() {
        vec![("L=2 [25,10]", vec![25, 10])]
    } else {
        vec![("L=2 [25,10]", vec![25, 10]), ("L=3 [9,5,4]", vec![9, 5, 4])]
    };
    for (label, fanouts) in cases {
        let entry = synth_entry(
            std::path::Path::new("/tmp"),
            "train",
            "gcn",
            "tiny",
            b_size,
            &fanouts,
            data.spec.dims,
        );
        let mut model = RefModel::new(&entry)?;
        let params = ParamSet::init(&entry, 7).data;
        let cfg = FanoutConfig::new(b_size, &fanouts);
        cfg.validate()?;
        let mut sampler = Sampler::new(cfg, WeightMode::GcnNorm, data.graph.num_vertices(), 3);
        let take = pre.train_parts[0].len().min(b_size);
        let targets: Vec<u32> = pre.train_parts[0][..take].to_vec();
        let mb = sampler.sample(&data, &targets, 0, 0);
        let (feat0, _) = svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0);
        let batch = BatchBuffers::from_minibatch(&mb, feat0, entry.dims.f0());

        let mut bk = Bench::new(&format!("kernels {label}"));
        let scalar_s = bk
            .measure(&format!("scalar train_step {label}"), |_| {
                black_box(model.train_step_scalar(&params, &batch).unwrap())
            })
            .median_s;
        assert!(kernels::set_tier(Tier::Blocked));
        let blocked_s = bk
            .measure(&format!("blocked train_step {label}"), |_| {
                black_box(model.train_step(&params, &batch).unwrap())
            })
            .median_s;
        let simd_s = if simd {
            assert!(kernels::set_tier(Tier::Avx2Fma));
            Some(
                bk.measure(&format!("simd train_step {label}"), |_| {
                    black_box(model.train_step(&params, &batch).unwrap())
                })
                .median_s,
            )
        } else {
            None
        };
        assert!(kernels::set_tier(entry_tier));
        bk.throughput(
            &format!("blocked throughput {label}"),
            mb.vertices_traversed() as f64,
            blocked_s,
            "vertices",
        );
        println!("  speedup {label}: blocked {:.2}x over scalar", scalar_s / blocked_s);
        if let Some(s) = simd_s {
            println!("  speedup {label}: simd {:.2}x over blocked", blocked_s / s);
        }
        suite.add(&bk);
        bk.finish();
    }
    suite.write(out)?;
    Ok(())
}

/// BENCH_models.json: end-to-end trainer NVTPS for every model-zoo
/// architecture at the headline pipeline configuration (tiny, 4 FPGAs,
/// ht=4 pd=2 — matching the `host` suite's NVTPS row so the gcn entries
/// are comparable across files). Tagged with the resolved kernel tier:
/// the attention kernels have their own blocked/SIMD implementations, so
/// a dispatch change moves these numbers without any zoo regression.
fn models_suite(out: &std::path::Path) -> anyhow::Result<()> {
    use hitgnn::runtime::kernels;
    use hitgnn::runtime::MODEL_NAMES;

    let quick = bench::quick();
    let mut suite = BenchSuite::new("models");
    let mut b = Bench::new("model_zoo");
    suite.extra(
        "kernel_dispatch",
        Json::obj(vec![("resolved_tier", Json::str(kernels::active_tier().name()))]),
    );
    let mut rows = Vec::new();
    for model in MODEL_NAMES {
        let cfg = TrainConfig {
            dataset: "tiny".into(),
            model: model.into(),
            algo: Algorithm::DistDgl,
            num_fpgas: 4,
            epochs: 2,
            scale_shift: 0,
            seed: 11,
            host_threads: 4,
            prefetch_depth: 2,
            max_iterations: if quick { Some(6) } else { None },
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        let report = trainer.run()?;
        let m = report.epochs.last().expect("two epochs");
        let nvtps = m.vertices_traversed as f64 / m.wall_seconds;
        b.throughput(
            &format!("NVTPS {model} (tiny, ht=4 pd=2)"),
            m.vertices_traversed as f64,
            m.wall_seconds,
            "vertices",
        );
        rows.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("nvtps", Json::num(nvtps)),
            ("epoch_wall_s", Json::num(m.wall_seconds)),
            ("vertices_traversed", Json::num(m.vertices_traversed as f64)),
            ("final_loss", Json::num(report.last_loss())),
        ]));
        trainer.shutdown();
    }
    suite.extra("model_zoo", Json::arr(rows));
    suite.add(&b);
    b.finish();
    suite.write(out)?;
    Ok(())
}

/// BENCH_sync.json: the gradient-synchronisation tail in isolation
/// (ISSUE 7 acceptance). A synthetic ~1M-element parameter set and p = 4
/// worker gradients; three sync implementations over the same inputs:
///
/// - `serial_average` — the seed's `average_grads` + `Sgd::step`
///   (allocates a fresh averaged gradient every call);
/// - `fused t=1`      — `GradReducer::reduce` (serial path) +
///   `Sgd::step_fused` (zero-alloc);
/// - `fused t=N`      — the scoped-thread reduce at N = min(4, cores).
///
/// Asserts the parallel fused path is ≥ 2× the serial baseline — gated
/// on ≥ 4 available cores and skipped under `HITGNN_BENCH_QUICK`
/// (single-run CI boxes are too noisy for a tight ratio assert).
fn sync_suite(out: &std::path::Path) -> anyhow::Result<()> {
    use hitgnn::coordinator::params::{average_grads, GradReducer, ParamSet, Sgd};
    use hitgnn::runtime::GradBuffers;
    use hitgnn::util::rng::Rng;

    let quick = bench::quick();
    // ~1.08M elements: two conv layers + biases at paper-ish widths
    let shapes: Vec<Vec<usize>> =
        vec![vec![602, 1024], vec![1024], vec![1024, 441], vec![441]];
    let mut rng = Rng::new(29);
    let data: Vec<Vec<f32>> = shapes
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            (0..n).map(|_| rng.f32() - 0.5).collect()
        })
        .collect();
    let names = (0..shapes.len()).map(|i| format!("p{i}")).collect();
    let params = ParamSet { names, shapes, data };
    let workers = 4usize;
    let grads: Vec<GradBuffers> = (0..workers)
        .map(|_| {
            params
                .data
                .iter()
                .map(|d| d.iter().map(|_| rng.f32() - 0.5).collect())
                .collect::<Vec<Vec<f32>>>()
                .into()
        })
        .collect();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let par_threads = cores.min(4);

    let mut suite = BenchSuite::new("sync");
    let mut b = Bench::new("grad_sync");
    let serial_s = {
        let mut p = params.clone();
        let mut opt = Sgd::new(0.1, 0.9, &p);
        b.measure("serial_average p=4", |_| {
            let avg = average_grads(&grads);
            opt.step(&mut p, &avg);
            black_box(p.data[0][0])
        })
        .median_s
    };
    let fused_serial_s = {
        let mut p = params.clone();
        let mut opt = Sgd::new(0.1, 0.9, &p);
        let mut red = GradReducer::new(&params, 1);
        b.measure("fused reduce+step t=1 p=4", |_| {
            red.reduce(&grads);
            opt.step_fused(&mut p, red.acc(), workers);
            black_box(p.data[0][0])
        })
        .median_s
    };
    let fused_par_s = {
        let mut p = params.clone();
        let mut opt = Sgd::new(0.1, 0.9, &p);
        let mut red = GradReducer::new(&params, par_threads);
        b.measure(&format!("fused reduce+step t={par_threads} p=4"), |_| {
            red.reduce(&grads);
            opt.step_fused(&mut p, red.acc(), workers);
            black_box(p.data[0][0])
        })
        .median_s
    };
    let fused_gain = serial_s / fused_par_s;
    println!(
        "  grad sync ({} elems, p=4): serial {:.3} ms | fused t=1 {:.3} ms | fused t={} {:.3} ms \
         ({fused_gain:.2}x over serial)",
        params.num_elems(),
        serial_s * 1e3,
        fused_serial_s * 1e3,
        par_threads,
        fused_par_s * 1e3,
    );
    suite.extra(
        "sync",
        Json::obj(vec![
            ("param_elems", Json::num(params.num_elems() as f64)),
            ("workers", Json::num(workers as f64)),
            ("reduce_threads", Json::num(par_threads as f64)),
            ("serial_average_s", Json::num(serial_s)),
            ("fused_serial_s", Json::num(fused_serial_s)),
            ("fused_parallel_s", Json::num(fused_par_s)),
            ("fused_gain_vs_serial", Json::num(fused_gain)),
        ]),
    );

    // pooled vs unpooled gradient buffers through the real trainer
    // (the --no-pool ablation also re-allocates batch buffers, so this
    // measures the whole carcass-recycling story end to end)
    let pool_cfg = |pool: bool| TrainConfig {
        dataset: "tiny".into(),
        model: "gcn".into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 4,
        epochs: 2,
        scale_shift: 0,
        seed: 11,
        host_threads: 4,
        prefetch_depth: 2,
        buffer_pool: pool,
        max_iterations: if quick { Some(6) } else { None },
        ..TrainConfig::default()
    };
    for pool in [true, false] {
        let mut samples = Vec::with_capacity(b.iters());
        for _ in 0..b.iters() {
            let mut tr = Trainer::new(pool_cfg(pool))?;
            let report = tr.run()?;
            samples.push(report.epochs.last().expect("two epochs").wall_seconds);
            tr.shutdown();
        }
        b.record(&format!("epoch_wall pool={pool}"), &samples);
    }

    suite.add(&b);
    b.finish();
    suite.write(out)?;
    if !quick && cores >= 4 {
        assert!(
            fused_gain >= 2.0,
            "parallel fused gradient sync must be ≥2x the serial average_grads baseline \
             at p=4 (got {fused_gain:.2}x over {:.3} ms)",
            serial_s * 1e3
        );
    }
    Ok(())
}

/// BENCH_io.json: the out-of-core storage trajectory. One packed tiny
/// dataset feeds both halves: (a) mmap-vs-in-memory epoch wall at the
/// headline pipeline configuration (the numerics are bit-identical —
/// asserted here on the final loss, pinned exhaustively in
/// tests/out_of_core.rs); (b) the DRAM-tier policy sweep, recording cold
/// and steady-state DRAM hit rates plus disk bytes per policy so the
/// LFU/window-vs-static gap under disk pricing is a tracked trajectory
/// number.
fn io_suite(out: &std::path::Path) -> anyhow::Result<()> {
    use hitgnn::graph::{datasets, ondisk};
    use hitgnn::store::CachePolicy;
    use hitgnn::util::stats::si;

    let quick = bench::quick();
    let dir = std::env::temp_dir().join("hitgnn-bench-io");
    std::fs::create_dir_all(&dir)?;
    let pack = dir.join(format!("bench-{}.hitg", std::process::id()));
    let spec = datasets::lookup("tiny")?;
    let pack_bytes = ondisk::pack_streamed(&spec, 0, 11, &pack, ondisk::DEFAULT_PACK_BUDGET)?;
    let pack_str = pack.to_str().expect("utf-8 temp path").to_string();

    let base = || TrainConfig {
        dataset: "tiny".into(),
        model: "gcn".into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 4,
        epochs: 2,
        scale_shift: 0,
        seed: 11,
        host_threads: 4,
        prefetch_depth: 2,
        max_iterations: if quick { Some(6) } else { None },
        ..TrainConfig::default()
    };

    println!("\n=== bench: out-of-core storage ===");
    let mut suite = BenchSuite::new("io");
    let mut b = Bench::new("out_of_core");

    // (a) mmap pack vs in-memory build, same seed → same numerics
    let mut mem_loss = f64::NAN;
    let mut map_loss = f64::NAN;
    for mapped in [false, true] {
        let mut samples = Vec::with_capacity(b.iters());
        for _ in 0..b.iters() {
            let mut cfg = base();
            if mapped {
                cfg.dataset_path = Some(pack_str.clone());
            }
            let mut tr = Trainer::new(cfg)?;
            let report = tr.run()?;
            samples.push(report.epochs.last().expect("two epochs").wall_seconds);
            if mapped {
                map_loss = report.last_loss();
            } else {
                mem_loss = report.last_loss();
            }
            tr.shutdown();
        }
        let label = if mapped { "mmap" } else { "memory" };
        b.record(&format!("epoch_wall source={label}"), &samples);
    }
    assert_eq!(
        mem_loss.to_bits(),
        map_loss.to_bits(),
        "mmap training must be bit-identical to in-memory ({mem_loss} vs {map_loss})"
    );

    // (b) DRAM-tier policy sweep over the pack at a fixed capacity
    let dram_ratio = 0.3;
    let epochs = if quick { 2 } else { 4 };
    let hit = |m: &EpochMetrics| {
        let split = m.dram_hit_bytes + m.disk_read_bytes;
        if split == 0 {
            1.0
        } else {
            m.dram_hit_bytes as f64 / split as f64
        }
    };
    let mut rows = Vec::new();
    for policy in CachePolicy::ALL {
        let mut cfg = base();
        cfg.dataset_path = Some(pack_str.clone());
        cfg.cache_policy = policy;
        cfg.dram_ratio = dram_ratio;
        cfg.epochs = epochs;
        let mut tr = Trainer::new(cfg)?;
        let report = tr.run()?;
        tr.shutdown();
        let cold = &report.epochs[0];
        let last = report.epochs.last().expect("epochs");
        let disk_total: u64 = report.epochs.iter().map(|m| m.disk_read_bytes).sum();
        println!(
            "  tier {} ratio {dram_ratio}: hit {:.3} -> {:.3}, disk {} over {epochs} epochs",
            policy.name(),
            hit(cold),
            hit(last),
            si(disk_total as f64)
        );
        rows.push(Json::obj(vec![
            ("policy", Json::str(policy.name())),
            ("dram_ratio", Json::num(dram_ratio)),
            ("cold_hit_rate", Json::num(hit(cold))),
            ("steady_hit_rate", Json::num(hit(last))),
            ("steady_disk_read_bytes", Json::num(last.disk_read_bytes as f64)),
            ("disk_read_bytes_total", Json::num(disk_total as f64)),
            (
                "per_epoch_hit",
                Json::arr(report.epochs.iter().map(|m| Json::num(hit(m))).collect()),
            ),
        ]));
    }
    println!("=== end bench: out-of-core storage ===");
    suite.extra(
        "io",
        Json::obj(vec![
            ("pack_bytes", Json::num(pack_bytes as f64)),
            ("zero_copy", Json::Bool(ondisk::zero_copy_ok())),
            ("tier_sweep", Json::arr(rows)),
        ]),
    );
    suite.add(&b);
    b.finish();
    suite.write(out)?;
    std::fs::remove_file(&pack).ok();
    Ok(())
}

/// The auto-tuner's objective for one epoch (crate::tune's score):
/// measured wall seconds plus the §6.2 modeled makespan of the planned
/// schedule — the modeled half is what makes the sched knob visible with
/// simulated FPGAs.
fn epoch_score(m: &EpochMetrics) -> f64 {
    m.wall_seconds + m.epoch_makespan_seconds
}

/// BENCH_tune.json: static hand-sweep vs the closed-loop trajectory.
fn tune_suite(out: &std::path::Path) -> anyhow::Result<()> {
    let fleet_spec = "u250:2,u250-half:2";
    let quick = bench::quick();
    let max_iters = if quick { Some(6) } else { None };
    let base = |ht: usize, pd: usize, sched: SchedMode, auto: AutoTuneMode, epochs: usize| {
        TrainConfig {
            dataset: "tiny".into(),
            model: "gcn".into(),
            algo: Algorithm::DistDgl,
            num_fpgas: 4,
            fleet: Some(parse_fleet(fleet_spec).expect("fleet spec")),
            sched,
            epochs,
            scale_shift: 0,
            seed: 11,
            host_threads: ht,
            prefetch_depth: pd,
            auto_tune: auto,
            max_iterations: max_iters,
            ..TrainConfig::default()
        }
    };

    println!("\n=== bench: auto-tune sweep (fleet {fleet_spec}) ===");
    let hts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };
    let pds: &[usize] = if quick { &[1, 2] } else { &[1, 2, 3] };
    let mut static_rows = Vec::new();
    let mut best_static = f64::INFINITY;
    for &ht in hts {
        for &pd in pds {
            for sched in SchedMode::ALL {
                let mut tr = Trainer::new(base(ht, pd, sched, AutoTuneMode::Off, 2))?;
                let report = tr.run()?;
                let s = epoch_score(report.epochs.last().expect("two epochs"));
                tr.shutdown();
                best_static = best_static.min(s);
                println!("  static ht={ht} pd={pd} sched={}: {s:.4}s", sched.name());
                static_rows.push(Json::obj(vec![
                    ("host_threads", Json::num(ht as f64)),
                    ("prefetch_depth", Json::num(pd as f64)),
                    ("sched", Json::str(sched.name())),
                    ("epoch_s", Json::num(s)),
                ]));
            }
        }
    }

    // the closed-loop trajectory, starting from the worst corner
    let epochs = 8usize;
    let mut tr = Trainer::new(base(1, 1, SchedMode::BatchCount, AutoTuneMode::On, epochs))?;
    let report = tr.run()?;
    tr.shutdown();
    let mut auto_rows = Vec::new();
    let mut best_auto = f64::INFINITY;
    for m in &report.epochs {
        let s = epoch_score(m);
        best_auto = best_auto.min(s);
        auto_rows.push(Json::obj(vec![
            ("epoch", Json::num(m.epoch as f64)),
            ("epoch_s", Json::num(s)),
            ("tune", m.tune.clone().unwrap_or(Json::Null)),
        ]));
    }

    let ratio = best_auto / best_static;
    let converged = ratio <= 1.05;
    println!(
        "auto-tune best {best_auto:.4}s vs best static {best_static:.4}s -> ratio {ratio:.3} \
         (<=1.05: {converged})"
    );
    println!("=== end bench: auto-tune sweep ===");

    let mut suite = BenchSuite::new("tune");
    suite.extra(
        "tune",
        Json::obj(vec![
            ("fleet", Json::str(fleet_spec)),
            ("objective", Json::str("epoch_s = wall_seconds + modeled_makespan_seconds")),
            ("start", Json::str("ht=1 pd=1 sched=batch-count")),
            ("epochs", Json::num(epochs as f64)),
            ("static_grid", Json::arr(static_rows)),
            ("best_static_s", Json::num(best_static)),
            ("trajectory", Json::arr(auto_rows)),
            ("best_auto_s", Json::num(best_auto)),
            ("ratio_vs_best_static", Json::num(ratio)),
            ("converged_1_05", Json::Bool(converged)),
        ]),
    );
    suite.write(out)?;
    // hard sanity floor only — the 1.05 criterion lives in the JSON where
    // trajectory diffs track it (single-run wall clocks are too noisy for
    // a tight CI assert)
    assert!(
        ratio.is_finite() && ratio < 1.5,
        "auto-tune failed to approach the best static configuration (ratio {ratio:.3})"
    );
    Ok(())
}

/// BENCH_robustness.json: the fault-tolerance trajectory (ISSUE 10).
/// (a) checkpoint overhead: epoch wall with `--checkpoint-dir` on vs off
/// at the headline pipeline configuration, plus the trainer's own
/// `checkpoint_seconds` so the snapshot cost is tracked both relatively
/// and absolutely; (b) degraded-fleet makespan: a `u250:2,u250-half:2`
/// fleet losing one board mid-epoch vs healthy — the wall clock, the
/// modeled §6.2 makespan, and the quarantine/reassignment counters all
/// land in the JSON so a degradation regression is a visible diff.
fn robustness_suite(out: &std::path::Path) -> anyhow::Result<()> {
    use hitgnn::fault::FaultPlan;

    let quick = bench::quick();
    let max_iters = if quick { Some(6) } else { None };
    let base = || TrainConfig {
        dataset: "tiny".into(),
        model: "gcn".into(),
        algo: Algorithm::DistDgl,
        num_fpgas: 4,
        epochs: 2,
        scale_shift: 0,
        seed: 11,
        host_threads: 4,
        prefetch_depth: 2,
        max_iterations: max_iters,
        ..TrainConfig::default()
    };

    println!("\n=== bench: fault tolerance ===");
    let mut suite = BenchSuite::new("robustness");
    let mut b = Bench::new("fault_tolerance");

    // (a) checkpoint on/off epoch-wall overhead
    let dir = std::env::temp_dir().join(format!("hitgnn-bench-ckpt-{}", std::process::id()));
    let mut wall = [0.0f64; 2];
    let mut ckpt_s = 0.0f64;
    for (i, checkpoint) in [false, true].into_iter().enumerate() {
        let mut samples = Vec::with_capacity(b.iters());
        let mut snap = Vec::with_capacity(b.iters());
        for _ in 0..b.iters() {
            std::fs::remove_dir_all(&dir).ok();
            let mut cfg = base();
            if checkpoint {
                cfg.checkpoint_dir = Some(dir.clone());
            }
            let mut tr = Trainer::new(cfg)?;
            let report = tr.run()?;
            let m = report.epochs.last().expect("two epochs");
            samples.push(m.wall_seconds);
            snap.push(m.checkpoint_seconds);
            tr.shutdown();
        }
        wall[i] = samples.iter().copied().sum::<f64>() / samples.len() as f64;
        if checkpoint {
            ckpt_s = snap.iter().copied().sum::<f64>() / snap.len() as f64;
        }
        b.record(&format!("epoch_wall checkpoint={checkpoint}"), &samples);
    }
    std::fs::remove_dir_all(&dir).ok();
    let overhead = wall[1] / wall[0];
    println!(
        "  checkpoint overhead: off {:.3} ms, on {:.3} ms ({overhead:.3}x, snapshot {:.3} ms)",
        wall[0] * 1e3,
        wall[1] * 1e3,
        ckpt_s * 1e3
    );

    // (b) degraded fleet vs healthy on u250:2,u250-half:2
    let fleet_spec = "u250:2,u250-half:2";
    let plan = "dev1:fail@e0i1";
    let run_fleet = |fault: Option<&str>| -> anyhow::Result<hitgnn::coordinator::TrainReport> {
        let mut cfg = base();
        cfg.fleet = Some(parse_fleet(fleet_spec)?);
        cfg.sched = SchedMode::Cost;
        cfg.fault_plan = fault.map(FaultPlan::parse).transpose()?;
        let mut tr = Trainer::new(cfg)?;
        let report = tr.run()?;
        tr.shutdown();
        Ok(report)
    };
    let healthy = run_fleet(None)?;
    let degraded = run_fleet(Some(plan))?;
    // same plan + same seed ⇒ bit-identical degraded run (the acceptance
    // determinism law, asserted where the bench already pays for the run)
    let rerun = run_fleet(Some(plan))?;
    for (a, c) in degraded.epochs.iter().zip(&rerun.epochs) {
        assert_eq!(a.iter_losses, c.iter_losses, "degraded run must be deterministic");
    }
    let sum = |r: &hitgnn::coordinator::TrainReport, f: &dyn Fn(&EpochMetrics) -> f64| -> f64 {
        r.epochs.iter().map(f).sum()
    };
    let h_mk = sum(&healthy, &|m| m.epoch_makespan_seconds);
    let d_mk = sum(&degraded, &|m| m.epoch_makespan_seconds);
    let reassigned: usize = degraded.epochs.iter().map(|m| m.reassigned_batches).sum();
    println!(
        "  degraded fleet ({plan}): modeled makespan {d_mk:.4}s vs healthy {h_mk:.4}s \
         ({:.3}x), {reassigned} batches reassigned",
        d_mk / h_mk
    );
    println!("=== end bench: fault tolerance ===");

    suite.extra(
        "robustness",
        Json::obj(vec![
            ("checkpoint_epoch_wall_off_s", Json::num(wall[0])),
            ("checkpoint_epoch_wall_on_s", Json::num(wall[1])),
            ("checkpoint_overhead_ratio", Json::num(overhead)),
            ("checkpoint_snapshot_s", Json::num(ckpt_s)),
            ("fleet", Json::str(fleet_spec)),
            ("fault_plan", Json::str(plan)),
            ("healthy_makespan_s", Json::num(h_mk)),
            ("degraded_makespan_s", Json::num(d_mk)),
            ("degraded_vs_healthy_ratio", Json::num(d_mk / h_mk)),
            (
                "healthy_wall_s",
                Json::num(sum(&healthy, &|m| m.wall_seconds)),
            ),
            (
                "degraded_wall_s",
                Json::num(sum(&degraded, &|m| m.wall_seconds)),
            ),
            (
                "quarantined_devices",
                Json::num(degraded.epochs.last().expect("epochs").quarantined_devices as f64),
            ),
            ("reassigned_batches", Json::num(reassigned as f64)),
            (
                "degraded_batches_per_epoch",
                Json::arr(degraded.epochs.iter().map(|m| Json::num(m.batches as f64)).collect()),
            ),
        ]),
    );
    suite.add(&b);
    b.finish();
    suite.write(out)?;
    // exactly-once even degraded: both runs train identical batch totals
    for (h, d) in healthy.epochs.iter().zip(&degraded.epochs) {
        assert_eq!(
            h.batches, d.batches,
            "epoch {}: degraded run must still train every batch exactly once",
            h.epoch
        );
    }
    Ok(())
}
