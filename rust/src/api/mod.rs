//! High-level user API — the Rust rendering of the paper's Table 2.
//!
//! The paper exposes Python APIs (`Graph_Partition`, `Feature_Storing`,
//! `GNN_Parameters`, `GNN_Model`, `FPGA_Metadata`, `Platform_Metadata`,
//! `Generate_Design`, `LoadInputGraph`, `Start_training`, `Save_model`);
//! here the same workflow is a builder:
//!
//! ```no_run
//! use hitgnn::api::HitGnn;
//! use hitgnn::partition::Algorithm;
//! use hitgnn::store::CachePolicy;
//!
//! let design = HitGnn::new()
//!     .load_input_graph("ogbn-products", 4)      // LoadInputGraph()
//!     .graph_partition(Algorithm::DistDgl)        // Graph_Partition()
//!     .feature_storing(CachePolicy::Lfu, 0.2)     // Feature_Storing()
//!     .gnn_computation("gcn")                     // GNN_Computation()
//!     .gnn_parameters(2, 128)                     // GNN_Parameters()
//!     .fpga_metadata(hitgnn::fpga::U250)          // FPGA_Metadata()
//!     .platform_metadata(4, 16.0, 205.0)          // Platform_Metadata()
//!     .generate_design()                          // Generate_Design()
//!     .unwrap();
//! let report = design.start_training(2).unwrap(); // Start_training()
//! design.save_model("model.json").unwrap();       // Save_model()
//! # let _ = report;
//! ```
//!
//! `generate_design()` runs the DSE engine (accelerator generator) and
//! assembles the host-program configuration (software generator); the
//! returned [`Design`] owns the trained state after `start_training`.

use std::cell::RefCell;
use std::path::Path;

use crate::coordinator::{TrainConfig, TrainReport, Trainer};
use crate::dse::{DseEngine, DseWorkload};
use crate::fpga::timing::BatchShape;
use crate::fpga::{DeviceSpec, DieConfig, FpgaSpec};
use crate::graph::datasets;
use crate::partition::Algorithm;
use crate::perf::PlatformSpec;
use crate::store::CachePolicy;
use crate::tune::AutoTuneMode;
use crate::util::json::Json;

/// Builder for a HitGNN design (the "input program" of Fig. 3).
#[derive(Clone, Debug)]
pub struct HitGnn {
    dataset: Option<String>,
    scale_shift: u32,
    algo: Algorithm,
    cache_policy: CachePolicy,
    cache_ratio: f64,
    model: Option<String>,
    /// Explicit `GNN_Parameters()` depth; reconciled with `fanouts` at
    /// `generate_design` (order-independent).
    layers: Option<usize>,
    hidden: usize,
    /// Per-layer fanouts (DESIGN.md §Mini-batch wire format order). None
    /// = the paper's 2-layer `[25, 10]` design point / the dataset
    /// artifact's default at training time.
    fanouts: Option<Vec<usize>>,
    fpga: FpgaSpec,
    num_fpgas: usize,
    pcie_gbs: f64,
    cpu_mem_gbs: f64,
    /// Heterogeneous fleet (per-device metadata); overrides the
    /// homogeneous `fpga`/`num_fpgas`/`pcie_gbs` trio when set.
    fleet: Option<Vec<DeviceSpec>>,
    auto_tune: AutoTuneMode,
    seed: u64,
    /// Out-of-core: serve the graph from a `hitgnn pack` file (mmap)
    /// instead of building it in memory.
    dataset_path: Option<String>,
    /// Host-DRAM cache tier capacity as a fraction of |V| rows; 1.0 =
    /// everything DRAM-resident, no disk term.
    dram_ratio: f64,
    /// Disk read bandwidth (GB/s) below the DRAM tier.
    disk_gbs: f64,
    /// Deterministic fault-injection spec (`--fault-plan` grammar);
    /// parsed and validated at `generate_design()`.
    fault_plan: Option<String>,
    /// Per-epoch snapshot directory for the generated host program.
    checkpoint_dir: Option<String>,
    /// Checkpoint file (or directory holding them) to resume from.
    resume: Option<String>,
}

impl Default for HitGnn {
    fn default() -> Self {
        HitGnn {
            dataset: None,
            scale_shift: 4,
            algo: Algorithm::DistDgl,
            cache_policy: CachePolicy::Static,
            cache_ratio: 0.2,
            model: None,
            layers: None,
            hidden: 128,
            fanouts: None,
            fpga: crate::fpga::U250,
            num_fpgas: 4,
            pcie_gbs: 16.0,
            cpu_mem_gbs: 205.0,
            fleet: None,
            auto_tune: AutoTuneMode::Off,
            seed: 42,
            dataset_path: None,
            dram_ratio: 1.0,
            disk_gbs: 2.0,
            fault_plan: None,
            checkpoint_dir: None,
            resume: None,
        }
    }
}

impl HitGnn {
    pub fn new() -> HitGnn {
        HitGnn::default()
    }

    /// `LoadInputGraph()`: dataset key + scale shift (execution path).
    pub fn load_input_graph(mut self, dataset: &str, scale_shift: u32) -> Self {
        self.dataset = Some(dataset.to_string());
        self.scale_shift = scale_shift;
        self
    }

    /// `LoadInputGraph()` from a packed on-disk file (`hitgnn pack`):
    /// the dataset key and scale shift come from the pack header, and
    /// training serves CSR + features via mmap with a bounded resident
    /// set. Overrides [`HitGnn::load_input_graph`]'s build source.
    pub fn load_packed_graph(mut self, path: &str) -> Self {
        self.dataset_path = Some(path.to_string());
        self
    }

    /// Host memory hierarchy for out-of-core training: keep
    /// `dram_ratio·|V|` feature rows in a host-DRAM cache tier (re-ranked
    /// by the configured [`HitGnn::feature_storing`] policy) above a disk
    /// tier read at `disk_gbs` GB/s. `dram_ratio = 1.0` (default)
    /// disables the tier. Validated at `generate_design()`.
    pub fn dram_tier(mut self, dram_ratio: f64, disk_gbs: f64) -> Self {
        self.dram_ratio = dram_ratio;
        self.disk_gbs = disk_gbs;
        self
    }

    /// `Graph_Partition()`: the synchronous training algorithm's
    /// partitioning strategy (Table 1).
    pub fn graph_partition(mut self, algo: Algorithm) -> Self {
        self.algo = algo;
        self
    }

    /// `Feature_Storing()`: the caching policy (the algorithm's static
    /// Table-1 store, LFU/hotness, or sliding-window recency) and the
    /// cache capacity fraction for caching strategies. `cache_ratio` must
    /// be in [0, 1] — validated at `generate_design()`.
    pub fn feature_storing(mut self, policy: CachePolicy, cache_ratio: f64) -> Self {
        self.cache_policy = policy;
        self.cache_ratio = cache_ratio;
        self
    }

    /// `GNN_Computation()`: a model-zoo architecture —
    /// "gcn" | "sage" | "gat" | "gin" (`runtime::MODEL_NAMES`). Validated
    /// at `generate_design()`.
    pub fn gnn_computation(mut self, model: &str) -> Self {
        self.model = Some(model.to_string());
        self
    }

    /// The configured model-zoo architecture, if `gnn_computation()` has
    /// been called.
    pub fn model(&self) -> Option<&str> {
        self.model.as_deref()
    }

    /// `GNN_Parameters()`: L and hidden dim. Hidden is pinned at 128 (the
    /// artifact set's width); depth is free — pair any L ≥ 1 with a
    /// matching [`HitGnn::fanouts`] call (L = 2 defaults to the paper's
    /// `[25, 10]`). Consistency is validated at `generate_design` time.
    pub fn gnn_parameters(mut self, layers: usize, hidden: usize) -> Self {
        self.layers = Some(layers);
        self.hidden = hidden;
        self
    }

    /// Per-layer sampling fanouts (DESIGN.md §Mini-batch wire format
    /// order: input-side hop first — e.g. `&[15, 10, 5]` is DistDGL's
    /// canonical 3-layer GraphSAGE recipe). Implies L; a `gnn_parameters`
    /// call — before or after — must agree (checked at
    /// `generate_design`).
    pub fn fanouts(mut self, fanouts: &[usize]) -> Self {
        self.fanouts = Some(fanouts.to_vec());
        self
    }

    /// `FPGA_Metadata()`.
    pub fn fpga_metadata(mut self, fpga: FpgaSpec) -> Self {
        self.fpga = fpga;
        self
    }

    /// `Platform_Metadata()`.
    pub fn platform_metadata(mut self, num_fpgas: usize, pcie_gbs: f64, cpu_mem_gbs: f64) -> Self {
        self.num_fpgas = num_fpgas;
        self.pcie_gbs = pcie_gbs;
        self.cpu_mem_gbs = cpu_mem_gbs;
        self
    }

    /// `Platform_Metadata()` for a heterogeneous fleet: one
    /// [`DeviceSpec`] per FPGA (mixed generations, partially populated
    /// dies, per-device PCIe shares — e.g. `fpga::parse_fleet(
    /// "u250:2,u250-half:2")`). The DSE engine then optimises a die
    /// configuration per device kind and the trainer schedules with the
    /// fleet's cost model.
    pub fn platform(mut self, fleet: Vec<DeviceSpec>, cpu_mem_gbs: f64) -> Self {
        self.num_fpgas = fleet.len();
        self.fleet = Some(fleet);
        self.cpu_mem_gbs = cpu_mem_gbs;
        self
    }

    /// Between-epoch closed-loop tuning of the runtime-safe knobs
    /// (DESIGN.md §Adaptive control): `On` lets the controller refine the
    /// DSE design online from each epoch's barrier measurements, `Freeze`
    /// observes and logs without changing anything, `Off` (the default)
    /// disables it. Loss sequences are unaffected either way.
    pub fn auto_tune(mut self, mode: AutoTuneMode) -> Self {
        self.auto_tune = mode;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Deterministic fault injection for the generated host program
    /// (DESIGN.md §Fault tolerance) — the `--fault-plan` grammar, e.g.
    /// `"dev1:fail@e2i7,dev3:slow*4@e1,disk:eio@0.01,prep:panic@e3i2"`.
    /// Parsed (with token-naming errors) at `generate_design()`; device
    /// ids and epoch anchors are pinned when training starts.
    pub fn fault_plan(mut self, spec: &str) -> Self {
        self.fault_plan = Some(spec.to_string());
        self
    }

    /// Write a versioned trainer snapshot after every epoch into `dir`
    /// (the `--checkpoint-dir` behavior; files are `ckpt-eNNNNN.hitg`).
    pub fn checkpointing(mut self, dir: &str) -> Self {
        self.checkpoint_dir = Some(dir.to_string());
        self
    }

    /// Resume training from a checkpoint file, or from the newest
    /// checkpoint in a directory (the `--resume` behavior). The resumed
    /// run continues the uninterrupted run's loss/traffic sequence
    /// bit-for-bit (same seed required).
    pub fn resume(mut self, path: &str) -> Self {
        self.resume = Some(path.to_string());
        self
    }

    /// `Generate_Design()`: run the DSE engine for the accelerator
    /// configuration and assemble the host-program configuration.
    pub fn generate_design(self) -> anyhow::Result<Design> {
        // a packed graph carries its own dataset key + scale shift
        let (dataset, scale_shift) = match &self.dataset_path {
            Some(p) => {
                let meta = crate::graph::ondisk::probe(Path::new(p))?;
                (meta.key, meta.scale_shift)
            }
            None => (
                self.dataset.clone().ok_or_else(|| {
                    anyhow::anyhow!(
                        "call load_input_graph() or load_packed_graph() before generate_design()"
                    )
                })?,
                self.scale_shift,
            ),
        };
        let model = self
            .model
            .clone()
            .ok_or_else(|| anyhow::anyhow!("call gnn_computation() before generate_design()"))?;
        crate::runtime::validate_model(&model)?;
        let fanouts: Vec<usize> = match &self.fanouts {
            Some(f) => {
                // order-independent consistency: whichever of
                // gnn_parameters()/fanouts() came last, they must agree
                if let Some(layers) = self.layers {
                    anyhow::ensure!(
                        f.len() == layers,
                        "gnn_parameters(L={layers}) disagrees with fanouts({f:?})"
                    );
                }
                f.clone()
            }
            None => {
                let layers = self.layers.unwrap_or(2);
                anyhow::ensure!(
                    layers == 2,
                    "call fanouts() to pick the per-layer fanouts for L={layers} \
                     (only L=2 has a paper default, [25, 10])"
                );
                crate::sampling::PAPER_FANOUTS.to_vec()
            }
        };
        // structural validation only: the level-0 memory bound depends on
        // the training batch size, which the artifact (not the builder)
        // owns — Trainer::new enforces it against the real b
        anyhow::ensure!(
            !fanouts.is_empty() && fanouts.iter().all(|&k| k >= 1),
            "fanouts() must list one fanout >= 1 per layer (got {:?})",
            fanouts
        );
        anyhow::ensure!(
            self.hidden == 128,
            "artifacts are built with hidden=128 (got {}); re-run `make artifacts`",
            self.hidden
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.cache_ratio),
            "feature_storing(): cache_ratio must be in [0, 1] (got {})",
            self.cache_ratio
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.dram_ratio),
            "dram_tier(): dram_ratio must be in [0, 1] (got {})",
            self.dram_ratio
        );
        anyhow::ensure!(
            self.disk_gbs.is_finite() && self.disk_gbs > 0.0,
            "dram_tier(): disk_gbs must be finite and positive (got {})",
            self.disk_gbs
        );
        if let Some(fleet) = &self.fleet {
            anyhow::ensure!(!fleet.is_empty(), "platform(): fleet needs at least one device");
            anyhow::ensure!(
                fleet.len() == self.num_fpgas,
                "platform(): fleet has {} devices but num_fpgas is {} (platform_metadata() \
                 after platform() overrode the count)",
                fleet.len(),
                self.num_fpgas
            );
        }
        anyhow::ensure!(self.num_fpgas >= 1, "platform needs at least one FPGA");
        // parse the fault schedule now so a malformed spec fails the
        // design, not the training run (fleet/epoch pinning happens in
        // Trainer::new once both are known)
        let fault_plan = self
            .fault_plan
            .as_deref()
            .map(crate::fault::FaultPlan::parse)
            .transpose()?;
        let spec = datasets::lookup(&dataset)?;

        // Eq. 7's β, measured (per-epoch) on a scaled instance under the
        // configured feature-storing policy — the steady-state value feeds
        // the DSE engine's workload instead of a hard-coded constant.
        let beta = crate::perf::experiments::measure_host_policy(
            &spec,
            self.algo,
            &model,
            self.num_fpgas,
            7,
            4,
            self.seed,
            self.cache_policy,
            self.cache_ratio,
            if self.cache_policy.is_dynamic() { 2 } else { 1 },
        )?
        .beta;
        let fanouts_f: Vec<f64> = fanouts.iter().map(|&k| k as f64).collect();
        let widths: Vec<f64> = crate::runtime::manifest::feature_widths(spec.dims, fanouts.len())
            .iter()
            .map(|&x| x as f64)
            .collect();
        let workload = DseWorkload {
            shape: BatchShape::nominal(1024.0, &fanouts_f, &widths),
            beta,
            cost: crate::fpga::timing::ModelCost::for_model(&model)?,
            sampling_s_per_batch: 2e-3,
            // disk term only when a DRAM tier caps resident rows; the
            // cold-start miss estimate is the uncached fraction
            disk_gbs: if self.dram_ratio < 1.0 { self.disk_gbs } else { 0.0 },
            disk_miss_frac: 1.0 - self.dram_ratio,
        };
        // accelerator generator: DSE over this dataset's dims — per
        // device kind on an explicit fleet, classic Algorithm 4 otherwise
        let (platform, accelerator, fleet, estimated_nvtps) = match &self.fleet {
            Some(devices) => {
                let res =
                    DseEngine::explore_fleet(devices, self.cpu_mem_gbs, &[workload], 16)?;
                let first = res.devices[0];
                let platform = PlatformSpec {
                    num_fpgas: res.devices.len(),
                    fpga: first.fpga,
                    pcie_gbs: first.pcie_gbs,
                    cpu_mem_gbs: self.cpu_mem_gbs,
                };
                (platform, first.die, res.devices, res.throughput)
            }
            None => {
                let platform = PlatformSpec {
                    num_fpgas: self.num_fpgas,
                    fpga: self.fpga,
                    pcie_gbs: self.pcie_gbs,
                    cpu_mem_gbs: self.cpu_mem_gbs,
                };
                let dse = DseEngine::new(platform).explore(&[workload])?;
                let devices = vec![
                    DeviceSpec::custom(self.fpga, dse.best.die, self.pcie_gbs);
                    self.num_fpgas
                ];
                (platform, dse.best.die, devices, dse.best.throughput)
            }
        };

        // software generator: the host-program configuration (the
        // scheduler runs cost-aware on the generated fleet by default)
        let train = TrainConfig {
            dataset,
            model,
            // only an explicit fanouts() call overrides the dataset
            // artifact's default depth at training time
            fanouts: self.fanouts.clone(),
            algo: self.algo,
            num_fpgas: self.num_fpgas,
            fleet: Some(fleet.clone()),
            cpu_mem_gbs: self.cpu_mem_gbs,
            scale_shift,
            cache_policy: self.cache_policy,
            cache_ratio: self.cache_ratio,
            auto_tune: self.auto_tune,
            seed: self.seed,
            dataset_path: self.dataset_path.clone(),
            dram_ratio: self.dram_ratio,
            disk_gbs: self.disk_gbs,
            fault_plan,
            checkpoint_dir: self.checkpoint_dir.as_ref().map(std::path::PathBuf::from),
            resume: self.resume.clone(),
            ..TrainConfig::default()
        };

        Ok(Design {
            platform,
            accelerator,
            fleet,
            estimated_nvtps,
            train,
            trained: RefCell::new(None),
        })
    }
}

/// A generated design: accelerator configuration + host program, ready to
/// train (`Start_training()`) and save (`Save_model()`).
pub struct Design {
    pub platform: PlatformSpec,
    /// Per-die accelerator configuration chosen by the DSE engine (the
    /// first device's on a heterogeneous fleet).
    pub accelerator: DieConfig,
    /// Per-device metadata with each device's DSE-chosen die.
    pub fleet: Vec<DeviceSpec>,
    pub estimated_nvtps: f64,
    pub train: TrainConfig,
    trained: RefCell<Option<crate::coordinator::params::ParamSet>>,
}

impl Design {
    /// FPGA-level (n, m) as the paper reports it.
    pub fn fpga_parallelism(&self) -> (u32, u32) {
        let d = self.platform.fpga.dies as u32;
        (self.accelerator.n * d, self.accelerator.m * d)
    }

    /// `Start_training()`: run the host program for `epochs`.
    pub fn start_training(&self, epochs: usize) -> anyhow::Result<TrainReport> {
        let mut cfg = self.train.clone();
        cfg.epochs = epochs;
        let mut trainer = Trainer::new(cfg)?;
        let report = trainer.run()?;
        *self.trained.borrow_mut() = Some(trainer.params.clone());
        trainer.shutdown();
        Ok(report)
    }

    /// `Save_model()`: write the trained parameters as JSON.
    pub fn save_model(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let trained = self.trained.borrow();
        let params = trained
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no trained model — call start_training() first"))?;
        let obj = Json::obj(
            params
                .names
                .iter()
                .zip(&params.data)
                .map(|(n, d)| {
                    (
                        n.as_str(),
                        Json::arr(d.iter().map(|&x| Json::num(x as f64)).collect()),
                    )
                })
                .collect(),
        );
        std::fs::write(path.as_ref(), obj.to_string())
            .map_err(|e| anyhow::anyhow!("writing model: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_requires_graph_and_model() {
        assert!(HitGnn::new().generate_design().is_err());
        assert!(HitGnn::new()
            .load_input_graph("reddit", 6)
            .generate_design()
            .is_err());
    }

    #[test]
    fn builder_validates_model_against_the_zoo() {
        let b = HitGnn::new().load_input_graph("reddit", 8).gnn_computation("gat");
        assert_eq!(b.model(), Some("gat"));
        assert_eq!(HitGnn::new().model(), None);
        let err = HitGnn::new()
            .load_input_graph("reddit", 8)
            .gnn_computation("transformer")
            .generate_design()
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown model 'transformer'"), "{msg}");
        assert!(msg.contains("expected one of gcn|sage|gat|gin"), "{msg}");
        // every zoo model makes it through DSE + design generation
        for model in crate::runtime::MODEL_NAMES {
            let d = HitGnn::new()
                .load_input_graph("reddit", 8)
                .gnn_computation(model)
                .generate_design()
                .unwrap();
            assert_eq!(d.train.model, model);
            assert!(d.estimated_nvtps > 0.0);
        }
    }

    #[test]
    fn builder_validates_artifact_coverage() {
        // L=3 without an explicit fanout vector has no default
        let r = HitGnn::new()
            .load_input_graph("reddit", 6)
            .gnn_computation("gcn")
            .gnn_parameters(3, 128)
            .generate_design();
        assert!(r.is_err());
        // hidden width is pinned by the artifact set
        let r = HitGnn::new()
            .load_input_graph("reddit", 6)
            .gnn_computation("gcn")
            .gnn_parameters(2, 64)
            .generate_design();
        assert!(r.is_err());
        // inconsistent layers × fanouts is rejected in either call order
        let r = HitGnn::new()
            .load_input_graph("reddit", 6)
            .gnn_computation("gcn")
            .fanouts(&[15, 10, 5])
            .gnn_parameters(2, 128)
            .generate_design();
        assert!(r.is_err());
        let r = HitGnn::new()
            .load_input_graph("reddit", 6)
            .gnn_computation("gcn")
            .gnn_parameters(3, 128)
            .fanouts(&[15, 10])
            .generate_design();
        assert!(r.is_err(), "gnn_parameters before fanouts must not be silently dropped");
        // degenerate fanouts are rejected at the API entry point
        let r = HitGnn::new()
            .load_input_graph("reddit", 6)
            .gnn_computation("gcn")
            .fanouts(&[15, 0])
            .generate_design();
        assert!(r.is_err());
    }

    #[test]
    fn three_layer_design_prices_depth_and_carries_fanouts() {
        let d2 = HitGnn::new()
            .load_input_graph("reddit", 8)
            .gnn_computation("gcn")
            .generate_design()
            .unwrap();
        let d3 = HitGnn::new()
            .load_input_graph("reddit", 8)
            .gnn_computation("gcn")
            .fanouts(&[15, 10, 5])
            .generate_design()
            .unwrap();
        assert_eq!(d3.train.fanouts, Some(vec![15, 10, 5]));
        assert!(d2.train.fanouts.is_none());
        // a third layer adds work: the modeled throughput in vertices/s
        // rises (more vertices per batch) but never for free — the DSE
        // estimate must differ from the 2-layer design point
        assert!(d3.estimated_nvtps > 0.0);
        assert_ne!(d2.estimated_nvtps, d3.estimated_nvtps);
    }

    #[test]
    fn generate_design_runs_dse() {
        let d = HitGnn::new()
            .load_input_graph("ogbn-products", 6)
            .graph_partition(Algorithm::PaGraph)
            .gnn_computation("gcn")
            .generate_design()
            .unwrap();
        assert!(d.estimated_nvtps > 0.0);
        let (n, m) = d.fpga_parallelism();
        assert!(n >= 4 && m >= 64);
        assert_eq!(d.train.algo, Algorithm::PaGraph);
    }

    #[test]
    fn feature_storing_validates_ratio_and_threads_policy() {
        for bad in [-0.5, 1.5, f64::NAN] {
            let r = HitGnn::new()
                .load_input_graph("reddit", 8)
                .gnn_computation("gcn")
                .feature_storing(CachePolicy::Lfu, bad)
                .generate_design();
            assert!(r.is_err(), "cache_ratio {bad} accepted");
        }
        let d = HitGnn::new()
            .load_input_graph("reddit", 8)
            .graph_partition(Algorithm::PaGraph)
            .gnn_computation("gcn")
            .feature_storing(CachePolicy::Window, 0.1)
            .generate_design()
            .unwrap();
        assert_eq!(d.train.cache_policy, CachePolicy::Window);
        assert_eq!(d.train.cache_ratio, 0.1);
        assert!(d.estimated_nvtps > 0.0);
    }

    #[test]
    fn heterogeneous_platform_generates_per_kind_design() {
        let fleet = crate::fpga::parse_fleet("u250:1,u250-half:1").unwrap();
        let d = HitGnn::new()
            .load_input_graph("reddit", 8)
            .gnn_computation("gcn")
            .platform(fleet, 205.0)
            .generate_design()
            .unwrap();
        assert_eq!(d.train.num_fpgas, 2);
        assert_eq!(d.fleet.len(), 2);
        assert_eq!(d.fleet[0].kind, "u250");
        assert_eq!(d.fleet[1].kind, "u250-half");
        assert!(d.estimated_nvtps > 0.0);
        // the generated host program carries the fleet + cost scheduling
        let devs = d.train.device_fleet();
        assert_eq!(devs[1].fpga.dies, 2);
        assert_eq!(d.train.sched, crate::sched::SchedMode::Cost);
        assert_eq!(d.accelerator, d.fleet[0].die);
    }

    #[test]
    fn auto_tune_threads_into_the_generated_design() {
        let d = HitGnn::new()
            .load_input_graph("ogbn-products", 6)
            .gnn_computation("gcn")
            .generate_design()
            .unwrap();
        assert_eq!(d.train.auto_tune, AutoTuneMode::Off, "off by default");
        let d = HitGnn::new()
            .load_input_graph("ogbn-products", 6)
            .gnn_computation("gcn")
            .auto_tune(AutoTuneMode::On)
            .generate_design()
            .unwrap();
        assert_eq!(d.train.auto_tune, AutoTuneMode::On);
        assert_eq!(d.train.to_json().req_str("auto_tune").unwrap(), "on");
    }

    #[test]
    fn homogeneous_design_still_carries_a_fleet() {
        let d = HitGnn::new()
            .load_input_graph("ogbn-products", 6)
            .gnn_computation("gcn")
            .generate_design()
            .unwrap();
        assert_eq!(d.fleet.len(), 4);
        assert!(d.fleet.iter().all(|dev| dev.die == d.accelerator));
    }

    #[test]
    fn dram_tier_validates_and_threads_into_the_design() {
        for bad in [-0.1, 1.5, f64::NAN] {
            let r = HitGnn::new()
                .load_input_graph("reddit", 8)
                .gnn_computation("gcn")
                .dram_tier(bad, 2.0)
                .generate_design();
            assert!(r.is_err(), "dram_ratio {bad} accepted");
        }
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let r = HitGnn::new()
                .load_input_graph("reddit", 8)
                .gnn_computation("gcn")
                .dram_tier(0.5, bad)
                .generate_design();
            assert!(r.is_err(), "disk_gbs {bad} accepted");
        }
        let tiered = HitGnn::new()
            .load_input_graph("reddit", 8)
            .gnn_computation("gcn")
            .dram_tier(0.25, 3.5)
            .generate_design()
            .unwrap();
        assert_eq!(tiered.train.dram_ratio, 0.25);
        assert_eq!(tiered.train.disk_gbs, 3.5);
        assert!(tiered.estimated_nvtps > 0.0);
        // a DRAM-capped tier pays a disk term the resident design does not
        let full = HitGnn::new()
            .load_input_graph("reddit", 8)
            .gnn_computation("gcn")
            .generate_design()
            .unwrap();
        assert_eq!(full.train.dram_ratio, 1.0);
        assert!(full.train.dataset_path.is_none());
        assert!(tiered.estimated_nvtps <= full.estimated_nvtps);
    }

    #[test]
    fn packed_graph_supplies_dataset_key_and_shift() {
        let spec = datasets::lookup("tiny").unwrap();
        let data = spec.build(1, 42);
        let dir = std::env::temp_dir().join("hitgnn-api-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("api-pack-{}.hitg", std::process::id()));
        crate::graph::ondisk::pack_dataset(&data, &path).unwrap();
        let d = HitGnn::new()
            .load_packed_graph(path.to_str().unwrap())
            .gnn_computation("gcn")
            .generate_design()
            .unwrap();
        assert_eq!(d.train.dataset, "tiny");
        assert_eq!(d.train.scale_shift, 1);
        assert_eq!(d.train.dataset_path.as_deref(), path.to_str());
        std::fs::remove_file(&path).ok();
        // a missing pack is a clean error, not a panic
        let r = HitGnn::new()
            .load_packed_graph("/nonexistent/pack.hitg")
            .gnn_computation("gcn")
            .generate_design();
        assert!(r.is_err());
    }

    #[test]
    fn fault_and_checkpoint_knobs_thread_into_the_design() {
        let d = HitGnn::new()
            .load_input_graph("reddit", 8)
            .gnn_computation("gcn")
            .fault_plan("dev0:slow*2@e0,disk:eio@0.001")
            .checkpointing("/tmp/hitgnn-api-ck")
            .resume("/tmp/hitgnn-api-ck")
            .generate_design()
            .unwrap();
        let p = d.train.fault_plan.as_ref().unwrap();
        assert_eq!(p.slowdowns.len(), 1);
        assert_eq!(p.disk_eio, Some(0.001));
        assert_eq!(
            d.train.checkpoint_dir.as_deref(),
            Some(std::path::Path::new("/tmp/hitgnn-api-ck"))
        );
        assert_eq!(d.train.resume.as_deref(), Some("/tmp/hitgnn-api-ck"));
        // a malformed spec fails the design with a token-naming error
        let err = HitGnn::new()
            .load_input_graph("reddit", 8)
            .gnn_computation("gcn")
            .fault_plan("dev0:melt@e0")
            .generate_design()
            .unwrap_err();
        assert!(format!("{err:#}").contains("dev0:melt@e0"), "{err:#}");
    }

    #[test]
    fn save_model_before_training_errors() {
        let d = HitGnn::new()
            .load_input_graph("ogbn-products", 6)
            .gnn_computation("gcn")
            .generate_design()
            .unwrap();
        assert!(d.save_model("/tmp/should_not_exist.json").is_err());
    }
}
