//! CPU↔FPGA / FPGA↔FPGA data-communication accounting and the host
//! feature service — the paper's data-communication (DC) optimization
//! (§5.2) and the β split of Eq. 7.
//!
//! For every mini-batch an FPGA executes, the features of the sampled
//! layer-0 vertices must be materialised in FPGA-local memory:
//!
//! - bytes already resident in the FPGA's [`Store`] → **local DDR**;
//! - missing bytes, DC **on** → fetched **directly from host CPU memory**
//!   over PCIe (the host holds the full X — §4.2);
//! - missing bytes, DC **off** (baseline) → if the row belongs to another
//!   FPGA's partition it travels FPGA→shared-host-buffer→FPGA, i.e. two
//!   PCIe crossings plus an extra CPU-memory copy ([26]); otherwise host.
//!
//! [`FeatureService`] is the execution-path twin: it actually gathers the
//! feature rows into the executable's input buffer and reports the same
//! byte accounting, so the analytic benches and the real runtime can never
//! drift apart.

use crate::graph::FeatureGen;
use crate::partition::Store;
use crate::sampling::MiniBatch;

/// Byte-level breakdown of one mini-batch's vertex-feature traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Traffic {
    /// Served from FPGA-local DDR.
    pub local_bytes: u64,
    /// Fetched directly from host CPU memory (one PCIe crossing).
    pub host_bytes: u64,
    /// FPGA-to-FPGA via the shared host buffer (two PCIe crossings + a
    /// CPU-memory copy) — only nonzero with DC disabled.
    pub f2f_bytes: u64,
}

impl std::ops::AddAssign for Traffic {
    /// Merge another batch's accounting (the coordinator combines the
    /// prep threads' per-batch values lock-free, in deterministic (iter,
    /// tag) order, at the gradient-sync barrier — `coordinator::trainer`).
    fn add_assign(&mut self, other: Traffic) {
        self.local_bytes += other.local_bytes;
        self.host_bytes += other.host_bytes;
        self.f2f_bytes += other.f2f_bytes;
    }
}

impl Traffic {
    /// The paper's β: fraction of feature bytes served locally (Eq. 7).
    pub fn beta(&self) -> f64 {
        let total = self.local_bytes + self.host_bytes + self.f2f_bytes;
        if total == 0 {
            1.0
        } else {
            self.local_bytes as f64 / total as f64
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.local_bytes + self.host_bytes + self.f2f_bytes
    }

    /// Wall-clock seconds to move this traffic, given DDR / PCIe GB/s.
    /// F2F pays two PCIe crossings through the shared host buffer; the
    /// crossings use different links and partially pipeline, so the
    /// effective penalty is [`F2F_PENALTY`]× a direct fetch plus the host
    /// copy (charged at CPU memory bandwidth `cpu_gbs`).
    pub fn seconds(&self, ddr_gbs: f64, pcie_gbs: f64, cpu_gbs: f64) -> f64 {
        const G: f64 = 1e9;
        self.local_bytes as f64 / (ddr_gbs * G)
            + self.host_bytes as f64 / (pcie_gbs * G)
            + self.f2f_bytes as f64 * (F2F_PENALTY / (pcie_gbs * G) + 1.0 / (cpu_gbs * G))
    }
}

/// Effective slowdown of an FPGA→host-buffer→FPGA transfer relative to a
/// direct host fetch: the write (source link) and read (destination link)
/// overlap store-and-forward fashion, leaving ~1.5 serialized crossings
/// (cf. [26]'s measurements of shared-memory FPGA-to-FPGA paths).
pub const F2F_PENALTY: f64 = 1.5;

/// Communication configuration.
#[derive(Clone, Copy, Debug)]
pub struct CommConfig {
    /// DC optimization: fetch misses directly from host memory instead of
    /// the owning FPGA (paper §5.2). Table 7's "DC" column.
    pub direct_host_fetch: bool,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig { direct_host_fetch: true }
    }
}

/// Account the feature traffic of `mb` executed on FPGA `fpga_id` whose
/// resident rows are `store`. `vertex_part` (vertex→partition) is needed
/// only for the DC-off path to decide which misses are remote.
pub fn feature_traffic(
    mb: &MiniBatch,
    store: &Store,
    row_bytes: usize,
    cfg: CommConfig,
    vertex_part: Option<&[u32]>,
    fpga_id: usize,
) -> Traffic {
    let mut t = Traffic::default();
    for &v in &mb.v0[..mb.n_v0] {
        let local = store.local_bytes(v, row_bytes) as u64;
        let miss = row_bytes as u64 - local;
        t.local_bytes += local;
        if miss == 0 {
            continue;
        }
        if cfg.direct_host_fetch {
            t.host_bytes += miss;
        } else {
            let remote = vertex_part
                .map(|part| part[v as usize] as usize != fpga_id)
                .unwrap_or(false);
            if remote {
                t.f2f_bytes += miss;
            } else {
                t.host_bytes += miss;
            }
        }
    }
    t
}

/// Gradient-synchronisation traffic per iteration: every FPGA ships its
/// gradients to the host and receives the averaged copy back (§4.2).
pub fn gradient_sync_bytes(param_bytes: u64, p: usize) -> u64 {
    2 * param_bytes * p as u64
}

/// Gradient sync time over PCIe (all links transfer concurrently, so the
/// wall clock is one round trip, bounded by CPU memory bandwidth for the
/// reduction itself).
pub fn gradient_sync_seconds(param_bytes: u64, p: usize, pcie_gbs: f64, cpu_gbs: f64) -> f64 {
    const G: f64 = 1e9;
    // up + down on each link (concurrent across FPGAs) + p-way reduce on host
    2.0 * param_bytes as f64 / (pcie_gbs * G) + p as f64 * param_bytes as f64 / (cpu_gbs * G)
}

/// Host feature service: the execution-path materialisation of layer-0
/// features, with identical accounting to [`feature_traffic`].
///
/// The service is stateless (`gather` takes `&self`), `Copy`, and `Sync`:
/// construct it **once** per prep thread and reuse it for every batch —
/// the per-call [`Traffic`] return value makes the accounting lock-free
/// (merge with `+=` at the barrier).
#[derive(Clone, Copy)]
pub struct FeatureService<'a> {
    features: &'a FeatureGen,
    cfg: CommConfig,
}

impl<'a> FeatureService<'a> {
    pub fn new(features: &'a FeatureGen, cfg: CommConfig) -> FeatureService<'a> {
        FeatureService { features, cfg }
    }

    /// Gather `mb`'s layer-0 feature rows into a `[v0_cap, f0]` buffer and
    /// report the traffic split. Padding rows are zero-filled.
    pub fn gather(
        &self,
        mb: &MiniBatch,
        store: &Store,
        vertex_part: Option<&[u32]>,
        fpga_id: usize,
    ) -> (Vec<f32>, Traffic) {
        let f0 = self.features.feat_dim();
        let mut buf = vec![0f32; mb.dims.v0_cap * f0];
        for (row, &v) in mb.v0[..mb.n_v0].iter().enumerate() {
            self.features.write_features(v, &mut buf[row * f0..(row + 1) * f0]);
        }
        let traffic = feature_traffic(
            mb,
            store,
            self.features.bytes_per_vertex(),
            self.cfg,
            vertex_part,
            fpga_id,
        );
        (buf, traffic)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::partition::{preprocess, Algorithm};
    use crate::sampling::{FanoutConfig, Sampler, WeightMode};

    fn setup() -> (crate::graph::Dataset, crate::partition::Preprocessed, MiniBatch) {
        let d = datasets::lookup("reddit").unwrap().build(8, 23);
        let pre = preprocess(Algorithm::DistDgl, &d, 4, 0.2, 3);
        let mut s = Sampler::new(
            FanoutConfig { batch_size: 32, k1: 5, k2: 3 },
            WeightMode::GcnNorm,
            d.graph.num_vertices(),
            5,
        );
        let targets: Vec<u32> = pre.train_parts[0][..32].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        (d, pre, mb)
    }

    #[test]
    fn conservation_local_plus_remote_equals_total() {
        let (d, pre, mb) = setup();
        let row = d.features.bytes_per_vertex();
        for dc in [true, false] {
            let t = feature_traffic(
                &mb,
                &pre.stores[0],
                row,
                CommConfig { direct_host_fetch: dc },
                pre.vertex_part.as_deref(),
                0,
            );
            assert_eq!(t.total_bytes(), (mb.n_v0 * row) as u64);
            assert!(t.beta() >= 0.0 && t.beta() <= 1.0);
        }
    }

    #[test]
    fn dc_on_has_no_f2f_traffic() {
        let (d, pre, mb) = setup();
        let t = feature_traffic(
            &mb,
            &pre.stores[0],
            d.features.bytes_per_vertex(),
            CommConfig { direct_host_fetch: true },
            pre.vertex_part.as_deref(),
            0,
        );
        assert_eq!(t.f2f_bytes, 0);
    }

    #[test]
    fn dc_off_routes_remote_misses_via_f2f_and_is_slower() {
        let (d, pre, mb) = setup();
        let row = d.features.bytes_per_vertex();
        let on = feature_traffic(&mb, &pre.stores[0], row, CommConfig { direct_host_fetch: true }, pre.vertex_part.as_deref(), 0);
        let off = feature_traffic(&mb, &pre.stores[0], row, CommConfig { direct_host_fetch: false }, pre.vertex_part.as_deref(), 0);
        // DistDGL stores partition rows locally, so every miss is remote:
        assert_eq!(off.host_bytes, 0);
        assert_eq!(off.f2f_bytes, on.host_bytes);
        // and the DC-off path is strictly slower for the same bytes
        let (ddr, pcie, cpu) = (19.25, 16.0, 205.0);
        assert!(off.seconds(ddr, pcie, cpu) > on.seconds(ddr, pcie, cpu));
    }

    #[test]
    fn p3_store_gives_partial_beta() {
        let d = datasets::lookup("reddit").unwrap().build(8, 23);
        let pre = preprocess(Algorithm::P3, &d, 4, 0.2, 3);
        let mut s = Sampler::new(
            FanoutConfig { batch_size: 32, k1: 5, k2: 3 },
            WeightMode::GcnNorm,
            d.graph.num_vertices(),
            5,
        );
        let targets: Vec<u32> = pre.train_parts[1][..32].to_vec();
        let mb = s.sample(&d, &targets, 1, 0);
        let t = feature_traffic(
            &mb,
            &pre.stores[1],
            d.features.bytes_per_vertex(),
            CommConfig::default(),
            None,
            1,
        );
        // every row is ~1/4 local under 4-way dimension slicing
        assert!((t.beta() - 0.25).abs() < 0.05, "beta={}", t.beta());
    }

    #[test]
    fn feature_service_matches_traffic_and_featgen() {
        let (d, pre, mb) = setup();
        let svc = FeatureService::new(&d.features, CommConfig::default());
        let (buf, t) = svc.gather(&mb, &pre.stores[0], pre.vertex_part.as_deref(), 0);
        let f0 = d.features.feat_dim();
        assert_eq!(buf.len(), mb.dims.v0_cap * f0);
        let t2 = feature_traffic(
            &mb,
            &pre.stores[0],
            d.features.bytes_per_vertex(),
            CommConfig::default(),
            pre.vertex_part.as_deref(),
            0,
        );
        assert_eq!(t, t2);
        // row contents match the generator
        let mut expect = vec![0f32; f0];
        d.features.write_features(mb.v0[3], &mut expect);
        assert_eq!(&buf[3 * f0..4 * f0], &expect[..]);
        // padding rows are zero
        assert!(buf[mb.n_v0 * f0..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn feature_service_is_reusable_and_traffic_merges() {
        let (d, pre, mb) = setup();
        let svc = FeatureService::new(&d.features, CommConfig::default());
        let (a, ta) = svc.gather(&mb, &pre.stores[0], pre.vertex_part.as_deref(), 0);
        let (b, tb) = svc.gather(&mb, &pre.stores[0], pre.vertex_part.as_deref(), 0);
        assert_eq!(a, b, "reused service must be deterministic");
        assert_eq!(ta, tb);
        let mut sum = Traffic::default();
        sum += ta;
        sum += tb;
        assert_eq!(sum.total_bytes(), 2 * ta.total_bytes());
    }

    #[test]
    fn gradient_sync_accounting() {
        assert_eq!(gradient_sync_bytes(1000, 4), 8000);
        let t4 = gradient_sync_seconds(1_000_000, 4, 16.0, 205.0);
        let t8 = gradient_sync_seconds(1_000_000, 8, 16.0, 205.0);
        assert!(t8 > t4);
    }
}
