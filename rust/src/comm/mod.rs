//! CPU↔FPGA / FPGA↔FPGA data-communication accounting and the host
//! feature service — the paper's data-communication (DC) optimization
//! (§5.2) and the β split of Eq. 7.
//!
//! For every mini-batch an FPGA executes, the features of the sampled
//! layer-0 vertices must be materialised in FPGA-local memory:
//!
//! - bytes already resident in the FPGA's [`FeatureStore`] → **local DDR**;
//! - missing bytes, DC **on** → fetched **directly from host CPU memory**
//!   over PCIe (the host holds the full X — §4.2);
//! - missing bytes, DC **off** (baseline) → if the row belongs to another
//!   FPGA's partition it travels FPGA→shared-host-buffer→FPGA, i.e. two
//!   PCIe crossings plus an extra CPU-memory copy ([26]); otherwise host.
//!
//! On top of the per-batch split, [`IterDedup`] implements
//! **iteration-level fetch dedup**: within one synchronous iteration the
//! `p` prepared batches often miss on the same hot vertices, so the host
//! read is staged once — the first host-path miss of a vertex per
//! iteration is charged to PCIe, every further copy only to CPU memory
//! bandwidth ([`Traffic::dedup_saved_bytes`]). The pass runs on the
//! coordinator at the gradient-sync barrier in (iter, tag) order, which
//! keeps the accounting bit-identical across pipeline configurations.
//!
//! [`FeatureService`] is the execution-path twin: it actually gathers the
//! feature rows into the executable's input buffer and reports the same
//! byte accounting, so the analytic benches and the real runtime can never
//! drift apart.

use crate::graph::FeatureGen;
use crate::sampling::MiniBatch;
use crate::store::FeatureStore;

/// Byte-level breakdown of one mini-batch's vertex-feature traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Traffic {
    /// Served from FPGA-local DDR.
    pub local_bytes: u64,
    /// Fetched directly from host CPU memory (one PCIe crossing).
    pub host_bytes: u64,
    /// FPGA-to-FPGA via the shared host buffer (two PCIe crossings + a
    /// CPU-memory copy) — only nonzero with DC disabled.
    pub f2f_bytes: u64,
    /// PCIe bytes avoided by iteration-level fetch dedup: duplicate
    /// host-path misses within one iteration ride the already-staged host
    /// read, paying only a CPU-memory copy. Zero until [`IterDedup`] runs.
    pub dedup_saved_bytes: u64,
    /// Layer-0 rows whose vertex was resident in the store (row-granular
    /// cache hits; equals β only for full-width stores).
    pub hit_rows: u64,
    /// Total layer-0 rows accounted.
    pub v0_rows: u64,
    /// Of the missed bytes (host + f2f + dedup-saved), the part served
    /// by the host-DRAM cache tier. Zero unless a `TieredStore` is
    /// active — DRAM-resident datasets serve every miss from DRAM and
    /// don't account the split. Not part of [`Traffic::total_bytes`]:
    /// `dram_hit + disk_read` *re-partitions* the miss bytes by source
    /// tier, it doesn't add new traffic.
    pub dram_hit_bytes: u64,
    /// Of the missed bytes, the part that fell through host DRAM to the
    /// on-disk tier (mmap page-in). See [`Traffic::dram_hit_bytes`].
    pub disk_read_bytes: u64,
}

impl std::ops::AddAssign for Traffic {
    /// Merge another batch's accounting (the coordinator combines the
    /// prep threads' per-batch values lock-free, in deterministic (iter,
    /// tag) order, at the gradient-sync barrier — `coordinator::trainer`).
    fn add_assign(&mut self, other: Traffic) {
        self.local_bytes += other.local_bytes;
        self.host_bytes += other.host_bytes;
        self.f2f_bytes += other.f2f_bytes;
        self.dedup_saved_bytes += other.dedup_saved_bytes;
        self.hit_rows += other.hit_rows;
        self.v0_rows += other.v0_rows;
        self.dram_hit_bytes += other.dram_hit_bytes;
        self.disk_read_bytes += other.disk_read_bytes;
    }
}

impl Traffic {
    /// The paper's β: fraction of feature bytes served locally (Eq. 7).
    /// Dedup-saved bytes still move (host copy), so they stay in the
    /// denominator — dedup changes *where* misses are paid, not β.
    pub fn beta(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 {
            1.0
        } else {
            self.local_bytes as f64 / total as f64
        }
    }

    /// Row-granular cache hit rate: fraction of layer-0 rows resident in
    /// the executing FPGA's store (1.0 when nothing was accounted).
    pub fn hit_rate(&self) -> f64 {
        if self.v0_rows == 0 {
            1.0
        } else {
            self.hit_rows as f64 / self.v0_rows as f64
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.local_bytes + self.host_bytes + self.f2f_bytes + self.dedup_saved_bytes
    }

    /// Bytes not served from FPGA-local DDR — exactly what the host
    /// memory hierarchy (DRAM tier, then disk) must supply. When a
    /// `TieredStore` is active, `dram_hit_bytes + disk_read_bytes`
    /// partitions this value (pinned by `prop_invariants`).
    pub fn missed_bytes(&self) -> u64 {
        self.host_bytes + self.f2f_bytes + self.dedup_saved_bytes
    }

    /// Fraction of missed bytes served by the host-DRAM tier (1.0 when
    /// nothing missed or no tiering split was recorded).
    pub fn dram_hit_rate(&self) -> f64 {
        let split = self.dram_hit_bytes + self.disk_read_bytes;
        if split == 0 {
            1.0
        } else {
            self.dram_hit_bytes as f64 / split as f64
        }
    }

    /// Wall-clock seconds to move this traffic, given DDR / PCIe GB/s.
    /// F2F pays two PCIe crossings through the shared host buffer; the
    /// crossings use different links and partially pipeline, so the
    /// effective penalty is [`F2F_PENALTY`]× a direct fetch plus the host
    /// copy (charged at CPU memory bandwidth `cpu_gbs`). Dedup-saved
    /// bytes are pure CPU-memory copies.
    pub fn seconds(&self, ddr_gbs: f64, pcie_gbs: f64, cpu_gbs: f64) -> f64 {
        const G: f64 = 1e9;
        self.local_bytes as f64 / (ddr_gbs * G)
            + self.host_bytes as f64 / (pcie_gbs * G)
            + self.f2f_bytes as f64 * (F2F_PENALTY / (pcie_gbs * G) + 1.0 / (cpu_gbs * G))
            + self.dedup_saved_bytes as f64 / (cpu_gbs * G)
    }
}

/// Effective slowdown of an FPGA→host-buffer→FPGA transfer relative to a
/// direct host fetch: the write (source link) and read (destination link)
/// overlap store-and-forward fashion, leaving ~1.5 serialized crossings
/// (cf. [26]'s measurements of shared-memory FPGA-to-FPGA paths).
pub const F2F_PENALTY: f64 = 1.5;

/// Communication configuration.
#[derive(Clone, Copy, Debug)]
pub struct CommConfig {
    /// DC optimization: fetch misses directly from host memory instead of
    /// the owning FPGA (paper §5.2). Table 7's "DC" column.
    pub direct_host_fetch: bool,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig { direct_host_fetch: true }
    }
}

/// Does a miss on vertex `v` take the host path (vs FPGA-to-FPGA)?
/// DC on: always. DC off: only when the row is not owned by a remote FPGA.
#[inline]
fn miss_is_host_path(
    cfg: CommConfig,
    vertex_part: Option<&[u32]>,
    fpga_id: usize,
    v: u32,
) -> bool {
    if cfg.direct_host_fetch {
        return true;
    }
    !vertex_part.map(|part| part[v as usize] as usize != fpga_id).unwrap_or(false)
}

/// Account the feature traffic of `mb` executed on FPGA `fpga_id` whose
/// resident rows are `store` (any [`FeatureStore`]; prep threads pass the
/// epoch's `Residency` snapshot). `vertex_part` (vertex→partition) is
/// needed only for the DC-off path to decide which misses are remote.
pub fn feature_traffic<S: FeatureStore + ?Sized>(
    mb: &MiniBatch,
    store: &S,
    row_bytes: usize,
    cfg: CommConfig,
    vertex_part: Option<&[u32]>,
    fpga_id: usize,
) -> Traffic {
    let res = store.residency();
    let mut t = Traffic::default();
    for &v in mb.level0() {
        let local = res.local_bytes(v, row_bytes) as u64;
        let miss = row_bytes as u64 - local;
        t.local_bytes += local;
        t.v0_rows += 1;
        if res.holds_row(v) {
            t.hit_rows += 1;
        }
        if miss == 0 {
            continue;
        }
        if miss_is_host_path(cfg, vertex_part, fpga_id, v) {
            t.host_bytes += miss;
        } else {
            t.f2f_bytes += miss;
        }
    }
    t
}

/// Iteration-scoped fetch-dedup state: a |V|-sized stamp array marking
/// which vertices already had their host read staged this iteration.
///
/// Protocol (coordinator only, at the gradient-sync barrier):
/// call [`next_iteration`](Self::next_iteration) once per iteration, then
/// [`apply`](Self::apply) for each of the iteration's prepared batches in
/// tag order, against the same residency snapshot the batch's traffic was
/// computed from. The pass only reclassifies host-path misses
/// (`host_bytes` → `dedup_saved_bytes`); local and F2F accounting — i.e.
/// the DC-on/off semantics — are untouched, and per-batch byte totals are
/// conserved.
pub struct IterDedup {
    stamp: Vec<u32>,
    cur: u32,
}

impl IterDedup {
    pub fn new(num_vertices: usize) -> IterDedup {
        IterDedup { stamp: vec![0; num_vertices], cur: 0 }
    }

    /// Open a new iteration window (forget the previous iteration's
    /// staged reads).
    pub fn next_iteration(&mut self) {
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            // stamp wrap-around: reset so stale marks can't collide
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.cur = 1;
        }
    }

    /// Reclassify this batch's duplicate host-path misses. `v0` is the
    /// batch's real layer-0 vertex list and `t` its [`feature_traffic`]
    /// accounting against `store` — both must match, or conservation
    /// breaks.
    ///
    /// Only full-width residencies participate: under dimension slicing
    /// (P3) each FPGA misses a *different* dim range of the same vertex,
    /// so a staged host read does not cover a later device's miss — a
    /// vertex-granular stamp would over-save. Partial-width batches pass
    /// through untouched.
    pub fn apply<S: FeatureStore + ?Sized>(
        &mut self,
        v0: &[u32],
        store: &S,
        row_bytes: usize,
        cfg: CommConfig,
        vertex_part: Option<&[u32]>,
        fpga_id: usize,
        t: &mut Traffic,
    ) {
        assert!(self.cur > 0, "call next_iteration() before apply()");
        let res = store.residency();
        if res.dim_fraction() < 1.0 {
            return;
        }
        for &v in v0 {
            let miss = row_bytes as u64 - res.local_bytes(v, row_bytes) as u64;
            if miss == 0 || !miss_is_host_path(cfg, vertex_part, fpga_id, v) {
                continue;
            }
            if self.stamp[v as usize] == self.cur {
                debug_assert!(t.host_bytes >= miss, "dedup applied twice or snapshot mismatch");
                t.host_bytes -= miss;
                t.dedup_saved_bytes += miss;
            } else {
                self.stamp[v as usize] = self.cur;
            }
        }
    }
}

/// Gradient-synchronisation traffic per iteration: every FPGA ships its
/// gradients to the host and receives the averaged copy back (§4.2).
pub fn gradient_sync_bytes(param_bytes: u64, p: usize) -> u64 {
    2 * param_bytes * p as u64
}

/// Gradient sync time over PCIe (all links transfer concurrently, so the
/// wall clock is one round trip, bounded by CPU memory bandwidth for the
/// reduction itself).
pub fn gradient_sync_seconds(param_bytes: u64, p: usize, pcie_gbs: f64, cpu_gbs: f64) -> f64 {
    const G: f64 = 1e9;
    // up + down on each link (concurrent across FPGAs) + p-way reduce on host
    2.0 * param_bytes as f64 / (pcie_gbs * G) + p as f64 * param_bytes as f64 / (cpu_gbs * G)
}

/// Host feature service: the execution-path materialisation of layer-0
/// features, with identical accounting to [`feature_traffic`].
///
/// The service is stateless (`gather` takes `&self`), `Copy`, and `Sync`:
/// construct it **once** per prep thread and reuse it for every batch —
/// the per-call [`Traffic`] return value makes the accounting lock-free
/// (merge with `+=` at the barrier).
#[derive(Clone, Copy)]
pub struct FeatureService<'a> {
    features: &'a FeatureGen,
    cfg: CommConfig,
}

impl<'a> FeatureService<'a> {
    pub fn new(features: &'a FeatureGen, cfg: CommConfig) -> FeatureService<'a> {
        FeatureService { features, cfg }
    }

    /// Gather `mb`'s layer-0 feature rows into a `[v0_cap, f0]` buffer and
    /// report the traffic split. Padding rows are zero-filled.
    pub fn gather<S: FeatureStore + ?Sized>(
        &self,
        mb: &MiniBatch,
        store: &S,
        vertex_part: Option<&[u32]>,
        fpga_id: usize,
    ) -> (Vec<f32>, Traffic) {
        let mut buf = Vec::new();
        let traffic = self.gather_into(mb, store, vertex_part, fpga_id, &mut buf);
        (buf, traffic)
    }

    /// [`FeatureService::gather`] into a caller-owned (recycled) buffer —
    /// the zero-allocation hot path. The buffer is resized to
    /// `[v0_cap, f0]` once and then fully overwritten each call: real
    /// rows by the generator, the padding tail explicitly zeroed, so a
    /// recycled buffer can never leak a previous batch's rows (DESIGN.md
    /// §Hot-path memory & kernels).
    pub fn gather_into<S: FeatureStore + ?Sized>(
        &self,
        mb: &MiniBatch,
        store: &S,
        vertex_part: Option<&[u32]>,
        fpga_id: usize,
        buf: &mut Vec<f32>,
    ) -> Traffic {
        let f0 = self.features.feat_dim();
        buf.resize(mb.dims.v0_cap() * f0, 0.0);
        for (row, &v) in mb.level0().iter().enumerate() {
            self.features.write_features(v, &mut buf[row * f0..(row + 1) * f0]);
        }
        buf[mb.n[0] * f0..].fill(0.0);
        feature_traffic(
            mb,
            store,
            self.features.bytes_per_vertex(),
            self.cfg,
            vertex_part,
            fpga_id,
        )
    }
}

/// The canonical sampler+gather steady-state allocation audit (feature
/// `alloc-count`): drive `Sampler::sample_into` + `gather_into` through
/// recycled buffers for `warmup` iterations, then measure `iters` more
/// through the counting global allocator and return the heap-allocation
/// event count (the zero-allocation contract expects 0). One protocol,
/// two consumers — `tests/alloc_steady_state.rs` asserts on it and the
/// `micro_host` kernel sweep reports it — so the audit can never drift
/// between CI and the bench.
#[cfg(feature = "alloc-count")]
#[allow(clippy::too_many_arguments)]
pub fn audit_sampler_gather_allocs<S: FeatureStore + ?Sized>(
    data: &crate::graph::Dataset,
    store: &S,
    vertex_part: Option<&[u32]>,
    fanout: crate::sampling::FanoutConfig,
    targets: &[u32],
    seed: u64,
    warmup: usize,
    iters: usize,
) -> u64 {
    use crate::sampling::{Sampler, WeightMode};
    use crate::util::alloc::allocation_count;
    let svc = FeatureService::new(&data.features, CommConfig::default());
    let mut sampler = Sampler::new(fanout, WeightMode::GcnNorm, data.graph.num_vertices(), seed);
    let mut mb = sampler.new_batch();
    let mut feat0 = Vec::new();
    for seq in 0..warmup {
        sampler.sample_into(&mut mb, data, targets, 0, seq);
        std::hint::black_box(svc.gather_into(&mb, store, vertex_part, 0, &mut feat0));
    }
    let before = allocation_count();
    for seq in warmup..warmup + iters {
        sampler.sample_into(&mut mb, data, targets, 0, seq);
        std::hint::black_box(svc.gather_into(&mb, store, vertex_part, 0, &mut feat0));
    }
    allocation_count() - before
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::partition::{preprocess, Algorithm};
    use crate::sampling::{FanoutConfig, Sampler, WeightMode};

    fn setup() -> (crate::graph::Dataset, crate::partition::Preprocessed, MiniBatch) {
        let d = datasets::lookup("reddit").unwrap().build(8, 23);
        let pre = preprocess(Algorithm::DistDgl, &d, 4, 0.2, 3);
        let mut s = Sampler::new(
            FanoutConfig::new(32, &[5, 3]),
            WeightMode::GcnNorm,
            d.graph.num_vertices(),
            5,
        );
        let targets: Vec<u32> = pre.train_parts[0][..32].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        (d, pre, mb)
    }

    #[test]
    fn conservation_local_plus_remote_equals_total() {
        let (d, pre, mb) = setup();
        let row = d.features.bytes_per_vertex();
        for dc in [true, false] {
            let t = feature_traffic(
                &mb,
                pre.stores[0].as_ref(),
                row,
                CommConfig { direct_host_fetch: dc },
                pre.vertex_part.as_deref(),
                0,
            );
            assert_eq!(t.total_bytes(), (mb.n[0] * row) as u64);
            assert!(t.beta() >= 0.0 && t.beta() <= 1.0);
            assert_eq!(t.v0_rows, mb.n[0] as u64);
            assert!(t.hit_rate() >= 0.0 && t.hit_rate() <= 1.0);
            assert_eq!(t.dedup_saved_bytes, 0, "plain accounting never dedups");
        }
    }

    #[test]
    fn dc_on_has_no_f2f_traffic() {
        let (d, pre, mb) = setup();
        let t = feature_traffic(
            &mb,
            pre.stores[0].as_ref(),
            d.features.bytes_per_vertex(),
            CommConfig { direct_host_fetch: true },
            pre.vertex_part.as_deref(),
            0,
        );
        assert_eq!(t.f2f_bytes, 0);
    }

    #[test]
    fn dc_off_routes_remote_misses_via_f2f_and_is_slower() {
        let (d, pre, mb) = setup();
        let row = d.features.bytes_per_vertex();
        let on = feature_traffic(&mb, pre.stores[0].as_ref(), row, CommConfig { direct_host_fetch: true }, pre.vertex_part.as_deref(), 0);
        let off = feature_traffic(&mb, pre.stores[0].as_ref(), row, CommConfig { direct_host_fetch: false }, pre.vertex_part.as_deref(), 0);
        // DistDGL stores partition rows locally, so every miss is remote:
        assert_eq!(off.host_bytes, 0);
        assert_eq!(off.f2f_bytes, on.host_bytes);
        // and the DC-off path is strictly slower for the same bytes
        let (ddr, pcie, cpu) = (19.25, 16.0, 205.0);
        assert!(off.seconds(ddr, pcie, cpu) > on.seconds(ddr, pcie, cpu));
    }

    #[test]
    fn p3_store_gives_partial_beta_but_full_hit_rate() {
        let d = datasets::lookup("reddit").unwrap().build(8, 23);
        let pre = preprocess(Algorithm::P3, &d, 4, 0.2, 3);
        let mut s = Sampler::new(
            FanoutConfig::new(32, &[5, 3]),
            WeightMode::GcnNorm,
            d.graph.num_vertices(),
            5,
        );
        let targets: Vec<u32> = pre.train_parts[1][..32].to_vec();
        let mb = s.sample(&d, &targets, 1, 0);
        let t = feature_traffic(
            &mb,
            pre.stores[1].as_ref(),
            d.features.bytes_per_vertex(),
            CommConfig::default(),
            None,
            1,
        );
        // every row is ~1/4 local under 4-way dimension slicing
        assert!((t.beta() - 0.25).abs() < 0.05, "beta={}", t.beta());
        // …but every row is (partially) resident: hit rate is row-granular
        assert_eq!(t.hit_rate(), 1.0);
    }

    #[test]
    fn iter_dedup_reclassifies_duplicate_host_misses() {
        let (d, pre, mb) = setup();
        let row = d.features.bytes_per_vertex();
        let cfg = CommConfig::default();
        // the same batch accounted on two FPGAs in one iteration: FPGA 1's
        // copy of any vertex FPGA 0 already missed rides the staged read
        let t0 = feature_traffic(&mb, pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0);
        let t1 = feature_traffic(&mb, pre.stores[1].as_ref(), row, cfg, pre.vertex_part.as_deref(), 1);
        let mut dd = IterDedup::new(d.graph.num_vertices());
        dd.next_iteration();
        let (mut a, mut b) = (t0, t1);
        dd.apply(mb.level0(), pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0, &mut a);
        dd.apply(mb.level0(), pre.stores[1].as_ref(), row, cfg, pre.vertex_part.as_deref(), 1, &mut b);
        // per-batch byte totals conserved; local / f2f untouched
        assert_eq!(a.total_bytes(), t0.total_bytes());
        assert_eq!(b.total_bytes(), t1.total_bytes());
        assert_eq!(a.local_bytes, t0.local_bytes);
        assert_eq!(b.local_bytes, t1.local_bytes);
        assert_eq!(a.f2f_bytes, t0.f2f_bytes);
        assert_eq!(b.f2f_bytes, t1.f2f_bytes);
        // the first batch stages every read: nothing to dedup yet
        assert_eq!(a.dedup_saved_bytes, 0);
        // DistDGL stores are disjoint, so every vertex missing on FPGA 1
        // but resident on FPGA 0 is NOT a duplicate; shared misses are the
        // rows resident on neither (partitions 2/3) — those must dedup
        let shared_miss: u64 = mb
            .level0()
            .iter()
            .filter(|&&v| {
                !pre.stores[0].residency().holds_row(v) && !pre.stores[1].residency().holds_row(v)
            })
            .count() as u64
            * row as u64;
        assert_eq!(b.dedup_saved_bytes, shared_miss);
        // dedup moves host bytes only
        assert_eq!(b.host_bytes + b.dedup_saved_bytes, t1.host_bytes);
        // and the deduped split is never slower
        let (ddr, pcie, cpu) = (19.25, 16.0, 205.0);
        assert!(b.seconds(ddr, pcie, cpu) <= t1.seconds(ddr, pcie, cpu));
    }

    #[test]
    fn iter_dedup_resets_between_iterations() {
        let (d, pre, mb) = setup();
        let row = d.features.bytes_per_vertex();
        let cfg = CommConfig::default();
        let base = feature_traffic(&mb, pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0);
        let mut dd = IterDedup::new(d.graph.num_vertices());
        for _ in 0..3 {
            dd.next_iteration();
            let mut t = base;
            dd.apply(mb.level0(), pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0, &mut t);
            // a fresh iteration has no staged reads to ride on
            assert_eq!(t, base);
            // …but a second copy within the same iteration dedups fully
            let mut t2 = base;
            dd.apply(mb.level0(), pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0, &mut t2);
            assert_eq!(t2.host_bytes, 0);
            assert_eq!(t2.dedup_saved_bytes, base.host_bytes);
            assert_eq!(t2.total_bytes(), base.total_bytes());
        }
    }

    #[test]
    fn iter_dedup_survives_stamp_wraparound() {
        // regression (ISSUE 5 satellite): after ~2^32 iterations the u32
        // stamp counter wraps and restarts at 1 — the stamp array must be
        // cleared on the wrap, or vertices staged back when the counter
        // was first at 1 would falsely dedup in the fresh iteration
        let (d, pre, mb) = setup();
        let row = d.features.bytes_per_vertex();
        let cfg = CommConfig::default();
        let base = feature_traffic(&mb, pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0);
        assert!(base.host_bytes > 0, "test needs host-path misses");
        let mut dd = IterDedup::new(d.graph.num_vertices());
        dd.next_iteration(); // cur == 1: stage this batch's reads
        let mut t = base;
        dd.apply(mb.level0(), pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0, &mut t);
        assert_eq!(t, base, "first apply only stages");
        // fast-forward to the wrap: the next iteration must restart at 1
        dd.cur = u32::MAX;
        dd.next_iteration();
        assert_eq!(dd.cur, 1, "counter restarts at 1 after the wrap");
        let mut t2 = base;
        dd.apply(mb.level0(), pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0, &mut t2);
        assert_eq!(t2, base, "stale stamps from the old cur==1 era must not alias");
        // dedup still works within the post-wrap iteration
        let mut t3 = base;
        dd.apply(mb.level0(), pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0, &mut t3);
        assert_eq!(t3.dedup_saved_bytes, base.host_bytes);
        assert_eq!(t3.host_bytes, 0);
    }

    #[test]
    fn gather_into_recycled_buffer_matches_fresh_gather() {
        // dirty buffer reuse across different batches must be invisible:
        // same bytes, same traffic as an allocating gather
        let (d, pre, mb) = setup();
        let svc = FeatureService::new(&d.features, CommConfig::default());
        let mut s = Sampler::new(
            FanoutConfig::new(32, &[5, 3]),
            WeightMode::GcnNorm,
            d.graph.num_vertices(),
            5,
        );
        let other = s.sample(&d, &pre.train_parts[1][..20], 1, 2);
        let mut buf = Vec::new();
        let t_other =
            svc.gather_into(&other, pre.stores[1].as_ref(), pre.vertex_part.as_deref(), 1, &mut buf);
        assert!(t_other.total_bytes() > 0);
        let t = svc.gather_into(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0, &mut buf);
        let (want, t_want) = svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0);
        assert_eq!(buf, want, "recycled gather buffer leaked state");
        assert_eq!(t, t_want);
    }

    #[test]
    fn iter_dedup_skips_dim_sliced_stores() {
        // P3: each FPGA misses a different dim range of the same vertex,
        // so a staged read covers nothing for the next device — the pass
        // must be a no-op on partial-width residencies
        let d = datasets::lookup("reddit").unwrap().build(8, 23);
        let pre = preprocess(Algorithm::P3, &d, 4, 0.2, 3);
        let mut s = Sampler::new(
            FanoutConfig::new(32, &[5, 3]),
            WeightMode::GcnNorm,
            d.graph.num_vertices(),
            5,
        );
        let mb = s.sample(&d, &pre.train_parts[0][..32], 0, 0);
        let row = d.features.bytes_per_vertex();
        let cfg = CommConfig::default();
        let mut dd = IterDedup::new(d.graph.num_vertices());
        dd.next_iteration();
        for fpga in 0..2 {
            let base = feature_traffic(&mb, pre.stores[fpga].as_ref(), row, cfg, None, fpga);
            let mut t = base;
            dd.apply(mb.level0(), pre.stores[fpga].as_ref(), row, cfg, None, fpga, &mut t);
            assert_eq!(t, base, "partial-width store must pass through untouched");
        }
    }

    #[test]
    fn iter_dedup_preserves_dc_off_f2f_semantics() {
        let (d, pre, mb) = setup();
        let row = d.features.bytes_per_vertex();
        let cfg = CommConfig { direct_host_fetch: false };
        let base = feature_traffic(&mb, pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0);
        let mut dd = IterDedup::new(d.graph.num_vertices());
        dd.next_iteration();
        let mut t = base;
        dd.apply(mb.level0(), pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0, &mut t);
        let mut t2 = base;
        dd.apply(mb.level0(), pre.stores[0].as_ref(), row, cfg, pre.vertex_part.as_deref(), 0, &mut t2);
        // under DistDGL + DC off every miss is F2F: dedup must not touch it
        assert_eq!(t2.f2f_bytes, base.f2f_bytes);
        assert_eq!(t2.dedup_saved_bytes, 0);
    }

    #[test]
    fn feature_service_matches_traffic_and_featgen() {
        let (d, pre, mb) = setup();
        let svc = FeatureService::new(&d.features, CommConfig::default());
        let (buf, t) = svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0);
        let f0 = d.features.feat_dim();
        assert_eq!(buf.len(), mb.dims.v0_cap() * f0);
        let t2 = feature_traffic(
            &mb,
            pre.stores[0].as_ref(),
            d.features.bytes_per_vertex(),
            CommConfig::default(),
            pre.vertex_part.as_deref(),
            0,
        );
        assert_eq!(t, t2);
        // row contents match the generator
        let mut expect = vec![0f32; f0];
        d.features.write_features(mb.v[0][3], &mut expect);
        assert_eq!(&buf[3 * f0..4 * f0], &expect[..]);
        // padding rows are zero
        assert!(buf[mb.n[0] * f0..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn feature_service_is_reusable_and_traffic_merges() {
        let (d, pre, mb) = setup();
        let svc = FeatureService::new(&d.features, CommConfig::default());
        let (a, ta) = svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0);
        let (b, tb) = svc.gather(&mb, pre.stores[0].as_ref(), pre.vertex_part.as_deref(), 0);
        assert_eq!(a, b, "reused service must be deterministic");
        assert_eq!(ta, tb);
        let mut sum = Traffic::default();
        sum += ta;
        sum += tb;
        assert_eq!(sum.total_bytes(), 2 * ta.total_bytes());
        assert_eq!(sum.v0_rows, 2 * ta.v0_rows);
    }

    #[test]
    fn gradient_sync_accounting() {
        assert_eq!(gradient_sync_bytes(1000, 4), 8000);
        let t4 = gradient_sync_seconds(1_000_000, 4, 16.0, 205.0);
        let t8 = gradient_sync_seconds(1_000_000, 8, 16.0, 205.0);
        assert!(t8 > t4);
    }
}
