//! Full-iteration steady-state allocation audit (ISSUE 7 acceptance,
//! feature `alloc-count`).
//!
//! Extends the sampler+gather audit (`comm::audit_sampler_gather_allocs`)
//! to the *whole* training iteration: sample → feature gather → batch
//! assembly → p reference train steps into recycled [`GradBuffers`] →
//! [`GradReducer::reduce`] → [`Sgd::step_fused`]. After warm-up the
//! entire loop must perform **zero** heap allocations per iteration. One
//! protocol, two consumers — `tests/alloc_steady_state.rs` asserts on it
//! and the `micro_host` kernel sweep reports it — so the audit can never
//! drift between CI and the bench.
//!
//! The reduction deliberately runs its serial path: tiny's parameter set
//! sits far below [`PAR_MIN_ELEMS`], and `std::thread::scope` spawns
//! allocate by design, so the scoped parallel path is outside the
//! zero-allocation contract. What the audit pins is that the per-element
//! work — summation, fused update, buffer recycling — never touches the
//! heap.
//!
//! [`GradBuffers`]: crate::runtime::GradBuffers
//! [`GradReducer::reduce`]: super::params::GradReducer::reduce
//! [`Sgd::step_fused`]: super::params::Sgd::step_fused
//! [`PAR_MIN_ELEMS`]: super::params::PAR_MIN_ELEMS

/// Drive `iters` full training iterations (after `warmup` warm-up
/// iterations) on the bundled tiny dataset with `num_fpgas` simulated
/// workers running `model` (any `runtime::MODEL_NAMES` architecture —
/// the zero-allocation contract covers the whole zoo, attention and MLP
/// lanes included), and return the heap-allocation event count of the
/// measured window (the contract expects 0).
pub fn audit_full_iteration_allocs(
    model: &str,
    num_fpgas: usize,
    warmup: usize,
    iters: usize,
) -> u64 {
    use crate::comm::{CommConfig, FeatureService};
    use crate::coordinator::params::{GradReducer, ParamSet, Sgd};
    use crate::graph::datasets;
    use crate::partition::{preprocess, Algorithm};
    use crate::runtime::manifest::synth_entry;
    use crate::runtime::{BatchBuffers, GradBuffers, RefModel};
    use crate::sampling::{FanoutConfig, MiniBatch, Sampler, WeightMode};
    use crate::util::alloc::allocation_count;

    /// One simulated-FPGA lane: its sampler, recycled batch carcasses,
    /// reference executor, and recycled gradient buffers.
    struct Lane {
        sampler: Sampler,
        mb: MiniBatch,
        targets: Vec<u32>,
        model: RefModel,
        bufs: BatchBuffers,
        grads: GradBuffers,
    }

    let b_size = 64usize;
    let fanouts = [5usize, 3];
    let data = datasets::lookup("tiny").expect("tiny dataset").build(0, 21);
    let pre = preprocess(Algorithm::DistDgl, &data, num_fpgas, 0.2, 21);
    let svc = FeatureService::new(&data.features, CommConfig::default());
    let mode = WeightMode::for_model(model).expect("zoo model");
    let entry = synth_entry(
        std::path::Path::new("/tmp"),
        "train",
        model,
        "tiny",
        b_size,
        &fanouts,
        data.spec.dims,
    );
    let f0 = entry.dims.f0();
    let mut params = ParamSet::init(&entry, 7);
    let mut opt = Sgd::new(0.1, 0.9, &params);
    // threads = 1: always the serial reduce path (see module docs)
    let mut reducer = GradReducer::new(&params, 1);
    let mut lanes: Vec<Lane> = (0..num_fpgas)
        .map(|w| {
            let cfg = FanoutConfig::new(b_size, &fanouts);
            let sampler = Sampler::new(cfg, mode, data.graph.num_vertices(), 9 + w as u64);
            let mb = sampler.new_batch();
            let take = pre.train_parts[w].len().min(b_size);
            Lane {
                mb,
                targets: pre.train_parts[w][..take].to_vec(),
                model: RefModel::new(&entry).expect("reference model"),
                bufs: BatchBuffers::empty(),
                grads: GradBuffers::empty(),
                sampler,
            }
        })
        .collect();
    let mut grad_scratch: Vec<GradBuffers> = Vec::with_capacity(num_fpgas);

    let mut before = 0u64;
    for seq in 0..warmup + iters {
        if seq == warmup {
            before = allocation_count();
        }
        grad_scratch.clear();
        for (w, lane) in lanes.iter_mut().enumerate() {
            lane.sampler.sample_into(&mut lane.mb, &data, &lane.targets, w, seq);
            std::hint::black_box(svc.gather_into(
                &lane.mb,
                pre.stores[w].as_ref(),
                pre.vertex_part.as_deref(),
                w,
                &mut lane.bufs.feat0,
            ));
            lane.bufs.fill_from(&lane.mb, f0);
            let loss = lane
                .model
                .train_step_into(&params.data, &lane.bufs, &mut lane.grads)
                .expect("train step");
            std::hint::black_box(loss);
            grad_scratch.push(std::mem::take(&mut lane.grads));
        }
        reducer.reduce(&grad_scratch);
        opt.step_fused(&mut params, reducer.acc(), grad_scratch.len());
        // hand the carcasses back, exactly like the trainer's grad pool
        for (lane, g) in lanes.iter_mut().zip(grad_scratch.drain(..)) {
            lane.grads = g;
        }
    }
    allocation_count() - before
}
