//! The `hitgnn` launcher.

use crate::dse::{paper_dse_workloads, DseEngine};
use crate::fpga::DieConfig;
use crate::graph::datasets;
use crate::partition::Algorithm;
use crate::perf::{FleetModel, PlatformModel, PlatformSpec, Workload};
use crate::sched::SchedMode;
use crate::util::bench::Table;
use crate::util::cli::Args;
use crate::util::stats::si;

const HELP: &str = "\
hitgnn — HitGNN: high-throughput GNN training on CPU+Multi-FPGA (reproduction)

USAGE:
    hitgnn <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
    train      run synchronous GNN training (real PJRT execution path)
    dse        run the hardware design-space exploration engine (§6)
    simulate   analytic platform estimate for one configuration (§6.2)
    pack       serialize a dataset to an on-disk .hitg pack (mmap training)
    info       print the dataset registry and platform metadata
    help       show this message

TRAIN OPTIONS:
    --dataset <reddit|yelp|amazon|ogbn-products>   (default ogbn-products)
    --model <gcn|sage|gat|gin>   --algo <distdgl|pagraph|p3>
    --fanouts <k1,..,kL>         per-layer fanouts, input-side hop first
                                 (DESIGN.md §Mini-batch wire format; e.g.
                                 15,10,5 = 3-layer GraphSAGE recipe).
                                 Default: the dataset artifact's depth
    --fpgas <p>                  --epochs <n>
    --fleet <spec>               heterogeneous fleet, comma-separated
                                 kind:count over u250 | u250-half |
                                 u250-quarter | u250-shared (e.g.
                                 u250:2,u250-half:2); implies --fpgas
    --sched <batch-count|cost>   stage-2 assignment: Algorithm 3's
                                 batch-count balancing or least-
                                 estimated-finish-time under the fleet
                                 cost model (default cost)
    --cpu-mem <GB/s>             host CPU memory bandwidth for the
                                 scheduler cost model (default 205)
    --lr <f>                     --momentum <f>
    --scale-shift <s>            graph scaled to |V|/2^s (default 4)
    --cache-ratio <f>            cache fraction of |V|, in [0, 1] (default 0.2)
    --cache-policy <p>           feature-store policy: static (Table-1
                                 algorithm default) | lfu (hotness cache,
                                 re-ranked per epoch from observed access
                                 counts) | window (sliding-window recency)
    --no-wb / --no-dc            disable an optimization (ablation)
    --no-dedup                   disable iteration-level fetch dedup
    --host-threads <n>           batch-preparation pool size (default 1)
    --prefetch-depth <d>         bounded prefetch window: up to d-1
                                 iterations prepare ahead of the one
                                 executing (default 1 = serial)
    --prefetch                   legacy alias for --prefetch-depth 2 (§8)
    --no-pool                    disable batch + gradient buffer recycling
                                 (debug/ablation; results are bit-identical
                                 either way)
    --reduce-threads <n>         scoped threads for the gradient reduction
                                 (default 4; 1 = serial; bit-identical at
                                 any value)
    --auto-tune <on|off|freeze>  closed-loop epoch auto-tuning (DESIGN.md
                                 §Adaptive control): retunes host-threads,
                                 prefetch-depth, sched, and (dynamic
                                 policies) cache-ratio between epochs;
                                 freeze observes/logs without retuning.
                                 Losses are bit-identical either way
                                 (default off)
    --max-iterations <n>         cap iterations per epoch
    --dataset-path <f.hitg>      train from a packed on-disk dataset
                                 (written by `hitgnn pack`): the graph +
                                 features are mmapped instead of generated
                                 in memory, and the pack's embedded key +
                                 scale shift override --dataset /
                                 --scale-shift
    --dram-ratio <f>             host-DRAM tier capacity as a fraction of
                                 |V| feature rows, in [0, 1] (default 1 =
                                 everything resident). Below 1 a DRAM
                                 cache sits between the FPGA stores and
                                 disk, re-ranked with --cache-policy at
                                 the epoch barrier; misses are charged as
                                 disk reads
    --disk-gbs <GB/s>            disk read bandwidth for the cost model's
                                 miss term (default 2; priced only when
                                 --dram-ratio < 1)
    --fault-plan <spec>          deterministic fault injection (DESIGN.md
                                 §Fault tolerance), comma-separated:
                                 devN:fail@eEiI (device lost before that
                                 iteration; its remaining batches reroute
                                 to survivors), devN:slow*M@eE (straggler:
                                 M× cost-model price from epoch E),
                                 disk:eio@p (transient disk-read errors,
                                 bounded retry), prep:panic@eEiI (a prep
                                 worker panics). Same plan + same seed =
                                 bit-identical losses
    --checkpoint-dir <dir>       write a versioned snapshot (params, SGD
                                 momentum, RNG, store + tuner state) after
                                 every epoch as ckpt-eNNNNN.hitg
    --resume <path>              resume from a checkpoint file, or from
                                 the newest one in a directory; training
                                 continues bit-identically to the
                                 uninterrupted run (same --seed required)
    --seed <u64>                 --artifacts <dir>
    --report <file.json>         write the training report

PACK OPTIONS:
    --dataset <key>              registry dataset to pack (default
                                 ogbn-products)
    --scale-shift <s>            scale |V|,|E| by 1/2^s (default 4)
    --seed <u64>                 generator seed (default 42); train runs
                                 must use the same seed for bit-identical
                                 losses vs the in-memory path
    --out <file.hitg>            output path (required)
    --mem-budget <bytes>         streaming writer working-set bound
                                 (default 64 MiB); the pack is byte-
                                 identical at any budget

DSE OPTIONS:
    --model <gcn|sage|gat|gin>   --fpgas <p>
    --m-step <k>                 update-PE sweep granularity (default 16)

SIMULATE OPTIONS:
    --dataset --model --algo --fpgas --fleet --sched --cpu-mem --no-wb --no-dc
                                 as above
    --beta <f>                   local-fetch ratio (default 0.75)
    --batch <B> --fanouts <list> mini-batch configuration (1024, 25,10);
                                 --k1/--k2 remain as 2-layer aliases
    (with --fleet the estimate runs the per-device fleet model and also
     reports the epoch makespan-seconds under both scheduler modes)
";

/// Entry point used by main.rs; returns the process exit code.
pub fn main_entry() -> i32 {
    let args = Args::from_env();
    match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    }
}

pub fn run(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("dse") => cmd_dse(args),
        Some("simulate") => cmd_simulate(args),
        Some("pack") => cmd_pack(args),
        Some("info") => cmd_info(args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand '{other}' (try `hitgnn help`)"),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = super::config::TrainConfig::from_args(args)?;
    let report_path = args.opt_str("report");
    args.finish()?;
    let mut trainer = super::trainer::Trainer::new(cfg)?;
    let report = trainer.run()?;
    let acc = trainer.evaluate(4)?;
    println!("final mean loss: {:.4}", report.last_loss());
    println!("train-set accuracy (4 batches): {:.3}", acc);
    if let Some(path) = report_path {
        report.save(std::path::Path::new(&path))?;
        println!("report written to {path}");
    }
    trainer.shutdown();
    Ok(())
}

fn cmd_dse(args: &Args) -> anyhow::Result<()> {
    let model = args.str("model", "sage");
    let p: usize = args.num("fpgas", 4)?;
    let m_step: u32 = args.num("m-step", 16)?;
    args.finish()?;
    let cost = crate::fpga::timing::ModelCost::for_model(&model)?;
    let mut spec = PlatformSpec::paper_4fpga();
    spec.num_fpgas = p;
    let mut engine = DseEngine::new(spec);
    engine.m_step = m_step;
    let res = engine.explore(&paper_dse_workloads(cost))?;
    println!(
        "search space: n ≤ {} per die, m ≤ {} per die ({} feasible points)",
        res.n_max,
        res.m_max,
        res.grid.len()
    );
    let b = &res.best;
    println!(
        "best: FPGA-level (n={}, m={}) → estimated {} NVTPS",
        b.n_fpga,
        b.m_fpga,
        si(b.throughput)
    );
    println!(
        "utilization: DSP {:.0}%  LUT {:.0}%  URAM {:.0}%  BRAM {:.0}%",
        b.utilization.dsp * 100.0,
        b.utilization.lut * 100.0,
        b.utilization.uram * 100.0,
        b.utilization.bram * 100.0
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let dataset = args.str("dataset", "ogbn-products");
    let model = args.str("model", "gcn");
    let _algo = Algorithm::parse(&args.str("algo", "distdgl"))?;
    let (fleet, p) = super::config::fleet_args(args, 4)?;
    let sched = SchedMode::parse(&args.str("sched", "cost"))?;
    let cpu_mem_gbs: f64 = args.num("cpu-mem", 205.0)?;
    let beta: f64 = args.num("beta", 0.75)?;
    let batch: f64 = args.num("batch", 1024.0)?;
    let k1: f64 = args.num("k1", 25.0)?;
    let k2: f64 = args.num("k2", 10.0)?;
    let fanouts: Vec<f64> = match args.opt_str("fanouts") {
        Some(list) => {
            let f = crate::sampling::parse_fanouts(&list)?;
            crate::sampling::FanoutConfig::new(batch.max(1.0) as usize, &f).validate()?;
            f.iter().map(|&k| k as f64).collect()
        }
        // legacy 2-layer aliases
        None => vec![k1, k2],
    };
    let wb = !args.flag("no-wb");
    let dc = !args.flag("no-dc");
    args.finish()?;

    let spec = datasets::lookup(&dataset)?;
    let mut plat = PlatformSpec::paper_4fpga();
    plat.num_fpgas = p;
    plat.cpu_mem_gbs = cpu_mem_gbs;
    let cost = crate::fpga::timing::ModelCost::for_model(&model)?;
    let widths: Vec<f64> = crate::runtime::manifest::feature_widths(spec.dims, fanouts.len())
        .iter()
        .map(|&x| x as f64)
        .collect();
    let shape = crate::fpga::timing::BatchShape::nominal(batch, &fanouts, &widths);
    let batches = (spec.vertices as f64 * spec.train_frac / batch).ceil() as usize;
    let w = Workload {
        shape,
        beta,
        cost,
        sampling_s_per_batch: 2e-3,
        batches_per_part: vec![batches / p.max(1); p],
        workload_balancing: wb,
        direct_host_fetch: dc,
        extra_pcie_bytes_per_batch: 0.0,
        prefetch: false,
        disk_gbs: 0.0,
        disk_miss_frac: 0.0,
    };
    let mut t = Table::new(&["metric", "value"]);
    if let Some(devices) = fleet {
        // heterogeneous path: per-device fleet model, scheduler-aware
        let fm = FleetModel::new(devices, plat.cpu_mem_gbs);
        let est = fm.epoch(&w, sched);
        let cost = fm.cost_model(&w);
        t.row(&["scheduler mode".into(), sched.name().to_string()]);
        t.row(&["epoch time (s)".into(), format!("{:.3}", est.epoch_s)]);
        t.row(&["iterations".into(), est.iterations.to_string()]);
        t.row(&["throughput (NVTPS)".into(), si(est.nvtps)]);
        t.row(&["makespan (batch units)".into(), est.makespan_batches.to_string()]);
        t.row(&[
            format!("makespan (s), {} WB", sched.name()),
            format!("{:.3}", est.makespan_seconds),
        ]);
        for mode in SchedMode::ALL {
            if mode == sched {
                continue; // already printed from est
            }
            let e = fm.epoch(&w, mode);
            t.row(&[
                format!("makespan (s), {} WB", mode.name()),
                format!("{:.3}", e.makespan_seconds),
            ]);
        }
        t.row(&["gradient sync (ms)".into(), format!("{:.3}", est.gradient_sync_s * 1e3)]);
        let per_dev: Vec<String> =
            cost.batch_s.iter().map(|s| format!("{:.2}", s * 1e3)).collect();
        t.row(&["per-device batch time (ms)".into(), per_dev.join(" / ")]);
    } else {
        let pm = PlatformModel::new(plat, DieConfig { n: 2, m: 512 });
        let est = pm.epoch(&w);
        t.row(&["epoch time (s)".into(), format!("{:.3}", est.epoch_s)]);
        t.row(&["iterations".into(), est.iterations.to_string()]);
        t.row(&["throughput (NVTPS)".into(), si(est.nvtps)]);
        t.row(&["BW efficiency (NVTPS/(GB/s))".into(), si(est.bw_efficiency)]);
        t.row(&["per-batch GNN time (ms)".into(), format!("{:.3}", est.batch_gnn_s * 1e3)]);
        t.row(&["gradient sync (ms)".into(), format!("{:.3}", est.gradient_sync_s * 1e3)]);
    }
    t.print();
    Ok(())
}

fn cmd_pack(args: &Args) -> anyhow::Result<()> {
    let dataset = args.str("dataset", "ogbn-products");
    let scale_shift: u32 = args.num("scale-shift", 4)?;
    let seed: u64 = args.num("seed", 42)?;
    let out = args
        .opt_str("out")
        .ok_or_else(|| anyhow::anyhow!("pack needs --out <file.hitg>"))?;
    let budget: usize =
        args.num("mem-budget", crate::graph::ondisk::DEFAULT_PACK_BUDGET)?;
    args.finish()?;
    let spec = datasets::lookup(&dataset)?;
    let path = std::path::Path::new(&out);
    let bytes = crate::graph::ondisk::pack_streamed(&spec, scale_shift, seed, path, budget)?;
    println!("wrote {} ({})", path.display(), si(bytes as f64));
    println!("{}", crate::graph::ondisk::describe(path)?);
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    args.finish()?;
    let mut t = Table::new(&["dataset", "|V|", "|E|", "f0", "f1", "f2", "train%"]);
    for s in &datasets::REGISTRY {
        t.row(&[
            s.key.to_string(),
            s.vertices.to_string(),
            s.edges.to_string(),
            s.dims.f0.to_string(),
            s.dims.f1.to_string(),
            s.dims.f2.to_string(),
            format!("{:.0}%", s.train_frac * 100.0),
        ]);
    }
    t.print();
    let f = crate::fpga::U250;
    println!(
        "\nFPGA: {} — {} dies, {} DSP/die, {} kLUT/die, {:.2} GB/s DDR/die, {} MHz",
        f.name,
        f.dies,
        f.dsp_per_die,
        f.lut_per_die / 1000,
        f.ddr_gbs_per_die,
        f.freq_mhz
    );
    let p = PlatformSpec::paper_4fpga();
    println!(
        "platform: {} FPGAs, PCIe {} GB/s per link, CPU mem {} GB/s (total BW {} GB/s)",
        p.num_fpgas,
        p.pcie_gbs,
        p.cpu_mem_gbs,
        p.total_bandwidth_gbs()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_runs() {
        run(&Args::parse(["help"])).unwrap();
        run(&Args::parse(Vec::<String>::new())).unwrap();
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(&Args::parse(["bogus"])).is_err());
    }

    #[test]
    fn info_and_simulate_run() {
        run(&Args::parse(["info"])).unwrap();
        run(&Args::parse(["simulate", "--dataset", "reddit", "--fpgas", "4"])).unwrap();
    }

    #[test]
    fn simulate_accepts_fleet_and_sched() {
        run(&Args::parse([
            "simulate", "--dataset", "reddit", "--fleet", "u250-half:2,u250:2",
        ]))
        .unwrap();
        run(&Args::parse([
            "simulate", "--fleet", "u250:2", "--sched", "batch-count",
        ]))
        .unwrap();
        // fleet/fpgas mismatch is rejected
        assert!(run(&Args::parse(["simulate", "--fleet", "u250:2", "--fpgas", "3"])).is_err());
        assert!(run(&Args::parse(["simulate", "--fleet", "gpu:2"])).is_err());
    }

    #[test]
    fn simulate_accepts_fanouts_list() {
        run(&Args::parse(["simulate", "--dataset", "reddit", "--fanouts", "15,10,5"])).unwrap();
        run(&Args::parse(["simulate", "--fleet", "u250:2", "--fanouts", "8,4"])).unwrap();
        assert!(run(&Args::parse(["simulate", "--fanouts", "0,5"])).is_err());
        assert!(run(&Args::parse(["simulate", "--fanouts", "abc"])).is_err());
    }

    #[test]
    fn pack_subcommand_writes_a_loadable_pack() {
        let dir = std::env::temp_dir().join("hitgnn-cli-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join(format!("cli-pack-{}.hitg", std::process::id()));
        let out_s = out.to_str().unwrap().to_string();
        run(&Args::parse([
            "pack", "--dataset", "tiny", "--scale-shift", "1", "--seed", "7", "--out",
            out_s.as_str(),
        ]))
        .unwrap();
        let meta = crate::graph::ondisk::probe(&out).unwrap();
        assert_eq!(meta.key, "tiny");
        assert_eq!(meta.scale_shift, 1);
        std::fs::remove_file(&out).ok();
        // --out is required; unknown datasets are rejected
        assert!(run(&Args::parse(["pack", "--dataset", "tiny"])).is_err());
        assert!(run(&Args::parse(["pack", "--dataset", "bogus", "--out", "/tmp/x.hitg"]))
            .is_err());
    }

    #[test]
    fn dse_runs_with_coarse_step() {
        run(&Args::parse(["dse", "--m-step", "128"])).unwrap();
    }

    #[test]
    fn simulate_and_dse_accept_every_zoo_model() {
        for model in crate::runtime::MODEL_NAMES {
            run(&Args::parse(["simulate", "--dataset", "reddit", "--model", model])).unwrap();
        }
        run(&Args::parse(["dse", "--model", "gat", "--m-step", "256"])).unwrap();
    }

    #[test]
    fn unknown_model_is_rejected_with_the_expected_set() {
        for cmd in ["simulate", "dse"] {
            let err = run(&Args::parse([cmd, "--model", "transformer"])).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("unknown model 'transformer'"), "{cmd}: {msg}");
            assert!(msg.contains("expected one of gcn|sage|gat|gin"), "{cmd}: {msg}");
        }
    }
}
