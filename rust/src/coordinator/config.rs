//! Training-run configuration.

use std::path::PathBuf;

use crate::fpga::{self, DeviceSpec};
use crate::partition::Algorithm;
use crate::sched::SchedMode;
use crate::store::CachePolicy;
use crate::tune::AutoTuneMode;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Everything a training run needs (the "user program" of Listing 1).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: String,
    /// Model-zoo architecture (`runtime::MODEL_NAMES`):
    /// "gcn" | "sage" | "gat" | "gin".
    pub model: String,
    /// Per-layer fanouts (`--fanouts 15,10,5`, DESIGN.md §Mini-batch wire
    /// format order: input-side hop first). `None` = the dataset
    /// artifact's default depth/fanouts. With the reference executor any
    /// depth trains (the entry is synthesized); PJRT builds require an
    /// artifact compiled at the requested fanouts.
    pub fanouts: Option<Vec<usize>>,
    pub algo: Algorithm,
    /// Simulated FPGAs (= partitions = workers).
    pub num_fpgas: usize,
    /// Per-device platform metadata (`--fleet u250:2,u250-half:2`).
    /// `None` = `num_fpgas` identical paper U250s; when set, its length
    /// must equal `num_fpgas` (FPGA *i* executes partition *i* in
    /// stage 1). Heterogeneity affects the scheduler's cost model and the
    /// makespan metrics — execution itself is simulated on CPU workers.
    pub fleet: Option<Vec<DeviceSpec>>,
    /// Stage-2 assignment mode: Algorithm 3's batch-count balancing or
    /// least-estimated-finish-time under the fleet cost model
    /// (`--sched batch-count|cost`). Identical plans on homogeneous
    /// fleets; paired (same batches per iteration) on heterogeneous ones.
    pub sched: SchedMode,
    /// Host CPU memory bandwidth (GB/s) for the scheduler cost model —
    /// the host-fetch path saturates at `cpu_mem_gbs / num_fpgas`.
    /// Default: the paper platform's 205 (Table 3); `HitGnn::platform`
    /// threads its value through so design-time DSE and the trainer use
    /// the same host metadata.
    pub cpu_mem_gbs: f64,
    pub epochs: usize,
    pub lr: f32,
    pub momentum: f32,
    /// Dataset scale shift (|V|,|E| ÷ 2^shift) for the execution path.
    pub scale_shift: u32,
    /// Cache capacity as a fraction of |V| (PaGraph and the dynamic
    /// policies). Must be in [0, 1].
    pub cache_ratio: f64,
    /// Feature-store caching policy: the algorithm's static Table-1 store
    /// or a dynamic (LFU-hotness / sliding-window) cache re-ranked at the
    /// epoch barrier from observed accesses.
    pub cache_policy: CachePolicy,
    /// Iteration-level fetch dedup: duplicate host-path misses within one
    /// iteration ride a single staged host read (`comm::IterDedup`).
    pub fetch_dedup: bool,
    /// WB optimization (two-stage scheduling).
    pub workload_balancing: bool,
    /// DC optimization (direct host fetch).
    pub direct_host_fetch: bool,
    /// §8 future-work extension: prepare iteration i+1's batches (sample +
    /// feature gather) while the workers execute iteration i. Kept for
    /// compatibility; equivalent to `prefetch_depth >= 2`.
    pub prefetch: bool,
    /// Size of the host batch-preparation pool (prep threads). 1 prepares
    /// each iteration's batches sequentially, as the seed did.
    pub host_threads: usize,
    /// Bounded prefetch window depth D: how many iterations may be in
    /// preparation ahead of the one executing (1 = no prefetch).
    pub prefetch_depth: usize,
    /// Scoped threads for the gradient reduction (`--reduce-threads`,
    /// DESIGN.md §SIMD dispatch & gradient sync). 1 = serial; any value
    /// produces bit-identical losses (per-element sums stay in worker
    /// tag order), so this knob is runtime-safe like `host_threads`.
    pub reduce_threads: usize,
    /// Recycle consumed batch buffers back to the prep pool (the
    /// zero-allocation steady state, DESIGN.md §Hot-path memory &
    /// kernels). `--no-pool` disables it — the debug/ablation escape
    /// hatch; results are bit-identical either way (the determinism
    /// suite asserts it).
    pub buffer_pool: bool,
    /// Between-epoch closed-loop tuning of the runtime-safe knobs
    /// (`--auto-tune on|off|freeze`, DESIGN.md §Adaptive control). `on`
    /// lets the controller retune host_threads / prefetch_depth / sched /
    /// cache_ratio; `freeze` runs the controller observe-and-log only;
    /// `off` skips it entirely. Never affects the loss sequence.
    pub auto_tune: AutoTuneMode,
    pub seed: u64,
    pub artifacts_dir: PathBuf,
    /// Cap on iterations per epoch (None = full epoch); lets examples and
    /// benches bound wall-clock.
    pub max_iterations: Option<usize>,
    /// Packed on-disk dataset (`--dataset-path run.hitg`, written by
    /// `hitgnn pack`). When set the graph/features are mmapped from the
    /// pack instead of generated in memory, and the pack's embedded
    /// dataset key + scale shift override `dataset`/`scale_shift`
    /// (DESIGN.md §Out-of-core storage).
    pub dataset_path: Option<String>,
    /// Host-DRAM tier capacity as a fraction of |V| feature rows
    /// (`--dram-ratio`). 1.0 = everything resident (no tier, the
    /// pre-out-of-core behavior); < 1.0 inserts a DRAM cache between the
    /// FPGA stores and disk, re-ranked with `cache_policy` at the epoch
    /// barrier. Must be in [0, 1].
    pub dram_ratio: f64,
    /// Sequential disk read bandwidth (GB/s) for the perf model's
    /// miss-traffic term (`--disk-gbs`); only priced when
    /// `dram_ratio < 1`.
    pub disk_gbs: f64,
    /// Deterministic fault schedule (`--fault-plan
    /// "dev1:fail@e2i7,dev3:slow*4@e1,disk:eio@0.01,prep:panic@e3i2"`,
    /// DESIGN.md §Fault tolerance). Device ids and epoch anchors are
    /// validated against the live fleet/run length in `Trainer::new`.
    pub fault_plan: Option<crate::fault::FaultPlan>,
    /// Write a versioned snapshot after every epoch into this directory
    /// (`--checkpoint-dir`; files are `ckpt-eNNNNN.hitg`).
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from a checkpoint file — or, when given a directory, from
    /// the newest checkpoint inside it (`--resume`).
    pub resume: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dataset: "ogbn-products".into(),
            model: "gcn".into(),
            fanouts: None,
            algo: Algorithm::DistDgl,
            num_fpgas: 4,
            fleet: None,
            sched: SchedMode::Cost,
            cpu_mem_gbs: 205.0,
            epochs: 1,
            lr: 0.05,
            momentum: 0.9,
            scale_shift: 4,
            cache_ratio: 0.2,
            cache_policy: CachePolicy::Static,
            fetch_dedup: true,
            workload_balancing: true,
            direct_host_fetch: true,
            prefetch: false,
            host_threads: 1,
            prefetch_depth: 1,
            reduce_threads: 4,
            buffer_pool: true,
            auto_tune: AutoTuneMode::Off,
            seed: 42,
            artifacts_dir: crate::runtime::Manifest::default_dir(),
            max_iterations: None,
            dataset_path: None,
            dram_ratio: 1.0,
            disk_gbs: 2.0,
            fault_plan: None,
            checkpoint_dir: None,
            resume: None,
        }
    }
}

/// Resolve the `--fleet` / `--fpgas` pair consistently (shared by
/// `train` config parsing and `simulate`): `--fleet` implies the FPGA
/// count; an explicit `--fpgas` must agree with the fleet size.
pub fn fleet_args(
    args: &Args,
    default_fpgas: usize,
) -> anyhow::Result<(Option<Vec<DeviceSpec>>, usize)> {
    let fleet = args.opt_str("fleet").map(|s| fpga::parse_fleet(&s)).transpose()?;
    let num_fpgas = match args.opt_str("fpgas") {
        Some(s) => s.parse::<usize>().map_err(|e| anyhow::anyhow!("--fpgas={s}: {e}"))?,
        None => fleet.as_ref().map_or(default_fpgas, |f| f.len()),
    };
    if let Some(f) = &fleet {
        anyhow::ensure!(
            f.len() == num_fpgas,
            "--fleet has {} devices but --fpgas is {num_fpgas}",
            f.len()
        );
    }
    Ok((fleet, num_fpgas))
}

impl TrainConfig {
    /// Parse from CLI arguments (shared by `hitgnn train` and examples).
    pub fn from_args(args: &Args) -> anyhow::Result<TrainConfig> {
        let d = TrainConfig::default();
        let (fleet, num_fpgas) = fleet_args(args, d.num_fpgas)?;
        let cfg = TrainConfig {
            dataset: args.str("dataset", &d.dataset),
            model: args.str("model", &d.model),
            fanouts: args
                .opt_str("fanouts")
                .map(|s| crate::sampling::parse_fanouts(&s))
                .transpose()?,
            algo: Algorithm::parse(&args.str("algo", "distdgl"))?,
            num_fpgas,
            fleet,
            sched: SchedMode::parse(&args.str("sched", d.sched.name()))?,
            cpu_mem_gbs: args.num("cpu-mem", d.cpu_mem_gbs)?,
            epochs: args.num("epochs", d.epochs)?,
            lr: args.num("lr", d.lr)?,
            momentum: args.num("momentum", d.momentum)?,
            scale_shift: args.num("scale-shift", d.scale_shift)?,
            cache_ratio: args.num("cache-ratio", d.cache_ratio)?,
            cache_policy: CachePolicy::parse(&args.str("cache-policy", "static"))?,
            fetch_dedup: !args.flag("no-dedup"),
            workload_balancing: !args.flag("no-wb"),
            direct_host_fetch: !args.flag("no-dc"),
            prefetch: args.flag("prefetch"),
            host_threads: args.num("host-threads", d.host_threads)?,
            prefetch_depth: args.num("prefetch-depth", d.prefetch_depth)?,
            reduce_threads: args.num("reduce-threads", d.reduce_threads)?,
            buffer_pool: !args.flag("no-pool"),
            auto_tune: AutoTuneMode::parse(&args.str("auto-tune", d.auto_tune.name()))?,
            seed: args.num("seed", d.seed)?,
            artifacts_dir: PathBuf::from(
                args.str("artifacts", &d.artifacts_dir.display().to_string()),
            ),
            max_iterations: args.opt_str("max-iterations").map(|s| s.parse()).transpose()?,
            dataset_path: args.opt_str("dataset-path"),
            dram_ratio: args.num("dram-ratio", d.dram_ratio)?,
            disk_gbs: args.num("disk-gbs", d.disk_gbs)?,
            fault_plan: args
                .opt_str("fault-plan")
                .map(|s| crate::fault::FaultPlan::parse(&s))
                .transpose()?,
            checkpoint_dir: args.opt_str("checkpoint-dir").map(PathBuf::from),
            resume: args.opt_str("resume"),
        };
        crate::runtime::validate_model(&cfg.model)?;
        anyhow::ensure!(cfg.num_fpgas >= 1, "--fpgas must be >= 1");
        anyhow::ensure!(cfg.epochs >= 1, "--epochs must be >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.cache_ratio),
            "--cache-ratio must be in [0, 1] (got {})",
            cfg.cache_ratio
        );
        anyhow::ensure!(cfg.host_threads >= 1, "--host-threads must be >= 1");
        anyhow::ensure!(cfg.prefetch_depth >= 1, "--prefetch-depth must be >= 1");
        anyhow::ensure!(cfg.reduce_threads >= 1, "--reduce-threads must be >= 1");
        if let Some(fanouts) = &cfg.fanouts {
            // full validation (incl. the level-0 memory bound) re-runs in
            // Trainer::new against the artifact's batch size; reject the
            // obviously degenerate lists right at the CLI
            anyhow::ensure!(
                !fanouts.is_empty() && fanouts.iter().all(|&k| k >= 1),
                "--fanouts must list one fanout >= 1 per layer (got {fanouts:?})"
            );
        }
        anyhow::ensure!(
            cfg.cpu_mem_gbs.is_finite() && cfg.cpu_mem_gbs > 0.0,
            "--cpu-mem must be positive (got {})",
            cfg.cpu_mem_gbs
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.dram_ratio),
            "--dram-ratio must be in [0, 1] (got {})",
            cfg.dram_ratio
        );
        anyhow::ensure!(
            cfg.disk_gbs.is_finite() && cfg.disk_gbs > 0.0,
            "--disk-gbs must be positive (got {})",
            cfg.disk_gbs
        );
        Ok(cfg)
    }

    /// Resolved per-device fleet: the explicit `--fleet`, or `num_fpgas`
    /// identical paper U250s.
    pub fn device_fleet(&self) -> Vec<DeviceSpec> {
        self.fleet.clone().unwrap_or_else(|| fpga::homogeneous_fleet(self.num_fpgas))
    }

    /// Effective bounded-prefetch window depth: the legacy `--prefetch`
    /// flag guarantees at least one iteration of lookahead (depth 2).
    pub fn pipeline_depth(&self) -> usize {
        let d = self.prefetch_depth.max(1);
        if self.prefetch {
            d.max(2)
        } else {
            d
        }
    }

    /// JSON round-trip (for the training report and saved runs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(&self.dataset)),
            ("model", Json::str(&self.model)),
            (
                "fanouts",
                match &self.fanouts {
                    Some(f) => Json::arr(f.iter().map(|&k| Json::num(k as f64)).collect()),
                    None => Json::Null,
                },
            ),
            ("algo", Json::str(self.algo.name())),
            ("num_fpgas", Json::num(self.num_fpgas as f64)),
            ("fleet", Json::str(&fpga::fleet_spec_string(&self.device_fleet()))),
            ("sched", Json::str(self.sched.name())),
            ("cpu_mem_gbs", Json::num(self.cpu_mem_gbs)),
            ("epochs", Json::num(self.epochs as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("momentum", Json::num(self.momentum as f64)),
            ("scale_shift", Json::num(self.scale_shift as f64)),
            ("cache_ratio", Json::num(self.cache_ratio)),
            ("cache_policy", Json::str(self.cache_policy.name())),
            ("fetch_dedup", Json::Bool(self.fetch_dedup)),
            ("workload_balancing", Json::Bool(self.workload_balancing)),
            ("direct_host_fetch", Json::Bool(self.direct_host_fetch)),
            ("host_threads", Json::num(self.host_threads as f64)),
            ("prefetch_depth", Json::num(self.pipeline_depth() as f64)),
            ("reduce_threads", Json::num(self.reduce_threads as f64)),
            ("buffer_pool", Json::Bool(self.buffer_pool)),
            ("auto_tune", Json::str(self.auto_tune.name())),
            ("seed", Json::num(self.seed as f64)),
            (
                "dataset_path",
                match &self.dataset_path {
                    Some(p) => Json::str(p),
                    None => Json::Null,
                },
            ),
            ("dram_ratio", Json::num(self.dram_ratio)),
            ("disk_gbs", Json::num(self.disk_gbs)),
            (
                "fault_plan",
                match &self.fault_plan {
                    Some(p) => Json::str(&p.spec),
                    None => Json::Null,
                },
            ),
            (
                "checkpoint_dir",
                match &self.checkpoint_dir {
                    Some(d) => Json::str(&d.display().to_string()),
                    None => Json::Null,
                },
            ),
            (
                "resume",
                match &self.resume {
                    Some(r) => Json::str(r),
                    None => Json::Null,
                },
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.num_fpgas, 4);
        assert!(c.workload_balancing && c.direct_host_fetch);
        assert_eq!((c.host_threads, c.prefetch_depth), (1, 1));
        assert_eq!(c.pipeline_depth(), 1);
    }

    #[test]
    fn pipeline_depth_honours_legacy_prefetch_flag() {
        let mut c = TrainConfig::default();
        c.prefetch = true;
        assert_eq!(c.pipeline_depth(), 2);
        c.prefetch_depth = 3;
        assert_eq!(c.pipeline_depth(), 3);
        c.prefetch = false;
        assert_eq!(c.pipeline_depth(), 3);
    }

    #[test]
    fn parses_pipeline_options_and_rejects_zero() {
        let args = Args::parse(["train", "--host-threads", "4", "--prefetch-depth", "2"]);
        let c = TrainConfig::from_args(&args).unwrap();
        assert_eq!((c.host_threads, c.prefetch_depth), (4, 2));
        assert!(c.buffer_pool, "buffer recycling defaults on");
        let c = TrainConfig::from_args(&Args::parse(["train", "--no-pool"])).unwrap();
        assert!(!c.buffer_pool);
        assert_eq!(c.to_json().req("buffer_pool").unwrap(), &Json::Bool(false));
        let args = Args::parse(["train", "--host-threads", "0"]);
        assert!(TrainConfig::from_args(&args).is_err());
        let args = Args::parse(["train", "--prefetch-depth", "0"]);
        assert!(TrainConfig::from_args(&args).is_err());
    }

    #[test]
    fn parses_reduce_threads_and_rejects_zero() {
        let c = TrainConfig::from_args(&Args::parse(["train"])).unwrap();
        assert_eq!(c.reduce_threads, 4, "defaults to a small reduction pool");
        let c = TrainConfig::from_args(&Args::parse(["train", "--reduce-threads", "2"])).unwrap();
        assert_eq!(c.reduce_threads, 2);
        assert_eq!(c.to_json().req_usize("reduce_threads").unwrap(), 2);
        let args = Args::parse(["train", "--reduce-threads", "0"]);
        assert!(TrainConfig::from_args(&args).is_err());
    }

    #[test]
    fn parses_cli_overrides() {
        let args = Args::parse([
            "train", "--dataset", "reddit", "--model", "sage", "--algo", "pagraph",
            "--fpgas", "2", "--no-wb", "--lr", "0.1",
        ]);
        let c = TrainConfig::from_args(&args).unwrap();
        assert_eq!(c.dataset, "reddit");
        assert_eq!(c.model, "sage");
        assert_eq!(c.algo, Algorithm::PaGraph);
        assert_eq!(c.num_fpgas, 2);
        assert!(!c.workload_balancing);
        assert!(c.direct_host_fetch);
        assert_eq!(c.lr, 0.1);
    }

    #[test]
    fn rejects_bad_values() {
        let args = Args::parse(["train", "--fpgas", "0"]);
        assert!(TrainConfig::from_args(&args).is_err());
        let args = Args::parse(["train", "--algo", "bogus"]);
        assert!(TrainConfig::from_args(&args).is_err());
    }

    #[test]
    fn validates_model_against_the_zoo_registry() {
        for model in crate::runtime::MODEL_NAMES {
            let c = TrainConfig::from_args(&Args::parse(["train", "--model", model])).unwrap();
            assert_eq!(c.model, model);
        }
        let err =
            TrainConfig::from_args(&Args::parse(["train", "--model", "transformer"])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown model 'transformer'"), "{msg}");
        assert!(msg.contains("expected one of gcn|sage|gat|gin"), "{msg}");
    }

    #[test]
    fn parses_cache_policy_and_dedup_toggle() {
        let c = TrainConfig::from_args(&Args::parse(["train"])).unwrap();
        assert_eq!(c.cache_policy, CachePolicy::Static);
        assert!(c.fetch_dedup);
        let c = TrainConfig::from_args(&Args::parse([
            "train", "--cache-policy", "lfu", "--no-dedup",
        ]))
        .unwrap();
        assert_eq!(c.cache_policy, CachePolicy::Lfu);
        assert!(!c.fetch_dedup);
        assert!(TrainConfig::from_args(&Args::parse(["train", "--cache-policy", "bogus"]))
            .is_err());
    }

    #[test]
    fn rejects_cache_ratio_outside_unit_interval() {
        for bad in ["-0.1", "1.5", "-3"] {
            let args = Args::parse(["train", "--cache-ratio", bad]);
            assert!(TrainConfig::from_args(&args).is_err(), "--cache-ratio {bad} accepted");
        }
        for ok in ["0", "0.2", "1"] {
            let args = Args::parse(["train", "--cache-ratio", ok]);
            assert!(TrainConfig::from_args(&args).is_ok(), "--cache-ratio {ok} rejected");
        }
    }

    #[test]
    fn parses_and_validates_fanouts() {
        let c = TrainConfig::from_args(&Args::parse(["train"])).unwrap();
        assert!(c.fanouts.is_none());
        let c = TrainConfig::from_args(&Args::parse(["train", "--fanouts", "15,10,5"])).unwrap();
        assert_eq!(c.fanouts, Some(vec![15, 10, 5]));
        for bad in ["", "0,5", "a,b", "10,,5"] {
            let args = Args::parse(["train", "--fanouts", bad]);
            assert!(TrainConfig::from_args(&args).is_err(), "--fanouts '{bad}' accepted");
        }
        // json carries the list (null when unset)
        let j = c.to_json();
        assert_eq!(j.req("fanouts").unwrap().as_arr().unwrap().len(), 3);
        let d = TrainConfig::default().to_json();
        assert_eq!(d.req("fanouts").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_auto_tune_mode() {
        let c = TrainConfig::from_args(&Args::parse(["train"])).unwrap();
        assert_eq!(c.auto_tune, AutoTuneMode::Off);
        for (s, m) in
            [("on", AutoTuneMode::On), ("off", AutoTuneMode::Off), ("freeze", AutoTuneMode::Freeze)]
        {
            let c = TrainConfig::from_args(&Args::parse(["train", "--auto-tune", s])).unwrap();
            assert_eq!(c.auto_tune, m, "--auto-tune {s}");
            assert_eq!(c.to_json().req_str("auto_tune").unwrap(), s);
        }
        assert!(TrainConfig::from_args(&Args::parse(["train", "--auto-tune", "maybe"])).is_err());
    }

    #[test]
    fn parses_out_of_core_knobs() {
        let c = TrainConfig::from_args(&Args::parse(["train"])).unwrap();
        assert!(c.dataset_path.is_none());
        assert_eq!(c.dram_ratio, 1.0, "everything DRAM-resident by default");
        assert_eq!(c.disk_gbs, 2.0);
        let c = TrainConfig::from_args(&Args::parse([
            "train", "--dataset-path", "/tmp/run.hitg", "--dram-ratio", "0.5", "--disk-gbs", "4",
        ]))
        .unwrap();
        assert_eq!(c.dataset_path.as_deref(), Some("/tmp/run.hitg"));
        assert_eq!(c.dram_ratio, 0.5);
        assert_eq!(c.disk_gbs, 4.0);
        let j = c.to_json();
        assert_eq!(j.req_str("dataset_path").unwrap(), "/tmp/run.hitg");
        assert_eq!(j.req("dram_ratio").unwrap(), &Json::num(0.5));
        assert_eq!(TrainConfig::default().to_json().req("dataset_path").unwrap(), &Json::Null);
        for bad in ["-0.1", "1.5", "nan"] {
            let args = Args::parse(["train", "--dram-ratio", bad]);
            assert!(TrainConfig::from_args(&args).is_err(), "--dram-ratio {bad} accepted");
        }
        for bad in ["0", "-2", "inf"] {
            let args = Args::parse(["train", "--disk-gbs", bad]);
            assert!(TrainConfig::from_args(&args).is_err(), "--disk-gbs {bad} accepted");
        }
    }

    #[test]
    fn parses_fault_and_checkpoint_flags() {
        let c = TrainConfig::from_args(&Args::parse(["train"])).unwrap();
        assert!(c.fault_plan.is_none() && c.checkpoint_dir.is_none() && c.resume.is_none());
        let c = TrainConfig::from_args(&Args::parse([
            "train",
            "--fault-plan",
            "dev1:fail@e2i7,dev3:slow*4@e1,disk:eio@0.01,prep:panic@e3i2",
            "--checkpoint-dir",
            "/tmp/ck",
            "--resume",
            "/tmp/ck",
        ]))
        .unwrap();
        let p = c.fault_plan.as_ref().unwrap();
        assert_eq!(p.failures.len(), 1);
        assert_eq!(p.slowdowns.len(), 1);
        assert_eq!(p.disk_eio, Some(0.01));
        assert_eq!(p.prep_panics.len(), 1);
        assert_eq!(c.checkpoint_dir.as_deref(), Some(std::path::Path::new("/tmp/ck")));
        assert_eq!(c.resume.as_deref(), Some("/tmp/ck"));
        let j = c.to_json();
        assert_eq!(
            j.req_str("fault_plan").unwrap(),
            "dev1:fail@e2i7,dev3:slow*4@e1,disk:eio@0.01,prep:panic@e3i2"
        );
        assert_eq!(j.req_str("checkpoint_dir").unwrap(), "/tmp/ck");
        assert_eq!(j.req_str("resume").unwrap(), "/tmp/ck");
        assert_eq!(TrainConfig::default().to_json().req("fault_plan").unwrap(), &Json::Null);

        // malformed plans are rejected at parse time, naming the token
        let err = TrainConfig::from_args(&Args::parse(["train", "--fault-plan", "dev1:melt@e0"]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("dev1:melt@e0"), "{err:#}");
    }

    #[test]
    fn json_roundtrip_fields() {
        let c = TrainConfig::default();
        let j = c.to_json();
        assert_eq!(j.req_str("algo").unwrap(), "DistDGL");
        assert_eq!(j.req_usize("num_fpgas").unwrap(), 4);
        assert_eq!(j.req_str("fleet").unwrap(), "u250:4");
        assert_eq!(j.req_str("sched").unwrap(), "cost");
    }

    #[test]
    fn parses_fleet_and_sched_options() {
        let c = TrainConfig::from_args(&Args::parse(["train"])).unwrap();
        assert!(c.fleet.is_none());
        assert_eq!(c.sched, crate::sched::SchedMode::Cost);
        assert_eq!(c.device_fleet().len(), 4);

        // --fleet implies --fpgas
        let c = TrainConfig::from_args(&Args::parse([
            "train", "--fleet", "u250-half:2,u250:2", "--sched", "batch-count",
        ]))
        .unwrap();
        assert_eq!(c.num_fpgas, 4);
        assert_eq!(c.sched, crate::sched::SchedMode::BatchCount);
        let fleet = c.device_fleet();
        assert_eq!(fleet[0].kind, "u250-half");
        assert_eq!(fleet[3].kind, "u250");

        // explicit --fpgas must agree with the fleet size
        let args = Args::parse(["train", "--fleet", "u250:2", "--fpgas", "3"]);
        assert!(TrainConfig::from_args(&args).is_err());
        let args = Args::parse(["train", "--fleet", "u250:3", "--fpgas", "3"]);
        assert_eq!(TrainConfig::from_args(&args).unwrap().num_fpgas, 3);
        // unknown kinds and modes are rejected
        assert!(TrainConfig::from_args(&Args::parse(["train", "--fleet", "v100:2"])).is_err());
        assert!(TrainConfig::from_args(&Args::parse(["train", "--sched", "bogus"])).is_err());

        // host-bandwidth override for the cost model
        let c = TrainConfig::from_args(&Args::parse(["train", "--cpu-mem", "100"])).unwrap();
        assert_eq!(c.cpu_mem_gbs, 100.0);
        assert!(TrainConfig::from_args(&Args::parse(["train", "--cpu-mem", "0"])).is_err());
        assert!(TrainConfig::from_args(&Args::parse(["train", "--cpu-mem", "-5"])).is_err());
    }
}
