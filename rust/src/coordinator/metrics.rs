//! Per-epoch measurements and the JSON training report.

use crate::util::json::Json;

/// Measurements from one epoch of real execution.
#[derive(Clone, Debug, Default)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub mean_loss: f64,
    pub final_loss: f64,
    pub wall_seconds: f64,
    pub iterations: usize,
    pub batches: usize,
    /// Σ over batches of (|V^0|+|V^1|+|V^2|) — the NVTPS numerator.
    pub vertices_traversed: u64,
    /// Measured execution-path throughput (CPU-PJRT, not FPGA-projected).
    pub nvtps: f64,
    /// Measured local-fetch ratio (Eq. 7's β) across all batches.
    pub beta: f64,
    /// Row-granular cache hit rate of the feature stores (fraction of
    /// layer-0 rows resident; equals β only for full-width stores).
    pub cache_hit_rate: f64,
    pub local_bytes: u64,
    pub host_bytes: u64,
    pub f2f_bytes: u64,
    /// PCIe bytes avoided by iteration-level fetch dedup (charged to CPU
    /// memory bandwidth instead — `comm::IterDedup`).
    pub dedup_saved_bytes: u64,
    /// Miss bytes served from the host-DRAM tier (`--dram-ratio < 1`;
    /// 0 without a tier). Together with `disk_read_bytes` this
    /// re-partitions the miss traffic by source tier — see
    /// `comm::Traffic`.
    pub dram_hit_bytes: u64,
    /// Miss bytes charged as disk reads (rows outside the DRAM tier).
    pub disk_read_bytes: u64,
    /// Feature stores whose resident set changed at this epoch's barrier
    /// (0 for static policies).
    pub stores_updated: usize,
    /// Epoch makespan in batch units: Σ over iterations of the max batch
    /// count on one FPGA (what WB minimises, Table 7).
    pub epoch_makespan_batches: usize,
    /// Epoch makespan in seconds under the fleet's per-device §6.2 cost
    /// model (what cost-aware scheduling minimises on heterogeneous
    /// fleets). Modeled from the epoch's actual iteration plans — the
    /// simulated-FPGA wall clock is not this number.
    pub epoch_makespan_seconds: f64,
    /// Host-side time breakdown (seconds, summed over the epoch).
    pub sample_seconds: f64,
    pub gather_seconds: f64,
    pub execute_seconds: f64,
    /// Coordinator time in the gradient reduction + fused optimizer step
    /// only. Disjoint from `execute_stall_seconds` (the collect-barrier
    /// wait), so the coordinator stages decompose:
    /// `prep_stall + execute_stall + sync ≤ wall` per epoch.
    pub sync_seconds: f64,
    /// Coordinator time blocked waiting for batch preparation (the
    /// reassembly `recv` loop) — the prep-vs-execute stall split the
    /// auto-tuner steers by. Disjoint from `execute_stall_seconds`.
    pub prep_stall_seconds: f64,
    /// Coordinator time blocked at the gradient-sync collect barrier.
    /// Disjoint from `sync_seconds` (reduction + optimizer step).
    pub execute_stall_seconds: f64,
    /// Mean loss of each iteration, in execution order. Reduced in
    /// deterministic (iteration, tag) order, so for a fixed seed this
    /// sequence is bit-identical across pipeline configurations
    /// (`tests/pipeline_determinism.rs`).
    pub iter_losses: Vec<f64>,
    /// The auto-tuner's decision after this epoch
    /// (`tune::TuneDecision::to_json`) — present when `--auto-tune` is
    /// `on` or `freeze`, so every knob change is auditable in the report.
    pub tune: Option<Json>,
    /// Devices under quarantine when this epoch's barrier closed (lost
    /// boards stay quarantined for the rest of the run — DESIGN.md
    /// §Fault tolerance).
    pub quarantined_devices: usize,
    /// Batches whose home partition belongs to a quarantined device,
    /// rerouted to survivors at planning time (each still trains exactly
    /// once).
    pub reassigned_batches: usize,
    /// Transient disk-read errors absorbed by bounded retry
    /// (`--fault-plan disk:eio@p`).
    pub disk_retries: u64,
    /// Wall time spent writing this epoch's snapshot
    /// (`--checkpoint-dir`; 0 when checkpointing is off).
    pub checkpoint_seconds: f64,
}

impl EpochMetrics {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("epoch", Json::num(self.epoch as f64)),
            ("mean_loss", Json::num(self.mean_loss)),
            ("final_loss", Json::num(self.final_loss)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("iterations", Json::num(self.iterations as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("vertices_traversed", Json::num(self.vertices_traversed as f64)),
            ("nvtps", Json::num(self.nvtps)),
            ("beta", Json::num(self.beta)),
            ("cache_hit_rate", Json::num(self.cache_hit_rate)),
            ("local_bytes", Json::num(self.local_bytes as f64)),
            ("host_bytes", Json::num(self.host_bytes as f64)),
            ("f2f_bytes", Json::num(self.f2f_bytes as f64)),
            ("dedup_saved_bytes", Json::num(self.dedup_saved_bytes as f64)),
            ("dram_hit_bytes", Json::num(self.dram_hit_bytes as f64)),
            ("disk_read_bytes", Json::num(self.disk_read_bytes as f64)),
            ("stores_updated", Json::num(self.stores_updated as f64)),
            ("epoch_makespan_batches", Json::num(self.epoch_makespan_batches as f64)),
            ("epoch_makespan_seconds", Json::num(self.epoch_makespan_seconds)),
            ("sample_seconds", Json::num(self.sample_seconds)),
            ("gather_seconds", Json::num(self.gather_seconds)),
            ("execute_seconds", Json::num(self.execute_seconds)),
            ("sync_seconds", Json::num(self.sync_seconds)),
            ("prep_stall_seconds", Json::num(self.prep_stall_seconds)),
            ("execute_stall_seconds", Json::num(self.execute_stall_seconds)),
            (
                "iter_losses",
                Json::arr(self.iter_losses.iter().map(|&x| Json::num(x)).collect()),
            ),
            ("quarantined_devices", Json::num(self.quarantined_devices as f64)),
            ("reassigned_batches", Json::num(self.reassigned_batches as f64)),
            ("disk_retries", Json::num(self.disk_retries as f64)),
            ("checkpoint_seconds", Json::num(self.checkpoint_seconds)),
        ];
        if let Some(t) = &self.tune {
            fields.push(("tune", t.clone()));
        }
        Json::obj(fields)
    }
}

/// Full training report (config + per-epoch metrics + measured shapes).
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub config: Json,
    pub epochs: Vec<EpochMetrics>,
    /// Mean measured mini-batch shape: [v_0..v_L, a_1..a_L] (2L+1
    /// entries; [v0, v1, v2, a1, a2] at the default depth 2).
    pub mean_shape: Vec<f64>,
}

impl TrainReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("config", self.config.clone()),
            (
                "epochs",
                Json::arr(self.epochs.iter().map(|e| e.to_json()).collect()),
            ),
            (
                "mean_shape",
                Json::arr(self.mean_shape.iter().map(|&x| Json::num(x)).collect()),
            ),
        ])
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    /// Loss of the last epoch (convergence check for tests/examples).
    pub fn last_loss(&self) -> f64 {
        self.epochs.last().map(|e| e.mean_loss).unwrap_or(f64::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_serialises_and_reparses() {
        let report = TrainReport {
            config: Json::obj(vec![("model", Json::str("gcn"))]),
            epochs: vec![EpochMetrics {
                epoch: 0,
                mean_loss: 1.5,
                cache_hit_rate: 0.5,
                dedup_saved_bytes: 4096,
                dram_hit_bytes: 2048,
                disk_read_bytes: 1024,
                stores_updated: 2,
                epoch_makespan_batches: 7,
                epoch_makespan_seconds: 0.25,
                prep_stall_seconds: 0.125,
                tune: Some(Json::obj(vec![("action", Json::str("hold"))])),
                quarantined_devices: 1,
                reassigned_batches: 3,
                disk_retries: 2,
                checkpoint_seconds: 0.0625,
                ..Default::default()
            }],
            mean_shape: vec![5.0, 4.0, 3.0, 2.0, 1.0],
        };
        let text = report.to_json().pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("epochs").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(
            parsed.get("config").unwrap().req_str("model").unwrap(),
            "gcn"
        );
        // the new feature-store observability fields survive the roundtrip
        let e0 = &parsed.get("epochs").unwrap().as_arr().unwrap()[0];
        assert_eq!(e0.req_usize("dedup_saved_bytes").unwrap(), 4096);
        assert_eq!(e0.req_usize("dram_hit_bytes").unwrap(), 2048);
        assert_eq!(e0.req_usize("disk_read_bytes").unwrap(), 1024);
        assert_eq!(e0.req_usize("stores_updated").unwrap(), 2);
        assert!(e0.get("cache_hit_rate").is_some());
        // scheduler observability fields survive the roundtrip
        assert_eq!(e0.req_usize("epoch_makespan_batches").unwrap(), 7);
        assert!(e0.get("epoch_makespan_seconds").is_some());
        // stall counters + the tune decision log survive the roundtrip
        assert!((e0.req_f64("prep_stall_seconds").unwrap() - 0.125).abs() < 1e-12);
        assert!(e0.get("execute_stall_seconds").is_some());
        assert_eq!(e0.req("tune").unwrap().req_str("action").unwrap(), "hold");
        // fault-tolerance counters are always present in the report
        assert_eq!(e0.req_usize("quarantined_devices").unwrap(), 1);
        assert_eq!(e0.req_usize("reassigned_batches").unwrap(), 3);
        assert_eq!(e0.req_usize("disk_retries").unwrap(), 2);
        assert!((e0.req_f64("checkpoint_seconds").unwrap() - 0.0625).abs() < 1e-12);
    }

    /// ISSUE-7 satellite: the coordinator-thread stages are disjoint
    /// timers (the old code booked the collect-barrier wait into both
    /// `execute_stall_seconds` and `sync_seconds`), so their sum cannot
    /// exceed the epoch wall clock. Only the coordinator-thread stages
    /// participate: `sample`/`gather`/`execute_seconds` sum across prep
    /// and worker threads and may legitimately exceed wall.
    #[test]
    fn coordinator_stage_timers_decompose_under_wall() {
        let cfg = crate::coordinator::TrainConfig {
            dataset: "tiny".into(),
            model: "gcn".into(),
            algo: crate::partition::Algorithm::DistDgl,
            num_fpgas: 2,
            epochs: 2,
            scale_shift: 0,
            seed: 13,
            host_threads: 2,
            prefetch_depth: 2,
            max_iterations: Some(4),
            ..Default::default()
        };
        let mut trainer = crate::coordinator::Trainer::new(cfg).unwrap();
        let report = trainer.run().unwrap();
        trainer.shutdown();
        assert_eq!(report.epochs.len(), 2);
        for m in &report.epochs {
            let staged = m.prep_stall_seconds + m.execute_stall_seconds + m.sync_seconds;
            assert!(
                staged <= m.wall_seconds,
                "epoch {}: prep_stall {} + execute_stall {} + sync {} = {} > wall {}",
                m.epoch,
                m.prep_stall_seconds,
                m.execute_stall_seconds,
                m.sync_seconds,
                staged,
                m.wall_seconds
            );
            assert!(m.sync_seconds >= 0.0 && m.execute_stall_seconds >= 0.0);
        }
    }
}
