//! The HitGNN host program (software-generator output, §4.1–4.2).
//!
//! The coordinator is what the paper's generated host program does at
//! runtime: graph preprocessing, mini-batch sampling, two-stage task
//! scheduling, CPU→FPGA feature service, dispatch to the (simulated) FPGA
//! workers, gradient synchronisation, and the weight update — synchronous
//! SGD across `p` devices (Algorithm 2 + §2.3).
//!
//! - `audit`     — full-iteration zero-allocation audit (feature
//!   `alloc-count`)
//! - [`config`]  — run configuration (CLI / JSON)
//! - [`params`]  — parameter set + SGD-with-momentum optimizer
//! - [`prep`]    — the host batch-preparation pipeline (PrepPool +
//!   bounded prefetch window; DESIGN.md §Host pipeline)
//! - [`worker`]  — per-FPGA worker threads running the executors
//! - [`trainer`] — the epoch loop tying everything together
//! - [`metrics`] — per-epoch measurements and the JSON training report
//! - [`cli`]     — the `hitgnn` launcher

#[cfg(feature = "alloc-count")]
pub mod audit;
pub mod cli;
pub mod config;
pub mod metrics;
pub mod params;
pub mod prep;
pub mod trainer;
pub mod worker;

pub use config::TrainConfig;
pub use metrics::{EpochMetrics, TrainReport};
pub use trainer::Trainer;
