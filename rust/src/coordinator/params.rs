//! Model parameters and the synchronous-SGD weight update.
//!
//! Parameters live on the host in artifact order; after every iteration
//! the coordinator reduces the per-FPGA gradients (gradient
//! synchronisation, §4.2) and applies SGD with momentum, then broadcasts
//! the updated weights (in the simulation: shares the new `Arc`).
//!
//! The hot path is [`GradReducer::reduce`] + [`Sgd::step_fused`]
//! (DESIGN.md §SIMD dispatch & gradient sync): an in-place sum over a
//! persistent flat accumulator, split by parameter tensor and row chunk
//! across a small scoped thread pool, followed by one fused
//! scale-by-1/p + momentum + weight-update pass. Per-element summation
//! stays in tag order across the p worker gradients regardless of the
//! reduction-thread count, so the result is bit-identical to the serial
//! [`average_grads`] baseline (kept as the oracle) and the PR-1
//! determinism law holds unchanged.

use crate::runtime::{ArtifactEntry, GradBuffers};
use crate::util::rng::Rng;

/// Flat parameter set in artifact order.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub data: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Glorot-initialised parameters matching an artifact's shapes
    /// (biases — rank-1 params — start at zero).
    pub fn init(entry: &ArtifactEntry, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed ^ 0x9a2a);
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        let mut data = Vec::new();
        for (name, shape) in &entry.params {
            let n: usize = shape.iter().product();
            let buf = if shape.len() >= 2 {
                let scale = (2.0 / (shape[0] + shape[1]) as f64).sqrt();
                (0..n).map(|_| (rng.normal() * scale) as f32).collect()
            } else {
                vec![0.0f32; n]
            };
            names.push(name.clone());
            shapes.push(shape.clone());
            data.push(buf);
        }
        ParamSet { names, shapes, data }
    }

    pub fn num_elems(&self) -> usize {
        self.data.iter().map(|d| d.len()).sum()
    }

    /// L2 norm over all parameters (diagnostics / tests).
    pub fn norm(&self) -> f64 {
        self.data
            .iter()
            .flat_map(|d| d.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// Average gradients across workers — the seed's serial, allocating
/// reduction, kept as the bitwise oracle for [`GradReducer`] (property
/// tests) and as the BENCH_sync baseline. The hot path uses
/// [`GradReducer::reduce`] + [`Sgd::step_fused`] instead.
pub fn average_grads(grads: &[GradBuffers]) -> Vec<Vec<f32>> {
    assert!(!grads.is_empty());
    let p = grads.len() as f32;
    let mut avg: Vec<Vec<f32>> = grads[0].to_vec();
    for g in &grads[1..] {
        assert_eq!(g.len(), avg.len(), "gradient arity mismatch");
        for (a, gi) in avg.iter_mut().zip(g) {
            assert_eq!(a.len(), gi.len(), "gradient shape mismatch");
            for (x, y) in a.iter_mut().zip(gi) {
                *x += *y;
            }
        }
    }
    for a in avg.iter_mut() {
        for x in a.iter_mut() {
            *x /= p;
        }
    }
    avg
}

/// Below this many total parameter elements the reduction stays serial
/// (scoped-thread spawn overhead would dominate the elementwise sums).
pub const PAR_MIN_ELEMS: usize = 1 << 16;

/// Persistent gradient-sum accumulator: the zero-allocation, parallel
/// replacement for [`average_grads`].
///
/// The accumulator is one flat `Vec<f32>` over every parameter tensor
/// (artifact order, prefix offsets in `offsets`). `reduce` splits it
/// into at most `threads` contiguous chunks, cut only at row boundaries
/// (`bounds`), and sums the p worker gradients into each chunk on a
/// scoped thread. Each element is owned by exactly one chunk and is
/// summed `g0 + g1 + … + g_{p-1}` in tag order, so the result does not
/// depend on the thread count — the determinism-law property the params
/// unit tests pin bitwise against [`average_grads`].
///
/// The sum is deliberately *not* pre-scaled by 1/p: [`Sgd::step_fused`]
/// folds the division into the weight update, matching the oracle's
/// "sum then divide" rounding exactly.
#[derive(Clone, Debug)]
pub struct GradReducer {
    acc: Vec<f32>,
    /// Prefix offsets of each tensor in `acc` (`len = ntensors + 1`).
    offsets: Vec<usize>,
    /// Legal chunk cut points: every tensor start plus every row start
    /// within rank ≥ 2 tensors (ascending; ends with the total).
    bounds: Vec<usize>,
    threads: usize,
    /// Serial-path cutoff (total elements); [`PAR_MIN_ELEMS`] unless
    /// overridden for tests/benches via [`GradReducer::set_par_min`].
    par_min: usize,
}

impl GradReducer {
    /// Build an accumulator shaped like `params`, reducing on up to
    /// `threads` scoped threads (1 = always serial).
    pub fn new(params: &ParamSet, threads: usize) -> GradReducer {
        let mut offsets = Vec::with_capacity(params.data.len() + 1);
        let mut bounds = Vec::new();
        let mut total = 0usize;
        offsets.push(0);
        for (shape, data) in params.shapes.iter().zip(&params.data) {
            let len = data.len();
            let row = if shape.len() >= 2 { shape[shape.len() - 1].max(1) } else { len.max(1) };
            let mut r = 0;
            while r < len {
                bounds.push(total + r);
                r += row;
            }
            total += len;
            offsets.push(total);
        }
        bounds.push(total);
        // Test/debug override for the serial cutoff: lets
        // tests/pipeline_determinism.rs force the scoped-thread path on
        // parameter sets far below `PAR_MIN_ELEMS`.
        let par_min = std::env::var("HITGNN_REDUCE_PAR_MIN")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(PAR_MIN_ELEMS);
        GradReducer {
            acc: vec![0.0; total],
            offsets,
            bounds,
            threads: threads.max(1),
            par_min,
        }
    }

    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Override the serial cutoff (tests/benches: force the parallel
    /// path on small parameter sets).
    pub fn set_par_min(&mut self, par_min: usize) {
        self.par_min = par_min;
    }

    /// The summed (un-averaged) gradients of the last [`GradReducer::reduce`].
    pub fn acc(&self) -> &[f32] {
        &self.acc
    }

    /// Sum the p worker gradients into the accumulator, overwriting it.
    /// Allocation-free (the chunk list lives on the stack per call via
    /// fixed-capacity splitting; scoped threads borrow, never move).
    pub fn reduce(&mut self, grads: &[GradBuffers]) {
        assert!(!grads.is_empty(), "reduce over zero workers");
        let ntensors = self.offsets.len() - 1;
        for g in grads {
            assert_eq!(g.len(), ntensors, "gradient arity mismatch");
            for (ti, gt) in g.into_iter().enumerate() {
                assert_eq!(
                    gt.len(),
                    self.offsets[ti + 1] - self.offsets[ti],
                    "gradient shape mismatch (tensor {ti})"
                );
            }
        }
        let total = self.acc.len();
        let t = self.threads.min(total.max(1));
        if t <= 1 || total < self.par_min {
            reduce_range(&mut self.acc, &self.offsets, grads, 0);
            return;
        }
        // cut points: ideal even split rounded up to the next row bound
        let offsets = &self.offsets;
        let mut rest: &mut [f32] = &mut self.acc;
        let mut consumed = 0usize;
        std::thread::scope(|s| {
            for wi in 1..=t {
                let end = if wi == t {
                    total
                } else {
                    let target = total * wi / t;
                    match self.bounds.binary_search(&target) {
                        Ok(j) => self.bounds[j],
                        Err(j) => *self.bounds.get(j).unwrap_or(&total),
                    }
                    .max(consumed)
                };
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - consumed);
                rest = tail;
                let start = consumed;
                consumed = end;
                if !chunk.is_empty() {
                    s.spawn(move || reduce_range(chunk, offsets, grads, start));
                }
            }
        });
    }
}

/// Sum the workers' gradients over the accumulator slice that begins at
/// flat offset `start` — per element strictly `g0 + g1 + …` in worker
/// tag order (the order [`average_grads`] uses).
fn reduce_range(chunk: &mut [f32], offsets: &[usize], grads: &[GradBuffers], start: usize) {
    let end = start + chunk.len();
    let mut s = start;
    let mut ti = match offsets.binary_search(&s) {
        Ok(i) => i,
        Err(i) => i - 1,
    };
    while s < end {
        let e = offsets[ti + 1].min(end);
        if e > s {
            let o = offsets[ti];
            let dst = &mut chunk[s - start..e - start];
            dst.copy_from_slice(&grads[0][ti][s - o..e - o]);
            for g in &grads[1..] {
                for (d, x) in dst.iter_mut().zip(&g[ti][s - o..e - o]) {
                    *d += *x;
                }
            }
        }
        s = e;
        ti += 1;
    }
}

/// SGD with momentum.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32, params: &ParamSet) -> Sgd {
        Sgd {
            lr,
            momentum,
            velocity: params.data.iter().map(|d| vec![0.0; d.len()]).collect(),
        }
    }

    /// In-place update: v = μ·v + g;  w -= lr·v.
    pub fn step(&mut self, params: &mut ParamSet, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), params.data.len());
        for ((w, v), g) in params.data.iter_mut().zip(&mut self.velocity).zip(grads) {
            assert_eq!(w.len(), g.len());
            for i in 0..w.len() {
                v[i] = self.momentum * v[i] + g[i];
                w[i] -= self.lr * v[i];
            }
        }
    }

    /// Momentum buffers in artifact order (checkpoint snapshot).
    pub fn velocity(&self) -> &[Vec<f32>] {
        &self.velocity
    }

    /// Restore momentum buffers from a checkpoint. The buffers must
    /// match the optimizer's current parameter layout exactly — a
    /// mismatch is a clean error (checkpoint from a different model).
    pub fn restore_velocity(&mut self, velocity: Vec<Vec<f32>>) -> anyhow::Result<()> {
        anyhow::ensure!(
            velocity.len() == self.velocity.len(),
            "checkpoint momentum has {} tensors, optimizer has {}",
            velocity.len(),
            self.velocity.len()
        );
        for (i, (new, cur)) in velocity.iter().zip(&self.velocity).enumerate() {
            anyhow::ensure!(
                new.len() == cur.len(),
                "checkpoint momentum tensor {i} has {} elements, optimizer has {}",
                new.len(),
                cur.len()
            );
        }
        self.velocity = velocity;
        Ok(())
    }

    /// Fused sync tail over a [`GradReducer`] accumulator: per element
    /// `g = acc/p; v = μ·v + g; w -= lr·v` in one pass — the same three
    /// expressions (division, not reciprocal multiply; no manual FMA) in
    /// the same order as [`average_grads`] + [`Sgd::step`], so the
    /// result is bit-identical to that serial baseline. Allocation-free.
    pub fn step_fused(&mut self, params: &mut ParamSet, acc: &[f32], num_workers: usize) {
        assert!(num_workers >= 1, "step_fused over zero workers");
        let p = num_workers as f32;
        let mut off = 0usize;
        for (w, v) in params.data.iter_mut().zip(&mut self.velocity) {
            assert_eq!(w.len(), v.len());
            let a = &acc[off..off + w.len()];
            for i in 0..w.len() {
                let g = a[i] / p;
                v[i] = self.momentum * v[i] + g;
                w[i] -= self.lr * v[i];
            }
            off += w.len();
        }
        assert_eq!(off, acc.len(), "accumulator/param element-count mismatch");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn entry() -> ArtifactEntry {
        ArtifactEntry {
            name: "t".into(),
            kind: "train".into(),
            model: "gcn".into(),
            dataset: "tiny".into(),
            path: PathBuf::from("/dev/null"),
            dims: crate::runtime::ArtifactDims::from_batch(4, &[2, 1], &[6, 5, 3]),
            params: vec![
                ("w1".into(), vec![6, 5]),
                ("b1".into(), vec![5]),
                ("w2".into(), vec![5, 3]),
                ("b2".into(), vec![3]),
            ],
            outputs: vec!["loss".into()],
        }
    }

    #[test]
    fn init_shapes_and_bias_zero() {
        let p = ParamSet::init(&entry(), 1);
        assert_eq!(p.num_elems(), 30 + 5 + 15 + 3);
        assert!(p.data[1].iter().all(|&x| x == 0.0)); // b1
        assert!(p.data[0].iter().any(|&x| x != 0.0)); // w1
        // deterministic
        let q = ParamSet::init(&entry(), 1);
        assert_eq!(p.data, q.data);
        assert_ne!(p.data, ParamSet::init(&entry(), 2).data);
    }

    #[test]
    fn average_is_elementwise_mean() {
        let g1: GradBuffers = vec![vec![1.0f32, 2.0], vec![0.0]].into();
        let g2: GradBuffers = vec![vec![3.0f32, 6.0], vec![2.0]].into();
        let avg = average_grads(&[g1, g2]);
        assert_eq!(avg, vec![vec![2.0, 4.0], vec![1.0]]);
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let mut p = ParamSet::init(&entry(), 3);
        let w0 = p.data[0][0];
        let mut opt = Sgd::new(0.1, 0.0, &p);
        let grads: Vec<Vec<f32>> = p.data.iter().map(|d| vec![1.0; d.len()]).collect();
        opt.step(&mut p, &grads);
        assert!((p.data[0][0] - (w0 - 0.1)).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let mut p = ParamSet::init(&entry(), 4);
        let w0 = p.data[0][0];
        let mut opt = Sgd::new(0.1, 0.5, &p);
        let grads: Vec<Vec<f32>> = p.data.iter().map(|d| vec![1.0; d.len()]).collect();
        opt.step(&mut p, &grads); // v=1, w -= .1
        opt.step(&mut p, &grads); // v=1.5, w -= .15
        assert!((p.data[0][0] - (w0 - 0.25)).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn average_rejects_mismatched_arity() {
        average_grads(&[vec![vec![1.0]].into(), vec![vec![1.0], vec![2.0]].into()]);
    }

    #[test]
    #[should_panic]
    fn reducer_rejects_mismatched_arity() {
        let p = ParamSet::init(&entry(), 1);
        let mut red = GradReducer::new(&p, 2);
        red.reduce(&[vec![vec![1.0]].into()]);
    }

    /// A parameter set big enough (> [`PAR_MIN_ELEMS`]) that `reduce`
    /// takes the scoped-thread path without a `par_min` override.
    fn big_params(seed: u64) -> ParamSet {
        let shapes =
            vec![vec![128usize, 400], vec![400], vec![400, 64], vec![64], vec![37]];
        let mut rng = Rng::new(seed);
        let data: Vec<Vec<f32>> = shapes
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                (0..n).map(|_| rng.f32() - 0.5).collect()
            })
            .collect();
        let names = (0..shapes.len()).map(|i| format!("p{i}")).collect();
        let p = ParamSet { names, shapes, data };
        assert!(p.num_elems() > PAR_MIN_ELEMS);
        p
    }

    fn random_grads(p: &ParamSet, workers: usize, seed: u64) -> Vec<GradBuffers> {
        let mut rng = Rng::new(seed);
        (0..workers)
            .map(|_| {
                p.data
                    .iter()
                    .map(|d| d.iter().map(|_| rng.f32() - 0.5).collect())
                    .collect::<Vec<Vec<f32>>>()
                    .into()
            })
            .collect()
    }

    /// The ISSUE-7 property sweep: `GradReducer::reduce` + `step_fused`
    /// must be elementwise bit-identical to the serial `average_grads` +
    /// `step` baseline across worker counts 1–8 and reduction-thread
    /// counts 1–4, on both the serial small-tensor path and the scoped
    /// parallel path.
    #[test]
    fn parallel_reduce_and_fused_step_match_serial_baseline_bitwise() {
        for (params, tag) in [(ParamSet::init(&entry(), 7), "small"), (big_params(5), "big")] {
            for workers in 1..=8usize {
                let grads = random_grads(&params, workers, 100 + workers as u64);
                let avg = average_grads(&grads);
                let mut p1 = params.clone();
                let mut o1 = Sgd::new(0.2, 0.9, &p1);
                o1.step(&mut p1, &avg);
                for threads in 1..=4usize {
                    let mut red = GradReducer::new(&params, threads);
                    // exercise the parallel path on the small set too
                    red.set_par_min(1);
                    red.reduce(&grads);
                    let mut off = 0;
                    for (ti, a) in avg.iter().enumerate() {
                        for (i, v) in a.iter().enumerate() {
                            let got = red.acc()[off + i] / workers as f32;
                            assert_eq!(
                                got.to_bits(),
                                v.to_bits(),
                                "{tag} w={workers} t={threads} tensor {ti}[{i}]: {got} vs {v}"
                            );
                        }
                        off += a.len();
                    }
                    let mut p2 = params.clone();
                    let mut o2 = Sgd::new(0.2, 0.9, &p2);
                    o2.step_fused(&mut p2, red.acc(), workers);
                    for (ti, (w1, w2)) in p1.data.iter().zip(&p2.data).enumerate() {
                        for (i, (x, y)) in w1.iter().zip(w2).enumerate() {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "{tag} w={workers} t={threads} param {ti}[{i}]: {x} vs {y}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn reducer_recycles_without_growth() {
        // the accumulator never re-allocates across reduces
        let p = big_params(9);
        let mut red = GradReducer::new(&p, 4);
        let cap_ptr = red.acc().as_ptr();
        for seed in 0..3 {
            let grads = random_grads(&p, 4, seed);
            red.reduce(&grads);
        }
        assert_eq!(red.acc().as_ptr(), cap_ptr);
        assert_eq!(red.acc().len(), p.num_elems());
    }
}
