//! Model parameters and the synchronous-SGD weight update.
//!
//! Parameters live on the host in artifact order; after every iteration
//! the coordinator averages the per-FPGA gradients (gradient
//! synchronisation, §4.2) and applies SGD with momentum, then broadcasts
//! the updated weights (in the simulation: shares the new `Arc`).

use crate::runtime::ArtifactEntry;
use crate::util::rng::Rng;

/// Flat parameter set in artifact order.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub data: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Glorot-initialised parameters matching an artifact's shapes
    /// (biases — rank-1 params — start at zero).
    pub fn init(entry: &ArtifactEntry, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed ^ 0x9a2a);
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        let mut data = Vec::new();
        for (name, shape) in &entry.params {
            let n: usize = shape.iter().product();
            let buf = if shape.len() >= 2 {
                let scale = (2.0 / (shape[0] + shape[1]) as f64).sqrt();
                (0..n).map(|_| (rng.normal() * scale) as f32).collect()
            } else {
                vec![0.0f32; n]
            };
            names.push(name.clone());
            shapes.push(shape.clone());
            data.push(buf);
        }
        ParamSet { names, shapes, data }
    }

    pub fn num_elems(&self) -> usize {
        self.data.iter().map(|d| d.len()).sum()
    }

    /// L2 norm over all parameters (diagnostics / tests).
    pub fn norm(&self) -> f64 {
        self.data
            .iter()
            .flat_map(|d| d.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// Average gradients across workers (synchronous SGD's reduction).
pub fn average_grads(grads: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    assert!(!grads.is_empty());
    let p = grads.len() as f32;
    let mut avg: Vec<Vec<f32>> = grads[0].clone();
    for g in &grads[1..] {
        assert_eq!(g.len(), avg.len(), "gradient arity mismatch");
        for (a, gi) in avg.iter_mut().zip(g) {
            assert_eq!(a.len(), gi.len(), "gradient shape mismatch");
            for (x, y) in a.iter_mut().zip(gi) {
                *x += *y;
            }
        }
    }
    for a in avg.iter_mut() {
        for x in a.iter_mut() {
            *x /= p;
        }
    }
    avg
}

/// SGD with momentum.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32, params: &ParamSet) -> Sgd {
        Sgd {
            lr,
            momentum,
            velocity: params.data.iter().map(|d| vec![0.0; d.len()]).collect(),
        }
    }

    /// In-place update: v = μ·v + g;  w -= lr·v.
    pub fn step(&mut self, params: &mut ParamSet, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), params.data.len());
        for ((w, v), g) in params.data.iter_mut().zip(&mut self.velocity).zip(grads) {
            assert_eq!(w.len(), g.len());
            for i in 0..w.len() {
                v[i] = self.momentum * v[i] + g[i];
                w[i] -= self.lr * v[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn entry() -> ArtifactEntry {
        ArtifactEntry {
            name: "t".into(),
            kind: "train".into(),
            model: "gcn".into(),
            dataset: "tiny".into(),
            path: PathBuf::from("/dev/null"),
            dims: crate::runtime::ArtifactDims::from_batch(4, &[2, 1], &[6, 5, 3]),
            params: vec![
                ("w1".into(), vec![6, 5]),
                ("b1".into(), vec![5]),
                ("w2".into(), vec![5, 3]),
                ("b2".into(), vec![3]),
            ],
            outputs: vec!["loss".into()],
        }
    }

    #[test]
    fn init_shapes_and_bias_zero() {
        let p = ParamSet::init(&entry(), 1);
        assert_eq!(p.num_elems(), 30 + 5 + 15 + 3);
        assert!(p.data[1].iter().all(|&x| x == 0.0)); // b1
        assert!(p.data[0].iter().any(|&x| x != 0.0)); // w1
        // deterministic
        let q = ParamSet::init(&entry(), 1);
        assert_eq!(p.data, q.data);
        assert_ne!(p.data, ParamSet::init(&entry(), 2).data);
    }

    #[test]
    fn average_is_elementwise_mean() {
        let g1 = vec![vec![1.0f32, 2.0], vec![0.0]];
        let g2 = vec![vec![3.0f32, 6.0], vec![2.0]];
        let avg = average_grads(&[g1, g2]);
        assert_eq!(avg, vec![vec![2.0, 4.0], vec![1.0]]);
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let mut p = ParamSet::init(&entry(), 3);
        let w0 = p.data[0][0];
        let mut opt = Sgd::new(0.1, 0.0, &p);
        let grads: Vec<Vec<f32>> = p.data.iter().map(|d| vec![1.0; d.len()]).collect();
        opt.step(&mut p, &grads);
        assert!((p.data[0][0] - (w0 - 0.1)).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let mut p = ParamSet::init(&entry(), 4);
        let w0 = p.data[0][0];
        let mut opt = Sgd::new(0.1, 0.5, &p);
        let grads: Vec<Vec<f32>> = p.data.iter().map(|d| vec![1.0; d.len()]).collect();
        opt.step(&mut p, &grads); // v=1, w -= .1
        opt.step(&mut p, &grads); // v=1.5, w -= .15
        assert!((p.data[0][0] - (w0 - 0.25)).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn average_rejects_mismatched_arity() {
        average_grads(&[vec![vec![1.0]], vec![vec![1.0], vec![2.0]]]);
    }
}
