//! The host batch-preparation pipeline.
//!
//! The seed prepared every task of an iteration serially on the
//! coordinator thread, so host prep scaled O(p) while FPGA execution
//! scaled O(1) — exactly the imbalance HyScale-GNN / HP-GNN identify as
//! the limiter on heterogeneous platforms. This module restructures the
//! epoch into three decoupled stages (DESIGN.md §Host pipeline):
//!
//! 1. **Planning** — [`plan_epoch_tasks`] materialises the whole epoch's
//!    iteration schedule up front: `TwoStageScheduler` task assignment
//!    plus `EpochPlan` target handout, as plain [`PrepTask`] values. No
//!    sampling happens here, so planning always runs ahead.
//! 2. **Preparation** — a pool of `--host-threads` workers
//!    ([`prep_worker`]) pulls tasks from a shared queue, samples and
//!    feature-gathers each into a [`PreparedBatch`]. The coordinator
//!    releases tasks through a **bounded prefetch window** of depth
//!    `--prefetch-depth`: while iteration *i* executes, iterations
//!    `i+1 .. i+D-1` may be in preparation (D = 1 reproduces the seed's
//!    serial behaviour; D = 2 the old `--prefetch` flag).
//! 3. **Execution** — the `WorkerPool` drains prepared iterations at the
//!    gradient-sync barrier (`trainer::run_epoch`).
//!
//! Determinism: a task's sampling RNG is keyed by (epoch stream,
//! partition, per-partition seq); prepared batches carry (iter, tag) and
//! are reassembled in that order; per-batch [`PrepStats`] are merged at
//! the barrier in the same order. Prep workers read an **epoch-versioned
//! residency snapshot** (`Preprocessed::residency_snapshot`) rather than
//! the live feature stores, so dynamic cache policies — whose
//! `observe`/`end_epoch` hooks run only on the coordinator at the
//! barriers — cannot make prepared traffic depend on preparation order.
//! The loss sequence for a given seed is therefore bit-identical for any
//! `--host-threads` × `--prefetch-depth` combination, including the
//! serial path (1, 1).

use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

use crate::comm::{CommConfig, FeatureService, Traffic};
use crate::graph::Dataset;
use crate::runtime::BatchBuffers;
use crate::sampling::{EpochPlan, MiniBatch, Sampler};
use crate::sched::TwoStageScheduler;
use crate::store::Residency;

/// One planned unit of host work: sample batch number `seq` of partition
/// `part` and gather its features against FPGA `fpga`'s store.
#[derive(Clone, Debug)]
pub struct PrepTask {
    /// Iteration index within the epoch.
    pub iter: usize,
    /// Task index within the iteration (reassembly + reduction order).
    pub tag: usize,
    pub part: usize,
    pub fpga: usize,
    /// Per-partition batch sequence number (RNG stream key).
    pub seq: usize,
    pub targets: Vec<u32>,
}

/// Host-side measurements of one prepared batch. Collected per batch and
/// merged into `EpochMetrics` in deterministic (iter, tag) order at the
/// barrier — no shared counters between prep threads.
#[derive(Clone, Debug, Default)]
pub struct PrepStats {
    pub sample_seconds: f64,
    pub gather_seconds: f64,
    pub vertices_traversed: u64,
    pub traffic: Traffic,
    /// Measured batch shape [v_0..v_L, a_1..a_L] (2L+1 entries).
    pub shape: Vec<f64>,
}

impl PrepStats {
    fn measure(
        mb: &MiniBatch,
        sample_seconds: f64,
        gather_seconds: f64,
        traffic: Traffic,
    ) -> PrepStats {
        let mut shape: Vec<f64> = mb.n.iter().map(|&x| x as f64).collect();
        shape.extend((1..=mb.layers()).map(|l| mb.edges(l) as f64));
        PrepStats {
            sample_seconds,
            gather_seconds,
            vertices_traversed: mb.vertices_traversed() as u64,
            traffic,
            shape,
        }
    }
}

/// A fully prepared batch, ready for dispatch to its FPGA worker.
pub struct PreparedBatch {
    pub iter: usize,
    pub tag: usize,
    pub fpga: usize,
    pub batch: BatchBuffers,
    pub stats: PrepStats,
    /// The batch's real (unpadded) layer-0 vertex ids — the coordinator's
    /// barrier pass feeds them to `comm::IterDedup` and to the feature
    /// store's `observe` hook.
    pub v0: Vec<u32>,
}

/// Planning stage: materialise the epoch's full iteration/task schedule.
/// Consumes `remaining` via the scheduler and the plan's target handout;
/// truncates at `max_iterations` so capped runs never plan (and therefore
/// never prepare or count) batches that would not execute.
pub fn plan_epoch_tasks(
    sched: &mut TwoStageScheduler,
    plan: &mut EpochPlan,
    remaining: &mut [usize],
    max_iterations: Option<usize>,
) -> Vec<Vec<PrepTask>> {
    let mut iterations: Vec<Vec<PrepTask>> = Vec::new();
    loop {
        if let Some(mx) = max_iterations {
            if iterations.len() >= mx {
                break;
            }
        }
        let Some(ip) = sched.plan_iteration_consuming(remaining) else {
            break;
        };
        let iter = iterations.len();
        let mut tasks = Vec::with_capacity(ip.tasks.len());
        for (tag, t) in ip.tasks.iter().enumerate() {
            let (seq, targets) = plan
                .next_targets_seq(t.part)
                .expect("scheduler consumed beyond the epoch plan");
            tasks.push(PrepTask {
                iter,
                tag,
                part: t.part,
                fpga: t.fpga,
                seq,
                targets: targets.to_vec(),
            });
        }
        iterations.push(tasks);
    }
    iterations
}

/// Body of one prep-pool worker. Borrows a per-thread [`Sampler`] whose
/// |V|-sized scratch persists across epochs (usable for any partition —
/// batch content is keyed, not stateful; only the stream base is re-keyed
/// here) and one reusable [`FeatureService`], hoisted out of the
/// per-batch loop. Exits when the task channel closes or the result
/// receiver is gone. A panic while preparing a batch sends an `Err`
/// sentinel first so the coordinator fails instead of waiting forever,
/// then resumes unwinding (the scope rethrows the original panic).
pub fn prep_worker(
    data: &Dataset,
    stores: &[Residency],
    vertex_part: Option<&[u32]>,
    sampler: &mut Sampler,
    comm: CommConfig,
    epoch_stream: u64,
    tasks: &Mutex<mpsc::Receiver<PrepTask>>,
    done: &mpsc::Sender<anyhow::Result<PreparedBatch>>,
) {
    sampler.set_stream(epoch_stream);
    let svc = FeatureService::new(&data.features, comm);
    let f0 = data.features.feat_dim();
    loop {
        let msg = match tasks.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break, // a sibling panicked while holding the lock
        };
        let Ok(task) = msg else { break };

        let prepared = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let t0 = Instant::now();
            let mb = sampler.sample(data, &task.targets, task.part, task.seq);
            let sample_seconds = t0.elapsed().as_secs_f64();

            let t1 = Instant::now();
            let (feat0, traffic) =
                svc.gather(&mb, &stores[task.fpga], vertex_part, task.fpga);
            let gather_seconds = t1.elapsed().as_secs_f64();

            let stats = PrepStats::measure(&mb, sample_seconds, gather_seconds, traffic);
            let v0 = mb.level0().to_vec();
            let batch = BatchBuffers::from_minibatch(&mb, feat0, f0);
            PreparedBatch { iter: task.iter, tag: task.tag, fpga: task.fpga, batch, stats, v0 }
        }));
        match prepared {
            Ok(pb) => {
                if done.send(Ok(pb)).is_err() {
                    break;
                }
            }
            Err(payload) => {
                let _ = done.send(Err(anyhow::anyhow!(
                    "prep worker panicked on iter {} tag {} (part {})",
                    task.iter,
                    task.tag,
                    task.part
                )));
                std::panic::resume_unwind(payload);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::partition::{preprocess, Algorithm, Preprocessed};
    use crate::sampling::{FanoutConfig, WeightMode};
    use crate::util::rng::Rng;

    fn setup(p: usize) -> (Dataset, Preprocessed) {
        let d = datasets::lookup("tiny").unwrap().build(0, 21);
        let pre = preprocess(Algorithm::DistDgl, &d, p, 0.2, 21);
        (d, pre)
    }

    fn plan_tasks(pre: &Preprocessed, p: usize, mx: Option<usize>) -> Vec<Vec<PrepTask>> {
        let mut rng = Rng::new(5);
        let mut plan = EpochPlan::new(&pre.train_parts, 32, &mut rng);
        let mut sched = TwoStageScheduler::new(p, true);
        let mut remaining: Vec<usize> = (0..p).map(|i| plan.remaining(i)).collect();
        plan_epoch_tasks(&mut sched, &mut plan, &mut remaining, mx)
    }

    #[test]
    fn planning_is_exhaustive_and_ordered() {
        let p = 2;
        let (_, pre) = setup(p);
        let iterations = plan_tasks(&pre, p, None);
        let total_batches: usize =
            (0..p).map(|i| (pre.train_parts[i].len() + 31) / 32).sum();
        assert_eq!(iterations.iter().map(|t| t.len()).sum::<usize>(), total_batches);
        // tags contiguous, iters consistent, per-partition seqs monotonic
        let mut next_seq = vec![0usize; p];
        for (i, tasks) in iterations.iter().enumerate() {
            for (tag, t) in tasks.iter().enumerate() {
                assert_eq!(t.iter, i);
                assert_eq!(t.tag, tag);
                assert_eq!(t.seq, next_seq[t.part]);
                next_seq[t.part] += 1;
                assert!(!t.targets.is_empty() && t.targets.len() <= 32);
            }
        }
    }

    #[test]
    fn planning_respects_iteration_cap() {
        let p = 2;
        let (_, pre) = setup(p);
        let iterations = plan_tasks(&pre, p, Some(3));
        assert_eq!(iterations.len(), 3);
        // stage-1 iterations: one batch per FPGA
        assert!(iterations.iter().all(|t| t.len() == p));
    }

    #[test]
    fn prep_worker_prepares_all_queued_tasks() {
        let p = 2;
        let (data, pre) = setup(p);
        let iterations = plan_tasks(&pre, p, Some(2));
        let n_tasks: usize = iterations.iter().map(|t| t.len()).sum();
        let (task_tx, task_rx) = mpsc::channel();
        let (done_tx, done_rx) = mpsc::channel();
        for tasks in iterations {
            for t in tasks {
                task_tx.send(t).unwrap();
            }
        }
        drop(task_tx);
        let fanout = FanoutConfig::new(32, &[3, 2]);
        let mut sampler =
            Sampler::new(fanout, WeightMode::GcnNorm, data.graph.num_vertices(), 0);
        let rx = Mutex::new(task_rx);
        let snaps = pre.residency_snapshot();
        std::thread::scope(|s| {
            let done_tx = done_tx.clone();
            let rxr = &rx;
            let d = &data;
            let stores = &snaps[..];
            let vertex_part = pre.vertex_part.as_deref();
            let smp = &mut sampler;
            s.spawn(move || {
                prep_worker(d, stores, vertex_part, smp, CommConfig::default(), 99, rxr, &done_tx)
            });
        });
        drop(done_tx);
        let got: Vec<PreparedBatch> = done_rx.iter().map(|r| r.unwrap()).collect();
        assert_eq!(got.len(), n_tasks);
        for b in &got {
            assert!(b.stats.vertices_traversed > 0);
            assert!(b.stats.traffic.total_bytes() > 0);
            assert!(b.stats.shape[0] >= b.stats.shape[1]);
            assert_eq!(b.v0.len(), b.stats.shape[0] as usize, "unpadded v0 travels with the batch");
        }
    }
}
