//! The host batch-preparation pipeline.
//!
//! The seed prepared every task of an iteration serially on the
//! coordinator thread, so host prep scaled O(p) while FPGA execution
//! scaled O(1) — exactly the imbalance HyScale-GNN / HP-GNN identify as
//! the limiter on heterogeneous platforms. This module restructures the
//! epoch into three decoupled stages (DESIGN.md §Host pipeline):
//!
//! 1. **Planning** — [`plan_epoch_tasks`] materialises the whole epoch's
//!    iteration schedule up front: `TwoStageScheduler` task assignment
//!    plus `EpochPlan` target handout, as plain [`PrepTask`] values. No
//!    sampling happens here, so planning always runs ahead.
//! 2. **Preparation** — a pool of `--host-threads` workers
//!    ([`prep_worker`]) pulls tasks from a shared queue, samples and
//!    feature-gathers each into a [`PreparedBatch`]. The coordinator
//!    releases tasks through a **bounded prefetch window** of depth
//!    `--prefetch-depth`: while iteration *i* executes, iterations
//!    `i+1 .. i+D-1` may be in preparation (D = 1 reproduces the seed's
//!    serial behaviour; D = 2 the old `--prefetch` flag).
//! 3. **Execution** — the `WorkerPool` drains prepared iterations at the
//!    gradient-sync barrier (`trainer::run_epoch`).
//!
//! Determinism: a task's sampling RNG is keyed by (epoch stream,
//! partition, per-partition seq); prepared batches carry (iter, tag) and
//! are reassembled in that order; per-batch [`PrepStats`] are merged at
//! the barrier in the same order. Prep workers read an **epoch-versioned
//! residency snapshot** (`Preprocessed::residency_snapshot`) rather than
//! the live feature stores, so dynamic cache policies — whose
//! `observe`/`end_epoch` hooks run only on the coordinator at the
//! barriers — cannot make prepared traffic depend on preparation order.
//! The loss sequence for a given seed is therefore bit-identical for any
//! `--host-threads` × `--prefetch-depth` combination, including the
//! serial path (1, 1).
//!
//! Both pipeline knobs (pool size and window depth) are owned by the
//! online auto-tuner when `--auto-tune on` (DESIGN.md §Adaptive control):
//! the trainer re-reads them at every epoch start, and the time the
//! coordinator spends blocked in the reassembly recv loop waiting for
//! this stage is surfaced as `EpochMetrics::prep_stall_seconds` — the
//! signal that drives the tuner's grow steps on these axes.

use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

use crate::comm::{CommConfig, FeatureService, Traffic};
use crate::graph::Dataset;
use crate::runtime::BatchBuffers;
use crate::sampling::{EpochPlan, MiniBatch, Sampler};
use crate::sched::TwoStageScheduler;
use crate::store::Residency;

/// One planned unit of host work: sample batch number `seq` of partition
/// `part` and gather its features against FPGA `fpga`'s store.
#[derive(Clone, Debug)]
pub struct PrepTask {
    /// Iteration index within the epoch.
    pub iter: usize,
    /// Task index within the iteration (reassembly + reduction order).
    pub tag: usize,
    pub part: usize,
    pub fpga: usize,
    /// Per-partition batch sequence number (RNG stream key).
    pub seq: usize,
    pub targets: Vec<u32>,
    /// Fault injection (`--fault-plan prep:panic@eEiI`): preparing this
    /// task panics, exercising the coordinator's error-path drain.
    pub inject_panic: bool,
}

/// Host-side measurements of one prepared batch. Collected per batch and
/// merged into `EpochMetrics` in deterministic (iter, tag) order at the
/// barrier — no shared counters between prep threads.
#[derive(Clone, Debug, Default)]
pub struct PrepStats {
    pub sample_seconds: f64,
    pub gather_seconds: f64,
    pub vertices_traversed: u64,
    pub traffic: Traffic,
    /// Measured batch shape [v_0..v_L, a_1..a_L] (2L+1 entries).
    pub shape: Vec<f64>,
}

impl PrepStats {
    fn measure(
        mb: &MiniBatch,
        sample_seconds: f64,
        gather_seconds: f64,
        traffic: Traffic,
    ) -> PrepStats {
        let mut shape: Vec<f64> = mb.n.iter().map(|&x| x as f64).collect();
        shape.extend((1..=mb.layers()).map(|l| mb.edges(l) as f64));
        PrepStats {
            sample_seconds,
            gather_seconds,
            vertices_traversed: mb.vertices_traversed() as u64,
            traffic,
            shape,
        }
    }
}

/// A fully prepared batch, ready for dispatch to its FPGA worker.
pub struct PreparedBatch {
    pub iter: usize,
    pub tag: usize,
    pub fpga: usize,
    /// The sampled block. Kept with the batch so the coordinator's
    /// barrier pass can read `mb.level0()` (fetch dedup + the store's
    /// `observe` hook) and then recycle the buffers via the return
    /// channel instead of dropping them.
    pub mb: MiniBatch,
    pub batch: BatchBuffers,
    pub stats: PrepStats,
}

/// A consumed batch's reusable buffers, cycled back to the prep pool by
/// the coordinator (DESIGN.md §Hot-path memory & kernels). The pool is
/// self-bounding: workers only allocate a fresh carcass when the return
/// channel is empty, so the number of live carcasses never exceeds the
/// pipeline's in-flight window (≈ `prefetch_depth · p + p` batches plus
/// one per prep thread).
pub struct BatchCarcass {
    pub mb: MiniBatch,
    pub bufs: BatchBuffers,
}

/// Drain every prepared batch from a closed result channel, propagating
/// the first worker error to the caller instead of panicking.
pub fn drain_prepared(
    rx: &mpsc::Receiver<anyhow::Result<PreparedBatch>>,
) -> anyhow::Result<Vec<PreparedBatch>> {
    rx.iter().collect()
}

/// Planning stage: materialise the epoch's full iteration/task schedule.
/// Consumes `remaining` via the scheduler and the plan's target handout;
/// truncates at `max_iterations` so capped runs never plan (and therefore
/// never prepare or count) batches that would not execute.
pub fn plan_epoch_tasks(
    sched: &mut TwoStageScheduler,
    plan: &mut EpochPlan,
    remaining: &mut [usize],
    max_iterations: Option<usize>,
) -> Vec<Vec<PrepTask>> {
    plan_epoch_tasks_with_faults(sched, plan, remaining, max_iterations, &[])
        .expect("fault-free planning cannot fail")
}

/// [`plan_epoch_tasks`] under a device-failure schedule: `failures` is
/// the epoch's (iteration, device) anchors sorted by iteration
/// (`FaultPlan::failures_in_epoch`). Before planning iteration *I*, every
/// failure anchored at *I* quarantines its device in the scheduler, so
/// that device executes no task of iteration *I* or later and its
/// partition's remaining batches drain deterministically to survivors.
/// Because the whole epoch is planned here — before any sampling or
/// wall-clock enters the picture — a faulted plan is a pure function of
/// (plan, schedule), and every batch still appears exactly once.
///
/// Fails cleanly when a quarantine leaves no survivors or an anchor's
/// iteration lies beyond the planned epoch (the anchor would silently
/// never fire).
pub fn plan_epoch_tasks_with_faults(
    sched: &mut TwoStageScheduler,
    plan: &mut EpochPlan,
    remaining: &mut [usize],
    max_iterations: Option<usize>,
    failures: &[(usize, usize)],
) -> anyhow::Result<Vec<Vec<PrepTask>>> {
    let mut iterations: Vec<Vec<PrepTask>> = Vec::new();
    let mut next_failure = 0usize;
    loop {
        if let Some(mx) = max_iterations {
            if iterations.len() >= mx {
                break;
            }
        }
        while next_failure < failures.len() && failures[next_failure].0 == iterations.len() {
            sched.quarantine(failures[next_failure].1)?;
            next_failure += 1;
        }
        let Some(ip) = sched.plan_iteration_consuming(remaining) else {
            break;
        };
        let iter = iterations.len();
        let mut tasks = Vec::with_capacity(ip.tasks.len());
        for (tag, t) in ip.tasks.iter().enumerate() {
            let (seq, targets) = plan
                .next_targets_seq(t.part)
                .expect("scheduler consumed beyond the epoch plan");
            tasks.push(PrepTask {
                iter,
                tag,
                part: t.part,
                fpga: t.fpga,
                seq,
                targets: targets.to_vec(),
                inject_panic: false,
            });
        }
        iterations.push(tasks);
    }
    if next_failure < failures.len() {
        let (it, dev) = failures[next_failure];
        anyhow::bail!(
            "fault plan anchors dev{dev} failure at iteration {it}, but the epoch planned \
             only {} iterations",
            iterations.len()
        );
    }
    Ok(iterations)
}

/// Body of one prep-pool worker. Borrows a per-thread [`Sampler`] whose
/// |V|-sized scratch persists across epochs (usable for any partition —
/// batch content is keyed, not stateful; only the stream base is re-keyed
/// here) and one reusable [`FeatureService`], hoisted out of the
/// per-batch loop. Each task is prepared into a recycled [`BatchCarcass`]
/// pulled (non-blocking) from `recycle` — the coordinator's return
/// channel — falling back to a fresh allocation when the pool is empty;
/// steady state is therefore allocation-free. Exits when the task channel
/// closes or the result receiver is gone. A panic while preparing a batch
/// is converted to a clean `Err` for the coordinator (which aborts the
/// epoch through the error path, not a poisoned join) and the worker
/// keeps serving remaining tasks.
#[allow(clippy::too_many_arguments)]
pub fn prep_worker(
    data: &Dataset,
    stores: &[Residency],
    vertex_part: Option<&[u32]>,
    sampler: &mut Sampler,
    comm: CommConfig,
    epoch_stream: u64,
    tasks: &Mutex<mpsc::Receiver<PrepTask>>,
    done: &mpsc::Sender<anyhow::Result<PreparedBatch>>,
    recycle: Option<&Mutex<mpsc::Receiver<BatchCarcass>>>,
) {
    sampler.set_stream(epoch_stream);
    let svc = FeatureService::new(&data.features, comm);
    let f0 = data.features.feat_dim();
    loop {
        let msg = match tasks.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break, // a sibling panicked while holding the lock
        };
        let Ok(task) = msg else { break };

        let carcass = recycle
            .and_then(|rx| rx.lock().ok().and_then(|guard| guard.try_recv().ok()))
            .unwrap_or_else(|| BatchCarcass {
                mb: sampler.new_batch(),
                bufs: BatchBuffers::empty(),
            });
        let BatchCarcass { mut mb, mut bufs } = carcass;

        let prepared = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if task.inject_panic {
                panic!("injected fault (--fault-plan prep:panic)");
            }
            let t0 = Instant::now();
            sampler.sample_into(&mut mb, data, &task.targets, task.part, task.seq);
            let sample_seconds = t0.elapsed().as_secs_f64();

            let t1 = Instant::now();
            let traffic =
                svc.gather_into(&mb, &stores[task.fpga], vertex_part, task.fpga, &mut bufs.feat0);
            let gather_seconds = t1.elapsed().as_secs_f64();
            bufs.fill_from(&mb, f0);

            let stats = PrepStats::measure(&mb, sample_seconds, gather_seconds, traffic);
            PreparedBatch { iter: task.iter, tag: task.tag, fpga: task.fpga, mb, batch: bufs, stats }
        }));
        let send_failed = match prepared {
            Ok(pb) => done.send(Ok(pb)).is_err(),
            Err(payload) => {
                // keep the original panic text in the propagated error
                let msg = payload
                    .downcast_ref::<&'static str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".into());
                done.send(Err(anyhow::anyhow!(
                    "prep worker panicked on iter {} tag {} (part {}): {msg}",
                    task.iter,
                    task.tag,
                    task.part
                )))
                .is_err()
            }
        };
        if send_failed {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::partition::{preprocess, Algorithm, Preprocessed};
    use crate::sampling::{FanoutConfig, WeightMode};
    use crate::util::rng::Rng;

    fn setup(p: usize) -> (Dataset, Preprocessed) {
        let d = datasets::lookup("tiny").unwrap().build(0, 21);
        let pre = preprocess(Algorithm::DistDgl, &d, p, 0.2, 21);
        (d, pre)
    }

    fn plan_tasks(pre: &Preprocessed, p: usize, mx: Option<usize>) -> Vec<Vec<PrepTask>> {
        let mut rng = Rng::new(5);
        let mut plan = EpochPlan::new(&pre.train_parts, 32, &mut rng);
        let mut sched = TwoStageScheduler::new(p, true);
        let mut remaining: Vec<usize> = (0..p).map(|i| plan.remaining(i)).collect();
        plan_epoch_tasks(&mut sched, &mut plan, &mut remaining, mx)
    }

    #[test]
    fn planning_is_exhaustive_and_ordered() {
        let p = 2;
        let (_, pre) = setup(p);
        let iterations = plan_tasks(&pre, p, None);
        let total_batches: usize =
            (0..p).map(|i| (pre.train_parts[i].len() + 31) / 32).sum();
        assert_eq!(iterations.iter().map(|t| t.len()).sum::<usize>(), total_batches);
        // tags contiguous, iters consistent, per-partition seqs monotonic
        let mut next_seq = vec![0usize; p];
        for (i, tasks) in iterations.iter().enumerate() {
            for (tag, t) in tasks.iter().enumerate() {
                assert_eq!(t.iter, i);
                assert_eq!(t.tag, tag);
                assert_eq!(t.seq, next_seq[t.part]);
                next_seq[t.part] += 1;
                assert!(!t.targets.is_empty() && t.targets.len() <= 32);
            }
        }
    }

    #[test]
    fn planning_respects_iteration_cap() {
        let p = 2;
        let (_, pre) = setup(p);
        let iterations = plan_tasks(&pre, p, Some(3));
        assert_eq!(iterations.len(), 3);
        // stage-1 iterations: one batch per FPGA
        assert!(iterations.iter().all(|t| t.len() == p));
    }

    #[test]
    fn prep_worker_prepares_all_queued_tasks() {
        let p = 2;
        let (data, pre) = setup(p);
        let iterations = plan_tasks(&pre, p, Some(2));
        let n_tasks: usize = iterations.iter().map(|t| t.len()).sum();
        let (task_tx, task_rx) = mpsc::channel();
        let (done_tx, done_rx) = mpsc::channel();
        for tasks in iterations {
            for t in tasks {
                task_tx.send(t).unwrap();
            }
        }
        drop(task_tx);
        let fanout = FanoutConfig::new(32, &[3, 2]);
        let mut sampler =
            Sampler::new(fanout, WeightMode::GcnNorm, data.graph.num_vertices(), 0);
        let rx = Mutex::new(task_rx);
        let snaps = pre.residency_snapshot();
        std::thread::scope(|s| {
            let done_tx = done_tx.clone();
            let rxr = &rx;
            let d = &data;
            let stores = &snaps[..];
            let vertex_part = pre.vertex_part.as_deref();
            let smp = &mut sampler;
            s.spawn(move || {
                prep_worker(
                    d,
                    stores,
                    vertex_part,
                    smp,
                    CommConfig::default(),
                    99,
                    rxr,
                    &done_tx,
                    None,
                )
            });
        });
        drop(done_tx);
        let got: Vec<PreparedBatch> = drain_prepared(&done_rx).unwrap();
        assert_eq!(got.len(), n_tasks);
        for b in &got {
            assert!(b.stats.vertices_traversed > 0);
            assert!(b.stats.traffic.total_bytes() > 0);
            assert!(b.stats.shape[0] >= b.stats.shape[1]);
            assert_eq!(
                b.mb.level0().len(),
                b.stats.shape[0] as usize,
                "unpadded level-0 ids travel with the batch"
            );
            assert_eq!(b.batch.n, b.mb.n, "executor buffers carry the real row counts");
        }
    }

    #[test]
    fn recycled_carcasses_produce_identical_batches() {
        // run the same task list twice — once allocating fresh buffers,
        // once through a recycle channel pre-seeded with dirty carcasses —
        // and require bit-identical prepared output (the determinism law
        // survives buffer reuse)
        let p = 2;
        let (data, pre) = setup(p);
        let iterations = plan_tasks(&pre, p, Some(2));
        let fanout = FanoutConfig::new(32, &[3, 2]);
        let snaps = pre.residency_snapshot();

        let run = |recycle: Option<&Mutex<mpsc::Receiver<BatchCarcass>>>| {
            let (task_tx, task_rx) = mpsc::channel();
            let (done_tx, done_rx) = mpsc::channel();
            for tasks in iterations.clone() {
                for t in tasks {
                    task_tx.send(t).unwrap();
                }
            }
            drop(task_tx);
            let mut sampler =
                Sampler::new(fanout.clone(), WeightMode::GcnNorm, data.graph.num_vertices(), 0);
            let rx = Mutex::new(task_rx);
            std::thread::scope(|s| {
                let done_tx = done_tx.clone();
                let rxr = &rx;
                let d = &data;
                let stores = &snaps[..];
                let vertex_part = pre.vertex_part.as_deref();
                let smp = &mut sampler;
                s.spawn(move || {
                    prep_worker(
                        d,
                        stores,
                        vertex_part,
                        smp,
                        CommConfig::default(),
                        99,
                        rxr,
                        &done_tx,
                        recycle,
                    )
                });
            });
            drop(done_tx);
            let mut got = drain_prepared(&done_rx).unwrap();
            got.sort_by_key(|b| (b.iter, b.tag));
            got
        };

        let fresh = run(None);

        // dirty carcasses: sample an unrelated batch into each first
        let (rec_tx, rec_rx) = mpsc::channel();
        let mut dirty_sampler =
            Sampler::new(fanout.clone(), WeightMode::GcnNorm, data.graph.num_vertices(), 7);
        for seq in 0..2 {
            let mut mb = dirty_sampler.new_batch();
            dirty_sampler.sample_into(&mut mb, &data, &pre.train_parts[0][..5], 0, seq + 100);
            let svc = FeatureService::new(&data.features, CommConfig::default());
            let mut bufs = BatchBuffers::empty();
            let _ = svc.gather_into(&mb, &snaps[0], pre.vertex_part.as_deref(), 0, &mut bufs.feat0);
            bufs.fill_from(&mb, data.features.feat_dim());
            rec_tx.send(BatchCarcass { mb, bufs }).unwrap();
        }
        drop(rec_tx);
        let rec_rx = Mutex::new(rec_rx);
        let recycled = run(Some(&rec_rx));

        assert_eq!(fresh.len(), recycled.len());
        for (a, b) in fresh.iter().zip(&recycled) {
            assert_eq!((a.iter, a.tag, a.fpga), (b.iter, b.tag, b.fpga));
            assert_eq!(a.batch.feat0, b.batch.feat0, "feat0 diverged under recycling");
            assert_eq!(a.batch.idx, b.batch.idx);
            assert_eq!(a.batch.w, b.batch.w);
            assert_eq!(a.batch.labels, b.batch.labels);
            assert_eq!(a.batch.mask, b.batch.mask);
            assert_eq!(a.batch.n, b.batch.n);
            assert_eq!(a.stats.shape, b.stats.shape);
            assert_eq!(a.stats.traffic, b.stats.traffic);
        }
        // the pre-seeded carcasses were consumed
        assert!(rec_rx.lock().unwrap().try_recv().is_err());
    }
}
