//! The training driver: preprocessing → epochs of (plan → pipelined
//! sample/gather → dispatch → gradient sync → weight update), with full
//! measurement.
//!
//! The epoch loop is a three-stage pipeline (see [`super::prep`]): the
//! planning stage materialises the iteration schedule up front, a pool of
//! `--host-threads` prep workers samples + gathers batches through a
//! bounded prefetch window of `--prefetch-depth` iterations, and the
//! coordinator drains prepared iterations into the `WorkerPool` at the
//! gradient-sync barrier. All reductions happen in deterministic
//! (iteration, tag) order, so the loss sequence for a given seed does not
//! depend on the pipeline configuration.
//!
//! Feature-store integration: prep threads gather against an
//! epoch-versioned residency snapshot; the coordinator runs the
//! iteration-level fetch-dedup pass (`comm::IterDedup`) and the cache
//! policy's `observe` hook at the gradient-sync barrier in (iter, tag)
//! order, and applies `end_epoch` re-ranking at the epoch barrier — so
//! dynamic policies keep the determinism law intact.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::config::TrainConfig;
use super::metrics::{EpochMetrics, TrainReport};
use super::params::{GradReducer, ParamSet, Sgd};
use super::prep;
use super::worker::{WorkItem, WorkerPool};
use crate::comm::{CommConfig, FeatureService, IterDedup};
use crate::fault::{self, checkpoint::Checkpoint, FaultPlan};
use crate::fpga::timing::BatchShape;
use crate::graph::{datasets, Dataset};
use crate::partition::{preprocess_with_policy, Preprocessed};
use crate::perf::{FleetModel, Workload};
use crate::store::{FeatureStore, Residency, TieredStore};
use crate::runtime::{ArtifactEntry, BatchBuffers, GradBuffers, Manifest, TrainExecutor};
use crate::sampling::{EpochPlan, FanoutConfig, Sampler, WeightMode};
use crate::sched::{CostModel, IterationPlan, Task, TwoStageScheduler};
use crate::tune::{AutoTuneMode, AutoTuner, EpochObservation, Knobs, TunePrior, TunerState};
use crate::util::rng::Rng;

/// Cold-start local-fetch ratio for the scheduler cost model before the
/// first epoch has measured one (the paper's nominal β).
const COLD_START_BETA: f64 = 0.75;

/// Everything needed to train; build with [`Trainer::new`], run with
/// [`Trainer::run`].
pub struct Trainer {
    pub cfg: TrainConfig,
    pub data: Dataset,
    pub pre: Preprocessed,
    entry: ArtifactEntry,
    /// Predict artifact, cached at construction so `evaluate` never
    /// re-reads the manifest from disk.
    predict_entry: Option<ArtifactEntry>,
    /// Compiled predict executor, built lazily on the first `evaluate`
    /// call and reused afterwards (PJRT compilation is not cheap).
    predict_exe: Option<TrainExecutor>,
    pool: WorkerPool,
    pub params: ParamSet,
    opt: Sgd,
    /// Persistent gradient-sum accumulator (`--reduce-threads` scoped
    /// reduction; DESIGN.md §SIMD dispatch & gradient sync).
    reducer: GradReducer,
    /// Cross-iteration gradient carcass pool: consumed [`GradBuffers`]
    /// return here after the reduction and ride back to the workers in
    /// the next `WorkItem` — the gradient-side mirror of the batch
    /// carcass channel below. `--no-pool` disables reuse (ablation).
    grad_pool: Vec<GradBuffers>,
    /// Reduction staging: the current iteration's gradients in tag
    /// order. Persistent so the per-iteration collect loop never
    /// allocates the outer vector.
    grad_scratch: Vec<GradBuffers>,
    mode: WeightMode,
    /// One sampler per prep thread; the |V|-sized scratch arrays persist
    /// across epochs (only the RNG stream base is re-keyed per epoch).
    samplers: Vec<Sampler>,
    /// Cross-epoch carcass pool (ISSUE 5 tentpole): consumed batch
    /// buffers flow back to the prep workers through this channel
    /// instead of being dropped. Hoisted onto the trainer — like the
    /// samplers — so the zero-allocation steady state survives epoch
    /// boundaries, not just iterations within one.
    recycle_tx: mpsc::Sender<prep::BatchCarcass>,
    recycle_rx: Mutex<mpsc::Receiver<prep::BatchCarcass>>,
    rng: Rng,
    /// Accumulated mean batch shape [v_0..v_L, a_1..a_L] (2L+1 entries,
    /// level/layer order per DESIGN.md §Mini-batch wire format).
    shape_acc: Vec<f64>,
    shape_n: f64,
    /// Last epoch's measured β — drives the next epoch's scheduler cost
    /// model (deterministic: measured at the barriers, so identical
    /// across pipeline configurations).
    last_beta: f64,
    /// Host-DRAM cache tier above disk (`--dram-ratio < 1`; None =
    /// everything resident). Charges every FPGA-store miss as a DRAM hit
    /// or a disk read against an epoch-immutable membership and re-ranks
    /// at the epoch barrier, exactly like the per-FPGA stores (DESIGN.md
    /// §Out-of-core storage).
    tier: Option<TieredStore>,
    /// Last epoch's measured disk-read share of miss traffic — the cost
    /// model's disk term (cold start: the uncached fraction 1−dram_ratio).
    disk_miss_frac: f64,
    /// Parsed `--fault-plan` (empty when none): the deterministic fault
    /// schedule this run injects (DESIGN.md §Fault tolerance).
    fault: FaultPlan,
    /// Devices lost so far (true = quarantined). A failed device stays
    /// quarantined for the rest of the run and across resume — the
    /// scheduler reroutes its partition's batches to survivors at
    /// planning time.
    quarantined: Vec<bool>,
    /// First epoch `run` executes (non-zero after `--resume`).
    start_epoch: usize,
    /// Tuner state restored from a checkpoint, applied when `run` builds
    /// the controller.
    resume_tuner: Option<TunerState>,
}

impl Trainer {
    pub fn new(mut cfg: TrainConfig) -> anyhow::Result<Trainer> {
        // a packed dataset carries its own key + scale shift; the manifest
        // lookup and report below must see the pack's identity
        let data = match &cfg.dataset_path {
            Some(p) => {
                let data = crate::graph::ondisk::load(std::path::Path::new(p))?;
                cfg.dataset = data.spec.key.to_string();
                cfg.scale_shift = data.scale_shift;
                data
            }
            None => {
                let spec = datasets::lookup(&cfg.dataset)?;
                spec.build(cfg.scale_shift, cfg.seed)
            }
        };
        let mode = WeightMode::for_model(&cfg.model)?;
        if let Some(fleet) = &cfg.fleet {
            anyhow::ensure!(
                fleet.len() == cfg.num_fpgas,
                "fleet has {} devices but num_fpgas is {}",
                fleet.len(),
                cfg.num_fpgas
            );
        }
        crate::log_info!("dataset: {}", data.summary());

        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.cache_ratio),
            "cache_ratio must be in [0, 1] (got {})",
            cfg.cache_ratio
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.dram_ratio),
            "dram_ratio must be in [0, 1] (got {})",
            cfg.dram_ratio
        );
        anyhow::ensure!(
            cfg.disk_gbs.is_finite() && cfg.disk_gbs > 0.0,
            "disk_gbs must be positive (got {})",
            cfg.disk_gbs
        );
        // pin the fault schedule against the live fleet and run length
        // before any work happens — unknown device ids and out-of-range
        // epoch anchors are config errors, not runtime surprises
        let fault = cfg.fault_plan.clone().unwrap_or_default();
        fault.validate(cfg.num_fpgas, cfg.epochs)?;
        let pre = preprocess_with_policy(
            cfg.algo,
            &data,
            cfg.num_fpgas,
            cfg.cache_ratio,
            cfg.cache_policy,
            cfg.seed,
        );
        crate::log_info!(
            "preprocessed with {} (cache policy {}): imbalance={:.3} edge_cut={:?}",
            cfg.algo.name(),
            cfg.cache_policy.name(),
            pre.train_imbalance(),
            pre.edge_cut(&data.graph).map(|c| (c * 1000.0).round() / 1000.0)
        );

        let manifest = Manifest::load_or_builtin(&cfg.artifacts_dir)?;
        let mut entry = manifest.find("train", &cfg.model, &cfg.dataset)?.clone();
        let mut predict_entry = manifest.find("predict", &cfg.model, &cfg.dataset).ok().cloned();
        if let Some(fanouts) = &cfg.fanouts {
            // --fanouts overrides the artifact's depth/fanouts: prefer a
            // manifest entry compiled at exactly this configuration (e.g.
            // the builtin 3-layer SAGE artifact); otherwise synthesize one
            // for the reference executor. PJRT artifacts have fixed
            // compiled shapes, so there the mismatch stays a clean error.
            FanoutConfig::new(entry.dims.b, fanouts).validate()?;
            if *fanouts != entry.dims.fanouts {
                match manifest.find_fanouts("train", &cfg.model, &cfg.dataset, fanouts) {
                    Some(e) => {
                        entry = e.clone();
                        predict_entry = manifest
                            .find_fanouts("predict", &cfg.model, &cfg.dataset, fanouts)
                            .cloned();
                    }
                    None if cfg!(feature = "pjrt") => anyhow::bail!(
                        "no artifact for model={} dataset={} fanouts={fanouts:?} — \
                         re-run `make artifacts` at that depth (or build without \
                         the `pjrt` feature to use the reference executor)",
                        cfg.model,
                        cfg.dataset
                    ),
                    None => {
                        entry = crate::runtime::manifest::synth_entry(
                            &cfg.artifacts_dir,
                            "train",
                            &cfg.model,
                            &cfg.dataset,
                            entry.dims.b,
                            fanouts,
                            data.spec.dims,
                        );
                        predict_entry = Some(crate::runtime::manifest::synth_entry(
                            &cfg.artifacts_dir,
                            "predict",
                            &cfg.model,
                            &cfg.dataset,
                            entry.dims.b,
                            fanouts,
                            data.spec.dims,
                        ));
                    }
                }
            }
        }
        anyhow::ensure!(
            entry.dims.f0() == data.spec.dims.f0,
            "artifact f0 {} != dataset f0 {}",
            entry.dims.f0(),
            data.spec.dims.f0
        );

        let pool = WorkerPool::spawn(&entry, cfg.num_fpgas)?;
        let params = ParamSet::init(&entry, cfg.seed);
        let opt = Sgd::new(cfg.lr, cfg.momentum, &params);
        let reducer = GradReducer::new(&params, cfg.reduce_threads);
        let rng = Rng::new(cfg.seed ^ 0x7a11);
        let fanout = entry.dims.fanout_config();
        let samplers = (0..cfg.host_threads.max(1))
            .map(|_| Sampler::new(fanout.clone(), mode, data.graph.num_vertices(), 0))
            .collect();
        let shape_acc = vec![0.0; 2 * entry.dims.layers() + 1];
        let (recycle_tx, recycle_rx) = mpsc::channel();
        // the DRAM tier shares the per-FPGA stores' policy machinery and
        // degree ranking; at dram_ratio == 1 there is nothing to account
        let tier = (cfg.dram_ratio < 1.0).then(|| {
            TieredStore::new(
                cfg.cache_policy,
                data.graph.num_vertices(),
                cfg.dram_ratio,
                data.features.feat_dim(),
                crate::store::dynamic::degree_rank(&data),
            )
        });
        let disk_miss_frac = 1.0 - cfg.dram_ratio;
        let quarantined = vec![false; cfg.num_fpgas];

        let mut trainer = Trainer {
            cfg,
            data,
            pre,
            entry,
            predict_entry,
            predict_exe: None,
            pool,
            params,
            opt,
            reducer,
            grad_pool: Vec::new(),
            grad_scratch: Vec::new(),
            mode,
            samplers,
            recycle_tx,
            recycle_rx: Mutex::new(recycle_rx),
            rng,
            shape_acc,
            shape_n: 0.0,
            last_beta: COLD_START_BETA,
            tier,
            disk_miss_frac,
            fault,
            quarantined,
            start_epoch: 0,
            resume_tuner: None,
        };
        if let Some(r) = trainer.cfg.resume.clone() {
            trainer.resume_from(std::path::Path::new(&r))?;
        }
        Ok(trainer)
    }

    /// Restore trainer state from a checkpoint file (or the newest one in
    /// a checkpoint directory). Everything the epoch loop carries across
    /// a barrier comes back bit-exactly, so resumed training continues
    /// the same loss/traffic sequence the uninterrupted run would have
    /// produced (the continuation law — `tests/pipeline_determinism.rs`).
    fn resume_from(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let ck = Checkpoint::load(path)?;
        anyhow::ensure!(
            ck.dataset == self.cfg.dataset && ck.model == self.cfg.model,
            "checkpoint is for {}/{} but this run trains {}/{}",
            ck.dataset,
            ck.model,
            self.cfg.dataset,
            self.cfg.model
        );
        anyhow::ensure!(
            ck.num_fpgas as usize == self.cfg.num_fpgas,
            "checkpoint fleet has {} devices but this run has {}",
            ck.num_fpgas,
            self.cfg.num_fpgas
        );
        anyhow::ensure!(
            ck.seed == self.cfg.seed,
            "checkpoint seed {} != run seed {} (resume must continue the same stream)",
            ck.seed,
            self.cfg.seed
        );
        let epoch_next = ck.epoch_next as usize;
        anyhow::ensure!(
            epoch_next < self.cfg.epochs,
            "checkpoint already covers {epoch_next} epochs; raise --epochs past {epoch_next} \
             to resume"
        );
        anyhow::ensure!(
            ck.params.len() == self.params.data.len(),
            "checkpoint has {} parameter tensors, model has {}",
            ck.params.len(),
            self.params.data.len()
        );
        for (i, (new, cur)) in ck.params.iter().zip(&self.params.data).enumerate() {
            anyhow::ensure!(
                new.len() == cur.len(),
                "checkpoint parameter tensor {i} has {} elements, model has {}",
                new.len(),
                cur.len()
            );
        }
        self.opt.restore_velocity(ck.velocity)?;
        self.params.data = ck.params;
        self.rng = Rng::from_state(ck.rng);
        anyhow::ensure!(
            ck.shape_acc.len() == self.shape_acc.len(),
            "checkpoint shape accumulator has {} entries, model depth needs {}",
            ck.shape_acc.len(),
            self.shape_acc.len()
        );
        self.shape_acc = ck.shape_acc;
        self.shape_n = ck.shape_n;
        self.last_beta = ck.last_beta;
        self.disk_miss_frac = ck.disk_miss_frac;
        anyhow::ensure!(
            ck.stores.len() == self.pre.stores.len(),
            "checkpoint has {} store states, fleet has {}",
            ck.stores.len(),
            self.pre.stores.len()
        );
        for (s, st) in self.pre.stores.iter_mut().zip(&ck.stores) {
            s.import_state(st)?;
        }
        match (self.tier.as_mut(), &ck.tier) {
            (Some(t), Some(st)) => t.import_state(st)?,
            (None, None) => {}
            (Some(_), None) => anyhow::bail!(
                "this run has a DRAM tier (--dram-ratio < 1) but the checkpoint carries no \
                 tier state"
            ),
            (None, Some(_)) => anyhow::bail!(
                "checkpoint carries DRAM-tier state but this run has no tier (--dram-ratio 1)"
            ),
        }
        match (self.cfg.auto_tune, ck.tuner) {
            (AutoTuneMode::Off, None) => {}
            (AutoTuneMode::Off, Some(_)) => anyhow::bail!(
                "checkpoint carries auto-tuner state but this run has --auto-tune off"
            ),
            (mode, None) => anyhow::bail!(
                "this run has --auto-tune {} but the checkpoint carries no tuner state",
                mode.name()
            ),
            (_, Some(state)) => self.resume_tuner = Some(state),
        }
        anyhow::ensure!(
            ck.quarantined.len() == self.cfg.num_fpgas,
            "checkpoint quarantine mask has {} devices, fleet has {}",
            ck.quarantined.len(),
            self.cfg.num_fpgas
        );
        self.quarantined = ck.quarantined;
        self.start_epoch = epoch_next;
        crate::log_info!(
            "resume: restored epoch-{} checkpoint ({} quarantined device(s))",
            epoch_next,
            self.quarantined.iter().filter(|&&q| q).count()
        );
        Ok(())
    }

    /// Snapshot the trainer at the epoch barrier into `dir`.
    fn save_checkpoint(
        &self,
        dir: &std::path::Path,
        epoch_next: usize,
        tuner: Option<&AutoTuner>,
    ) -> anyhow::Result<std::path::PathBuf> {
        let ck = Checkpoint {
            dataset: self.cfg.dataset.clone(),
            model: self.cfg.model.clone(),
            num_fpgas: self.cfg.num_fpgas as u32,
            seed: self.cfg.seed,
            epoch_next: epoch_next as u64,
            rng: self.rng.state(),
            shape_n: self.shape_n,
            last_beta: self.last_beta,
            disk_miss_frac: self.disk_miss_frac,
            shape_acc: self.shape_acc.clone(),
            params: self.params.data.clone(),
            velocity: self.opt.velocity().to_vec(),
            stores: self.pre.stores.iter().map(|s| s.export_state()).collect(),
            tier: self.tier.as_ref().map(|t| t.export_state()),
            tuner: tuner.map(|t| t.to_state()),
            quarantined: self.quarantined.clone(),
        };
        ck.save(dir)
    }

    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Run the configured number of epochs; returns the full report.
    ///
    /// With `--auto-tune on` the between-epoch controller
    /// ([`crate::tune::AutoTuner`]) consumes each epoch's barrier-measured
    /// metrics and retunes the runtime-safe knobs for the next epoch;
    /// `freeze` runs the controller observe-and-log only. Either way every
    /// decision is recorded in `EpochMetrics::tune`.
    pub fn run(&mut self) -> anyhow::Result<TrainReport> {
        let mut tuner = self.make_tuner();
        if let Some(state) = self.resume_tuner.take() {
            let t = tuner.as_mut().expect("resume_from validated the tuner mode");
            t.restore(&state)?;
            if t.mode() == AutoTuneMode::On {
                // re-apply the knobs in effect when the snapshot was
                // taken (the pending trial's, if one was mid-flight)
                self.apply_knobs(t.knobs());
            }
        }
        let mut epochs = Vec::new();
        for epoch in self.start_epoch..self.cfg.epochs {
            let mut m = self.run_epoch(epoch)?;
            if let Some(t) = tuner.as_mut() {
                let obs = EpochObservation {
                    wall_seconds: m.wall_seconds,
                    modeled_makespan_seconds: m.epoch_makespan_seconds,
                    prep_stall_seconds: m.prep_stall_seconds,
                    execute_stall_seconds: m.execute_stall_seconds,
                    beta: m.beta,
                    cache_hit_rate: m.cache_hit_rate,
                };
                let d = t.observe(epoch, &obs);
                if t.mode() == AutoTuneMode::On {
                    if d.action != "hold" {
                        crate::log_info!(
                            "auto-tune epoch {epoch}: {} ({}, score {:.4}s)",
                            d.action,
                            d.outcome,
                            d.score_s
                        );
                    }
                    self.apply_knobs(d.knobs);
                }
                m.tune = Some(d.to_json());
            }
            if let Some(dir) = self.cfg.checkpoint_dir.clone() {
                // snapshot after the tuner's decision so the restored
                // controller replays exactly the straight run's choices
                let t0 = Instant::now();
                let path = self.save_checkpoint(&dir, epoch + 1, tuner.as_ref())?;
                m.checkpoint_seconds = t0.elapsed().as_secs_f64();
                crate::log_info!("checkpoint: wrote {}", path.display());
            }
            crate::log_info!(
                "epoch {:>3}: loss {:.4} | {:.2}s | {} iters | NVTPS {} | beta {:.3} | hit {:.3} | dedup {} | {} stores re-ranked | makespan {} batches / {:.3}s modeled",
                epoch,
                m.mean_loss,
                m.wall_seconds,
                m.iterations,
                crate::util::stats::si(m.nvtps),
                m.beta,
                m.cache_hit_rate,
                crate::util::stats::si(m.dedup_saved_bytes as f64),
                m.stores_updated,
                m.epoch_makespan_batches,
                m.epoch_makespan_seconds
            );
            epochs.push(m);
        }
        Ok(TrainReport {
            config: self.cfg.to_json(),
            epochs,
            mean_shape: self.mean_shape(),
        })
    }

    /// Mean measured batch shape [v_0..v_L, a_1..a_L] over all batches so
    /// far (drives the analytic benches with real dedup statistics).
    pub fn mean_shape(&self) -> Vec<f64> {
        if self.shape_n == 0.0 {
            return vec![0.0; self.shape_acc.len()];
        }
        let mut s = self.shape_acc.clone();
        for x in s.iter_mut() {
            *x /= self.shape_n;
        }
        s
    }

    /// The §6.2 fleet workload for the current measured state: mean
    /// measured batch shape (nominal before epoch 0) and the
    /// policy-measured β. Shared by the scheduler cost model and the
    /// auto-tuner's modeled prior so both see the same platform.
    fn fleet_workload(&self, batches_per_part: Vec<usize>) -> Workload {
        let d = &self.entry.dims;
        let lcount = d.layers();
        let f: Vec<f64> = d.f.iter().map(|&x| x as f64).collect();
        let shape = if self.shape_n > 0.0 {
            let s = self.mean_shape();
            BatchShape { v: s[..=lcount].to_vec(), a: s[lcount + 1..].to_vec(), f }
        } else {
            let fanouts: Vec<f64> = d.fanouts.iter().map(|&k| k as f64).collect();
            BatchShape::nominal(d.b as f64, &fanouts, &f)
        };
        Workload {
            shape,
            beta: self.last_beta,
            cost: crate::fpga::timing::ModelCost::for_model(&self.cfg.model)
                .expect("model validated by TrainConfig"),
            sampling_s_per_batch: 0.0,
            batches_per_part,
            workload_balancing: self.cfg.workload_balancing,
            direct_host_fetch: self.cfg.direct_host_fetch,
            extra_pcie_bytes_per_batch: 0.0,
            prefetch: false,
            disk_gbs: if self.tier.is_some() { self.cfg.disk_gbs } else { 0.0 },
            disk_miss_frac: self.disk_miss_frac,
        }
    }

    /// The scheduler's per-device cost model for the *next* epoch:
    /// per-device §6.2 timing (`perf::FleetModel::cost_model` — the same
    /// function the DSE engine and `simulate` use) driven by the measured
    /// mean batch shape and the policy-measured β of the epochs run so
    /// far (nominal artifact shape and the paper's β before epoch 0).
    /// All inputs are barrier-measured, so the model — and therefore the
    /// planned schedule — is identical across pipeline configurations.
    pub fn fleet_cost(&self) -> CostModel {
        let w = self.fleet_workload(vec![0; self.cfg.num_fpgas]);
        FleetModel::new(self.cfg.device_fleet(), self.cfg.cpu_mem_gbs).cost_model(&w)
    }

    /// The auto-tuner's design-time prior: the scheduler mode the fleet's
    /// modeled cost prefers for this run's actual per-partition batch
    /// counts (the DSE design picked the fleet; this is the same §6.2
    /// model asking which stage-2 assignment suits it).
    pub fn tune_prior(&self) -> TunePrior {
        let b = self.entry.dims.b;
        let batches: Vec<usize> =
            self.pre.train_parts.iter().map(|p| p.len().div_ceil(b)).collect();
        let w = self.fleet_workload(batches);
        let fm = FleetModel::new(self.cfg.device_fleet(), self.cfg.cpu_mem_gbs);
        TunePrior { preferred_sched: fm.preferred_sched(&w) }
    }

    /// Build the between-epoch controller per `--auto-tune` (None = off).
    fn make_tuner(&self) -> Option<AutoTuner> {
        if self.cfg.auto_tune == AutoTuneMode::Off {
            return None;
        }
        let initial = Knobs {
            host_threads: self.cfg.host_threads.max(1),
            prefetch_depth: self.cfg.pipeline_depth(),
            sched: self.cfg.sched,
            cache_ratio: self.cfg.cache_ratio,
        };
        Some(
            AutoTuner::new(self.cfg.auto_tune, initial, self.cfg.cache_policy.is_dynamic())
                .with_prior(self.tune_prior()),
        )
    }

    /// Apply an accepted knob vector for the next epoch. `run_epoch`
    /// re-reads every knob per epoch: the sampler pool grows/shrinks with
    /// `host_threads`, the prefetch window with `prefetch_depth`, the
    /// scheduler with `sched`; a `cache_ratio` change retargets the live
    /// stores' capacity right here at the epoch boundary — the same
    /// barrier `end_epoch` re-snapshots at, so the next epoch's prep
    /// threads read one consistent residency version.
    fn apply_knobs(&mut self, k: Knobs) {
        self.cfg.host_threads = k.host_threads;
        self.cfg.prefetch_depth = k.prefetch_depth;
        // the knob owns the effective depth from here on
        self.cfg.prefetch = false;
        self.cfg.sched = k.sched;
        if (k.cache_ratio - self.cfg.cache_ratio).abs() > 1e-12 {
            self.cfg.cache_ratio = k.cache_ratio;
            let rows = ((self.data.graph.num_vertices() as f64) * k.cache_ratio).round() as usize;
            for s in self.pre.stores.iter_mut() {
                s.set_capacity(rows);
            }
        }
    }

    /// One epoch of synchronous training through the host pipeline.
    pub fn run_epoch(&mut self, epoch: usize) -> anyhow::Result<EpochMetrics> {
        let cfg = self.cfg.clone();
        let p = cfg.num_fpgas;
        let host_threads = cfg.host_threads.max(1);
        let depth = cfg.pipeline_depth();
        let t_epoch = Instant::now();

        // ---- planning stage (decoupled from preparation) ----------------
        let mut plan = EpochPlan::new(&self.pre.train_parts, self.entry.dims.b, &mut self.rng);
        let epoch_stream = self.rng.next_u64();
        let mut cost = self.fleet_cost();
        // straggler injection only re-prices the cost model: `--sched
        // cost` routes extras around the slow device, while the loss
        // sequence (a function of the partition stream alone) is
        // untouched
        for (d, c) in cost.batch_s.iter_mut().enumerate() {
            *c *= self.fault.slow_multiplier(d, epoch);
        }
        let mut sched =
            TwoStageScheduler::for_mode(p, cfg.workload_balancing, cfg.sched, Some(cost.clone()));
        // devices lost in earlier epochs stay dead
        for d in 0..p {
            if self.quarantined[d] {
                sched.quarantine(d)?;
            }
        }
        let mut remaining: Vec<usize> = (0..p).map(|i| plan.remaining(i)).collect();
        let mut iterations = prep::plan_epoch_tasks_with_faults(
            &mut sched,
            &mut plan,
            &mut remaining,
            cfg.max_iterations,
            &self.fault.failures_in_epoch(epoch),
        )?;
        let alive = sched.alive().to_vec();
        for (q, &a) in self.quarantined.iter_mut().zip(&alive) {
            *q = !a;
        }
        let sizes: Vec<usize> = iterations.iter().map(|t| t.len()).collect();
        let n_iters = iterations.len();

        // mark the iterations whose preparation must panic (`prep:panic`
        // anchors) — the harness for the coordinator's error-path drain
        for it in self.fault.prep_panics_in_epoch(epoch) {
            anyhow::ensure!(
                it < n_iters,
                "fault plan anchor e{epoch}i{it} is out of range: epoch {epoch} planned only \
                 {n_iters} iterations"
            );
            if let Some(t0) = iterations[it].first_mut() {
                t0.inject_panic = true;
            }
        }

        // scheduler observability: the planned epoch's makespan in batch
        // units and in modeled seconds, via the sched module's one
        // definition of both quantities
        let mut makespan_batches = 0usize;
        let mut makespan_seconds = 0.0f64;
        for tasks in &iterations {
            let plan = IterationPlan {
                tasks: tasks.iter().map(|t| Task { part: t.part, fpga: t.fpga }).collect(),
            };
            makespan_batches += plan.makespan_batches(p);
            makespan_seconds += plan.makespan_seconds(&cost);
        }

        let mut m = EpochMetrics {
            epoch,
            epoch_makespan_batches: makespan_batches,
            epoch_makespan_seconds: makespan_seconds,
            quarantined_devices: alive.iter().filter(|&&a| !a).count(),
            // batches whose home partition belongs to a dead device,
            // rerouted to a survivor at planning time (pre-failure
            // batches of that partition ran on their own device and are
            // not reassignments)
            reassigned_batches: iterations
                .iter()
                .flatten()
                .filter(|t| !alive[t.part] && t.fpga != t.part)
                .count(),
            ..Default::default()
        };
        if m.quarantined_devices > 0 {
            crate::log_info!(
                "fault: epoch {epoch} runs with {} quarantined device(s), {} batch(es) \
                 reassigned to survivors",
                m.quarantined_devices,
                m.reassigned_batches
            );
        }
        let mut loss_sum = 0.0f64;
        let mut traffic_total = crate::comm::Traffic::default();

        // epoch-versioned residency snapshot: prep threads read this
        // immutable copy for the whole epoch while the coordinator drives
        // the live stores' observe/end_epoch hooks at the barriers — the
        // determinism law survives dynamic cache policies by construction
        let snaps: Vec<Residency> = self.pre.residency_snapshot();
        let row_bytes = self.data.features.bytes_per_vertex();
        let mut dedup =
            if cfg.fetch_dedup { Some(IterDedup::new(self.data.graph.num_vertices())) } else { None };

        // ---- preparation pool + execution loop ---------------------------
        let (task_tx, task_rx) = mpsc::channel::<prep::PrepTask>();
        let (done_tx, done_rx) = mpsc::channel::<anyhow::Result<prep::PreparedBatch>>();
        let task_rx = Arc::new(Mutex::new(task_rx));
        // buffer recycling: the persistent carcass pool (see the field
        // docs) — `--no-pool` disables the return path (workers then
        // allocate fresh buffers per batch, the debug/ablation mode)
        let use_pool = cfg.buffer_pool;

        // per-thread samplers persist across epochs; grow the pool if the
        // configuration was raised after construction
        if self.samplers.len() < host_threads {
            let fanout = self.entry.dims.fanout_config();
            let n_vertices = self.data.graph.num_vertices();
            let mode = self.mode;
            self.samplers
                .resize_with(host_threads, || Sampler::new(fanout.clone(), mode, n_vertices, 0));
        }

        // disjoint field borrows for the scoped threads vs the coordinator
        let recycle_tx = &self.recycle_tx;
        let recycle_rx = &self.recycle_rx;
        let data = &self.data;
        let vertex_part = self.pre.vertex_part.as_deref();
        let stores = &mut self.pre.stores;
        let tier = &mut self.tier;
        let comm = CommConfig { direct_host_fetch: cfg.direct_host_fetch };
        let pool = &self.pool;
        let samplers = &mut self.samplers;
        let param_set = &mut self.params;
        let opt = &mut self.opt;
        let reducer = &mut self.reducer;
        let grad_pool = &mut self.grad_pool;
        let grad_scratch = &mut self.grad_scratch;
        let shape_acc = &mut self.shape_acc;
        let shape_n = &mut self.shape_n;
        let fault_plan = &self.fault;
        // runtime-safe knob: any thread count reduces in the same
        // per-element order (see GradReducer), so retuning is free
        reducer.set_threads(cfg.reduce_threads.max(1));

        std::thread::scope(|s| -> anyhow::Result<()> {
            for sampler in samplers.iter_mut().take(host_threads) {
                let task_rx = Arc::clone(&task_rx);
                let done_tx = done_tx.clone();
                let snaps = &snaps[..];
                let recycle = use_pool.then_some(recycle_rx);
                s.spawn(move || {
                    prep::prep_worker(
                        data,
                        snaps,
                        vertex_part,
                        sampler,
                        comm,
                        epoch_stream,
                        &task_rx,
                        &done_tx,
                        recycle,
                    )
                });
            }
            // coordinator keeps only the receiver: if every prep worker
            // dies, recv() errors instead of hanging
            drop(done_tx);

            // submitted-but-uncollected worker items: on an aborted epoch
            // these must be drained, or the next epoch's collect barrier
            // would receive this epoch's stale results (a poisoned pool)
            let mut inflight = 0usize;
            let result = (|| -> anyhow::Result<()> {
            let mut issued = 0usize;
            let mut buffered: BTreeMap<usize, Vec<prep::PreparedBatch>> = BTreeMap::new();
            for i in 0..n_iters {
                // bounded prefetch: release tasks for iterations < i + D
                while issued < n_iters && issued < i + depth {
                    for t in iterations[issued].drain(..) {
                        task_tx
                            .send(t)
                            .map_err(|_| anyhow::anyhow!("prep pool shut down early"))?;
                    }
                    issued += 1;
                }

                // reassemble iteration i (batches may arrive out of order);
                // time blocked here is the prep-stall the auto-tuner uses
                // to detect a preparation-bound pipeline
                let t1 = Instant::now();
                while buffered.get(&i).map_or(0, |v| v.len()) < sizes[i] {
                    let pb = done_rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("prep workers disconnected"))??;
                    buffered.entry(pb.iter).or_default().push(pb);
                }
                m.prep_stall_seconds += t1.elapsed().as_secs_f64();
                let mut items = buffered.remove(&i).unwrap_or_default();
                items.sort_by_key(|b| b.tag);

                // transient disk-error injection (`disk:eio@p`): each
                // batch's read is drawn from a stateless hash of its
                // logical position, retried with deterministic backoff,
                // fatal after DISK_RETRY_MAX attempts. Runs at the
                // barrier in (iter, tag) order so the same plan + seed
                // retries the same batches on any host.
                if fault_plan.disk_eio.is_some() {
                    for b in &items {
                        let mut attempt = 0u32;
                        while fault_plan.disk_error(cfg.seed, epoch, i, b.tag, attempt) {
                            attempt += 1;
                            m.disk_retries += 1;
                            anyhow::ensure!(
                                attempt < fault::DISK_RETRY_MAX,
                                "disk read failed {} times for epoch {epoch} iteration {i} \
                                 batch tag {} (--fault-plan disk:eio)",
                                fault::DISK_RETRY_MAX,
                                b.tag
                            );
                            std::thread::sleep(std::time::Duration::from_micros(
                                fault::retry_backoff_us(attempt),
                            ));
                        }
                    }
                }

                // iteration-scoped barrier pass, in (iter, tag) order:
                // fetch dedup against the epoch snapshot, then feed the
                // access stream to the cache policy's observe hook
                if let Some(dd) = dedup.as_mut() {
                    dd.next_iteration();
                    for b in items.iter_mut() {
                        let (mb, traffic) = (&b.mb, &mut b.stats.traffic);
                        dd.apply(
                            mb.level0(),
                            &snaps[b.fpga],
                            row_bytes,
                            comm,
                            vertex_part,
                            b.fpga,
                            traffic,
                        );
                    }
                }
                // DRAM-tier accounting, same (iter, tag) order: every
                // FPGA-store miss lands on the host — split it into DRAM
                // hits and disk reads against this epoch's immutable tier
                // membership, then feed the access stream to the tier's
                // own policy (re-ranked only at the epoch barrier)
                if let Some(tier) = tier.as_mut() {
                    for b in items.iter_mut() {
                        tier.charge(b.mb.level0(), &snaps[b.fpga], row_bytes, &mut b.stats.traffic);
                        tier.observe(b.mb.level0());
                    }
                }
                for b in &items {
                    stores[b.fpga].observe(b.mb.level0());
                }

                // merge host-side stats in deterministic (iter, tag) order
                for b in &items {
                    let st = &b.stats;
                    m.sample_seconds += st.sample_seconds;
                    m.gather_seconds += st.gather_seconds;
                    m.vertices_traversed += st.vertices_traversed;
                    traffic_total += st.traffic;
                    m.batches += 1;
                    for (acc, v) in shape_acc.iter_mut().zip(st.shape.iter()) {
                        *acc += *v;
                    }
                    *shape_n += 1.0;
                }

                // dispatch and wait at the gradient-sync barrier; the
                // sampled blocks stay behind (tag order) so their buffers
                // can be recycled once the workers hand the input
                // carcasses back
                let params = Arc::new(param_set.data.clone());
                let submitted = items.len();
                let mut sampled: Vec<(usize, crate::sampling::MiniBatch)> =
                    Vec::with_capacity(submitted);
                for b in items {
                    sampled.push((b.tag, b.mb));
                    // each work item carries a recycled gradient carcass
                    // (empty on a cold pool — the worker sizes it once)
                    let grads = grad_pool.pop().unwrap_or_default();
                    pool.submit(
                        b.fpga,
                        WorkItem { params: params.clone(), batch: b.batch, grads, tag: b.tag },
                    )?;
                    inflight += 1;
                }
                let t2 = Instant::now();
                let mut results = pool.collect(submitted)?;
                inflight -= results.len();
                // time blocked at the collect barrier (execute-stall;
                // sync_seconds below starts a fresh timer, so the two
                // stages are disjoint — no double counting)
                m.execute_stall_seconds += t2.elapsed().as_secs_f64();
                // reduce in tag order regardless of worker arrival order
                results.sort_by_key(|r| r.tag);
                grad_scratch.clear();
                let mut iter_loss = 0.0f64;
                for (r, (tag, mb)) in results.into_iter().zip(sampled) {
                    debug_assert_eq!(r.tag, tag, "carcass pairing out of order");
                    let out = r.result?;
                    m.execute_seconds += r.exec_seconds;
                    iter_loss += out.loss as f64;
                    m.final_loss = out.loss as f64;
                    grad_scratch.push(out.grads);
                    if use_pool {
                        // return the consumed buffers to the prep pool
                        let _ = recycle_tx.send(prep::BatchCarcass { mb, bufs: r.batch });
                    }
                }
                loss_sum += iter_loss;
                m.iter_losses.push(iter_loss / submitted.max(1) as f64);
                // gradient sync: in-place parallel sum + fused scale/
                // momentum/update — bit-identical to the retired serial
                // average_grads + step (the params tests pin this)
                let t3 = Instant::now();
                if !grad_scratch.is_empty() {
                    reducer.reduce(grad_scratch);
                    opt.step_fused(param_set, reducer.acc(), grad_scratch.len());
                }
                m.sync_seconds += t3.elapsed().as_secs_f64();
                if use_pool {
                    // consumed gradient carcasses ride back to the workers
                    grad_pool.append(grad_scratch);
                } else {
                    grad_scratch.clear();
                }
                m.iterations += 1;
            }
            Ok(())
            })();
            // closing the task channel winds the prep pool down — on the
            // success path and the abort path alike
            drop(task_tx);
            if result.is_err() {
                // mid-epoch abort (injected prep panic, worker error,
                // exhausted disk retries): drain the prep channel until
                // every worker has exited and swallow any in-flight
                // execution results, so the pool the trainer keeps for
                // the next epoch (or shutdown) is clean — no hang, no
                // stale results, no leaked carcasses
                while done_rx.recv().is_ok() {}
                pool.drain(inflight);
            }
            result
        })?;

        // epoch barrier: dynamic policies re-rank their resident sets —
        // versioning the snapshot the *next* epoch's prep threads will read
        let mut stores_updated = 0usize;
        for s in stores.iter_mut() {
            if s.end_epoch() {
                stores_updated += 1;
            }
        }
        if let Some(t) = tier.as_mut() {
            if t.end_epoch() {
                stores_updated += 1;
            }
        }

        m.wall_seconds = t_epoch.elapsed().as_secs_f64();
        m.mean_loss = loss_sum / m.batches.max(1) as f64;
        m.nvtps = m.vertices_traversed as f64 / m.wall_seconds;
        m.local_bytes = traffic_total.local_bytes;
        m.host_bytes = traffic_total.host_bytes;
        m.f2f_bytes = traffic_total.f2f_bytes;
        m.dedup_saved_bytes = traffic_total.dedup_saved_bytes;
        m.dram_hit_bytes = traffic_total.dram_hit_bytes;
        m.disk_read_bytes = traffic_total.disk_read_bytes;
        m.beta = traffic_total.beta();
        m.cache_hit_rate = traffic_total.hit_rate();
        m.stores_updated = stores_updated;
        if m.batches > 0 {
            // feed the measured β into the next epoch's cost model
            self.last_beta = m.beta;
        }
        let missed = traffic_total.missed_bytes();
        if self.tier.is_some() && missed > 0 {
            // measured disk share of miss traffic for the next epoch's
            // cost model (replaces the cold-start 1−dram_ratio estimate)
            self.disk_miss_frac = traffic_total.disk_read_bytes as f64 / missed as f64;
        }
        Ok(m)
    }

    /// Evaluate prediction accuracy on up to `n_batches` fresh batches
    /// (uses the cached predict artifact on the coordinator thread).
    pub fn evaluate(&mut self, n_batches: usize) -> anyhow::Result<f64> {
        if self.predict_exe.is_none() {
            let pentry = self.predict_entry.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "no predict artifact for model={} dataset={}",
                    self.cfg.model,
                    self.cfg.dataset
                )
            })?;
            self.predict_exe = Some(TrainExecutor::compile(pentry)?);
        }
        let exe = self.predict_exe.as_mut().expect("compiled above");
        let comm = CommConfig { direct_host_fetch: self.cfg.direct_host_fetch };
        // reusable service + sampler, hoisted out of the batch loop
        let svc = FeatureService::new(&self.data.features, comm);
        let f0 = self.data.features.feat_dim();
        let f2 = self.entry.dims.classes();
        let b = self.entry.dims.b;
        let mut plan = EpochPlan::new(&self.pre.train_parts, b, &mut self.rng);
        let eval_stream = self.rng.next_u64();
        let sampler = &mut self.samplers[0];
        sampler.set_stream(eval_stream);

        let mut correct = 0usize;
        let mut total = 0usize;
        for i in 0..n_batches {
            let part = i % self.cfg.num_fpgas;
            let Some((seq, targets)) = plan.next_targets_seq(part).map(|(s, t)| (s, t.to_vec()))
            else {
                break;
            };
            let mb = sampler.sample(&self.data, &targets, part, seq);
            let (feat0, _) = svc.gather(
                &mb,
                self.pre.stores[part].as_ref(),
                self.pre.vertex_part.as_deref(),
                part,
            );
            let batch = BatchBuffers::from_minibatch(&mb, feat0, f0);
            let logits = exe.predict(&self.params.data, &batch)?;
            for r in 0..mb.n_targets() {
                let row = &logits[r * f2..(r + 1) * f2];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as u32 == mb.labels[r] {
                    correct += 1;
                }
                total += 1;
            }
        }
        anyhow::ensure!(total > 0, "no evaluation targets");
        Ok(correct as f64 / total as f64)
    }

    /// Shut down the worker pool explicitly (also happens on drop).
    pub fn shutdown(self) {
        self.pool.shutdown();
    }

    /// Canonical host-pipeline micro-benchmark: wall seconds of one full
    /// training epoch on the bundled synthetic dataset at 4 simulated
    /// FPGAs (epoch 0 warms up, epoch 1 is measured; fresh trainer per
    /// call so worker-pool spawn stays excluded). Shared by
    /// `benches/micro_host.rs` and `examples/scalability.rs` so the
    /// pipeline acceptance numbers are measured exactly one way.
    pub fn pipeline_bench_epoch_wall(
        host_threads: usize,
        prefetch_depth: usize,
    ) -> anyhow::Result<f64> {
        let cfg = TrainConfig {
            dataset: "tiny".into(),
            model: "gcn".into(),
            algo: crate::partition::Algorithm::DistDgl,
            num_fpgas: 4,
            epochs: 2,
            scale_shift: 0,
            seed: 11,
            host_threads,
            prefetch_depth,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        let report = trainer.run()?;
        let wall = report.epochs.last().map(|e| e.wall_seconds).unwrap_or(f64::NAN);
        trainer.shutdown();
        Ok(wall)
    }
}
