//! The training driver: preprocessing → epochs of (sample → gather →
//! dispatch → gradient sync → weight update), with full measurement.

use std::sync::Arc;
use std::time::Instant;

use super::config::TrainConfig;
use super::metrics::{EpochMetrics, TrainReport};
use super::params::{average_grads, ParamSet, Sgd};
use super::worker::{WorkItem, WorkerPool};
use crate::comm::{CommConfig, FeatureService};
use crate::graph::{datasets, Dataset};
use crate::partition::{preprocess, Preprocessed};
use crate::runtime::{ArtifactEntry, BatchBuffers, Manifest, TrainExecutor};
use crate::sampling::{EpochPlan, MiniBatch, Sampler, WeightMode};
use crate::sched::TwoStageScheduler;
use crate::util::rng::Rng;

/// Everything needed to train; build with [`Trainer::new`], run with
/// [`Trainer::run`].
pub struct Trainer {
    pub cfg: TrainConfig,
    pub data: Dataset,
    pub pre: Preprocessed,
    entry: ArtifactEntry,
    pool: WorkerPool,
    pub params: ParamSet,
    opt: Sgd,
    samplers: Vec<Sampler>,
    rng: Rng,
    /// Accumulated mean batch shape [v0, v1, v2, a1, a2].
    shape_acc: [f64; 5],
    shape_n: f64,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> anyhow::Result<Trainer> {
        let spec = datasets::lookup(&cfg.dataset)?;
        let data = spec.build(cfg.scale_shift, cfg.seed);
        crate::log_info!("dataset: {}", data.summary());

        let pre = preprocess(cfg.algo, &data, cfg.num_fpgas, cfg.cache_ratio, cfg.seed);
        crate::log_info!(
            "preprocessed with {}: imbalance={:.3} edge_cut={:?}",
            cfg.algo.name(),
            pre.train_imbalance(),
            pre.edge_cut(&data.graph).map(|c| (c * 1000.0).round() / 1000.0)
        );

        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let entry = manifest.find("train", &cfg.model, &cfg.dataset)?.clone();
        anyhow::ensure!(
            entry.dims.f0 == data.spec.dims.f0,
            "artifact f0 {} != dataset f0 {}",
            entry.dims.f0,
            data.spec.dims.f0
        );

        let pool = WorkerPool::spawn(&entry, cfg.num_fpgas)?;
        let params = ParamSet::init(&entry, cfg.seed);
        let opt = Sgd::new(cfg.lr, cfg.momentum, &params);

        let mode = WeightMode::for_model(&cfg.model)?;
        let fanout = entry.dims.fanout_config();
        let mut rng = Rng::new(cfg.seed ^ 0x7a11);
        let samplers = (0..cfg.num_fpgas)
            .map(|i| {
                Sampler::new(fanout, mode, data.graph.num_vertices(), rng.fork(i as u64).next_u64())
            })
            .collect();

        Ok(Trainer {
            cfg,
            data,
            pre,
            entry,
            pool,
            params,
            opt,
            samplers,
            rng,
            shape_acc: [0.0; 5],
            shape_n: 0.0,
        })
    }

    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Run the configured number of epochs; returns the full report.
    pub fn run(&mut self) -> anyhow::Result<TrainReport> {
        let mut epochs = Vec::new();
        for epoch in 0..self.cfg.epochs {
            let m = self.run_epoch(epoch)?;
            crate::log_info!(
                "epoch {:>3}: loss {:.4} | {:.2}s | {} iters | NVTPS {} | beta {:.3}",
                epoch,
                m.mean_loss,
                m.wall_seconds,
                m.iterations,
                crate::util::stats::si(m.nvtps),
                m.beta
            );
            epochs.push(m);
        }
        Ok(TrainReport {
            config: self.cfg.to_json(),
            epochs,
            mean_shape: self.mean_shape(),
        })
    }

    /// Mean measured batch shape [v0, v1, v2, a1, a2] over all batches so
    /// far (drives the analytic benches with real dedup statistics).
    pub fn mean_shape(&self) -> [f64; 5] {
        if self.shape_n == 0.0 {
            return [0.0; 5];
        }
        let mut s = self.shape_acc;
        for x in s.iter_mut() {
            *x /= self.shape_n;
        }
        s
    }

    fn record_shape(&mut self, mb: &MiniBatch) {
        self.shape_acc[0] += mb.n_v0 as f64;
        self.shape_acc[1] += mb.n_v1 as f64;
        self.shape_acc[2] += mb.n_targets as f64;
        self.shape_acc[3] += mb.edges_layer1() as f64;
        self.shape_acc[4] += mb.edges_layer2() as f64;
        self.shape_n += 1.0;
    }

    /// Sample + gather every task of one iteration plan (the host-side
    /// batch preparation; does not touch `self.params`, so with
    /// prefetching it can run while the workers execute the previous
    /// iteration).
    fn prepare_iteration(
        &mut self,
        iter_plan: &crate::sched::IterationPlan,
        plan: &mut EpochPlan,
        remaining: &mut [usize],
        m: &mut EpochMetrics,
    ) -> anyhow::Result<Vec<(usize, usize, BatchBuffers)>> {
        let comm = CommConfig { direct_host_fetch: self.cfg.direct_host_fetch };
        let f0 = self.data.features.feat_dim();
        let mut items = Vec::with_capacity(iter_plan.tasks.len());
        for (tag, task) in iter_plan.tasks.iter().enumerate() {
            remaining[task.part] -= 1;
            let t0 = Instant::now();
            let targets = plan
                .next_targets(task.part)
                .ok_or_else(|| anyhow::anyhow!("partition {} exhausted early", task.part))?
                .to_vec();
            let mb = self.samplers[task.part].sample(&self.data, &targets, task.part, tag);
            m.sample_seconds += t0.elapsed().as_secs_f64();
            self.record_shape(&mb);
            m.vertices_traversed += mb.vertices_traversed() as u64;
            m.batches += 1;

            // host feature service: gather + traffic accounting against
            // the *executing* FPGA's store
            let t1 = Instant::now();
            let svc = FeatureService::new(&self.data.features, comm);
            let (feat0, traffic) = svc.gather(
                &mb,
                &self.pre.stores[task.fpga],
                self.pre.vertex_part.as_deref(),
                task.fpga,
            );
            m.gather_seconds += t1.elapsed().as_secs_f64();
            m.local_bytes += traffic.local_bytes;
            m.host_bytes += traffic.host_bytes;
            m.f2f_bytes += traffic.f2f_bytes;

            items.push((task.fpga, tag, BatchBuffers::from_minibatch(&mb, feat0, f0)));
        }
        Ok(items)
    }

    /// One epoch of synchronous training. With `cfg.prefetch` the next
    /// iteration's batches are prepared while the workers execute the
    /// current one (§8 future-work extension; `--prefetch` on the CLI).
    pub fn run_epoch(&mut self, epoch: usize) -> anyhow::Result<EpochMetrics> {
        let cfg = self.cfg.clone();
        let p = cfg.num_fpgas;
        let t_epoch = Instant::now();

        let mut plan = EpochPlan::new(
            &self.pre.train_parts,
            self.entry.dims.b,
            &mut self.rng,
        );
        let mut sched = TwoStageScheduler::new(p, cfg.workload_balancing);

        let mut m = EpochMetrics { epoch, ..Default::default() };
        let mut loss_sum = 0.0f64;
        let mut remaining: Vec<usize> = (0..p).map(|i| plan.remaining(i)).collect();

        // prepare the first iteration
        let mut next_prepared = {
            match sched.plan_iteration(&remaining) {
                Some(ip) => {
                    let items = self.prepare_iteration(&ip, &mut plan, &mut remaining, &mut m)?;
                    Some(items)
                }
                None => None,
            }
        };

        while let Some(items) = next_prepared.take() {
            if let Some(maxit) = cfg.max_iterations {
                if m.iterations >= maxit {
                    break;
                }
            }
            let params = Arc::new(self.params.data.clone());
            let submitted = items.len();
            for (fpga, tag, batch) in items {
                self.pool.submit(fpga, WorkItem { params: params.clone(), batch, tag })?;
            }

            // prefetch: prepare iteration i+1 while the workers execute i
            // (skip when the iteration cap would discard the prepared work)
            let next_allowed = cfg.max_iterations.map_or(true, |mx| m.iterations + 1 < mx);
            if cfg.prefetch && next_allowed {
                if let Some(ip) = sched.plan_iteration(&remaining) {
                    next_prepared =
                        Some(self.prepare_iteration(&ip, &mut plan, &mut remaining, &mut m)?);
                }
            }

            // gradient synchronisation barrier
            let t2 = Instant::now();
            let results = self.pool.collect(submitted)?;
            let mut grads = Vec::with_capacity(submitted);
            for r in results {
                let out = r.result?;
                m.execute_seconds += r.exec_seconds;
                loss_sum += out.loss as f64;
                m.final_loss = out.loss as f64;
                grads.push(out.grads);
            }
            let avg = average_grads(&grads);
            self.opt.step(&mut self.params, &avg);
            m.sync_seconds += t2.elapsed().as_secs_f64();
            m.iterations += 1;

            // non-prefetch path: prepare the next iteration after the sync
            // (same iteration-cap guard so capped runs don't count
            // prepared-but-never-executed batches in the metrics)
            let next_allowed = cfg.max_iterations.map_or(true, |mx| m.iterations < mx);
            if !cfg.prefetch && next_allowed {
                if let Some(ip) = sched.plan_iteration(&remaining) {
                    next_prepared =
                        Some(self.prepare_iteration(&ip, &mut plan, &mut remaining, &mut m)?);
                }
            }
        }

        m.wall_seconds = t_epoch.elapsed().as_secs_f64();
        m.mean_loss = loss_sum / m.batches.max(1) as f64;
        m.nvtps = m.vertices_traversed as f64 / m.wall_seconds;
        let total = (m.local_bytes + m.host_bytes + m.f2f_bytes) as f64;
        m.beta = if total > 0.0 { m.local_bytes as f64 / total } else { 1.0 };
        Ok(m)
    }

    /// Evaluate prediction accuracy on up to `n_batches` fresh batches
    /// (uses the predict artifact on the coordinator thread).
    pub fn evaluate(&mut self, n_batches: usize) -> anyhow::Result<f64> {
        let manifest = Manifest::load(&self.cfg.artifacts_dir)?;
        let pentry = manifest.find("predict", &self.cfg.model, &self.cfg.dataset)?;
        let exe = TrainExecutor::compile(pentry)?;
        let comm = CommConfig { direct_host_fetch: self.cfg.direct_host_fetch };
        let f0 = self.data.features.feat_dim();
        let f2 = self.entry.dims.f2;
        let b = self.entry.dims.b;

        let mut correct = 0usize;
        let mut total = 0usize;
        let mut plan =
            EpochPlan::new(&self.pre.train_parts, b, &mut self.rng);
        for i in 0..n_batches {
            let part = i % self.cfg.num_fpgas;
            let Some(targets) = plan.next_targets(part).map(|t| t.to_vec()) else {
                break;
            };
            let mb = self.samplers[part].sample(&self.data, &targets, part, i);
            let svc = FeatureService::new(&self.data.features, comm);
            let (feat0, _) =
                svc.gather(&mb, &self.pre.stores[part], self.pre.vertex_part.as_deref(), part);
            let batch = BatchBuffers::from_minibatch(&mb, feat0, f0);
            let logits = exe.predict(&self.params.data, &batch)?;
            for r in 0..mb.n_targets {
                let row = &logits[r * f2..(r + 1) * f2];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as u32 == mb.labels[r] {
                    correct += 1;
                }
                total += 1;
            }
        }
        anyhow::ensure!(total > 0, "no evaluation targets");
        Ok(correct as f64 / total as f64)
    }

    /// Shut down the worker pool explicitly (also happens on drop).
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}
