//! Per-FPGA worker threads.
//!
//! Each simulated FPGA is a thread that owns its own PJRT client and
//! compiled executable (the xla handles are not `Send`), receives work
//! over an mpsc channel, and returns (loss, gradients) to the
//! coordinator. This mirrors the paper's runtime system: the host enqueues
//! a mini-batch per FPGA per iteration and waits at the gradient-sync
//! barrier.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::runtime::{ArtifactEntry, BatchBuffers, GradBuffers, StepOutput, TrainExecutor};

/// One unit of work for a worker.
pub struct WorkItem {
    /// Current parameters (shared snapshot — the "broadcast" of §4.2).
    pub params: Arc<Vec<Vec<f32>>>,
    pub batch: BatchBuffers,
    /// Recycled gradient buffers the step writes into (the gradient-side
    /// carcass pool, mirroring `batch` — DESIGN.md §SIMD dispatch &
    /// gradient sync). `GradBuffers::empty()` on first use.
    pub grads: GradBuffers,
    /// Coordinator-side correlation tag (iteration-local task index).
    pub tag: usize,
}

/// A worker's reply.
pub struct WorkResult {
    pub worker: usize,
    pub tag: usize,
    pub result: anyhow::Result<crate::runtime::StepOutput>,
    /// Pure execute wall time (excludes queueing).
    pub exec_seconds: f64,
    /// The consumed input buffers, returned so the coordinator can send
    /// the carcass back to the prep pool (DESIGN.md §Hot-path memory &
    /// kernels) instead of paying an allocate/free per batch.
    pub batch: BatchBuffers,
}

enum Msg {
    Work(WorkItem),
    Stop,
}

/// Pool of `p` simulated-FPGA workers.
pub struct WorkerPool {
    txs: Vec<mpsc::Sender<Msg>>,
    rx: mpsc::Receiver<WorkResult>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `p` workers, each compiling `entry` on its own PJRT client.
    /// Blocks until every worker has finished compiling (so that training
    /// time does not include compilation).
    pub fn spawn(entry: &ArtifactEntry, p: usize) -> anyhow::Result<WorkerPool> {
        let (result_tx, rx) = mpsc::channel::<WorkResult>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let mut txs = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for worker in 0..p {
            let (tx, work_rx) = mpsc::channel::<Msg>();
            txs.push(tx);
            let entry = entry.clone();
            let result_tx = result_tx.clone();
            let ready_tx = ready_tx.clone();
            handles.push(std::thread::spawn(move || {
                let mut exe = match TrainExecutor::compile(&entry) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(Msg::Work(mut item)) = work_rx.recv() {
                    let t0 = std::time::Instant::now();
                    let result = exe
                        .train_step_into(&item.params, &item.batch, &mut item.grads)
                        .map(|loss| StepOutput { loss, grads: std::mem::take(&mut item.grads) });
                    let _ = result_tx.send(WorkResult {
                        worker,
                        tag: item.tag,
                        result,
                        exec_seconds: t0.elapsed().as_secs_f64(),
                        batch: item.batch,
                    });
                }
            }));
        }
        // wait for all compiles
        for _ in 0..p {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker died during compile"))??;
        }
        Ok(WorkerPool { txs, rx, handles })
    }

    pub fn num_workers(&self) -> usize {
        self.txs.len()
    }

    /// Enqueue a batch on worker `fpga`.
    pub fn submit(&self, fpga: usize, item: WorkItem) -> anyhow::Result<()> {
        self.txs[fpga]
            .send(Msg::Work(item))
            .map_err(|_| anyhow::anyhow!("worker {fpga} channel closed"))
    }

    /// Collect exactly `n` results (barrier — gradient synchronisation).
    pub fn collect(&self, n: usize) -> anyhow::Result<Vec<WorkResult>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(
                self.rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("all workers disconnected"))?,
            );
        }
        Ok(out)
    }

    /// Best-effort barrier for the error path: receive and discard up to
    /// `n` outstanding results so an aborted epoch never leaves in-flight
    /// work queued against a pool that the next epoch (or the caller's
    /// shutdown) will reuse. Unlike [`WorkerPool::collect`] this ignores
    /// per-item errors and tolerates dead workers — it must never mask
    /// the error that triggered the abort.
    pub fn drain(&self, n: usize) {
        for _ in 0..n {
            if self.rx.recv().is_err() {
                break;
            }
        }
    }

    /// Stop all workers and join.
    pub fn shutdown(mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Stop);
        }
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
