//! Hardware design-space exploration — §6.3, Algorithm 4.
//!
//! For each die of each FPGA the engine constructs the search space
//! (n_max, m_max from the §6.1 resource model), sweeps every feasible
//! (n, m) exhaustively, evaluates the training throughput of each point
//! with the §6.2 performance model averaged over the input workloads, and
//! keeps the argmax. All dies of a U250 are identical, so one sweep per
//! FPGA suffices (the code still exposes the per-die loop for platforms
//! with heterogeneous dies).

use crate::fpga::timing::{BatchShape, ModelCost};
use crate::fpga::{DeviceSpec, DieConfig, ResourceModel, Utilization};
use crate::perf::{FleetModel, PlatformModel, PlatformSpec, Workload};
use crate::sched::SchedMode;

/// One evaluated design point.
#[derive(Clone, Copy, Debug)]
pub struct DesignPoint {
    pub die: DieConfig,
    /// FPGA-level parallelism (die config × number of dies) — the paper
    /// reports these totals, e.g. (8, 2048) on a 4-die U250.
    pub n_fpga: u32,
    pub m_fpga: u32,
    pub utilization: Utilization,
    /// Average NVTPS across the evaluation workloads.
    pub throughput: f64,
}

/// DSE result: the optimum plus the full swept grid (Fig. 7 needs it).
#[derive(Clone, Debug)]
pub struct DseResult {
    pub best: DesignPoint,
    pub grid: Vec<DesignPoint>,
    pub n_max: u32,
    pub m_max: u32,
}

/// Evaluation workload for the DSE engine: mini-batch configuration and
/// GNN dimensions (§6: "takes the configuration of a mini-batch, GNN
/// hidden dimensions, and platform metadata as input").
#[derive(Clone, Debug)]
pub struct DseWorkload {
    pub shape: BatchShape,
    /// Local-fetch ratio β (Eq. 7). `api::generate_design` feeds the
    /// steady-state per-epoch value measured under the configured
    /// feature-store policy (`perf::experiments::measure_host_policy`);
    /// the canned paper workloads use the paper's nominal 0.75.
    pub beta: f64,
    /// Model-dependent cost terms ([`ModelCost::for_model`]) — makes the
    /// swept throughput sensitive to the GNN architecture (attention adds
    /// an edge-proportional stage the update/aggregate overlap can't hide).
    pub cost: ModelCost,
    pub sampling_s_per_batch: f64,
    /// Disk bandwidth feeding the host-DRAM tier (GB/s); 0 = the dataset
    /// is DRAM-resident and the swept designs pay no disk term.
    pub disk_gbs: f64,
    /// Fraction of feature-miss bytes falling through DRAM to disk
    /// (`--dram-ratio` cold-start is `1 - ratio`; measured thereafter).
    pub disk_miss_frac: f64,
}

impl DseWorkload {
    fn to_workload(&self, p: usize, batches: usize) -> Workload {
        Workload {
            shape: self.shape.clone(),
            beta: self.beta,
            cost: self.cost,
            sampling_s_per_batch: self.sampling_s_per_batch,
            batches_per_part: vec![batches; p],
            workload_balancing: true,
            direct_host_fetch: true,
            extra_pcie_bytes_per_batch: 0.0,
            prefetch: false,
            disk_gbs: self.disk_gbs,
            disk_miss_frac: self.disk_miss_frac,
        }
    }
}

/// The DSE engine.
pub struct DseEngine {
    pub platform: PlatformSpec,
    pub resources: ResourceModel,
    /// m is swept in steps of this size (the update kernel is generated
    /// in power-of-two PE groups; sweeping every integer m wastes time on
    /// indistinguishable designs). 1 = fully exhaustive.
    pub m_step: u32,
}

impl DseEngine {
    pub fn new(platform: PlatformSpec) -> DseEngine {
        DseEngine { platform, resources: ResourceModel::new(platform.fpga), m_step: 16 }
    }

    /// Throughput of one die configuration, averaged over the workloads
    /// (the paper's Fig. 7 averages the four datasets).
    pub fn throughput(&self, die: DieConfig, workloads: &[DseWorkload]) -> f64 {
        let model = PlatformModel::new(self.platform, die);
        let p = self.platform.num_fpgas;
        let mut sum = 0.0;
        for w in workloads {
            // steady-state epoch: balanced partitions, enough batches that
            // edge effects vanish
            let est = model.epoch(&w.to_workload(p, 32));
            sum += est.nvtps;
        }
        sum / workloads.len() as f64
    }

    /// Algorithm 4: exhaustive sweep over the feasible (n, m) grid.
    pub fn explore(&self, workloads: &[DseWorkload]) -> anyhow::Result<DseResult> {
        anyhow::ensure!(!workloads.is_empty(), "DSE needs at least one workload");
        let n_max = self.resources.n_max();
        let m_max = self.resources.m_max();
        let dies = self.platform.fpga.dies as u32;

        let mut grid = Vec::new();
        let mut best: Option<DesignPoint> = None;
        for n in 1..=n_max {
            let mut m = self.m_step;
            while m <= m_max {
                let die = DieConfig { n, m };
                if self.resources.check(die) {
                    let point = DesignPoint {
                        die,
                        n_fpga: n * dies,
                        m_fpga: m * dies,
                        utilization: self.resources.utilization(die),
                        throughput: self.throughput(die, workloads),
                    };
                    let improved = match &best {
                        Some(b) => point.throughput > b.throughput,
                        None => true,
                    };
                    if improved {
                        best = Some(point);
                    }
                    grid.push(point);
                }
                m += self.m_step;
            }
        }
        let best = best.ok_or_else(|| anyhow::anyhow!("no feasible design point"))?;
        Ok(DseResult { best, grid, n_max, m_max })
    }

    /// Evaluate a specific FPGA-level (n, m) — Table 5's comparison rows.
    pub fn evaluate_fpga_config(
        &self,
        n_fpga: u32,
        m_fpga: u32,
        workloads: &[DseWorkload],
    ) -> anyhow::Result<DesignPoint> {
        let dies = self.platform.fpga.dies as u32;
        anyhow::ensure!(
            n_fpga % dies == 0 && m_fpga % dies == 0,
            "FPGA-level config ({n_fpga},{m_fpga}) must divide across {dies} dies"
        );
        let die = DieConfig { n: n_fpga / dies, m: m_fpga / dies };
        anyhow::ensure!(
            self.resources.check(die),
            "config ({n_fpga},{m_fpga}) infeasible per die: {:?}",
            self.resources.utilization(die)
        );
        Ok(DesignPoint {
            die,
            n_fpga,
            m_fpga,
            utilization: self.resources.utilization(die),
            throughput: self.throughput(die, workloads),
        })
    }
}

/// DSE result for a heterogeneous fleet.
#[derive(Clone, Debug)]
pub struct FleetDseResult {
    /// The input fleet with each device's die set to its kind's optimum.
    pub devices: Vec<DeviceSpec>,
    /// Chosen die + utilization per distinct device kind, in
    /// first-appearance order.
    pub per_kind: Vec<(String, DieConfig, Utilization)>,
    /// Average fleet NVTPS at the chosen dies under cost-aware WB.
    pub throughput: f64,
    /// Scheduler mode the fleet-level §6.2 model prefers at the chosen
    /// dies (lower mean modeled makespan across the workloads; Cost on
    /// ties). Seeds the online auto-tuner's prior so it skips the sched
    /// flip the model already rules out (`Trainer::tune_prior`).
    pub preferred_sched: SchedMode,
}

impl DseEngine {
    /// Algorithm 4 generalised to a heterogeneous fleet: each distinct
    /// device kind gets its own §6.1 resource model and exhaustive
    /// (n, m) sweep, but every candidate is scored with the *fleet-level*
    /// cost model (`perf::FleetModel`, cost-aware scheduling) — the same
    /// per-device timing the trainer's scheduler uses — so a slow device
    /// weighs on the estimate exactly as it does at training time. Kinds
    /// are optimised by one greedy coordinate-descent pass in
    /// first-appearance order (deterministic; each kind's feasible set is
    /// independent of the other kinds' choices, only the score couples).
    pub fn explore_fleet(
        fleet: &[DeviceSpec],
        cpu_mem_gbs: f64,
        workloads: &[DseWorkload],
        m_step: u32,
    ) -> anyhow::Result<FleetDseResult> {
        anyhow::ensure!(!fleet.is_empty(), "fleet DSE needs at least one device");
        anyhow::ensure!(!workloads.is_empty(), "DSE needs at least one workload");
        anyhow::ensure!(m_step >= 1, "m_step must be >= 1");
        let p = fleet.len();
        let mut devices = fleet.to_vec();
        let eval = |devs: &[DeviceSpec]| -> f64 {
            let fm = FleetModel::new(devs.to_vec(), cpu_mem_gbs);
            let mut sum = 0.0;
            for w in workloads {
                sum += fm.epoch(&w.to_workload(p, 32), SchedMode::Cost).nvtps;
            }
            sum / workloads.len() as f64
        };

        let mut kinds: Vec<&'static str> = Vec::new();
        for d in &devices {
            if !kinds.contains(&d.kind) {
                kinds.push(d.kind);
            }
        }
        // standalone per-batch seconds of one device of this kind at a
        // candidate die, averaged over the workloads — the tie-breaker
        // below (fleet NVTPS plateaus once another kind is the
        // bottleneck in the balanced scoring epoch, but a faster die
        // still matters at training time when stage-2 extras stack on
        // fast devices)
        let solo_s = |proto: &DeviceSpec, die: DieConfig| -> f64 {
            let share = cpu_mem_gbs / p as f64;
            workloads
                .iter()
                .map(|w| {
                    crate::perf::device_batch_gnn_s(
                        proto.fpga,
                        die,
                        proto.pcie_gbs,
                        share,
                        cpu_mem_gbs,
                        &w.to_workload(p, 32),
                    )
                })
                .sum::<f64>()
                / workloads.len() as f64
        };

        let mut per_kind = Vec::new();
        for kind in kinds {
            let proto = devices.iter().find(|d| d.kind == kind).copied().expect("kind from fleet");
            let resources = ResourceModel::new(proto.fpga);
            let n_max = resources.n_max();
            let m_max = resources.m_max();
            let mut best: Option<(DieConfig, f64, f64)> = None;
            for n in 1..=n_max {
                let mut m = m_step;
                while m <= m_max {
                    let die = DieConfig { n, m };
                    if resources.check(die) {
                        let mut cand = devices.clone();
                        for d in cand.iter_mut() {
                            if d.kind == kind {
                                d.die = die;
                            }
                        }
                        let thr = eval(&cand);
                        let solo = solo_s(&proto, die);
                        // strictly better fleet score wins; on the
                        // plateau (another kind bottlenecks the balanced
                        // scoring epoch) prefer the die that is fastest
                        // for this kind standalone
                        let improved = match best {
                            Some((_, b_thr, b_solo)) => {
                                thr > b_thr || (thr >= b_thr && solo < b_solo)
                            }
                            None => true,
                        };
                        if improved {
                            best = Some((die, thr, solo));
                        }
                    }
                    m += m_step;
                }
            }
            let (die, _, _) = best
                .ok_or_else(|| anyhow::anyhow!("no feasible design point for kind '{kind}'"))?;
            for d in devices.iter_mut() {
                if d.kind == kind {
                    d.die = die;
                }
            }
            per_kind.push((kind.to_string(), die, resources.utilization(die)));
        }
        let throughput = eval(&devices);
        let fm = FleetModel::new(devices.clone(), cpu_mem_gbs);
        let mean_makespan = |mode: SchedMode| -> f64 {
            workloads
                .iter()
                .map(|w| fm.epoch(&w.to_workload(p, 32), mode).makespan_seconds)
                .sum::<f64>()
                / workloads.len() as f64
        };
        let preferred_sched =
            if mean_makespan(SchedMode::BatchCount) < mean_makespan(SchedMode::Cost) {
                SchedMode::BatchCount
            } else {
                SchedMode::Cost
            };
        Ok(FleetDseResult { devices, per_kind, throughput, preferred_sched })
    }
}

/// The four-dataset average workload the paper sweeps in Fig. 7
/// (GraphSAGE, B=1024, fanouts 25/10).
pub fn paper_dse_workloads(cost: ModelCost) -> Vec<DseWorkload> {
    crate::graph::datasets::REGISTRY
        .iter()
        .map(|spec| DseWorkload {
            shape: BatchShape::nominal(
                1024.0,
                &[25.0, 10.0],
                &[spec.dims.f0 as f64, spec.dims.f1 as f64, spec.dims.f2 as f64],
            ),
            beta: 0.75,
            cost,
            sampling_s_per_batch: 2e-3,
            disk_gbs: 0.0,
            disk_miss_frac: 0.0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> DseEngine {
        DseEngine::new(PlatformSpec::paper_4fpga())
    }

    #[test]
    fn explores_nonempty_grid_and_best_is_max() {
        let e = engine();
        let res = e.explore(&paper_dse_workloads(ModelCost::for_model("sage").unwrap())).unwrap();
        assert!(!res.grid.is_empty());
        let max = res
            .grid
            .iter()
            .map(|p| p.throughput)
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(res.best.throughput, max);
        assert!(res.best.utilization.feasible());
    }

    #[test]
    fn all_grid_points_feasible() {
        let e = engine();
        let res = e.explore(&paper_dse_workloads(ModelCost::GCN)).unwrap();
        for p in &res.grid {
            assert!(p.utilization.feasible(), "{:?}", p.die);
        }
    }

    #[test]
    fn table5_comparison_shapes() {
        // Table 5: FPGA-level (8,2048) vs (16,1024); both feasible, and the
        // DSE prefers (8,2048) — more update parallelism wins because the
        // optimized aggregation has shifted the bottleneck to update.
        let e = engine();
        let w = paper_dse_workloads(ModelCost::for_model("sage").unwrap());
        let a = e.evaluate_fpga_config(8, 2048, &w).unwrap();
        let b = e.evaluate_fpga_config(16, 1024, &w).unwrap();
        assert!(a.throughput > b.throughput, "a={} b={}", a.throughput, b.throughput);
    }

    #[test]
    fn rejects_infeasible_config() {
        let e = engine();
        let w = paper_dse_workloads(ModelCost::GCN);
        assert!(e.evaluate_fpga_config(128, 4096, &w).is_err());
        assert!(e.evaluate_fpga_config(7, 2048, &w).is_err()); // not /4
    }

    #[test]
    fn empty_workloads_rejected() {
        let e = engine();
        assert!(e.explore(&[]).is_err());
    }

    #[test]
    fn fleet_dse_picks_a_die_per_kind() {
        let fleet = crate::fpga::parse_fleet("u250:2,u250-half:2").unwrap();
        let w = paper_dse_workloads(ModelCost::for_model("sage").unwrap());
        let res = DseEngine::explore_fleet(&fleet, 205.0, &w, 64).unwrap();
        assert_eq!(res.devices.len(), 4);
        assert_eq!(res.per_kind.len(), 2);
        assert!(res.throughput > 0.0);
        // a het fleet never prefers batch-count scheduling (cost-aware
        // WB is at worst a tie, and ties resolve to Cost)
        assert_eq!(res.preferred_sched, SchedMode::Cost);
        // every device of a kind shares that kind's chosen die, and the
        // die is feasible on that kind's resources
        for (kind, die, util) in &res.per_kind {
            assert!(util.feasible(), "{kind}: {util:?}");
            for d in res.devices.iter().filter(|d| d.kind == kind.as_str()) {
                assert_eq!(d.die, *die);
            }
        }
        // kinds keep their fleet positions
        assert!(res.devices[..2].iter().all(|d| d.kind == "u250"));
        assert!(res.devices[2..].iter().all(|d| d.kind == "u250-half"));
    }

    #[test]
    fn fleet_dse_rejects_empty_inputs() {
        let w = paper_dse_workloads(ModelCost::GCN);
        assert!(DseEngine::explore_fleet(&[], 205.0, &w, 16).is_err());
        let fleet = crate::fpga::parse_fleet("u250").unwrap();
        assert!(DseEngine::explore_fleet(&fleet, 205.0, &[], 16).is_err());
    }

    #[test]
    fn dse_estimates_are_model_dependent() {
        // the attention term must show up in the swept throughput: at a
        // matched shape, GAT traverses fewer vertices per second than GCN,
        // and SAGE's doubled update weights also cost on update-bound dies
        let e = engine();
        let die = DieConfig { n: 2, m: 512 };
        let gcn = e.throughput(die, &paper_dse_workloads(ModelCost::GCN));
        let gat = e.throughput(die, &paper_dse_workloads(ModelCost::for_model("gat").unwrap()));
        let sage = e.throughput(die, &paper_dse_workloads(ModelCost::for_model("sage").unwrap()));
        assert!(gat < gcn, "gat={gat} gcn={gcn}");
        assert!(sage <= gcn, "sage={sage} gcn={gcn}");
    }

    #[test]
    fn best_throughput_in_paper_ballpark() {
        // paper Table 5: estimated throughput ~97 M NVTPS for the best
        // GraphSAGE config on the 4-dataset average; accept a wide band
        // (this is a model, not their testbed).
        let e = engine();
        let res = e.explore(&paper_dse_workloads(ModelCost::for_model("sage").unwrap())).unwrap();
        assert!(
            res.best.throughput > 2.0e7 && res.best.throughput < 1.0e9,
            "throughput={}",
            res.best.throughput
        );
    }
}
