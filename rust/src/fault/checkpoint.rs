//! Versioned epoch-boundary checkpoints (`--checkpoint-dir` /
//! `--resume`; DESIGN.md §Fault tolerance).
//!
//! A checkpoint is everything the trainer's epoch loop carries across an
//! epoch barrier — parameters, SGD momentum, the coordinator RNG
//! position, feature-store policy state (per-FPGA and DRAM tier),
//! auto-tuner state, measured-shape accumulators, and the quarantine
//! mask — snapshotted *at* the barrier, where every one of those is
//! consistent. Restoring it therefore satisfies the continuation law:
//! training N epochs straight and training K epochs, resuming, and
//! training the remaining N−K produce bit-identical loss and traffic
//! sequences (`tests/pipeline_determinism.rs` pins this).
//!
//! ## Format
//!
//! Little-endian throughout, in the `.hitg` pack idiom
//! ([`crate::graph::ondisk`]): magic `HITGNNck` (u64), version (u32),
//! flags (u32), then length-prefixed sections in a fixed order. Every
//! read is bounds-checked and the file must be consumed *exactly* —
//! truncated files, bit-flipped tags, future versions, and trailing
//! garbage are all clean `Err`s, never a panic or a silent wrong resume.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::sched::SchedMode;
use crate::store::StoreState;
use crate::tune::{Knobs, TrialState, TunerState};

/// ASCII "HITGNNck" read as a little-endian u64.
pub const MAGIC: u64 = u64::from_le_bytes(*b"HITGNNck");
/// Current checkpoint format version.
pub const VERSION: u32 = 1;

/// One epoch-barrier snapshot of the trainer.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Config fingerprint — a resume against a different dataset, model,
    /// fleet size, or seed is rejected with a clean error.
    pub dataset: String,
    pub model: String,
    pub num_fpgas: u32,
    pub seed: u64,
    /// First epoch the resumed run executes (epochs 0..epoch_next are
    /// inside this snapshot).
    pub epoch_next: u64,
    /// Coordinator RNG position (`Rng::state`).
    pub rng: [u64; 4],
    pub shape_n: f64,
    pub last_beta: f64,
    pub disk_miss_frac: f64,
    pub shape_acc: Vec<f64>,
    /// Model parameters, per tensor.
    pub params: Vec<Vec<f32>>,
    /// SGD momentum, per tensor (same shapes as `params`).
    pub velocity: Vec<Vec<f32>>,
    /// Per-FPGA feature-store policy state.
    pub stores: Vec<StoreState>,
    /// DRAM-tier policy state (`--dram-ratio < 1` runs only).
    pub tier: Option<StoreState>,
    /// Auto-tuner state (`--auto-tune on|freeze` runs only).
    pub tuner: Option<TunerState>,
    /// Device quarantine mask (true = lost; survives resume so a dead
    /// board stays dead).
    pub quarantined: Vec<bool>,
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn wr_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn wr_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn wr_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn wr_str(out: &mut Vec<u8>, s: &str) {
    wr_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn wr_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    wr_u64(out, xs.len() as u64);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn wr_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    wr_u64(out, xs.len() as u64);
    for &x in xs {
        wr_u32(out, x);
    }
}

fn wr_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    wr_u64(out, xs.len() as u64);
    for &x in xs {
        wr_u64(out, x);
    }
}

fn wr_store(out: &mut Vec<u8>, s: &StoreState) {
    match s {
        StoreState::Static => out.push(0),
        StoreState::Lfu { capacity, resident, counts } => {
            out.push(1);
            wr_u64(out, *capacity);
            wr_u32s(out, resident);
            wr_u64s(out, counts);
        }
        StoreState::Window { capacity, clock, resident, last_seen } => {
            out.push(2);
            wr_u64(out, *capacity);
            wr_u64(out, *clock);
            wr_u32s(out, resident);
            wr_u64s(out, last_seen);
        }
    }
}

fn wr_knobs(out: &mut Vec<u8>, k: &Knobs) {
    wr_u64(out, k.host_threads as u64);
    wr_u64(out, k.prefetch_depth as u64);
    out.push(match k.sched {
        SchedMode::BatchCount => 0,
        SchedMode::Cost => 1,
    });
    wr_f64(out, k.cache_ratio);
}

fn wr_tuner(out: &mut Vec<u8>, t: &TunerState) {
    wr_knobs(out, &t.current);
    match t.best_score {
        Some(s) => {
            out.push(1);
            wr_f64(out, s);
        }
        None => out.push(0),
    }
    match &t.trial {
        Some(tr) => {
            out.push(1);
            out.push(tr.axis);
            out.push(tr.dir as u8);
            wr_knobs(out, &tr.knobs);
            wr_str(out, &tr.action);
        }
        None => out.push(0),
    }
    for axis in &t.blocked {
        for &b in axis {
            out.push(b as u8);
        }
    }
    out.push(t.sched_tried as u8);
}

// ---------------------------------------------------------------------------
// Decoding (bounds-checked cursor; every failure is a clean error)
// ---------------------------------------------------------------------------

struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.b.len(),
            "checkpoint truncated: wanted {n} bytes at offset {}, file has {}",
            self.pos,
            self.b.len()
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Length prefix for a sequence of `elem` -byte items; rejects
    /// lengths the remaining file cannot possibly hold (a bit flip in a
    /// length field must not trigger a huge allocation).
    fn len(&mut self, elem: usize) -> anyhow::Result<usize> {
        let n = self.u64()? as usize;
        anyhow::ensure!(
            n.checked_mul(elem).is_some_and(|b| self.pos + b <= self.b.len()),
            "checkpoint corrupt: sequence length {n} exceeds the remaining file"
        );
        Ok(n)
    }

    fn string(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("checkpoint corrupt: non-utf8 string")
    }

    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.len(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_le_bytes(self.take(4)?.try_into().unwrap()));
        }
        Ok(v)
    }

    fn u32s(&mut self) -> anyhow::Result<Vec<u32>> {
        let n = self.len(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn u64s(&mut self) -> anyhow::Result<Vec<u64>> {
        let n = self.len(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u64()?);
        }
        Ok(v)
    }

    fn f64s(&mut self) -> anyhow::Result<Vec<f64>> {
        let n = self.len(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    fn store(&mut self) -> anyhow::Result<StoreState> {
        match self.u8()? {
            0 => Ok(StoreState::Static),
            1 => Ok(StoreState::Lfu {
                capacity: self.u64()?,
                resident: self.u32s()?,
                counts: self.u64s()?,
            }),
            2 => {
                let capacity = self.u64()?;
                let clock = self.u64()?;
                Ok(StoreState::Window {
                    capacity,
                    clock,
                    resident: self.u32s()?,
                    last_seen: self.u64s()?,
                })
            }
            t => anyhow::bail!("checkpoint corrupt: unknown store-state tag {t}"),
        }
    }

    fn knobs(&mut self) -> anyhow::Result<Knobs> {
        let host_threads = self.u64()? as usize;
        let prefetch_depth = self.u64()? as usize;
        let sched = match self.u8()? {
            0 => SchedMode::BatchCount,
            1 => SchedMode::Cost,
            t => anyhow::bail!("checkpoint corrupt: unknown sched-mode tag {t}"),
        };
        Ok(Knobs { host_threads, prefetch_depth, sched, cache_ratio: self.f64()? })
    }

    fn tuner(&mut self) -> anyhow::Result<TunerState> {
        let current = self.knobs()?;
        let best_score = match self.u8()? {
            0 => None,
            1 => Some(self.f64()?),
            t => anyhow::bail!("checkpoint corrupt: bad best-score tag {t}"),
        };
        let trial = match self.u8()? {
            0 => None,
            1 => {
                let axis = self.u8()?;
                let dir = self.u8()? as i8;
                let knobs = self.knobs()?;
                Some(TrialState { axis, dir, knobs, action: self.string()? })
            }
            t => anyhow::bail!("checkpoint corrupt: bad trial tag {t}"),
        };
        let mut blocked = [[false; 2]; 4];
        for axis in blocked.iter_mut() {
            for b in axis.iter_mut() {
                *b = match self.u8()? {
                    0 => false,
                    1 => true,
                    t => anyhow::bail!("checkpoint corrupt: bad blocked flag {t}"),
                };
            }
        }
        let sched_tried = self.u8()? != 0;
        Ok(TunerState { current, best_score, trial, blocked, sched_tried })
    }
}

impl Checkpoint {
    /// Serialize to the on-disk byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        wr_u64(&mut out, MAGIC);
        wr_u32(&mut out, VERSION);
        wr_u32(&mut out, 0); // flags
        wr_str(&mut out, &self.dataset);
        wr_str(&mut out, &self.model);
        wr_u32(&mut out, self.num_fpgas);
        wr_u64(&mut out, self.seed);
        wr_u64(&mut out, self.epoch_next);
        for s in self.rng {
            wr_u64(&mut out, s);
        }
        wr_f64(&mut out, self.shape_n);
        wr_f64(&mut out, self.last_beta);
        wr_f64(&mut out, self.disk_miss_frac);
        wr_u64(&mut out, self.shape_acc.len() as u64);
        for &x in &self.shape_acc {
            wr_f64(&mut out, x);
        }
        wr_u64(&mut out, self.params.len() as u64);
        for t in &self.params {
            wr_f32s(&mut out, t);
        }
        wr_u64(&mut out, self.velocity.len() as u64);
        for t in &self.velocity {
            wr_f32s(&mut out, t);
        }
        wr_u64(&mut out, self.stores.len() as u64);
        for s in &self.stores {
            wr_store(&mut out, s);
        }
        match &self.tier {
            Some(s) => {
                out.push(1);
                wr_store(&mut out, s);
            }
            None => out.push(0),
        }
        match &self.tuner {
            Some(t) => {
                out.push(1);
                wr_tuner(&mut out, t);
            }
            None => out.push(0),
        }
        wr_u64(&mut out, self.quarantined.len() as u64);
        for &q in &self.quarantined {
            out.push(q as u8);
        }
        out
    }

    /// Decode and fully validate one checkpoint image.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
        let mut c = Cur { b: bytes, pos: 0 };
        let magic = c.u64()?;
        anyhow::ensure!(
            magic == MAGIC,
            "not a HitGNN checkpoint (bad magic {magic:#018x}, want {MAGIC:#018x})"
        );
        let version = c.u32()?;
        anyhow::ensure!(
            version == VERSION,
            "unsupported checkpoint version {version} (this build reads version {VERSION})"
        );
        let flags = c.u32()?;
        anyhow::ensure!(flags == 0, "checkpoint corrupt: nonzero flags {flags:#x}");
        let dataset = c.string()?;
        let model = c.string()?;
        let num_fpgas = c.u32()?;
        let seed = c.u64()?;
        let epoch_next = c.u64()?;
        let rng = [c.u64()?, c.u64()?, c.u64()?, c.u64()?];
        let shape_n = c.f64()?;
        let last_beta = c.f64()?;
        let disk_miss_frac = c.f64()?;
        let shape_acc = c.f64s()?;
        let n_params = c.len(1)?;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            params.push(c.f32s()?);
        }
        let n_vel = c.len(1)?;
        let mut velocity = Vec::with_capacity(n_vel);
        for _ in 0..n_vel {
            velocity.push(c.f32s()?);
        }
        let n_stores = c.len(1)?;
        let mut stores = Vec::with_capacity(n_stores);
        for _ in 0..n_stores {
            stores.push(c.store()?);
        }
        let tier = match c.u8()? {
            0 => None,
            1 => Some(c.store()?),
            t => anyhow::bail!("checkpoint corrupt: bad tier tag {t}"),
        };
        let tuner = match c.u8()? {
            0 => None,
            1 => Some(c.tuner()?),
            t => anyhow::bail!("checkpoint corrupt: bad tuner tag {t}"),
        };
        let n_q = c.len(1)?;
        let mut quarantined = Vec::with_capacity(n_q);
        for _ in 0..n_q {
            quarantined.push(match c.u8()? {
                0 => false,
                1 => true,
                t => anyhow::bail!("checkpoint corrupt: bad quarantine flag {t}"),
            });
        }
        anyhow::ensure!(
            c.pos == bytes.len(),
            "checkpoint corrupt: {} trailing bytes after the last section",
            bytes.len() - c.pos
        );
        Ok(Checkpoint {
            dataset,
            model,
            num_fpgas,
            seed,
            epoch_next,
            rng,
            shape_n,
            last_beta,
            disk_miss_frac,
            shape_acc,
            params,
            velocity,
            stores,
            tier,
            tuner,
            quarantined,
        })
    }

    /// Canonical file name for a snapshot taken after `epoch_next - 1`.
    pub fn file_name(epoch_next: usize) -> String {
        format!("ckpt-e{epoch_next:05}.hitg")
    }

    /// Write atomically (temp file + rename) into `dir`.
    pub fn save(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let path = dir.join(Self::file_name(self.epoch_next as usize));
        let tmp = dir.join(format!(".{}.tmp", Self::file_name(self.epoch_next as usize)));
        std::fs::write(&tmp, self.encode())
            .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing checkpoint {}", path.display()))?;
        Ok(path)
    }

    /// Load from a checkpoint file, or — when `path` is a directory —
    /// from the newest (highest `epoch_next`) checkpoint inside it.
    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let file = if path.is_dir() { latest_in_dir(path)? } else { path.to_path_buf() };
        let bytes = std::fs::read(&file)
            .with_context(|| format!("reading checkpoint {}", file.display()))?;
        Checkpoint::decode(&bytes).with_context(|| format!("decoding {}", file.display()))
    }
}

/// The newest checkpoint file in `dir` (by embedded epoch number in the
/// canonical name, falling back to lexicographic order which matches the
/// zero-padded scheme).
pub fn latest_in_dir(dir: &Path) -> anyhow::Result<PathBuf> {
    let mut best: Option<PathBuf> = None;
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("listing checkpoint dir {}", dir.display()))?
    {
        let p = entry?.path();
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("ckpt-e") && name.ends_with(".hitg") {
            if best.as_ref().is_none_or(|b| p.file_name() > b.file_name()) {
                best = Some(p);
            }
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!("no checkpoint (ckpt-e*.hitg) found in {}", dir.display())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            dataset: "tiny".into(),
            model: "gcn".into(),
            num_fpgas: 2,
            seed: 33,
            epoch_next: 4,
            rng: [1, 2, 3, 4],
            shape_n: 16.0,
            last_beta: 0.8125,
            disk_miss_frac: 0.25,
            shape_acc: vec![5.0, 4.0, 3.0, 2.0, 1.0],
            params: vec![vec![0.5f32; 6], vec![-1.25f32; 3]],
            velocity: vec![vec![0.125f32; 6], vec![0.0f32; 3]],
            stores: vec![
                StoreState::Static,
                StoreState::Lfu { capacity: 8, resident: vec![0, 3, 5], counts: vec![1, 0, 7, 2] },
            ],
            tier: Some(StoreState::Window {
                capacity: 4,
                clock: 99,
                resident: vec![1, 2],
                last_seen: vec![9, 8, 7],
            }),
            tuner: Some(TunerState {
                current: Knobs {
                    host_threads: 2,
                    prefetch_depth: 3,
                    sched: SchedMode::Cost,
                    cache_ratio: 0.25,
                },
                best_score: Some(1.5),
                trial: Some(TrialState {
                    axis: 1,
                    dir: -1,
                    knobs: Knobs {
                        host_threads: 2,
                        prefetch_depth: 2,
                        sched: SchedMode::Cost,
                        cache_ratio: 0.25,
                    },
                    action: "prefetch_depth 3 -> 2".into(),
                }),
                blocked: [[false, true], [false; 2], [true, false], [false; 2]],
                sched_tried: true,
            }),
            quarantined: vec![false, true],
        }
    }

    #[test]
    fn roundtrips_bit_exactly() {
        let ck = sample();
        let bytes = ck.encode();
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back, ck);
        // minimal variant (no tier/tuner, static stores) also roundtrips
        let min = Checkpoint {
            tier: None,
            tuner: None,
            stores: vec![StoreState::Static; 2],
            ..ck
        };
        assert_eq!(Checkpoint::decode(&min.encode()).unwrap(), min);
    }

    #[test]
    fn truncation_at_every_cut_is_a_clean_error() {
        let bytes = sample().encode();
        // every strict prefix must fail with Err — never panic, never Ok
        for cut in 0..bytes.len() {
            let r = Checkpoint::decode(&bytes[..cut]);
            assert!(r.is_err(), "decode of {cut}-byte prefix unexpectedly succeeded");
        }
    }

    #[test]
    fn bad_magic_version_and_trailing_garbage_are_rejected() {
        let ck = sample();
        let good = ck.encode();

        let mut bad = good.clone();
        bad[0] ^= 0xff;
        let err = Checkpoint::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        let mut future = good.clone();
        future[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = Checkpoint::decode(&future).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");

        let mut trailing = good.clone();
        trailing.extend_from_slice(&[0u8; 3]);
        let err = Checkpoint::decode(&trailing).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn bit_flips_in_tags_and_lengths_are_clean_errors() {
        let bytes = sample().encode();
        // flip one bit at a time across the whole image: decode must
        // never panic, and when it "succeeds" it must not equal the
        // original only by accident of the flipped field (we only assert
        // no panic + Err or changed value)
        let orig = Checkpoint::decode(&bytes).unwrap();
        for i in (0..bytes.len()).step_by(7) {
            let mut b = bytes.clone();
            b[i] ^= 0x10;
            match Checkpoint::decode(&b) {
                Err(_) => {}
                Ok(ck) => assert!(ck != orig || b == bytes, "flip at {i} was silently absorbed"),
            }
        }
    }

    #[test]
    fn save_load_and_latest_selection() {
        let dir = std::env::temp_dir().join(format!("hitgnn-ckpt-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut ck = sample();
        ck.epoch_next = 1;
        ck.save(&dir).unwrap();
        ck.epoch_next = 3;
        let p3 = ck.save(&dir).unwrap();
        assert!(p3.ends_with("ckpt-e00003.hitg"));
        // dir resolution picks the newest
        let loaded = Checkpoint::load(&dir).unwrap();
        assert_eq!(loaded.epoch_next, 3);
        // explicit file path works too
        assert_eq!(Checkpoint::load(&p3).unwrap(), loaded);
        // empty dir is a clean error
        let empty = dir.join("empty");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(Checkpoint::load(&empty).unwrap_err().to_string().contains("no checkpoint"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
