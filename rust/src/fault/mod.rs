//! Deterministic fault injection and the fault-tolerant fleet runtime
//! (DESIGN.md §Fault tolerance).
//!
//! Long multi-FPGA training runs fail in practice: a board drops off the
//! PCIe bus, a shared-link neighbour turns a device into a straggler, an
//! out-of-core read hits a transient I/O error, a host prep thread dies.
//! This module gives the coordinator a *deterministic* model of those
//! events so the degradation machinery (scheduler quarantine, bounded
//! disk retry, error-path drain, checkpoint/resume) can be tested
//! bit-for-bit:
//!
//! - [`FaultPlan`] parses `--fault-plan` specs like
//!   `dev1:fail@e2i7,dev3:slow*4@e1,disk:eio@0.01,prep:panic@e3i2` into a
//!   schedule keyed on **logical positions** — (epoch, iteration)
//!   anchors, never wall-clock — so the same plan and seed reproduce the
//!   same faulted run on any host.
//! - Device failures are applied at *planning time*: the whole epoch's
//!   iteration schedule is materialised up front
//!   (`prep::plan_epoch_tasks`), so quarantining a device mid-plan
//!   deterministically reroutes its remaining (part, seq) work to
//!   survivors while every batch still trains exactly once.
//! - Straggler slowdowns only re-price the scheduler's per-device
//!   [`CostModel`](crate::sched::CostModel) — `--sched cost` then
//!   visibly routes extras around the slow device, while the loss
//!   sequence (a function of the partition stream alone) is untouched.
//! - Transient disk errors are drawn by a stateless hash of
//!   (seed, epoch, iter, tag, attempt) — no RNG stream is consumed, so
//!   injecting faults cannot shift the sampling sequence of a run.

pub mod checkpoint;

use crate::util::rng::hash64;

/// A logical schedule position: fire *before* iteration `iter` of epoch
/// `epoch` (0-based, matching `EpochMetrics::epoch` and the planner's
/// iteration index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Anchor {
    pub epoch: usize,
    pub iter: usize,
}

impl std::fmt::Display for Anchor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "e{}i{}", self.epoch, self.iter)
    }
}

/// `devN:fail@eEiI` — device N is lost for the rest of the run, starting
/// at the anchor (it executes no batch of iteration I or later).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeviceFailure {
    pub dev: usize,
    pub at: Anchor,
}

/// `devN:slow*M@eE` — device N runs M× slower from epoch E onward.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slowdown {
    pub dev: usize,
    pub mult: f64,
    pub from_epoch: usize,
}

/// A parsed `--fault-plan`: the full deterministic fault schedule of a
/// run. Parsing rejects malformed tokens by name; [`FaultPlan::validate`]
/// additionally pins device ids and epoch anchors to the live fleet and
/// run length once those are known.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// The original spec text (config echo / report round-trip).
    pub spec: String,
    pub failures: Vec<DeviceFailure>,
    pub slowdowns: Vec<Slowdown>,
    /// `disk:eio@p` — probability a batch's disk read fails transiently.
    pub disk_eio: Option<f64>,
    /// `prep:panic@eEiI` — a prep worker panics preparing that iteration.
    pub prep_panics: Vec<Anchor>,
}

/// Parse `"e<digits>i<digits>"` (a full anchor).
fn parse_anchor(s: &str, tok: &str) -> anyhow::Result<Anchor> {
    let rest = s
        .strip_prefix('e')
        .ok_or_else(|| anyhow::anyhow!("bad fault token '{tok}': anchor '{s}' must be eEiI"))?;
    let (e, i) = rest
        .split_once('i')
        .ok_or_else(|| anyhow::anyhow!("bad fault token '{tok}': anchor '{s}' must be eEiI"))?;
    let epoch = e
        .parse::<usize>()
        .map_err(|_| anyhow::anyhow!("bad fault token '{tok}': epoch '{e}' is not a number"))?;
    let iter = i
        .parse::<usize>()
        .map_err(|_| anyhow::anyhow!("bad fault token '{tok}': iteration '{i}' is not a number"))?;
    Ok(Anchor { epoch, iter })
}

/// Parse `"e<digits>"` (an epoch-only anchor).
fn parse_epoch(s: &str, tok: &str) -> anyhow::Result<usize> {
    s.strip_prefix('e')
        .and_then(|e| e.parse::<usize>().ok())
        .ok_or_else(|| anyhow::anyhow!("bad fault token '{tok}': anchor '{s}' must be eE"))
}

impl FaultPlan {
    /// Parse a comma-separated fault spec. Grammar per token:
    /// `devN:fail@eEiI` | `devN:slow*M@eE` | `disk:eio@P` |
    /// `prep:panic@eEiI`. Every rejection names the offending token.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan { spec: spec.trim().to_string(), ..FaultPlan::default() };
        if plan.spec.is_empty() {
            return Ok(plan);
        }
        for tok in plan.spec.split(',') {
            let tok = tok.trim();
            if let Some(rest) = tok.strip_prefix("dev") {
                let (id, action) = rest.split_once(':').ok_or_else(|| {
                    anyhow::anyhow!("bad fault token '{tok}': expected devN:fail@… or devN:slow*M@…")
                })?;
                let dev = id.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("bad fault token '{tok}': device id '{id}' is not a number")
                })?;
                if let Some(anchor) = action.strip_prefix("fail@") {
                    let at = parse_anchor(anchor, tok)?;
                    anyhow::ensure!(
                        !plan.failures.iter().any(|f| f.dev == dev),
                        "bad fault token '{tok}': device {dev} already has a failure"
                    );
                    plan.failures.push(DeviceFailure { dev, at });
                } else if let Some(rest) = action.strip_prefix("slow*") {
                    let (m, anchor) = rest.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("bad fault token '{tok}': expected slow*M@eE")
                    })?;
                    let mult = m.parse::<f64>().map_err(|_| {
                        anyhow::anyhow!("bad fault token '{tok}': multiplier '{m}' is not a number")
                    })?;
                    anyhow::ensure!(
                        mult.is_finite() && mult >= 1.0,
                        "bad fault token '{tok}': slowdown multiplier must be a finite number >= 1"
                    );
                    let from_epoch = parse_epoch(anchor, tok)?;
                    plan.slowdowns.push(Slowdown { dev, mult, from_epoch });
                } else {
                    anyhow::bail!(
                        "bad fault token '{tok}': unknown device action (fail@eEiI|slow*M@eE)"
                    );
                }
            } else if let Some(rest) = tok.strip_prefix("disk:eio@") {
                let p = rest.parse::<f64>().map_err(|_| {
                    anyhow::anyhow!("bad fault token '{tok}': probability '{rest}' is not a number")
                })?;
                anyhow::ensure!(
                    p.is_finite() && (0.0..=1.0).contains(&p),
                    "bad fault token '{tok}': probability must be in [0, 1]"
                );
                anyhow::ensure!(
                    plan.disk_eio.is_none(),
                    "bad fault token '{tok}': disk:eio given twice"
                );
                plan.disk_eio = Some(p);
            } else if let Some(anchor) = tok.strip_prefix("prep:panic@") {
                plan.prep_panics.push(parse_anchor(anchor, tok)?);
            } else if tok.is_empty() {
                anyhow::bail!("bad fault token '' (empty entry in fault plan '{spec}')");
            } else {
                anyhow::bail!(
                    "bad fault token '{tok}': expected devN:fail@eEiI, devN:slow*M@eE, \
                     disk:eio@p, or prep:panic@eEiI"
                );
            }
        }
        Ok(plan)
    }

    pub fn is_empty(&self) -> bool {
        self.failures.is_empty()
            && self.slowdowns.is_empty()
            && self.disk_eio.is_none()
            && self.prep_panics.is_empty()
    }

    /// Pin the plan against the live run: device ids must name fleet
    /// members and epoch anchors must fall inside the run. (Iteration
    /// anchors are checked per epoch by the planner, which is the first
    /// place the iteration count exists.)
    pub fn validate(&self, num_fpgas: usize, epochs: usize) -> anyhow::Result<()> {
        for f in &self.failures {
            anyhow::ensure!(
                f.dev < num_fpgas,
                "fault plan names dev{} but the fleet has {num_fpgas} devices (dev0..dev{})",
                f.dev,
                num_fpgas - 1
            );
            anyhow::ensure!(
                f.at.epoch < epochs,
                "fault plan anchor {} is out of range: the run has {epochs} epochs",
                f.at
            );
        }
        anyhow::ensure!(
            self.failures.len() < num_fpgas,
            "fault plan kills all {num_fpgas} devices — no survivors to finish an epoch"
        );
        for s in &self.slowdowns {
            anyhow::ensure!(
                s.dev < num_fpgas,
                "fault plan names dev{} but the fleet has {num_fpgas} devices (dev0..dev{})",
                s.dev,
                num_fpgas - 1
            );
            anyhow::ensure!(
                s.from_epoch < epochs,
                "fault plan slowdown anchor e{} is out of range: the run has {epochs} epochs",
                s.from_epoch
            );
        }
        for a in &self.prep_panics {
            anyhow::ensure!(
                a.epoch < epochs,
                "fault plan anchor {a} is out of range: the run has {epochs} epochs"
            );
        }
        Ok(())
    }

    /// Devices whose failure anchor lies in an epoch *before* `epoch` —
    /// already dead when this epoch starts (used to rebuild the
    /// quarantine set on resume).
    pub fn failed_before(&self, epoch: usize) -> Vec<usize> {
        let mut devs: Vec<usize> =
            self.failures.iter().filter(|f| f.at.epoch < epoch).map(|f| f.dev).collect();
        devs.sort_unstable();
        devs
    }

    /// Failures anchored inside `epoch`, as (iteration, device) sorted by
    /// iteration — the planner consumes these in order.
    pub fn failures_in_epoch(&self, epoch: usize) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .failures
            .iter()
            .filter(|f| f.at.epoch == epoch)
            .map(|f| (f.at.iter, f.dev))
            .collect();
        v.sort_unstable();
        v
    }

    /// Combined straggler multiplier for `dev` during `epoch` (product of
    /// all slowdowns whose anchor epoch has passed; 1.0 when healthy).
    pub fn slow_multiplier(&self, dev: usize, epoch: usize) -> f64 {
        self.slowdowns
            .iter()
            .filter(|s| s.dev == dev && s.from_epoch <= epoch)
            .map(|s| s.mult)
            .product()
    }

    /// Iterations of `epoch` whose preparation must panic (sorted).
    pub fn prep_panics_in_epoch(&self, epoch: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .prep_panics
            .iter()
            .filter(|a| a.epoch == epoch)
            .map(|a| a.iter)
            .collect();
        v.sort_unstable();
        v
    }

    /// Deterministic transient-disk-error draw for one (batch, attempt):
    /// a stateless hash of the run seed and the batch's logical position,
    /// compared against the plan's `disk:eio` probability. Consumes no
    /// RNG stream, so a faulted run samples identically to a healthy one.
    pub fn disk_error(&self, seed: u64, epoch: usize, iter: usize, tag: usize, attempt: u32) -> bool {
        let Some(p) = self.disk_eio else {
            return false;
        };
        if p <= 0.0 {
            return false;
        }
        let mut x = seed ^ 0x6469_736b_5f65_696f; // "disk_eio"
        for v in [epoch as u64, iter as u64, tag as u64, attempt as u64] {
            x = hash64(x ^ hash64(v));
        }
        let u = (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < p
    }
}

/// Bounded-retry policy for transient disk errors: a read that keeps
/// failing after [`DISK_RETRY_MAX`] attempts is a fatal, clean error.
pub const DISK_RETRY_MAX: u32 = 5;

/// Deterministic backoff before retry `attempt` (1-based), in
/// microseconds: 50µs · 2^(attempt-1), capped at 1ms. Real time is spent
/// (the wall-clock metrics see it) but nothing downstream keys on it.
pub fn retry_backoff_us(attempt: u32) -> u64 {
    (50u64 << (attempt - 1).min(10)).min(1_000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p =
            FaultPlan::parse("dev1:fail@e2i7, dev3:slow*4@e1, disk:eio@0.01, prep:panic@e3i2")
                .unwrap();
        assert_eq!(p.failures, vec![DeviceFailure { dev: 1, at: Anchor { epoch: 2, iter: 7 } }]);
        assert_eq!(p.slowdowns, vec![Slowdown { dev: 3, mult: 4.0, from_epoch: 1 }]);
        assert_eq!(p.disk_eio, Some(0.01));
        assert_eq!(p.prep_panics, vec![Anchor { epoch: 3, iter: 2 }]);
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn rejections_name_the_bad_token() {
        for (spec, needle) in [
            ("devx:fail@e1i0", "'devx:fail@e1i0'"),
            ("dev0:explode@e1i0", "unknown device action"),
            ("dev0:fail@e1", "must be eEiI"),
            ("dev0:fail@i1e1", "must be eEiI"),
            ("dev0:slow*abc@e1", "not a number"),
            ("dev0:slow*0.5@e1", ">= 1"),
            ("dev0:slow*4@i3", "must be eE"),
            ("disk:eio@1.5", "in [0, 1]"),
            ("disk:eio@nan", "in [0, 1]"),
            ("disk:eio@0.1,disk:eio@0.2", "twice"),
            ("prep:panic@e1", "must be eEiI"),
            ("gpu0:fail@e1i0", "expected devN:fail@eEiI"),
            ("dev0:fail@e1i1,,disk:eio@0.1", "empty entry"),
            ("dev2:fail@e0i0,dev2:fail@e1i0", "already has a failure"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err().to_string();
            assert!(err.contains(needle), "spec '{spec}': error '{err}' lacks '{needle}'");
        }
    }

    #[test]
    fn validate_pins_fleet_and_run_bounds() {
        let p = FaultPlan::parse("dev3:fail@e1i0").unwrap();
        assert!(p.validate(4, 2).is_ok());
        let err = p.validate(2, 2).unwrap_err().to_string();
        assert!(err.contains("dev3") && err.contains("2 devices"), "{err}");
        let err = p.validate(4, 1).unwrap_err().to_string();
        assert!(err.contains("e1i0") && err.contains("1 epochs"), "{err}");
        let slow = FaultPlan::parse("dev9:slow*2@e0").unwrap();
        assert!(slow.validate(2, 1).unwrap_err().to_string().contains("dev9"));
        let panic = FaultPlan::parse("prep:panic@e5i0").unwrap();
        assert!(panic.validate(2, 2).unwrap_err().to_string().contains("e5i0"));
        // killing the whole fleet is rejected up front
        let all = FaultPlan::parse("dev0:fail@e0i0,dev1:fail@e0i1").unwrap();
        assert!(all.validate(2, 2).unwrap_err().to_string().contains("no survivors"));
    }

    #[test]
    fn epoch_queries_partition_the_schedule() {
        let p = FaultPlan::parse("dev1:fail@e2i7,dev0:fail@e2i3,dev2:fail@e0i1").unwrap();
        assert_eq!(p.failures_in_epoch(2), vec![(3, 0), (7, 1)]);
        assert_eq!(p.failures_in_epoch(1), vec![]);
        assert_eq!(p.failed_before(0), vec![]);
        assert_eq!(p.failed_before(1), vec![2]);
        assert_eq!(p.failed_before(3), vec![0, 1, 2]);
    }

    #[test]
    fn slow_multipliers_compound_from_their_epoch() {
        let p = FaultPlan::parse("dev1:slow*4@e1,dev1:slow*2@e3,dev0:slow*3@e0").unwrap();
        assert_eq!(p.slow_multiplier(1, 0), 1.0);
        assert_eq!(p.slow_multiplier(1, 1), 4.0);
        assert_eq!(p.slow_multiplier(1, 3), 8.0);
        assert_eq!(p.slow_multiplier(0, 5), 3.0);
        assert_eq!(p.slow_multiplier(2, 5), 1.0);
    }

    #[test]
    fn disk_draw_is_deterministic_and_calibrated() {
        let p = FaultPlan::parse("disk:eio@0.1").unwrap();
        let mut hits = 0;
        for i in 0..10_000 {
            let a = p.disk_error(42, 1, i, 0, 0);
            let b = p.disk_error(42, 1, i, 0, 0);
            assert_eq!(a, b, "draw must be a pure function of its position");
            if a {
                hits += 1;
            }
        }
        let rate = hits as f64 / 10_000.0;
        assert!((rate - 0.1).abs() < 0.02, "rate={rate}");
        // attempt index decorrelates retries; seed decorrelates runs
        assert!((0..64).any(|att| !p.disk_error(42, 0, 0, 0, att)));
        let healthy = FaultPlan::parse("dev0:fail@e0i0").unwrap();
        assert!(!healthy.disk_error(42, 0, 0, 0, 0));
    }

    #[test]
    fn backoff_is_bounded() {
        assert_eq!(retry_backoff_us(1), 50);
        assert_eq!(retry_backoff_us(2), 100);
        assert!(retry_backoff_us(40) <= 1_000);
    }
}
