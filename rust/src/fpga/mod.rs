//! FPGA device model: platform metadata (paper Table 3 / Listing 1) and
//! the resource-utilization model of §6.1 (Eqs. 1–2, extended to URAM and
//! BRAM so Table 5 can be reproduced in full).
//!
//! `n` = scatter-gather PEs in the aggregate kernel, `m` = PEs in the
//! update kernel — both **per die** (the DSE engine explores per die,
//! Algorithm 4; each die has one DDR channel). FPGA-level parallelism is
//! `dies ×` the per-die configuration.

pub mod timing;

/// Static FPGA platform metadata (the `FPGA_Metadata()` API of Table 2).
#[derive(Clone, Copy, Debug)]
pub struct FpgaSpec {
    pub name: &'static str,
    /// Super logic regions (dies); U250 has 4, one DDR channel each.
    pub dies: usize,
    /// Per-die resources.
    pub dsp_per_die: u32,
    pub lut_per_die: u32,
    pub uram_per_die: u32,
    pub bram_per_die: u32,
    /// Per-die DDR channel bandwidth (GB/s); 77 total on U250 → 19.25.
    pub ddr_gbs_per_die: f64,
    /// Kernel clock (MHz). Paper: 300.
    pub freq_mhz: f64,
    /// SIMD lanes per scatter-gather PE: 512-bit / 32-bit = 16 (Eq. 8).
    pub pe_simd: u32,
}

/// Xilinx Alveo U250 — the paper's FPGA (Table 3, Listing 1).
pub const U250: FpgaSpec = FpgaSpec {
    name: "Xilinx Alveo U250",
    dies: 4,
    dsp_per_die: 3072,
    lut_per_die: 423_000,
    uram_per_die: 320,
    bram_per_die: 672,
    ddr_gbs_per_die: 19.25,
    freq_mhz: 300.0,
    pe_simd: 16,
};

/// U250 with only 2 of its 4 SLRs usable (a partially populated /
/// floorplan-constrained card): half the DDR bandwidth and half the PE
/// budget of a full U250.
pub const U250_HALF: FpgaSpec = FpgaSpec {
    name: "Xilinx Alveo U250 (2-die)",
    dies: 2,
    dsp_per_die: 3072,
    lut_per_die: 423_000,
    uram_per_die: 320,
    bram_per_die: 672,
    ddr_gbs_per_die: 19.25,
    freq_mhz: 300.0,
    pe_simd: 16,
};

/// Single-SLR U250 (one die, one DDR channel) — the smallest member of
/// the heterogeneous-fleet registry.
pub const U250_QUARTER: FpgaSpec = FpgaSpec {
    name: "Xilinx Alveo U250 (1-die)",
    dies: 1,
    dsp_per_die: 3072,
    lut_per_die: 423_000,
    uram_per_die: 320,
    bram_per_die: 672,
    ddr_gbs_per_die: 19.25,
    freq_mhz: 300.0,
    pe_simd: 16,
};

impl FpgaSpec {
    /// Total DDR bandwidth of the card.
    pub fn ddr_gbs_total(&self) -> f64 {
        self.ddr_gbs_per_die * self.dies as f64
    }
    /// Kernel frequency in Hz.
    pub fn freq_hz(&self) -> f64 {
        self.freq_mhz * 1e6
    }
}

/// Per-die accelerator configuration: the DSE decision variables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DieConfig {
    /// Scatter-gather PEs in the aggregate kernel.
    pub n: u32,
    /// PEs in the update kernel.
    pub m: u32,
}

/// The die configuration the paper's DSE selects on a U250 (Table 5,
/// FPGA-level (8, 2048) = per-die (2, 512)) — the registry default.
pub const DEFAULT_DIE: DieConfig = DieConfig { n: 2, m: 512 };

/// One device of a (possibly heterogeneous) fleet: per-device platform
/// metadata — the `FPGA_Metadata()` of Table 2 generalised so mixed
/// generations, partially populated dies and shared PCIe links can be
/// described per card instead of assuming `p` identical U250s.
#[derive(Clone, Copy, Debug)]
pub struct DeviceSpec {
    /// Registry key this device was built from ("u250", "u250-half", …;
    /// "custom" for API-assembled devices).
    pub kind: &'static str,
    pub fpga: FpgaSpec,
    /// Per-die accelerator configuration (DSE output; registry default
    /// is the paper's Table-5 pick).
    pub die: DieConfig,
    /// This device's host↔FPGA PCIe bandwidth share (GB/s). 16 for a
    /// dedicated PCIe 3×16 link; less behind a shared switch.
    pub pcie_gbs: f64,
}

impl DeviceSpec {
    /// An API-assembled device (not from the named registry).
    pub fn custom(fpga: FpgaSpec, die: DieConfig, pcie_gbs: f64) -> DeviceSpec {
        DeviceSpec { kind: "custom", fpga, die, pcie_gbs }
    }
}

/// Look up a named device kind (`--fleet` vocabulary).
pub fn device_kind(kind: &str) -> anyhow::Result<DeviceSpec> {
    let d = match kind {
        "u250" => DeviceSpec { kind: "u250", fpga: U250, die: DEFAULT_DIE, pcie_gbs: 16.0 },
        "u250-half" => {
            DeviceSpec { kind: "u250-half", fpga: U250_HALF, die: DEFAULT_DIE, pcie_gbs: 16.0 }
        }
        "u250-quarter" => {
            DeviceSpec { kind: "u250-quarter", fpga: U250_QUARTER, die: DEFAULT_DIE, pcie_gbs: 16.0 }
        }
        // full card behind a shared PCIe switch: half the link bandwidth
        "u250-shared" => {
            DeviceSpec { kind: "u250-shared", fpga: U250, die: DEFAULT_DIE, pcie_gbs: 8.0 }
        }
        other => anyhow::bail!(
            "unknown device kind '{other}' (u250|u250-half|u250-quarter|u250-shared)"
        ),
    };
    Ok(d)
}

/// Parse a fleet specification: comma-separated `kind:count` (or bare
/// `kind` = 1), e.g. `u250:4` or `u250:2,u250-half:2`. Device order is
/// significant — FPGA *i* of the fleet executes partition *i* in stage 1.
pub fn parse_fleet(spec: &str) -> anyhow::Result<Vec<DeviceSpec>> {
    let mut fleet = Vec::new();
    for group in spec.split(',') {
        let group = group.trim();
        anyhow::ensure!(!group.is_empty(), "empty device group in fleet '{spec}'");
        let (kind, count) = match group.split_once(':') {
            Some((k, c)) => {
                let count: usize = c
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad device count '{c}' in '{group}': {e}"))?;
                (k, count)
            }
            None => (group, 1),
        };
        anyhow::ensure!(count >= 1, "device count must be >= 1 in '{group}'");
        let dev = device_kind(kind)?;
        for _ in 0..count {
            fleet.push(dev);
        }
    }
    anyhow::ensure!(!fleet.is_empty(), "fleet '{spec}' has no devices");
    Ok(fleet)
}

/// The homogeneous paper platform: `p` identical U250s at the Table-5
/// die configuration on dedicated PCIe 3×16 links.
pub fn homogeneous_fleet(p: usize) -> Vec<DeviceSpec> {
    vec![device_kind("u250").expect("registry"); p]
}

/// Canonical `kind:count` run-length rendering of a fleet for reports
/// and logs. Display metadata, not a lossless round-trip: API-assembled
/// devices render as `custom:n` (which [`parse_fleet`] rejects), and
/// per-device die tuning (DSE output) is not encoded.
pub fn fleet_spec_string(fleet: &[DeviceSpec]) -> String {
    let mut out: Vec<String> = Vec::new();
    let mut i = 0;
    while i < fleet.len() {
        let kind = fleet[i].kind;
        let mut j = i;
        while j < fleet.len() && fleet[j].kind == kind {
            j += 1;
        }
        out.push(format!("{kind}:{}", j - i));
        i = j;
    }
    out.join(",")
}

/// Resource-consumption coefficients (Eqs. 1–2 plus URAM/BRAM analogues).
/// Fitted so the U250 utilizations of Table 5 are reproduced — see
/// EXPERIMENTS.md §Table 5 for the fit.
#[derive(Clone, Copy, Debug)]
pub struct ResourceCoeffs {
    /// DSPs: λ1·m + λ2·n ≤ N_DSP (Eq. 1).
    pub lambda1: f64,
    pub lambda2: f64,
    /// LUTs: ρ1·m + ρ2·n + ρ3·n·log2(n) ≤ N_LUT (Eq. 2; the n·log n term
    /// models the aggregate kernel's routing network).
    pub rho1: f64,
    pub rho2: f64,
    pub rho3: f64,
    /// URAM: μ1·m + μ2·n (result buffers).
    pub mu1: f64,
    pub mu2: f64,
    /// BRAM: ν1·m + ν2·n (stream FIFOs).
    pub nu1: f64,
    pub nu2: f64,
}

impl Default for ResourceCoeffs {
    fn default() -> Self {
        // Fit against Table 5 (per-die configs (2,512) and (4,256) are the
        // paper's FPGA-level (8,2048) / (16,1024) divided by 4 dies):
        //   DSP  90% / 56%, LUT 72% / 65%, URAM 48% / 34%, BRAM 40% / 28%.
        ResourceCoeffs {
            lambda1: 5.0,    // f32 MAC ≈ 5 DSP48 per update PE
            lambda2: 102.0,  // 16-lane SIMD scatter-gather PE
            rho1: 487.0,
            rho2: 17_557.0,
            rho3: 10_000.0,
            mu1: 0.258,
            mu2: 10.67,
            nu1: 0.455,      // fitted to BRAM 40%/28% of 672
            nu2: 17.92,
        }
    }
}

/// Utilization fractions for one die configuration.
#[derive(Clone, Copy, Debug)]
pub struct Utilization {
    pub dsp: f64,
    pub lut: f64,
    pub uram: f64,
    pub bram: f64,
}

impl Utilization {
    /// Within budget on every resource (the Eq. 1/2 feasibility check).
    pub fn feasible(&self) -> bool {
        self.dsp <= 1.0 && self.lut <= 1.0 && self.uram <= 1.0 && self.bram <= 1.0
    }
    pub fn max_fraction(&self) -> f64 {
        self.dsp.max(self.lut).max(self.uram).max(self.bram)
    }
}

/// The §6.1 resource-utilization model.
#[derive(Clone, Copy, Debug)]
pub struct ResourceModel {
    pub spec: FpgaSpec,
    pub coeffs: ResourceCoeffs,
}

impl ResourceModel {
    pub fn new(spec: FpgaSpec) -> ResourceModel {
        ResourceModel { spec, coeffs: ResourceCoeffs::default() }
    }

    /// Per-die utilization of configuration `c`.
    pub fn utilization(&self, c: DieConfig) -> Utilization {
        let (n, m) = (c.n as f64, c.m as f64);
        let k = &self.coeffs;
        let nlogn = if c.n > 1 { n * n.log2() } else { 0.0 };
        Utilization {
            dsp: (k.lambda1 * m + k.lambda2 * n) / self.spec.dsp_per_die as f64,
            lut: (k.rho1 * m + k.rho2 * n + k.rho3 * nlogn) / self.spec.lut_per_die as f64,
            uram: (k.mu1 * m + k.mu2 * n) / self.spec.uram_per_die as f64,
            bram: (k.nu1 * m + k.nu2 * n) / self.spec.bram_per_die as f64,
        }
    }

    /// Feasibility under Eqs. 1–2 (+ URAM/BRAM).
    pub fn check(&self, c: DieConfig) -> bool {
        c.n >= 1 && c.m >= 1 && self.utilization(c).feasible()
    }

    /// Largest feasible `n` with m = 1 (Algorithm 4's search-space bound).
    pub fn n_max(&self) -> u32 {
        let mut n = 1;
        while self.check(DieConfig { n: n + 1, m: 1 }) {
            n += 1;
        }
        n
    }

    /// Largest feasible `m` with n = 1.
    pub fn m_max(&self) -> u32 {
        let mut lo = 1u32;
        let mut hi = self.spec.dsp_per_die; // m is DSP-bound long before this
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.check(DieConfig { n: 1, m: mid }) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ResourceModel {
        ResourceModel::new(U250)
    }

    #[test]
    fn table5_config_8_2048_utilization() {
        // FPGA-level (8,2048) = per-die (2,512)
        let u = model().utilization(DieConfig { n: 2, m: 512 });
        assert!((u.dsp - 0.90).abs() < 0.03, "dsp={}", u.dsp);
        assert!((u.lut - 0.72).abs() < 0.03, "lut={}", u.lut);
        assert!((u.uram - 0.48).abs() < 0.04, "uram={}", u.uram);
        assert!((u.bram - 0.40).abs() < 0.04, "bram={}", u.bram);
        assert!(u.feasible());
    }

    #[test]
    fn table5_config_16_1024_utilization() {
        // FPGA-level (16,1024) = per-die (4,256)
        let u = model().utilization(DieConfig { n: 4, m: 256 });
        assert!((u.dsp - 0.56).abs() < 0.03, "dsp={}", u.dsp);
        assert!((u.lut - 0.65).abs() < 0.03, "lut={}", u.lut);
        assert!((u.uram - 0.34).abs() < 0.04, "uram={}", u.uram);
        assert!((u.bram - 0.28).abs() < 0.04, "bram={}", u.bram);
        assert!(u.feasible());
    }

    #[test]
    fn infeasible_when_oversubscribed() {
        let m = model();
        assert!(!m.check(DieConfig { n: 2, m: 100_000 }));
        assert!(!m.check(DieConfig { n: 1000, m: 1 }));
        assert!(!m.check(DieConfig { n: 0, m: 16 }));
    }

    #[test]
    fn search_space_bounds_are_tight() {
        let m = model();
        let nmax = m.n_max();
        let mmax = m.m_max();
        assert!(m.check(DieConfig { n: nmax, m: 1 }));
        assert!(!m.check(DieConfig { n: nmax + 1, m: 1 }));
        assert!(m.check(DieConfig { n: 1, m: mmax }));
        assert!(!m.check(DieConfig { n: 1, m: mmax + 1 }));
        // sanity: U250 die supports a handful of aggregate PEs and a few
        // hundred update PEs
        assert!(nmax >= 4 && nmax < 64, "nmax={nmax}");
        assert!(mmax >= 256 && mmax < 1024, "mmax={mmax}");
    }

    #[test]
    fn utilization_monotone_in_n_and_m() {
        let m = model();
        let base = m.utilization(DieConfig { n: 2, m: 128 });
        let more_n = m.utilization(DieConfig { n: 4, m: 128 });
        let more_m = m.utilization(DieConfig { n: 2, m: 256 });
        assert!(more_n.max_fraction() > base.max_fraction());
        assert!(more_m.max_fraction() > base.max_fraction());
    }

    #[test]
    fn u250_totals() {
        assert!((U250.ddr_gbs_total() - 77.0).abs() < 1e-9);
        assert_eq!(U250.freq_hz(), 3.0e8);
    }

    #[test]
    fn partial_cards_scale_bandwidth_with_dies() {
        assert!((U250_HALF.ddr_gbs_total() - 38.5).abs() < 1e-9);
        assert!((U250_QUARTER.ddr_gbs_total() - 19.25).abs() < 1e-9);
    }

    #[test]
    fn fleet_parses_counts_and_preserves_order() {
        let fleet = parse_fleet("u250-half:2,u250:2").unwrap();
        assert_eq!(fleet.len(), 4);
        assert_eq!(fleet[0].kind, "u250-half");
        assert_eq!(fleet[1].kind, "u250-half");
        assert_eq!(fleet[2].kind, "u250");
        assert_eq!(fleet[0].fpga.dies, 2);
        assert_eq!(fleet[2].fpga.dies, 4);
        assert_eq!(fleet_spec_string(&fleet), "u250-half:2,u250:2");
        // bare kind = count 1
        let one = parse_fleet("u250-shared").unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].pcie_gbs, 8.0);
    }

    #[test]
    fn fleet_rejects_bad_specs() {
        assert!(parse_fleet("").is_err());
        assert!(parse_fleet("u9999:2").is_err());
        assert!(parse_fleet("u250:0").is_err());
        assert!(parse_fleet("u250:x").is_err());
        assert!(parse_fleet("u250:2,,u250").is_err());
    }

    #[test]
    fn homogeneous_fleet_is_paper_platform() {
        let fleet = homogeneous_fleet(4);
        assert_eq!(fleet.len(), 4);
        assert!(fleet.iter().all(|d| d.kind == "u250"
            && d.die == DEFAULT_DIE
            && d.pcie_gbs == 16.0
            && d.fpga.dies == 4));
        assert_eq!(fleet_spec_string(&fleet), "u250:4");
    }
}
