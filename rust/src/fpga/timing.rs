//! Kernel timing model — §6.2, Eqs. 5–9, generalized to L layers.
//!
//! All times are per mini-batch on one FPGA. The model works on a
//! [`BatchShape`] (the |V^l| / |A^l| / f^l statistics of a sampled
//! mini-batch) so it can be driven either by the paper's nominal
//! parameters or by *measured* shapes from the real sampler. Depth is a
//! first-class input: every per-layer quantity is a vector indexed as in
//! DESIGN.md §Mini-batch wire format, and the batch time sums L
//! aggregate/update stages instead of two hard-coded ones.

use super::{DieConfig, FpgaSpec};

/// Mini-batch shape statistics for an L-layer GNN.
#[derive(Clone, Debug)]
pub struct BatchShape {
    /// Sampled vertex counts per level: `v[l]`, l = 0..=L (`v[L]` targets).
    pub v: Vec<f64>,
    /// Sampled edge counts per layer: `a[l-1]` = |A^l| (self edges
    /// included), l = 1..=L.
    pub a: Vec<f64>,
    /// Feature widths per level: `f[l]`, l = 0..=L.
    pub f: Vec<f64>,
}

impl BatchShape {
    /// Nominal paper shape: B targets, one fanout per layer (DESIGN.md
    /// §Mini-batch wire format order — input-side hop first), dedup
    /// ignored (upper bound — matches how the paper sizes its DSE input).
    pub fn nominal(batch: f64, fanouts: &[f64], f: &[f64]) -> BatchShape {
        let lcount = fanouts.len();
        assert_eq!(f.len(), lcount + 1, "need one feature width per level");
        let mut v = vec![0.0; lcount + 1];
        let mut a = vec![0.0; lcount];
        v[lcount] = batch;
        for l in (1..=lcount).rev() {
            v[l - 1] = v[l] * (fanouts[l - 1] + 1.0);
            a[l - 1] = v[l] * (fanouts[l - 1] + 1.0);
        }
        BatchShape { v, a, f: f.to_vec() }
    }

    /// Number of GNN layers L.
    pub fn layers(&self) -> usize {
        self.a.len()
    }

    /// Total sampled vertices (the NVTPS numerator contribution).
    pub fn vertices(&self) -> f64 {
        self.v.iter().sum()
    }

    /// Model parameter bytes (f32): Σ_l f^{l-1}·f^l (GCN; SAGE doubles it
    /// via the W_self path — handled by the caller's `param_scale`).
    /// Rounded to the nearest byte: truncation undercounts whenever the
    /// f/`param_scale` product is not integral.
    pub fn param_bytes(&self, param_scale: f64) -> u64 {
        let elems: f64 = (1..self.f.len()).map(|l| self.f[l - 1] * self.f[l]).sum();
        (elems * 4.0 * param_scale).round() as u64
    }
}

/// Memory-path bandwidths seen by one FPGA.
#[derive(Clone, Copy, Debug)]
pub struct Bandwidths {
    /// FPGA-local DDR (GB/s) — full card.
    pub ddr_gbs: f64,
    /// Host↔FPGA PCIe (GB/s).
    pub pcie_gbs: f64,
}

/// Architecture-dependent cost knobs of the §6.2 model (HyScale-GNN's
/// observation: the cost model must price each architecture's stages
/// differently, or the DSE picks the wrong design point).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelCost {
    /// Update-stage (MLP) work multiplier vs GCN's single `fin×fout`
    /// matmul per layer: SAGE's separate self/neighbor weights and
    /// GIN's 2-layer MLP double it.
    pub param_scale: f64,
    /// Edge-proportional attention work added *serially* to the layer
    /// time (per-edge logits + softmax + score backward cannot overlap
    /// the aggregate they gate). 0 for non-attention models.
    pub attn_edge_scale: f64,
}

impl ModelCost {
    /// GCN baseline: unit update work, no attention term.
    pub const GCN: ModelCost = ModelCost { param_scale: 1.0, attn_edge_scale: 0.0 };

    /// Cost knobs for a model-zoo architecture
    /// (`runtime::model_ops::MODEL_NAMES`).
    pub fn for_model(model: &str) -> anyhow::Result<ModelCost> {
        Ok(match model {
            "gcn" => ModelCost::GCN,
            "sage" => ModelCost { param_scale: 2.0, attn_edge_scale: 0.0 },
            // GAT: one transform like GCN, plus 2 serial edge-parallel
            // passes (forward softmax, backward scores) over |A^l|·f^l
            "gat" => ModelCost { param_scale: 1.0, attn_edge_scale: 2.0 },
            "gin" => ModelCost { param_scale: 2.0, attn_edge_scale: 0.0 },
            other => anyhow::bail!(
                "unknown model '{other}', expected one of {}",
                crate::runtime::model_ops::MODEL_NAMES.join("|")
            ),
        })
    }
}

/// Per-layer timing breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerTiming {
    pub load_s: f64,
    pub compute_s: f64,
    pub aggregate_s: f64,
    pub update_s: f64,
    /// Edge-proportional attention time (0 for non-attention models) —
    /// serial with the pipelined aggregate/update pair.
    pub attn_s: f64,
    /// max(aggregate, update) + attn: aggregate and update pipeline,
    /// the attention pass gates them.
    pub layer_s: f64,
}

/// Timing for one mini-batch (forward + loss + backward).
#[derive(Clone, Debug, Default)]
pub struct BatchTiming {
    /// One entry per layer, layer 1 (input side) first.
    pub layers: Vec<LayerTiming>,
    pub fp_s: f64,
    pub lc_s: f64,
    pub bp_s: f64,
    /// t_GNN = t_FP + t_LC + t_BP (Eq. 5).
    pub gnn_s: f64,
}

/// The §6.2 kernel timing model for a whole FPGA (dies × per-die config).
#[derive(Clone, Copy, Debug)]
pub struct TimingModel {
    pub spec: FpgaSpec,
    pub die: DieConfig,
    pub bw: Bandwidths,
}

pub const S_FEAT: f64 = 4.0; // f32 feature bytes (Eq. 7's S_feat)

impl TimingModel {
    pub fn new(spec: FpgaSpec, die: DieConfig, pcie_gbs: f64) -> TimingModel {
        TimingModel {
            spec,
            die,
            bw: Bandwidths { ddr_gbs: spec.ddr_gbs_total(), pcie_gbs },
        }
    }

    /// FPGA-level PE counts (all dies work on the same batch).
    pub fn n_total(&self) -> f64 {
        (self.die.n as usize * self.spec.dies) as f64
    }
    pub fn m_total(&self) -> f64 {
        (self.die.m as usize * self.spec.dies) as f64
    }

    /// Eq. 7: vertex-feature loading time for layer `l` (1-based).
    /// β is the local-fetch ratio; layers ≥ 2 read the previous layer's
    /// results that are already on-card, so β is forced to 1 there.
    pub fn t_load(&self, shape: &BatchShape, l: usize, beta: f64) -> f64 {
        let (rows, width) = (shape.v[l - 1], shape.f[l - 1]);
        let beta = if l >= 2 { 1.0 } else { beta };
        let bytes = rows * width * S_FEAT;
        bytes * beta / (self.bw.ddr_gbs * 1e9) + bytes * (1.0 - beta) / (self.bw.pcie_gbs * 1e9)
    }

    /// Eq. 8: aggregation compute time for layer `l`.
    pub fn t_compute(&self, shape: &BatchShape, l: usize) -> f64 {
        shape.a[l - 1] * shape.f[l - 1]
            / (self.n_total() * self.spec.pe_simd as f64 * self.spec.freq_hz())
    }

    /// Eq. 9: feature-update (MLP) time for layer `l`.
    pub fn t_update(&self, shape: &BatchShape, l: usize) -> f64 {
        shape.v[l] * shape.f[l - 1] * shape.f[l] / (self.m_total() * self.spec.freq_hz())
    }

    /// Eq. 6 + pipeline composition for one layer.
    pub fn layer(&self, shape: &BatchShape, l: usize, beta: f64) -> LayerTiming {
        let load_s = self.t_load(shape, l, beta);
        let compute_s = self.t_compute(shape, l);
        let aggregate_s = load_s.max(compute_s);
        let update_s = self.t_update(shape, l);
        LayerTiming { load_s, compute_s, aggregate_s, update_s, layer_s: aggregate_s.max(update_s) }
    }

    /// Full mini-batch timing (Eq. 5): Σ over the L layers of the
    /// pipelined layer time, plus loss calculation and the mirrored
    /// backward pass. The [`ModelCost`] knobs price the architecture:
    /// `param_scale` multiplies the update stage (1 for GCN, 2 for
    /// SAGE/GIN), `attn_edge_scale` adds the edge-proportional
    /// attention term (GAT) on the aggregation PEs, serial with the
    /// pipelined aggregate/update pair.
    pub fn batch(&self, shape: &BatchShape, beta: f64, cost: ModelCost) -> BatchTiming {
        let lcount = shape.layers();
        let mut layers = Vec::with_capacity(lcount);
        let mut fp_s = 0.0;
        for l in 1..=lcount {
            let mut lt = self.layer(shape, l, beta);
            lt.update_s *= cost.param_scale;
            lt.attn_s = cost.attn_edge_scale * shape.a[l - 1] * shape.f[l]
                / (self.n_total() * self.spec.pe_simd as f64 * self.spec.freq_hz());
            lt.layer_s = lt.aggregate_s.max(lt.update_s) + lt.attn_s;
            fp_s += lt.layer_s;
            layers.push(lt);
        }
        // loss calculation: softmax+CE over |V^L|·f^L, on the update PEs
        let lc_s = shape.v[lcount] * shape.f[lcount] / (self.m_total() * self.spec.freq_hz());
        // backward pass: same dataflow reversed (paper: "similar
        // computation as forward propagation but in the reverse direction")
        let bp_s = fp_s;
        BatchTiming { layers, fp_s, lc_s, bp_s, gnn_s: fp_s + lc_s + bp_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::U250;

    fn model() -> TimingModel {
        TimingModel::new(U250, DieConfig { n: 2, m: 512 }, 16.0)
    }

    fn shape() -> BatchShape {
        // paper nominal: B=1024, fanouts [25, 10], products dims
        BatchShape::nominal(1024.0, &[25.0, 10.0], &[100.0, 128.0, 47.0])
    }

    #[test]
    fn nominal_shape_counts() {
        let s = shape();
        assert_eq!(s.layers(), 2);
        assert_eq!(s.v[2], 1024.0);
        assert_eq!(s.v[1], 1024.0 * 11.0);
        assert_eq!(s.v[0], 1024.0 * 11.0 * 26.0);
        assert_eq!(s.a[0], s.v[0]);
        assert_eq!(s.a[1], s.v[1]);
    }

    #[test]
    fn three_layer_nominal_shape_counts() {
        let s = BatchShape::nominal(1024.0, &[15.0, 10.0, 5.0], &[100.0, 128.0, 128.0, 47.0]);
        assert_eq!(s.layers(), 3);
        assert_eq!(s.v[3], 1024.0);
        assert_eq!(s.v[2], 1024.0 * 6.0);
        assert_eq!(s.v[1], 1024.0 * 6.0 * 11.0);
        assert_eq!(s.v[0], 1024.0 * 6.0 * 11.0 * 16.0);
        assert_eq!(s.a[2], s.v[2]);
        assert_eq!(s.a[0], s.v[0]);
    }

    #[test]
    fn load_time_splits_by_beta() {
        let m = model();
        let s = shape();
        let local = m.t_load(&s, 1, 1.0);
        let remote = m.t_load(&s, 1, 0.0);
        let mixed = m.t_load(&s, 1, 0.5);
        // PCIe (16 GB/s) is slower than card DDR (77 GB/s)
        assert!(remote > local);
        assert!(local < mixed && mixed < remote);
        // exact endpoints
        let bytes = s.v[0] * s.f[0] * 4.0;
        assert!((local - bytes / 77.0e9).abs() / local < 1e-9);
        assert!((remote - bytes / 16.0e9).abs() / remote < 1e-9);
    }

    #[test]
    fn upper_layer_loads_are_always_local() {
        let m = model();
        let s = BatchShape::nominal(256.0, &[8.0, 5.0, 3.0], &[100.0, 128.0, 128.0, 47.0]);
        for l in 2..=3 {
            assert_eq!(m.t_load(&s, l, 0.0), m.t_load(&s, l, 1.0), "layer {l}");
        }
    }

    #[test]
    fn compute_scales_inverse_with_n() {
        let s = shape();
        let m1 = TimingModel::new(U250, DieConfig { n: 2, m: 512 }, 16.0);
        let m2 = TimingModel::new(U250, DieConfig { n: 4, m: 512 }, 16.0);
        let r = m1.t_compute(&s, 1) / m2.t_compute(&s, 1);
        assert!((r - 2.0).abs() < 1e-9);
    }

    #[test]
    fn update_scales_inverse_with_m() {
        let s = shape();
        let m1 = TimingModel::new(U250, DieConfig { n: 2, m: 512 }, 16.0);
        let m2 = TimingModel::new(U250, DieConfig { n: 2, m: 1024 }, 16.0);
        let r = m1.t_update(&s, 1) / m2.t_update(&s, 1);
        assert!((r - 2.0).abs() < 1e-9);
    }

    #[test]
    fn layer_time_is_pipelined_max() {
        let m = model();
        let s = shape();
        let l = m.layer(&s, 1, 0.8);
        assert_eq!(l.aggregate_s, l.load_s.max(l.compute_s));
        assert_eq!(l.layer_s, l.aggregate_s.max(l.update_s));
    }

    #[test]
    fn batch_time_composition() {
        let m = model();
        let s = shape();
        let b = m.batch(&s, 0.8, ModelCost::GCN);
        assert_eq!(b.layers.len(), 2);
        assert!((b.gnn_s - (b.fp_s + b.lc_s + b.bp_s)).abs() < 1e-15);
        assert!(b.fp_s >= b.layers[0].layer_s);
        assert!(b.gnn_s > 0.0);
    }

    #[test]
    fn batch_time_sums_all_layers_at_depth_three() {
        let m = model();
        let s = BatchShape::nominal(256.0, &[8.0, 5.0, 3.0], &[100.0, 128.0, 128.0, 47.0]);
        let b = m.batch(&s, 0.8, ModelCost::GCN);
        assert_eq!(b.layers.len(), 3);
        let sum: f64 = b.layers.iter().map(|l| l.layer_s).sum();
        assert!((b.fp_s - sum).abs() < 1e-15);
        // a third layer at positive work strictly increases the total vs
        // the same shape truncated to 2 layers
        let s2 = BatchShape { v: s.v[..3].to_vec(), a: s.a[..2].to_vec(), f: s.f[..3].to_vec() };
        let b2 = m.batch(&s2, 0.8, ModelCost::GCN);
        assert!(b.fp_s > b2.fp_s);
    }

    #[test]
    fn sage_param_scale_slows_update_bound_configs() {
        // tiny n so aggregation dominates → param_scale may not matter;
        // big n / small m so update dominates → param_scale must matter.
        let s = shape();
        let m = TimingModel::new(U250, DieConfig { n: 8, m: 64 }, 16.0);
        let gcn = m.batch(&s, 1.0, ModelCost::GCN);
        let sage = m.batch(&s, 1.0, ModelCost::for_model("sage").unwrap());
        assert!(sage.gnn_s > gcn.gnn_s);
    }

    #[test]
    fn model_costs_resolve_and_reject_like_the_zoo_registry() {
        assert_eq!(ModelCost::for_model("gcn").unwrap(), ModelCost::GCN);
        assert_eq!(ModelCost::for_model("sage").unwrap().param_scale, 2.0);
        assert!(ModelCost::for_model("gat").unwrap().attn_edge_scale > 0.0);
        assert_eq!(ModelCost::for_model("gin").unwrap().param_scale, 2.0);
        let err = ModelCost::for_model("transformer").unwrap_err().to_string();
        assert!(err.contains("unknown model 'transformer'"), "{err}");
        assert!(err.contains("gcn|sage|gat|gin"), "{err}");
    }

    #[test]
    fn attention_makespan_is_strictly_above_matched_gcn() {
        // ISSUE 8 acceptance: the attention term is additive (serial
        // with the pipelined stages), so at ANY matched shape — whether
        // load-, compute-, or update-bound — GAT prices strictly above
        // GCN, and the per-layer breakdown exposes the term.
        let s = shape();
        for die in [DieConfig { n: 2, m: 512 }, DieConfig { n: 8, m: 64 }] {
            let m = TimingModel::new(U250, die, 16.0);
            let gcn = m.batch(&s, 0.8, ModelCost::GCN);
            let gat = m.batch(&s, 0.8, ModelCost::for_model("gat").unwrap());
            assert!(gat.gnn_s > gcn.gnn_s, "die {die:?}");
            for (lg, lc) in gat.layers.iter().zip(&gcn.layers) {
                assert!(lg.attn_s > 0.0);
                assert_eq!(lc.attn_s, 0.0);
                assert!(lg.layer_s > lc.layer_s);
            }
        }
    }

    #[test]
    fn param_bytes_rounds_instead_of_truncating() {
        // (1·1 + 1·1)·4 = 8 parameter bytes; a fractional param_scale
        // used to truncate (0.7 → 5.6 read as 5) instead of rounding
        let s = BatchShape { v: vec![1.0; 3], a: vec![1.0; 2], f: vec![1.0; 3] };
        assert_eq!(s.param_bytes(1.0), 8);
        assert_eq!(s.param_bytes(0.7), 6, "5.6 rounds up, not down");
        assert_eq!(s.param_bytes(0.3), 2, "2.4 rounds down");
        // paper shape at GCN/SAGE scales stays exact
        let paper = BatchShape::nominal(1024.0, &[25.0, 10.0], &[100.0, 128.0, 47.0]);
        assert_eq!(paper.param_bytes(1.0), (100 * 128 + 128 * 47) * 4);
        assert_eq!(paper.param_bytes(2.0), 2 * (100 * 128 + 128 * 47) * 4);
        // depth adds a term per layer
        let deep = BatchShape::nominal(1024.0, &[15.0, 10.0, 5.0], &[100.0, 128.0, 128.0, 47.0]);
        assert_eq!(deep.param_bytes(1.0), (100 * 128 + 128 * 128 + 128 * 47) * 4);
    }

    #[test]
    fn beta_one_is_never_slower() {
        let m = model();
        let s = shape();
        let fast = m.batch(&s, 1.0, ModelCost::GCN);
        let slow = m.batch(&s, 0.3, ModelCost::GCN);
        assert!(fast.gnn_s <= slow.gnn_s);
    }
}
