//! Compressed Sparse Row graph storage.
//!
//! Vertices are `u32` (the largest paper graph has 2.4M vertices; u32 also
//! halves memory traffic during sampling, which matters because sampling
//! is on the host critical path — Eq. 5). Offsets are `usize`.
//!
//! Storage is either owned vectors (the in-memory build path) or a
//! zero-copy view into an mmap'd pack file (`ondisk`), so the sampler
//! reads out-of-core graphs through the same `neighbors()` seam.

use std::sync::Arc;

use super::ondisk::Mapping;

/// Backing storage for a CSR: owned vectors, or byte ranges inside a
/// shared mapping of the on-disk pack format (64-bit little-endian hosts
/// only; other hosts decode into the Owned variant at load time).
#[derive(Clone, Debug)]
enum Storage {
    Owned { offsets: Vec<usize>, adj: Vec<u32> },
    Mapped {
        map: Arc<Mapping>,
        /// Byte offset of the `(n+1) × u64` offsets section.
        offsets_at: usize,
        num_vertices: usize,
        /// Byte offset of the `m × u32` adjacency section.
        adj_at: usize,
        num_edges: usize,
    },
}

/// CSR adjacency (out-edges). For GNN sampling we store the graph with
/// edges pointing from a vertex to the neighbors it *aggregates from*,
/// i.e. `neighbors(v)` are the candidates for `N_s(v)` in Algorithm 1.
#[derive(Clone, Debug)]
pub struct Csr {
    storage: Storage,
}

impl Csr {
    /// Build from an edge list `(src, dst)` meaning "src aggregates from
    /// dst". Duplicate edges are kept (multi-edges are legal in sampled
    /// blocks); self loops are kept. Counting-sort construction: O(V+E).
    pub fn from_edges(num_vertices: usize, edges: &[(u32, u32)]) -> Csr {
        let mut counts = vec![0usize; num_vertices + 1];
        for &(s, _) in edges {
            counts[s as usize + 1] += 1;
        }
        for i in 0..num_vertices {
            counts[i + 1] += counts[i];
        }
        // `counts` now *is* the offsets array. Use it directly as the
        // write cursor (counts[v] walks from offsets[v] to offsets[v+1])
        // and shift it back down afterwards — no cloned second array.
        let mut adj = vec![0u32; edges.len()];
        for &(s, d) in edges {
            adj[counts[s as usize]] = d;
            counts[s as usize] += 1;
        }
        for v in (1..=num_vertices).rev() {
            counts[v] = counts[v - 1];
        }
        if num_vertices > 0 {
            counts[0] = 0;
        }
        Csr::from_parts(counts, adj)
    }

    /// Build the symmetrised graph (u→v and v→u for every input edge),
    /// which is how Reddit/Yelp/Amazon/products are used for GraphSAGE/GCN.
    pub fn from_edges_symmetric(num_vertices: usize, edges: &[(u32, u32)]) -> Csr {
        let mut both = Vec::with_capacity(edges.len() * 2);
        for &(s, d) in edges {
            both.push((s, d));
            if s != d {
                both.push((d, s));
            }
        }
        Csr::from_edges(num_vertices, &both)
    }

    /// Assemble from pre-built arrays (offsets.len() == n+1, last offset
    /// == adj.len()). Callers are trusted; `validate()` checks the rest.
    pub fn from_parts(offsets: Vec<usize>, adj: Vec<u32>) -> Csr {
        Csr { storage: Storage::Owned { offsets, adj } }
    }

    /// Zero-copy view into a mapping of the pack format. Only sound on
    /// 64-bit little-endian hosts with 8-aligned `offsets_at` and
    /// 4-aligned `adj_at`; [`ondisk::load`] enforces all of that and
    /// falls back to an owned decode elsewhere.
    pub(crate) fn from_mapping(
        map: Arc<Mapping>,
        offsets_at: usize,
        num_vertices: usize,
        adj_at: usize,
        num_edges: usize,
    ) -> Csr {
        Csr { storage: Storage::Mapped { map, offsets_at, num_vertices, adj_at, num_edges } }
    }

    #[inline]
    fn offsets(&self) -> &[usize] {
        match &self.storage {
            Storage::Owned { offsets, .. } => offsets,
            Storage::Mapped { map, offsets_at, num_vertices, .. } => {
                map.usize_slice(*offsets_at, num_vertices + 1)
            }
        }
    }

    #[inline]
    fn adj(&self) -> &[u32] {
        match &self.storage {
            Storage::Owned { adj, .. } => adj,
            Storage::Mapped { map, adj_at, num_edges, .. } => map.u32_slice(*adj_at, *num_edges),
        }
    }

    /// True when the adjacency is served from an mmap'd pack file.
    pub fn is_mapped(&self) -> bool {
        matches!(self.storage, Storage::Mapped { .. })
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        match &self.storage {
            Storage::Owned { offsets, .. } => offsets.len() - 1,
            Storage::Mapped { num_vertices, .. } => *num_vertices,
        }
    }

    #[inline]
    pub fn num_edges(&self) -> usize {
        match &self.storage {
            Storage::Owned { adj, .. } => adj.len(),
            Storage::Mapped { num_edges, .. } => *num_edges,
        }
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        let v = v as usize;
        let offsets = self.offsets();
        offsets[v + 1] - offsets[v]
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let v = v as usize;
        let offsets = self.offsets();
        &self.adj()[offsets[v]..offsets[v + 1]]
    }

    /// Total degree of a vertex set (used by partition balance constraints).
    pub fn total_degree(&self, vs: &[u32]) -> usize {
        vs.iter().map(|&v| self.degree(v)).sum()
    }

    /// Maximum out-degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as u32).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Structural validation — every target in range, offsets monotone.
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.num_vertices() as u32;
        let offsets = self.offsets();
        anyhow::ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "offsets not monotone"
        );
        anyhow::ensure!(
            *offsets.last().unwrap() == self.num_edges(),
            "offsets do not cover adjacency"
        );
        if let Some(&bad) = self.adj().iter().find(|&&t| t >= n) {
            anyhow::bail!("edge target {bad} out of range (n={n})");
        }
        Ok(())
    }

    /// Degree histogram up to `buckets` (last bucket = overflow); used by
    /// dataset stats reporting.
    pub fn degree_histogram(&self, buckets: usize) -> Vec<usize> {
        let mut h = vec![0usize; buckets + 1];
        for v in 0..self.num_vertices() as u32 {
            let d = self.degree(v).min(buckets);
            h[d] += 1;
        }
        h
    }

    /// Approximate *heap* footprint in bytes. Mapped storage reports 0 —
    /// its pages live in the page cache, not the process heap, which is
    /// exactly what the out-of-core path is accounting for.
    pub fn bytes(&self) -> usize {
        match &self.storage {
            Storage::Owned { offsets, adj } => {
                offsets.len() * std::mem::size_of::<usize>() + adj.len() * 4
            }
            Storage::Mapped { .. } => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Csr {
        // 0→1, 0→2, 1→2, 3→0, 2→2 (self loop)
        Csr::from_edges(4, &[(0, 1), (0, 2), (1, 2), (3, 0), (2, 2)])
    }

    #[test]
    fn basic_shape() {
        let g = toy();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 1);
        assert_eq!(g.degree(2), 1);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[0]);
        g.validate().unwrap();
    }

    #[test]
    fn unsorted_input_grouped_correctly() {
        let g = Csr::from_edges(3, &[(2, 0), (0, 1), (2, 1), (0, 0)]);
        assert_eq!(g.neighbors(0), &[1, 0]);
        assert_eq!(g.neighbors(1), &[] as &[u32]);
        assert_eq!(g.neighbors(2), &[0, 1]);
    }

    #[test]
    fn symmetric_doubles_edges_except_self_loops() {
        let g = Csr::from_edges_symmetric(3, &[(0, 1), (2, 2)]);
        assert_eq!(g.num_edges(), 3); // 0→1, 1→0, 2→2
        assert_eq!(g.neighbors(1), &[0]);
        assert_eq!(g.neighbors(2), &[2]);
    }

    #[test]
    fn isolated_vertices_have_zero_degree() {
        let g = Csr::from_edges(5, &[(0, 1)]);
        assert_eq!(g.degree(4), 0);
        assert_eq!(g.neighbors(4), &[] as &[u32]);
    }

    #[test]
    fn validate_catches_out_of_range() {
        // construct a malformed CSR directly
        let g = Csr::from_parts(vec![0, 1], vec![7]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(0, &[]);
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
        g.validate().unwrap();
    }

    #[test]
    fn degree_histogram_counts() {
        let g = toy();
        let h = g.degree_histogram(2);
        // degrees: [2,1,1,1] → bucket1: 3 vertices, bucket2: 1
        assert_eq!(h[1], 3);
        assert_eq!(h[2], 1);
    }

    #[test]
    fn total_degree_sums() {
        let g = toy();
        assert_eq!(g.total_degree(&[0, 3]), 3);
    }
}
