//! Dataset registry — the paper's Table 4.
//!
//! | Dataset            | #Vertices | #Edges      | f0  | f1  | f2  |
//! |--------------------|-----------|-------------|-----|-----|-----|
//! | Reddit (RD)        | 232,965   | 23,213,838  | 602 | 128 | 41  |
//! | Yelp (YP)          | 716,847   | 13,954,819  | 300 | 128 | 100 |
//! | Amazon (AM)        | 1,569,960 | 264,339,468 | 200 | 128 | 107 |
//! | ogbn-products (PR) | 2,449,029 | 61,859,140  | 100 | 128 | 47  |
//!
//! `build(scale_shift)` produces an R-MAT graph with |V| and |E| divided by
//! `2^scale_shift`: shift 0 = full-scale (analytic benches, topology only),
//! shift 4 = 1/16 (the real execution path). Feature dims are never scaled
//! — they determine artifact shapes and the performance model.

use super::csr::Csr;
use super::features::FeatureGen;
use super::rmat::{self, RmatParams};
use crate::util::rng::Rng;

/// GNN-layer dimensions (f0 = input features, f1 = hidden, f2 = classes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GnnDims {
    pub f0: usize,
    pub f1: usize,
    pub f2: usize,
}

/// Static description of a dataset (full-scale numbers from Table 4).
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    /// Short key used on the CLI and in EXPERIMENTS.md ("reddit", ...).
    pub key: &'static str,
    /// Paper abbreviation ("RD", ...).
    pub abbrev: &'static str,
    pub vertices: usize,
    pub edges: usize,
    pub dims: GnnDims,
    /// Fraction of vertices used as training targets (paper follows the
    /// standard splits; ~0.66 Reddit, ~0.75 Yelp/Amazon, ~0.08 products).
    pub train_frac: f64,
}

/// The four evaluation datasets, in the paper's order.
pub const REGISTRY: [DatasetSpec; 4] = [
    DatasetSpec {
        key: "reddit",
        abbrev: "RD",
        vertices: 232_965,
        edges: 23_213_838,
        dims: GnnDims { f0: 602, f1: 128, f2: 41 },
        train_frac: 0.66,
    },
    DatasetSpec {
        key: "yelp",
        abbrev: "YP",
        vertices: 716_847,
        edges: 13_954_819,
        dims: GnnDims { f0: 300, f1: 128, f2: 100 },
        train_frac: 0.75,
    },
    DatasetSpec {
        key: "amazon",
        abbrev: "AM",
        vertices: 1_569_960,
        edges: 264_339_468,
        dims: GnnDims { f0: 200, f1: 128, f2: 107 },
        train_frac: 0.75,
    },
    DatasetSpec {
        key: "ogbn-products",
        abbrev: "PR",
        vertices: 2_449_029,
        edges: 61_859_140,
        dims: GnnDims { f0: 100, f1: 128, f2: 47 },
        train_frac: 0.08,
    },
];

/// Tiny synthetic dataset matching the `tiny` AOT artifact dims —
/// quickstart + integration tests (not part of the paper's Table 4).
pub const TINY: DatasetSpec = DatasetSpec {
    key: "tiny",
    abbrev: "TN",
    vertices: 4096,
    edges: 65_536,
    dims: GnnDims { f0: 32, f1: 16, f2: 8 },
    train_frac: 0.5,
};

/// Look up a dataset by key or abbreviation (case-insensitive).
pub fn lookup(name: &str) -> anyhow::Result<DatasetSpec> {
    let lower = name.to_ascii_lowercase();
    REGISTRY
        .iter()
        .chain(std::iter::once(&TINY))
        .find(|s| s.key == lower || s.abbrev.to_ascii_lowercase() == lower)
        .copied()
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown dataset '{name}' (known: {})",
                REGISTRY.iter().map(|s| s.key).collect::<Vec<_>>().join(", ")
            )
        })
}

/// A materialised dataset: topology + feature/label generator + train set.
pub struct Dataset {
    pub spec: DatasetSpec,
    /// Effective vertex/edge counts after scaling.
    pub graph: Csr,
    pub features: FeatureGen,
    /// Training target vertices (deterministic subset).
    pub train_vertices: Vec<u32>,
    /// The scale shift this instance was built with.
    pub scale_shift: u32,
}

impl DatasetSpec {
    /// Effective counts under a scale shift.
    pub fn scaled_vertices(&self, shift: u32) -> usize {
        (self.vertices >> shift).max(1024)
    }
    pub fn scaled_edges(&self, shift: u32) -> usize {
        (self.edges >> shift).max(4096)
    }

    /// Build the dataset deterministically. `seed` controls everything.
    pub fn build(&self, scale_shift: u32, seed: u64) -> Dataset {
        let n_target = self.scaled_vertices(scale_shift);
        // R-MAT needs a power-of-two id space; round up, generate, then
        // fold ids into [0, n_target) to keep the exact vertex count.
        let scale = (usize::BITS - (n_target - 1).leading_zeros()) as u32;
        let m = self.scaled_edges(scale_shift);
        let _ = scale;
        let mut rng = Rng::new(seed ^ crate::util::rng::hash64(self.key.len() as u64 ^ self.vertices as u64));
        // community-mixture R-MAT: power-law degrees + METIS-partitionable
        // community structure (see rmat::generate_community_edges). One
        // community per ~1k vertices, 90% intra-community edges — yields
        // 4-way edge cuts in the 10–25% band real datasets show.
        let communities = ((n_target as u32) / 1024).max(16);
        let mut edges = rmat::generate_community_edges(
            &mut rng,
            n_target as u32,
            m,
            RmatParams::default(),
            communities,
            0.90,
        );
        rmat::permute_ids(&mut edges, n_target as u32, seed ^ 0x9e37);
        let graph = Csr::from_edges_symmetric(n_target, &edges);
        let features = FeatureGen::new(seed ^ 0xFEED, self.dims.f0, self.dims.f2);
        // Deterministic train split: hash-based Bernoulli per vertex.
        const TRAIN_TAG: u64 = 0x7261_316e; // "ra1n"
        let train_vertices: Vec<u32> = (0..n_target as u32)
            .filter(|&v| {
                let h = crate::util::rng::hash64(seed ^ TRAIN_TAG ^ v as u64);
                ((h >> 11) as f64 / (1u64 << 53) as f64) < self.train_frac
            })
            .collect();
        Dataset { spec: *self, graph, features, train_vertices, scale_shift }
    }
}

impl Dataset {
    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{} (shift {}): |V|={} |E|={} f=({},{},{}) train={}",
            self.spec.key,
            self.scale_shift,
            self.graph.num_vertices(),
            self.graph.num_edges(),
            self.spec.dims.f0,
            self.spec.dims.f1,
            self.spec.dims.f2,
            self.train_vertices.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table4() {
        assert_eq!(REGISTRY[0].vertices, 232_965);
        assert_eq!(REGISTRY[2].edges, 264_339_468);
        assert_eq!(REGISTRY[3].dims, GnnDims { f0: 100, f1: 128, f2: 47 });
    }

    #[test]
    fn lookup_by_key_and_abbrev() {
        assert_eq!(lookup("reddit").unwrap().abbrev, "RD");
        assert_eq!(lookup("PR").unwrap().key, "ogbn-products");
        assert!(lookup("nope").is_err());
    }

    #[test]
    fn build_scaled_is_deterministic_and_valid() {
        let spec = lookup("reddit").unwrap();
        let a = spec.build(6, 42);
        let b = spec.build(6, 42);
        assert_eq!(a.graph.num_vertices(), b.graph.num_vertices());
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
        assert_eq!(a.train_vertices, b.train_vertices);
        a.graph.validate().unwrap();
        assert_eq!(a.graph.num_vertices(), spec.scaled_vertices(6));
    }

    #[test]
    fn train_fraction_approximate() {
        let spec = lookup("yelp").unwrap();
        let d = spec.build(5, 7);
        let frac = d.train_vertices.len() as f64 / d.graph.num_vertices() as f64;
        assert!((frac - spec.train_frac).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn scaled_counts_have_floors() {
        let spec = lookup("reddit").unwrap();
        assert!(spec.scaled_vertices(30) >= 1024);
        assert!(spec.scaled_edges(30) >= 4096);
    }
}
