//! Planted-centroid feature / label model.
//!
//! Real dataset features are not redistributable, so we synthesise them:
//! every vertex gets a latent class; its feature vector is that class's
//! centroid plus noise, generated *on demand* from a stateless hash so the
//! full |V|×f0 matrix (1.2 GB for Amazon) never needs to be materialised
//! on the host. A GNN trained on this signal converges (loss ↓), which is
//! what the end-to-end example must demonstrate; the *performance* model
//! only consumes feature byte-counts, which are exact.

use std::sync::Arc;

use crate::graph::ondisk::Mapping;
use crate::util::rng::{hash64, Rng};

/// Row-major feature shard inside an mmap'd pack file (the on-disk tier
/// below host DRAM). Rows were materialised from the same generator at
/// pack time, so serving them from the mapping is bit-identical to
/// recomputing — only the source of the bytes changes.
#[derive(Clone, Debug)]
struct Backing {
    map: Arc<Mapping>,
    /// Byte offset of the `rows × feat_dim × f32` matrix.
    at: usize,
    rows: usize,
}

/// Deterministic per-vertex feature/label generator.
#[derive(Clone, Debug)]
pub struct FeatureGen {
    seed: u64,
    feat_dim: usize,
    num_classes: usize,
    /// Class centroids, row-major `[num_classes, feat_dim]`.
    centroids: Vec<f32>,
    /// Noise stddev relative to centroid scale.
    noise: f32,
    /// When set, `write_features` copies rows out of the pack mapping
    /// instead of recomputing them (labels stay procedural either way).
    backing: Option<Backing>,
}

impl FeatureGen {
    pub fn new(seed: u64, feat_dim: usize, num_classes: usize) -> FeatureGen {
        assert!(num_classes > 0 && feat_dim > 0);
        let mut rng = Rng::new(seed ^ 0xFEA7);
        let centroids: Vec<f32> =
            (0..num_classes * feat_dim).map(|_| rng.normal() as f32).collect();
        FeatureGen { seed, feat_dim, num_classes, centroids, noise: 0.5, backing: None }
    }

    /// The generator seed (stored in the pack header so a loader can
    /// reconstruct the identical centroid model).
    #[inline]
    pub(crate) fn seed(&self) -> u64 {
        self.seed
    }

    /// Serve rows from `rows × feat_dim` f32s at byte offset `at` inside
    /// `map` (the pack file's feature section) instead of recomputing.
    pub(crate) fn set_backing(&mut self, map: Arc<Mapping>, at: usize, rows: usize) {
        self.backing = Some(Backing { map, at, rows });
    }

    /// True when rows are served from an mmap'd pack file.
    pub fn is_mapped(&self) -> bool {
        self.backing.is_some()
    }

    #[inline]
    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    #[inline]
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Latent class of vertex `v` (also its training label).
    #[inline]
    pub fn label(&self, v: u32) -> u32 {
        (hash64(self.seed ^ 0x1abe1 ^ v as u64) % self.num_classes as u64) as u32
    }

    /// Write the feature vector of `v` into `out` (len == feat_dim).
    pub fn write_features(&self, v: u32, out: &mut [f32]) {
        assert_eq!(out.len(), self.feat_dim);
        if let Some(b) = &self.backing {
            debug_assert!((v as usize) < b.rows, "vertex {v} outside backed rows");
            let row = b.map.f32_slice(b.at + v as usize * self.feat_dim * 4, self.feat_dim);
            out.copy_from_slice(row);
            return;
        }
        let class = self.label(v) as usize;
        let base = &self.centroids[class * self.feat_dim..(class + 1) * self.feat_dim];
        // Cheap deterministic noise: one hash yields two 24-bit uniforms
        // (hashing dominates the host feature-gather path — §Perf), each
        // mapped to a centered value. Uniform noise is fine for
        // separability; Box–Muller would double the hash cost.
        let vseed = hash64(self.seed ^ 0xF00D ^ ((v as u64) << 20));
        let scale = 2.0 * self.noise / (1u64 << 24) as f32;
        let mut i = 0;
        while i < self.feat_dim {
            let h = hash64(vseed ^ (i as u64 >> 1));
            let u0 = (h >> 40) as f32 * scale - self.noise;
            out[i] = base[i] + u0;
            if i + 1 < self.feat_dim {
                let u1 = ((h >> 16) & 0xFF_FFFF) as f32 * scale - self.noise;
                out[i + 1] = base[i + 1] + u1;
            }
            i += 2;
        }
    }

    /// Convenience: materialise features for a list of vertices into a
    /// row-major buffer (used to build executable inputs).
    pub fn gather(&self, vs: &[u32], out: &mut [f32]) {
        assert_eq!(out.len(), vs.len() * self.feat_dim);
        for (row, &v) in vs.iter().enumerate() {
            self.write_features(v, &mut out[row * self.feat_dim..(row + 1) * self.feat_dim]);
        }
    }

    /// Bytes per feature vector (f32).
    pub fn bytes_per_vertex(&self) -> usize {
        self.feat_dim * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let g = FeatureGen::new(7, 16, 4);
        let mut a = vec![0.0; 16];
        let mut b = vec![0.0; 16];
        g.write_features(3, &mut a);
        g.write_features(3, &mut b);
        assert_eq!(a, b);
        g.write_features(4, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_in_range_and_spread() {
        let g = FeatureGen::new(1, 8, 7);
        let mut counts = vec![0usize; 7];
        for v in 0..7000u32 {
            counts[g.label(v) as usize] += 1;
        }
        for (c, &n) in counts.iter().enumerate() {
            assert!(n > 700, "class {c} underrepresented: {n}");
        }
    }

    #[test]
    fn same_class_features_are_closer_than_cross_class() {
        let g = FeatureGen::new(11, 32, 3);
        // find vertices per class
        let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); 3];
        for v in 0..300u32 {
            by_class[g.label(v) as usize].push(v);
        }
        let dist = |a: u32, b: u32| {
            let mut fa = vec![0.0f32; 32];
            let mut fb = vec![0.0f32; 32];
            g.write_features(a, &mut fa);
            g.write_features(b, &mut fb);
            fa.iter().zip(&fb).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        let same = dist(by_class[0][0], by_class[0][1]);
        let cross = dist(by_class[0][0], by_class[1][0]);
        assert!(same < cross, "same={same} cross={cross}");
    }

    #[test]
    fn gather_matches_single() {
        let g = FeatureGen::new(5, 4, 2);
        let vs = [9u32, 2, 9];
        let mut buf = vec![0.0; 12];
        g.gather(&vs, &mut buf);
        let mut single = vec![0.0; 4];
        g.write_features(9, &mut single);
        assert_eq!(&buf[0..4], &single[..]);
        assert_eq!(&buf[8..12], &single[..]);
    }
}
