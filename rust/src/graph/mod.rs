//! Graph substrate: CSR storage, synthetic R-MAT generation, and the
//! dataset registry mirroring the paper's evaluation set (Table 4).
//!
//! The paper trains on Reddit / Yelp / Amazon / ogbn-products. Those
//! datasets are not redistributable here, so [`datasets`] builds
//! deterministic R-MAT graphs with the published |V|, |E| and GNN-layer
//! dimensions (and a `scale` knob for the execution path — see DESIGN.md
//! §Substitutions). Vertex features/labels come from a planted-centroid
//! model ([`features`]) so end-to-end training has a learnable signal.

pub mod csr;
pub mod datasets;
pub mod features;
pub mod ondisk;
pub mod rmat;

pub use csr::Csr;
pub use datasets::{Dataset, DatasetSpec, GnnDims};
pub use features::FeatureGen;
