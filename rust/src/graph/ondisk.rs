//! Out-of-core graph storage: the `.hitg` pack format + mmap loader.
//!
//! Every dataset used to be fully materialised in RAM, capping us far
//! below the papers100M-class graphs the paper's CPU+Multi-FPGA platform
//! is built to feed. This module defines a little-endian on-disk layout
//! for CSR + row-major feature shards, a writer that serialises any
//! in-memory [`Dataset`] (or streams a synthetic R-MAT graph in bounded
//! memory), and a loader that maps the file and threads it behind the
//! existing `Csr` / `FeatureGen` seams — the sampler and
//! `FeatureService::gather_into` never know the difference.
//!
//! ## Format (normative; DESIGN.md §Out-of-core storage mirrors this)
//!
//! All integers little-endian. 104-byte header:
//!
//! | field          | type | notes                                   |
//! |----------------|------|-----------------------------------------|
//! | magic          | u64  | ASCII `HITGNNv1`                        |
//! | version        | u32  | currently 1                             |
//! | flags          | u32  | must be 0                               |
//! | num_vertices n | u64  | scaled vertex count                     |
//! | num_edges m    | u64  | directed adj entries (post-symmetrise)  |
//! | feat_dim f0    | u64  |                                         |
//! | hidden_dim f1  | u64  |                                         |
//! | num_classes f2 | u64  |                                         |
//! | feature_seed   | u64  | reconstructs the centroid generator     |
//! | train_count    | u64  |                                         |
//! | scale_shift    | u32  |                                         |
//! | key_len        | u32  | dataset key byte length                 |
//! | full_vertices  | u64  | spec's unscaled \|V\|                   |
//! | full_edges     | u64  | spec's unscaled \|E\|                   |
//! | train_frac     | f64  | IEEE-754 bits                           |
//!
//! Sections follow, each starting 8-aligned (zero padding between):
//! key bytes, offsets `(n+1)×u64`, adj `m×u32`, features `n×f0×f32`
//! (row-major), train vertices `train_count×u32`. The file length must
//! equal the computed total exactly — truncated or oversized files are
//! rejected with a clean `Err`, never a panic.
//!
//! On 64-bit little-endian hosts the offsets/adj/features sections are
//! used zero-copy straight out of the mapping; elsewhere the loader
//! decodes them into owned vectors (correct everywhere, out-of-core
//! only where the fast path applies).

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::Context;

use super::csr::Csr;
use super::datasets::{self, Dataset, DatasetSpec, GnnDims};
use super::features::FeatureGen;
use super::rmat::{self, RmatParams};
use crate::util::rng::{hash64, Rng};

/// ASCII "HITGNNv1" read as a little-endian u64.
pub const MAGIC: u64 = u64::from_le_bytes(*b"HITGNNv1");
/// Current format version.
pub const VERSION: u32 = 1;
/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 104;

/// Streaming-pack memory budget default: edge/feature chunk buffers and
/// the per-bucket adjacency stay under this (plus O(|V|) index state).
pub const DEFAULT_PACK_BUDGET: usize = 64 << 20;

#[inline]
fn pad8(x: usize) -> usize {
    (x + 7) & !7
}

/// Zero-copy reinterpretation of the mapping is sound only when the
/// file's little-endian 8-byte layout *is* the native layout.
#[inline]
pub fn zero_copy_ok() -> bool {
    cfg!(target_endian = "little") && std::mem::size_of::<usize>() == 8
}

// ---------------------------------------------------------------------------
// Mapping: read-only mmap with an owned-buffer fallback
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// A read-only byte mapping of a pack file. On unix this is a real
/// `mmap(PROT_READ, MAP_PRIVATE)` — the kernel pages data in on demand
/// and may evict it under memory pressure, which is what makes the
/// resident set bounded. Elsewhere (or if mmap fails) the file is read
/// into an 8-aligned owned buffer: same API, no paging.
pub struct Mapping {
    ptr: *const u8,
    len: usize,
    /// `Some` = owned fallback buffer (u64 for 8-byte alignment);
    /// `None` = a live mmap that `Drop` must unmap.
    owned: Option<Vec<u64>>,
}

// The mapping is immutable for its whole lifetime (read-only pages /
// never-mutated buffer), so shared references from any thread are fine.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mapping")
            .field("len", &self.len)
            .field("mmap", &self.owned.is_none())
            .finish()
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(unix)]
        if self.owned.is_none() && self.len > 0 {
            unsafe {
                sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
            }
        }
    }
}

impl Mapping {
    /// Map `path` read-only (mmap where available, owned read otherwise).
    pub fn from_file(path: &Path) -> anyhow::Result<Mapping> {
        let mut file =
            File::open(path).with_context(|| format!("open pack file {}", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat pack file {}", path.display()))?
            .len() as usize;
        if len == 0 {
            return Ok(Mapping { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0, owned: Some(Vec::new()) });
        }
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize != -1 && !ptr.is_null() {
                return Ok(Mapping { ptr: ptr as *const u8, len, owned: None });
            }
        }
        // Fallback: read into an 8-aligned owned buffer.
        let words = (len + 7) / 8;
        let mut buf: Vec<u64> = vec![0u64; words];
        {
            let bytes =
                unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len) };
            file.read_exact(bytes)
                .with_context(|| format!("read pack file {}", path.display()))?;
        }
        let ptr = buf.as_ptr() as *const u8;
        Ok(Mapping { ptr, len, owned: Some(buf) })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    fn typed_slice<T>(&self, at: usize, count: usize) -> &[T] {
        let bytes = count * std::mem::size_of::<T>();
        assert!(at + bytes <= self.len, "mapping slice out of bounds");
        let p = unsafe { self.ptr.add(at) };
        assert_eq!(p as usize % std::mem::align_of::<T>(), 0, "misaligned mapping slice");
        unsafe { std::slice::from_raw_parts(p as *const T, count) }
    }

    /// `count` u32s starting at byte offset `at` (must be 4-aligned).
    #[inline]
    pub fn u32_slice(&self, at: usize, count: usize) -> &[u32] {
        debug_assert!(zero_copy_ok());
        self.typed_slice::<u32>(at, count)
    }

    /// `count` native usizes at byte offset `at` (64-bit LE hosts only).
    #[inline]
    pub fn usize_slice(&self, at: usize, count: usize) -> &[usize] {
        assert!(zero_copy_ok(), "usize_slice requires a 64-bit little-endian host");
        self.typed_slice::<usize>(at, count)
    }

    /// `count` f32s at byte offset `at` (must be 4-aligned).
    #[inline]
    pub fn f32_slice(&self, at: usize, count: usize) -> &[f32] {
        debug_assert!(zero_copy_ok());
        self.typed_slice::<f32>(at, count)
    }
}

// ---------------------------------------------------------------------------
// Layout
// ---------------------------------------------------------------------------

/// Parsed header + computed section offsets.
#[derive(Clone, Debug)]
struct Layout {
    n: usize,
    m: usize,
    f0: usize,
    f1: usize,
    f2: usize,
    feature_seed: u64,
    train_count: usize,
    scale_shift: u32,
    key: String,
    full_vertices: usize,
    full_edges: usize,
    train_frac: f64,
    offsets_at: usize,
    adj_at: usize,
    features_at: usize,
    train_at: usize,
    total: usize,
}

impl Layout {
    fn compute(
        n: usize,
        m: usize,
        dims: GnnDims,
        feature_seed: u64,
        train_count: usize,
        scale_shift: u32,
        key: &str,
        full_vertices: usize,
        full_edges: usize,
        train_frac: f64,
    ) -> Layout {
        let key_at = HEADER_BYTES;
        let offsets_at = pad8(key_at + key.len());
        let adj_at = offsets_at + (n + 1) * 8;
        let features_at = pad8(adj_at + m * 4);
        let train_at = pad8(features_at + n * dims.f0 * 4);
        let total = pad8(train_at + train_count * 4);
        Layout {
            n,
            m,
            f0: dims.f0,
            f1: dims.f1,
            f2: dims.f2,
            feature_seed,
            train_count,
            scale_shift,
            key: key.to_string(),
            full_vertices,
            full_edges,
            train_frac,
            offsets_at,
            adj_at,
            features_at,
            train_at,
            total,
        }
    }

    fn parse(bytes: &[u8]) -> anyhow::Result<Layout> {
        let mut r = Cursor { b: bytes, pos: 0 };
        let magic = r.u64().context("pack header truncated")?;
        anyhow::ensure!(
            magic == MAGIC,
            "bad magic 0x{magic:016x}: not a hitgnn pack file"
        );
        let version = r.u32()?;
        anyhow::ensure!(
            version == VERSION,
            "unsupported pack version {version} (this build reads version {VERSION})"
        );
        let flags = r.u32()?;
        anyhow::ensure!(flags == 0, "unsupported pack flags 0x{flags:08x}");
        let n = r.u64()? as usize;
        let m = r.u64()? as usize;
        let f0 = r.u64()? as usize;
        let f1 = r.u64()? as usize;
        let f2 = r.u64()? as usize;
        let feature_seed = r.u64()?;
        let train_count = r.u64()? as usize;
        let scale_shift = r.u32()?;
        let key_len = r.u32()? as usize;
        let full_vertices = r.u64()? as usize;
        let full_edges = r.u64()? as usize;
        let train_frac = f64::from_bits(r.u64()?);
        debug_assert_eq!(r.pos, HEADER_BYTES);
        anyhow::ensure!(n > 0 && f0 > 0 && f2 > 0, "degenerate pack dimensions");
        anyhow::ensure!(
            train_count <= n,
            "train_count {train_count} exceeds vertex count {n}"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&train_frac),
            "train_frac {train_frac} out of [0,1]"
        );
        let key_bytes = r.take(key_len).context("pack key truncated")?;
        let key = std::str::from_utf8(key_bytes).context("pack key is not utf-8")?.to_string();
        // Validate the total length in u128 *before* computing usize
        // section offsets, so adversarial counts in a corrupt header can
        // never overflow-panic — they fail this check instead.
        let p8 = |x: u128| (x + 7) & !7u128;
        let total = {
            let offsets_at = p8(HEADER_BYTES as u128 + key_len as u128);
            let adj_at = offsets_at + (n as u128 + 1) * 8;
            let features_at = p8(adj_at + m as u128 * 4);
            let train_at = p8(features_at + n as u128 * f0 as u128 * 4);
            p8(train_at + train_count as u128 * 4)
        };
        anyhow::ensure!(
            bytes.len() as u128 == total,
            "pack file length {} != expected {total} (truncated or corrupt)",
            bytes.len(),
        );
        let dims = GnnDims { f0, f1, f2 };
        let l = Layout::compute(
            n,
            m,
            dims,
            feature_seed,
            train_count,
            scale_shift,
            &key,
            full_vertices,
            full_edges,
            train_frac,
        );
        debug_assert_eq!(l.total as u128, total);
        Ok(l)
    }

    fn write_header(&self, w: &mut impl Write) -> anyhow::Result<()> {
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&0u32.to_le_bytes())?; // flags
        w.write_all(&(self.n as u64).to_le_bytes())?;
        w.write_all(&(self.m as u64).to_le_bytes())?;
        w.write_all(&(self.f0 as u64).to_le_bytes())?;
        w.write_all(&(self.f1 as u64).to_le_bytes())?;
        w.write_all(&(self.f2 as u64).to_le_bytes())?;
        w.write_all(&self.feature_seed.to_le_bytes())?;
        w.write_all(&(self.train_count as u64).to_le_bytes())?;
        w.write_all(&self.scale_shift.to_le_bytes())?;
        w.write_all(&(self.key.len() as u32).to_le_bytes())?;
        w.write_all(&(self.full_vertices as u64).to_le_bytes())?;
        w.write_all(&(self.full_edges as u64).to_le_bytes())?;
        w.write_all(&self.train_frac.to_bits().to_le_bytes())?;
        w.write_all(self.key.as_bytes())?;
        write_zeros(w, pad8(HEADER_BYTES + self.key.len()) - (HEADER_BYTES + self.key.len()))?;
        Ok(())
    }
}

/// Bounds-checked little-endian reads (clean `Err` on truncation).
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, len: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + len <= self.b.len(),
            "pack file truncated at byte {} (need {} more)",
            self.b.len(),
            self.pos + len - self.b.len()
        );
        let s = &self.b[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn write_zeros(w: &mut impl Write, count: usize) -> std::io::Result<()> {
    const Z: [u8; 8] = [0; 8];
    debug_assert!(count < 8);
    w.write_all(&Z[..count])
}

fn write_u32s(w: &mut impl Write, vals: &[u32]) -> std::io::Result<()> {
    for &v in vals {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn write_f32s(w: &mut impl Write, vals: &[f32]) -> std::io::Result<()> {
    for &v in vals {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Writers
// ---------------------------------------------------------------------------

/// Serialise an in-memory dataset. Returns the file size in bytes.
/// Packing `spec.build(shift, seed)` produces a file byte-identical to
/// [`pack_streamed`] with the same `(spec, shift, seed)` — pinned by
/// tests — so either path yields the same training stream.
pub fn pack_dataset(data: &Dataset, path: &Path) -> anyhow::Result<u64> {
    let g = &data.graph;
    let n = g.num_vertices();
    let l = Layout::compute(
        n,
        g.num_edges(),
        data.spec.dims,
        data.features.seed(),
        data.train_vertices.len(),
        data.scale_shift,
        data.spec.key,
        data.spec.vertices,
        data.spec.edges,
        data.spec.train_frac,
    );
    let file =
        File::create(path).with_context(|| format!("create pack file {}", path.display()))?;
    let mut w = BufWriter::new(file);
    l.write_header(&mut w)?;
    // offsets: rebuilt from degrees so we never reach into Csr internals
    let mut off = 0u64;
    w.write_all(&off.to_le_bytes())?;
    for v in 0..n as u32 {
        off += g.degree(v) as u64;
        w.write_all(&off.to_le_bytes())?;
    }
    // adjacency
    for v in 0..n as u32 {
        write_u32s(&mut w, g.neighbors(v))?;
    }
    write_zeros(&mut w, l.features_at - (l.adj_at + l.m * 4))?;
    // features, row-major, materialised from the generator
    let mut row = vec![0.0f32; l.f0];
    for v in 0..n as u32 {
        data.features.write_features(v, &mut row);
        write_f32s(&mut w, &row)?;
    }
    write_zeros(&mut w, l.train_at - (l.features_at + n * l.f0 * 4))?;
    write_u32s(&mut w, &data.train_vertices)?;
    write_zeros(&mut w, l.total - (l.train_at + l.train_count * 4))?;
    w.flush()?;
    Ok(l.total as u64)
}

/// Stream a synthetic R-MAT dataset to disk without ever materialising
/// the edge list, adjacency, or feature matrix: O(|V| + budget) memory.
///
/// Replays `DatasetSpec::build` exactly — same generator seeds, same
/// edge order, same symmetrisation — via three passes over the
/// deterministic chunked edge stream: (1) degree counting, (2..) one
/// regeneration pass per adjacency bucket (vertex ranges sized so each
/// bucket's adjacency fits in `budget` bytes; a single hub vertex may
/// exceed it, bounded by max-degree), then feature rows and the train
/// split streamed in chunks. The output is byte-identical to
/// [`pack_dataset`] of the equivalent in-memory build.
pub fn pack_streamed(
    spec: &DatasetSpec,
    scale_shift: u32,
    seed: u64,
    path: &Path,
    budget: usize,
) -> anyhow::Result<u64> {
    let budget = budget.max(4096);
    let n = spec.scaled_vertices(scale_shift);
    let m_in = spec.scaled_edges(scale_shift);
    let gen_seed = seed ^ hash64(spec.key.len() as u64 ^ spec.vertices as u64);
    let communities = ((n as u32) / 1024).max(16);
    let edge_chunk = (budget / 16).max(1);

    // Pseudo-random id permutation, exactly as rmat::permute_ids builds it.
    let mut perm: Vec<u32> = (0..n as u32).collect();
    Rng::new(seed ^ 0x9e37).shuffle(&mut perm);

    // Pass 1: symmetrised degree counting over the chunked stream.
    let mut counts = vec![0u64; n + 1];
    {
        let mut rng = Rng::new(gen_seed);
        let mut stream = rmat::edges_chunked(
            &mut rng,
            n as u32,
            m_in,
            RmatParams::default(),
            communities,
            0.90,
            edge_chunk,
        );
        while let Some(chunk) = stream.next_chunk() {
            for &(s, d) in chunk {
                let (ps, pd) = (perm[s as usize], perm[d as usize]);
                counts[ps as usize + 1] += 1;
                if ps != pd {
                    counts[pd as usize + 1] += 1;
                }
            }
        }
    }
    for i in 0..n {
        counts[i + 1] += counts[i];
    }
    let offsets = counts; // offsets[v]..offsets[v+1] = adjacency of v
    let m_dir = offsets[n] as usize;

    // Train split size (streamed; the same hash filter as build()).
    const TRAIN_TAG: u64 = 0x7261_316e;
    let is_train = |v: u32| {
        let h = hash64(seed ^ TRAIN_TAG ^ v as u64);
        ((h >> 11) as f64 / (1u64 << 53) as f64) < spec.train_frac
    };
    let train_count = (0..n as u32).filter(|&v| is_train(v)).count();

    let features = FeatureGen::new(seed ^ 0xFEED, spec.dims.f0, spec.dims.f2);
    let l = Layout::compute(
        n,
        m_dir,
        spec.dims,
        features.seed(),
        train_count,
        scale_shift,
        spec.key,
        spec.vertices,
        spec.edges,
        spec.train_frac,
    );
    let file =
        File::create(path).with_context(|| format!("create pack file {}", path.display()))?;
    let mut w = BufWriter::new(file);
    l.write_header(&mut w)?;
    for &o in offsets.iter() {
        w.write_all(&o.to_le_bytes())?;
    }

    // Passes 2..: adjacency, bucketed by vertex range so each bucket's
    // edges fit in `budget`; every bucket replays the full edge stream.
    let mut lo = 0usize;
    while lo < n {
        let mut hi = lo + 1;
        while hi < n && (offsets[hi + 1] - offsets[lo]) * 4 <= budget as u64 {
            hi += 1;
        }
        let base = offsets[lo];
        let mut bucket = vec![0u32; (offsets[hi] - base) as usize];
        let mut cursor: Vec<u32> =
            (lo..hi).map(|v| (offsets[v] - base) as u32).collect();
        let in_bucket = |v: u32| (v as usize) >= lo && (v as usize) < hi;
        let mut push = |bucket: &mut [u32], cursor: &mut [u32], s: u32, d: u32| {
            let c = &mut cursor[s as usize - lo];
            bucket[*c as usize] = d;
            *c += 1;
        };
        let mut rng = Rng::new(gen_seed);
        let mut stream = rmat::edges_chunked(
            &mut rng,
            n as u32,
            m_in,
            RmatParams::default(),
            communities,
            0.90,
            edge_chunk,
        );
        while let Some(chunk) = stream.next_chunk() {
            for &(s, d) in chunk {
                let (ps, pd) = (perm[s as usize], perm[d as usize]);
                // same order as Csr::from_edges_symmetric: forward edge
                // first, reverse second, self-loops not doubled
                if in_bucket(ps) {
                    push(&mut bucket, &mut cursor, ps, pd);
                }
                if ps != pd && in_bucket(pd) {
                    push(&mut bucket, &mut cursor, pd, ps);
                }
            }
        }
        write_u32s(&mut w, &bucket)?;
        lo = hi;
    }
    write_zeros(&mut w, l.features_at - (l.adj_at + m_dir * 4))?;

    // Features: generated in row chunks.
    let rows_per_chunk = (budget / (spec.dims.f0 * 4)).max(1);
    let mut buf = vec![0.0f32; rows_per_chunk * spec.dims.f0];
    let mut v = 0usize;
    while v < n {
        let take = rows_per_chunk.min(n - v);
        for r in 0..take {
            features.write_features(
                (v + r) as u32,
                &mut buf[r * spec.dims.f0..(r + 1) * spec.dims.f0],
            );
        }
        write_f32s(&mut w, &buf[..take * spec.dims.f0])?;
        v += take;
    }
    write_zeros(&mut w, l.train_at - (l.features_at + n * spec.dims.f0 * 4))?;

    // Train split.
    for v in (0..n as u32).filter(|&v| is_train(v)) {
        w.write_all(&v.to_le_bytes())?;
    }
    write_zeros(&mut w, l.total - (l.train_at + train_count * 4))?;
    w.flush()?;
    Ok(l.total as u64)
}

// ---------------------------------------------------------------------------
// Loader
// ---------------------------------------------------------------------------

/// Load a packed dataset. On 64-bit little-endian hosts the CSR and the
/// feature matrix are served zero-copy from the mapping (page cache =
/// the OS-managed disk tier); elsewhere they are decoded into owned
/// memory. Either way the returned [`Dataset`] is observationally
/// identical to `spec.build(scale_shift, seed)` for a pack produced
/// from that build.
pub fn load(path: &Path) -> anyhow::Result<Dataset> {
    let map = Arc::new(Mapping::from_file(path)?);
    let l = Layout::parse(map.bytes())
        .with_context(|| format!("invalid pack file {}", path.display()))?;

    // Prefer the registry spec when the pack matches it exactly (keeps
    // the &'static key without leaking); otherwise synthesise one from
    // the header so foreign packs still load.
    let spec = match datasets::lookup(&l.key) {
        Ok(s)
            if s.vertices == l.full_vertices
                && s.edges == l.full_edges
                && s.dims == (GnnDims { f0: l.f0, f1: l.f1, f2: l.f2 })
                && s.train_frac.to_bits() == l.train_frac.to_bits() =>
        {
            s
        }
        _ => DatasetSpec {
            key: Box::leak(l.key.clone().into_boxed_str()),
            abbrev: "PK",
            vertices: l.full_vertices,
            edges: l.full_edges,
            dims: GnnDims { f0: l.f0, f1: l.f1, f2: l.f2 },
            train_frac: l.train_frac,
        },
    };

    let graph = if zero_copy_ok() {
        Csr::from_mapping(Arc::clone(&map), l.offsets_at, l.n, l.adj_at, l.m)
    } else {
        let mut r = Cursor { b: map.bytes(), pos: l.offsets_at };
        let mut offsets = Vec::with_capacity(l.n + 1);
        for _ in 0..=l.n {
            offsets.push(r.u64()? as usize);
        }
        let mut adj = Vec::with_capacity(l.m);
        let mut r = Cursor { b: map.bytes(), pos: l.adj_at };
        for _ in 0..l.m {
            adj.push(r.u32()?);
        }
        Csr::from_parts(offsets, adj)
    };
    // Cheap structural sanity (full validate() is an O(V+E) test affair).
    anyhow::ensure!(
        graph.num_vertices() == l.n && graph.num_edges() == l.m,
        "pack CSR shape mismatch"
    );

    let mut features = FeatureGen::new(l.feature_seed, l.f0, l.f2);
    if zero_copy_ok() {
        features.set_backing(Arc::clone(&map), l.features_at, l.n);
    }

    let mut train_vertices = Vec::with_capacity(l.train_count);
    let mut r = Cursor { b: map.bytes(), pos: l.train_at };
    for _ in 0..l.train_count {
        let v = r.u32()?;
        anyhow::ensure!((v as usize) < l.n, "train vertex {v} out of range");
        train_vertices.push(v);
    }

    Ok(Dataset { spec, graph, features, train_vertices, scale_shift: l.scale_shift })
}

/// Pack-file metadata (header summary, no section decoding).
#[derive(Clone, Debug)]
pub struct PackMeta {
    pub key: String,
    pub scale_shift: u32,
    pub num_vertices: usize,
    pub num_edges: usize,
    pub dims: GnnDims,
    pub train_count: usize,
    pub total_bytes: usize,
}

/// Read a pack file's metadata without loading it (validates the header
/// and total length like [`load`]).
pub fn probe(path: &Path) -> anyhow::Result<PackMeta> {
    let map = Mapping::from_file(path)?;
    let l = Layout::parse(map.bytes())
        .with_context(|| format!("invalid pack file {}", path.display()))?;
    Ok(PackMeta {
        key: l.key.clone(),
        scale_shift: l.scale_shift,
        num_vertices: l.n,
        num_edges: l.m,
        dims: GnnDims { f0: l.f0, f1: l.f1, f2: l.f2 },
        train_count: l.train_count,
        total_bytes: l.total,
    })
}

/// One-line summary of a pack file without fully loading it (used by
/// `hitgnn pack` reporting and `info`).
pub fn describe(path: &Path) -> anyhow::Result<String> {
    let m = probe(path)?;
    Ok(format!(
        "{} (shift {}): |V|={} |E|={} f=({},{},{}) train={} — {} bytes",
        m.key,
        m.scale_shift,
        m.num_vertices,
        m.num_edges,
        m.dims.f0,
        m.dims.f1,
        m.dims.f2,
        m.train_count,
        m.total_bytes
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hitgnn-ondisk-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn pack_roundtrip_matches_in_memory_build() {
        let spec = datasets::lookup("tiny").unwrap();
        let data = spec.build(1, 42);
        let path = tmp("roundtrip.hitg");
        let bytes = pack_dataset(&data, &path).unwrap();
        assert!(bytes >= HEADER_BYTES as u64);
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.spec.key, data.spec.key);
        assert_eq!(loaded.scale_shift, data.scale_shift);
        assert_eq!(loaded.graph.num_vertices(), data.graph.num_vertices());
        assert_eq!(loaded.graph.num_edges(), data.graph.num_edges());
        for v in 0..data.graph.num_vertices() as u32 {
            assert_eq!(loaded.graph.neighbors(v), data.graph.neighbors(v), "v={v}");
        }
        loaded.graph.validate().unwrap();
        assert_eq!(loaded.train_vertices, data.train_vertices);
        let f0 = spec.dims.f0;
        let (mut a, mut b) = (vec![0.0f32; f0], vec![0.0f32; f0]);
        for v in [0u32, 1, 7, 1023] {
            data.features.write_features(v, &mut a);
            loaded.features.write_features(v, &mut b);
            assert_eq!(a, b, "features differ at v={v}");
            assert_eq!(data.features.label(v), loaded.features.label(v));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn streamed_pack_is_byte_identical_to_in_memory_pack() {
        let spec = datasets::lookup("tiny").unwrap();
        let (pa, pb) = (tmp("mem.hitg"), tmp("stream.hitg"));
        pack_dataset(&spec.build(1, 7), &pa).unwrap();
        // tiny budget forces many adjacency buckets + feature chunks
        pack_streamed(&spec, 1, 7, &pb, 1).unwrap();
        let (a, b) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        assert_eq!(a.len(), b.len());
        assert!(a == b, "streamed pack diverges from in-memory pack");
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
    }

    #[test]
    fn rejects_corrupt_and_truncated_files() {
        let spec = datasets::lookup("tiny").unwrap();
        let path = tmp("corrupt.hitg");
        pack_dataset(&spec.build(2, 3), &path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // truncations at awkward places: clean Err, no panic
        for cut in [0usize, 4, HEADER_BYTES - 1, HEADER_BYTES, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load(&path).is_err(), "truncated at {cut} must be rejected");
        }
        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // future version
        let mut bad = good.clone();
        bad[8] = 99;
        std::fs::write(&path, &bad).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // trailing garbage (length mismatch)
        let mut bad = good.clone();
        bad.extend_from_slice(&[0u8; 16]);
        std::fs::write(&path, &bad).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_a_clean_error() {
        let err = load(Path::new("/nonexistent/nope.hitg")).unwrap_err().to_string();
        assert!(err.contains("nope.hitg"), "{err}");
    }

    #[test]
    fn describe_summarises_without_loading() {
        let spec = datasets::lookup("tiny").unwrap();
        let path = tmp("describe.hitg");
        pack_dataset(&spec.build(2, 9), &path).unwrap();
        let s = describe(&path).unwrap();
        assert!(s.contains("tiny"), "{s}");
        std::fs::remove_file(&path).ok();
    }
}
