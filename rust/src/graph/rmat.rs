//! R-MAT (recursive matrix) graph generator.
//!
//! Produces power-law graphs with community structure, the standard
//! synthetic stand-in for social / co-purchase networks (Graph500 uses
//! a=0.57, b=c=0.19, d=0.05). We perturb the quadrant probabilities per
//! level ("smoothing") to avoid the pathological staircase degree
//! distribution of textbook R-MAT.

use crate::util::rng::Rng;

/// R-MAT parameters.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Per-level multiplicative noise on (a,b,c,d); 0 = none.
    pub noise: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        // Graph500 constants; d is implied (1 - a - b - c).
        RmatParams { a: 0.57, b: 0.19, c: 0.19, noise: 0.1 }
    }
}

/// Generate `num_edges` directed edges over `2^scale` vertices.
/// Deterministic given `rng`'s seed.
pub fn generate_edges(
    rng: &mut Rng,
    scale: u32,
    num_edges: usize,
    params: RmatParams,
) -> Vec<(u32, u32)> {
    assert!(scale <= 31, "rmat scale too large for u32 vertex ids");
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        edges.push(one_edge(rng, scale, params));
    }
    edges
}

fn one_edge(rng: &mut Rng, scale: u32, p: RmatParams) -> (u32, u32) {
    let (mut src, mut dst) = (0u32, 0u32);
    for _ in 0..scale {
        // per-level noisy quadrant probabilities
        let na = p.a * (1.0 + p.noise * (rng.f64() - 0.5));
        let nb = p.b * (1.0 + p.noise * (rng.f64() - 0.5));
        let nc = p.c * (1.0 + p.noise * (rng.f64() - 0.5));
        let nd = (1.0 - p.a - p.b - p.c) * (1.0 + p.noise * (rng.f64() - 0.5));
        let total = na + nb + nc + nd;
        let r = rng.f64() * total;
        let (sbit, dbit) = if r < na {
            (0, 0)
        } else if r < na + nb {
            (0, 1)
        } else if r < na + nb + nc {
            (1, 0)
        } else {
            (1, 1)
        };
        src = (src << 1) | sbit;
        dst = (dst << 1) | dbit;
    }
    (src, dst)
}

/// Community-mixture R-MAT: real graphs (Reddit, products, …) combine a
/// power-law degree distribution with strong community structure — METIS
/// finds 4-way edge cuts of ~10–25% on them, whereas plain R-MAT is
/// notoriously partition-resistant (cut ≈ random). With probability
/// `mu` an edge is drawn *within* a community (R-MAT over the community's
/// id range); otherwise it is global. Communities are contiguous id
/// blocks of size `n / communities` (callers permute ids afterwards).
pub fn generate_community_edges(
    rng: &mut Rng,
    n: u32,
    num_edges: usize,
    params: RmatParams,
    communities: u32,
    mu: f64,
) -> Vec<(u32, u32)> {
    let mix = CommunityMix::new(n, params, communities, mu);
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        edges.push(mix.draw(rng));
    }
    edges
}

/// The per-edge community-mixture draw, factored out so the all-at-once
/// generator above and the chunked streaming driver below consume the
/// *same* RNG stream edge for edge — bit-identity between the two paths
/// is by construction, and pinned by a regression test.
#[derive(Clone, Copy, Debug)]
pub struct CommunityMix {
    n: u32,
    comm_size: u32,
    comm_scale: u32,
    global_scale: u32,
    communities: u32,
    mu: f64,
    params: RmatParams,
}

impl CommunityMix {
    pub fn new(n: u32, params: RmatParams, communities: u32, mu: f64) -> CommunityMix {
        assert!(communities >= 1 && communities <= n);
        let comm_size = (n / communities).max(1);
        // scale of the per-community R-MAT id space
        let comm_scale = 32 - (comm_size - 1).max(1).leading_zeros();
        let global_scale = 32 - (n - 1).max(1).leading_zeros();
        CommunityMix { n, comm_size, comm_scale, global_scale, communities, mu, params }
    }

    #[inline]
    pub fn draw(&self, rng: &mut Rng) -> (u32, u32) {
        if rng.f64() < self.mu {
            let c = rng.next_below(self.communities as u64) as u32;
            let base = c * self.comm_size;
            let (mut s, mut d) = one_edge(rng, self.comm_scale, self.params);
            s %= self.comm_size;
            d %= self.comm_size;
            ((base + s) % self.n, (base + d) % self.n)
        } else {
            let (s, d) = one_edge(rng, self.global_scale, self.params);
            (s % self.n, d % self.n)
        }
    }
}

/// Deterministic chunked edge stream: yields the exact edge sequence of
/// [`generate_community_edges`] in bounded memory (`chunk` edges at a
/// time), so `hitgnn pack` can emit graphs larger than RAM. The caller
/// owns the `Rng`; a fresh `Rng` with the same seed replays the stream.
pub struct EdgeChunks<'a> {
    rng: &'a mut Rng,
    mix: CommunityMix,
    remaining: usize,
    chunk: usize,
    buf: Vec<(u32, u32)>,
}

pub fn edges_chunked<'a>(
    rng: &'a mut Rng,
    n: u32,
    num_edges: usize,
    params: RmatParams,
    communities: u32,
    mu: f64,
    chunk: usize,
) -> EdgeChunks<'a> {
    assert!(chunk > 0, "chunk size must be positive");
    EdgeChunks {
        rng,
        mix: CommunityMix::new(n, params, communities, mu),
        remaining: num_edges,
        chunk,
        buf: Vec::with_capacity(chunk.min(num_edges)),
    }
}

impl EdgeChunks<'_> {
    /// Next chunk of edges, or `None` once `num_edges` have been yielded.
    /// The returned slice is only valid until the next call (the buffer
    /// is reused — this is what bounds memory).
    pub fn next_chunk(&mut self) -> Option<&[(u32, u32)]> {
        if self.remaining == 0 {
            return None;
        }
        let take = self.remaining.min(self.chunk);
        self.buf.clear();
        for _ in 0..take {
            self.buf.push(self.mix.draw(self.rng));
        }
        self.remaining -= take;
        Some(&self.buf)
    }
}

/// Map vertex ids through a pseudo-random permutation so that R-MAT's
/// id-correlated degree structure does not trivially align with partition
/// boundaries (real datasets have arbitrary id ordering).
pub fn permute_ids(edges: &mut [(u32, u32)], n: u32, seed: u64) {
    let mut perm: Vec<u32> = (0..n).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut perm);
    for e in edges.iter_mut() {
        e.0 = perm[e.0 as usize];
        e.1 = perm[e.1 as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    #[test]
    fn deterministic() {
        let a = generate_edges(&mut Rng::new(1), 10, 5000, RmatParams::default());
        let b = generate_edges(&mut Rng::new(1), 10, 5000, RmatParams::default());
        assert_eq!(a, b);
    }

    #[test]
    fn edge_count_and_range() {
        let edges = generate_edges(&mut Rng::new(2), 12, 20_000, RmatParams::default());
        assert_eq!(edges.len(), 20_000);
        assert!(edges.iter().all(|&(s, d)| s < 4096 && d < 4096));
    }

    #[test]
    fn skewed_degree_distribution() {
        // R-MAT should be much more skewed than Erdős–Rényi: the max degree
        // must significantly exceed the mean degree.
        let n = 1 << 12;
        let m = 16 * n;
        let edges = generate_edges(&mut Rng::new(3), 12, m, RmatParams::default());
        let g = Csr::from_edges(n, &edges);
        let mean = m as f64 / n as f64;
        assert!(
            g.max_degree() as f64 > 8.0 * mean,
            "max={} mean={mean}",
            g.max_degree()
        );
    }

    #[test]
    fn chunked_stream_is_bit_identical_to_all_at_once() {
        let n = 1u32 << 10;
        let m = 10_000;
        let p = RmatParams::default();
        let all = generate_community_edges(&mut Rng::new(42), n, m, p, 16, 0.9);
        // several chunk sizes, including ones that do not divide m
        for chunk in [1usize, 7, 1024, 3000, 100_000] {
            let mut rng = Rng::new(42);
            let mut stream = edges_chunked(&mut rng, n, m, p, 16, 0.9, chunk);
            let mut got = Vec::with_capacity(m);
            while let Some(c) = stream.next_chunk() {
                got.extend_from_slice(c);
            }
            assert_eq!(got, all, "chunk={chunk}");
        }
    }

    #[test]
    fn permute_preserves_multiset_degrees() {
        let n = 1u32 << 8;
        let mut edges = generate_edges(&mut Rng::new(4), 8, 2000, RmatParams::default());
        let before = Csr::from_edges(n as usize, &edges);
        let mut before_deg: Vec<usize> =
            (0..n).map(|v| before.degree(v)).collect();
        permute_ids(&mut edges, n, 99);
        let after = Csr::from_edges(n as usize, &edges);
        let mut after_deg: Vec<usize> = (0..n).map(|v| after.degree(v)).collect();
        before_deg.sort_unstable();
        after_deg.sort_unstable();
        assert_eq!(before_deg, after_deg);
        assert!(edges.iter().all(|&(s, d)| s < n && d < n));
    }
}
