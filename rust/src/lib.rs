//! # HitGNN — high-throughput synchronous GNN training on CPU+Multi-FPGA
//!
//! Reproduction of *HitGNN* (Lin, Zhang, Prasanna, 2023): a framework that
//! maps synchronous mini-batch GNN training algorithms (DistDGL, PaGraph,
//! P3) and GNN models (GCN, GraphSAGE) onto a CPU + multi-FPGA platform.
//!
//! Architecture (three layers, Python never on the request path):
//! - **L3 (this crate)** — the host program / coordinator: graph
//!   preprocessing, mini-batch sampling, two-stage task scheduling,
//!   CPU↔FPGA communication accounting, gradient synchronisation, plus the
//!   FPGA device model, performance model, and DSE engine from the paper.
//! - **L2** — JAX model (GCN / GraphSAGE fwd+bwd), AOT-lowered to HLO text.
//! - **L1** — Pallas kernels (aggregate gather-sum, update matmul) called
//!   from L2.
//!
//! The simulated FPGAs execute the real AOT-compiled artifacts through the
//! PJRT CPU client ([`runtime`]); their *timing* comes from the paper's
//! analytic model ([`fpga`], [`perf`]). See `DESIGN.md` for the
//! substitution table and per-experiment index.

pub mod api;
pub mod comm;
pub mod coordinator;
pub mod dse;
pub mod fault;
pub mod fpga;
pub mod graph;
pub mod partition;
pub mod perf;
pub mod runtime;
pub mod sampling;
pub mod sched;
pub mod store;
pub mod tune;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
