//! `hitgnn` CLI — the launcher for the HitGNN coordinator.
//!
//! Subcommands (see `hitgnn help`):
//! - `train`     run synchronous GNN training on the simulated
//!               CPU+Multi-FPGA platform (real PJRT execution path)
//! - `dse`       run the hardware design-space exploration engine
//! - `simulate`  analytic platform simulation (epoch time / NVTPS)
//! - `info`      print dataset / platform registries

fn main() {
    let code = hitgnn::coordinator::cli::main_entry();
    std::process::exit(code);
}
