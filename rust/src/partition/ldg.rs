//! Multi-constraint streaming partitioner — the DistDGL stand-in.
//!
//! DistDGL uses METIS with multi-constraint balancing (vertices, edges,
//! *and* training vertices) while minimising edge cut. A full METIS
//! implementation is out of scope; what Table 7 / Fig 8 actually depend on
//! is (a) a low-but-nonzero edge-cut fraction and (b) the residual
//! imbalance METIS leaves in practice. Linear Deterministic Greedy (LDG,
//! Stanton & Kliot KDD'12) with multi-constraint penalties reproduces both:
//! vertices stream in random order and go to the partition with the most
//! already-placed neighbours, discounted by that partition's fill across
//! all three constraints.

use super::Preprocessed;
use crate::graph::Dataset;
use crate::store::{FeatureStore, Residency};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;

/// Tunables for the LDG pass.
#[derive(Clone, Copy, Debug)]
pub struct LdgConfig {
    /// Slack multiplier on per-constraint capacity (1.0 = perfectly tight;
    /// METIS defaults to ~1.05).
    pub slack: f64,
    /// Weight of the balance discount relative to neighbour affinity.
    pub balance_weight: f64,
    /// Label-propagation refinement sweeps after the streaming pass
    /// (KL-lite: move a vertex to its majority-neighbour partition when
    /// the move respects the slack) — the cheap analogue of METIS's
    /// refinement phase.
    pub refine_passes: usize,
}

impl Default for LdgConfig {
    fn default() -> Self {
        // Slack 1.15 reproduces the residual imbalance METIS leaves when
        // it prioritises edge-cut under multi-constraint balancing (the
        // paper's Challenge 2 / Table 7 WB motivation: DistDGL's METIS
        // partitions are ~10–15% uneven in training vertices); the
        // refinement passes then recover METIS-like locality without
        // re-balancing.
        LdgConfig { slack: 1.15, balance_weight: 0.7, refine_passes: 2 }
    }
}

/// DistDGL-style preprocessing: LDG partition + partition-based feature
/// store (FPGA i holds the rows of partition i — Table 1).
pub fn preprocess(data: &Dataset, p: usize, seed: u64) -> Preprocessed {
    let part = partition(data, p, LdgConfig::default(), seed);
    let n = data.graph.num_vertices();

    // train vertices per partition
    let mut train_parts = vec![Vec::new(); p];
    for &v in &data.train_vertices {
        train_parts[part[v as usize] as usize].push(v);
    }

    // feature store: rows of own partition
    let stores: Vec<Box<dyn FeatureStore>> = (0..p)
        .map(|i| {
            let mut bits = Bitset::new(n);
            for v in 0..n {
                if part[v] as usize == i {
                    bits.set(v);
                }
            }
            Box::new(Residency::rows_subset(bits, data.spec.dims.f0)) as Box<dyn FeatureStore>
        })
        .collect();

    Preprocessed {
        algo: super::Algorithm::DistDgl,
        num_parts: p,
        vertex_part: Some(part),
        train_parts,
        stores,
    }
}

/// Multi-constraint LDG: returns vertex→partition.
pub fn partition(data: &Dataset, p: usize, cfg: LdgConfig, seed: u64) -> Vec<u32> {
    let g = &data.graph;
    let n = g.num_vertices();
    if p == 1 {
        return vec![0; n];
    }

    // is_train bitmap for the third constraint
    let mut is_train = Bitset::new(n);
    for &v in &data.train_vertices {
        is_train.set(v as usize);
    }

    // capacities (with slack) for the three constraints
    let cap_v = (n as f64 / p as f64) * cfg.slack;
    let cap_e = (g.num_edges() as f64 / p as f64) * cfg.slack;
    let cap_t = (data.train_vertices.len() as f64 / p as f64) * cfg.slack;

    let mut load_v = vec![0f64; p];
    let mut load_e = vec![0f64; p];
    let mut load_t = vec![0f64; p];
    let mut part = vec![u32::MAX; n];

    // random stream order (LDG quality depends on it; deterministic seed)
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::new(seed ^ 0x1d6);
    rng.shuffle(&mut order);

    let mut nbr_count = vec![0u32; p];
    for &v in &order {
        // count already-placed neighbours per partition
        for x in nbr_count.iter_mut() {
            *x = 0;
        }
        for &u in g.neighbors(v) {
            let pu = part[u as usize];
            if pu != u32::MAX {
                nbr_count[pu as usize] += 1;
            }
        }
        let deg = g.degree(v) as f64;
        let t = if is_train.get(v as usize) { 1.0 } else { 0.0 };

        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for i in 0..p {
            // multi-constraint fill: the tightest constraint dominates
            let fill = (load_v[i] / cap_v)
                .max(load_e[i] / cap_e)
                .max(if cap_t > 0.0 { load_t[i] / cap_t } else { 0.0 });
            if fill >= 1.0 {
                continue; // at capacity under slack
            }
            let score =
                (1.0 + nbr_count[i] as f64) * (1.0 - cfg.balance_weight * fill);
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        if best_score == f64::NEG_INFINITY {
            // all partitions nominally full (can happen at the very end
            // with tight slack): place on the least-filled one.
            best = (0..p)
                .min_by(|&a, &b| {
                    let fa = load_v[a] / cap_v;
                    let fb = load_v[b] / cap_v;
                    fa.partial_cmp(&fb).unwrap()
                })
                .unwrap();
        }
        part[v as usize] = best as u32;
        load_v[best] += 1.0;
        load_e[best] += deg;
        load_t[best] += t;
    }

    // refinement sweeps: move vertices to their majority-neighbour
    // partition when the balance constraints allow it
    for _ in 0..cfg.refine_passes {
        let mut moved = 0usize;
        for &v in &order {
            let cur = part[v as usize] as usize;
            for x in nbr_count.iter_mut() {
                *x = 0;
            }
            let mut best = cur;
            let mut best_c = 0u32;
            for &u in g.neighbors(v) {
                let pu = part[u as usize] as usize;
                nbr_count[pu] += 1;
                if nbr_count[pu] > best_c {
                    best_c = nbr_count[pu];
                    best = pu;
                }
            }
            if best == cur || nbr_count[best] <= nbr_count[cur] {
                continue;
            }
            let deg = g.degree(v) as f64;
            let t = if is_train.get(v as usize) { 1.0 } else { 0.0 };
            let fits = load_v[best] + 1.0 <= cap_v
                && load_e[best] + deg <= cap_e
                && (cap_t == 0.0 || load_t[best] + t <= cap_t.max(1.0));
            if fits {
                part[v as usize] = best as u32;
                load_v[cur] -= 1.0;
                load_e[cur] -= deg;
                load_t[cur] -= t;
                load_v[best] += 1.0;
                load_e[best] += deg;
                load_t[best] += t;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
    part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::partition::Algorithm;

    fn data() -> Dataset {
        datasets::lookup("ogbn-products").unwrap().build(8, 3)
    }

    #[test]
    fn assigns_every_vertex() {
        let d = data();
        let part = partition(&d, 4, LdgConfig::default(), 1);
        assert_eq!(part.len(), d.graph.num_vertices());
        assert!(part.iter().all(|&x| x < 4));
    }

    #[test]
    fn respects_vertex_balance_within_slack() {
        let d = data();
        let p = 4;
        let part = partition(&d, p, LdgConfig::default(), 1);
        let mut counts = vec![0usize; p];
        for &x in &part {
            counts[x as usize] += 1;
        }
        let cap = (d.graph.num_vertices() as f64 / p as f64) * 1.15 + 1.0;
        for &c in &counts {
            assert!((c as f64) <= cap, "count {c} exceeds cap {cap}");
        }
    }

    #[test]
    fn beats_random_edge_cut() {
        let d = data();
        let pre = preprocess(&d, 4, 5);
        let cut = pre.edge_cut(&d.graph).unwrap();
        // random 4-way partition has expected cut 0.75
        assert!(cut < 0.70, "LDG edge cut {cut} not better than random");
    }

    #[test]
    fn preprocess_shape_and_store_consistency() {
        let d = data();
        let pre = preprocess(&d, 3, 5);
        assert_eq!(pre.algo, Algorithm::DistDgl);
        let part = pre.vertex_part.as_ref().unwrap();
        // store i holds exactly partition i's rows
        for (i, s) in pre.stores.iter().enumerate() {
            let expected = part.iter().filter(|&&x| x as usize == i).count();
            assert_eq!(s.residency().resident_rows(), Some(expected));
            assert_eq!(s.residency().dim_fraction(), 1.0);
        }
        // stores are disjoint and cover all vertices
        let total: usize =
            pre.stores.iter().map(|s| s.residency().resident_rows().unwrap()).sum();
        assert_eq!(total, d.graph.num_vertices());
    }

    #[test]
    fn train_imbalance_is_bounded_but_nonzero() {
        // The paper's Challenge 2: METIS-style partitioning leaves residual
        // train-vertex imbalance — WB exists because of it. LDG's
        // multi-constraint discount keeps it within slack, but the default
        // config deliberately trades balance for locality.
        let d = data();
        let pre = preprocess(&d, 4, 5);
        let imb = pre.train_imbalance();
        assert!(imb > 1.01 && imb < 1.35, "imbalance {imb}");
    }

    #[test]
    fn single_partition_trivial() {
        let d = data();
        let part = partition(&d, 1, LdgConfig::default(), 1);
        assert!(part.iter().all(|&x| x == 0));
    }
}
