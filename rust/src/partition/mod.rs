//! Graph preprocessing: partitioning + feature storing (paper §2.3, Table 1).
//!
//! | Algorithm | Partitioning                          | Feature storing          |
//! |-----------|---------------------------------------|--------------------------|
//! | DistDGL   | METIS w/ multi-constraints (→ [`ldg`]) | rows of own partition    |
//! | PaGraph   | greedy balancing #train vertices       | high-out-degree cache    |
//! | P3        | along the feature dimension            | feature-dim slice        |
//!
//! The outputs that matter downstream are captured by [`Preprocessed`]:
//! which partition every *training* vertex belongs to (drives mini-batch
//! counts → workload imbalance → the WB optimization) and each FPGA's
//! pluggable [`FeatureStore`] (drives the local-fetch ratio β in Eq. 7 →
//! the DC optimization). Each algorithm emits its Table-1 static store;
//! [`preprocess_with_policy`] can swap in a dynamic [`CachePolicy`]
//! (LFU/hotness or sliding-window recency — `crate::store::dynamic`)
//! that inherits the algorithm's feature-dim range and is re-ranked at
//! the epoch barrier from observed accesses.

pub mod ldg;
pub mod p3;
pub mod pagraph;

use crate::graph::Dataset;
pub use crate::store::{CachePolicy, FeatureStore, Residency, Rows};

/// Synchronous GNN training algorithm selector (paper Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    DistDgl,
    PaGraph,
    P3,
}

impl Algorithm {
    pub fn parse(s: &str) -> anyhow::Result<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "distdgl" => Ok(Algorithm::DistDgl),
            "pagraph" => Ok(Algorithm::PaGraph),
            "p3" => Ok(Algorithm::P3),
            _ => anyhow::bail!("unknown algorithm '{s}' (distdgl|pagraph|p3)"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::DistDgl => "DistDGL",
            Algorithm::PaGraph => "PaGraph",
            Algorithm::P3 => "P3",
        }
    }
    pub const ALL: [Algorithm; 3] = [Algorithm::DistDgl, Algorithm::PaGraph, Algorithm::P3];
}

/// Result of the graph preprocessing stage.
pub struct Preprocessed {
    pub algo: Algorithm,
    pub num_parts: usize,
    /// Topology assignment vertex→partition. `None` for P3 (every FPGA
    /// holds the full topology; features are dimension-partitioned).
    pub vertex_part: Option<Vec<u32>>,
    /// Training target vertices per partition — the sampler draws from
    /// these, so their sizes determine the per-partition mini-batch counts.
    pub train_parts: Vec<Vec<u32>>,
    /// Per-FPGA pluggable feature store (policy + residency state). The
    /// coordinator drives `observe`/`end_epoch`; everyone else reads an
    /// epoch-versioned [`residency_snapshot`](Self::residency_snapshot).
    pub stores: Vec<Box<dyn FeatureStore>>,
}

impl Preprocessed {
    /// Number of mini-batches partition `i` yields at batch size `b`
    /// (ceiling division — a final short batch still counts).
    pub fn batches_in_part(&self, i: usize, batch_size: usize) -> usize {
        (self.train_parts[i].len() + batch_size - 1) / batch_size
    }

    /// Imbalance factor: max/mean of per-partition training-vertex counts.
    pub fn train_imbalance(&self) -> f64 {
        let counts: Vec<usize> = self.train_parts.iter().map(|p| p.len()).collect();
        let max = *counts.iter().max().unwrap_or(&0) as f64;
        let mean = counts.iter().sum::<usize>() as f64 / counts.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Fraction of edges whose endpoints live in different partitions
    /// (edge-cut; not defined for P3's feature-dim partitioning).
    pub fn edge_cut(&self, graph: &crate::graph::Csr) -> Option<f64> {
        let part = self.vertex_part.as_ref()?;
        let mut cut = 0usize;
        let mut total = 0usize;
        for v in 0..graph.num_vertices() as u32 {
            for &u in graph.neighbors(v) {
                total += 1;
                if part[v as usize] != part[u as usize] {
                    cut += 1;
                }
            }
        }
        Some(if total == 0 { 0.0 } else { cut as f64 / total as f64 })
    }

    /// Epoch-versioned snapshot of every FPGA's resident set. Prep threads
    /// read the snapshot (immutable for the whole epoch) while the
    /// coordinator mutates the stores at the barriers, which is what keeps
    /// dynamic policies bit-identical across pipeline configurations.
    pub fn residency_snapshot(&self) -> Vec<Residency> {
        self.stores.iter().map(|s| s.residency().clone()).collect()
    }
}

/// Run the selected algorithm's graph preprocessing (partitioning + the
/// algorithm's static Table-1 feature storing) for `num_parts` FPGAs.
///
/// `cache_ratio` is the fraction of |V| whose feature rows fit in one
/// FPGA's DDR budget for caching-style stores (PaGraph and the dynamic
/// policies); partition-based static stores (DistDGL) ignore it (each
/// partition's rows are assumed resident, as in the paper).
pub fn preprocess(
    algo: Algorithm,
    data: &Dataset,
    num_parts: usize,
    cache_ratio: f64,
    seed: u64,
) -> Preprocessed {
    preprocess_with_policy(algo, data, num_parts, cache_ratio, CachePolicy::Static, seed)
}

/// [`preprocess`] with an explicit caching policy. Dynamic policies
/// replace the algorithm's static store with a capacity-bounded
/// (`cache_ratio·|V|` rows) cache that inherits the static store's
/// feature-dim range and cold-starts from the top-degree rows.
pub fn preprocess_with_policy(
    algo: Algorithm,
    data: &Dataset,
    num_parts: usize,
    cache_ratio: f64,
    policy: CachePolicy,
    seed: u64,
) -> Preprocessed {
    assert!(num_parts >= 1, "need at least one partition");
    assert!(
        (0.0..=1.0).contains(&cache_ratio),
        "cache_ratio must be in [0, 1] (got {cache_ratio})"
    );
    let mut pre = match algo {
        Algorithm::DistDgl => ldg::preprocess(data, num_parts, seed),
        Algorithm::PaGraph => pagraph::preprocess(data, num_parts, cache_ratio, seed),
        Algorithm::P3 => p3::preprocess(data, num_parts),
    };
    if policy.is_dynamic() {
        let rank = crate::store::dynamic::degree_rank(data);
        let n = data.graph.num_vertices();
        pre.stores = pre
            .stores
            .iter()
            .map(|s| {
                let r = s.residency();
                crate::store::dynamic::dynamic_store(
                    policy,
                    n,
                    cache_ratio,
                    (r.dim_lo, r.dim_hi, r.feat_dim),
                    rank.clone(),
                )
            })
            .collect();
    }
    pre
}

/// Split `vs` round-robin into `p` chunks (helper shared by p3 and tests).
pub(crate) fn round_robin_split(vs: &[u32], p: usize) -> Vec<Vec<u32>> {
    let mut parts = vec![Vec::with_capacity(vs.len() / p + 1); p];
    for (i, &v) in vs.iter().enumerate() {
        parts[i % p].push(v);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn tiny() -> Dataset {
        datasets::lookup("reddit").unwrap().build(8, 1)
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
        assert!(Algorithm::parse("x").is_err());
    }

    #[test]
    fn preprocess_all_algorithms_cover_train_vertices() {
        let d = tiny();
        for algo in Algorithm::ALL {
            let pre = preprocess(algo, &d, 4, 0.2, 7);
            assert_eq!(pre.num_parts, 4);
            assert_eq!(pre.stores.len(), 4);
            let total: usize = pre.train_parts.iter().map(|p| p.len()).sum();
            assert_eq!(total, d.train_vertices.len(), "{algo:?}");
            // every train vertex appears exactly once
            let mut seen = std::collections::HashSet::new();
            for part in &pre.train_parts {
                for &v in part {
                    assert!(seen.insert(v), "{algo:?}: duplicate train vertex {v}");
                }
            }
        }
    }

    #[test]
    fn dynamic_policies_are_capacity_bounded_and_inherit_dims() {
        let d = tiny();
        let n = d.graph.num_vertices();
        let ratio = 0.1;
        let cap = ((n as f64) * ratio).round() as usize;
        for algo in Algorithm::ALL {
            for policy in [CachePolicy::Lfu, CachePolicy::Window] {
                let pre = preprocess_with_policy(algo, &d, 4, ratio, policy, 7);
                let static_pre = preprocess(algo, &d, 4, ratio, 7);
                for (s, st) in pre.stores.iter().zip(&static_pre.stores) {
                    assert_eq!(s.policy(), policy);
                    assert_eq!(s.residency().resident_rows(), Some(cap), "{algo:?}");
                    // feature-dim range inherited from the static store
                    let (r, rs) = (s.residency(), st.residency());
                    assert_eq!((r.dim_lo, r.dim_hi, r.feat_dim), (rs.dim_lo, rs.dim_hi, rs.feat_dim));
                }
            }
        }
    }

    #[test]
    fn static_policy_matches_plain_preprocess() {
        let d = tiny();
        let a = preprocess(Algorithm::PaGraph, &d, 2, 0.15, 3);
        let b = preprocess_with_policy(Algorithm::PaGraph, &d, 2, 0.15, CachePolicy::Static, 3);
        assert_eq!(a.residency_snapshot(), b.residency_snapshot());
        assert_eq!(a.train_parts, b.train_parts);
    }

    #[test]
    #[should_panic(expected = "cache_ratio")]
    fn negative_cache_ratio_rejected() {
        let d = tiny();
        preprocess(Algorithm::PaGraph, &d, 2, -0.1, 3);
    }

    #[test]
    #[should_panic(expected = "cache_ratio")]
    fn cache_ratio_above_one_rejected() {
        let d = tiny();
        preprocess(Algorithm::PaGraph, &d, 2, 1.5, 3);
    }

    #[test]
    fn batches_in_part_ceils() {
        let d = tiny();
        let pre = preprocess(Algorithm::P3, &d, 2, 0.2, 7);
        let b = pre.batches_in_part(0, 100);
        assert_eq!(b, (pre.train_parts[0].len() + 99) / 100);
    }

    #[test]
    fn round_robin_split_is_balanced() {
        let vs: Vec<u32> = (0..103).collect();
        let parts = round_robin_split(&vs, 4);
        let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert_eq!(lens.iter().sum::<usize>(), 103);
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }
}
