//! P3-style preprocessing (Table 1).
//!
//! P3 (Gandhi & Iyer, OSDI'21) partitions along the *feature dimension*:
//! every device holds the full graph topology and an `f0/p`-wide slice of
//! every vertex's feature vector. Training targets are split evenly across
//! devices (P3 has no topology-induced imbalance). The extra all-to-all
//! after layer 1 is handled by the coordinator as a special case, exactly
//! as the paper does (Listing 3, lines 14–19).

use super::Preprocessed;
use crate::graph::Dataset;
use crate::store::{FeatureStore, Residency};

pub fn preprocess(data: &Dataset, p: usize) -> Preprocessed {
    let f0 = data.spec.dims.f0;
    assert!(p <= f0, "P3 needs at least one feature dim per device (p={p}, f0={f0})");

    // even dim slices: width ceil/floor mix so they cover [0, f0) exactly
    let stores: Vec<Box<dyn FeatureStore>> = (0..p)
        .map(|i| {
            let lo = i * f0 / p;
            let hi = (i + 1) * f0 / p;
            Box::new(Residency::dim_slice(lo, hi, f0)) as Box<dyn FeatureStore>
        })
        .collect();

    // targets split round-robin — deterministic and balanced
    let train_parts = super::round_robin_split(&data.train_vertices, p);

    Preprocessed {
        algo: super::Algorithm::P3,
        num_parts: p,
        vertex_part: None, // full topology everywhere
        train_parts,
        stores,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    #[test]
    fn slices_cover_feature_range_disjointly() {
        let d = datasets::lookup("amazon").unwrap().build(9, 5);
        let p = 4;
        let pre = preprocess(&d, p);
        let mut covered = vec![false; d.spec.dims.f0];
        for s in &pre.stores {
            let r = s.residency();
            for dim in r.dim_lo..r.dim_hi {
                assert!(!covered[dim], "dim {dim} covered twice");
                covered[dim] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn train_split_balanced_and_total() {
        let d = datasets::lookup("amazon").unwrap().build(9, 5);
        let pre = preprocess(&d, 3);
        let lens: Vec<usize> = pre.train_parts.iter().map(|t| t.len()).collect();
        assert_eq!(lens.iter().sum::<usize>(), d.train_vertices.len());
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }

    #[test]
    fn every_store_holds_every_row_partially() {
        let d = datasets::lookup("amazon").unwrap().build(9, 5);
        let pre = preprocess(&d, 4);
        for s in &pre.stores {
            let r = s.residency();
            assert!(r.holds_row(0));
            assert!(r.holds_row((d.graph.num_vertices() - 1) as u32));
            assert!((r.dim_fraction() - 0.25).abs() < 0.05);
        }
    }

    #[test]
    fn uneven_division_still_covers() {
        let d = datasets::lookup("ogbn-products").unwrap().build(9, 5); // f0=100
        let pre = preprocess(&d, 3);
        let widths: Vec<usize> =
            pre.stores.iter().map(|s| s.residency().dim_hi - s.residency().dim_lo).collect();
        assert_eq!(widths.iter().sum::<usize>(), 100);
    }

    #[test]
    #[should_panic(expected = "P3 needs")]
    fn too_many_parts_rejected() {
        let d = datasets::lookup("ogbn-products").unwrap().build(11, 5);
        preprocess(&d, 101);
    }
}
