//! PaGraph-style preprocessing (Table 1).
//!
//! Partitioning: "a greedy approach which aims to balance the number of
//! training vertices among partitions" — we implement PaGraph's scoring
//! rule: a training vertex goes to the partition maximising
//! `|N(v) ∩ TV_i| · (TV_avail_i / TV_expected)`, i.e. neighbour affinity
//! damped by remaining train-vertex budget. Non-training vertices follow
//! their neighbour majority (they only matter for β bookkeeping symmetry).
//!
//! Feature storing: "store feature vectors of vertices with high
//! out-degree" — every FPGA caches the same top-degree `cache_ratio·|V|`
//! rows (Listing 2 passes the same X to each FPGA), independent of the
//! partitioning.

use super::Preprocessed;
use crate::graph::Dataset;
use crate::store::{FeatureStore, Residency};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;

pub fn preprocess(data: &Dataset, p: usize, cache_ratio: f64, seed: u64) -> Preprocessed {
    let g = &data.graph;
    let n = g.num_vertices();

    // ---- partition training vertices greedily --------------------------
    let expected = (data.train_vertices.len() as f64 / p as f64).max(1.0);
    let mut tv_part: Vec<u32> = vec![u32::MAX; n]; // train-vertex assignment
    let mut tv_count = vec![0usize; p];
    let mut order = data.train_vertices.clone();
    Rng::new(seed ^ 0x9a6).shuffle(&mut order);

    let mut nbr_count = vec![0u32; p];
    for &v in &order {
        for x in nbr_count.iter_mut() {
            *x = 0;
        }
        for &u in g.neighbors(v) {
            let pu = tv_part[u as usize];
            if pu != u32::MAX {
                nbr_count[pu as usize] += 1;
            }
        }
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for i in 0..p {
            let avail = (expected * 1.02 - tv_count[i] as f64).max(0.0);
            let score = (1.0 + nbr_count[i] as f64) * avail / expected;
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        tv_part[v as usize] = best as u32;
        tv_count[best] += 1;
    }

    let mut train_parts = vec![Vec::new(); p];
    for &v in &data.train_vertices {
        train_parts[tv_part[v as usize] as usize].push(v);
    }

    // ---- assign remaining vertices by neighbour majority ----------------
    let mut part: Vec<u32> = tv_part;
    let mut rr = 0u32;
    for v in 0..n as u32 {
        if part[v as usize] != u32::MAX {
            continue;
        }
        for x in nbr_count.iter_mut() {
            *x = 0;
        }
        let mut best = u32::MAX;
        let mut best_c = 0u32;
        for &u in g.neighbors(v) {
            let pu = part[u as usize];
            if pu != u32::MAX {
                nbr_count[pu as usize] += 1;
                if nbr_count[pu as usize] > best_c {
                    best_c = nbr_count[pu as usize];
                    best = pu;
                }
            }
        }
        part[v as usize] = if best != u32::MAX {
            best
        } else {
            rr = (rr + 1) % p as u32;
            rr
        };
    }

    // ---- feature store: top out-degree cache, same on every FPGA --------
    let cache_rows = ((n as f64) * cache_ratio).round() as usize;
    let cached = top_degree_rows(data, cache_rows);
    let stores: Vec<Box<dyn FeatureStore>> = (0..p)
        .map(|_| {
            Box::new(Residency::rows_subset(cached.clone(), data.spec.dims.f0))
                as Box<dyn FeatureStore>
        })
        .collect();

    Preprocessed {
        algo: super::Algorithm::PaGraph,
        num_parts: p,
        vertex_part: Some(part),
        train_parts,
        stores,
    }
}

/// Bitmap of the `k` highest-out-degree vertices — the first `k` of the
/// canonical [`crate::store::dynamic::degree_order`], which the dynamic
/// cache policies also cold-start from (keeping policy sweeps paired).
pub fn top_degree_rows(data: &Dataset, k: usize) -> Bitset {
    let n = data.graph.num_vertices();
    let mut bits = Bitset::new(n);
    for &v in crate::store::dynamic::degree_order(data).iter().take(k.min(n)) {
        bits.set(v as usize);
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn data() -> Dataset {
        datasets::lookup("yelp").unwrap().build(8, 11)
    }

    #[test]
    fn train_counts_are_tightly_balanced() {
        let d = data();
        let pre = preprocess(&d, 4, 0.1, 2);
        let counts: Vec<usize> = pre.train_parts.iter().map(|t| t.len()).collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        // PaGraph's whole point: training vertices are balanced.
        assert!(
            (max - min) as f64 <= 0.05 * max as f64 + 2.0,
            "counts {counts:?}"
        );
    }

    #[test]
    fn all_vertices_assigned() {
        let d = data();
        let pre = preprocess(&d, 3, 0.1, 2);
        let part = pre.vertex_part.as_ref().unwrap();
        assert!(part.iter().all(|&x| x < 3));
    }

    #[test]
    fn stores_identical_and_sized_by_ratio() {
        let d = data();
        let ratio = 0.15;
        let pre = preprocess(&d, 4, ratio, 2);
        let expect = ((d.graph.num_vertices() as f64) * ratio).round() as usize;
        for s in &pre.stores {
            assert_eq!(s.residency().resident_rows(), Some(expect));
        }
        // identical caches on every FPGA (Listing 2: same X for each FPGA)
        let rows_of = |s: &dyn FeatureStore| -> Vec<usize> {
            match &s.residency().rows {
                crate::store::Rows::Subset(b) => b.iter_ones().collect(),
                _ => panic!(),
            }
        };
        let first = rows_of(pre.stores[0].as_ref());
        for s in &pre.stores[1..] {
            assert_eq!(rows_of(s.as_ref()), first);
        }
    }

    #[test]
    fn cache_prefers_high_degree() {
        let d = data();
        let bits = top_degree_rows(&d, 100);
        let g = &d.graph;
        let cached_min = bits
            .iter_ones()
            .map(|v| g.degree(v as u32))
            .min()
            .unwrap();
        // every uncached vertex must have degree <= the minimum cached degree
        let uncached_max = (0..g.num_vertices())
            .filter(|&v| !bits.get(v))
            .map(|v| g.degree(v as u32))
            .max()
            .unwrap();
        assert!(uncached_max <= cached_min);
    }

    #[test]
    fn zero_cache_ratio_gives_empty_stores() {
        let d = data();
        let pre = preprocess(&d, 2, 0.0, 2);
        for s in &pre.stores {
            assert_eq!(s.residency().resident_rows(), Some(0));
        }
    }
}
