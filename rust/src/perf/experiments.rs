//! Experiment drivers for the paper's evaluation section: one function per
//! table/figure, shared by `rust/benches/*` and the examples.
//!
//! Methodology (DESIGN.md §Per-experiment index): the *host side* is
//! measured — the real partitioner, feature stores, and sampler run on a
//! scaled R-MAT instance of each dataset, yielding β (local-fetch ratio),
//! train-vertex imbalance, mini-batch dedup factors, and sampling time.
//! Those measurements parameterise the §6.2 platform model at full scale
//! (the paper's own evaluation beyond 4 FPGAs is likewise simulator-based,
//! §7.6). Table 6/7 epochs are full passes over all vertices (this is the
//! only target-set choice that reproduces the paper's NVTPS magnitudes —
//! see EXPERIMENTS.md §Table 6).

use crate::fpga::timing::{BatchShape, ModelCost};
use crate::fpga::{DeviceSpec, DieConfig};
use crate::graph::datasets::{self, DatasetSpec};
use crate::partition::{preprocess_with_policy, Algorithm};
use crate::perf::gpu::{GpuModel, GpuPlatformSpec};
use crate::perf::{EpochEstimate, FleetModel, PlatformModel, PlatformSpec, Workload};
use crate::sampling::{FanoutConfig, Sampler, WeightMode};
use crate::sched::SchedMode;
use crate::store::{CachePolicy, FeatureStore};
use crate::util::rng::Rng;

/// Paper evaluation parameters (§7.1).
pub const PAPER_BATCH: f64 = 1024.0;
/// Paper fanouts in DESIGN.md §Mini-batch wire format order (input-side
/// hop first): the f64 twin of `sampling::PAPER_FANOUTS`.
pub const PAPER_FANOUTS_F: [f64; 2] = [25.0, 10.0];
/// The accelerator configuration the DSE selects (Table 5, FPGA-level
/// (8, 2048) = per-die (2, 512)) — the fleet registry's default die.
pub const BEST_DIE: DieConfig = crate::fpga::DEFAULT_DIE;
/// Host sampler threads per FPGA. The paper's host is a dual-socket EPYC
/// 7763 (128 cores) feeding 4 FPGAs; DistDGL-style loaders run many
/// sampler workers so per-batch sampling time divides across threads.
/// Our measurement is single-threaded — scale it down accordingly.
pub const SAMPLER_THREADS: f64 = 8.0;

/// Host-side measurements from the real partitioner + sampler.
#[derive(Clone, Debug)]
pub struct HostMeasurement {
    /// Steady-state local-fetch ratio against the executing FPGA's store
    /// — the **last epoch's** measured β (for static policies every epoch
    /// measures the same residency; for dynamic policies this is the
    /// re-ranked cache). This is what parameterises Eq. 7.
    pub beta: f64,
    /// Per-epoch measured β, in epoch order (`beta_epochs[0]` is the
    /// cold-start / static value).
    pub beta_epochs: Vec<f64>,
    /// Per-partition share of training batches (sums to 1).
    pub part_shares: Vec<f64>,
    /// Dedup factors vs the no-dedup nominal: [v0, v1] (v2 == 1).
    pub dedup: [f64; 2],
    /// Measured sampling seconds per batch (scaled graph).
    pub sampling_s: f64,
}

/// Measure β / imbalance / dedup on a scaled instance of `spec` with the
/// algorithm's static Table-1 store (one epoch — equivalent to
/// [`measure_host_policy`] at `CachePolicy::Static`).
///
/// `shift` trades fidelity for time; 4 (=1/16 scale) keeps the largest
/// graph (~16M edges) tractable while preserving degree skew.
pub fn measure_host(
    spec: &DatasetSpec,
    algo: Algorithm,
    model: &str,
    p: usize,
    shift: u32,
    n_batches: usize,
    seed: u64,
) -> anyhow::Result<HostMeasurement> {
    measure_host_policy(spec, algo, model, p, shift, n_batches, seed, CachePolicy::Static, 0.2, 1)
}

/// [`measure_host`] generalised over the feature-store policy: runs
/// `epochs` simulated epochs of `n_batches` batches each against the
/// epoch-versioned residency snapshot, feeding every batch's layer-0
/// access stream to the store's `observe` hook and applying `end_epoch`
/// re-ranking between epochs — exactly the coordinator's barrier
/// protocol, so the measured per-epoch β matches what a real training run
/// reports in `EpochMetrics`.
///
/// The sampled batches depend only on `(seed, epoch, batch)` — never on
/// the policy — so sweeping policies at equal `cache_ratio` is a paired
/// comparison.
#[allow(clippy::too_many_arguments)]
pub fn measure_host_policy(
    spec: &DatasetSpec,
    algo: Algorithm,
    model: &str,
    p: usize,
    shift: u32,
    n_batches: usize,
    seed: u64,
    policy: CachePolicy,
    cache_ratio: f64,
    epochs: usize,
) -> anyhow::Result<HostMeasurement> {
    anyhow::ensure!(epochs >= 1, "need at least one measurement epoch");
    let data = spec.build(shift, seed);
    let mut pre = preprocess_with_policy(algo, &data, p, cache_ratio, policy, seed);
    let mode = WeightMode::for_model(model)?;
    // Scale-matched batch size: dedup depends on the ratio of the sampled
    // neighborhood capacity to |V|, so shrinking the batch with the graph
    // (both ÷ 2^shift) keeps the measured dedup factor transferable to
    // full scale. Fanouts stay at the paper's 25/10.
    let scaled_batch = ((PAPER_BATCH as usize) >> shift).max(8);
    let cfg = FanoutConfig::new(scaled_batch, &crate::sampling::PAPER_FANOUTS);
    let mut sampler = Sampler::new(cfg.clone(), mode, data.graph.num_vertices(), seed ^ 0x5a);

    let mut rng = Rng::new(seed ^ 0xE0);
    let mut v0_sum = 0f64;
    let mut v1_sum = 0f64;
    let mut t_sample = 0f64;
    let mut batches_measured = 0usize;
    let dims = cfg.dims();
    let row_bytes = data.features.bytes_per_vertex();
    let mut beta_epochs = Vec::with_capacity(epochs);
    for epoch in 0..epochs {
        let snaps = pre.residency_snapshot();
        let vertex_part = pre.vertex_part.as_deref();
        let mut local = 0u64;
        let mut total = 0u64;
        for b in 0..n_batches {
            let part = b % p;
            let tp = &pre.train_parts[part];
            if tp.is_empty() {
                continue;
            }
            // random contiguous window of targets
            let start = rng.index(tp.len().saturating_sub(cfg.batch_size).max(1));
            let end = (start + cfg.batch_size).min(tp.len());
            let t0 = std::time::Instant::now();
            let mb = sampler.sample(&data, &tp[start..end], part, epoch * n_batches + b);
            t_sample += t0.elapsed().as_secs_f64();
            let traffic = crate::comm::feature_traffic(
                &mb,
                &snaps[part],
                row_bytes,
                crate::comm::CommConfig::default(),
                vertex_part,
                part,
            );
            pre.stores[part].observe(mb.level0());
            local += traffic.local_bytes;
            total += traffic.total_bytes();
            v0_sum += mb.n[0] as f64 / dims.caps[0] as f64;
            v1_sum += mb.n[1] as f64 / dims.caps[1] as f64;
            batches_measured += 1;
        }
        beta_epochs.push(if total == 0 { 1.0 } else { local as f64 / total as f64 });
        for s in pre.stores.iter_mut() {
            s.end_epoch();
        }
    }
    let n = batches_measured.max(1) as f64;
    let share_total: f64 = pre.train_parts.iter().map(|t| t.len() as f64).sum();
    Ok(HostMeasurement {
        beta: *beta_epochs.last().expect("epochs >= 1"),
        beta_epochs,
        part_shares: pre
            .train_parts
            .iter()
            .map(|t| t.len() as f64 / share_total)
            .collect(),
        dedup: [v0_sum / n, v1_sum / n],
        // scale measured single-thread sampling cost up to a paper-sized
        // batch, then across the host's sampler threads
        sampling_s: t_sample / n * (PAPER_BATCH / scaled_batch as f64) / SAMPLER_THREADS,
    })
}

/// Compose the full-scale workload for one (dataset, algo, model) cell.
pub fn build_workload(
    spec: &DatasetSpec,
    algo: Algorithm,
    model: &str,
    host: &HostMeasurement,
    p: usize,
    wb: bool,
    dc: bool,
) -> Workload {
    let f = [spec.dims.f0 as f64, spec.dims.f1 as f64, spec.dims.f2 as f64];
    let mut shape = BatchShape::nominal(PAPER_BATCH, &PAPER_FANOUTS_F, &f);
    // apply measured dedup to the vertex sets (edges |A^l| are unchanged:
    // every sampled edge is aggregated regardless of row dedup)
    shape.v[0] *= host.dedup[0];
    shape.v[1] *= host.dedup[1];

    // Table 6 epochs: full pass over all vertices (see module docs)
    let total_batches = (spec.vertices as f64 / PAPER_BATCH).ceil();
    let batches_per_part: Vec<usize> = host
        .part_shares
        .iter()
        .map(|s| (s * total_batches).round().max(1.0) as usize)
        .collect();

    // P3: feature access is slice-local (β=1) plus the layer-1 all-to-all
    // of partial activations (Listing 3) — 2(p-1)/p · |V^1|·f^1 floats.
    let (beta, extra) = if algo == Algorithm::P3 {
        let bytes = 2.0 * (p as f64 - 1.0) / p as f64 * shape.v[1] * f[1] * 4.0;
        (1.0, bytes)
    } else {
        (host.beta, 0.0)
    };

    Workload {
        shape,
        beta,
        cost: ModelCost::for_model(model).expect("model validated by measure_host"),
        sampling_s_per_batch: host.sampling_s,
        batches_per_part,
        workload_balancing: wb,
        direct_host_fetch: dc,
        extra_pcie_bytes_per_batch: extra,
        prefetch: false,
        disk_gbs: 0.0,
        disk_miss_frac: 0.0,
    }
}

/// One Table 6 cell: GPU baseline vs HitGNN.
#[derive(Clone, Debug)]
pub struct CrossPlatformRow {
    pub algo: Algorithm,
    pub model: String,
    pub dataset: &'static str,
    pub gpu: EpochEstimate,
    pub ours: EpochEstimate,
}

/// Table 6: 3 algorithms × 2 models × 4 datasets, GPU vs CPU+Multi-FPGA.
pub fn table6(p: usize, shift: u32, n_batches: usize) -> anyhow::Result<Vec<CrossPlatformRow>> {
    let mut fpga_spec = PlatformSpec::paper_4fpga();
    fpga_spec.num_fpgas = p;
    let mut gpu_spec = GpuPlatformSpec::paper_4gpu();
    gpu_spec.num_gpus = p;
    let fpga = PlatformModel::new(fpga_spec, BEST_DIE);
    let gpu = GpuModel::new(gpu_spec);

    let mut rows = Vec::new();
    for algo in Algorithm::ALL {
        for spec in &datasets::REGISTRY {
            // host statistics (β, shares, dedup) depend on the algorithm
            // and dataset but not on the GNN model — measure once per pair
            let host = measure_host(spec, algo, "gcn", p, shift, n_batches, 17)?;
            for model in ["gcn", "sage"] {
                // HitGNN: WB + DC on. GPU baseline: unmodified algorithm.
                let w_ours = build_workload(spec, algo, model, &host, p, true, true);
                let w_gpu = build_workload(spec, algo, model, &host, p, false, false);
                rows.push(CrossPlatformRow {
                    algo,
                    model: model.to_string(),
                    dataset: spec.key,
                    gpu: gpu.epoch(&w_gpu),
                    ours: fpga.epoch(&w_ours),
                });
            }
        }
    }
    Ok(rows)
}

/// One Table 7 ablation row.
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub dataset: &'static str,
    pub model: String,
    pub baseline: f64,
    pub wb: f64,
    pub wb_dc: f64,
}

impl AblationRow {
    pub fn speedup_pct(&self) -> f64 {
        (self.wb_dc / self.baseline - 1.0) * 100.0
    }
}

/// Table 7: DistDGL, throughput with {baseline, +WB, +WB+DC}, under the
/// static Table-1 store (the paper's configuration).
pub fn table7(p: usize, shift: u32, n_batches: usize) -> anyhow::Result<Vec<AblationRow>> {
    table7_with_policy(p, shift, n_batches, CachePolicy::Static, 0.2, 1)
}

/// [`table7`] with the Eq. 7 β measured under an explicit cache policy:
/// `epochs` simulated epochs drive the policy's observe/end_epoch loop
/// and the steady-state (last-epoch) β parameterises the platform model,
/// so the ablation reflects what a dynamic cache actually delivers.
pub fn table7_with_policy(
    p: usize,
    shift: u32,
    n_batches: usize,
    policy: CachePolicy,
    cache_ratio: f64,
    epochs: usize,
) -> anyhow::Result<Vec<AblationRow>> {
    let mut spec4 = PlatformSpec::paper_4fpga();
    spec4.num_fpgas = p;
    let fpga = PlatformModel::new(spec4, BEST_DIE);
    let mut rows = Vec::new();
    for spec in &datasets::REGISTRY {
        let host = measure_host_policy(
            spec,
            Algorithm::DistDgl,
            "gcn",
            p,
            shift,
            n_batches,
            17,
            policy,
            cache_ratio,
            epochs,
        )?;
        for model in ["gcn", "sage"] {
            let run = |wb, dc| {
                fpga.epoch(&build_workload(spec, Algorithm::DistDgl, model, &host, p, wb, dc))
                    .nvtps
            };
            rows.push(AblationRow {
                dataset: spec.key,
                model: model.to_string(),
                baseline: run(false, false),
                wb: run(true, false),
                wb_dc: run(true, true),
            });
        }
    }
    Ok(rows)
}

/// One scheduler-ablation row (Table-7 experiment path on a
/// heterogeneous fleet): epoch makespan-seconds under {WB off,
/// batch-count WB, cost-aware WB}, from the same measured host
/// statistics that parameterise `table7`.
#[derive(Clone, Debug)]
pub struct SchedAblationRow {
    pub dataset: &'static str,
    pub model: String,
    /// WB off (every batch on its own partition's device).
    pub makespan_base_s: f64,
    /// WB on, Algorithm 3's batch-count balancing.
    pub makespan_batch_s: f64,
    /// WB on, least-estimated-finish-time assignment.
    pub makespan_cost_s: f64,
    pub iterations: usize,
}

impl SchedAblationRow {
    /// Relative makespan reduction of cost-aware over batch-count WB.
    pub fn cost_gain_pct(&self) -> f64 {
        (1.0 - self.makespan_cost_s / self.makespan_batch_s) * 100.0
    }
}

/// Table-7-style scheduler ablation on a heterogeneous fleet: measure
/// host statistics per dataset (as `table7` does), compose the full-scale
/// workload, then drive the fleet model in each scheduler configuration.
/// `batches_per_part` overrides the measured shares when given (paired
/// sweeps over engineered imbalance profiles).
pub fn table7_fleet(
    fleet: &[DeviceSpec],
    cpu_mem_gbs: f64,
    shift: u32,
    n_batches: usize,
    batches_per_part: Option<&[usize]>,
) -> anyhow::Result<Vec<SchedAblationRow>> {
    let p = fleet.len();
    if let Some(b) = batches_per_part {
        anyhow::ensure!(b.len() == p, "batches_per_part must have one entry per device");
    }
    let fm = FleetModel::new(fleet.to_vec(), cpu_mem_gbs);
    let mut rows = Vec::new();
    for spec in &datasets::REGISTRY {
        let host = measure_host(spec, Algorithm::DistDgl, "gcn", p, shift, n_batches, 17)?;
        for model in ["gcn", "sage"] {
            let mut w = build_workload(spec, Algorithm::DistDgl, model, &host, p, true, true);
            if let Some(b) = batches_per_part {
                w.batches_per_part = b.to_vec();
            }
            let wb_batch = fm.epoch(&w, SchedMode::BatchCount);
            let wb_cost = fm.epoch(&w, SchedMode::Cost);
            let mut w_off = w.clone();
            w_off.workload_balancing = false;
            let base = fm.epoch(&w_off, SchedMode::BatchCount);
            rows.push(SchedAblationRow {
                dataset: spec.key,
                model: model.to_string(),
                makespan_base_s: base.makespan_seconds,
                makespan_batch_s: wb_batch.makespan_seconds,
                makespan_cost_s: wb_cost.makespan_seconds,
                iterations: wb_cost.iterations,
            });
        }
    }
    Ok(rows)
}

/// Fig 8: speedup vs FPGA count, per algorithm (ogbn-products, GraphSAGE —
/// the scalability workload).
///
/// Methodology follows the paper's simulator (§7.6): per-dataset host
/// statistics (β, dedup) are measured once on the reference 4-partition
/// preprocessing and held fixed across p, so the scaling limit is the
/// platform effect the paper analyses — CPU memory bandwidth saturating
/// at ~205/16 ≈ 12.8 concurrent PCIe fetchers — rather than partition-
/// locality drift (which their METIS partitioning also holds roughly
/// constant on the real datasets).
pub fn fig8(
    fpga_counts: &[usize],
    shift: u32,
    n_batches: usize,
) -> anyhow::Result<Vec<(Algorithm, Vec<f64>)>> {
    let spec = datasets::lookup("ogbn-products")?;
    let mut out = Vec::new();
    for algo in Algorithm::ALL {
        let mut host = measure_host(&spec, algo, "sage", 4, shift, n_batches.max(4), 23)?;
        let mut nvtps = Vec::new();
        for &p in fpga_counts {
            let mut plat = PlatformSpec::paper_4fpga();
            plat.num_fpgas = p;
            let fpga = PlatformModel::new(plat, BEST_DIE);
            // even batch shares at this p (WB absorbs residual imbalance)
            host.part_shares = vec![1.0 / p as f64; p];
            let w = build_workload(&spec, algo, "sage", &host, p, true, true);
            nvtps.push(fpga.epoch(&w).nvtps);
        }
        let base = nvtps[0];
        out.push((algo, nvtps.iter().map(|x| x / base).collect()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_measurement_is_sane() {
        let spec = datasets::lookup("reddit").unwrap();
        let h = measure_host(&spec, Algorithm::DistDgl, "gcn", 4, 7, 4, 3).unwrap();
        assert!(h.beta > 0.0 && h.beta <= 1.0, "beta={}", h.beta);
        assert_eq!(h.beta_epochs, vec![h.beta], "static single-epoch measurement");
        assert!((h.part_shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(h.dedup[0] > 0.0 && h.dedup[0] <= 1.0, "dedup0={}", h.dedup[0]);
        assert!(h.dedup[1] > 0.0 && h.dedup[1] <= 1.0, "dedup1={}", h.dedup[1]);
        assert!(h.sampling_s > 0.0);
    }

    #[test]
    fn policy_sweep_is_paired_and_records_per_epoch_beta() {
        let spec = datasets::lookup("reddit").unwrap();
        let st = measure_host_policy(
            &spec, Algorithm::PaGraph, "gcn", 4, 7, 8, 17, CachePolicy::Static, 0.1, 2,
        )
        .unwrap();
        let lfu = measure_host_policy(
            &spec, Algorithm::PaGraph, "gcn", 4, 7, 8, 17, CachePolicy::Lfu, 0.1, 2,
        )
        .unwrap();
        assert_eq!(st.beta_epochs.len(), 2);
        assert_eq!(lfu.beta_epochs.len(), 2);
        // identical batches + identical cold-start residency ⇒ epoch 0 is
        // bit-identical across policies (the sweep is a paired comparison)
        assert_eq!(st.beta_epochs[0], lfu.beta_epochs[0]);
        for b in lfu.beta_epochs.iter().chain(&st.beta_epochs) {
            assert!((0.0..=1.0).contains(b), "beta {b} out of range");
        }
    }

    #[test]
    fn lfu_policy_does_not_lose_to_static_pagraph() {
        // The micro_host cache-policy sweep asserts the strict win at
        // bench scale; tier-1 pins the invariant that re-ranking from
        // observed counts never ends up behind the degree-ranked static
        // fill at equal capacity, and wins strictly somewhere.
        let mut strict = 0;
        for key in ["reddit", "ogbn-products"] {
            let spec = datasets::lookup(key).unwrap();
            let st = measure_host_policy(
                &spec, Algorithm::PaGraph, "gcn", 4, 7, 16, 17, CachePolicy::Static, 0.1, 3,
            )
            .unwrap();
            let lfu = measure_host_policy(
                &spec, Algorithm::PaGraph, "gcn", 4, 7, 16, 17, CachePolicy::Lfu, 0.1, 3,
            )
            .unwrap();
            // tiny tolerance: boundary rows are re-ranked from finite
            // observations, so allow sampling noise without letting a
            // real regression through
            assert!(
                lfu.beta >= st.beta - 5e-3,
                "{key}: lfu beta {} < static beta {}",
                lfu.beta,
                st.beta
            );
            if lfu.beta > st.beta {
                strict += 1;
            }
        }
        assert!(strict >= 1, "LFU re-ranking changed nothing on any dataset");
    }

    #[test]
    fn p3_workload_has_full_beta_and_extra_comm() {
        let spec = datasets::lookup("yelp").unwrap();
        let h = measure_host(&spec, Algorithm::P3, "gcn", 4, 7, 4, 3).unwrap();
        let w = build_workload(&spec, Algorithm::P3, "gcn", &h, 4, true, true);
        assert_eq!(w.beta, 1.0);
        assert!(w.extra_pcie_bytes_per_batch > 0.0);
        let w2 = build_workload(
            &spec,
            Algorithm::DistDgl,
            "gcn",
            &h,
            4,
            true,
            true,
        );
        assert_eq!(w2.extra_pcie_bytes_per_batch, 0.0);
    }

    #[test]
    fn ablation_ordering_holds() {
        // WB ≥ baseline and WB+DC ≥ WB on every row (small sample size)
        let rows = table7(4, 8, 2).unwrap();
        assert_eq!(rows.len(), 8);
        for r in &rows {
            assert!(r.wb >= r.baseline * 0.999, "{r:?}");
            assert!(r.wb_dc >= r.wb * 0.999, "{r:?}");
        }
    }

    #[test]
    fn fleet_scheduler_ablation_ordering_holds() {
        // On a heterogeneous fleet, cost-aware WB never exceeds
        // batch-count WB, which never exceeds the no-WB baseline — and
        // the engineered tail profile yields a strict cost win.
        let fleet = crate::fpga::parse_fleet("u250-half:2,u250:2").unwrap();
        let profile = [6usize, 6, 20, 6];
        let rows = table7_fleet(&fleet, 205.0, 8, 2, Some(&profile[..])).unwrap();
        assert_eq!(rows.len(), 8);
        for r in &rows {
            assert!(
                r.makespan_cost_s < r.makespan_batch_s,
                "cost-aware must strictly win on the tail profile: {r:?}"
            );
            assert!(r.makespan_batch_s <= r.makespan_base_s * (1.0 + 1e-9), "{r:?}");
            assert!(r.cost_gain_pct() > 0.0);
        }
    }
}
