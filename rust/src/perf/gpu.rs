//! Analytic multi-GPU baseline (the paper's comparison platform: 4× RTX
//! A5000 running PyTorch-Geometric — Table 3).
//!
//! We cannot run the authors' GPU testbed, so the GPU rows of Table 6 are
//! produced by a bandwidth/compute model mirroring the structure of the
//! FPGA model: β-split feature access (local partition in HBM, misses over
//! PCIe), aggregation charged to HBM at a random-gather efficiency, update
//! charged to peak FLOPs at a small-matmul efficiency, plus a per-batch
//! framework overhead and an NCCL-style ring allreduce. The efficiency
//! constants are *global* (one set for all datasets/models/algorithms) and
//! were chosen once so the GPU geo-mean lands near the paper's — see
//! EXPERIMENTS.md §Table 6 for the paper-vs-model comparison.

use super::{EpochEstimate, Workload};
use crate::fpga::timing::S_FEAT;
use crate::sched::TwoStageScheduler;

/// GPU device metadata (Table 3's A5000 column).
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    pub hbm_gbs: f64,
    pub peak_tflops: f64,
}

pub const A5000: GpuSpec = GpuSpec { name: "NVIDIA RTX A5000", hbm_gbs: 768.0, peak_tflops: 27.8 };

/// Multi-GPU platform metadata.
#[derive(Clone, Copy, Debug)]
pub struct GpuPlatformSpec {
    pub num_gpus: usize,
    pub gpu: GpuSpec,
    pub pcie_gbs: f64,
    pub cpu_mem_gbs: f64,
}

impl GpuPlatformSpec {
    pub fn paper_4gpu() -> GpuPlatformSpec {
        GpuPlatformSpec { num_gpus: 4, gpu: A5000, pcie_gbs: 16.0, cpu_mem_gbs: 205.0 }
    }

    /// Platform bandwidth for the §7.4 BW-efficiency metric.
    pub fn total_bandwidth_gbs(&self) -> f64 {
        self.gpu.hbm_gbs * self.num_gpus as f64 + self.cpu_mem_gbs
    }
}

/// Efficiency constants of the GPU model (global across all workloads).
#[derive(Clone, Copy, Debug)]
pub struct GpuEfficiency {
    /// Achieved fraction of HBM bandwidth under edge-gather access.
    pub gather: f64,
    /// Achieved fraction of peak FLOPs on the (small) update GEMMs.
    pub gemm: f64,
    /// Achieved fraction of PCIe bandwidth for host feature fetches.
    pub pcie: f64,
    /// Per-batch framework overhead (kernel launches, python glue).
    pub overhead_s: f64,
}

impl Default for GpuEfficiency {
    fn default() -> Self {
        GpuEfficiency { gather: 0.30, gemm: 0.20, pcie: 0.75, overhead_s: 0.002 }
    }
}

/// Analytic multi-GPU platform model.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    pub spec: GpuPlatformSpec,
    pub eff: GpuEfficiency,
}

impl GpuModel {
    pub fn new(spec: GpuPlatformSpec) -> GpuModel {
        GpuModel { spec, eff: GpuEfficiency::default() }
    }

    /// Per-batch time on one GPU (forward + backward).
    pub fn batch_s(&self, w: &Workload) -> f64 {
        let s = &w.shape;
        let hbm = self.spec.gpu.hbm_gbs * 1e9;
        let flops = self.spec.gpu.peak_tflops * 1e12;

        // layer-0 feature access: β resident in HBM, misses over PCIe
        let feat_bytes = s.v[0] * s.f[0] * S_FEAT;
        let t_feat = feat_bytes * w.beta / (hbm * self.eff.gather)
            + feat_bytes * (1.0 - w.beta) / (self.spec.pcie_gbs * 1e9 * self.eff.pcie);

        // aggregation: per edge, read f + accumulate f + write back
        // (3 touches), bandwidth-bound at gather efficiency
        let mut t_agg = 0.0;
        for l in 1..=s.layers() {
            t_agg += s.a[l - 1] * s.f[l - 1] * S_FEAT * 3.0 / (hbm * self.eff.gather);
        }

        // update GEMMs: 2·|V^l|·f^{l-1}·f^l MACs per layer, plus the
        // per-edge attention score work (f^l MACs per edge) for models
        // whose cost carries an attention term
        let mut t_upd = 0.0;
        for l in 1..=s.layers() {
            t_upd += 2.0 * s.v[l] * s.f[l - 1] * s.f[l] * w.cost.param_scale
                / (flops * self.eff.gemm);
            t_upd += 2.0 * w.cost.attn_edge_scale * s.a[l - 1] * s.f[l]
                / (flops * self.eff.gemm);
        }

        // extra all-to-all traffic (P3) over PCIe
        let t_extra =
            w.extra_pcie_bytes_per_batch / (self.spec.pcie_gbs * 1e9 * self.eff.pcie);

        // forward + backward (backward re-traverses both stages)
        t_feat + 2.0 * (t_agg + t_upd) + t_extra + self.eff.overhead_s
    }

    /// NCCL-style ring allreduce of the gradients over PCIe.
    pub fn allreduce_s(&self, w: &Workload) -> f64 {
        let p = self.spec.num_gpus as f64;
        let bytes = w.shape.param_bytes(w.cost.param_scale) as f64;
        2.0 * bytes * (p - 1.0) / p / (self.spec.pcie_gbs * 1e9)
    }

    /// Epoch estimate, using the same scheduler abstraction as the FPGA
    /// model (the GPU baselines in the paper run the *unmodified*
    /// algorithms: no WB, but batches still execute synchronously).
    pub fn epoch(&self, w: &Workload) -> EpochEstimate {
        let p = self.spec.num_gpus;
        assert_eq!(w.batches_per_part.len(), p);
        let batch_s = self.batch_s(w);
        let sync_s = self.allreduce_s(w);

        let mut sched = TwoStageScheduler::new(p, false); // no WB on GPUs
        let plans = sched.plan_epoch(&w.batches_per_part);

        let mut epoch_s = 0.0;
        let mut total_batches = 0usize;
        for plan in &plans {
            let counts = plan.per_fpga_counts(p);
            total_batches += plan.tasks.len();
            let iter = counts
                .iter()
                .map(|&c| {
                    let exec = c as f64 * batch_s;
                    let samp = c as f64 * w.sampling_s_per_batch;
                    exec.max(samp)
                })
                .fold(0.0f64, f64::max);
            epoch_s += iter + sync_s;
        }

        let vertices = total_batches as f64 * w.shape.vertices();
        let nvtps = vertices / epoch_s;
        EpochEstimate {
            epoch_s,
            iterations: plans.len(),
            nvtps,
            bw_efficiency: nvtps / self.spec.total_bandwidth_gbs(),
            batch_gnn_s: batch_s,
            gradient_sync_s: sync_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::timing::{BatchShape, ModelCost};

    fn workload() -> Workload {
        Workload {
            shape: BatchShape::nominal(1024.0, &[25.0, 10.0], &[100.0, 128.0, 47.0]),
            beta: 0.7,
            cost: ModelCost::GCN,
            sampling_s_per_batch: 0.001,
            batches_per_part: vec![150; 4],
            workload_balancing: false,
            direct_host_fetch: false,
            extra_pcie_bytes_per_batch: 0.0,
            prefetch: false,
            disk_gbs: 0.0,
            disk_miss_frac: 0.0,
        }
    }

    #[test]
    fn epoch_is_consistent() {
        let m = GpuModel::new(GpuPlatformSpec::paper_4gpu());
        let w = workload();
        let e = m.epoch(&w);
        assert!(e.epoch_s > 0.0);
        let vertices = 600.0 * w.shape.vertices();
        assert!((e.nvtps - vertices / e.epoch_s).abs() / e.nvtps < 1e-12);
    }

    #[test]
    fn gpu_platform_bandwidth_matches_table3() {
        let s = GpuPlatformSpec::paper_4gpu();
        assert!((s.total_bandwidth_gbs() - (4.0 * 768.0 + 205.0)).abs() < 1e-9);
    }

    #[test]
    fn wider_features_cost_more() {
        let m = GpuModel::new(GpuPlatformSpec::paper_4gpu());
        let mut w = workload();
        let t_small = m.batch_s(&w);
        w.shape = BatchShape::nominal(1024.0, &[25.0, 10.0], &[602.0, 128.0, 41.0]);
        let t_big = m.batch_s(&w);
        assert!(t_big > 2.0 * t_small);
    }

    #[test]
    fn low_beta_hurts() {
        let m = GpuModel::new(GpuPlatformSpec::paper_4gpu());
        let mut w = workload();
        w.beta = 1.0;
        let fast = m.batch_s(&w);
        w.beta = 0.2;
        let slow = m.batch_s(&w);
        assert!(slow > fast);
    }

    #[test]
    fn allreduce_grows_with_p() {
        let w = workload();
        let m4 = GpuModel::new(GpuPlatformSpec::paper_4gpu());
        let mut s8 = GpuPlatformSpec::paper_4gpu();
        s8.num_gpus = 8;
        let m8 = GpuModel { spec: s8, eff: GpuEfficiency::default() };
        assert!(m8.allreduce_s(&w) > m4.allreduce_s(&w));
    }
}
