//! Platform-level performance model — §6.2 Eqs. 3–5 composed over the
//! whole CPU+Multi-FPGA platform, including the CPU-memory-bandwidth
//! saturation that limits scalability (§7.6) and the WB/DC optimization
//! toggles used by the Table 7 ablation.

pub mod experiments;
pub mod gpu;

use crate::fpga::timing::{BatchShape, ModelCost, TimingModel, S_FEAT};
use crate::fpga::{DeviceSpec, DieConfig, FpgaSpec};
use crate::sched::{epoch_makespan_batches, epoch_makespan_seconds, CostModel, SchedMode, TwoStageScheduler};

/// Platform metadata (the `Platform_Metadata()` API of Table 2).
#[derive(Clone, Copy, Debug)]
pub struct PlatformSpec {
    pub num_fpgas: usize,
    pub fpga: FpgaSpec,
    /// Host↔FPGA PCIe bandwidth per link (GB/s). Paper: 16 (PCIe 3x16).
    pub pcie_gbs: f64,
    /// Host CPU memory bandwidth (GB/s). Paper: 205 (EPYC 7763).
    pub cpu_mem_gbs: f64,
}

impl PlatformSpec {
    pub fn paper_4fpga() -> PlatformSpec {
        PlatformSpec {
            num_fpgas: 4,
            fpga: crate::fpga::U250,
            pcie_gbs: 16.0,
            cpu_mem_gbs: 205.0,
        }
    }

    /// "Available memory bandwidth of the target platform" used by the
    /// paper's bandwidth-efficiency metric (§7.4): device DDR × p + CPU.
    pub fn total_bandwidth_gbs(&self) -> f64 {
        self.fpga.ddr_gbs_total() * self.num_fpgas as f64 + self.cpu_mem_gbs
    }

    /// Effective host-fetch bandwidth per FPGA: the PCIe link rate until
    /// `p` concurrent fetchers saturate CPU memory (the Fig. 8 limiter:
    /// 205/16 ≈ 12.8 FPGAs).
    pub fn effective_host_fetch_gbs(&self) -> f64 {
        self.pcie_gbs.min(self.cpu_mem_gbs / self.num_fpgas as f64)
    }
}

/// Per-workload inputs to the platform model.
#[derive(Clone, Debug)]
pub struct Workload {
    pub shape: BatchShape,
    /// Local-fetch ratio β per FPGA (measured or estimated).
    pub beta: f64,
    /// Model-dependent cost terms (weight-matrix multiplicity plus the
    /// attention edge-score term) — see [`ModelCost::for_model`].
    pub cost: ModelCost,
    /// Host-side sampling time per mini-batch (overlapped with compute).
    pub sampling_s_per_batch: f64,
    /// Mini-batches per partition for one epoch.
    pub batches_per_part: Vec<usize>,
    /// WB optimization (two-stage scheduling).
    pub workload_balancing: bool,
    /// DC optimization (direct host fetch instead of FPGA-to-FPGA).
    pub direct_host_fetch: bool,
    /// Extra per-batch PCIe bytes (P3's layer-1 all-to-all of partial
    /// activations — Listing 3 lines 14–19; 0 for DistDGL/PaGraph).
    pub extra_pcie_bytes_per_batch: f64,
    /// Data prefetching (the paper's §8 future-work extension): the host
    /// pushes batch i+1's feature misses over PCIe while the FPGA computes
    /// batch i, hiding the host-fetch latency behind compute instead of
    /// serialising it into Eq. 7.
    pub prefetch: bool,
    /// Disk read bandwidth (GB/s) feeding the host-DRAM tier for
    /// out-of-core datasets. 0 = dataset is DRAM-resident, no disk term.
    pub disk_gbs: f64,
    /// Fraction of feature-miss bytes that fall through the host-DRAM
    /// tier to disk (measured `disk_read / missed` from the previous
    /// epoch's `Traffic`, or `1 - dram_ratio` cold-start). Only
    /// meaningful with `disk_gbs > 0`.
    pub disk_miss_frac: f64,
}

/// Epoch-level estimate.
#[derive(Clone, Copy, Debug)]
pub struct EpochEstimate {
    pub epoch_s: f64,
    pub iterations: usize,
    /// Number of Vertices Traversed Per Second (Eq. 3).
    pub nvtps: f64,
    /// NVTPS / platform bandwidth (§7.4).
    pub bw_efficiency: f64,
    /// Per-batch GNN time on one FPGA (diagnostics).
    pub batch_gnn_s: f64,
    pub gradient_sync_s: f64,
}

/// Analytic model of the CPU+Multi-FPGA platform.
#[derive(Clone, Copy, Debug)]
pub struct PlatformModel {
    pub spec: PlatformSpec,
    pub die: DieConfig,
}

impl PlatformModel {
    pub fn new(spec: PlatformSpec, die: DieConfig) -> PlatformModel {
        PlatformModel { spec, die }
    }

    /// Per-batch timing on one FPGA under this workload's communication
    /// configuration (see [`device_batch_gnn_s`]).
    pub fn batch_gnn_s(&self, w: &Workload) -> f64 {
        device_batch_gnn_s(
            self.spec.fpga,
            self.die,
            self.spec.pcie_gbs,
            self.spec.cpu_mem_gbs / self.spec.num_fpgas as f64,
            self.spec.cpu_mem_gbs,
            w,
        )
    }

    /// Gradient synchronisation per iteration (Eq. 4's extra term).
    pub fn gradient_sync_s(&self, w: &Workload) -> f64 {
        let param_bytes = w.shape.param_bytes(w.cost.param_scale);
        crate::comm::gradient_sync_seconds(
            param_bytes,
            self.spec.num_fpgas,
            self.spec.pcie_gbs,
            self.spec.cpu_mem_gbs,
        )
    }

    /// Eq. 3–5 composed over a full epoch, driving the real two-stage
    /// scheduler so WB on/off changes the iteration makespans exactly as
    /// it does in the execution path.
    pub fn epoch(&self, w: &Workload) -> EpochEstimate {
        let p = self.spec.num_fpgas;
        assert_eq!(w.batches_per_part.len(), p, "one partition per FPGA");
        let batch_gnn_s = self.batch_gnn_s(w);
        let sync_s = self.gradient_sync_s(w);

        let mut sched = TwoStageScheduler::new(p, w.workload_balancing);
        let plans = sched.plan_epoch(&w.batches_per_part);

        let mut epoch_s = 0.0;
        let mut total_batches = 0usize;
        for plan in &plans {
            let counts = plan.per_fpga_counts(p);
            total_batches += plan.tasks.len();
            // Eq. 4/5: slowest FPGA bounds the iteration; sampling (on the
            // host, all partitions in parallel with compute) overlaps.
            let iter_exec = counts
                .iter()
                .map(|&c| {
                    let gnn = c as f64 * batch_gnn_s;
                    let samp = c as f64 * w.sampling_s_per_batch;
                    gnn.max(samp)
                })
                .fold(0.0f64, f64::max);
            epoch_s += iter_exec + sync_s;
        }

        let vertices = total_batches as f64 * w.shape.vertices();
        let nvtps = vertices / epoch_s;
        EpochEstimate {
            epoch_s,
            iterations: plans.len(),
            nvtps,
            bw_efficiency: nvtps / self.spec.total_bandwidth_gbs(),
            batch_gnn_s,
            gradient_sync_s: sync_s,
        }
    }
}

/// Per-batch GNN time of one device under workload `w` — the shared
/// §6.2 per-device model behind `PlatformModel`, [`FleetModel`], the DSE
/// engine and the trainer's scheduler cost model, so all four agree.
///
/// `cpu_share_gbs` is this device's share of host CPU memory bandwidth
/// (`cpu_mem_gbs / p`): the host-fetch path runs at PCIe speed until `p`
/// concurrent fetchers saturate CPU memory (the Fig. 8 limiter). DC-off
/// reroutes feature misses through the shared host buffer: two PCIe
/// crossings plus a CPU copy (§5.2, [26]).
pub fn device_batch_gnn_s(
    fpga: FpgaSpec,
    die: DieConfig,
    pcie_gbs: f64,
    cpu_share_gbs: f64,
    cpu_mem_gbs: f64,
    w: &Workload,
) -> f64 {
    let mut t = TimingModel::new(fpga, die, pcie_gbs);
    // host-fetch path: PCIe limited by CPU memory saturation
    let host_gbs = pcie_gbs.min(cpu_share_gbs);
    let miss_gbs = if w.direct_host_fetch {
        host_gbs
    } else {
        // FPGA→host-buffer→FPGA: pipelined crossings + host copy
        1.0 / (crate::comm::F2F_PENALTY / host_gbs + 1.0 / cpu_mem_gbs)
    };
    t.bw.pcie_gbs = miss_gbs;
    let extra = w.extra_pcie_bytes_per_batch / (host_gbs * 1e9);
    // Out-of-core term: the slice of miss bytes that fell through the
    // host-DRAM tier is first paged in from disk before it can cross
    // PCIe. Proportional to (1-β), so β-monotonicity is preserved.
    let miss_bytes = w.shape.v[0] * w.shape.f[0] * S_FEAT * (1.0 - w.beta);
    let disk_s = if w.disk_gbs > 0.0 {
        miss_bytes * w.disk_miss_frac.clamp(0.0, 1.0) / (w.disk_gbs * 1e9)
    } else {
        0.0
    };
    if w.prefetch {
        // §8 extension: the host-fetch stream for batch i+1 overlaps
        // batch i's compute. Steady state: per-batch time is the max
        // of (GNN time with all features staged locally) and the
        // PCIe/host fetch time of one batch's misses (disk page-in
        // feeds that same overlapped stream).
        let gnn_local = t.batch(&w.shape, 1.0, w.cost).gnn_s;
        let fetch = miss_bytes / (miss_gbs * 1e9) + extra + disk_s;
        gnn_local.max(fetch)
    } else {
        t.batch(&w.shape, w.beta, w.cost).gnn_s + extra + disk_s
    }
}

/// Epoch-level estimate for a heterogeneous fleet.
#[derive(Clone, Copy, Debug)]
pub struct FleetEpochEstimate {
    pub epoch_s: f64,
    pub iterations: usize,
    pub nvtps: f64,
    /// Epoch makespan in batch units (Σ per-iteration max batch count).
    pub makespan_batches: usize,
    /// Epoch makespan in seconds (Σ per-iteration slowest-device compute
    /// time) — the quantity cost-aware scheduling minimises.
    pub makespan_seconds: f64,
    pub gradient_sync_s: f64,
}

/// Analytic model of a heterogeneous CPU+Multi-FPGA fleet: per-device
/// §6.2 timing models composed through the real two-stage scheduler.
/// [`PlatformModel`] is the homogeneous special case.
#[derive(Clone, Debug)]
pub struct FleetModel {
    pub devices: Vec<DeviceSpec>,
    /// Host CPU memory bandwidth (GB/s), shared by all devices.
    pub cpu_mem_gbs: f64,
}

impl FleetModel {
    pub fn new(devices: Vec<DeviceSpec>, cpu_mem_gbs: f64) -> FleetModel {
        assert!(!devices.is_empty(), "fleet needs at least one device");
        FleetModel { devices, cpu_mem_gbs }
    }

    /// Homogeneous fleet from the paper-style platform metadata.
    pub fn from_platform(spec: PlatformSpec, die: DieConfig) -> FleetModel {
        let dev = DeviceSpec::custom(spec.fpga, die, spec.pcie_gbs);
        FleetModel::new(vec![dev; spec.num_fpgas], spec.cpu_mem_gbs)
    }

    pub fn num_fpgas(&self) -> usize {
        self.devices.len()
    }

    /// Per-device seconds per mini-batch — the scheduler's cost model.
    /// Every consumer of per-device timing (trainer scheduling, DSE,
    /// `simulate`) goes through this one function.
    pub fn cost_model(&self, w: &Workload) -> CostModel {
        let p = self.devices.len();
        let share = self.cpu_mem_gbs / p as f64;
        CostModel::new(
            self.devices
                .iter()
                .map(|d| device_batch_gnn_s(d.fpga, d.die, d.pcie_gbs, share, self.cpu_mem_gbs, w))
                .collect(),
        )
    }

    /// Gradient synchronisation per iteration: bounded by the slowest
    /// PCIe link in the fleet (synchronous all-reduce).
    pub fn gradient_sync_s(&self, w: &Workload) -> f64 {
        let min_pcie = self.devices.iter().map(|d| d.pcie_gbs).fold(f64::INFINITY, f64::min);
        crate::comm::gradient_sync_seconds(
            w.shape.param_bytes(w.cost.param_scale),
            self.devices.len(),
            min_pcie,
            self.cpu_mem_gbs,
        )
    }

    /// Eq. 3–5 composed over a full epoch on the fleet, driving the real
    /// two-stage scheduler in the requested assignment mode so the
    /// estimate and the trainer plan identically.
    pub fn epoch(&self, w: &Workload, mode: SchedMode) -> FleetEpochEstimate {
        let p = self.devices.len();
        assert_eq!(w.batches_per_part.len(), p, "one partition per device");
        let cost = self.cost_model(w);
        let sync_s = self.gradient_sync_s(w);

        let mut sched =
            TwoStageScheduler::for_mode(p, w.workload_balancing, mode, Some(cost.clone()));
        let plans = sched.plan_epoch(&w.batches_per_part);

        let mut epoch_s = 0.0;
        let mut total_batches = 0usize;
        for plan in &plans {
            let counts = plan.per_fpga_counts(p);
            total_batches += plan.tasks.len();
            // slowest device bounds the iteration; host sampling overlaps
            let iter_exec = counts
                .iter()
                .enumerate()
                .map(|(i, &c)| {
                    let gnn = c as f64 * cost.batch_s[i];
                    let samp = c as f64 * w.sampling_s_per_batch;
                    gnn.max(samp)
                })
                .fold(0.0f64, f64::max);
            epoch_s += iter_exec + sync_s;
        }

        let vertices = total_batches as f64 * w.shape.vertices();
        FleetEpochEstimate {
            epoch_s,
            iterations: plans.len(),
            nvtps: vertices / epoch_s,
            makespan_batches: epoch_makespan_batches(&plans, p),
            makespan_seconds: epoch_makespan_seconds(&plans, &cost),
            gradient_sync_s: sync_s,
        }
    }

    /// The stage-2 assignment mode this fleet's cost model prefers for
    /// workload `w` — the auto-tuner's modeled scheduling prior
    /// (`tune::TunePrior`). `Cost` on ties: it plans identically to
    /// batch-count whenever per-device costs agree, so the tuner then
    /// skips the flip trial entirely.
    pub fn preferred_sched(&self, w: &Workload) -> SchedMode {
        let bc = self.epoch(w, SchedMode::BatchCount).makespan_seconds;
        let cost = self.epoch(w, SchedMode::Cost).makespan_seconds;
        if bc < cost {
            SchedMode::BatchCount
        } else {
            SchedMode::Cost
        }
    }
}

/// Eq. 7-style β estimate for a nominal workload where a fraction
/// `local_rows` of sampled rows hit the local store with dim fraction
/// `dim_frac` (analytic benches that do not sample).
pub fn beta_estimate(local_rows: f64, dim_frac: f64) -> f64 {
    (local_rows * dim_frac).clamp(0.0, 1.0)
}

/// Bytes of one epoch's feature traffic (diagnostics for EXPERIMENTS.md).
pub fn epoch_feature_bytes(w: &Workload) -> f64 {
    let batches: usize = w.batches_per_part.iter().sum();
    batches as f64 * w.shape.v[0] * w.shape.f[0] * S_FEAT
}

#[cfg(test)]
mod tests {
    use super::*;
    fn workload(p: usize) -> Workload {
        Workload {
            shape: BatchShape::nominal(1024.0, &[25.0, 10.0], &[100.0, 128.0, 47.0]),
            beta: 0.8,
            cost: ModelCost::GCN,
            sampling_s_per_batch: 0.001,
            batches_per_part: vec![48; p],
            workload_balancing: true,
            direct_host_fetch: true,
            extra_pcie_bytes_per_batch: 0.0,
            prefetch: false,
            disk_gbs: 0.0,
            disk_miss_frac: 0.0,
        }
    }

    fn model(p: usize) -> PlatformModel {
        let mut spec = PlatformSpec::paper_4fpga();
        spec.num_fpgas = p;
        PlatformModel::new(spec, DieConfig { n: 2, m: 512 })
    }

    #[test]
    fn epoch_estimate_is_positive_and_consistent() {
        let m = model(4);
        let w = workload(4);
        let e = m.epoch(&w);
        assert!(e.epoch_s > 0.0);
        assert_eq!(e.iterations, 48);
        let vertices = 4.0 * 48.0 * w.shape.vertices();
        assert!((e.nvtps - vertices / e.epoch_s).abs() / e.nvtps < 1e-12);
        assert!(e.bw_efficiency > 0.0);
    }

    #[test]
    fn wb_improves_imbalanced_epochs() {
        let m = model(4);
        let mut w = workload(4);
        w.batches_per_part = vec![80, 40, 40, 32];
        let on = m.epoch(&w);
        w.workload_balancing = false;
        let off = m.epoch(&w);
        assert!(on.epoch_s < off.epoch_s, "on={} off={}", on.epoch_s, off.epoch_s);
        assert!(on.nvtps > off.nvtps);
    }

    #[test]
    fn dc_improves_low_beta_epochs() {
        let m = model(4);
        let mut w = workload(4);
        w.beta = 0.3;
        let on = m.epoch(&w);
        w.direct_host_fetch = false;
        let off = m.epoch(&w);
        assert!(on.epoch_s < off.epoch_s);
    }

    #[test]
    fn scaling_sublinear_beyond_cpu_bw_saturation() {
        // Fig. 8: speedup is near-linear until ~13 FPGAs, then flattens.
        let base = {
            let m = model(1);
            let mut w = workload(1);
            w.beta = 0.5;
            m.epoch(&w).nvtps
        };
        let at = |p: usize| {
            let m = model(p);
            let mut w = workload(p);
            w.beta = 0.5;
            m.epoch(&w).nvtps / base
        };
        let s8 = at(8);
        let s16 = at(16);
        let s32 = at(32);
        assert!(s8 > 6.0, "s8={s8}");
        assert!(s16 > s8);
        // past saturation the marginal gain collapses
        assert!(s32 - s16 < 0.35 * (s16 - s8), "s16={s16} s32={s32}");
    }

    #[test]
    fn effective_host_fetch_saturates() {
        let mut spec = PlatformSpec::paper_4fpga();
        assert_eq!(spec.effective_host_fetch_gbs(), 16.0);
        spec.num_fpgas = 16;
        assert!((spec.effective_host_fetch_gbs() - 205.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn total_bandwidth_matches_paper_platform() {
        let spec = PlatformSpec::paper_4fpga();
        // 4×77 + 205 = 513 GB/s
        assert!((spec.total_bandwidth_gbs() - 513.0).abs() < 1e-9);
    }

    #[test]
    fn homogeneous_fleet_matches_platform_model() {
        let spec = PlatformSpec::paper_4fpga();
        let die = DieConfig { n: 2, m: 512 };
        let pm = PlatformModel::new(spec, die);
        let fm = FleetModel::from_platform(spec, die);
        let mut w = workload(4);
        w.batches_per_part = vec![80, 40, 40, 32];
        let a = pm.epoch(&w);
        for mode in SchedMode::ALL {
            let b = fm.epoch(&w, mode);
            // identical per-device model + identical plans on equal costs
            assert_eq!(a.epoch_s, b.epoch_s, "{mode:?}");
            assert_eq!(a.iterations, b.iterations);
            assert_eq!(a.nvtps, b.nvtps);
            assert_eq!(a.gradient_sync_s, b.gradient_sync_s);
        }
    }

    #[test]
    fn cost_mode_reduces_makespan_on_heterogeneous_fleet() {
        // 2 half-bandwidth devices first (the devices batch-count WB hands
        // extras to first), 2 full U250s carrying the long partition
        let fleet = crate::fpga::parse_fleet("u250-half:2,u250:2").unwrap();
        let fm = FleetModel::new(fleet, 205.0);
        let mut w = workload(4);
        w.batches_per_part = vec![6, 6, 20, 6];
        let bc = fm.epoch(&w, SchedMode::BatchCount);
        let ca = fm.epoch(&w, SchedMode::Cost);
        assert!(
            ca.makespan_seconds < bc.makespan_seconds,
            "cost {} !< batch-count {}",
            ca.makespan_seconds,
            bc.makespan_seconds
        );
        assert!(ca.epoch_s < bc.epoch_s);
        assert!(ca.nvtps > bc.nvtps);
        // same batches, same iteration structure: the batch-unit makespan
        // is mode-invariant — only the seconds change
        assert_eq!(ca.iterations, bc.iterations);
        assert_eq!(ca.makespan_batches, bc.makespan_batches);
    }

    #[test]
    fn preferred_sched_is_cost_on_het_fleets_and_on_homogeneous_ties() {
        let het = FleetModel::new(crate::fpga::parse_fleet("u250-half:2,u250:2").unwrap(), 205.0);
        let mut w = workload(4);
        w.batches_per_part = vec![6, 6, 20, 6];
        assert_eq!(het.preferred_sched(&w), SchedMode::Cost);
        // homogeneous: both modes plan identically → tie → Cost
        let hom =
            FleetModel::from_platform(PlatformSpec::paper_4fpga(), DieConfig { n: 2, m: 512 });
        assert_eq!(hom.preferred_sched(&w), SchedMode::Cost);
    }

    #[test]
    fn fleet_cost_model_orders_devices_by_capability() {
        let fleet = crate::fpga::parse_fleet("u250,u250-half,u250-quarter").unwrap();
        let fm = FleetModel::new(fleet, 205.0);
        let w = workload(3);
        let cost = fm.cost_model(&w);
        assert!(cost.batch_s[0] < cost.batch_s[1], "{:?}", cost.batch_s);
        assert!(cost.batch_s[1] < cost.batch_s[2], "{:?}", cost.batch_s);
        // shared-PCIe device only pays when it misses (β < 1)
        let shared = FleetModel::new(crate::fpga::parse_fleet("u250,u250-shared").unwrap(), 205.0);
        let mut w2 = workload(2);
        w2.beta = 0.3;
        let c2 = shared.cost_model(&w2);
        assert!(c2.batch_s[1] > c2.batch_s[0], "{:?}", c2.batch_s);
    }

    #[test]
    fn sampling_bound_epochs_are_flat_in_die_config() {
        // if sampling dominates (Eq. 5 max), faster accelerators don't help
        let mut w = workload(4);
        w.sampling_s_per_batch = 10.0;
        let slow = PlatformModel::new(PlatformSpec::paper_4fpga(), DieConfig { n: 1, m: 64 });
        let fast = PlatformModel::new(PlatformSpec::paper_4fpga(), DieConfig { n: 4, m: 512 });
        let a = slow.epoch(&w);
        let b = fast.epoch(&w);
        assert!((a.epoch_s - b.epoch_s).abs() / a.epoch_s < 0.05);
    }
}
