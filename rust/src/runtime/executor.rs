//! Typed execution of one AOT artifact, behind a pluggable backend.
//!
//! A [`TrainExecutor`] is the per-simulated-FPGA compute engine: it turns
//! (parameters, mini-batch buffers) into (loss, gradients). Two backends:
//!
//! - **PJRT** (`--features pjrt`): parses the artifact's HLO text and
//!   compiles it on the PJRT CPU client (the xla handles are not `Send`,
//!   so each worker thread owns its own client + executable).
//! - **Reference** (default): the pure-Rust model implementation in
//!   [`super::reference`] — same semantics, no external dependencies, no
//!   artifact files needed. This keeps the crate self-contained offline.

use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::manifest::ArtifactEntry;
use super::reference::RefModel;
use crate::sampling::MiniBatch;

/// Flat mini-batch input buffers in artifact order (feat0 gathered by the
/// comm layer — see `comm::FeatureService`). `idx[l-1]`/`w[l-1]` carry
/// layer l's positions/weights, layer 1 (input side) first — the same
/// level lists as [`MiniBatch`] (DESIGN.md §Mini-batch wire format).
///
/// The buffers are recyclable: [`BatchBuffers::fill_from`] overwrites an
/// existing instance in place (no allocation once the capacities are
/// grown), which is how the prep pool reuses consumed batches
/// (DESIGN.md §Hot-path memory & kernels).
#[derive(Clone, Debug)]
pub struct BatchBuffers {
    pub feat0: Vec<f32>,
    pub idx: Vec<Vec<i32>>,
    pub w: Vec<Vec<f32>>,
    pub labels: Vec<i32>,
    pub mask: Vec<f32>,
    /// Real (unpadded) per-level row counts `n[0..=L]` — lets the
    /// reference executor skip padding rows. Empty = unknown (legacy
    /// construction; the executor then sweeps full capacities).
    pub n: Vec<usize>,
}

impl BatchBuffers {
    /// An unsized carcass for the recycling pool; [`BatchBuffers::fill_from`]
    /// (after a feature gather into `feat0`) makes it a real batch.
    pub fn empty() -> BatchBuffers {
        BatchBuffers {
            feat0: Vec::new(),
            idx: Vec::new(),
            w: Vec::new(),
            labels: Vec::new(),
            mask: Vec::new(),
            n: Vec::new(),
        }
    }

    /// Assemble from a sampled mini-batch plus the gathered features.
    pub fn from_minibatch(mb: &MiniBatch, feat0: Vec<f32>, f0: usize) -> BatchBuffers {
        let mut b = BatchBuffers::empty();
        b.feat0 = feat0;
        b.fill_from(mb, f0);
        b
    }

    /// Overwrite every field (except `feat0`, which the comm layer's
    /// `gather_into` fills beforehand) from a sampled mini-batch. All
    /// copies are full-buffer, so a recycled instance carries no state
    /// from its previous batch.
    pub fn fill_from(&mut self, mb: &MiniBatch, f0: usize) {
        assert_eq!(self.feat0.len(), mb.dims.v0_cap() * f0, "feat0 buffer size mismatch");
        let lcount = mb.layers();
        self.idx.resize(lcount, Vec::new());
        self.w.resize(lcount, Vec::new());
        for (dst, src) in self.idx.iter_mut().zip(&mb.idx) {
            dst.clear();
            dst.extend_from_slice(src);
        }
        for (dst, src) in self.w.iter_mut().zip(&mb.w) {
            dst.clear();
            dst.extend_from_slice(src);
        }
        self.labels.clear();
        self.labels.extend(mb.labels.iter().map(|&l| l as i32));
        self.mask.clear();
        self.mask.extend_from_slice(&mb.mask);
        self.n.clear();
        self.n.extend_from_slice(&mb.n);
    }
}

/// Per-parameter gradient buffers in the artifact's parameter order —
/// the gradient-side analogue of [`BatchBuffers`]. Recyclable: the
/// trainer keeps a pool of consumed instances and threads them back to
/// the workers through `WorkItem`, so [`TrainExecutor::train_step_into`]
/// only allocates on first use (DESIGN.md §SIMD dispatch & gradient
/// sync).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GradBuffers {
    bufs: Vec<Vec<f32>>,
}

impl GradBuffers {
    /// An unsized carcass for the recycling pool; `train_step_into`
    /// sizes it to the artifact's parameter shapes on first use.
    pub fn empty() -> GradBuffers {
        GradBuffers { bufs: Vec::new() }
    }

    /// Resize to `count` tensors, each sized by `len(i)`. Existing
    /// buffers of the right length are kept as-is (contents stale — the
    /// caller must fully overwrite); growth allocates, shrink keeps
    /// capacity.
    pub fn resize_with(&mut self, count: usize, len: impl Fn(usize) -> usize) {
        self.bufs.resize(count, Vec::new());
        for (i, buf) in self.bufs.iter_mut().enumerate() {
            buf.resize(len(i), 0.0);
        }
    }
}

impl std::ops::Deref for GradBuffers {
    type Target = [Vec<f32>];
    fn deref(&self) -> &[Vec<f32>] {
        &self.bufs
    }
}

impl std::ops::DerefMut for GradBuffers {
    fn deref_mut(&mut self) -> &mut [Vec<f32>] {
        &mut self.bufs
    }
}

impl From<Vec<Vec<f32>>> for GradBuffers {
    fn from(bufs: Vec<Vec<f32>>) -> GradBuffers {
        GradBuffers { bufs }
    }
}

/// Deref does not satisfy generic `IntoIterator` bounds (e.g. `zip`),
/// so borrow-iteration is provided directly.
impl<'a> IntoIterator for &'a GradBuffers {
    type Item = &'a Vec<f32>;
    type IntoIter = std::slice::Iter<'a, Vec<f32>>;
    fn into_iter(self) -> Self::IntoIter {
        self.bufs.iter()
    }
}

/// One train-step result.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Gradients in the artifact's parameter order.
    pub grads: GradBuffers,
}

enum Backend {
    #[cfg(feature = "pjrt")]
    Pjrt {
        _client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
    },
    #[allow(dead_code)] // the only variant without `pjrt`
    Reference(RefModel),
}

/// Executor for one artifact (train or predict).
pub struct TrainExecutor {
    entry: ArtifactEntry,
    backend: Backend,
}

impl TrainExecutor {
    /// Build the executor for `entry`. With the `pjrt` feature this parses
    /// and compiles the HLO text on a fresh CPU client; otherwise it
    /// validates the entry against the built-in reference models.
    pub fn compile(entry: &ArtifactEntry) -> anyhow::Result<TrainExecutor> {
        #[cfg(feature = "pjrt")]
        {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let proto = xla::HloModuleProto::from_text_file(
                entry.path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", entry.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?;
            Ok(TrainExecutor {
                entry: entry.clone(),
                backend: Backend::Pjrt { _client: client, exe },
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let model = RefModel::new(entry)?;
            Ok(TrainExecutor { entry: entry.clone(), backend: Backend::Reference(model) })
        }
    }

    /// Convenience: load an HLO path directly (integration tests).
    pub fn compile_path(entry: &ArtifactEntry, path: &Path) -> anyhow::Result<TrainExecutor> {
        let mut e = entry.clone();
        e.path = path.to_path_buf();
        TrainExecutor::compile(&e)
    }

    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Shared argument validation (both backends fail identically).
    fn check_params(&self, params: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            params.len() == self.entry.params.len(),
            "expected {} params, got {}",
            self.entry.params.len(),
            params.len()
        );
        for (buf, (name, shape)) in params.iter().zip(&self.entry.params) {
            let n: usize = shape.iter().product();
            anyhow::ensure!(
                buf.len() == n,
                "param {name}: buffer len {} != shape {:?}",
                buf.len(),
                shape
            );
        }
        Ok(())
    }

    /// Execute a train step: returns loss and per-parameter gradients.
    /// Allocating wrapper over [`TrainExecutor::train_step_into`] for
    /// tests and one-shot callers.
    pub fn train_step(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<StepOutput> {
        let mut grads = GradBuffers::empty();
        let loss = self.train_step_into(params, batch, &mut grads)?;
        Ok(StepOutput { loss, grads })
    }

    /// Execute a train step, writing the gradients into a recycled
    /// [`GradBuffers`] (sized on first use; allocation-free thereafter).
    /// `&mut self`: the reference backend writes its intermediates into a
    /// per-instance scratch workspace (no per-step allocation).
    pub fn train_step_into(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
        grads: &mut GradBuffers,
    ) -> anyhow::Result<f32> {
        anyhow::ensure!(self.entry.kind == "train", "not a train artifact");
        self.check_params(params)?;
        match &mut self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { exe, .. } => {
                let args = Self::build_args(&self.entry, params, batch)?;
                let outs = Self::run_pjrt(exe, &args)?;
                anyhow::ensure!(
                    outs.len() == 1 + self.entry.params.len(),
                    "expected {} outputs, got {}",
                    1 + self.entry.params.len(),
                    outs.len()
                );
                let loss = outs[0].to_vec::<f32>()?[0];
                grads.resize_with(outs.len() - 1, |_| 0);
                for (dst, lit) in grads.iter_mut().zip(&outs[1..]) {
                    let v = lit.to_vec::<f32>()?;
                    dst.clear();
                    dst.extend_from_slice(&v);
                }
                Ok(loss)
            }
            Backend::Reference(model) => model.train_step_into(params, batch, grads),
        }
    }

    /// Execute inference: returns logits `[b, classes]` row-major.
    pub fn predict(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.entry.kind == "predict", "not a predict artifact");
        self.check_params(params)?;
        match &mut self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { exe, .. } => {
                let args = Self::build_args(&self.entry, params, batch)?;
                let outs = Self::run_pjrt(exe, &args)?;
                anyhow::ensure!(outs.len() == 1, "predict should return one output");
                Ok(outs[0].to_vec::<f32>()?)
            }
            Backend::Reference(model) => model.predict(params, batch),
        }
    }

    #[cfg(feature = "pjrt")]
    fn literal_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == data.len(), "buffer len {} != shape {:?}", data.len(), shape);
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    fn literal_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == data.len(), "buffer len {} != shape {:?}", data.len(), shape);
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// Build the full literal argument list (params, feat0, per-layer
    /// idx/w from the input side up, labels, mask). Associated fn so the
    /// caller can hold `backend` mutably while borrowing only the entry.
    #[cfg(feature = "pjrt")]
    fn build_args(
        entry: &ArtifactEntry,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let d = &entry.dims;
        let lcount = d.layers();
        let mut args = Vec::with_capacity(params.len() + 3 + 2 * lcount);
        for (buf, (name, shape)) in params.iter().zip(&entry.params) {
            args.push(Self::literal_f32(buf, shape).with_context(|| format!("param {name}"))?);
        }
        args.push(Self::literal_f32(&batch.feat0, &[d.caps[0], d.f[0]])?);
        for l in 1..=lcount {
            let rows = d.caps[l];
            let k = d.fanouts[l - 1] + 1;
            args.push(Self::literal_i32(&batch.idx[l - 1], &[rows, k])?);
            args.push(Self::literal_f32(&batch.w[l - 1], &[rows, k])?);
        }
        args.push(Self::literal_i32(&batch.labels, &[d.b])?);
        args.push(Self::literal_f32(&batch.mask, &[d.b])?);
        Ok(args)
    }

    #[cfg(feature = "pjrt")]
    fn run_pjrt(
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(args)?;
        anyhow::ensure!(
            result.len() == 1 && result[0].len() == 1,
            "unexpected replica structure"
        );
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}
