//! The reference executor's compute kernels: cache-blocked, register-tiled
//! dense math plus the padded-wire-format gather/scatter primitives — all
//! with write-into-`&mut [f32]` signatures so the executor's [`Workspace`]
//! (`super::workspace`) owns every intermediate and the steady-state hot
//! path performs no heap allocation.
//!
//! Blocking scheme (DESIGN.md §Hot-path memory & kernels): the matmul
//! family processes the k-dimension in tiles of [`KT`] values per pass
//! over a full output row, so each output element is loaded/stored once
//! per tile instead of once per k — an autovectorizer-friendly shape
//! (the inner loops are plain indexed f32 FMA chains over contiguous
//! rows). A whole-tile zero test keeps the wire format's padding-row
//! sparsity shortcut: an all-zero x tile (every padded row) skips the
//! row entirely, exactly like the scalar kernels' per-element skip.
//!
//! The original scalar kernels live in [`scalar`] — allocation-per-call,
//! one-k-at-a-time — and stay the numerics oracle: the unit tests below
//! assert the blocked matmuls match them within FP-reassociation
//! tolerance and the gather/scatter kernels match them bit-exactly
//! (identical accumulation order).
//!
//! SIMD dispatch (DESIGN.md §SIMD dispatch & gradient sync): on x86-64
//! hosts with AVX2+FMA (checked once via `is_x86_feature_detected!`),
//! the matmul family and the gather/scatter family dispatch to the
//! width-8 microkernels in [`x86`]; everywhere else — and under
//! `HITGNN_NO_SIMD` — the blocked kernels above remain the portable
//! fallback. The matmul microkernels use FMA (covered by the oracle's
//! FP tolerance); the gather/scatter microkernels vectorize over the
//! feature dimension with separate mul+add, so each lane reproduces the
//! scalar oracle's per-element rounding exactly and the bit-exactness
//! tests hold on every tier. The resolved tier is logged once and can
//! be overridden in-process via [`set_tier`] (bench A/B only — the tier
//! must stay constant while train steps run, or the PR-1 bitwise
//! determinism law breaks).
//!
//! [`Workspace`]: super::workspace::Workspace

use std::sync::atomic::{AtomicU8, Ordering};

/// k-dimension register-tile width of the blocked matmuls.
pub const KT: usize = 4;

/// Which kernel implementation the public entry points dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Width-8 `std::arch` AVX2+FMA microkernels ([`x86`]).
    Avx2Fma,
    /// The portable cache-blocked kernels (every platform).
    Blocked,
}

impl Tier {
    /// Stable name for logs and bench JSON columns.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Avx2Fma => "avx2+fma",
            Tier::Blocked => "blocked",
        }
    }
}

/// 0 = unresolved, 1 = Avx2Fma, 2 = Blocked.
static TIER: AtomicU8 = AtomicU8::new(0);

#[cold]
fn resolve_tier() -> u8 {
    let tier = if simd_supported() && !no_simd_env() { Tier::Avx2Fma } else { Tier::Blocked };
    let code = match tier {
        Tier::Avx2Fma => 1,
        Tier::Blocked => 2,
    };
    // First resolution wins the race; the log line fires at most once
    // per process (per-thread duplicates are possible only on a tie).
    if TIER.compare_exchange(0, code, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
        crate::log_info!("kernel dispatch tier: {}", tier.name());
        code
    } else {
        TIER.load(Ordering::Relaxed)
    }
}

/// Whether this host can run the [`Tier::Avx2Fma`] microkernels.
pub fn simd_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn no_simd_env() -> bool {
    std::env::var_os("HITGNN_NO_SIMD").is_some_and(|v| !v.is_empty() && v != "0")
}

#[inline]
fn tier_code() -> u8 {
    let v = TIER.load(Ordering::Relaxed);
    if v != 0 {
        v
    } else {
        resolve_tier()
    }
}

/// The tier the public kernels currently dispatch to.
pub fn active_tier() -> Tier {
    if tier_code() == 1 {
        Tier::Avx2Fma
    } else {
        Tier::Blocked
    }
}

/// Force the dispatch tier (bench/test A/B only). Returns `false` —
/// leaving the tier unchanged — if [`Tier::Avx2Fma`] is requested on a
/// host without AVX2+FMA. Process-global: never flip it while train
/// steps are in flight, or within-process bitwise determinism breaks.
pub fn set_tier(tier: Tier) -> bool {
    if tier == Tier::Avx2Fma && !simd_supported() {
        return false;
    }
    let code = match tier {
        Tier::Avx2Fma => 1,
        Tier::Blocked => 2,
    };
    TIER.store(code, Ordering::Relaxed);
    true
}

#[inline]
fn use_simd() -> bool {
    cfg!(target_arch = "x86_64") && tier_code() == 1
}

/// `orow += xrow · w` for one output row — the shared inner kernel of
/// [`matmul_bias`] / [`add_matmul`]: k-tiles of [`KT`] with a whole-tile
/// zero shortcut.
#[inline]
fn axpy_row(orow: &mut [f32], xrow: &[f32], w: &[f32], fin: usize, fout: usize) {
    let mut kk = 0;
    while kk + KT <= fin {
        let (x0, x1, x2, x3) = (xrow[kk], xrow[kk + 1], xrow[kk + 2], xrow[kk + 3]);
        if x0 != 0.0 || x1 != 0.0 || x2 != 0.0 || x3 != 0.0 {
            let w0 = &w[kk * fout..(kk + 1) * fout];
            let w1 = &w[(kk + 1) * fout..(kk + 2) * fout];
            let w2 = &w[(kk + 2) * fout..(kk + 3) * fout];
            let w3 = &w[(kk + 3) * fout..(kk + 4) * fout];
            for j in 0..fout {
                orow[j] += x0 * w0[j] + x1 * w1[j] + x2 * w2[j] + x3 * w3[j];
            }
        }
        kk += KT;
    }
    while kk < fin {
        let xv = xrow[kk];
        if xv != 0.0 {
            let wrow = &w[kk * fout..(kk + 1) * fout];
            for j in 0..fout {
                orow[j] += xv * wrow[j];
            }
        }
        kk += 1;
    }
}

/// `out[n, fout] = x[n, fin] · w[fin, fout] + bias`, row-major.
pub fn matmul_bias(
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    fin: usize,
    fout: usize,
) {
    debug_assert!(out.len() >= n * fout && x.len() >= n * fin);
    debug_assert!(w.len() == fin * fout && bias.len() == fout);
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::matmul_bias(out, x, w, bias, n, fin, fout) };
        return;
    }
    matmul_bias_blocked(out, x, w, bias, n, fin, fout)
}

fn matmul_bias_blocked(
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    fin: usize,
    fout: usize,
) {
    for r in 0..n {
        let orow = &mut out[r * fout..(r + 1) * fout];
        orow.copy_from_slice(bias);
        axpy_row(orow, &x[r * fin..(r + 1) * fin], w, fin, fout);
    }
}

/// `out[n, fout] += x[n, fin] · w[fin, fout]` (second matmul path of a
/// SAGE layer).
pub fn add_matmul(out: &mut [f32], x: &[f32], w: &[f32], n: usize, fin: usize, fout: usize) {
    debug_assert!(out.len() >= n * fout && x.len() >= n * fin && w.len() == fin * fout);
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::add_matmul(out, x, w, n, fin, fout) };
        return;
    }
    add_matmul_blocked(out, x, w, n, fin, fout)
}

fn add_matmul_blocked(out: &mut [f32], x: &[f32], w: &[f32], n: usize, fin: usize, fout: usize) {
    for r in 0..n {
        axpy_row(&mut out[r * fout..(r + 1) * fout], &x[r * fin..(r + 1) * fin], w, fin, fout);
    }
}

/// `out[fa, fb] = aᵀ·b` for `a[n, fa]`, `b[n, fb]` (weight gradients).
/// Overwrites `out`; the n-dimension is tiled by [`KT`] rows so each
/// output row is touched once per row tile.
pub fn matmul_at_b(out: &mut [f32], a: &[f32], b: &[f32], n: usize, fa: usize, fb: usize) {
    debug_assert!(out.len() == fa * fb && a.len() >= n * fa && b.len() >= n * fb);
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::matmul_at_b(out, a, b, n, fa, fb) };
        return;
    }
    matmul_at_b_blocked(out, a, b, n, fa, fb)
}

fn matmul_at_b_blocked(out: &mut [f32], a: &[f32], b: &[f32], n: usize, fa: usize, fb: usize) {
    out.fill(0.0);
    let mut r = 0;
    while r + KT <= n {
        for kk in 0..fa {
            let a0 = a[r * fa + kk];
            let a1 = a[(r + 1) * fa + kk];
            let a2 = a[(r + 2) * fa + kk];
            let a3 = a[(r + 3) * fa + kk];
            if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                let b0 = &b[r * fb..(r + 1) * fb];
                let b1 = &b[(r + 1) * fb..(r + 2) * fb];
                let b2 = &b[(r + 2) * fb..(r + 3) * fb];
                let b3 = &b[(r + 3) * fb..(r + 4) * fb];
                let orow = &mut out[kk * fb..(kk + 1) * fb];
                for j in 0..fb {
                    orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
            }
        }
        r += KT;
    }
    while r < n {
        for kk in 0..fa {
            let av = a[r * fa + kk];
            if av != 0.0 {
                let brow = &b[r * fb..(r + 1) * fb];
                let orow = &mut out[kk * fb..(kk + 1) * fb];
                for j in 0..fb {
                    orow[j] += av * brow[j];
                }
            }
        }
        r += 1;
    }
}

/// `out[n, fb] = a[n, fa] · wᵀ` for `w[fb, fa]` (input gradients).
/// [`KT`] dot products share each load of the `a` row.
pub fn matmul_b_t(out: &mut [f32], a: &[f32], w: &[f32], n: usize, fa: usize, fb: usize) {
    debug_assert!(out.len() >= n * fb && a.len() >= n * fa && w.len() == fb * fa);
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::matmul_b_t(out, a, w, n, fa, fb) };
        return;
    }
    matmul_b_t_blocked(out, a, w, n, fa, fb)
}

fn matmul_b_t_blocked(out: &mut [f32], a: &[f32], w: &[f32], n: usize, fa: usize, fb: usize) {
    for r in 0..n {
        let arow = &a[r * fa..(r + 1) * fa];
        let orow = &mut out[r * fb..(r + 1) * fb];
        let mut kb = 0;
        while kb + KT <= fb {
            let w0 = &w[kb * fa..(kb + 1) * fa];
            let w1 = &w[(kb + 1) * fa..(kb + 2) * fa];
            let w2 = &w[(kb + 2) * fa..(kb + 3) * fa];
            let w3 = &w[(kb + 3) * fa..(kb + 4) * fa];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for j in 0..fa {
                let av = arow[j];
                s0 += av * w0[j];
                s1 += av * w1[j];
                s2 += av * w2[j];
                s3 += av * w3[j];
            }
            orow[kb] = s0;
            orow[kb + 1] = s1;
            orow[kb + 2] = s2;
            orow[kb + 3] = s3;
            kb += KT;
        }
        while kb < fb {
            let wrow = &w[kb * fa..(kb + 1) * fa];
            let mut acc = 0.0f32;
            for j in 0..fa {
                acc += arow[j] * wrow[j];
            }
            orow[kb] = acc;
            kb += 1;
        }
    }
}

/// `out[j] = Σ_r x[r, j]` over the first `n` rows (bias gradients).
pub fn col_sums(out: &mut [f32], x: &[f32], n: usize, f: usize) {
    debug_assert!(out.len() == f && x.len() >= n * f);
    out.fill(0.0);
    for r in 0..n {
        let xrow = &x[r * f..(r + 1) * f];
        for j in 0..f {
            out[j] += xrow[j];
        }
    }
}

/// `out[..len] = max(z[..len], 0)`.
pub fn relu(out: &mut [f32], z: &[f32], len: usize) {
    for (o, &v) in out[..len].iter_mut().zip(&z[..len]) {
        *o = v.max(0.0);
    }
}

/// In-place relu backward: zero `dz` where the pre-activation was not
/// positive (zero at exactly 0, matching jax.nn.relu's convention).
pub fn relu_mask(dz: &mut [f32], z: &[f32], len: usize) {
    for (d, &v) in dz[..len].iter_mut().zip(&z[..len]) {
        if v <= 0.0 {
            *d = 0.0;
        }
    }
}

/// `out[r] = Σ_c w[r,c]·h[idx[r,c]]` over feature width `f`; with
/// `skip_self` the self column (c = 0) is excluded (SAGE neighbor mean).
/// Zeroes the first `rows·f` of `out` first; accumulation order is
/// identical to [`scalar::aggregate`] (bit-exact).
#[allow(clippy::too_many_arguments)]
pub fn aggregate(
    out: &mut [f32],
    h: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
    skip_self: bool,
) {
    debug_assert!(out.len() >= rows * f);
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::aggregate(out, h, idx, w, rows, k, f, skip_self) };
        return;
    }
    aggregate_blocked(out, h, idx, w, rows, k, f, skip_self)
}

#[allow(clippy::too_many_arguments)]
fn aggregate_blocked(
    out: &mut [f32],
    h: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
    skip_self: bool,
) {
    out[..rows * f].fill(0.0);
    let c0 = usize::from(skip_self);
    for r in 0..rows {
        let dst = &mut out[r * f..(r + 1) * f];
        for c in c0..k {
            let weight = w[r * k + c];
            if weight == 0.0 {
                continue;
            }
            let src = idx[r * k + c] as usize;
            let src_row = &h[src * f..(src + 1) * f];
            for j in 0..f {
                dst[j] += weight * src_row[j];
            }
        }
    }
}

/// Fused SAGE input gather: one walk of layer-l's idx/w rows fills both
/// the neighbor mean (self column skipped) and the gathered self rows —
/// the two inputs [`scalar::aggregate`] + [`scalar::take_rows`] built in
/// two passes.
#[allow(clippy::too_many_arguments)]
pub fn aggregate_with_self(
    agg: &mut [f32],
    selfr: &mut [f32],
    h: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
) {
    debug_assert!(agg.len() >= rows * f && selfr.len() >= rows * f);
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::aggregate_with_self(agg, selfr, h, idx, w, rows, k, f) };
        return;
    }
    aggregate_with_self_blocked(agg, selfr, h, idx, w, rows, k, f)
}

#[allow(clippy::too_many_arguments)]
fn aggregate_with_self_blocked(
    agg: &mut [f32],
    selfr: &mut [f32],
    h: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
) {
    agg[..rows * f].fill(0.0);
    for r in 0..rows {
        let src = idx[r * k] as usize;
        selfr[r * f..(r + 1) * f].copy_from_slice(&h[src * f..(src + 1) * f]);
        let dst = &mut agg[r * f..(r + 1) * f];
        for c in 1..k {
            let weight = w[r * k + c];
            if weight == 0.0 {
                continue;
            }
            let s = idx[r * k + c] as usize;
            let src_row = &h[s * f..(s + 1) * f];
            for j in 0..f {
                dst[j] += weight * src_row[j];
            }
        }
    }
}

/// Transpose of [`aggregate`]: `dh[idx[r,c]] += w[r,c]·dout[r]`. The
/// caller zeroes the live region of `dh`; accumulation order matches
/// [`scalar::scatter_aggregate`] (bit-exact).
#[allow(clippy::too_many_arguments)]
pub fn scatter_aggregate(
    dh: &mut [f32],
    dout: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
    skip_self: bool,
) {
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::scatter_aggregate(dh, dout, idx, w, rows, k, f, skip_self) };
        return;
    }
    scatter_aggregate_blocked(dh, dout, idx, w, rows, k, f, skip_self)
}

#[allow(clippy::too_many_arguments)]
fn scatter_aggregate_blocked(
    dh: &mut [f32],
    dout: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
    skip_self: bool,
) {
    let c0 = usize::from(skip_self);
    for r in 0..rows {
        for c in c0..k {
            let weight = w[r * k + c];
            if weight == 0.0 {
                continue;
            }
            let src = idx[r * k + c] as usize;
            for j in 0..f {
                dh[src * f + j] += weight * dout[r * f + j];
            }
        }
    }
}

/// Gather the self rows `h[idx[r,0]]` (SAGE's W_self input) into `out`.
pub fn take_rows(out: &mut [f32], h: &[f32], idx: &[i32], rows: usize, k: usize, f: usize) {
    debug_assert!(out.len() >= rows * f);
    for r in 0..rows {
        let src = idx[r * k] as usize;
        out[r * f..(r + 1) * f].copy_from_slice(&h[src * f..(src + 1) * f]);
    }
}

/// Transpose of [`take_rows`]: `dh[idx[r,0]] += dout[r]`.
pub fn scatter_self(dh: &mut [f32], dout: &[f32], idx: &[i32], rows: usize, k: usize, f: usize) {
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::scatter_self(dh, dout, idx, rows, k, f) };
        return;
    }
    for r in 0..rows {
        let src = idx[r * k] as usize;
        for j in 0..f {
            dh[src * f + j] += dout[r * f + j];
        }
    }
}

/// Shared masked-softmax pass of [`attn_edge_softmax`]: identical scalar
/// code on every tier (and in the [`scalar`] oracle), so the kernel
/// family stays bit-exact as long as the logit phase is. Entries whose
/// wire weight is zero (padding) come out exactly 0, and all-padding
/// rows are zeroed without computing an exp.
#[inline]
fn softmax_masked_row(arow: &mut [f32], wrow: &[f32]) {
    let mut m = f32::NEG_INFINITY;
    for (a, &wv) in arow.iter().zip(wrow) {
        if wv != 0.0 && *a > m {
            m = *a;
        }
    }
    if m == f32::NEG_INFINITY {
        arow.fill(0.0);
        return;
    }
    let mut s = 0.0f32;
    for (a, &wv) in arow.iter_mut().zip(wrow) {
        if wv != 0.0 {
            let e = (*a - m).exp();
            *a = e;
            s += e;
        } else {
            *a = 0.0;
        }
    }
    for a in arow.iter_mut() {
        *a /= s;
    }
}

/// GAT edge-parallel attention weights (DESIGN.md §Model zoo): for each
/// of the `rows` ragged neighbor lists in the padded `idx`/`w` wire
/// format, compute the logit
/// `e[r,c] = leakyrelu(sself[idx[r,0]] + snbr[idx[r,c]], slope)` and
/// write the max-subtracted masked softmax over the row's real columns
/// (`w[r,c] != 0`) into `alpha[r,c]`. Padding columns come out exactly
/// 0 and all-padding rows produce all-zero alpha rows, so downstream
/// gather/scatter kernels skip them like any other zero weight.
///
/// Bit-exact across tiers: the AVX2 twin vectorizes only the
/// gather+add+LeakyReLU logit phase with lane-wise IEEE-identical
/// operations (no FMA), and every tier runs the same scalar softmax
/// pass ([`softmax_masked_row`]).
#[allow(clippy::too_many_arguments)]
pub fn attn_edge_softmax(
    alpha: &mut [f32],
    sself: &[f32],
    snbr: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    slope: f32,
) {
    debug_assert!(alpha.len() >= rows * k && idx.len() >= rows * k && w.len() >= rows * k);
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::attn_edge_softmax(alpha, sself, snbr, idx, w, rows, k, slope) };
        return;
    }
    attn_edge_softmax_blocked(alpha, sself, snbr, idx, w, rows, k, slope)
}

#[allow(clippy::too_many_arguments)]
fn attn_edge_softmax_blocked(
    alpha: &mut [f32],
    sself: &[f32],
    snbr: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    slope: f32,
) {
    for r in 0..rows {
        let s0 = sself[idx[r * k] as usize];
        let arow = &mut alpha[r * k..(r + 1) * k];
        for (a, &i) in arow.iter_mut().zip(&idx[r * k..(r + 1) * k]) {
            let x = s0 + snbr[i as usize];
            *a = if x > 0.0 { x } else { slope * x };
        }
        softmax_masked_row(arow, &w[r * k..(r + 1) * k]);
    }
}

/// Per-edge gradient dot products of the GAT backward:
/// `dalpha[r,c] = ⟨dz[r,·], ht[idx[r,c],·]⟩` for every real column
/// (`mask[r,c] != 0`, the forward alpha — zero exactly on padding);
/// padding columns are written as exactly 0. Matmul-family numerics:
/// the AVX2 tier uses FMA dot products (FP tolerance vs the scalar
/// oracle), the blocked tier matches the oracle exactly.
#[allow(clippy::too_many_arguments)]
pub fn attn_edge_dot(
    dalpha: &mut [f32],
    dz: &[f32],
    ht: &[f32],
    idx: &[i32],
    mask: &[f32],
    rows: usize,
    k: usize,
    f: usize,
) {
    debug_assert!(dalpha.len() >= rows * k && dz.len() >= rows * f);
    #[cfg(target_arch = "x86_64")]
    if use_simd() {
        // SAFETY: use_simd() implies AVX2+FMA were detected at runtime.
        unsafe { x86::attn_edge_dot(dalpha, dz, ht, idx, mask, rows, k, f) };
        return;
    }
    attn_edge_dot_blocked(dalpha, dz, ht, idx, mask, rows, k, f)
}

#[allow(clippy::too_many_arguments)]
fn attn_edge_dot_blocked(
    dalpha: &mut [f32],
    dz: &[f32],
    ht: &[f32],
    idx: &[i32],
    mask: &[f32],
    rows: usize,
    k: usize,
    f: usize,
) {
    for r in 0..rows {
        let drow = &dz[r * f..(r + 1) * f];
        for c in 0..k {
            let o = &mut dalpha[r * k + c];
            if mask[r * k + c] == 0.0 {
                *o = 0.0;
                continue;
            }
            let src = idx[r * k + c] as usize;
            let hrow = &ht[src * f..(src + 1) * f];
            let mut acc = 0.0f32;
            for (&dv, &hv) in drow.iter().zip(hrow) {
                acc += dv * hv;
            }
            *o = acc;
        }
    }
}

/// In-place softmax + LeakyReLU backward over the attention lane:
/// entering, `dalpha` holds ∂loss/∂alpha; leaving, it holds the
/// raw-logit gradient
/// `de[r,c] = lrelu'(x)·alpha[r,c]·(dalpha[r,c] − Σ_c' alpha[r,c']·dalpha[r,c'])`
/// with the LeakyReLU mask recomputed from the forward per-vertex
/// scores (`x = sself[idx[r,0]] + snbr[idx[r,c]]`). Scalar on every
/// tier — the fixed accumulation order keeps the backward
/// bit-deterministic. Padding columns (alpha exactly 0) contribute
/// exact zeros.
#[allow(clippy::too_many_arguments)]
pub fn attn_softmax_backward(
    dalpha: &mut [f32],
    alpha: &[f32],
    sself: &[f32],
    snbr: &[f32],
    idx: &[i32],
    rows: usize,
    k: usize,
    slope: f32,
) {
    debug_assert!(dalpha.len() >= rows * k && alpha.len() >= rows * k);
    for r in 0..rows {
        let arow = &alpha[r * k..(r + 1) * k];
        let drow = &mut dalpha[r * k..(r + 1) * k];
        let mut s = 0.0f32;
        for (&av, &dv) in arow.iter().zip(drow.iter()) {
            s += av * dv;
        }
        let s0 = sself[idx[r * k] as usize];
        for (c, (d, &av)) in drow.iter_mut().zip(arow).enumerate() {
            if av == 0.0 {
                // padding (or fully-saturated-away) edge: exactly zero,
                // without reading the stale score behind a padding index
                *d = 0.0;
                continue;
            }
            let de = av * (*d - s);
            let x = s0 + snbr[idx[r * k + c] as usize];
            *d = if x > 0.0 { de } else { slope * de };
        }
    }
}

/// Scatter the raw-logit gradients back onto the per-vertex score
/// gradients: `dsself[idx[r,0]] += Σ_c draw[r,c]` and
/// `dsnbr[idx[r,c]] += draw[r,c]`. The caller zeroes the live regions
/// first.
pub fn attn_scatter_scores(
    dsself: &mut [f32],
    dsnbr: &mut [f32],
    draw: &[f32],
    idx: &[i32],
    rows: usize,
    k: usize,
) {
    for r in 0..rows {
        let mut row_sum = 0.0f32;
        for c in 0..k {
            let d = draw[r * k + c];
            row_sum += d;
            dsnbr[idx[r * k + c] as usize] += d;
        }
        dsself[idx[r * k] as usize] += row_sum;
    }
}

/// `out[r, ·] += bias` over the first `n` rows (the attention
/// aggregate's bias, applied after the alpha-weighted gather).
pub fn add_bias(out: &mut [f32], bias: &[f32], n: usize, f: usize) {
    debug_assert!(out.len() >= n * f && bias.len() == f);
    for r in 0..n {
        for (o, &bv) in out[r * f..(r + 1) * f].iter_mut().zip(bias) {
            *o += bv;
        }
    }
}

/// `out[..len] += scale · x[..len]` (GIN's (1+ε)-weighted self rows).
pub fn scaled_add(out: &mut [f32], x: &[f32], scale: f32, len: usize) {
    for (o, &xv) in out[..len].iter_mut().zip(&x[..len]) {
        *o += scale * xv;
    }
}

/// `Σ_i a[i]·b[i]` over the first `len` elements with fixed
/// left-to-right accumulation (GIN's ∂loss/∂ε).
pub fn dot(a: &[f32], b: &[f32], len: usize) -> f32 {
    let mut acc = 0.0f32;
    for (&av, &bv) in a[..len].iter().zip(&b[..len]) {
        acc += av * bv;
    }
    acc
}

/// [`scatter_self`] with a scalar weight:
/// `dh[idx[r,0]] += scale · dout[r, ·]` (GIN's (1+ε)-scaled self-path
/// input gradient).
pub fn scatter_self_scaled(
    dh: &mut [f32],
    dout: &[f32],
    idx: &[i32],
    scale: f32,
    rows: usize,
    k: usize,
    f: usize,
) {
    for r in 0..rows {
        let src = idx[r * k] as usize;
        for j in 0..f {
            dh[src * f + j] += scale * dout[r * f + j];
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    //! Width-8 AVX2+FMA microkernels ([`super::Tier::Avx2Fma`]).
    //!
    //! Every function carries `#[target_feature(enable = "avx2,fma")]`
    //! and is therefore `unsafe`: the caller (the dispatchers in the
    //! parent module, or the tests) must have confirmed AVX2+FMA via
    //! `is_x86_feature_detected!`. The matmul family accumulates with
    //! `_mm256_fmadd_ps` (one rounding per multiply-add — covered by
    //! the scalar oracle's FP tolerance); the gather/scatter family
    //! deliberately uses separate `_mm256_mul_ps` + `_mm256_add_ps` so
    //! each lane rounds exactly like the scalar oracle and stays
    //! bit-exact with it. Feature-dimension tails (`f % 8`) fall back
    //! to the same per-element expression the vector body computes.

    // Safety contract is module-wide (header above): callers must have
    // verified AVX2+FMA at runtime before entering any fn in here.
    #![allow(clippy::missing_safety_doc)]

    use super::KT;
    use std::arch::x86_64::*;

    /// Horizontal sum of all 8 lanes.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// `orow += xrow · w`: the shared AVX2 inner kernel of
    /// [`matmul_bias`] / [`add_matmul`] — k-tiles of [`KT`] broadcasts,
    /// eight output columns per FMA chain.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn axpy_row(orow: &mut [f32], xrow: &[f32], w: &[f32], fin: usize, fout: usize) {
        let f8 = fout & !7;
        let op = orow.as_mut_ptr();
        let mut kk = 0;
        while kk + KT <= fin {
            let (x0, x1, x2, x3) = (xrow[kk], xrow[kk + 1], xrow[kk + 2], xrow[kk + 3]);
            if x0 != 0.0 || x1 != 0.0 || x2 != 0.0 || x3 != 0.0 {
                let (v0, v1, v2, v3) = (
                    _mm256_set1_ps(x0),
                    _mm256_set1_ps(x1),
                    _mm256_set1_ps(x2),
                    _mm256_set1_ps(x3),
                );
                let w0 = w.as_ptr().add(kk * fout);
                let w1 = w.as_ptr().add((kk + 1) * fout);
                let w2 = w.as_ptr().add((kk + 2) * fout);
                let w3 = w.as_ptr().add((kk + 3) * fout);
                let mut j = 0;
                while j < f8 {
                    let mut acc = _mm256_loadu_ps(op.add(j));
                    acc = _mm256_fmadd_ps(v0, _mm256_loadu_ps(w0.add(j)), acc);
                    acc = _mm256_fmadd_ps(v1, _mm256_loadu_ps(w1.add(j)), acc);
                    acc = _mm256_fmadd_ps(v2, _mm256_loadu_ps(w2.add(j)), acc);
                    acc = _mm256_fmadd_ps(v3, _mm256_loadu_ps(w3.add(j)), acc);
                    _mm256_storeu_ps(op.add(j), acc);
                    j += 8;
                }
                for j in f8..fout {
                    orow[j] += x0 * *w0.add(j) + x1 * *w1.add(j) + x2 * *w2.add(j) + x3 * *w3.add(j);
                }
            }
            kk += KT;
        }
        while kk < fin {
            let xv = xrow[kk];
            if xv != 0.0 {
                let v = _mm256_set1_ps(xv);
                let wr = w.as_ptr().add(kk * fout);
                let mut j = 0;
                while j < f8 {
                    let acc =
                        _mm256_fmadd_ps(v, _mm256_loadu_ps(wr.add(j)), _mm256_loadu_ps(op.add(j)));
                    _mm256_storeu_ps(op.add(j), acc);
                    j += 8;
                }
                for j in f8..fout {
                    orow[j] += xv * *wr.add(j);
                }
            }
            kk += 1;
        }
    }

    /// See [`super::matmul_bias`].
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_bias(
        out: &mut [f32],
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        n: usize,
        fin: usize,
        fout: usize,
    ) {
        for r in 0..n {
            let orow = &mut out[r * fout..(r + 1) * fout];
            orow.copy_from_slice(bias);
            axpy_row(orow, &x[r * fin..(r + 1) * fin], w, fin, fout);
        }
    }

    /// See [`super::add_matmul`].
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn add_matmul(
        out: &mut [f32],
        x: &[f32],
        w: &[f32],
        n: usize,
        fin: usize,
        fout: usize,
    ) {
        for r in 0..n {
            axpy_row(&mut out[r * fout..(r + 1) * fout], &x[r * fin..(r + 1) * fin], w, fin, fout);
        }
    }

    /// See [`super::matmul_at_b`].
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_at_b(out: &mut [f32], a: &[f32], b: &[f32], n: usize, fa: usize, fb: usize) {
        out.fill(0.0);
        let f8 = fb & !7;
        let mut r = 0;
        while r + KT <= n {
            for kk in 0..fa {
                let a0 = a[r * fa + kk];
                let a1 = a[(r + 1) * fa + kk];
                let a2 = a[(r + 2) * fa + kk];
                let a3 = a[(r + 3) * fa + kk];
                if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                    let (v0, v1, v2, v3) = (
                        _mm256_set1_ps(a0),
                        _mm256_set1_ps(a1),
                        _mm256_set1_ps(a2),
                        _mm256_set1_ps(a3),
                    );
                    let b0 = b.as_ptr().add(r * fb);
                    let b1 = b.as_ptr().add((r + 1) * fb);
                    let b2 = b.as_ptr().add((r + 2) * fb);
                    let b3 = b.as_ptr().add((r + 3) * fb);
                    let op = out.as_mut_ptr().add(kk * fb);
                    let mut j = 0;
                    while j < f8 {
                        let mut acc = _mm256_loadu_ps(op.add(j));
                        acc = _mm256_fmadd_ps(v0, _mm256_loadu_ps(b0.add(j)), acc);
                        acc = _mm256_fmadd_ps(v1, _mm256_loadu_ps(b1.add(j)), acc);
                        acc = _mm256_fmadd_ps(v2, _mm256_loadu_ps(b2.add(j)), acc);
                        acc = _mm256_fmadd_ps(v3, _mm256_loadu_ps(b3.add(j)), acc);
                        _mm256_storeu_ps(op.add(j), acc);
                        j += 8;
                    }
                    for j in f8..fb {
                        *op.add(j) +=
                            a0 * *b0.add(j) + a1 * *b1.add(j) + a2 * *b2.add(j) + a3 * *b3.add(j);
                    }
                }
            }
            r += KT;
        }
        while r < n {
            for kk in 0..fa {
                let av = a[r * fa + kk];
                if av != 0.0 {
                    let v = _mm256_set1_ps(av);
                    let br = b.as_ptr().add(r * fb);
                    let op = out.as_mut_ptr().add(kk * fb);
                    let mut j = 0;
                    while j < f8 {
                        let acc = _mm256_fmadd_ps(
                            v,
                            _mm256_loadu_ps(br.add(j)),
                            _mm256_loadu_ps(op.add(j)),
                        );
                        _mm256_storeu_ps(op.add(j), acc);
                        j += 8;
                    }
                    for j in f8..fb {
                        *op.add(j) += av * *br.add(j);
                    }
                }
            }
            r += 1;
        }
    }

    /// See [`super::matmul_b_t`].
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_b_t(out: &mut [f32], a: &[f32], w: &[f32], n: usize, fa: usize, fb: usize) {
        let f8 = fa & !7;
        for r in 0..n {
            let ap = a.as_ptr().add(r * fa);
            let orow = &mut out[r * fb..(r + 1) * fb];
            let mut kb = 0;
            while kb + KT <= fb {
                let w0 = w.as_ptr().add(kb * fa);
                let w1 = w.as_ptr().add((kb + 1) * fa);
                let w2 = w.as_ptr().add((kb + 2) * fa);
                let w3 = w.as_ptr().add((kb + 3) * fa);
                let mut s0 = _mm256_setzero_ps();
                let mut s1 = _mm256_setzero_ps();
                let mut s2 = _mm256_setzero_ps();
                let mut s3 = _mm256_setzero_ps();
                let mut j = 0;
                while j < f8 {
                    let av = _mm256_loadu_ps(ap.add(j));
                    s0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(w0.add(j)), s0);
                    s1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(w1.add(j)), s1);
                    s2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(w2.add(j)), s2);
                    s3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(w3.add(j)), s3);
                    j += 8;
                }
                let (mut r0, mut r1, mut r2, mut r3) = (hsum(s0), hsum(s1), hsum(s2), hsum(s3));
                for j in f8..fa {
                    let av = *ap.add(j);
                    r0 += av * *w0.add(j);
                    r1 += av * *w1.add(j);
                    r2 += av * *w2.add(j);
                    r3 += av * *w3.add(j);
                }
                orow[kb] = r0;
                orow[kb + 1] = r1;
                orow[kb + 2] = r2;
                orow[kb + 3] = r3;
                kb += KT;
            }
            while kb < fb {
                let wr = w.as_ptr().add(kb * fa);
                let mut s = _mm256_setzero_ps();
                let mut j = 0;
                while j < f8 {
                    s = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(wr.add(j)), s);
                    j += 8;
                }
                let mut acc = hsum(s);
                for j in f8..fa {
                    acc += *ap.add(j) * *wr.add(j);
                }
                orow[kb] = acc;
                kb += 1;
            }
        }
    }

    /// `dst[..f] += weight · src[..f]`, separate mul+add per lane so the
    /// per-element rounding matches the scalar oracle bit-exactly.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn weighted_add_row(dst: *mut f32, src: *const f32, weight: f32, f: usize) {
        let f8 = f & !7;
        let wv = _mm256_set1_ps(weight);
        let mut j = 0;
        while j < f8 {
            let acc = _mm256_add_ps(
                _mm256_loadu_ps(dst.add(j)),
                _mm256_mul_ps(wv, _mm256_loadu_ps(src.add(j))),
            );
            _mm256_storeu_ps(dst.add(j), acc);
            j += 8;
        }
        for j in f8..f {
            *dst.add(j) += weight * *src.add(j);
        }
    }

    /// See [`super::aggregate`] (bit-exact with the scalar oracle).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn aggregate(
        out: &mut [f32],
        h: &[f32],
        idx: &[i32],
        w: &[f32],
        rows: usize,
        k: usize,
        f: usize,
        skip_self: bool,
    ) {
        out[..rows * f].fill(0.0);
        let c0 = usize::from(skip_self);
        for r in 0..rows {
            let dst = out.as_mut_ptr().add(r * f);
            for c in c0..k {
                let weight = w[r * k + c];
                if weight == 0.0 {
                    continue;
                }
                let src = idx[r * k + c] as usize;
                weighted_add_row(dst, h.as_ptr().add(src * f), weight, f);
            }
        }
    }

    /// See [`super::aggregate_with_self`] (bit-exact with the two-pass
    /// scalar oracle).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn aggregate_with_self(
        agg: &mut [f32],
        selfr: &mut [f32],
        h: &[f32],
        idx: &[i32],
        w: &[f32],
        rows: usize,
        k: usize,
        f: usize,
    ) {
        agg[..rows * f].fill(0.0);
        for r in 0..rows {
            let src = idx[r * k] as usize;
            selfr[r * f..(r + 1) * f].copy_from_slice(&h[src * f..(src + 1) * f]);
            let dst = agg.as_mut_ptr().add(r * f);
            for c in 1..k {
                let weight = w[r * k + c];
                if weight == 0.0 {
                    continue;
                }
                let s = idx[r * k + c] as usize;
                weighted_add_row(dst, h.as_ptr().add(s * f), weight, f);
            }
        }
    }

    /// See [`super::scatter_aggregate`] (bit-exact with the scalar
    /// oracle).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scatter_aggregate(
        dh: &mut [f32],
        dout: &[f32],
        idx: &[i32],
        w: &[f32],
        rows: usize,
        k: usize,
        f: usize,
        skip_self: bool,
    ) {
        let c0 = usize::from(skip_self);
        for r in 0..rows {
            let dr = dout.as_ptr().add(r * f);
            for c in c0..k {
                let weight = w[r * k + c];
                if weight == 0.0 {
                    continue;
                }
                let src = idx[r * k + c] as usize;
                weighted_add_row(dh.as_mut_ptr().add(src * f), dr, weight, f);
            }
        }
    }

    /// See [`super::scatter_self`] (bit-exact: pure lane-wise adds).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scatter_self(
        dh: &mut [f32],
        dout: &[f32],
        idx: &[i32],
        rows: usize,
        k: usize,
        f: usize,
    ) {
        let f8 = f & !7;
        for r in 0..rows {
            let src = idx[r * k] as usize;
            let dst = dh.as_mut_ptr().add(src * f);
            let dr = dout.as_ptr().add(r * f);
            let mut j = 0;
            while j < f8 {
                let acc = _mm256_add_ps(_mm256_loadu_ps(dst.add(j)), _mm256_loadu_ps(dr.add(j)));
                _mm256_storeu_ps(dst.add(j), acc);
                j += 8;
            }
            for j in f8..f {
                *dst.add(j) += *dr.add(j);
            }
        }
    }

    /// See [`super::attn_edge_softmax`] (bit-exact with the scalar
    /// oracle): the logit phase vectorizes the neighbor-score gather,
    /// the broadcast add, and a compare+blend LeakyReLU — all lane-wise
    /// IEEE-identical to the scalar expression (no FMA) — then the
    /// masked-softmax pass is the shared scalar code. Requires every
    /// `idx` entry to be in bounds for `snbr` (wire-format invariant:
    /// padding indices stay within the level's capacity).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn attn_edge_softmax(
        alpha: &mut [f32],
        sself: &[f32],
        snbr: &[f32],
        idx: &[i32],
        w: &[f32],
        rows: usize,
        k: usize,
        slope: f32,
    ) {
        let k8 = k & !7;
        let zero = _mm256_setzero_ps();
        let sv = _mm256_set1_ps(slope);
        for r in 0..rows {
            let s0 = sself[idx[r * k] as usize];
            let s0v = _mm256_set1_ps(s0);
            let ip = idx.as_ptr().add(r * k);
            let arow = &mut alpha[r * k..(r + 1) * k];
            let ap = arow.as_mut_ptr();
            let mut c = 0;
            while c < k8 {
                let vi = _mm256_loadu_si256(ip.add(c) as *const __m256i);
                let x = _mm256_add_ps(s0v, _mm256_i32gather_ps::<4>(snbr.as_ptr(), vi));
                let e = _mm256_blendv_ps(
                    _mm256_mul_ps(sv, x),
                    x,
                    _mm256_cmp_ps::<_CMP_GT_OQ>(x, zero),
                );
                _mm256_storeu_ps(ap.add(c), e);
                c += 8;
            }
            for c in k8..k {
                let x = s0 + snbr[*ip.add(c) as usize];
                arow[c] = if x > 0.0 { x } else { slope * x };
            }
            super::softmax_masked_row(arow, &w[r * k..(r + 1) * k]);
        }
    }

    /// See [`super::attn_edge_dot`] (FMA dot products — matmul-family
    /// FP tolerance vs the scalar oracle).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn attn_edge_dot(
        dalpha: &mut [f32],
        dz: &[f32],
        ht: &[f32],
        idx: &[i32],
        mask: &[f32],
        rows: usize,
        k: usize,
        f: usize,
    ) {
        let f8 = f & !7;
        for r in 0..rows {
            let dp = dz.as_ptr().add(r * f);
            for c in 0..k {
                let o = &mut dalpha[r * k + c];
                if mask[r * k + c] == 0.0 {
                    *o = 0.0;
                    continue;
                }
                let hp = ht.as_ptr().add(idx[r * k + c] as usize * f);
                let mut s = _mm256_setzero_ps();
                let mut j = 0;
                while j < f8 {
                    s = _mm256_fmadd_ps(_mm256_loadu_ps(dp.add(j)), _mm256_loadu_ps(hp.add(j)), s);
                    j += 8;
                }
                let mut acc = hsum(s);
                for j in f8..f {
                    acc += *dp.add(j) * *hp.add(j);
                }
                *o = acc;
            }
        }
    }
}

pub mod scalar {
    //! The seed's scalar kernels — allocation per call, one k at a time —
    //! kept verbatim as the numerics oracle for the blocked kernels and
    //! as the baseline of the `micro_host` kernel-sweep bench.

    /// See [`super::aggregate`].
    #[allow(clippy::too_many_arguments)]
    pub fn aggregate(
        h: &[f32],
        idx: &[i32],
        w: &[f32],
        rows: usize,
        k: usize,
        f: usize,
        skip_self: bool,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * f];
        let c0 = usize::from(skip_self);
        for r in 0..rows {
            for c in c0..k {
                let weight = w[r * k + c];
                if weight == 0.0 {
                    continue;
                }
                let src = idx[r * k + c] as usize;
                let (dst, src_row) = (&mut out[r * f..(r + 1) * f], &h[src * f..(src + 1) * f]);
                for j in 0..f {
                    dst[j] += weight * src_row[j];
                }
            }
        }
        out
    }

    /// See [`super::scatter_aggregate`].
    #[allow(clippy::too_many_arguments)]
    pub fn scatter_aggregate(
        dh: &mut [f32],
        dout: &[f32],
        idx: &[i32],
        w: &[f32],
        rows: usize,
        k: usize,
        f: usize,
        skip_self: bool,
    ) {
        let c0 = usize::from(skip_self);
        for r in 0..rows {
            for c in c0..k {
                let weight = w[r * k + c];
                if weight == 0.0 {
                    continue;
                }
                let src = idx[r * k + c] as usize;
                for j in 0..f {
                    dh[src * f + j] += weight * dout[r * f + j];
                }
            }
        }
    }

    /// See [`super::take_rows`].
    pub fn take_rows(h: &[f32], idx: &[i32], rows: usize, k: usize, f: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * f];
        for r in 0..rows {
            let src = idx[r * k] as usize;
            out[r * f..(r + 1) * f].copy_from_slice(&h[src * f..(src + 1) * f]);
        }
        out
    }

    /// See [`super::scatter_self`].
    pub fn scatter_self(dh: &mut [f32], dout: &[f32], idx: &[i32], rows: usize, k: usize, f: usize) {
        for r in 0..rows {
            let src = idx[r * k] as usize;
            for j in 0..f {
                dh[src * f + j] += dout[r * f + j];
            }
        }
    }

    /// See [`super::matmul_bias`].
    pub fn matmul_bias(
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        n: usize,
        fin: usize,
        fout: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; n * fout];
        for r in 0..n {
            let orow = &mut out[r * fout..(r + 1) * fout];
            orow.copy_from_slice(bias);
            for kk in 0..fin {
                let xv = x[r * fin + kk];
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * fout..(kk + 1) * fout];
                for j in 0..fout {
                    orow[j] += xv * wrow[j];
                }
            }
        }
        out
    }

    /// See [`super::add_matmul`].
    pub fn add_matmul(out: &mut [f32], x: &[f32], w: &[f32], n: usize, fin: usize, fout: usize) {
        for r in 0..n {
            for kk in 0..fin {
                let xv = x[r * fin + kk];
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * fout..(kk + 1) * fout];
                let orow = &mut out[r * fout..(r + 1) * fout];
                for j in 0..fout {
                    orow[j] += xv * wrow[j];
                }
            }
        }
    }

    /// See [`super::matmul_at_b`].
    pub fn matmul_at_b(a: &[f32], b: &[f32], n: usize, fa: usize, fb: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; fa * fb];
        for r in 0..n {
            for kk in 0..fa {
                let av = a[r * fa + kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[r * fb..(r + 1) * fb];
                let orow = &mut out[kk * fb..(kk + 1) * fb];
                for j in 0..fb {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    /// See [`super::matmul_b_t`].
    pub fn matmul_b_t(a: &[f32], w: &[f32], n: usize, fa: usize, fb: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * fb];
        for r in 0..n {
            let arow = &a[r * fa..(r + 1) * fa];
            let orow = &mut out[r * fb..(r + 1) * fb];
            for kk in 0..fb {
                let wrow = &w[kk * fa..(kk + 1) * fa];
                let mut acc = 0.0f32;
                for j in 0..fa {
                    acc += arow[j] * wrow[j];
                }
                orow[kk] = acc;
            }
        }
        out
    }

    /// See [`super::col_sums`].
    pub fn col_sums(x: &[f32], n: usize, f: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; f];
        for r in 0..n {
            for j in 0..f {
                out[j] += x[r * f + j];
            }
        }
        out
    }

    /// See [`super::relu`].
    pub fn relu(x: &[f32]) -> Vec<f32> {
        x.iter().map(|&v| v.max(0.0)).collect()
    }

    /// See [`super::relu_mask`]: gradient through relu as a fresh buffer.
    pub fn relu_grad(z: &[f32], dh: &[f32]) -> Vec<f32> {
        z.iter().zip(dh).map(|(&zv, &dv)| if zv > 0.0 { dv } else { 0.0 }).collect()
    }

    /// See [`super::attn_edge_softmax`].
    #[allow(clippy::too_many_arguments)]
    pub fn attn_edge_softmax(
        sself: &[f32],
        snbr: &[f32],
        idx: &[i32],
        w: &[f32],
        rows: usize,
        k: usize,
        slope: f32,
    ) -> Vec<f32> {
        let mut alpha = vec![0.0f32; rows * k];
        for r in 0..rows {
            let s0 = sself[idx[r * k] as usize];
            let arow = &mut alpha[r * k..(r + 1) * k];
            for (a, &i) in arow.iter_mut().zip(&idx[r * k..(r + 1) * k]) {
                let x = s0 + snbr[i as usize];
                *a = if x > 0.0 { x } else { slope * x };
            }
            super::softmax_masked_row(arow, &w[r * k..(r + 1) * k]);
        }
        alpha
    }

    /// See [`super::attn_edge_dot`].
    #[allow(clippy::too_many_arguments)]
    pub fn attn_edge_dot(
        dz: &[f32],
        ht: &[f32],
        idx: &[i32],
        mask: &[f32],
        rows: usize,
        k: usize,
        f: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * k];
        for r in 0..rows {
            for c in 0..k {
                if mask[r * k + c] == 0.0 {
                    continue;
                }
                let src = idx[r * k + c] as usize;
                let mut acc = 0.0f32;
                for j in 0..f {
                    acc += dz[r * f + j] * ht[src * f + j];
                }
                out[r * k + c] = acc;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Random dense matrix with a sprinkling of exact zeros and (when
    /// `zero_rows`) whole all-zero rows — the padded wire format's shape.
    fn rand_mat(rng: &mut Rng, n: usize, f: usize, zero_rows: bool) -> Vec<f32> {
        let mut out: Vec<f32> = (0..n * f)
            .map(|_| {
                if rng.bool(0.2) {
                    0.0
                } else {
                    rng.f32() - 0.5
                }
            })
            .collect();
        if zero_rows {
            for r in 0..n {
                if rng.bool(0.3) {
                    out[r * f..(r + 1) * f].fill(0.0);
                }
            }
        }
        out
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32, tag: &str) {
        assert_eq!(got.len(), want.len(), "{tag}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let scale = 1.0 + g.abs().max(w.abs());
            assert!((g - w).abs() <= tol * scale, "{tag}[{i}]: {g} vs {w}");
        }
    }

    /// Shapes deliberately off the KT=4 tile grid, plus degenerate rows=0
    /// and width-1 cases.
    const SHAPES: [(usize, usize, usize); 7] = [
        (0, 5, 7),
        (1, 1, 1),
        (3, 4, 8),
        (5, 7, 9),
        (8, 16, 4),
        (13, 33, 6),
        (6, 2, 31),
    ];

    #[test]
    fn blocked_matmul_bias_matches_scalar_oracle() {
        let mut rng = Rng::new(1);
        for (n, fin, fout) in SHAPES {
            let x = rand_mat(&mut rng, n, fin, true);
            let w = rand_mat(&mut rng, fin, fout, false);
            let bias = rand_mat(&mut rng, 1, fout, false);
            let want = scalar::matmul_bias(&x, &w, &bias, n, fin, fout);
            let mut got = vec![f32::NAN; n * fout]; // dirty: must be overwritten
            matmul_bias(&mut got, &x, &w, &bias, n, fin, fout);
            assert_close(&got, &want, 1e-5, &format!("matmul_bias {n}x{fin}x{fout}"));
        }
    }

    #[test]
    fn blocked_add_matmul_matches_scalar_oracle() {
        let mut rng = Rng::new(2);
        for (n, fin, fout) in SHAPES {
            let x = rand_mat(&mut rng, n, fin, true);
            let w = rand_mat(&mut rng, fin, fout, false);
            let base = rand_mat(&mut rng, n, fout, false);
            let mut want = base.clone();
            scalar::add_matmul(&mut want, &x, &w, n, fin, fout);
            let mut got = base;
            add_matmul(&mut got, &x, &w, n, fin, fout);
            assert_close(&got, &want, 1e-5, &format!("add_matmul {n}x{fin}x{fout}"));
        }
    }

    #[test]
    fn blocked_matmul_at_b_matches_scalar_oracle() {
        let mut rng = Rng::new(3);
        for (n, fa, fb) in SHAPES {
            let a = rand_mat(&mut rng, n, fa, true);
            let b = rand_mat(&mut rng, n, fb, false);
            let want = scalar::matmul_at_b(&a, &b, n, fa, fb);
            let mut got = vec![f32::NAN; fa * fb];
            matmul_at_b(&mut got, &a, &b, n, fa, fb);
            assert_close(&got, &want, 1e-5, &format!("matmul_at_b {n}x{fa}x{fb}"));
        }
    }

    #[test]
    fn blocked_matmul_b_t_matches_scalar_oracle() {
        let mut rng = Rng::new(4);
        for (n, fa, fb) in SHAPES {
            let a = rand_mat(&mut rng, n, fa, true);
            let w = rand_mat(&mut rng, fb, fa, false);
            let want = scalar::matmul_b_t(&a, &w, n, fa, fb);
            let mut got = vec![f32::NAN; n * fb];
            matmul_b_t(&mut got, &a, &w, n, fa, fb);
            assert_close(&got, &want, 1e-5, &format!("matmul_b_t {n}x{fa}x{fb}"));
        }
    }

    #[test]
    fn col_sums_and_relu_match_scalar_exactly() {
        let mut rng = Rng::new(5);
        for (n, f, _) in SHAPES {
            let x = rand_mat(&mut rng, n, f, true);
            let want = scalar::col_sums(&x, n, f);
            let mut got = vec![f32::NAN; f];
            col_sums(&mut got, &x, n, f);
            assert_eq!(got, want, "col_sums {n}x{f}");

            let want = scalar::relu(&x);
            let mut got = vec![f32::NAN; x.len()];
            relu(&mut got, &x, x.len());
            assert_eq!(got, want, "relu {n}x{f}");

            let z = rand_mat(&mut rng, n, f, false);
            let want = scalar::relu_grad(&z, &x);
            let mut got = x.clone();
            relu_mask(&mut got, &z, x.len());
            assert_eq!(got, want, "relu_mask {n}x{f}");
        }
    }

    /// Random padded (idx, w) block over `n_src` source rows; some rows
    /// fully zero-weighted (padding rows), some columns zero.
    fn rand_block(
        rng: &mut Rng,
        rows: usize,
        k: usize,
        n_src: usize,
    ) -> (Vec<i32>, Vec<f32>) {
        let mut idx = vec![0i32; rows * k];
        let mut w = vec![0f32; rows * k];
        for r in 0..rows {
            let padded = rng.bool(0.25);
            for c in 0..k {
                idx[r * k + c] = rng.index(n_src) as i32;
                if !padded && !rng.bool(0.2) {
                    w[r * k + c] = rng.f32() + 0.01;
                }
            }
        }
        (idx, w)
    }

    #[test]
    fn gather_scatter_kernels_match_scalar_bit_exactly() {
        let mut rng = Rng::new(6);
        for (rows, k, f) in [(0, 3, 4), (4, 1, 5), (7, 4, 3), (12, 6, 8), (9, 5, 1)] {
            let n_src = (2 * rows).max(4);
            let h = rand_mat(&mut rng, n_src, f, false);
            let (idx, w) = rand_block(&mut rng, rows, k, n_src);
            for skip_self in [false, true] {
                let want = scalar::aggregate(&h, &idx, &w, rows, k, f, skip_self);
                let mut got = vec![f32::NAN; rows * f];
                aggregate(&mut got, &h, &idx, &w, rows, k, f, skip_self);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got), bits(&want), "aggregate skip_self={skip_self}");
            }

            let want = scalar::take_rows(&h, &idx, rows, k, f);
            let mut got = vec![f32::NAN; rows * f];
            take_rows(&mut got, &h, &idx, rows, k, f);
            assert_eq!(got, want, "take_rows");

            let dout = rand_mat(&mut rng, rows, f, false);
            for skip_self in [false, true] {
                let mut want = vec![0f32; n_src * f];
                scalar::scatter_aggregate(&mut want, &dout, &idx, &w, rows, k, f, skip_self);
                let mut got = vec![0f32; n_src * f];
                scatter_aggregate(&mut got, &dout, &idx, &w, rows, k, f, skip_self);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got), bits(&want), "scatter_aggregate skip_self={skip_self}");
            }

            let mut want = vec![0f32; n_src * f];
            scalar::scatter_self(&mut want, &dout, &idx, rows, k, f);
            let mut got = vec![0f32; n_src * f];
            scatter_self(&mut got, &dout, &idx, rows, k, f);
            assert_eq!(got, want, "scatter_self");
        }
    }

    #[test]
    fn fused_aggregate_with_self_matches_two_pass_oracle() {
        let mut rng = Rng::new(7);
        for (rows, k, f) in [(5, 3, 4), (8, 6, 7), (1, 1, 2), (0, 4, 3)] {
            let n_src = (2 * rows).max(4);
            let h = rand_mat(&mut rng, n_src, f, false);
            let (idx, w) = rand_block(&mut rng, rows, k, n_src);
            let want_agg = scalar::aggregate(&h, &idx, &w, rows, k, f, true);
            let want_self = scalar::take_rows(&h, &idx, rows, k, f);
            let mut agg = vec![f32::NAN; rows * f];
            let mut selfr = vec![f32::NAN; rows * f];
            aggregate_with_self(&mut agg, &mut selfr, &h, &idx, &w, rows, k, f);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&agg), bits(&want_agg), "fused agg {rows}x{k}x{f}");
            assert_eq!(selfr, want_self, "fused self rows {rows}x{k}x{f}");
        }
    }

    #[test]
    fn all_zero_weight_rows_produce_zero_output() {
        // padding rows: weights all zero → aggregate output must be
        // exactly 0 regardless of idx garbage, in both implementations
        let h = vec![1.5f32; 8 * 3];
        let idx = vec![2i32; 4 * 5];
        let w = vec![0f32; 4 * 5];
        let mut got = vec![f32::NAN; 4 * 3];
        aggregate(&mut got, &h, &idx, &w, 4, 5, 3, false);
        assert!(got.iter().all(|&x| x == 0.0));
        assert_eq!(got, scalar::aggregate(&h, &idx, &w, 4, 5, 3, false));
    }

    #[test]
    fn attn_edge_softmax_matches_scalar_bit_exactly_and_normalizes() {
        let mut rng = Rng::new(10);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        // rows=0, k=1 (self-only lists), and ragged rows via rand_block's
        // zero-weight columns / fully-padded rows
        for (rows, k) in [(0, 3), (1, 1), (4, 1), (7, 4), (12, 6), (9, 16)] {
            let n_src = (2 * rows).max(4);
            let sself = rand_mat(&mut rng, n_src, 1, false);
            let snbr = rand_mat(&mut rng, n_src, 1, false);
            let (idx, w) = rand_block(&mut rng, rows, k, n_src);
            let want = scalar::attn_edge_softmax(&sself, &snbr, &idx, &w, rows, k, 0.2);
            let mut got = vec![f32::NAN; rows * k];
            attn_edge_softmax(&mut got, &sself, &snbr, &idx, &w, rows, k, 0.2);
            assert_eq!(bits(&got), bits(&want), "attn_edge_softmax {rows}x{k}");
            for r in 0..rows {
                let real = (0..k).filter(|&c| w[r * k + c] != 0.0).count();
                let arow = &got[r * k..(r + 1) * k];
                if real == 0 {
                    assert!(arow.iter().all(|&a| a == 0.0), "padding row {r} must be 0");
                    continue;
                }
                let sum: f32 = arow.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "row {r} sums to {sum}");
                for (c, &a) in arow.iter().enumerate() {
                    assert!(a >= 0.0, "row {r} col {c}: alpha {a} < 0");
                    assert!(w[r * k + c] != 0.0 || a == 0.0, "row {r} col {c}: padding not 0");
                }
            }
        }
    }

    #[test]
    fn attn_edge_dot_matches_scalar_oracle() {
        let mut rng = Rng::new(11);
        for (rows, k, f) in [(0, 3, 4), (4, 1, 5), (7, 4, 3), (12, 6, 8), (5, 3, 19)] {
            let n_src = (2 * rows).max(4);
            let ht = rand_mat(&mut rng, n_src, f, false);
            let dz = rand_mat(&mut rng, rows, f, false);
            let (idx, w) = rand_block(&mut rng, rows, k, n_src);
            let want = scalar::attn_edge_dot(&dz, &ht, &idx, &w, rows, k, f);
            let mut got = vec![f32::NAN; rows * k];
            attn_edge_dot(&mut got, &dz, &ht, &idx, &w, rows, k, f);
            assert_close(&got, &want, 1e-5, &format!("attn_edge_dot {rows}x{k}x{f}"));
        }
    }

    #[test]
    fn attn_softmax_backward_is_shift_invariant_and_masks_padding() {
        // A constant dalpha over a softmax row must yield (near-)zero
        // raw-logit gradients — softmax is invariant to constant logit
        // shifts — and padding columns (alpha exactly 0) must vanish.
        let (rows, k, n_src) = (3usize, 4usize, 6usize);
        let mut rng = Rng::new(12);
        let sself = rand_mat(&mut rng, n_src, 1, false);
        let snbr = rand_mat(&mut rng, n_src, 1, false);
        let (idx, w) = rand_block(&mut rng, rows, k, n_src);
        let alpha = scalar::attn_edge_softmax(&sself, &snbr, &idx, &w, rows, k, 0.2);
        let mut dalpha = vec![0.5f32; rows * k];
        attn_softmax_backward(&mut dalpha, &alpha, &sself, &snbr, &idx, rows, k, 0.2);
        for (i, &d) in dalpha.iter().enumerate() {
            assert!(d.abs() < 1e-6, "constant dalpha must vanish, got {d} at {i}");
        }
    }

    #[test]
    fn attn_scatter_scores_matches_naive_two_pass() {
        let mut rng = Rng::new(13);
        for (rows, k) in [(0, 3), (4, 1), (7, 4), (12, 6)] {
            let n_src = (2 * rows).max(4);
            let (idx, _) = rand_block(&mut rng, rows, k, n_src);
            let draw = rand_mat(&mut rng, rows, k, false);
            let mut dsself = vec![0.0f32; n_src];
            let mut dsnbr = vec![0.0f32; n_src];
            attn_scatter_scores(&mut dsself, &mut dsnbr, &draw, &idx, rows, k);
            let mut want_self = vec![0.0f32; n_src];
            let mut want_nbr = vec![0.0f32; n_src];
            for r in 0..rows {
                let mut s = 0.0f32;
                for c in 0..k {
                    s += draw[r * k + c];
                    want_nbr[idx[r * k + c] as usize] += draw[r * k + c];
                }
                want_self[idx[r * k] as usize] += s;
            }
            assert_eq!(dsself, want_self, "dsself {rows}x{k}");
            assert_eq!(dsnbr, want_nbr, "dsnbr {rows}x{k}");
        }
    }

    #[test]
    fn small_elementwise_kernels_match_reference_expressions() {
        let mut rng = Rng::new(14);
        let x = rand_mat(&mut rng, 5, 7, false);
        let bias = rand_mat(&mut rng, 1, 7, false);
        let mut out = x.clone();
        add_bias(&mut out, &bias, 5, 7);
        for r in 0..5 {
            for j in 0..7 {
                assert_eq!(out[r * 7 + j], x[r * 7 + j] + bias[j]);
            }
        }

        let y = rand_mat(&mut rng, 5, 7, false);
        let mut out = x.clone();
        scaled_add(&mut out, &y, 1.25, 35);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, x[i] + 1.25 * y[i]);
        }

        let d = dot(&x, &y, 35);
        let mut want = 0.0f32;
        for (&xv, &yv) in x.iter().zip(&y) {
            want += xv * yv;
        }
        assert_eq!(d.to_bits(), want.to_bits());

        let idx = vec![3i32, 0, 1, 0, 2, 0]; // rows=3, k=2
        let dout = rand_mat(&mut rng, 3, 4, false);
        let mut dh = vec![0.0f32; 5 * 4];
        scatter_self_scaled(&mut dh, &dout, &idx, 1.5, 3, 2, 4);
        let mut want = vec![0.0f32; 5 * 4];
        for r in 0..3 {
            let src = idx[r * 2] as usize;
            for j in 0..4 {
                want[src * 4 + j] += 1.5 * dout[r * 4 + j];
            }
        }
        assert_eq!(dh, want);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn simd_attention_kernels_match_scalar_oracle() {
        if !simd_supported() {
            return; // fallback hosts: the dispatch tests above cover it
        }
        let mut rng = Rng::new(15);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        // k ≥ 8 exercises the gathered vector path; k=1 and rows=0 the
        // degenerate scalar tails
        for (rows, k, f) in [(0, 3, 4), (4, 1, 5), (7, 9, 3), (12, 16, 8), (5, 21, 19)] {
            let n_src = (2 * rows).max(4);
            let sself = rand_mat(&mut rng, n_src, 1, false);
            let snbr = rand_mat(&mut rng, n_src, 1, false);
            let (idx, w) = rand_block(&mut rng, rows, k, n_src);
            let tag = format!("simd attn {rows}x{k}x{f}");

            let want = scalar::attn_edge_softmax(&sself, &snbr, &idx, &w, rows, k, 0.2);
            let mut got = vec![f32::NAN; rows * k];
            unsafe { x86::attn_edge_softmax(&mut got, &sself, &snbr, &idx, &w, rows, k, 0.2) };
            assert_eq!(bits(&got), bits(&want), "{tag} softmax");

            let ht = rand_mat(&mut rng, n_src, f, false);
            let dz = rand_mat(&mut rng, rows, f, false);
            let want = scalar::attn_edge_dot(&dz, &ht, &idx, &w, rows, k, f);
            let mut got = vec![f32::NAN; rows * k];
            unsafe { x86::attn_edge_dot(&mut got, &dz, &ht, &idx, &w, rows, k, f) };
            assert_close(&got, &want, 1e-5, &format!("{tag} edge dot"));
        }
    }

    #[test]
    fn tier_resolves_and_rejects_unsupported_override() {
        let t = active_tier();
        assert!(matches!(t, Tier::Avx2Fma | Tier::Blocked));
        assert!(!t.name().is_empty());
        if !simd_supported() {
            // the override must refuse to enable microkernels the host
            // cannot execute, leaving the blocked tier active
            assert!(!set_tier(Tier::Avx2Fma));
            assert_eq!(active_tier(), Tier::Blocked);
        }
    }

    /// Shapes deliberately off the 8-lane grid (`cols % 8 ≠ 0`), plus
    /// rows = 0, the exact-lane case, and width-1 degenerates — the
    /// satellite property sweep for the SIMD microkernels. The x86
    /// module is exercised directly (not via [`set_tier`]) so the
    /// process-global dispatch tier never flips under concurrent tests.
    #[cfg(target_arch = "x86_64")]
    const SIMD_SHAPES: [(usize, usize, usize); 8] = [
        (0, 5, 7),
        (1, 1, 1),
        (2, 9, 3),
        (5, 7, 9),
        (4, 8, 8),
        (13, 33, 6),
        (7, 12, 17),
        (6, 2, 31),
    ];

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn simd_matmuls_match_scalar_oracle_on_off_lane_shapes() {
        if !simd_supported() {
            return; // fallback hosts: the blocked tests above cover it
        }
        let mut rng = Rng::new(8);
        for (n, fin, fout) in SIMD_SHAPES {
            // rand_mat's zero_rows sprinkles whole all-zero x tiles, the
            // padded wire format's shape the kernels shortcut on
            let x = rand_mat(&mut rng, n, fin, true);
            let w = rand_mat(&mut rng, fin, fout, false);
            let bias = rand_mat(&mut rng, 1, fout, false);
            let tag = format!("simd {n}x{fin}x{fout}");

            let want = scalar::matmul_bias(&x, &w, &bias, n, fin, fout);
            let mut got = vec![f32::NAN; n * fout];
            unsafe { x86::matmul_bias(&mut got, &x, &w, &bias, n, fin, fout) };
            assert_close(&got, &want, 1e-5, &format!("{tag} matmul_bias"));

            let base = rand_mat(&mut rng, n, fout, false);
            let mut want = base.clone();
            scalar::add_matmul(&mut want, &x, &w, n, fin, fout);
            let mut got = base;
            unsafe { x86::add_matmul(&mut got, &x, &w, n, fin, fout) };
            assert_close(&got, &want, 1e-5, &format!("{tag} add_matmul"));

            let (fa, fb) = (fin, fout);
            let a = rand_mat(&mut rng, n, fa, true);
            let b = rand_mat(&mut rng, n, fb, false);
            let want = scalar::matmul_at_b(&a, &b, n, fa, fb);
            let mut got = vec![f32::NAN; fa * fb];
            unsafe { x86::matmul_at_b(&mut got, &a, &b, n, fa, fb) };
            assert_close(&got, &want, 1e-5, &format!("{tag} matmul_at_b"));

            let wt = rand_mat(&mut rng, fb, fa, false);
            let want = scalar::matmul_b_t(&a, &wt, n, fa, fb);
            let mut got = vec![f32::NAN; n * fb];
            unsafe { x86::matmul_b_t(&mut got, &a, &wt, n, fa, fb) };
            assert_close(&got, &want, 1e-5, &format!("{tag} matmul_b_t"));
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn simd_gather_scatter_match_scalar_oracle_bit_exactly() {
        if !simd_supported() {
            return;
        }
        let mut rng = Rng::new(9);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for (rows, k, f) in [(0, 3, 4), (4, 1, 5), (7, 4, 3), (12, 6, 8), (9, 5, 1), (5, 3, 19)] {
            let n_src = (2 * rows).max(4);
            let h = rand_mat(&mut rng, n_src, f, false);
            let (idx, w) = rand_block(&mut rng, rows, k, n_src);
            let tag = format!("simd {rows}x{k}x{f}");

            for skip_self in [false, true] {
                let want = scalar::aggregate(&h, &idx, &w, rows, k, f, skip_self);
                let mut got = vec![f32::NAN; rows * f];
                unsafe { x86::aggregate(&mut got, &h, &idx, &w, rows, k, f, skip_self) };
                assert_eq!(bits(&got), bits(&want), "{tag} aggregate skip_self={skip_self}");
            }

            let want_agg = scalar::aggregate(&h, &idx, &w, rows, k, f, true);
            let want_self = scalar::take_rows(&h, &idx, rows, k, f);
            let mut agg = vec![f32::NAN; rows * f];
            let mut selfr = vec![f32::NAN; rows * f];
            unsafe { x86::aggregate_with_self(&mut agg, &mut selfr, &h, &idx, &w, rows, k, f) };
            assert_eq!(bits(&agg), bits(&want_agg), "{tag} fused agg");
            assert_eq!(selfr, want_self, "{tag} fused self rows");

            let dout = rand_mat(&mut rng, rows, f, false);
            for skip_self in [false, true] {
                let mut want = vec![0f32; n_src * f];
                scalar::scatter_aggregate(&mut want, &dout, &idx, &w, rows, k, f, skip_self);
                let mut got = vec![0f32; n_src * f];
                unsafe {
                    x86::scatter_aggregate(&mut got, &dout, &idx, &w, rows, k, f, skip_self)
                };
                assert_eq!(bits(&got), bits(&want), "{tag} scatter skip_self={skip_self}");
            }

            let mut want = vec![0f32; n_src * f];
            scalar::scatter_self(&mut want, &dout, &idx, rows, k, f);
            let mut got = vec![0f32; n_src * f];
            unsafe { x86::scatter_self(&mut got, &dout, &idx, rows, k, f) };
            assert_eq!(bits(&got), bits(&want), "{tag} scatter_self");
        }

        // all-zero weight tiles (pure padding rows) must yield exact zeros
        let h = vec![1.5f32; 8 * 11];
        let idx = vec![2i32; 4 * 5];
        let w = vec![0f32; 4 * 5];
        let mut got = vec![f32::NAN; 4 * 11];
        unsafe { x86::aggregate(&mut got, &h, &idx, &w, 4, 5, 11, false) };
        assert!(got.iter().all(|&v| v == 0.0), "zero-tile aggregate");
    }
}
