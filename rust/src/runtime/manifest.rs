//! `artifacts/manifest.json` — the contract between the Python AOT
//! compiler and the Rust runtime.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Static shapes of one artifact (mirror of python `ModelDims`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactDims {
    pub b: usize,
    pub k1: usize,
    pub k2: usize,
    pub v1_cap: usize,
    pub v0_cap: usize,
    pub f0: usize,
    pub f1: usize,
    pub f2: usize,
}

impl ArtifactDims {
    fn from_json(j: &Json) -> anyhow::Result<ArtifactDims> {
        let d = ArtifactDims {
            b: j.req_usize("b")?,
            k1: j.req_usize("k1")?,
            k2: j.req_usize("k2")?,
            v1_cap: j.req_usize("v1_cap")?,
            v0_cap: j.req_usize("v0_cap")?,
            f0: j.req_usize("f0")?,
            f1: j.req_usize("f1")?,
            f2: j.req_usize("f2")?,
        };
        anyhow::ensure!(
            d.v1_cap == d.b * (d.k2 + 1) && d.v0_cap == d.v1_cap * (d.k1 + 1),
            "inconsistent artifact dims: {d:?}"
        );
        Ok(d)
    }

    /// Matching sampler configuration.
    pub fn fanout_config(&self) -> crate::sampling::FanoutConfig {
        crate::sampling::FanoutConfig { batch_size: self.b, k1: self.k1, k2: self.k2 }
    }
}

/// One compiled-artifact descriptor.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// "train" or "predict".
    pub kind: String,
    /// "gcn" or "sage".
    pub model: String,
    pub dataset: String,
    /// HLO text file, absolute.
    pub path: PathBuf,
    pub dims: ArtifactDims,
    /// Parameter names and shapes, in artifact input order.
    pub params: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<String>,
}

impl ArtifactEntry {
    /// Total parameter element count (for optimizer state sizing).
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
    pub fn param_bytes(&self) -> u64 {
        (self.param_elems() * 4) as u64
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and validate that the artifact files
    /// exist.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::from_file(&dir.join("manifest.json"))?;
        let version = j.req_usize("version")?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let mut entries = Vec::new();
        for e in j.req("entries")?.as_arr().unwrap_or(&[]) {
            let dims = ArtifactDims::from_json(e.req("dims")?)?;
            let params = e
                .req("params")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|p| {
                    let name = p.req_str("name")?.to_string();
                    let shape: Vec<usize> = p
                        .req("shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect();
                    Ok((name, shape))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect();
            let path = dir.join(e.req_str("file")?);
            anyhow::ensure!(path.exists(), "artifact file missing: {}", path.display());
            entries.push(ArtifactEntry {
                name: e.req_str("name")?.to_string(),
                kind: e.req_str("kind")?.to_string(),
                model: e.req_str("model")?.to_string(),
                dataset: e.req_str("dataset")?.to_string(),
                path,
                dims,
                params,
                outputs,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no entries");
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Find an entry by kind/model/dataset.
    pub fn find(&self, kind: &str, model: &str, dataset: &str) -> anyhow::Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.model == model && e.dataset == dataset)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for kind={kind} model={model} dataset={dataset} \
                     (have: {}) — run `make artifacts`",
                    self.entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>().join(", ")
                )
            })
    }

    /// Load `<dir>/manifest.json`, falling back to the [`Manifest::builtin`]
    /// synthetic manifest when no artifacts have been generated. The
    /// reference executor needs only dims + parameter shapes, not HLO
    /// files, so the coordinator can train without `make artifacts`.
    /// PJRT builds keep the actionable "run make artifacts" error instead
    /// of failing later on fabricated entries whose HLO files don't exist.
    pub fn load_or_builtin(dir: &Path) -> anyhow::Result<Manifest> {
        if dir.join("manifest.json").exists() {
            Manifest::load(dir)
        } else if cfg!(feature = "pjrt") {
            anyhow::bail!(
                "no artifacts in {} — run `make artifacts` (or build without \
                 the `pjrt` feature to use the reference executor)",
                dir.display()
            )
        } else {
            Ok(Manifest::builtin(dir))
        }
    }

    /// Synthetic manifest mirroring the `python -m compile.aot` defaults:
    /// tiny (b=32, fanout 3/2) plus the Table-4 datasets (b=256, fanout
    /// 10/5), for gcn and sage, train and predict. Entry `path`s point
    /// into `dir` but are not required to exist (reference backend).
    pub fn builtin(dir: &Path) -> Manifest {
        let mut entries = Vec::new();
        for model in ["gcn", "sage"] {
            for spec in crate::graph::datasets::REGISTRY.iter() {
                push_builtin(&mut entries, dir, model, spec.key, 256, 10, 5, spec.dims);
            }
            let tiny = crate::graph::datasets::TINY;
            push_builtin(&mut entries, dir, model, tiny.key, 32, 3, 2, tiny.dims);
        }
        Manifest { dir: dir.to_path_buf(), entries }
    }

    /// Default artifacts directory: $HITGNN_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("HITGNN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

/// Append the train + predict builtin entries for one (model, dataset).
fn push_builtin(
    entries: &mut Vec<ArtifactEntry>,
    dir: &Path,
    model: &str,
    dataset: &str,
    b: usize,
    k1: usize,
    k2: usize,
    gd: crate::graph::GnnDims,
) {
    let v1_cap = b * (k2 + 1);
    let dims = ArtifactDims {
        b,
        k1,
        k2,
        v1_cap,
        v0_cap: v1_cap * (k1 + 1),
        f0: gd.f0,
        f1: gd.f1,
        f2: gd.f2,
    };
    let (f0, f1, f2) = (gd.f0, gd.f1, gd.f2);
    let params: Vec<(String, Vec<usize>)> = match model {
        "gcn" => vec![
            ("w1".into(), vec![f0, f1]),
            ("b1".into(), vec![f1]),
            ("w2".into(), vec![f1, f2]),
            ("b2".into(), vec![f2]),
        ],
        _ => vec![
            ("w1_self".into(), vec![f0, f1]),
            ("w1_nbr".into(), vec![f0, f1]),
            ("b1".into(), vec![f1]),
            ("w2_self".into(), vec![f1, f2]),
            ("w2_nbr".into(), vec![f1, f2]),
            ("b2".into(), vec![f2]),
        ],
    };
    for kind in ["train", "predict"] {
        let name = format!("{kind}_{model}_{}", dataset.replace('-', "_"));
        let outputs = if kind == "train" {
            std::iter::once("loss".to_string())
                .chain(params.iter().map(|(n, _)| format!("grad_{n}")))
                .collect()
        } else {
            vec!["logits".to_string()]
        };
        entries.push(ArtifactEntry {
            name: name.clone(),
            kind: kind.to_string(),
            model: model.to_string(),
            dataset: dataset.to_string(),
            path: dir.join(format!("{name}.hlo.txt")),
            dims,
            params: params.clone(),
            outputs,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        Manifest::default_dir()
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.len() >= 4);
        let e = m.find("train", "gcn", "tiny").unwrap();
        assert_eq!(e.dims.b, 32);
        assert_eq!(e.params[0].0, "w1");
        assert_eq!(e.outputs[0], "loss");
        assert_eq!(e.param_elems(), 32 * 16 + 16 + 16 * 8 + 8);
        assert!(m.find("train", "gcn", "nonexistent").is_err());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn builtin_covers_all_models_and_datasets() {
        let m = Manifest::builtin(Path::new("/nonexistent"));
        // 2 models × (4 registry + tiny) × (train, predict)
        assert_eq!(m.entries.len(), 2 * 5 * 2);
        let e = m.find("train", "gcn", "tiny").unwrap();
        assert_eq!(e.dims.b, 32);
        assert_eq!(e.dims.v1_cap, 32 * 3);
        assert_eq!(e.dims.v0_cap, 32 * 3 * 4);
        assert_eq!(e.params[0], ("w1".to_string(), vec![32, 16]));
        assert_eq!(e.param_elems(), 32 * 16 + 16 + 16 * 8 + 8);
        let s = m.find("predict", "sage", "ogbn-products").unwrap();
        assert_eq!(s.params.len(), 6);
        assert_eq!(s.outputs, vec!["logits".to_string()]);
        assert_eq!(s.dims.f0, 100);
    }

    #[test]
    fn load_or_builtin_prefers_real_manifest() {
        // missing dir → builtin (reference builds) / clean error (pjrt)
        let r = Manifest::load_or_builtin(Path::new("/nonexistent"));
        if cfg!(feature = "pjrt") {
            assert!(r.is_err());
        } else {
            assert!(r.unwrap().find("train", "sage", "reddit").is_ok());
        }
        // present but malformed manifest → strict error, no silent fallback
        let tmp = std::env::temp_dir().join(format!("hitgnn_lob_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), "{not json").unwrap();
        assert!(Manifest::load_or_builtin(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn rejects_bad_version_and_inconsistent_dims() {
        let tmp = std::env::temp_dir().join(format!("hitgnn_m_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), r#"{"version": 9, "entries": []}"#).unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::write(
            tmp.join("manifest.json"),
            r#"{"version": 1, "entries": [{"name":"x","kind":"train","model":"gcn",
                "dataset":"d","file":"x.hlo.txt","params":[],"outputs":[],
                "dims":{"b":4,"k1":2,"k2":2,"v1_cap":999,"v0_cap":36,
                        "f0":4,"f1":4,"f2":4}}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
