//! `artifacts/manifest.json` — the contract between the Python AOT
//! compiler and the Rust runtime.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Static shapes of one artifact (mirror of python `ModelDims`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactDims {
    pub b: usize,
    pub k1: usize,
    pub k2: usize,
    pub v1_cap: usize,
    pub v0_cap: usize,
    pub f0: usize,
    pub f1: usize,
    pub f2: usize,
}

impl ArtifactDims {
    fn from_json(j: &Json) -> anyhow::Result<ArtifactDims> {
        let d = ArtifactDims {
            b: j.req_usize("b")?,
            k1: j.req_usize("k1")?,
            k2: j.req_usize("k2")?,
            v1_cap: j.req_usize("v1_cap")?,
            v0_cap: j.req_usize("v0_cap")?,
            f0: j.req_usize("f0")?,
            f1: j.req_usize("f1")?,
            f2: j.req_usize("f2")?,
        };
        anyhow::ensure!(
            d.v1_cap == d.b * (d.k2 + 1) && d.v0_cap == d.v1_cap * (d.k1 + 1),
            "inconsistent artifact dims: {d:?}"
        );
        Ok(d)
    }

    /// Matching sampler configuration.
    pub fn fanout_config(&self) -> crate::sampling::FanoutConfig {
        crate::sampling::FanoutConfig { batch_size: self.b, k1: self.k1, k2: self.k2 }
    }
}

/// One compiled-artifact descriptor.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// "train" or "predict".
    pub kind: String,
    /// "gcn" or "sage".
    pub model: String,
    pub dataset: String,
    /// HLO text file, absolute.
    pub path: PathBuf,
    pub dims: ArtifactDims,
    /// Parameter names and shapes, in artifact input order.
    pub params: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<String>,
}

impl ArtifactEntry {
    /// Total parameter element count (for optimizer state sizing).
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
    pub fn param_bytes(&self) -> u64 {
        (self.param_elems() * 4) as u64
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and validate that the artifact files
    /// exist.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::from_file(&dir.join("manifest.json"))?;
        let version = j.req_usize("version")?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let mut entries = Vec::new();
        for e in j.req("entries")?.as_arr().unwrap_or(&[]) {
            let dims = ArtifactDims::from_json(e.req("dims")?)?;
            let params = e
                .req("params")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|p| {
                    let name = p.req_str("name")?.to_string();
                    let shape: Vec<usize> = p
                        .req("shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect();
                    Ok((name, shape))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect();
            let path = dir.join(e.req_str("file")?);
            anyhow::ensure!(path.exists(), "artifact file missing: {}", path.display());
            entries.push(ArtifactEntry {
                name: e.req_str("name")?.to_string(),
                kind: e.req_str("kind")?.to_string(),
                model: e.req_str("model")?.to_string(),
                dataset: e.req_str("dataset")?.to_string(),
                path,
                dims,
                params,
                outputs,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no entries");
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Find an entry by kind/model/dataset.
    pub fn find(&self, kind: &str, model: &str, dataset: &str) -> anyhow::Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.model == model && e.dataset == dataset)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for kind={kind} model={model} dataset={dataset} \
                     (have: {}) — run `make artifacts`",
                    self.entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>().join(", ")
                )
            })
    }

    /// Default artifacts directory: $HITGNN_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("HITGNN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        Manifest::default_dir()
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.len() >= 4);
        let e = m.find("train", "gcn", "tiny").unwrap();
        assert_eq!(e.dims.b, 32);
        assert_eq!(e.params[0].0, "w1");
        assert_eq!(e.outputs[0], "loss");
        assert_eq!(e.param_elems(), 32 * 16 + 16 + 16 * 8 + 8);
        assert!(m.find("train", "gcn", "nonexistent").is_err());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn rejects_bad_version_and_inconsistent_dims() {
        let tmp = std::env::temp_dir().join(format!("hitgnn_m_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), r#"{"version": 9, "entries": []}"#).unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::write(
            tmp.join("manifest.json"),
            r#"{"version": 1, "entries": [{"name":"x","kind":"train","model":"gcn",
                "dataset":"d","file":"x.hlo.txt","params":[],"outputs":[],
                "dims":{"b":4,"k1":2,"k2":2,"v1_cap":999,"v0_cap":36,
                        "f0":4,"f1":4,"f2":4}}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
