//! `artifacts/manifest.json` — the contract between the Python AOT
//! compiler and the Rust runtime.

use std::path::{Path, PathBuf};

use crate::graph::GnnDims;
use crate::util::json::Json;

/// Static shapes of one artifact (mirror of python `ModelDims`),
/// generalized to arbitrary depth L (see DESIGN.md §Mini-batch wire
/// format for the level numbering and the fanout-vector order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactDims {
    /// Target capacity (batch size B).
    pub b: usize,
    /// Per-layer fanouts (`fanouts[l-1]` = layer-l fanout; length L).
    pub fanouts: Vec<usize>,
    /// Per-level vertex capacities (`caps[L] = b`).
    pub caps: Vec<usize>,
    /// Per-level feature widths (`f[0]` input, `f[L]` classes).
    pub f: Vec<usize>,
}

impl ArtifactDims {
    /// Compute the capacity recurrence from (b, fanouts, feature widths).
    pub fn from_batch(b: usize, fanouts: &[usize], f: &[usize]) -> ArtifactDims {
        assert_eq!(f.len(), fanouts.len() + 1, "need one feature width per level");
        let lcount = fanouts.len();
        let mut caps = vec![0usize; lcount + 1];
        caps[lcount] = b;
        for l in (1..=lcount).rev() {
            caps[l - 1] = caps[l] * (fanouts[l - 1] + 1);
        }
        ArtifactDims { b, fanouts: fanouts.to_vec(), caps, f: f.to_vec() }
    }

    /// Number of GNN layers L.
    pub fn layers(&self) -> usize {
        self.fanouts.len()
    }

    /// Input feature width f^0.
    pub fn f0(&self) -> usize {
        self.f[0]
    }

    /// Output classes f^L.
    pub fn classes(&self) -> usize {
        *self.f.last().expect("non-empty feature widths")
    }

    /// Level-0 (feature-gather) capacity.
    pub fn v0_cap(&self) -> usize {
        self.caps[0]
    }

    fn from_json(j: &Json) -> anyhow::Result<ArtifactDims> {
        let (b, fanouts, f) = if j.get("fanouts").is_some() {
            // depth-L format: {b, fanouts: [..], f: [..]} (+ optional caps)
            let fanouts = req_usize_arr(j, "fanouts")?;
            let f = req_usize_arr(j, "f")?;
            anyhow::ensure!(
                f.len() == fanouts.len() + 1,
                "artifact dims: f has {} entries for {} layers",
                f.len(),
                fanouts.len()
            );
            (j.req_usize("b")?, fanouts, f)
        } else {
            // legacy 2-layer format: {b, k1, k2, v1_cap, v0_cap, f0, f1, f2}
            (
                j.req_usize("b")?,
                vec![j.req_usize("k1")?, j.req_usize("k2")?],
                vec![j.req_usize("f0")?, j.req_usize("f1")?, j.req_usize("f2")?],
            )
        };
        // manifest load is a fanout entry point: reject degenerate shapes
        // (and usize-overflowing capacity products — validate's recurrence
        // is checked) *before* the unchecked from_batch recurrence runs
        crate::sampling::FanoutConfig::new(b, &fanouts).validate()?;
        let d = ArtifactDims::from_batch(b, &fanouts, &f);
        if j.get("caps").is_some() {
            let caps = req_usize_arr(j, "caps")?;
            anyhow::ensure!(caps == d.caps, "inconsistent artifact dims: {d:?}");
        }
        if j.get("v1_cap").is_some() {
            anyhow::ensure!(
                d.caps[1] == j.req_usize("v1_cap")? && d.caps[0] == j.req_usize("v0_cap")?,
                "inconsistent artifact dims: {d:?}"
            );
        }
        Ok(d)
    }

    /// Matching sampler configuration.
    pub fn fanout_config(&self) -> crate::sampling::FanoutConfig {
        crate::sampling::FanoutConfig::new(self.b, &self.fanouts)
    }
}

fn req_usize_arr(j: &Json, key: &str) -> anyhow::Result<Vec<usize>> {
    let arr = j.req(key)?.as_arr().unwrap_or(&[]);
    let vals: Vec<usize> = arr.iter().filter_map(|x| x.as_usize()).collect();
    anyhow::ensure!(vals.len() == arr.len() && !vals.is_empty(), "bad '{key}' array");
    Ok(vals)
}

/// One compiled-artifact descriptor.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// "train" or "predict".
    pub kind: String,
    /// Model-zoo name (`model_ops::MODEL_NAMES`): gcn, sage, gat, gin.
    pub model: String,
    pub dataset: String,
    /// HLO text file, absolute.
    pub path: PathBuf,
    pub dims: ArtifactDims,
    /// Parameter names and shapes, in artifact input order.
    pub params: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<String>,
}

impl ArtifactEntry {
    /// Total parameter element count (for optimizer state sizing).
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
    pub fn param_bytes(&self) -> u64 {
        (self.param_elems() * 4) as u64
    }
}

/// The canonical per-layer parameter list of
/// `python/compile/model.py::init_params` for an L-layer model: GCN has
/// (w_l, b_l) per layer, SAGE (w_l_self, w_l_nbr, b_l), GAT
/// (w_l, a_l_self, a_l_nbr, b_l — single-head attention vectors of the
/// output width), GIN (w_l_1, b_l_1, w_l_2, b_l_2, eps_l — the 2-layer
/// MLP update plus the trainable scalar ε). At L = 2 the gcn/sage lists
/// are exactly the seed's parameter order.
///
/// Every model name must be one of `model_ops::MODEL_NAMES` — callers
/// validate at their entry point (`model_ops::validate_model`), so an
/// unknown name here is a bug, not user input, and panics loudly
/// instead of silently borrowing another model's layout.
pub fn param_specs(model: &str, dims: &ArtifactDims) -> Vec<(String, Vec<usize>)> {
    let mut params = Vec::new();
    for l in 1..=dims.layers() {
        let (fin, fout) = (dims.f[l - 1], dims.f[l]);
        match model {
            "gcn" => {
                params.push((format!("w{l}"), vec![fin, fout]));
                params.push((format!("b{l}"), vec![fout]));
            }
            "sage" => {
                params.push((format!("w{l}_self"), vec![fin, fout]));
                params.push((format!("w{l}_nbr"), vec![fin, fout]));
                params.push((format!("b{l}"), vec![fout]));
            }
            "gat" => {
                params.push((format!("w{l}"), vec![fin, fout]));
                params.push((format!("a{l}_self"), vec![fout]));
                params.push((format!("a{l}_nbr"), vec![fout]));
                params.push((format!("b{l}"), vec![fout]));
            }
            "gin" => {
                params.push((format!("w{l}_1"), vec![fin, fout]));
                params.push((format!("b{l}_1"), vec![fout]));
                params.push((format!("w{l}_2"), vec![fout, fout]));
                params.push((format!("b{l}_2"), vec![fout]));
                params.push((format!("eps{l}"), vec![1]));
            }
            other => panic!(
                "unknown model '{other}' in param_specs — callers must \
                 validate via model_ops::validate_model first"
            ),
        }
    }
    params
}

/// Per-level feature widths for an L-layer model on a dataset: input
/// width, then the hidden width for every interior level, then classes.
pub fn feature_widths(gd: GnnDims, layers: usize) -> Vec<usize> {
    let mut f = Vec::with_capacity(layers + 1);
    f.push(gd.f0);
    for _ in 1..layers {
        f.push(gd.f1);
    }
    f.push(gd.f2);
    f
}

/// Synthesize one artifact entry (reference-executor backend: dims +
/// parameter shapes are all it needs; the `path` is not required to
/// exist). Non-2-layer entries get an `_l{L}` name suffix so names stay
/// unique next to the default-depth artifact of the same dataset.
pub fn synth_entry(
    dir: &Path,
    kind: &str,
    model: &str,
    dataset: &str,
    b: usize,
    fanouts: &[usize],
    gd: GnnDims,
) -> ArtifactEntry {
    let dims = ArtifactDims::from_batch(b, fanouts, &feature_widths(gd, fanouts.len()));
    let params = param_specs(model, &dims);
    let ds = dataset.replace('-', "_");
    let name = if fanouts.len() == 2 {
        format!("{kind}_{model}_{ds}")
    } else {
        format!("{kind}_{model}_{ds}_l{}", fanouts.len())
    };
    let outputs = if kind == "train" {
        std::iter::once("loss".to_string())
            .chain(params.iter().map(|(n, _)| format!("grad_{n}")))
            .collect()
    } else {
        vec!["logits".to_string()]
    };
    ArtifactEntry {
        name: name.clone(),
        kind: kind.to_string(),
        model: model.to_string(),
        dataset: dataset.to_string(),
        path: dir.join(format!("{name}.hlo.txt")),
        dims,
        params,
        outputs,
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and validate that the artifact files
    /// exist.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::from_file(&dir.join("manifest.json"))?;
        let version = j.req_usize("version")?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let mut entries = Vec::new();
        for e in j.req("entries")?.as_arr().unwrap_or(&[]) {
            let dims = ArtifactDims::from_json(e.req("dims")?)?;
            let params = e
                .req("params")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|p| {
                    let name = p.req_str("name")?.to_string();
                    let shape: Vec<usize> = p
                        .req("shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect();
                    Ok((name, shape))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect();
            let path = dir.join(e.req_str("file")?);
            anyhow::ensure!(path.exists(), "artifact file missing: {}", path.display());
            entries.push(ArtifactEntry {
                name: e.req_str("name")?.to_string(),
                kind: e.req_str("kind")?.to_string(),
                model: e.req_str("model")?.to_string(),
                dataset: e.req_str("dataset")?.to_string(),
                path,
                dims,
                params,
                outputs,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no entries");
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Find an entry by kind/model/dataset (the first match — i.e. the
    /// dataset's default-depth artifact; see [`Manifest::find_fanouts`]).
    pub fn find(&self, kind: &str, model: &str, dataset: &str) -> anyhow::Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.model == model && e.dataset == dataset)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for kind={kind} model={model} dataset={dataset} \
                     (have: {}) — run `make artifacts`",
                    self.entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>().join(", ")
                )
            })
    }

    /// Find an entry at an exact fanout configuration (e.g. the builtin
    /// 3-layer SAGE artifact that shares its dataset key with the
    /// default-depth one).
    pub fn find_fanouts(
        &self,
        kind: &str,
        model: &str,
        dataset: &str,
        fanouts: &[usize],
    ) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.kind == kind && e.model == model && e.dataset == dataset && e.dims.fanouts == fanouts
        })
    }

    /// Load `<dir>/manifest.json`, falling back to the [`Manifest::builtin`]
    /// synthetic manifest when no artifacts have been generated. The
    /// reference executor needs only dims + parameter shapes, not HLO
    /// files, so the coordinator can train without `make artifacts`.
    /// PJRT builds keep the actionable "run make artifacts" error instead
    /// of failing later on fabricated entries whose HLO files don't exist.
    pub fn load_or_builtin(dir: &Path) -> anyhow::Result<Manifest> {
        if dir.join("manifest.json").exists() {
            Manifest::load(dir)
        } else if cfg!(feature = "pjrt") {
            anyhow::bail!(
                "no artifacts in {} — run `make artifacts` (or build without \
                 the `pjrt` feature to use the reference executor)",
                dir.display()
            )
        } else {
            Ok(Manifest::builtin(dir))
        }
    }

    /// Synthetic manifest mirroring the `python -m compile.aot` defaults:
    /// tiny (b=32, fanouts [3, 2]) plus the Table-4 datasets (b=256,
    /// fanouts [10, 5]), for gcn and sage, train and predict — plus a
    /// 3-layer SAGE tiny entry (fanouts [3, 2, 2], DistDGL's deeper
    /// recipe scaled down) and tiny entries for the gat/gin model
    /// families (the zoo's quickstart shapes). Entry `path`s point into
    /// `dir` but are not required to exist (reference backend).
    pub fn builtin(dir: &Path) -> Manifest {
        let mut entries = Vec::new();
        for model in ["gcn", "sage"] {
            for spec in crate::graph::datasets::REGISTRY.iter() {
                push_builtin(&mut entries, dir, model, spec.key, 256, &[10, 5], spec.dims);
            }
            let tiny = crate::graph::datasets::TINY;
            push_builtin(&mut entries, dir, model, tiny.key, 32, &[3, 2], tiny.dims);
        }
        let tiny = crate::graph::datasets::TINY;
        push_builtin(&mut entries, dir, "sage", tiny.key, 32, &[3, 2, 2], tiny.dims);
        for model in ["gat", "gin"] {
            push_builtin(&mut entries, dir, model, tiny.key, 32, &[3, 2], tiny.dims);
        }
        Manifest { dir: dir.to_path_buf(), entries }
    }

    /// Default artifacts directory: $HITGNN_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("HITGNN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

/// Append the train + predict builtin entries for one (model, dataset).
fn push_builtin(
    entries: &mut Vec<ArtifactEntry>,
    dir: &Path,
    model: &str,
    dataset: &str,
    b: usize,
    fanouts: &[usize],
    gd: GnnDims,
) {
    for kind in ["train", "predict"] {
        entries.push(synth_entry(dir, kind, model, dataset, b, fanouts, gd));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        Manifest::default_dir()
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.len() >= 4);
        let e = m.find("train", "gcn", "tiny").unwrap();
        assert_eq!(e.dims.b, 32);
        assert_eq!(e.params[0].0, "w1");
        assert_eq!(e.outputs[0], "loss");
        assert_eq!(e.param_elems(), 32 * 16 + 16 + 16 * 8 + 8);
        assert!(m.find("train", "gcn", "nonexistent").is_err());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn builtin_covers_all_models_and_datasets() {
        let m = Manifest::builtin(Path::new("/nonexistent"));
        // 2 models × (4 registry + tiny) × (train, predict) + the
        // 3-layer SAGE tiny pair + the gat/gin tiny pairs
        assert_eq!(m.entries.len(), 2 * 5 * 2 + 2 + 2 * 2);
        let e = m.find("train", "gcn", "tiny").unwrap();
        assert_eq!(e.dims.b, 32);
        assert_eq!(e.dims.caps[1], 32 * 3);
        assert_eq!(e.dims.caps[0], 32 * 3 * 4);
        assert_eq!(e.params[0], ("w1".to_string(), vec![32, 16]));
        assert_eq!(e.param_elems(), 32 * 16 + 16 + 16 * 8 + 8);
        let s = m.find("predict", "sage", "ogbn-products").unwrap();
        assert_eq!(s.params.len(), 6);
        assert_eq!(s.outputs, vec!["logits".to_string()]);
        assert_eq!(s.dims.f0(), 100);
    }

    #[test]
    fn builtin_has_gat_and_gin_tiny_entries_with_zoo_layouts() {
        let m = Manifest::builtin(Path::new("/nonexistent"));
        let g = m.find("train", "gat", "tiny").unwrap();
        // per layer: w [fin,fout], a_self [fout], a_nbr [fout], b [fout]
        assert_eq!(g.params.len(), 8);
        assert_eq!(g.params[0], ("w1".to_string(), vec![32, 16]));
        assert_eq!(g.params[1], ("a1_self".to_string(), vec![16]));
        assert_eq!(g.params[2], ("a1_nbr".to_string(), vec![16]));
        assert_eq!(g.params[7], ("b2".to_string(), vec![8]));
        assert!(g.outputs.iter().any(|o| o == "grad_a2_nbr"));
        let n = m.find("train", "gin", "tiny").unwrap();
        // per layer: w1 [fin,fout], b1 [fout], w2 [fout,fout], b2 [fout],
        // eps [1]
        assert_eq!(n.params.len(), 10);
        assert_eq!(n.params[0], ("w1_1".to_string(), vec![32, 16]));
        assert_eq!(n.params[2], ("w1_2".to_string(), vec![16, 16]));
        assert_eq!(n.params[4], ("eps1".to_string(), vec![1]));
        assert_eq!(n.params[9], ("eps2".to_string(), vec![1]));
        assert!(m.find("predict", "gat", "tiny").is_ok());
        assert!(m.find("predict", "gin", "tiny").is_ok());
        // gat/gin ship tiny-only: the Table-4 datasets stay gcn/sage
        assert!(m.find("train", "gat", "reddit").is_err());
    }

    #[test]
    #[should_panic(expected = "unknown model")]
    fn param_specs_panics_on_unvalidated_model_names() {
        let d = ArtifactDims::from_batch(4, &[2], &[8, 4]);
        let _ = param_specs("transformer", &d);
    }

    #[test]
    fn builtin_has_a_three_layer_sage_entry() {
        let m = Manifest::builtin(Path::new("/nonexistent"));
        // the plain find keeps returning the default-depth entry…
        assert_eq!(m.find("train", "sage", "tiny").unwrap().dims.layers(), 2);
        // …and the 3-layer one is reachable by exact fanouts
        let e = m.find_fanouts("train", "sage", "tiny", &[3, 2, 2]).unwrap();
        assert_eq!(e.name, "train_sage_tiny_l3");
        assert_eq!(e.dims.layers(), 3);
        assert_eq!(e.dims.caps, vec![32 * 3 * 3 * 4, 32 * 3 * 3, 32 * 3, 32]);
        assert_eq!(e.dims.f, vec![32, 16, 16, 8]);
        // SAGE: 3 params per layer, names suffixed per layer
        assert_eq!(e.params.len(), 9);
        assert_eq!(e.params[6].0, "w3_self");
        assert!(m.find_fanouts("train", "sage", "tiny", &[9, 9]).is_none());
        assert!(m.find_fanouts("predict", "sage", "tiny", &[3, 2, 2]).is_some());
    }

    #[test]
    fn load_or_builtin_prefers_real_manifest() {
        // missing dir → builtin (reference builds) / clean error (pjrt)
        let r = Manifest::load_or_builtin(Path::new("/nonexistent"));
        if cfg!(feature = "pjrt") {
            assert!(r.is_err());
        } else {
            assert!(r.unwrap().find("train", "sage", "reddit").is_ok());
        }
        // present but malformed manifest → strict error, no silent fallback
        let tmp = std::env::temp_dir().join(format!("hitgnn_lob_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), "{not json").unwrap();
        assert!(Manifest::load_or_builtin(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn rejects_bad_version_and_inconsistent_dims() {
        let tmp = std::env::temp_dir().join(format!("hitgnn_m_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), r#"{"version": 9, "entries": []}"#).unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::write(
            tmp.join("manifest.json"),
            r#"{"version": 1, "entries": [{"name":"x","kind":"train","model":"gcn",
                "dataset":"d","file":"x.hlo.txt","params":[],"outputs":[],
                "dims":{"b":4,"k1":2,"k2":2,"v1_cap":999,"v0_cap":36,
                        "f0":4,"f1":4,"f2":4}}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn parses_depth_l_dims_and_rejects_zero_fanouts() {
        // new-format dims parse and agree with the recurrence
        let j = Json::parse(
            r#"{"b": 8, "fanouts": [3, 2, 2], "f": [12, 16, 16, 5],
                "caps": [288, 72, 24, 8]}"#,
        )
        .unwrap();
        let d = ArtifactDims::from_json(&j).unwrap();
        assert_eq!(d.layers(), 3);
        assert_eq!(d.caps, vec![288, 72, 24, 8]);
        assert_eq!(d.fanout_config().fanouts, vec![3, 2, 2]);
        // wrong caps are rejected
        let j = Json::parse(r#"{"b": 8, "fanouts": [3], "f": [12, 5], "caps": [99, 8]}"#).unwrap();
        assert!(ArtifactDims::from_json(&j).is_err());
        // zero / empty fanouts are rejected at manifest load
        let j = Json::parse(r#"{"b": 8, "fanouts": [3, 0], "f": [12, 16, 5]}"#).unwrap();
        assert!(ArtifactDims::from_json(&j).is_err());
        let j = Json::parse(r#"{"b": 8, "fanouts": [], "f": [12]}"#).unwrap();
        assert!(ArtifactDims::from_json(&j).is_err());
        // legacy dims still parse
        let j = Json::parse(
            r#"{"b":4,"k1":1,"k2":1,"v1_cap":8,"v0_cap":16,"f0":4,"f1":4,"f2":4}"#,
        )
        .unwrap();
        let d = ArtifactDims::from_json(&j).unwrap();
        assert_eq!(d.fanouts, vec![1, 1]);
        assert_eq!(d.caps, vec![16, 8, 4]);
        assert_eq!(d.f, vec![4, 4, 4]);
    }
}
