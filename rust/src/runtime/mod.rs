//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the Rust hot path.
//!
//! Python is build-time only — this module reads `artifacts/manifest.json`
//! plus HLO *text* files, compiles them on the PJRT CPU client
//! (`HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile`), and wraps execution behind typed entry points
//! ([`executor::TrainExecutor`]).
//!
//! Thread model: the `xla` crate's handles hold raw pointers (`!Send`), so
//! each simulated-FPGA worker thread constructs its *own* client and
//! executable ([`executor`] is cheap to build: one text parse + compile at
//! startup) and communicates with the coordinator via channels of plain
//! `Vec<f32>` buffers.
//!
//! Backends: the PJRT path is behind the `pjrt` cargo feature; the default
//! build dispatches to the pure-Rust [`reference`] executor, which
//! implements the same model semantics without the `xla` crate or artifact
//! files (see DESIGN.md §Execution backends).

pub mod executor;
pub mod kernels;
pub mod manifest;
pub mod model_ops;
pub mod reference;
pub mod workspace;

pub use executor::{BatchBuffers, GradBuffers, StepOutput, TrainExecutor};
pub use manifest::{ArtifactDims, ArtifactEntry, Manifest};
pub use model_ops::{ops_for, validate_model, ModelOps, MODEL_NAMES};
pub use reference::RefModel;
pub use workspace::{LaneSpec, Workspace};
