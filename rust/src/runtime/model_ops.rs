//! The pluggable model-ops seam (DESIGN.md §Model zoo).
//!
//! Every GNN architecture the reference executor can train is a
//! [`ModelOps`] implementation: a stateless description of one layer's
//! forward and backward stages as a fixed sequence of
//! [`kernels`](super::kernels) calls over the [`Workspace`] arena. The
//! executor owns the loop structure (layer order, inter-layer relu,
//! loss); the ops own everything architecture-specific (which lanes
//! they touch, how many params a layer carries, which kernels fire in
//! which order). Adding a model means implementing this trait plus a
//! [`param_specs`](super::manifest::ArtifactEntry) arm — nothing in the
//! executor, sampler, or coordinator changes.
//!
//! Two hard invariants every impl must keep:
//!
//! - **Zero allocation**: the blocked `forward_layer`/`backward_layer`
//!   stages may only write into `Workspace` lanes declared by
//!   [`ModelOps::lane_spec`] and the caller-provided grad buffers. The
//!   full-iteration alloc audit runs against every registered model.
//! - **Fixed accumulation order**: the kernel sequence (and therefore
//!   the f32 rounding order) must not depend on row count, thread
//!   count, or batch content — the pipeline determinism law is sweep-
//!   tested per model.
//!
//! The `*_scalar` twins re-express the same math over the seed's
//! allocating scalar kernels and serve as the oracle for the
//! blocked/SIMD path (`blocked_path_matches_scalar_oracle` in
//! `reference.rs`).

use super::executor::BatchBuffers;
use super::kernels::{self, scalar};
use super::workspace::{LaneSpec, Workspace};

/// LeakyReLU slope of the GAT attention logits (the GAT paper's 0.2).
pub const LEAKY_SLOPE: f32 = 0.2;

/// Canonical model names, in the order they appear in sweeps, docs,
/// and the "expected one of" validation error.
pub const MODEL_NAMES: [&str; 4] = ["gcn", "sage", "gat", "gin"];

/// Resolve a model name to its ops table, or fail with the canonical
/// validation error ("unknown model 'X', expected one of ...").
pub fn ops_for(model: &str) -> anyhow::Result<&'static dyn ModelOps> {
    match model {
        "gcn" => Ok(&GcnOps),
        "sage" => Ok(&SageOps),
        "gat" => Ok(&GatOps),
        "gin" => Ok(&GinOps),
        other => anyhow::bail!(
            "unknown model '{other}', expected one of {}",
            MODEL_NAMES.join("|")
        ),
    }
}

/// Entry-point validation of a `--model` string (CLI, config, API):
/// same registry and error message as [`ops_for`], without exposing
/// the ops table.
pub fn validate_model(model: &str) -> anyhow::Result<()> {
    ops_for(model).map(|_| ())
}

/// Per-layer geometry handed to every stage. `n`/`below` are the row
/// counts actually processed at this level and the level beneath it —
/// the real (clamped) counts on the hot path, the full capacities on
/// the scalar-oracle and predict paths.
#[derive(Clone, Copy, Debug)]
pub struct LayerCtx {
    /// 1-based layer index.
    pub l: usize,
    /// Total layer count of the model instance.
    pub lcount: usize,
    /// Rows computed at level `l`.
    pub n: usize,
    /// Rows live at level `l - 1` (the gather source).
    pub below: usize,
    /// Padded neighbor-list width at this level (`fanouts[l-1] + 1`).
    pub k: usize,
    /// Input feature width.
    pub fin: usize,
    /// Output feature width.
    pub fout: usize,
}

/// Forward intermediates of one layer on the scalar-oracle path.
/// Unused lanes stay empty; each architecture fills exactly the lanes
/// its backward stage reads.
#[derive(Default)]
pub struct ScalarLayer {
    /// Aggregated neighborhood (gcn/sage; gin stores the full MLP input
    /// `sum + (1+eps)·self` here).
    pub agg: Vec<f32>,
    /// Self rows (sage concat half, gin eps path).
    pub selfr: Vec<f32>,
    /// Pre-activation output; the last layer's `z` is the logits.
    pub z: Vec<f32>,
    /// GAT: transformed features `hin · W` over the below-level rows.
    pub ht: Vec<f32>,
    /// GAT: per-edge attention weights.
    pub alpha: Vec<f32>,
    /// GAT: per-vertex self scores `ht · a_self`.
    pub sself: Vec<f32>,
    /// GAT: per-vertex neighbor scores `ht · a_nbr`.
    pub snbr: Vec<f32>,
    /// GIN: first MLP pre-activation.
    pub z1: Vec<f32>,
    /// GIN: first MLP activation.
    pub h1: Vec<f32>,
}

/// One GNN architecture's per-layer stages. Implementations are
/// stateless unit structs; `ops_for` hands out `&'static` instances.
///
/// Contracts shared by all stages: `pl`/`gl` are the layer's slice of
/// the flat param/grad lists (`params_per_layer` entries, ordered as in
/// `param_specs`); every grad buffer in `gl` is fully overwritten
/// (recycled buffers can never leak stale gradients); `hin` resolution
/// (`batch.feat0` at layer 1, the relu'd hidden lane below otherwise)
/// happens inside the stage so lane borrows stay field-disjoint.
pub trait ModelOps: Sync {
    /// Canonical model name (`MODEL_NAMES` entry).
    fn name(&self) -> &'static str;
    /// Parameters per layer (the `param_specs` arity).
    fn params_per_layer(&self) -> usize;
    /// Which workspace lanes this architecture needs allocated.
    fn lane_spec(&self) -> LaneSpec;
    /// Blocked/SIMD forward of layer `cx.l`: reads the layer input
    /// (feat0 or `ws.h[l-2]`), writes `ws.z[l-1]` (plus private lanes).
    fn forward_layer(&self, cx: &LayerCtx, pl: &[Vec<f32>], batch: &BatchBuffers, ws: &mut Workspace);
    /// Blocked/SIMD backward of layer `cx.l`: reads `ws.dz[l-1]` (the
    /// gradient at this layer's pre-activation), writes the layer's
    /// grads into `gl` and, for `l > 1`, the relu-masked input gradient
    /// into `ws.dz[l-2]`.
    fn backward_layer(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        batch: &BatchBuffers,
        ws: &mut Workspace,
        gl: &mut [Vec<f32>],
    );
    /// Scalar-oracle forward of layer `cx.l` (allocating).
    fn forward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        hin: &[f32],
        idx: &[i32],
        w: &[f32],
    ) -> ScalarLayer;
    /// Scalar-oracle backward of layer `cx.l`: fills `gl` and returns
    /// the input gradient over the below level (pre relu mask; empty
    /// for `l == 1` — the driver applies `relu_grad` against the
    /// below layer's stored pre-activation).
    #[allow(clippy::too_many_arguments)]
    fn backward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        fwd: &ScalarLayer,
        hin: &[f32],
        idx: &[i32],
        w: &[f32],
        dz: &[f32],
        gl: &mut [Vec<f32>],
    ) -> Vec<f32>;
}

/// GCN: mean-normalized aggregate (self folded into the weighted
/// neighbor list) followed by a dense update. Params per layer:
/// `w [fin,fout]`, `b [fout]`.
pub struct GcnOps;

impl ModelOps for GcnOps {
    fn name(&self) -> &'static str {
        "gcn"
    }

    fn params_per_layer(&self) -> usize {
        2
    }

    fn lane_spec(&self) -> LaneSpec {
        LaneSpec { agg: true, dx: true, ..Default::default() }
    }

    fn forward_layer(&self, cx: &LayerCtx, pl: &[Vec<f32>], batch: &BatchBuffers, ws: &mut Workspace) {
        let (l, n, k, fin, fout) = (cx.l, cx.n, cx.k, cx.fin, cx.fout);
        let (wl, bl) = (&pl[0], &pl[1]);
        let (idx, wv) = (&batch.idx[l - 1], &batch.w[l - 1]);
        {
            let hin: &[f32] = if l == 1 { &batch.feat0 } else { &ws.h[l - 2] };
            kernels::aggregate(&mut ws.agg[l - 1], hin, idx, wv, n, k, fin, false);
        }
        kernels::matmul_bias(&mut ws.z[l - 1], &ws.agg[l - 1], wl, bl, n, fin, fout);
    }

    fn backward_layer(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        batch: &BatchBuffers,
        ws: &mut Workspace,
        gl: &mut [Vec<f32>],
    ) {
        let (l, n, k, fin, fout, below) = (cx.l, cx.n, cx.k, cx.fin, cx.fout, cx.below);
        let wl = &pl[0];
        let (idx, wv) = (&batch.idx[l - 1], &batch.w[l - 1]);
        kernels::matmul_at_b(&mut gl[0], &ws.agg[l - 1], &ws.dz[l - 1], n, fin, fout);
        kernels::col_sums(&mut gl[1], &ws.dz[l - 1], n, fout);
        if l > 1 {
            kernels::matmul_b_t(&mut ws.dx[l - 1], &ws.dz[l - 1], wl, n, fout, fin);
            ws.dz[l - 2][..below * fin].fill(0.0);
            kernels::scatter_aggregate(&mut ws.dz[l - 2], &ws.dx[l - 1], idx, wv, n, k, fin, false);
            kernels::relu_mask(&mut ws.dz[l - 2], &ws.z[l - 2], below * fin);
        }
    }

    fn forward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        hin: &[f32],
        idx: &[i32],
        w: &[f32],
    ) -> ScalarLayer {
        let agg = scalar::aggregate(hin, idx, w, cx.n, cx.k, cx.fin, false);
        let z = scalar::matmul_bias(&agg, &pl[0], &pl[1], cx.n, cx.fin, cx.fout);
        ScalarLayer { agg, z, ..Default::default() }
    }

    fn backward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        fwd: &ScalarLayer,
        _hin: &[f32],
        idx: &[i32],
        w: &[f32],
        dz: &[f32],
        gl: &mut [Vec<f32>],
    ) -> Vec<f32> {
        gl[0] = scalar::matmul_at_b(&fwd.agg, dz, cx.n, cx.fin, cx.fout);
        gl[1] = scalar::col_sums(dz, cx.n, cx.fout);
        if cx.l > 1 {
            let dagg = scalar::matmul_b_t(dz, &pl[0], cx.n, cx.fout, cx.fin);
            let mut dh = vec![0.0f32; cx.below * cx.fin];
            scalar::scatter_aggregate(&mut dh, &dagg, idx, w, cx.n, cx.k, cx.fin, false);
            return dh;
        }
        Vec::new()
    }
}

/// GraphSAGE (mean variant): separate self and mean-of-neighbors
/// paths, concatenation expressed as two matmuls into the same output.
/// Params per layer: `w_self [fin,fout]`, `w_nbr [fin,fout]`,
/// `b [fout]`.
pub struct SageOps;

impl ModelOps for SageOps {
    fn name(&self) -> &'static str {
        "sage"
    }

    fn params_per_layer(&self) -> usize {
        3
    }

    fn lane_spec(&self) -> LaneSpec {
        LaneSpec { agg: true, selfr: true, dx: true, dx2: true, ..Default::default() }
    }

    fn forward_layer(&self, cx: &LayerCtx, pl: &[Vec<f32>], batch: &BatchBuffers, ws: &mut Workspace) {
        let (l, n, k, fin, fout) = (cx.l, cx.n, cx.k, cx.fin, cx.fout);
        let (wsf, wn, bl) = (&pl[0], &pl[1], &pl[2]);
        let (idx, wv) = (&batch.idx[l - 1], &batch.w[l - 1]);
        {
            let hin: &[f32] = if l == 1 { &batch.feat0 } else { &ws.h[l - 2] };
            kernels::aggregate_with_self(
                &mut ws.agg[l - 1],
                &mut ws.selfr[l - 1],
                hin,
                idx,
                wv,
                n,
                k,
                fin,
            );
        }
        kernels::matmul_bias(&mut ws.z[l - 1], &ws.selfr[l - 1], wsf, bl, n, fin, fout);
        kernels::add_matmul(&mut ws.z[l - 1], &ws.agg[l - 1], wn, n, fin, fout);
    }

    fn backward_layer(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        batch: &BatchBuffers,
        ws: &mut Workspace,
        gl: &mut [Vec<f32>],
    ) {
        let (l, n, k, fin, fout, below) = (cx.l, cx.n, cx.k, cx.fin, cx.fout, cx.below);
        let (wsf, wn) = (&pl[0], &pl[1]);
        let (idx, wv) = (&batch.idx[l - 1], &batch.w[l - 1]);
        kernels::matmul_at_b(&mut gl[0], &ws.selfr[l - 1], &ws.dz[l - 1], n, fin, fout);
        kernels::matmul_at_b(&mut gl[1], &ws.agg[l - 1], &ws.dz[l - 1], n, fin, fout);
        kernels::col_sums(&mut gl[2], &ws.dz[l - 1], n, fout);
        if l > 1 {
            kernels::matmul_b_t(&mut ws.dx[l - 1], &ws.dz[l - 1], wsf, n, fout, fin);
            kernels::matmul_b_t(&mut ws.dx2[l - 1], &ws.dz[l - 1], wn, n, fout, fin);
            ws.dz[l - 2][..below * fin].fill(0.0);
            kernels::scatter_self(&mut ws.dz[l - 2], &ws.dx[l - 1], idx, n, k, fin);
            kernels::scatter_aggregate(&mut ws.dz[l - 2], &ws.dx2[l - 1], idx, wv, n, k, fin, true);
            kernels::relu_mask(&mut ws.dz[l - 2], &ws.z[l - 2], below * fin);
        }
    }

    fn forward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        hin: &[f32],
        idx: &[i32],
        w: &[f32],
    ) -> ScalarLayer {
        let agg = scalar::aggregate(hin, idx, w, cx.n, cx.k, cx.fin, true);
        let selfr = scalar::take_rows(hin, idx, cx.n, cx.k, cx.fin);
        let mut z = scalar::matmul_bias(&selfr, &pl[0], &pl[2], cx.n, cx.fin, cx.fout);
        scalar::add_matmul(&mut z, &agg, &pl[1], cx.n, cx.fin, cx.fout);
        ScalarLayer { agg, selfr, z, ..Default::default() }
    }

    fn backward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        fwd: &ScalarLayer,
        _hin: &[f32],
        idx: &[i32],
        w: &[f32],
        dz: &[f32],
        gl: &mut [Vec<f32>],
    ) -> Vec<f32> {
        gl[0] = scalar::matmul_at_b(&fwd.selfr, dz, cx.n, cx.fin, cx.fout);
        gl[1] = scalar::matmul_at_b(&fwd.agg, dz, cx.n, cx.fin, cx.fout);
        gl[2] = scalar::col_sums(dz, cx.n, cx.fout);
        if cx.l > 1 {
            let dself = scalar::matmul_b_t(dz, &pl[0], cx.n, cx.fout, cx.fin);
            let dagg = scalar::matmul_b_t(dz, &pl[1], cx.n, cx.fout, cx.fin);
            let mut dh = vec![0.0f32; cx.below * cx.fin];
            scalar::scatter_self(&mut dh, &dself, idx, cx.n, cx.k, cx.fin);
            scalar::scatter_aggregate(&mut dh, &dagg, idx, w, cx.n, cx.k, cx.fin, true);
            return dh;
        }
        Vec::new()
    }
}

/// GAT (single head, GATv1): transform the below-level rows once
/// (`ht = hin · W`), score every vertex against the shared attention
/// vectors (`sself = ht·a_self`, `snbr = ht·a_nbr`), softmax the
/// LeakyReLU'd edge logits over each ragged neighbor list, and
/// aggregate `ht` with the attention weights. Params per layer:
/// `w [fin,fout]`, `a_self [fout]`, `a_nbr [fout]`, `b [fout]`.
///
/// The sampler's edge weights act purely as the real-vs-padding mask
/// ([`crate::sampling::WeightMode::Unit`]): attention replaces the
/// fixed normalization.
pub struct GatOps;

impl ModelOps for GatOps {
    fn name(&self) -> &'static str {
        "gat"
    }

    fn params_per_layer(&self) -> usize {
        4
    }

    fn lane_spec(&self) -> LaneSpec {
        LaneSpec { attention: true, ..Default::default() }
    }

    fn forward_layer(&self, cx: &LayerCtx, pl: &[Vec<f32>], batch: &BatchBuffers, ws: &mut Workspace) {
        let (l, n, k, fin, fout, below) = (cx.l, cx.n, cx.k, cx.fin, cx.fout, cx.below);
        let (wl, a_self, a_nbr, bl) = (&pl[0], &pl[1], &pl[2], &pl[3]);
        let (idx, wv) = (&batch.idx[l - 1], &batch.w[l - 1]);
        {
            let hin: &[f32] = if l == 1 { &batch.feat0 } else { &ws.h[l - 2] };
            let ht = &mut ws.att_ht[l - 1];
            ht[..below * fout].fill(0.0);
            kernels::add_matmul(ht, hin, wl, below, fin, fout);
        }
        kernels::matmul_b_t(&mut ws.att_sself[l - 1], &ws.att_ht[l - 1], a_self, below, fout, 1);
        kernels::matmul_b_t(&mut ws.att_snbr[l - 1], &ws.att_ht[l - 1], a_nbr, below, fout, 1);
        kernels::attn_edge_softmax(
            &mut ws.att_alpha[l - 1],
            &ws.att_sself[l - 1],
            &ws.att_snbr[l - 1],
            idx,
            wv,
            n,
            k,
            LEAKY_SLOPE,
        );
        kernels::aggregate(&mut ws.z[l - 1], &ws.att_ht[l - 1], idx, &ws.att_alpha[l - 1], n, k, fout, false);
        kernels::add_bias(&mut ws.z[l - 1], bl, n, fout);
    }

    fn backward_layer(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        batch: &BatchBuffers,
        ws: &mut Workspace,
        gl: &mut [Vec<f32>],
    ) {
        let (l, n, k, fin, fout, below) = (cx.l, cx.n, cx.k, cx.fin, cx.fout, cx.below);
        let (wl, a_self, a_nbr) = (&pl[0], &pl[1], &pl[2]);
        let idx = &batch.idx[l - 1];
        kernels::col_sums(&mut gl[3], &ws.dz[l - 1], n, fout);
        // ∂loss/∂alpha, then in place through softmax + LeakyReLU
        kernels::attn_edge_dot(
            &mut ws.att_dalpha[l - 1],
            &ws.dz[l - 1],
            &ws.att_ht[l - 1],
            idx,
            &ws.att_alpha[l - 1],
            n,
            k,
            fout,
        );
        kernels::attn_softmax_backward(
            &mut ws.att_dalpha[l - 1],
            &ws.att_alpha[l - 1],
            &ws.att_sself[l - 1],
            &ws.att_snbr[l - 1],
            idx,
            n,
            k,
            LEAKY_SLOPE,
        );
        // aggregation path: dht = alpha-weighted scatter of dz
        ws.att_dht[l - 1][..below * fout].fill(0.0);
        kernels::scatter_aggregate(
            &mut ws.att_dht[l - 1],
            &ws.dz[l - 1],
            idx,
            &ws.att_alpha[l - 1],
            n,
            k,
            fout,
            false,
        );
        // score path: the forward per-vertex scores are dead after the
        // softmax backward, so their lanes recycle as grad accumulators
        ws.att_sself[l - 1][..below].fill(0.0);
        ws.att_snbr[l - 1][..below].fill(0.0);
        kernels::attn_scatter_scores(
            &mut ws.att_sself[l - 1],
            &mut ws.att_snbr[l - 1],
            &ws.att_dalpha[l - 1],
            idx,
            n,
            k,
        );
        kernels::matmul_at_b(&mut gl[1], &ws.att_ht[l - 1], &ws.att_sself[l - 1], below, fout, 1);
        kernels::matmul_at_b(&mut gl[2], &ws.att_ht[l - 1], &ws.att_snbr[l - 1], below, fout, 1);
        kernels::add_matmul(&mut ws.att_dht[l - 1], &ws.att_sself[l - 1], a_self, below, 1, fout);
        kernels::add_matmul(&mut ws.att_dht[l - 1], &ws.att_snbr[l - 1], a_nbr, below, 1, fout);
        {
            let hin: &[f32] = if l == 1 { &batch.feat0 } else { &ws.h[l - 2] };
            kernels::matmul_at_b(&mut gl[0], hin, &ws.att_dht[l - 1], below, fin, fout);
        }
        if l > 1 {
            // the transform covers every below-level row, so the input
            // gradient is dense — no scatter, straight matmul
            kernels::matmul_b_t(&mut ws.dz[l - 2], &ws.att_dht[l - 1], wl, below, fout, fin);
            kernels::relu_mask(&mut ws.dz[l - 2], &ws.z[l - 2], below * fin);
        }
    }

    fn forward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        hin: &[f32],
        idx: &[i32],
        w: &[f32],
    ) -> ScalarLayer {
        let (nb, n, k, fin, fout) = (cx.below, cx.n, cx.k, cx.fin, cx.fout);
        let mut ht = vec![0.0f32; nb * fout];
        scalar::add_matmul(&mut ht, hin, &pl[0], nb, fin, fout);
        let sself = scalar::matmul_b_t(&ht, &pl[1], nb, fout, 1);
        let snbr = scalar::matmul_b_t(&ht, &pl[2], nb, fout, 1);
        let alpha = scalar::attn_edge_softmax(&sself, &snbr, idx, w, n, k, LEAKY_SLOPE);
        let mut z = scalar::aggregate(&ht, idx, &alpha, n, k, fout, false);
        kernels::add_bias(&mut z, &pl[3], n, fout);
        ScalarLayer { z, ht, alpha, sself, snbr, ..Default::default() }
    }

    fn backward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        fwd: &ScalarLayer,
        hin: &[f32],
        idx: &[i32],
        _w: &[f32],
        dz: &[f32],
        gl: &mut [Vec<f32>],
    ) -> Vec<f32> {
        let (nb, n, k, fin, fout) = (cx.below, cx.n, cx.k, cx.fin, cx.fout);
        gl[3] = scalar::col_sums(dz, n, fout);
        let mut dalpha = scalar::attn_edge_dot(dz, &fwd.ht, idx, &fwd.alpha, n, k, fout);
        kernels::attn_softmax_backward(
            &mut dalpha,
            &fwd.alpha,
            &fwd.sself,
            &fwd.snbr,
            idx,
            n,
            k,
            LEAKY_SLOPE,
        );
        let mut dht = vec![0.0f32; nb * fout];
        scalar::scatter_aggregate(&mut dht, dz, idx, &fwd.alpha, n, k, fout, false);
        let mut dsself = vec![0.0f32; nb];
        let mut dsnbr = vec![0.0f32; nb];
        kernels::attn_scatter_scores(&mut dsself, &mut dsnbr, &dalpha, idx, n, k);
        gl[1] = scalar::matmul_at_b(&fwd.ht, &dsself, nb, fout, 1);
        gl[2] = scalar::matmul_at_b(&fwd.ht, &dsnbr, nb, fout, 1);
        scalar::add_matmul(&mut dht, &dsself, &pl[1], nb, 1, fout);
        scalar::add_matmul(&mut dht, &dsnbr, &pl[2], nb, 1, fout);
        gl[0] = scalar::matmul_at_b(hin, &dht, nb, fin, fout);
        if cx.l > 1 {
            return scalar::matmul_b_t(&dht, &pl[0], nb, fout, fin);
        }
        Vec::new()
    }
}

/// GIN-ε: injective sum aggregation `s = Σ_nbr w·h + (1+ε)·h_self`
/// followed by a 2-layer MLP update (`relu` between the MLP layers,
/// widths `fin → fout → fout`). Params per layer: `w1 [fin,fout]`,
/// `b1 [fout]`, `w2 [fout,fout]`, `b2 [fout]`, `eps [1]` (trainable,
/// zero-initialized — GIN-0 at step 0).
pub struct GinOps;

impl ModelOps for GinOps {
    fn name(&self) -> &'static str {
        "gin"
    }

    fn params_per_layer(&self) -> usize {
        5
    }

    fn lane_spec(&self) -> LaneSpec {
        LaneSpec {
            agg: true,
            selfr: true,
            dx: true,
            dx_at_layer1: true,
            mlp: true,
            ..Default::default()
        }
    }

    fn forward_layer(&self, cx: &LayerCtx, pl: &[Vec<f32>], batch: &BatchBuffers, ws: &mut Workspace) {
        let (l, n, k, fin, fout) = (cx.l, cx.n, cx.k, cx.fin, cx.fout);
        let (w1, b1, w2, b2, eps) = (&pl[0], &pl[1], &pl[2], &pl[3], &pl[4]);
        let (idx, wv) = (&batch.idx[l - 1], &batch.w[l - 1]);
        {
            let hin: &[f32] = if l == 1 { &batch.feat0 } else { &ws.h[l - 2] };
            kernels::aggregate_with_self(
                &mut ws.agg[l - 1],
                &mut ws.selfr[l - 1],
                hin,
                idx,
                wv,
                n,
                k,
                fin,
            );
        }
        // agg becomes the full MLP input; selfr survives for ∂ε
        kernels::scaled_add(&mut ws.agg[l - 1], &ws.selfr[l - 1], 1.0 + eps[0], n * fin);
        kernels::matmul_bias(&mut ws.mlp_z1[l - 1], &ws.agg[l - 1], w1, b1, n, fin, fout);
        kernels::relu(&mut ws.mlp_h1[l - 1], &ws.mlp_z1[l - 1], n * fout);
        kernels::matmul_bias(&mut ws.z[l - 1], &ws.mlp_h1[l - 1], w2, b2, n, fout, fout);
    }

    fn backward_layer(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        batch: &BatchBuffers,
        ws: &mut Workspace,
        gl: &mut [Vec<f32>],
    ) {
        let (l, n, k, fin, fout, below) = (cx.l, cx.n, cx.k, cx.fin, cx.fout, cx.below);
        let (w1, w2, eps) = (&pl[0], &pl[2], &pl[4]);
        let (idx, wv) = (&batch.idx[l - 1], &batch.w[l - 1]);
        // second MLP layer
        kernels::matmul_at_b(&mut gl[2], &ws.mlp_h1[l - 1], &ws.dz[l - 1], n, fout, fout);
        kernels::col_sums(&mut gl[3], &ws.dz[l - 1], n, fout);
        kernels::matmul_b_t(&mut ws.mlp_dh1[l - 1], &ws.dz[l - 1], w2, n, fout, fout);
        kernels::relu_mask(&mut ws.mlp_dh1[l - 1], &ws.mlp_z1[l - 1], n * fout);
        // first MLP layer
        kernels::matmul_at_b(&mut gl[0], &ws.agg[l - 1], &ws.mlp_dh1[l - 1], n, fin, fout);
        kernels::col_sums(&mut gl[1], &ws.mlp_dh1[l - 1], n, fout);
        // gradient at the MLP input (the aggregated sum)
        kernels::matmul_b_t(&mut ws.dx[l - 1], &ws.mlp_dh1[l - 1], w1, n, fout, fin);
        gl[4][0] = kernels::dot(&ws.selfr[l - 1], &ws.dx[l - 1], n * fin);
        if l > 1 {
            ws.dz[l - 2][..below * fin].fill(0.0);
            kernels::scatter_aggregate(&mut ws.dz[l - 2], &ws.dx[l - 1], idx, wv, n, k, fin, true);
            kernels::scatter_self_scaled(
                &mut ws.dz[l - 2],
                &ws.dx[l - 1],
                idx,
                1.0 + eps[0],
                n,
                k,
                fin,
            );
            kernels::relu_mask(&mut ws.dz[l - 2], &ws.z[l - 2], below * fin);
        }
    }

    fn forward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        hin: &[f32],
        idx: &[i32],
        w: &[f32],
    ) -> ScalarLayer {
        let (n, k, fin, fout) = (cx.n, cx.k, cx.fin, cx.fout);
        let mut agg = scalar::aggregate(hin, idx, w, n, k, fin, true);
        let selfr = scalar::take_rows(hin, idx, n, k, fin);
        kernels::scaled_add(&mut agg, &selfr, 1.0 + pl[4][0], n * fin);
        let z1 = scalar::matmul_bias(&agg, &pl[0], &pl[1], n, fin, fout);
        let h1 = scalar::relu(&z1);
        let z = scalar::matmul_bias(&h1, &pl[2], &pl[3], n, fout, fout);
        ScalarLayer { agg, selfr, z, z1, h1, ..Default::default() }
    }

    fn backward_layer_scalar(
        &self,
        cx: &LayerCtx,
        pl: &[Vec<f32>],
        fwd: &ScalarLayer,
        _hin: &[f32],
        idx: &[i32],
        w: &[f32],
        dz: &[f32],
        gl: &mut [Vec<f32>],
    ) -> Vec<f32> {
        let (n, k, fin, fout) = (cx.n, cx.k, cx.fin, cx.fout);
        gl[2] = scalar::matmul_at_b(&fwd.h1, dz, n, fout, fout);
        gl[3] = scalar::col_sums(dz, n, fout);
        let dh1 = scalar::matmul_b_t(dz, &pl[2], n, fout, fout);
        let dh1 = scalar::relu_grad(&fwd.z1, &dh1);
        gl[0] = scalar::matmul_at_b(&fwd.agg, &dh1, n, fin, fout);
        gl[1] = scalar::col_sums(&dh1, n, fout);
        let dagg = scalar::matmul_b_t(&dh1, &pl[0], n, fout, fin);
        gl[4] = vec![kernels::dot(&fwd.selfr, &dagg, n * fin)];
        if cx.l > 1 {
            let mut dh = vec![0.0f32; cx.below * fin];
            scalar::scatter_aggregate(&mut dh, &dagg, idx, w, n, k, fin, true);
            kernels::scatter_self_scaled(&mut dh, &dagg, idx, 1.0 + pl[4][0], n, k, fin);
            return dh;
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_canonical_name() {
        for name in MODEL_NAMES {
            let ops = ops_for(name).unwrap();
            assert_eq!(ops.name(), name);
            assert!(ops.params_per_layer() >= 2);
        }
    }

    #[test]
    fn unknown_model_reports_the_expected_set() {
        let err = ops_for("transformer").unwrap_err().to_string();
        assert!(err.contains("unknown model 'transformer'"), "{err}");
        assert!(err.contains("expected one of gcn|sage|gat|gin"), "{err}");
        assert!(validate_model("gat").is_ok());
        assert!(validate_model("gsg").is_err());
    }

    #[test]
    fn lane_specs_cover_each_architectures_scratch_needs() {
        assert_eq!(
            GcnOps.lane_spec(),
            LaneSpec { agg: true, dx: true, ..Default::default() }
        );
        assert!(SageOps.lane_spec().dx2 && SageOps.lane_spec().selfr);
        assert!(GatOps.lane_spec().attention && !GatOps.lane_spec().agg);
        let gin = GinOps.lane_spec();
        assert!(gin.mlp && gin.dx_at_layer1 && gin.selfr);
    }
}
