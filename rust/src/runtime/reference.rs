//! Pure-Rust reference executor — the offline twin of the PJRT backend.
//!
//! Implements the exact L2 model semantics (`python/compile/model.py`)
//! for every architecture in the model zoo (`model_ops::MODEL_NAMES`:
//! gcn, sage, gat, gin) over the padded mini-batch wire format
//! (DESIGN.md §Mini-batch wire format) — including the backward pass
//! and the masked softmax cross-entropy loss. The executor owns only
//! the architecture-independent structure: the layer loop, the
//! inter-layer relu, the loss, and the row-count bookkeeping. Every
//! architecture-specific stage lives behind the
//! [`ModelOps`](super::model_ops::ModelOps) seam, so adding a model
//! touches `model_ops.rs` + `param_specs`, not this file. Depth comes
//! from the artifact's fanout vector; gradients are finite-difference-
//! checked at L ∈ {1, 2, 3} for all four models in the unit tests.
//! This lets the full coordinator pipeline (and its tests) run in
//! environments without the `xla` crate or AOT artifacts: build
//! without the `pjrt` feature and [`super::TrainExecutor`] dispatches
//! here.
//!
//! Hot path (DESIGN.md §Hot-path memory & kernels): every intermediate
//! lives in a per-instance [`Workspace`] (lanes selected by the model's
//! [`LaneSpec`](super::workspace::LaneSpec)) and the math runs on the
//! blocked, write-into kernels of [`super::kernels`] — no per-step heap
//! allocation beyond the gradient output, and training steps touch only
//! the batch's *real* row counts (`BatchBuffers::n`), not the padded
//! capacities. Padding rows are never observable: the wire format
//! guarantees no index references them and the loss mask excludes them,
//! so the restriction is semantics-preserving (the scalar oracle path
//! [`RefModel::train_step_scalar`], kept as the seed's full-capacity
//! implementation, pins this in the unit tests). Prediction keeps the
//! full-capacity sweep so its logits match compiled artifacts row for
//! row.
//!
//! Numerics are f32 loops with a fixed accumulation order, so a training
//! run is bit-reproducible — the property the pipeline determinism tests
//! (`tests/pipeline_determinism.rs`) assert per model.

use super::executor::{BatchBuffers, GradBuffers, StepOutput};
use super::kernels::{self, scalar};
use super::manifest::{param_specs, ArtifactDims, ArtifactEntry};
use super::model_ops::{ops_for, LayerCtx, ModelOps, ScalarLayer};
use super::workspace::Workspace;

/// Reference implementation of one artifact (train or predict).
pub struct RefModel {
    /// The architecture's per-layer stages (model zoo seam).
    ops: &'static dyn ModelOps,
    dims: ArtifactDims,
    /// Flat element count of each expected parameter tensor, in
    /// artifact order — sizes the recycled gradient buffers.
    param_lens: Vec<usize>,
    /// Pre-sized scratch arena owning every per-step intermediate.
    ws: Workspace,
}

impl RefModel {
    /// Validate the entry against the known model architectures. Mirrors
    /// what PJRT compilation catches (shape mismatches fail at compile
    /// time, not mid-epoch).
    pub fn new(entry: &ArtifactEntry) -> anyhow::Result<RefModel> {
        let ops = ops_for(&entry.model)?;
        let d = entry.dims.clone();
        let expect = param_specs(&entry.model, &d);
        let layout = || {
            expect
                .iter()
                .map(|(n, s)| format!("{n}{s:?}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        anyhow::ensure!(
            entry.params.len() == expect.len(),
            "artifact '{}' has {} params, {}-layer {} model needs {} — expected layout: [{}]",
            entry.name,
            entry.params.len(),
            d.layers(),
            entry.model,
            expect.len(),
            layout()
        );
        for ((name, shape), (ename, eshape)) in entry.params.iter().zip(&expect) {
            anyhow::ensure!(
                name == ename && shape == eshape,
                "artifact '{}' param {name}{shape:?} != expected {ename}{eshape:?} \
                 — expected layout: [{}]",
                entry.name,
                layout()
            );
        }
        let param_lens = expect.iter().map(|(_, s)| s.iter().product()).collect();
        let ws = Workspace::new(&d, ops.lane_spec());
        Ok(RefModel { ops, dims: d, param_lens, ws })
    }

    /// Canonical name of the architecture this instance runs.
    pub fn model(&self) -> &'static str {
        self.ops.name()
    }

    /// Geometry of layer `l` on the hot path (real row counts).
    fn layer_ctx(&self, l: usize) -> LayerCtx {
        LayerCtx {
            l,
            lcount: self.dims.layers(),
            n: self.ws.rows[l],
            below: self.ws.rows[l - 1],
            k: self.dims.fanouts[l - 1] + 1,
            fin: self.dims.f[l - 1],
            fout: self.dims.f[l],
        }
    }

    /// Geometry of layer `l` on the scalar-oracle path (full padded
    /// capacities, the seed's sweep).
    fn scalar_ctx(&self, l: usize) -> LayerCtx {
        LayerCtx {
            l,
            lcount: self.dims.layers(),
            n: self.dims.caps[l],
            below: self.dims.caps[l - 1],
            k: self.dims.fanouts[l - 1] + 1,
            fin: self.dims.f[l - 1],
            fout: self.dims.f[l],
        }
    }

    /// Set the per-level rows the next step computes: the batch's `n`
    /// clamped to the capacities, or the full capacities when the caller
    /// did not carry counts (legacy construction — full-padding sweep,
    /// still correct). Writes the workspace's `rows` lane in place.
    fn set_rows(&mut self, batch: &BatchBuffers) {
        let d = &self.dims;
        let ws = &mut self.ws;
        if batch.n.len() == d.caps.len() {
            for (r, (&n, &c)) in ws.rows.iter_mut().zip(batch.n.iter().zip(&d.caps)) {
                *r = n.min(c);
            }
        } else {
            ws.rows.copy_from_slice(&d.caps);
        }
    }

    /// Forward + backward + masked CE loss (train artifacts). Allocating
    /// wrapper over [`RefModel::train_step_into`].
    pub fn train_step(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<StepOutput> {
        let mut grads = GradBuffers::empty();
        let loss = self.train_step_into(params, batch, &mut grads)?;
        Ok(StepOutput { loss, grads })
    }

    /// Forward + backward + masked CE loss, writing the gradients into a
    /// recycled [`GradBuffers`]: sized on first use, allocation-free on
    /// every reuse (the backward stages fully overwrite each tensor, so
    /// stale contents cannot leak).
    pub fn train_step_into(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
        grads: &mut GradBuffers,
    ) -> anyhow::Result<f32> {
        self.set_rows(batch);
        self.forward(params, batch);
        let loss = self.loss_and_dlogits(batch);
        self.backward_into(params, batch, grads);
        Ok(loss)
    }

    /// Forward only (predict artifacts) → logits `[b, classes]`. Runs the
    /// full-capacity sweep so padding rows carry the same bias-propagated
    /// values a compiled artifact produces.
    pub fn predict(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<Vec<f32>> {
        self.ws.rows.copy_from_slice(&self.dims.caps);
        self.forward(params, batch);
        Ok(self.ws.z[self.dims.layers() - 1].clone())
    }

    // -- forward -----------------------------------------------------------

    /// L model-ops stages over the first `ws.rows[l]` rows per level;
    /// relu between layers, linear output (`z[L-1]` is the logits).
    fn forward(&mut self, params: &[Vec<f32>], batch: &BatchBuffers) {
        let ops = self.ops;
        let ppl = ops.params_per_layer();
        let lcount = self.dims.layers();
        for l in 1..=lcount {
            let cx = self.layer_ctx(l);
            ops.forward_layer(&cx, &params[ppl * (l - 1)..ppl * l], batch, &mut self.ws);
            if l < lcount {
                kernels::relu(&mut self.ws.h[l - 1], &self.ws.z[l - 1], cx.n * cx.fout);
            }
        }
    }

    /// Masked mean softmax cross-entropy over the computed logits, with
    /// dlogits written into `ws.dz[L-1]` (fully zeroed first, so padding
    /// target rows contribute nothing to the backward pass).
    fn loss_and_dlogits(&mut self, batch: &BatchBuffers) -> f32 {
        let d = &self.dims;
        let ws = &mut self.ws;
        let lcount = d.layers();
        let classes = d.classes();
        let n_t = ws.rows[lcount].min(d.b);
        let denom = batch.mask.iter().sum::<f32>().max(1.0);
        let logits = &ws.z[lcount - 1];
        let dl = &mut ws.dz[lcount - 1];
        dl.fill(0.0);
        let mut loss = 0.0f32;
        for r in 0..n_t {
            let mk = batch.mask[r];
            if mk == 0.0 {
                continue;
            }
            let row = &logits[r * classes..(r + 1) * classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sumexp: f32 = row.iter().map(|&x| (x - max).exp()).sum();
            let logz = max + sumexp.ln();
            let label = batch.labels[r] as usize;
            loss += mk * (logz - row[label]);
            let scale = mk / denom;
            for j in 0..classes {
                let softmax = (row[j] - max).exp() / sumexp;
                let onehot = if j == label { 1.0 } else { 0.0 };
                dl[r * classes + j] = scale * (softmax - onehot);
            }
        }
        loss / denom
    }

    // -- backward ----------------------------------------------------------

    /// Transposed model-ops stages, layer L down to 1. `ws.dz[L-1]` must
    /// hold the dlogits on entry; gradients land in `grads` in artifact
    /// parameter order, each tensor sized to its `param_specs` shape.
    /// Every tensor is fully overwritten by its stage, so recycled
    /// buffers carry nothing across steps.
    fn backward_into(&mut self, params: &[Vec<f32>], batch: &BatchBuffers, grads: &mut GradBuffers) {
        let ops = self.ops;
        let ppl = ops.params_per_layer();
        let lcount = self.dims.layers();
        let lens = &self.param_lens;
        grads.resize_with(lens.len(), |gi| lens[gi]);
        for l in (1..=lcount).rev() {
            let cx = self.layer_ctx(l);
            ops.backward_layer(
                &cx,
                &params[ppl * (l - 1)..ppl * l],
                batch,
                &mut self.ws,
                &mut grads[ppl * (l - 1)..ppl * l],
            );
        }
    }

    // -- scalar oracle path ------------------------------------------------

    /// The seed's scalar, allocation-per-call implementation over the full
    /// padded capacities — kept as the numerics oracle for the blocked
    /// path (unit tests) and as the baseline of the `micro_host` kernel
    /// sweep. Semantically identical to [`RefModel::train_step`].
    pub fn train_step_scalar(
        &self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<StepOutput> {
        let layers = self.forward_scalar(params, batch);
        let d = &self.dims;
        let classes = d.classes();
        let denom = batch.mask.iter().sum::<f32>().max(1.0);
        let logits = &layers[d.layers() - 1].z;

        // masked mean softmax cross-entropy and dlogits in one pass
        let mut loss = 0.0f32;
        let mut dlogits = vec![0.0f32; d.b * classes];
        for r in 0..d.b {
            let mk = batch.mask[r];
            if mk == 0.0 {
                continue;
            }
            let row = &logits[r * classes..(r + 1) * classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sumexp: f32 = row.iter().map(|&x| (x - max).exp()).sum();
            let logz = max + sumexp.ln();
            let label = batch.labels[r] as usize;
            loss += mk * (logz - row[label]);
            let scale = mk / denom;
            for j in 0..classes {
                let softmax = (row[j] - max).exp() / sumexp;
                let onehot = if j == label { 1.0 } else { 0.0 };
                dlogits[r * classes + j] = scale * (softmax - onehot);
            }
        }
        loss /= denom;

        let grads = self.backward_scalar(params, batch, &layers, &dlogits);
        Ok(StepOutput { loss, grads: grads.into() })
    }

    /// L model-ops stages over the full capacities (scalar oracle).
    fn forward_scalar(&self, params: &[Vec<f32>], batch: &BatchBuffers) -> Vec<ScalarLayer> {
        let ops = self.ops;
        let ppl = ops.params_per_layer();
        let lcount = self.dims.layers();
        let mut layers: Vec<ScalarLayer> = Vec::with_capacity(lcount);
        let mut h: Vec<f32> = Vec::new();
        for l in 1..=lcount {
            let cx = self.scalar_ctx(l);
            let hin: &[f32] = if l == 1 { &batch.feat0 } else { &h };
            let sl = ops.forward_layer_scalar(
                &cx,
                &params[ppl * (l - 1)..ppl * l],
                hin,
                &batch.idx[l - 1],
                &batch.w[l - 1],
            );
            if l < lcount {
                h = scalar::relu(&sl.z);
            }
            layers.push(sl);
        }
        layers
    }

    /// Transposed model-ops stages over the full capacities (scalar
    /// oracle). The layer input is recomputed from the stored
    /// pre-activations (`relu(z[l-2])`) for the stages that need it.
    fn backward_scalar(
        &self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
        layers: &[ScalarLayer],
        dlogits: &[f32],
    ) -> Vec<Vec<f32>> {
        let ops = self.ops;
        let ppl = ops.params_per_layer();
        let lcount = self.dims.layers();
        let mut grads: Vec<Vec<f32>> = vec![Vec::new(); self.param_lens.len()];
        let mut dz = dlogits.to_vec();
        for l in (1..=lcount).rev() {
            let cx = self.scalar_ctx(l);
            let hin_buf;
            let hin: &[f32] = if l == 1 {
                &batch.feat0
            } else {
                hin_buf = scalar::relu(&layers[l - 2].z);
                &hin_buf
            };
            let dh = ops.backward_layer_scalar(
                &cx,
                &params[ppl * (l - 1)..ppl * l],
                &layers[l - 1],
                hin,
                &batch.idx[l - 1],
                &batch.w[l - 1],
                &dz,
                &mut grads[ppl * (l - 1)..ppl * l],
            );
            if l > 1 {
                dz = scalar::relu_grad(&layers[l - 2].z, &dh);
            }
        }
        grads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::synth_entry;
    use crate::runtime::model_ops::MODEL_NAMES;
    use crate::runtime::Manifest;
    use crate::util::rng::Rng;

    fn tiny_entry(model: &str, kind: &str) -> ArtifactEntry {
        Manifest::builtin(std::path::Path::new("/tmp"))
            .find(kind, model, "tiny")
            .unwrap()
            .clone()
    }

    /// Synthetic entry at an arbitrary depth (b=8 keeps the fd check fast).
    fn depth_entry(model: &str, fanouts: &[usize]) -> ArtifactEntry {
        let gd = crate::graph::GnnDims { f0: 12, f1: 10, f2: 5 };
        synth_entry(std::path::Path::new("/tmp"), "train", model, "tiny", 8, fanouts, gd)
    }

    fn random_batch(d: &ArtifactDims, seed: u64) -> BatchBuffers {
        let mut rng = Rng::new(seed);
        let lcount = d.layers();
        let classes = d.classes();
        // a self-consistent random padded batch: n real rows per level
        let n: Vec<usize> = d.caps.iter().map(|&c| (c / 2).max(1)).collect();
        let feat0: Vec<f32> = (0..d.caps[0] * d.f[0]).map(|_| rng.f32() - 0.5).collect();
        let mut idx = Vec::with_capacity(lcount);
        let mut w = Vec::with_capacity(lcount);
        for l in 1..=lcount {
            let k = d.fanouts[l - 1] + 1;
            let mut il = vec![0i32; d.caps[l] * k];
            let mut wl = vec![0f32; d.caps[l] * k];
            for r in 0..n[l] {
                for c in 0..k {
                    il[r * k + c] = rng.index(n[l - 1]) as i32;
                    wl[r * k + c] = rng.f32();
                }
            }
            idx.push(il);
            w.push(wl);
        }
        let labels: Vec<i32> = (0..d.b).map(|_| rng.index(classes) as i32).collect();
        let mut mask = vec![0f32; d.b];
        for m in mask.iter_mut().take(n[lcount]) {
            *m = 1.0;
        }
        BatchBuffers { feat0, idx, w, labels, mask, n }
    }

    /// [`crate::coordinator::params::ParamSet::init`] zero-initializes
    /// every rank-1 tensor, which for the attention models puts every
    /// LeakyReLU logit exactly on its kink — poison for a central-
    /// difference check. Perturb all params to small random values.
    fn random_params(entry: &ArtifactEntry, seed: u64) -> Vec<Vec<f32>> {
        let mut params = crate::coordinator::params::ParamSet::init(entry, seed).data;
        let mut rng = Rng::new(seed ^ 0x5eed);
        for p in params.iter_mut() {
            for v in p.iter_mut() {
                *v += 0.2 * (rng.f32() - 0.5);
            }
        }
        params
    }

    fn loss_of(model: &mut RefModel, params: &[Vec<f32>], batch: &BatchBuffers) -> f64 {
        model.train_step(params, batch).unwrap().loss as f64
    }

    /// Central-difference gradient check: the analytic backward pass must
    /// match numerical differentiation on sampled coordinates. Runs on
    /// the blocked workspace path.
    fn grad_check_with(entry: &ArtifactEntry, params: &[Vec<f32>], tag: &str) {
        let mut model = RefModel::new(entry).unwrap();
        let batch = random_batch(&entry.dims, 4);
        let out = model.train_step(params, &batch).unwrap();
        let mut rng = Rng::new(77);
        let eps = 1e-3f32;
        let mut checked = 0;
        for (pi, p) in params.iter().enumerate() {
            for _ in 0..4 {
                let i = rng.index(p.len());
                let mut plus = params.to_vec();
                plus[pi][i] += eps;
                let mut minus = params.to_vec();
                minus[pi][i] -= eps;
                let num = (loss_of(&mut model, &plus, &batch)
                    - loss_of(&mut model, &minus, &batch))
                    / (2.0 * eps as f64);
                let ana = out.grads[pi][i] as f64;
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                    "{tag} param {pi}[{i}]: numeric {num} vs analytic {ana}"
                );
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    fn grad_check_entry(entry: &ArtifactEntry, tag: &str) {
        let params = crate::coordinator::params::ParamSet::init(entry, 9).data;
        grad_check_with(entry, &params, tag);
    }

    fn grad_check(model_name: &str) {
        grad_check_entry(&tiny_entry(model_name, "train"), model_name);
    }

    #[test]
    fn gcn_gradients_match_finite_differences() {
        grad_check("gcn");
    }

    #[test]
    fn sage_gradients_match_finite_differences() {
        grad_check("sage");
    }

    #[test]
    fn gradients_match_finite_differences_at_depths_one_and_three() {
        for model in ["gcn", "sage"] {
            for fanouts in [vec![3usize], vec![3, 2, 2]] {
                let entry = depth_entry(model, &fanouts);
                grad_check_entry(&entry, &format!("{model} L={}", fanouts.len()));
            }
        }
    }

    #[test]
    fn gat_and_gin_gradients_match_finite_differences_at_depths_one_two_three() {
        // the new model families, fd-checked at every supported depth —
        // with random non-zero attention vectors / eps (see
        // random_params on why zero init is hostile to fd here)
        for model in ["gat", "gin"] {
            for fanouts in [vec![3usize], vec![3, 2], vec![3, 2, 2]] {
                let entry = depth_entry(model, &fanouts);
                let params = random_params(&entry, 21);
                grad_check_with(&entry, &params, &format!("{model} L={}", fanouts.len()));
            }
        }
    }

    #[test]
    fn builtin_three_layer_sage_entry_gradcheck() {
        // the manifest's shipped 3-layer artifact, end to end through the
        // same validation path the trainer uses
        let m = Manifest::builtin(std::path::Path::new("/tmp"));
        let entry = m.find_fanouts("train", "sage", "tiny", &[3, 2, 2]).unwrap().clone();
        grad_check_entry(&entry, "builtin sage l3");
    }

    #[test]
    fn blocked_path_matches_scalar_oracle_at_depths_one_two_three() {
        // ISSUE 5 tentpole guard, swept across the model zoo: the
        // workspace/blocked executor must be numerically interchangeable
        // with the seed's scalar path on every model family at every
        // supported depth — identical loss and gradients within
        // FP-reassociation tolerance.
        for model_name in MODEL_NAMES {
            for fanouts in [vec![3usize], vec![3, 2], vec![3, 2, 2]] {
                let entry = depth_entry(model_name, &fanouts);
                let mut model = RefModel::new(&entry).unwrap();
                let params = match model_name {
                    "gat" | "gin" => random_params(&entry, 5),
                    _ => crate::coordinator::params::ParamSet::init(&entry, 5).data,
                };
                let batch = random_batch(&entry.dims, 11);
                let blocked = model.train_step(&params, &batch).unwrap();
                let oracle = model.train_step_scalar(&params, &batch).unwrap();
                let tag = format!("{model_name} L={}", fanouts.len());
                let lscale = 1.0 + oracle.loss.abs();
                assert!(
                    (blocked.loss - oracle.loss).abs() < 1e-5 * lscale,
                    "{tag}: loss {} vs oracle {}",
                    blocked.loss,
                    oracle.loss
                );
                assert_eq!(blocked.grads.len(), oracle.grads.len(), "{tag}");
                for (pi, (g, og)) in blocked.grads.iter().zip(&oracle.grads).enumerate() {
                    assert_eq!(g.len(), og.len(), "{tag} param {pi}");
                    for (i, (a, b)) in g.iter().zip(og).enumerate() {
                        let scale = 1.0 + a.abs().max(b.abs());
                        assert!(
                            (a - b).abs() < 1e-4 * scale,
                            "{tag} grad {pi}[{i}]: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn loss_is_masked_mean_ce() {
        let entry = tiny_entry("gcn", "train");
        let mut model = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 2).data;
        let batch = random_batch(&entry.dims, 6);
        let out = model.train_step(&params, &batch).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        // all-zero mask: loss 0, grads 0
        let mut b2 = batch;
        b2.mask.iter_mut().for_each(|m| *m = 0.0);
        let out2 = model.train_step(&params, &b2).unwrap();
        assert_eq!(out2.loss, 0.0);
        assert!(out2.grads.iter().flatten().all(|&g| g == 0.0));
    }

    #[test]
    fn rejects_unknown_model_and_bad_shapes() {
        let mut entry = tiny_entry("gcn", "train");
        entry.model = "transformer".into();
        let err = RefModel::new(&entry).unwrap_err().to_string();
        assert!(err.contains("unknown model 'transformer'"), "{err}");
        assert!(err.contains("expected one of gcn|sage|gat|gin"), "{err}");
        let mut entry = tiny_entry("gcn", "train");
        entry.params[0].1 = vec![1, 1];
        assert!(RefModel::new(&entry).is_err());
        // a 3-layer entry with a 2-layer parameter list is caught
        let mut entry = depth_entry("gcn", &[3, 2, 2]);
        entry.params.truncate(4);
        assert!(RefModel::new(&entry).is_err());
    }

    #[test]
    fn param_mismatch_errors_report_the_expected_layout() {
        // satellite of ISSUE 8: the first thing a user wiring a new model
        // hits must spell out the per-layer names + shapes, not counts
        let mut entry = depth_entry("gin", &[3]);
        entry.params.truncate(2);
        let count_err = RefModel::new(&entry).unwrap_err().to_string();
        assert!(count_err.contains("expected layout"), "{count_err}");
        assert!(count_err.contains("eps1[1]"), "{count_err}");
        let mut entry = tiny_entry("gcn", "train");
        entry.params[0].1 = vec![1, 1];
        let shape_err = RefModel::new(&entry).unwrap_err().to_string();
        assert!(shape_err.contains("expected layout"), "{shape_err}");
        assert!(shape_err.contains("!= expected"), "{shape_err}");
    }

    #[test]
    fn deterministic_bitwise() {
        for model_name in MODEL_NAMES {
            let entry = tiny_entry(model_name, "train");
            let mut model = RefModel::new(&entry).unwrap();
            assert_eq!(model.model(), model_name);
            let params = random_params(&entry, 5);
            let batch = random_batch(&entry.dims, 8);
            let a = model.train_step(&params, &batch).unwrap();
            let b = model.train_step(&params, &batch).unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{model_name}");
            assert_eq!(a.grads, b.grads, "{model_name}");
        }
    }

    #[test]
    fn recycled_workspace_cannot_leak_between_batches() {
        // two different batches alternated through one model instance:
        // results must match a fresh instance's on every step (the
        // workspace is fully overwritten per step over the live region).
        // Swept over the zoo — the attention/MLP lanes and their
        // in-place recycling are exactly where stale state would hide.
        for model_name in MODEL_NAMES {
            let entry = tiny_entry(model_name, "train");
            let mut reused = RefModel::new(&entry).unwrap();
            let params = random_params(&entry, 5);
            let batches = [random_batch(&entry.dims, 8), random_batch(&entry.dims, 9)];
            // dirty the workspace AND the recycled gradient buffers with
            // batch 1 first, then replay both
            let mut grads = GradBuffers::empty();
            let _ = reused.train_step_into(&params, &batches[1], &mut grads).unwrap();
            for b in &batches {
                let mut fresh = RefModel::new(&entry).unwrap();
                let want = fresh.train_step(&params, b).unwrap();
                let loss = reused.train_step_into(&params, b, &mut grads).unwrap();
                assert_eq!(loss.to_bits(), want.loss.to_bits(), "{model_name}");
                assert_eq!(grads, want.grads, "{model_name}");
            }
        }
    }
}
