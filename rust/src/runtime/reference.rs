//! Pure-Rust reference executor — the offline twin of the PJRT backend.
//!
//! Implements the exact L2 model semantics (`python/compile/model.py`) for
//! the two shipped model families — L-layer GCN and GraphSAGE-mean over
//! the padded mini-batch wire format (DESIGN.md §Mini-batch wire format)
//! — including the backward pass and the masked softmax cross-entropy
//! loss. Depth comes from the artifact's fanout vector; each layer is one
//! aggregate→update stage forward and the transposed pair backward, so
//! the executor prices any L ≥ 1 (gradients are finite-difference-checked
//! at L ∈ {1, 2, 3} in the unit tests). This lets the full coordinator
//! pipeline (and its tests) run in environments without the `xla` crate
//! or AOT artifacts: build without the `pjrt` feature and
//! [`super::TrainExecutor`] dispatches here.
//!
//! Hot path (DESIGN.md §Hot-path memory & kernels): every intermediate
//! lives in a per-instance [`Workspace`] and the math runs on the
//! blocked, write-into kernels of [`super::kernels`] — no per-step heap
//! allocation beyond the gradient output, and training steps touch only
//! the batch's *real* row counts (`BatchBuffers::n`), not the padded
//! capacities. Padding rows are never observable: the wire format
//! guarantees no index references them and the loss mask excludes them,
//! so the restriction is semantics-preserving (the scalar oracle path
//! [`RefModel::train_step_scalar`], kept as the seed's full-capacity
//! implementation, pins this in the unit tests). Prediction keeps the
//! full-capacity sweep so its logits match compiled artifacts row for
//! row.
//!
//! Numerics are f32 loops with a fixed accumulation order, so a training
//! run is bit-reproducible — the property the pipeline determinism tests
//! (`tests/pipeline_determinism.rs`) assert.

use super::executor::{BatchBuffers, GradBuffers, StepOutput};
use super::kernels::{self, scalar};
use super::manifest::{param_specs, ArtifactDims, ArtifactEntry};
use super::workspace::Workspace;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ModelKind {
    Gcn,
    Sage,
}

/// Reference implementation of one artifact (train or predict).
pub struct RefModel {
    kind: ModelKind,
    dims: ArtifactDims,
    /// Pre-sized scratch arena owning every per-step intermediate.
    ws: Workspace,
}

impl RefModel {
    /// Validate the entry against the known model architectures. Mirrors
    /// what PJRT compilation catches (shape mismatches fail at compile
    /// time, not mid-epoch).
    pub fn new(entry: &ArtifactEntry) -> anyhow::Result<RefModel> {
        let kind = match entry.model.as_str() {
            "gcn" => ModelKind::Gcn,
            "sage" => ModelKind::Sage,
            other => anyhow::bail!(
                "reference executor supports gcn|sage, not '{other}' \
                 (enable the `pjrt` feature for arbitrary HLO artifacts)"
            ),
        };
        let d = entry.dims.clone();
        let expect = param_specs(&entry.model, &d);
        anyhow::ensure!(
            entry.params.len() == expect.len(),
            "artifact '{}' has {} params, {}-layer {} model needs {}",
            entry.name,
            entry.params.len(),
            d.layers(),
            entry.model,
            expect.len()
        );
        for ((name, shape), (ename, eshape)) in entry.params.iter().zip(&expect) {
            anyhow::ensure!(
                name == ename && shape == eshape,
                "artifact '{}' param {name}{shape:?} != expected {ename}{eshape:?}",
                entry.name
            );
        }
        let ws = Workspace::new(&d, kind == ModelKind::Sage);
        Ok(RefModel { kind, dims: d, ws })
    }

    /// Parameters-per-layer of this model kind.
    fn ppl(&self) -> usize {
        match self.kind {
            ModelKind::Gcn => 2,
            ModelKind::Sage => 3,
        }
    }

    /// Set the per-level rows the next step computes: the batch's `n`
    /// clamped to the capacities, or the full capacities when the caller
    /// did not carry counts (legacy construction — full-padding sweep,
    /// still correct). Writes the workspace's `rows` lane in place.
    fn set_rows(&mut self, batch: &BatchBuffers) {
        let d = &self.dims;
        let ws = &mut self.ws;
        if batch.n.len() == d.caps.len() {
            for (r, (&n, &c)) in ws.rows.iter_mut().zip(batch.n.iter().zip(&d.caps)) {
                *r = n.min(c);
            }
        } else {
            ws.rows.copy_from_slice(&d.caps);
        }
    }

    /// Forward + backward + masked CE loss (train artifacts). Allocating
    /// wrapper over [`RefModel::train_step_into`].
    pub fn train_step(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<StepOutput> {
        let mut grads = GradBuffers::empty();
        let loss = self.train_step_into(params, batch, &mut grads)?;
        Ok(StepOutput { loss, grads })
    }

    /// Forward + backward + masked CE loss, writing the gradients into a
    /// recycled [`GradBuffers`]: sized on first use, allocation-free on
    /// every reuse (the backward kernels fully overwrite each tensor, so
    /// stale contents cannot leak).
    pub fn train_step_into(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
        grads: &mut GradBuffers,
    ) -> anyhow::Result<f32> {
        self.set_rows(batch);
        self.forward(params, batch);
        let loss = self.loss_and_dlogits(batch);
        self.backward_into(params, batch, grads);
        Ok(loss)
    }

    /// Forward only (predict artifacts) → logits `[b, classes]`. Runs the
    /// full-capacity sweep so padding rows carry the same bias-propagated
    /// values a compiled artifact produces.
    pub fn predict(
        &mut self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<Vec<f32>> {
        self.ws.rows.copy_from_slice(&self.dims.caps);
        self.forward(params, batch);
        Ok(self.ws.z[self.dims.layers() - 1].clone())
    }

    // -- forward -----------------------------------------------------------

    /// L aggregate→update stages over the first `ws.rows[l]` rows per
    /// level; relu between layers, linear output (`z[L-1]` is the logits).
    fn forward(&mut self, params: &[Vec<f32>], batch: &BatchBuffers) {
        let ppl = self.ppl();
        let kind = self.kind;
        let d = &self.dims;
        let ws = &mut self.ws;
        let lcount = d.layers();
        for l in 1..=lcount {
            let n = ws.rows[l];
            let k = d.fanouts[l - 1] + 1;
            let (fin, fout) = (d.f[l - 1], d.f[l]);
            let (idx, w) = (&batch.idx[l - 1], &batch.w[l - 1]);
            match kind {
                ModelKind::Gcn => {
                    let (wl, bl) = (&params[ppl * (l - 1)], &params[ppl * (l - 1) + 1]);
                    {
                        let hin: &[f32] = if l == 1 { &batch.feat0 } else { &ws.h[l - 2] };
                        kernels::aggregate(&mut ws.agg[l - 1], hin, idx, w, n, k, fin, false);
                    }
                    kernels::matmul_bias(&mut ws.z[l - 1], &ws.agg[l - 1], wl, bl, n, fin, fout);
                }
                ModelKind::Sage => {
                    // self rows through W_self, neighbor mean (self column
                    // skipped) through W_nbr — one fused walk of idx/w
                    let (wsf, wn, bl) = (
                        &params[ppl * (l - 1)],
                        &params[ppl * (l - 1) + 1],
                        &params[ppl * (l - 1) + 2],
                    );
                    {
                        let hin: &[f32] = if l == 1 { &batch.feat0 } else { &ws.h[l - 2] };
                        kernels::aggregate_with_self(
                            &mut ws.agg[l - 1],
                            &mut ws.selfr[l - 1],
                            hin,
                            idx,
                            w,
                            n,
                            k,
                            fin,
                        );
                    }
                    kernels::matmul_bias(&mut ws.z[l - 1], &ws.selfr[l - 1], wsf, bl, n, fin, fout);
                    kernels::add_matmul(&mut ws.z[l - 1], &ws.agg[l - 1], wn, n, fin, fout);
                }
            }
            if l < lcount {
                kernels::relu(&mut ws.h[l - 1], &ws.z[l - 1], n * fout);
            }
        }
    }

    /// Masked mean softmax cross-entropy over the computed logits, with
    /// dlogits written into `ws.dz[L-1]` (fully zeroed first, so padding
    /// target rows contribute nothing to the backward pass).
    fn loss_and_dlogits(&mut self, batch: &BatchBuffers) -> f32 {
        let d = &self.dims;
        let ws = &mut self.ws;
        let lcount = d.layers();
        let classes = d.classes();
        let n_t = ws.rows[lcount].min(d.b);
        let denom = batch.mask.iter().sum::<f32>().max(1.0);
        let logits = &ws.z[lcount - 1];
        let dl = &mut ws.dz[lcount - 1];
        dl.fill(0.0);
        let mut loss = 0.0f32;
        for r in 0..n_t {
            let mk = batch.mask[r];
            if mk == 0.0 {
                continue;
            }
            let row = &logits[r * classes..(r + 1) * classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sumexp: f32 = row.iter().map(|&x| (x - max).exp()).sum();
            let logz = max + sumexp.ln();
            let label = batch.labels[r] as usize;
            loss += mk * (logz - row[label]);
            let scale = mk / denom;
            for j in 0..classes {
                let softmax = (row[j] - max).exp() / sumexp;
                let onehot = if j == label { 1.0 } else { 0.0 };
                dl[r * classes + j] = scale * (softmax - onehot);
            }
        }
        loss / denom
    }

    // -- backward ----------------------------------------------------------

    /// Transposed stages, layer L down to 1 (the dataflow of the seed's
    /// explicit 2-layer backward, looped). `ws.dz[L-1]` must hold the
    /// dlogits on entry; gradients land in `grads` in artifact parameter
    /// order. Every tensor is fully overwritten (`matmul_at_b` and
    /// `col_sums` zero their outputs first), so recycled buffers carry
    /// nothing across steps.
    fn backward_into(&mut self, params: &[Vec<f32>], batch: &BatchBuffers, grads: &mut GradBuffers) {
        let ppl = self.ppl();
        let kind = self.kind;
        let d = &self.dims;
        let lcount = d.layers();
        // layer l owns slots ppl*(l-1) .. ppl*l: weight tensors [fin, fout]
        // then the bias [fout]
        grads.resize_with(ppl * lcount, |gi| {
            let l = gi / ppl + 1;
            let (fin, fout) = (d.f[l - 1], d.f[l]);
            if gi % ppl == ppl - 1 {
                fout
            } else {
                fin * fout
            }
        });
        let ws = &mut self.ws;
        for l in (1..=lcount).rev() {
            let n = ws.rows[l];
            let k = d.fanouts[l - 1] + 1;
            let (fin, fout) = (d.f[l - 1], d.f[l]);
            let (idx, w) = (&batch.idx[l - 1], &batch.w[l - 1]);
            match kind {
                ModelKind::Gcn => {
                    let wl = &params[ppl * (l - 1)];
                    kernels::matmul_at_b(
                        &mut grads[ppl * (l - 1)],
                        &ws.agg[l - 1],
                        &ws.dz[l - 1],
                        n,
                        fin,
                        fout,
                    );
                    kernels::col_sums(&mut grads[ppl * (l - 1) + 1], &ws.dz[l - 1], n, fout);
                    if l > 1 {
                        kernels::matmul_b_t(&mut ws.dx[l - 1], &ws.dz[l - 1], wl, n, fout, fin);
                        let below = ws.rows[l - 1];
                        ws.dz[l - 2][..below * fin].fill(0.0);
                        kernels::scatter_aggregate(
                            &mut ws.dz[l - 2],
                            &ws.dx[l - 1],
                            idx,
                            w,
                            n,
                            k,
                            fin,
                            false,
                        );
                        kernels::relu_mask(&mut ws.dz[l - 2], &ws.z[l - 2], below * fin);
                    }
                }
                ModelKind::Sage => {
                    let (wsf, wn) = (&params[ppl * (l - 1)], &params[ppl * (l - 1) + 1]);
                    kernels::matmul_at_b(
                        &mut grads[ppl * (l - 1)],
                        &ws.selfr[l - 1],
                        &ws.dz[l - 1],
                        n,
                        fin,
                        fout,
                    );
                    kernels::matmul_at_b(
                        &mut grads[ppl * (l - 1) + 1],
                        &ws.agg[l - 1],
                        &ws.dz[l - 1],
                        n,
                        fin,
                        fout,
                    );
                    kernels::col_sums(&mut grads[ppl * (l - 1) + 2], &ws.dz[l - 1], n, fout);
                    if l > 1 {
                        kernels::matmul_b_t(&mut ws.dx[l - 1], &ws.dz[l - 1], wsf, n, fout, fin);
                        kernels::matmul_b_t(&mut ws.dx2[l - 1], &ws.dz[l - 1], wn, n, fout, fin);
                        let below = ws.rows[l - 1];
                        ws.dz[l - 2][..below * fin].fill(0.0);
                        kernels::scatter_self(&mut ws.dz[l - 2], &ws.dx[l - 1], idx, n, k, fin);
                        kernels::scatter_aggregate(
                            &mut ws.dz[l - 2],
                            &ws.dx2[l - 1],
                            idx,
                            w,
                            n,
                            k,
                            fin,
                            true,
                        );
                        kernels::relu_mask(&mut ws.dz[l - 2], &ws.z[l - 2], below * fin);
                    }
                }
            }
        }
    }

    // -- scalar oracle path ------------------------------------------------

    /// The seed's scalar, allocation-per-call implementation over the full
    /// padded capacities — kept as the numerics oracle for the blocked
    /// path (unit tests) and as the baseline of the `micro_host` kernel
    /// sweep. Semantically identical to [`RefModel::train_step`].
    pub fn train_step_scalar(
        &self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<StepOutput> {
        let fwd = self.forward_scalar(params, batch);
        let d = &self.dims;
        let classes = d.classes();
        let denom = batch.mask.iter().sum::<f32>().max(1.0);

        // masked mean softmax cross-entropy and dlogits in one pass
        let mut loss = 0.0f32;
        let mut dlogits = vec![0.0f32; d.b * classes];
        for r in 0..d.b {
            let mk = batch.mask[r];
            if mk == 0.0 {
                continue;
            }
            let row = &fwd.logits()[r * classes..(r + 1) * classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sumexp: f32 = row.iter().map(|&x| (x - max).exp()).sum();
            let logz = max + sumexp.ln();
            let label = batch.labels[r] as usize;
            loss += mk * (logz - row[label]);
            let scale = mk / denom;
            for j in 0..classes {
                let softmax = (row[j] - max).exp() / sumexp;
                let onehot = if j == label { 1.0 } else { 0.0 };
                dlogits[r * classes + j] = scale * (softmax - onehot);
            }
        }
        loss /= denom;

        let grads = self.backward_scalar(params, batch, &fwd, &dlogits);
        Ok(StepOutput { loss, grads: grads.into() })
    }

    /// L aggregate→update stages over the full capacities (scalar oracle).
    fn forward_scalar(&self, params: &[Vec<f32>], batch: &BatchBuffers) -> Forward {
        let d = &self.dims;
        let lcount = d.layers();
        let ppl = self.ppl();
        let mut aggs = Vec::with_capacity(lcount);
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(lcount);
        let mut selfs = Vec::with_capacity(lcount);
        let mut h: Vec<f32> = Vec::new();
        for l in 1..=lcount {
            let rows = d.caps[l];
            let k = d.fanouts[l - 1] + 1;
            let (fin, fout) = (d.f[l - 1], d.f[l]);
            let (idx, w) = (&batch.idx[l - 1], &batch.w[l - 1]);
            let hin: &[f32] = if l == 1 { &batch.feat0 } else { &h };
            let z = match self.kind {
                ModelKind::Gcn => {
                    let (wl, bl) = (&params[ppl * (l - 1)], &params[ppl * (l - 1) + 1]);
                    let agg = scalar::aggregate(hin, idx, w, rows, k, fin, false);
                    let z = scalar::matmul_bias(&agg, wl, bl, rows, fin, fout);
                    aggs.push(agg);
                    z
                }
                ModelKind::Sage => {
                    let (wsf, wn, bl) = (
                        &params[ppl * (l - 1)],
                        &params[ppl * (l - 1) + 1],
                        &params[ppl * (l - 1) + 2],
                    );
                    let agg = scalar::aggregate(hin, idx, w, rows, k, fin, true);
                    let selfr = scalar::take_rows(hin, idx, rows, k, fin);
                    let mut z = scalar::matmul_bias(&selfr, wsf, bl, rows, fin, fout);
                    scalar::add_matmul(&mut z, &agg, wn, rows, fin, fout);
                    aggs.push(agg);
                    selfs.push(selfr);
                    z
                }
            };
            if l < lcount {
                h = scalar::relu(&z);
            }
            zs.push(z);
        }
        Forward { aggs, zs, selfs }
    }

    /// Transposed stages over the full capacities (scalar oracle).
    fn backward_scalar(
        &self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
        fwd: &Forward,
        dlogits: &[f32],
    ) -> Vec<Vec<f32>> {
        let d = &self.dims;
        let lcount = d.layers();
        let ppl = self.ppl();
        let mut grads: Vec<Vec<f32>> = vec![Vec::new(); ppl * lcount];
        let mut dz = dlogits.to_vec();
        for l in (1..=lcount).rev() {
            let rows = d.caps[l];
            let k = d.fanouts[l - 1] + 1;
            let (fin, fout) = (d.f[l - 1], d.f[l]);
            let (idx, w) = (&batch.idx[l - 1], &batch.w[l - 1]);
            match self.kind {
                ModelKind::Gcn => {
                    let wl = &params[ppl * (l - 1)];
                    grads[ppl * (l - 1)] =
                        scalar::matmul_at_b(&fwd.aggs[l - 1], &dz, rows, fin, fout);
                    grads[ppl * (l - 1) + 1] = scalar::col_sums(&dz, rows, fout);
                    if l > 1 {
                        let dagg = scalar::matmul_b_t(&dz, wl, rows, fout, fin);
                        let mut dh = vec![0.0f32; d.caps[l - 1] * fin];
                        scalar::scatter_aggregate(&mut dh, &dagg, idx, w, rows, k, fin, false);
                        dz = scalar::relu_grad(&fwd.zs[l - 2], &dh);
                    }
                }
                ModelKind::Sage => {
                    let (wsf, wn) = (&params[ppl * (l - 1)], &params[ppl * (l - 1) + 1]);
                    grads[ppl * (l - 1)] =
                        scalar::matmul_at_b(&fwd.selfs[l - 1], &dz, rows, fin, fout);
                    grads[ppl * (l - 1) + 1] =
                        scalar::matmul_at_b(&fwd.aggs[l - 1], &dz, rows, fin, fout);
                    grads[ppl * (l - 1) + 2] = scalar::col_sums(&dz, rows, fout);
                    if l > 1 {
                        let dself = scalar::matmul_b_t(&dz, wsf, rows, fout, fin);
                        let dnbr = scalar::matmul_b_t(&dz, wn, rows, fout, fin);
                        let mut dh = vec![0.0f32; d.caps[l - 1] * fin];
                        scalar::scatter_self(&mut dh, &dself, idx, rows, k, fin);
                        scalar::scatter_aggregate(&mut dh, &dnbr, idx, w, rows, k, fin, true);
                        dz = scalar::relu_grad(&fwd.zs[l - 2], &dh);
                    }
                }
            }
        }
        grads
    }
}

/// Scalar-path forward intermediates kept for the backward pass (one
/// entry per layer; `selfs` is SAGE-only).
struct Forward {
    aggs: Vec<Vec<f32>>,
    /// Pre-activations z_l; z_L *is* the logits (no relu on the output
    /// layer), see [`Forward::logits`].
    zs: Vec<Vec<f32>>,
    selfs: Vec<Vec<f32>>,
}

impl Forward {
    fn logits(&self) -> &[f32] {
        self.zs.last().expect("at least one layer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::synth_entry;
    use crate::runtime::Manifest;
    use crate::util::rng::Rng;

    fn tiny_entry(model: &str, kind: &str) -> ArtifactEntry {
        Manifest::builtin(std::path::Path::new("/tmp"))
            .find(kind, model, "tiny")
            .unwrap()
            .clone()
    }

    /// Synthetic entry at an arbitrary depth (b=8 keeps the fd check fast).
    fn depth_entry(model: &str, fanouts: &[usize]) -> ArtifactEntry {
        let gd = crate::graph::GnnDims { f0: 12, f1: 10, f2: 5 };
        synth_entry(std::path::Path::new("/tmp"), "train", model, "tiny", 8, fanouts, gd)
    }

    fn random_batch(d: &ArtifactDims, seed: u64) -> BatchBuffers {
        let mut rng = Rng::new(seed);
        let lcount = d.layers();
        let classes = d.classes();
        // a self-consistent random padded batch: n real rows per level
        let n: Vec<usize> = d.caps.iter().map(|&c| (c / 2).max(1)).collect();
        let feat0: Vec<f32> = (0..d.caps[0] * d.f[0]).map(|_| rng.f32() - 0.5).collect();
        let mut idx = Vec::with_capacity(lcount);
        let mut w = Vec::with_capacity(lcount);
        for l in 1..=lcount {
            let k = d.fanouts[l - 1] + 1;
            let mut il = vec![0i32; d.caps[l] * k];
            let mut wl = vec![0f32; d.caps[l] * k];
            for r in 0..n[l] {
                for c in 0..k {
                    il[r * k + c] = rng.index(n[l - 1]) as i32;
                    wl[r * k + c] = rng.f32();
                }
            }
            idx.push(il);
            w.push(wl);
        }
        let labels: Vec<i32> = (0..d.b).map(|_| rng.index(classes) as i32).collect();
        let mut mask = vec![0f32; d.b];
        for m in mask.iter_mut().take(n[lcount]) {
            *m = 1.0;
        }
        BatchBuffers { feat0, idx, w, labels, mask, n }
    }

    fn loss_of(model: &mut RefModel, params: &[Vec<f32>], batch: &BatchBuffers) -> f64 {
        model.train_step(params, batch).unwrap().loss as f64
    }

    /// Central-difference gradient check: the analytic backward pass must
    /// match numerical differentiation on sampled coordinates. Runs on
    /// the blocked workspace path.
    fn grad_check_entry(entry: &ArtifactEntry, tag: &str) {
        let mut model = RefModel::new(entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(entry, 9).data;
        let batch = random_batch(&entry.dims, 4);
        let out = model.train_step(&params, &batch).unwrap();
        let mut rng = Rng::new(77);
        let eps = 1e-3f32;
        let mut checked = 0;
        for (pi, p) in params.iter().enumerate() {
            for _ in 0..4 {
                let i = rng.index(p.len());
                let mut plus = params.clone();
                plus[pi][i] += eps;
                let mut minus = params.clone();
                minus[pi][i] -= eps;
                let num = (loss_of(&mut model, &plus, &batch)
                    - loss_of(&mut model, &minus, &batch))
                    / (2.0 * eps as f64);
                let ana = out.grads[pi][i] as f64;
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                    "{tag} param {pi}[{i}]: numeric {num} vs analytic {ana}"
                );
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    fn grad_check(model_name: &str) {
        grad_check_entry(&tiny_entry(model_name, "train"), model_name);
    }

    #[test]
    fn gcn_gradients_match_finite_differences() {
        grad_check("gcn");
    }

    #[test]
    fn sage_gradients_match_finite_differences() {
        grad_check("sage");
    }

    #[test]
    fn gradients_match_finite_differences_at_depths_one_and_three() {
        for model in ["gcn", "sage"] {
            for fanouts in [vec![3usize], vec![3, 2, 2]] {
                let entry = depth_entry(model, &fanouts);
                grad_check_entry(&entry, &format!("{model} L={}", fanouts.len()));
            }
        }
    }

    #[test]
    fn builtin_three_layer_sage_entry_gradcheck() {
        // the manifest's shipped 3-layer artifact, end to end through the
        // same validation path the trainer uses
        let m = Manifest::builtin(std::path::Path::new("/tmp"));
        let entry = m.find_fanouts("train", "sage", "tiny", &[3, 2, 2]).unwrap().clone();
        grad_check_entry(&entry, "builtin sage l3");
    }

    #[test]
    fn blocked_path_matches_scalar_oracle_at_depths_one_two_three() {
        // ISSUE 5 tentpole guard: the workspace/blocked executor must be
        // numerically interchangeable with the seed's scalar path on
        // both model families at every supported depth — identical loss
        // and gradients within FP-reassociation tolerance.
        for model_name in ["gcn", "sage"] {
            for fanouts in [vec![3usize], vec![3, 2], vec![3, 2, 2]] {
                let entry = depth_entry(model_name, &fanouts);
                let mut model = RefModel::new(&entry).unwrap();
                let params = crate::coordinator::params::ParamSet::init(&entry, 5).data;
                let batch = random_batch(&entry.dims, 11);
                let blocked = model.train_step(&params, &batch).unwrap();
                let oracle = model.train_step_scalar(&params, &batch).unwrap();
                let tag = format!("{model_name} L={}", fanouts.len());
                let lscale = 1.0 + oracle.loss.abs();
                assert!(
                    (blocked.loss - oracle.loss).abs() < 1e-5 * lscale,
                    "{tag}: loss {} vs oracle {}",
                    blocked.loss,
                    oracle.loss
                );
                assert_eq!(blocked.grads.len(), oracle.grads.len(), "{tag}");
                for (pi, (g, og)) in blocked.grads.iter().zip(&oracle.grads).enumerate() {
                    assert_eq!(g.len(), og.len(), "{tag} param {pi}");
                    for (i, (a, b)) in g.iter().zip(og).enumerate() {
                        let scale = 1.0 + a.abs().max(b.abs());
                        assert!(
                            (a - b).abs() < 1e-4 * scale,
                            "{tag} grad {pi}[{i}]: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn loss_is_masked_mean_ce() {
        let entry = tiny_entry("gcn", "train");
        let mut model = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 2).data;
        let batch = random_batch(&entry.dims, 6);
        let out = model.train_step(&params, &batch).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        // all-zero mask: loss 0, grads 0
        let mut b2 = batch;
        b2.mask.iter_mut().for_each(|m| *m = 0.0);
        let out2 = model.train_step(&params, &b2).unwrap();
        assert_eq!(out2.loss, 0.0);
        assert!(out2.grads.iter().flatten().all(|&g| g == 0.0));
    }

    #[test]
    fn rejects_unknown_model_and_bad_shapes() {
        let mut entry = tiny_entry("gcn", "train");
        entry.model = "transformer".into();
        assert!(RefModel::new(&entry).is_err());
        let mut entry = tiny_entry("gcn", "train");
        entry.params[0].1 = vec![1, 1];
        assert!(RefModel::new(&entry).is_err());
        // a 3-layer entry with a 2-layer parameter list is caught
        let mut entry = depth_entry("gcn", &[3, 2, 2]);
        entry.params.truncate(4);
        assert!(RefModel::new(&entry).is_err());
    }

    #[test]
    fn deterministic_bitwise() {
        let entry = tiny_entry("sage", "train");
        let mut model = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 5).data;
        let batch = random_batch(&entry.dims, 8);
        let a = model.train_step(&params, &batch).unwrap();
        let b = model.train_step(&params, &batch).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.grads, b.grads);
    }

    #[test]
    fn recycled_workspace_cannot_leak_between_batches() {
        // two different batches alternated through one model instance:
        // results must match a fresh instance's on every step (the
        // workspace is fully overwritten per step over the live region)
        let entry = tiny_entry("sage", "train");
        let mut reused = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 5).data;
        let batches = [random_batch(&entry.dims, 8), random_batch(&entry.dims, 9)];
        // dirty the workspace AND the recycled gradient buffers with
        // batch 1 first, then replay both
        let mut grads = GradBuffers::empty();
        let _ = reused.train_step_into(&params, &batches[1], &mut grads).unwrap();
        for b in &batches {
            let mut fresh = RefModel::new(&entry).unwrap();
            let want = fresh.train_step(&params, b).unwrap();
            let loss = reused.train_step_into(&params, b, &mut grads).unwrap();
            assert_eq!(loss.to_bits(), want.loss.to_bits());
            assert_eq!(grads, want.grads);
        }
    }
}
