//! Pure-Rust reference executor — the offline twin of the PJRT backend.
//!
//! Implements the exact L2 model semantics (`python/compile/model.py`) for
//! the two shipped model families — L-layer GCN and GraphSAGE-mean over
//! the padded mini-batch wire format (DESIGN.md §Mini-batch wire format)
//! — including the backward pass and the masked softmax cross-entropy
//! loss. Depth comes from the artifact's fanout vector; each layer is one
//! aggregate→update stage forward and the transposed pair backward, so
//! the executor prices any L ≥ 1 (gradients are finite-difference-checked
//! at L ∈ {1, 2, 3} in the unit tests). This lets the full coordinator
//! pipeline (and its tests) run in environments without the `xla` crate
//! or AOT artifacts: build without the `pjrt` feature and
//! [`super::TrainExecutor`] dispatches here.
//!
//! Numerics are plain f32 loops with a fixed accumulation order, so a
//! training run is bit-reproducible — the property the pipeline
//! determinism tests (`tests/pipeline_determinism.rs`) assert. At L = 2
//! the loop unrolls to exactly the seed's operation sequence, keeping the
//! golden-equivalence guarantee.

use super::executor::{BatchBuffers, StepOutput};
use super::manifest::{param_specs, ArtifactDims, ArtifactEntry};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ModelKind {
    Gcn,
    Sage,
}

/// Reference implementation of one artifact (train or predict).
pub struct RefModel {
    kind: ModelKind,
    dims: ArtifactDims,
}

impl RefModel {
    /// Validate the entry against the known model architectures. Mirrors
    /// what PJRT compilation catches (shape mismatches fail at compile
    /// time, not mid-epoch).
    pub fn new(entry: &ArtifactEntry) -> anyhow::Result<RefModel> {
        let kind = match entry.model.as_str() {
            "gcn" => ModelKind::Gcn,
            "sage" => ModelKind::Sage,
            other => anyhow::bail!(
                "reference executor supports gcn|sage, not '{other}' \
                 (enable the `pjrt` feature for arbitrary HLO artifacts)"
            ),
        };
        let d = entry.dims.clone();
        let expect = param_specs(&entry.model, &d);
        anyhow::ensure!(
            entry.params.len() == expect.len(),
            "artifact '{}' has {} params, {}-layer {} model needs {}",
            entry.name,
            entry.params.len(),
            d.layers(),
            entry.model,
            expect.len()
        );
        for ((name, shape), (ename, eshape)) in entry.params.iter().zip(&expect) {
            anyhow::ensure!(
                name == ename && shape == eshape,
                "artifact '{}' param {name}{shape:?} != expected {ename}{eshape:?}",
                entry.name
            );
        }
        Ok(RefModel { kind, dims: d })
    }

    /// Forward + backward + masked CE loss (train artifacts).
    pub fn train_step(
        &self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
    ) -> anyhow::Result<StepOutput> {
        let fwd = self.forward(params, batch);
        let d = &self.dims;
        let classes = d.classes();
        let denom = batch.mask.iter().sum::<f32>().max(1.0);

        // masked mean softmax cross-entropy and dlogits in one pass
        let mut loss = 0.0f32;
        let mut dlogits = vec![0.0f32; d.b * classes];
        for r in 0..d.b {
            let mk = batch.mask[r];
            if mk == 0.0 {
                continue;
            }
            let row = &fwd.logits()[r * classes..(r + 1) * classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sumexp: f32 = row.iter().map(|&x| (x - max).exp()).sum();
            let logz = max + sumexp.ln();
            let label = batch.labels[r] as usize;
            loss += mk * (logz - row[label]);
            let scale = mk / denom;
            for j in 0..classes {
                let softmax = (row[j] - max).exp() / sumexp;
                let onehot = if j == label { 1.0 } else { 0.0 };
                dlogits[r * classes + j] = scale * (softmax - onehot);
            }
        }
        loss /= denom;

        let grads = self.backward(params, batch, &fwd, &dlogits);
        Ok(StepOutput { loss, grads })
    }

    /// Forward only (predict artifacts) → logits `[b, classes]`.
    pub fn predict(&self, params: &[Vec<f32>], batch: &BatchBuffers) -> anyhow::Result<Vec<f32>> {
        let mut fwd = self.forward(params, batch);
        Ok(fwd.zs.pop().expect("at least one layer"))
    }

    /// Parameters-per-layer of this model kind.
    fn ppl(&self) -> usize {
        match self.kind {
            ModelKind::Gcn => 2,
            ModelKind::Sage => 3,
        }
    }

    // -- forward -----------------------------------------------------------

    /// L aggregate→update stages; relu between layers, linear output.
    /// Layer 1 reads `feat0` by reference (no copy of the batch's largest
    /// buffer); the output layer's pre-activation doubles as the logits.
    fn forward(&self, params: &[Vec<f32>], batch: &BatchBuffers) -> Forward {
        let d = &self.dims;
        let lcount = d.layers();
        let ppl = self.ppl();
        let mut aggs = Vec::with_capacity(lcount);
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(lcount);
        let mut selfs = Vec::with_capacity(lcount);
        let mut h: Vec<f32> = Vec::new();
        for l in 1..=lcount {
            let rows = d.caps[l];
            let k = d.fanouts[l - 1] + 1;
            let (fin, fout) = (d.f[l - 1], d.f[l]);
            let (idx, w) = (&batch.idx[l - 1], &batch.w[l - 1]);
            let hin: &[f32] = if l == 1 { &batch.feat0 } else { &h };
            let z = match self.kind {
                ModelKind::Gcn => {
                    let (wl, bl) = (&params[ppl * (l - 1)], &params[ppl * (l - 1) + 1]);
                    let agg = aggregate(hin, idx, w, rows, k, fin, false);
                    let z = matmul_bias(&agg, wl, bl, rows, fin, fout);
                    aggs.push(agg);
                    z
                }
                ModelKind::Sage => {
                    // self rows through W_self, neighbor mean (col 0 of the
                    // weights zeroed) through W_nbr
                    let (ws, wn, bl) = (
                        &params[ppl * (l - 1)],
                        &params[ppl * (l - 1) + 1],
                        &params[ppl * (l - 1) + 2],
                    );
                    let agg = aggregate(hin, idx, w, rows, k, fin, true);
                    let selfr = take_rows(hin, idx, rows, k, fin);
                    let mut z = matmul_bias(&selfr, ws, bl, rows, fin, fout);
                    add_matmul(&mut z, &agg, wn, rows, fin, fout);
                    aggs.push(agg);
                    selfs.push(selfr);
                    z
                }
            };
            if l < lcount {
                h = relu(&z);
            }
            zs.push(z);
        }
        Forward { aggs, zs, selfs }
    }

    // -- backward ----------------------------------------------------------

    /// Transposed stages, layer L down to 1 (the dataflow of the seed's
    /// explicit 2-layer backward, looped).
    fn backward(
        &self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
        fwd: &Forward,
        dlogits: &[f32],
    ) -> Vec<Vec<f32>> {
        let d = &self.dims;
        let lcount = d.layers();
        let ppl = self.ppl();
        let mut grads: Vec<Vec<f32>> = vec![Vec::new(); ppl * lcount];
        let mut dz = dlogits.to_vec();
        for l in (1..=lcount).rev() {
            let rows = d.caps[l];
            let k = d.fanouts[l - 1] + 1;
            let (fin, fout) = (d.f[l - 1], d.f[l]);
            let (idx, w) = (&batch.idx[l - 1], &batch.w[l - 1]);
            match self.kind {
                ModelKind::Gcn => {
                    let wl = &params[ppl * (l - 1)];
                    grads[ppl * (l - 1)] = matmul_at_b(&fwd.aggs[l - 1], &dz, rows, fin, fout);
                    grads[ppl * (l - 1) + 1] = col_sums(&dz, rows, fout);
                    if l > 1 {
                        let dagg = matmul_b_t(&dz, wl, rows, fout, fin);
                        let mut dh = vec![0.0f32; d.caps[l - 1] * fin];
                        scatter_aggregate(&mut dh, &dagg, idx, w, rows, k, fin, false);
                        dz = relu_grad(&fwd.zs[l - 2], &dh);
                    }
                }
                ModelKind::Sage => {
                    let (ws, wn) = (&params[ppl * (l - 1)], &params[ppl * (l - 1) + 1]);
                    grads[ppl * (l - 1)] = matmul_at_b(&fwd.selfs[l - 1], &dz, rows, fin, fout);
                    grads[ppl * (l - 1) + 1] = matmul_at_b(&fwd.aggs[l - 1], &dz, rows, fin, fout);
                    grads[ppl * (l - 1) + 2] = col_sums(&dz, rows, fout);
                    if l > 1 {
                        let dself = matmul_b_t(&dz, ws, rows, fout, fin);
                        let dnbr = matmul_b_t(&dz, wn, rows, fout, fin);
                        let mut dh = vec![0.0f32; d.caps[l - 1] * fin];
                        scatter_self(&mut dh, &dself, idx, rows, k, fin);
                        scatter_aggregate(&mut dh, &dnbr, idx, w, rows, k, fin, true);
                        dz = relu_grad(&fwd.zs[l - 2], &dh);
                    }
                }
            }
        }
        grads
    }
}

/// Forward-pass intermediates kept for the backward pass (one entry per
/// layer; `selfs` is SAGE-only).
struct Forward {
    aggs: Vec<Vec<f32>>,
    /// Pre-activations z_l; z_L *is* the logits (no relu on the output
    /// layer), see [`Forward::logits`].
    zs: Vec<Vec<f32>>,
    selfs: Vec<Vec<f32>>,
}

impl Forward {
    fn logits(&self) -> &[f32] {
        self.zs.last().expect("at least one layer")
    }
}

/// `out[r] = Σ_c w[r,c]·h[idx[r,c]]` over feature width `f`; with
/// `skip_self` the self column (c = 0) is excluded (SAGE neighbor mean).
fn aggregate(
    h: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
    skip_self: bool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * f];
    let c0 = usize::from(skip_self);
    for r in 0..rows {
        for c in c0..k {
            let weight = w[r * k + c];
            if weight == 0.0 {
                continue;
            }
            let src = idx[r * k + c] as usize;
            let (dst, src_row) = (&mut out[r * f..(r + 1) * f], &h[src * f..(src + 1) * f]);
            for j in 0..f {
                dst[j] += weight * src_row[j];
            }
        }
    }
    out
}

/// Transpose of [`aggregate`]: `dh[idx[r,c]] += w[r,c]·dout[r]`.
fn scatter_aggregate(
    dh: &mut [f32],
    dout: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
    skip_self: bool,
) {
    let c0 = usize::from(skip_self);
    for r in 0..rows {
        for c in c0..k {
            let weight = w[r * k + c];
            if weight == 0.0 {
                continue;
            }
            let src = idx[r * k + c] as usize;
            for j in 0..f {
                dh[src * f + j] += weight * dout[r * f + j];
            }
        }
    }
}

/// Gather the self rows `h[idx[r,0]]` (SAGE's W_self input).
fn take_rows(h: &[f32], idx: &[i32], rows: usize, k: usize, f: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * f];
    for r in 0..rows {
        let src = idx[r * k] as usize;
        out[r * f..(r + 1) * f].copy_from_slice(&h[src * f..(src + 1) * f]);
    }
    out
}

/// Transpose of [`take_rows`]: `dh[idx[r,0]] += dout[r]`.
fn scatter_self(dh: &mut [f32], dout: &[f32], idx: &[i32], rows: usize, k: usize, f: usize) {
    for r in 0..rows {
        let src = idx[r * k] as usize;
        for j in 0..f {
            dh[src * f + j] += dout[r * f + j];
        }
    }
}

/// `x[n, fin] · w[fin, fout] + bias` row-major.
fn matmul_bias(x: &[f32], w: &[f32], bias: &[f32], n: usize, fin: usize, fout: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * fout];
    for r in 0..n {
        let orow = &mut out[r * fout..(r + 1) * fout];
        orow.copy_from_slice(bias);
        for kk in 0..fin {
            let xv = x[r * fin + kk];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * fout..(kk + 1) * fout];
            for j in 0..fout {
                orow[j] += xv * wrow[j];
            }
        }
    }
    out
}

/// `out += x[n, fin] · w[fin, fout]` (second matmul path of a SAGE layer).
fn add_matmul(out: &mut [f32], x: &[f32], w: &[f32], n: usize, fin: usize, fout: usize) {
    for r in 0..n {
        for kk in 0..fin {
            let xv = x[r * fin + kk];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * fout..(kk + 1) * fout];
            let orow = &mut out[r * fout..(r + 1) * fout];
            for j in 0..fout {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

/// `aᵀ·b` for `a[n, fa]`, `b[n, fb]` → `[fa, fb]` (weight gradients).
fn matmul_at_b(a: &[f32], b: &[f32], n: usize, fa: usize, fb: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; fa * fb];
    for r in 0..n {
        for kk in 0..fa {
            let av = a[r * fa + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[r * fb..(r + 1) * fb];
            let orow = &mut out[kk * fb..(kk + 1) * fb];
            for j in 0..fb {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// `a[n, fa] · wᵀ` for `w[fb, fa]` → `[n, fb]` (input gradients).
fn matmul_b_t(a: &[f32], w: &[f32], n: usize, fa: usize, fb: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * fb];
    for r in 0..n {
        let arow = &a[r * fa..(r + 1) * fa];
        let orow = &mut out[r * fb..(r + 1) * fb];
        for kk in 0..fb {
            let wrow = &w[kk * fa..(kk + 1) * fa];
            let mut acc = 0.0f32;
            for j in 0..fa {
                acc += arow[j] * wrow[j];
            }
            orow[kk] = acc;
        }
    }
    out
}

fn col_sums(x: &[f32], n: usize, f: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; f];
    for r in 0..n {
        for j in 0..f {
            out[j] += x[r * f + j];
        }
    }
    out
}

fn relu(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| v.max(0.0)).collect()
}

/// Gradient through relu: pass where the pre-activation was positive
/// (zero at exactly 0, matching jax.nn.relu's convention).
fn relu_grad(z: &[f32], dh: &[f32]) -> Vec<f32> {
    z.iter().zip(dh).map(|(&zv, &dv)| if zv > 0.0 { dv } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::synth_entry;
    use crate::runtime::Manifest;
    use crate::util::rng::Rng;

    fn tiny_entry(model: &str, kind: &str) -> ArtifactEntry {
        Manifest::builtin(std::path::Path::new("/tmp"))
            .find(kind, model, "tiny")
            .unwrap()
            .clone()
    }

    /// Synthetic entry at an arbitrary depth (b=8 keeps the fd check fast).
    fn depth_entry(model: &str, fanouts: &[usize]) -> ArtifactEntry {
        let gd = crate::graph::GnnDims { f0: 12, f1: 10, f2: 5 };
        synth_entry(std::path::Path::new("/tmp"), "train", model, "tiny", 8, fanouts, gd)
    }

    fn random_batch(d: &ArtifactDims, seed: u64) -> BatchBuffers {
        let mut rng = Rng::new(seed);
        let lcount = d.layers();
        let classes = d.classes();
        // a self-consistent random padded batch: n real rows per level
        let n: Vec<usize> = d.caps.iter().map(|&c| (c / 2).max(1)).collect();
        let feat0: Vec<f32> = (0..d.caps[0] * d.f[0]).map(|_| rng.f32() - 0.5).collect();
        let mut idx = Vec::with_capacity(lcount);
        let mut w = Vec::with_capacity(lcount);
        for l in 1..=lcount {
            let k = d.fanouts[l - 1] + 1;
            let mut il = vec![0i32; d.caps[l] * k];
            let mut wl = vec![0f32; d.caps[l] * k];
            for r in 0..n[l] {
                for c in 0..k {
                    il[r * k + c] = rng.index(n[l - 1]) as i32;
                    wl[r * k + c] = rng.f32();
                }
            }
            idx.push(il);
            w.push(wl);
        }
        let labels: Vec<i32> = (0..d.b).map(|_| rng.index(classes) as i32).collect();
        let mut mask = vec![0f32; d.b];
        for m in mask.iter_mut().take(n[lcount]) {
            *m = 1.0;
        }
        BatchBuffers { feat0, idx, w, labels, mask }
    }

    fn loss_of(model: &RefModel, params: &[Vec<f32>], batch: &BatchBuffers) -> f64 {
        model.train_step(params, batch).unwrap().loss as f64
    }

    /// Central-difference gradient check: the analytic backward pass must
    /// match numerical differentiation on sampled coordinates.
    fn grad_check_entry(entry: &ArtifactEntry, tag: &str) {
        let model = RefModel::new(entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(entry, 9).data;
        let batch = random_batch(&entry.dims, 4);
        let out = model.train_step(&params, &batch).unwrap();
        let mut rng = Rng::new(77);
        let eps = 1e-3f32;
        let mut checked = 0;
        for (pi, p) in params.iter().enumerate() {
            for _ in 0..4 {
                let i = rng.index(p.len());
                let mut plus = params.clone();
                plus[pi][i] += eps;
                let mut minus = params.clone();
                minus[pi][i] -= eps;
                let num = (loss_of(&model, &plus, &batch) - loss_of(&model, &minus, &batch))
                    / (2.0 * eps as f64);
                let ana = out.grads[pi][i] as f64;
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                    "{tag} param {pi}[{i}]: numeric {num} vs analytic {ana}"
                );
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    fn grad_check(model_name: &str) {
        grad_check_entry(&tiny_entry(model_name, "train"), model_name);
    }

    #[test]
    fn gcn_gradients_match_finite_differences() {
        grad_check("gcn");
    }

    #[test]
    fn sage_gradients_match_finite_differences() {
        grad_check("sage");
    }

    #[test]
    fn gradients_match_finite_differences_at_depths_one_and_three() {
        for model in ["gcn", "sage"] {
            for fanouts in [vec![3usize], vec![3, 2, 2]] {
                let entry = depth_entry(model, &fanouts);
                grad_check_entry(&entry, &format!("{model} L={}", fanouts.len()));
            }
        }
    }

    #[test]
    fn builtin_three_layer_sage_entry_gradcheck() {
        // the manifest's shipped 3-layer artifact, end to end through the
        // same validation path the trainer uses
        let m = Manifest::builtin(std::path::Path::new("/tmp"));
        let entry = m.find_fanouts("train", "sage", "tiny", &[3, 2, 2]).unwrap().clone();
        grad_check_entry(&entry, "builtin sage l3");
    }

    #[test]
    fn loss_is_masked_mean_ce() {
        let entry = tiny_entry("gcn", "train");
        let model = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 2).data;
        let batch = random_batch(&entry.dims, 6);
        let out = model.train_step(&params, &batch).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        // all-zero mask: loss 0, grads 0
        let mut b2 = batch;
        b2.mask.iter_mut().for_each(|m| *m = 0.0);
        let out2 = model.train_step(&params, &b2).unwrap();
        assert_eq!(out2.loss, 0.0);
        assert!(out2.grads.iter().flatten().all(|&g| g == 0.0));
    }

    #[test]
    fn rejects_unknown_model_and_bad_shapes() {
        let mut entry = tiny_entry("gcn", "train");
        entry.model = "transformer".into();
        assert!(RefModel::new(&entry).is_err());
        let mut entry = tiny_entry("gcn", "train");
        entry.params[0].1 = vec![1, 1];
        assert!(RefModel::new(&entry).is_err());
        // a 3-layer entry with a 2-layer parameter list is caught
        let mut entry = depth_entry("gcn", &[3, 2, 2]);
        entry.params.truncate(4);
        assert!(RefModel::new(&entry).is_err());
    }

    #[test]
    fn deterministic_bitwise() {
        let entry = tiny_entry("sage", "train");
        let model = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 5).data;
        let batch = random_batch(&entry.dims, 8);
        let a = model.train_step(&params, &batch).unwrap();
        let b = model.train_step(&params, &batch).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.grads, b.grads);
    }
}
