//! Pure-Rust reference executor — the offline twin of the PJRT backend.
//!
//! Implements the exact L2 model semantics (`python/compile/model.py`) for
//! the two shipped models — 2-layer GCN and GraphSAGE-mean over the padded
//! mini-batch wire format (DESIGN.md §Mini-batch wire format) — including
//! the backward pass and the masked softmax cross-entropy loss. This lets
//! the full coordinator pipeline (and its tests) run in environments
//! without the `xla` crate or AOT artifacts: build without the `pjrt`
//! feature and [`super::TrainExecutor`] dispatches here.
//!
//! Numerics are plain f32 loops with a fixed accumulation order, so a
//! training run is bit-reproducible — the property the pipeline
//! determinism tests (`tests/pipeline_determinism.rs`) assert.

use super::executor::{BatchBuffers, StepOutput};
use super::manifest::{ArtifactDims, ArtifactEntry};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ModelKind {
    Gcn,
    Sage,
}

/// Reference implementation of one artifact (train or predict).
pub struct RefModel {
    kind: ModelKind,
    dims: ArtifactDims,
}

impl RefModel {
    /// Validate the entry against the known model architectures. Mirrors
    /// what PJRT compilation catches (shape mismatches fail at compile
    /// time, not mid-epoch).
    pub fn new(entry: &ArtifactEntry) -> anyhow::Result<RefModel> {
        let kind = match entry.model.as_str() {
            "gcn" => ModelKind::Gcn,
            "sage" => ModelKind::Sage,
            other => anyhow::bail!(
                "reference executor supports gcn|sage, not '{other}' \
                 (enable the `pjrt` feature for arbitrary HLO artifacts)"
            ),
        };
        let d = entry.dims;
        let expect = expected_params(kind, &d);
        anyhow::ensure!(
            entry.params.len() == expect.len(),
            "artifact '{}' has {} params, {} model needs {}",
            entry.name,
            entry.params.len(),
            entry.model,
            expect.len()
        );
        for ((name, shape), (ename, eshape)) in entry.params.iter().zip(&expect) {
            anyhow::ensure!(
                name == ename && shape == eshape,
                "artifact '{}' param {name}{shape:?} != expected {ename}{eshape:?}",
                entry.name
            );
        }
        Ok(RefModel { kind, dims: d })
    }

    /// Forward + backward + masked CE loss (train artifacts).
    pub fn train_step(&self, params: &[Vec<f32>], batch: &BatchBuffers) -> anyhow::Result<StepOutput> {
        let fwd = self.forward(params, batch);
        let d = &self.dims;
        let denom = batch.mask.iter().sum::<f32>().max(1.0);

        // masked mean softmax cross-entropy and dlogits in one pass
        let mut loss = 0.0f32;
        let mut dlogits = vec![0.0f32; d.b * d.f2];
        for r in 0..d.b {
            let mk = batch.mask[r];
            if mk == 0.0 {
                continue;
            }
            let row = &fwd.logits[r * d.f2..(r + 1) * d.f2];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sumexp: f32 = row.iter().map(|&x| (x - max).exp()).sum();
            let logz = max + sumexp.ln();
            let label = batch.labels[r] as usize;
            loss += mk * (logz - row[label]);
            let scale = mk / denom;
            for j in 0..d.f2 {
                let softmax = (row[j] - max).exp() / sumexp;
                let onehot = if j == label { 1.0 } else { 0.0 };
                dlogits[r * d.f2 + j] = scale * (softmax - onehot);
            }
        }
        loss /= denom;

        let grads = match self.kind {
            ModelKind::Gcn => self.backward_gcn(params, batch, &fwd, &dlogits),
            ModelKind::Sage => self.backward_sage(params, batch, &fwd, &dlogits),
        };
        Ok(StepOutput { loss, grads })
    }

    /// Forward only (predict artifacts) → logits `[b, f2]`.
    pub fn predict(&self, params: &[Vec<f32>], batch: &BatchBuffers) -> anyhow::Result<Vec<f32>> {
        Ok(self.forward(params, batch).logits)
    }

    // -- forward -----------------------------------------------------------

    fn forward(&self, params: &[Vec<f32>], batch: &BatchBuffers) -> Forward {
        let d = &self.dims;
        match self.kind {
            ModelKind::Gcn => {
                let (w1, b1, w2, b2) = (&params[0], &params[1], &params[2], &params[3]);
                // layer 1: aggregate(feat0) → update → relu
                let agg1 = aggregate(&batch.feat0, &batch.idx1, &batch.w1, d.v1_cap, d.k1 + 1, d.f0, false);
                let z1 = matmul_bias(&agg1, w1, b1, d.v1_cap, d.f0, d.f1);
                let h1 = relu(&z1);
                // layer 2: aggregate(h1) → update
                let agg2 = aggregate(&h1, &batch.idx2, &batch.w2, d.b, d.k2 + 1, d.f1, false);
                let logits = matmul_bias(&agg2, w2, b2, d.b, d.f1, d.f2);
                Forward { agg1, z1, agg2, logits, self1: Vec::new(), self2: Vec::new() }
            }
            ModelKind::Sage => {
                let (w1s, w1n, b1, w2s, w2n, b2) =
                    (&params[0], &params[1], &params[2], &params[3], &params[4], &params[5]);
                // layer 1: self rows through W_self, neighbor mean (col 0
                // of the weights zeroed) through W_nbr
                let agg1 = aggregate(&batch.feat0, &batch.idx1, &batch.w1, d.v1_cap, d.k1 + 1, d.f0, true);
                let self1 = take_rows(&batch.feat0, &batch.idx1, d.v1_cap, d.k1 + 1, d.f0);
                let mut z1 = matmul_bias(&self1, w1s, b1, d.v1_cap, d.f0, d.f1);
                add_matmul(&mut z1, &agg1, w1n, d.v1_cap, d.f0, d.f1);
                let h1 = relu(&z1);
                // layer 2
                let agg2 = aggregate(&h1, &batch.idx2, &batch.w2, d.b, d.k2 + 1, d.f1, true);
                let self2 = take_rows(&h1, &batch.idx2, d.b, d.k2 + 1, d.f1);
                let mut logits = matmul_bias(&self2, w2s, b2, d.b, d.f1, d.f2);
                add_matmul(&mut logits, &agg2, w2n, d.b, d.f1, d.f2);
                Forward { agg1, z1, agg2, logits, self1, self2 }
            }
        }
    }

    // -- backward ----------------------------------------------------------

    fn backward_gcn(
        &self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
        fwd: &Forward,
        dlogits: &[f32],
    ) -> Vec<Vec<f32>> {
        let d = &self.dims;
        let w2 = &params[2];
        // layer 2 update: dw2 = agg2ᵀ·dlogits, db2 = Σ rows, dagg2 = dlogits·w2ᵀ
        let dw2 = matmul_at_b(&fwd.agg2, dlogits, d.b, d.f1, d.f2);
        let db2 = col_sums(dlogits, d.b, d.f2);
        let dagg2 = matmul_b_t(dlogits, w2, d.b, d.f2, d.f1);
        // layer 2 aggregate transpose: scatter into h1 rows
        let mut dh1 = vec![0.0f32; d.v1_cap * d.f1];
        scatter_aggregate(&mut dh1, &dagg2, &batch.idx2, &batch.w2, d.b, d.k2 + 1, d.f1, false);
        // relu
        let dz1 = relu_grad(&fwd.z1, &dh1);
        // layer 1 update
        let dw1 = matmul_at_b(&fwd.agg1, &dz1, d.v1_cap, d.f0, d.f1);
        let db1 = col_sums(&dz1, d.v1_cap, d.f1);
        vec![dw1, db1, dw2, db2]
    }

    fn backward_sage(
        &self,
        params: &[Vec<f32>],
        batch: &BatchBuffers,
        fwd: &Forward,
        dlogits: &[f32],
    ) -> Vec<Vec<f32>> {
        let d = &self.dims;
        let (w2s, w2n) = (&params[3], &params[4]);
        // layer 2 update
        let dw2s = matmul_at_b(&fwd.self2, dlogits, d.b, d.f1, d.f2);
        let dw2n = matmul_at_b(&fwd.agg2, dlogits, d.b, d.f1, d.f2);
        let db2 = col_sums(dlogits, d.b, d.f2);
        // into h1: self path + neighbor path
        let dself2 = matmul_b_t(dlogits, w2s, d.b, d.f2, d.f1);
        let dnbr2 = matmul_b_t(dlogits, w2n, d.b, d.f2, d.f1);
        let mut dh1 = vec![0.0f32; d.v1_cap * d.f1];
        scatter_self(&mut dh1, &dself2, &batch.idx2, d.b, d.k2 + 1, d.f1);
        scatter_aggregate(&mut dh1, &dnbr2, &batch.idx2, &batch.w2, d.b, d.k2 + 1, d.f1, true);
        // relu
        let dz1 = relu_grad(&fwd.z1, &dh1);
        // layer 1 update (no gradient into feat0 needed)
        let dw1s = matmul_at_b(&fwd.self1, &dz1, d.v1_cap, d.f0, d.f1);
        let dw1n = matmul_at_b(&fwd.agg1, &dz1, d.v1_cap, d.f0, d.f1);
        let db1 = col_sums(&dz1, d.v1_cap, d.f1);
        vec![dw1s, dw1n, db1, dw2s, dw2n, db2]
    }
}

/// Forward-pass intermediates kept for the backward pass.
struct Forward {
    agg1: Vec<f32>,
    z1: Vec<f32>,
    agg2: Vec<f32>,
    logits: Vec<f32>,
    /// SAGE only: gathered self rows per layer (empty for GCN).
    self1: Vec<f32>,
    self2: Vec<f32>,
}

/// The canonical parameter list of `python/compile/model.py::init_params`.
fn expected_params(kind: ModelKind, d: &ArtifactDims) -> Vec<(String, Vec<usize>)> {
    let (f0, f1, f2) = (d.f0, d.f1, d.f2);
    match kind {
        ModelKind::Gcn => vec![
            ("w1".into(), vec![f0, f1]),
            ("b1".into(), vec![f1]),
            ("w2".into(), vec![f1, f2]),
            ("b2".into(), vec![f2]),
        ],
        ModelKind::Sage => vec![
            ("w1_self".into(), vec![f0, f1]),
            ("w1_nbr".into(), vec![f0, f1]),
            ("b1".into(), vec![f1]),
            ("w2_self".into(), vec![f1, f2]),
            ("w2_nbr".into(), vec![f1, f2]),
            ("b2".into(), vec![f2]),
        ],
    }
}

/// `out[r] = Σ_c w[r,c]·h[idx[r,c]]` over feature width `f`; with
/// `skip_self` the self column (c = 0) is excluded (SAGE neighbor mean).
fn aggregate(
    h: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
    skip_self: bool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * f];
    let c0 = usize::from(skip_self);
    for r in 0..rows {
        for c in c0..k {
            let weight = w[r * k + c];
            if weight == 0.0 {
                continue;
            }
            let src = idx[r * k + c] as usize;
            let (dst, src_row) = (&mut out[r * f..(r + 1) * f], &h[src * f..(src + 1) * f]);
            for j in 0..f {
                dst[j] += weight * src_row[j];
            }
        }
    }
    out
}

/// Transpose of [`aggregate`]: `dh[idx[r,c]] += w[r,c]·dout[r]`.
fn scatter_aggregate(
    dh: &mut [f32],
    dout: &[f32],
    idx: &[i32],
    w: &[f32],
    rows: usize,
    k: usize,
    f: usize,
    skip_self: bool,
) {
    let c0 = usize::from(skip_self);
    for r in 0..rows {
        for c in c0..k {
            let weight = w[r * k + c];
            if weight == 0.0 {
                continue;
            }
            let src = idx[r * k + c] as usize;
            for j in 0..f {
                dh[src * f + j] += weight * dout[r * f + j];
            }
        }
    }
}

/// Gather the self rows `h[idx[r,0]]` (SAGE's W_self input).
fn take_rows(h: &[f32], idx: &[i32], rows: usize, k: usize, f: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * f];
    for r in 0..rows {
        let src = idx[r * k] as usize;
        out[r * f..(r + 1) * f].copy_from_slice(&h[src * f..(src + 1) * f]);
    }
    out
}

/// Transpose of [`take_rows`]: `dh[idx[r,0]] += dout[r]`.
fn scatter_self(dh: &mut [f32], dout: &[f32], idx: &[i32], rows: usize, k: usize, f: usize) {
    for r in 0..rows {
        let src = idx[r * k] as usize;
        for j in 0..f {
            dh[src * f + j] += dout[r * f + j];
        }
    }
}

/// `x[n, fin] · w[fin, fout] + bias` row-major.
fn matmul_bias(x: &[f32], w: &[f32], bias: &[f32], n: usize, fin: usize, fout: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * fout];
    for r in 0..n {
        let orow = &mut out[r * fout..(r + 1) * fout];
        orow.copy_from_slice(bias);
        for kk in 0..fin {
            let xv = x[r * fin + kk];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * fout..(kk + 1) * fout];
            for j in 0..fout {
                orow[j] += xv * wrow[j];
            }
        }
    }
    out
}

/// `out += x[n, fin] · w[fin, fout]` (second matmul path of a SAGE layer).
fn add_matmul(out: &mut [f32], x: &[f32], w: &[f32], n: usize, fin: usize, fout: usize) {
    for r in 0..n {
        for kk in 0..fin {
            let xv = x[r * fin + kk];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * fout..(kk + 1) * fout];
            let orow = &mut out[r * fout..(r + 1) * fout];
            for j in 0..fout {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

/// `aᵀ·b` for `a[n, fa]`, `b[n, fb]` → `[fa, fb]` (weight gradients).
fn matmul_at_b(a: &[f32], b: &[f32], n: usize, fa: usize, fb: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; fa * fb];
    for r in 0..n {
        for kk in 0..fa {
            let av = a[r * fa + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[r * fb..(r + 1) * fb];
            let orow = &mut out[kk * fb..(kk + 1) * fb];
            for j in 0..fb {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// `a[n, fa] · wᵀ` for `w[fb, fa]` → `[n, fb]` (input gradients).
fn matmul_b_t(a: &[f32], w: &[f32], n: usize, fa: usize, fb: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * fb];
    for r in 0..n {
        let arow = &a[r * fa..(r + 1) * fa];
        let orow = &mut out[r * fb..(r + 1) * fb];
        for kk in 0..fb {
            let wrow = &w[kk * fa..(kk + 1) * fa];
            let mut acc = 0.0f32;
            for j in 0..fa {
                acc += arow[j] * wrow[j];
            }
            orow[kk] = acc;
        }
    }
    out
}

fn col_sums(x: &[f32], n: usize, f: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; f];
    for r in 0..n {
        for j in 0..f {
            out[j] += x[r * f + j];
        }
    }
    out
}

fn relu(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| v.max(0.0)).collect()
}

/// Gradient through relu: pass where the pre-activation was positive
/// (zero at exactly 0, matching jax.nn.relu's convention).
fn relu_grad(z: &[f32], dh: &[f32]) -> Vec<f32> {
    z.iter().zip(dh).map(|(&zv, &dv)| if zv > 0.0 { dv } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::util::rng::Rng;

    fn tiny_entry(model: &str, kind: &str) -> ArtifactEntry {
        Manifest::builtin(std::path::Path::new("/tmp"))
            .find(kind, model, "tiny")
            .unwrap()
            .clone()
    }

    fn random_batch(d: &ArtifactDims, seed: u64) -> BatchBuffers {
        let mut rng = Rng::new(seed);
        let k1 = d.k1 + 1;
        let k2 = d.k2 + 1;
        // a self-consistent random padded batch: n real rows per level
        let n_v0 = d.v0_cap / 2;
        let n_v1 = d.v1_cap / 2;
        let n_t = d.b / 2;
        let feat0: Vec<f32> = (0..d.v0_cap * d.f0).map(|_| rng.f32() - 0.5).collect();
        let mut idx1 = vec![0i32; d.v1_cap * k1];
        let mut w1 = vec![0f32; d.v1_cap * k1];
        for r in 0..n_v1 {
            for c in 0..k1 {
                idx1[r * k1 + c] = rng.index(n_v0) as i32;
                w1[r * k1 + c] = rng.f32();
            }
        }
        let mut idx2 = vec![0i32; d.b * k2];
        let mut w2 = vec![0f32; d.b * k2];
        for r in 0..n_t {
            for c in 0..k2 {
                idx2[r * k2 + c] = rng.index(n_v1) as i32;
                w2[r * k2 + c] = rng.f32();
            }
        }
        let labels: Vec<i32> = (0..d.b).map(|_| rng.index(d.f2) as i32).collect();
        let mut mask = vec![0f32; d.b];
        for m in mask.iter_mut().take(n_t) {
            *m = 1.0;
        }
        BatchBuffers { feat0, idx1, w1, idx2, w2, labels, mask }
    }

    fn loss_of(model: &RefModel, params: &[Vec<f32>], batch: &BatchBuffers) -> f64 {
        model.train_step(params, batch).unwrap().loss as f64
    }

    /// Central-difference gradient check: the analytic backward pass must
    /// match numerical differentiation on sampled coordinates.
    fn grad_check(model_name: &str) {
        let entry = tiny_entry(model_name, "train");
        let model = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 9).data;
        let batch = random_batch(&entry.dims, 4);
        let out = model.train_step(&params, &batch).unwrap();
        let mut rng = Rng::new(77);
        let eps = 1e-3f32;
        let mut checked = 0;
        for (pi, p) in params.iter().enumerate() {
            for _ in 0..4 {
                let i = rng.index(p.len());
                let mut plus = params.clone();
                plus[pi][i] += eps;
                let mut minus = params.clone();
                minus[pi][i] -= eps;
                let num = (loss_of(&model, &plus, &batch) - loss_of(&model, &minus, &batch))
                    / (2.0 * eps as f64);
                let ana = out.grads[pi][i] as f64;
                assert!(
                    (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                    "{model_name} param {pi}[{i}]: numeric {num} vs analytic {ana}"
                );
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn gcn_gradients_match_finite_differences() {
        grad_check("gcn");
    }

    #[test]
    fn sage_gradients_match_finite_differences() {
        grad_check("sage");
    }

    #[test]
    fn loss_is_masked_mean_ce() {
        let entry = tiny_entry("gcn", "train");
        let model = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 2).data;
        let batch = random_batch(&entry.dims, 6);
        let out = model.train_step(&params, &batch).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        // all-zero mask: loss 0, grads 0
        let mut b2 = batch;
        b2.mask.iter_mut().for_each(|m| *m = 0.0);
        let out2 = model.train_step(&params, &b2).unwrap();
        assert_eq!(out2.loss, 0.0);
        assert!(out2.grads.iter().flatten().all(|&g| g == 0.0));
    }

    #[test]
    fn rejects_unknown_model_and_bad_shapes() {
        let mut entry = tiny_entry("gcn", "train");
        entry.model = "transformer".into();
        assert!(RefModel::new(&entry).is_err());
        let mut entry = tiny_entry("gcn", "train");
        entry.params[0].1 = vec![1, 1];
        assert!(RefModel::new(&entry).is_err());
    }

    #[test]
    fn deterministic_bitwise() {
        let entry = tiny_entry("sage", "train");
        let model = RefModel::new(&entry).unwrap();
        let params = crate::coordinator::params::ParamSet::init(&entry, 5).data;
        let batch = random_batch(&entry.dims, 8);
        let a = model.train_step(&params, &batch).unwrap();
        let b = model.train_step(&params, &batch).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.grads, b.grads);
    }
}
