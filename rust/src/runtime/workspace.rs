//! Shape-keyed scratch arena for the reference executor.
//!
//! Every intermediate a train/predict step needs — per-layer
//! aggregations, pre- and post-activations, backward gradients and the
//! matmul scratch they flow through — is allocated **once** per
//! [`RefModel`](super::reference::RefModel) from the artifact's static
//! [`ArtifactDims`], then rewritten in place on every step. Gradients
//! leave through a recycled `GradBuffers` the trainer pools, so the
//! reference executor's steady state is fully allocation-free — the
//! executor half of the zero-allocation hot path (DESIGN.md §Hot-path
//! memory & kernels and §SIMD dispatch & gradient sync).
//!
//! Ownership map (layer l = 1..=L stored at index l-1; shapes are the
//! padded wire-format capacities, but kernels only touch the batch's
//! real row counts):
//!
//! | buffer      | shape                | role                               |
//! |-------------|----------------------|------------------------------------|
//! | `agg[l-1]`  | `[caps[l], f[l-1]]`  | neighbor aggregation input         |
//! | `selfr[l-1]`| `[caps[l], f[l-1]]`  | gathered self rows (SAGE only)     |
//! | `z[l-1]`    | `[caps[l], f[l]]`    | pre-activation; `z[L-1]` = logits  |
//! | `h[l-1]`    | `[caps[l], f[l]]`    | post-relu activation (l < L)       |
//! | `dz[l-1]`   | `[caps[l], f[l]]`    | ∂loss/∂z; `dz[L-1]` starts as dlogits |
//! | `dx[l-1]`   | `[caps[l], f[l-1]]`  | backward matmul scratch (l > 1)    |
//! | `dx2[l-1]`  | `[caps[l], f[l-1]]`  | second scratch (SAGE ∂nbr, l > 1)  |

use super::manifest::ArtifactDims;

/// Pre-sized executor scratch; see the module docs for the ownership map.
pub struct Workspace {
    pub agg: Vec<Vec<f32>>,
    pub selfr: Vec<Vec<f32>>,
    pub z: Vec<Vec<f32>>,
    pub h: Vec<Vec<f32>>,
    pub dz: Vec<Vec<f32>>,
    pub dx: Vec<Vec<f32>>,
    pub dx2: Vec<Vec<f32>>,
    /// Per-level row counts the current step computes (`n` clamped to the
    /// capacities for training; the full capacities for prediction).
    /// Lives in the workspace so a step allocates nothing but its
    /// gradient output.
    pub rows: Vec<usize>,
}

impl Workspace {
    /// Allocate every buffer an L-layer model of these dims will touch
    /// (`sage` additionally sizes the self-row and second-scratch lanes).
    pub fn new(dims: &ArtifactDims, sage: bool) -> Workspace {
        let lcount = dims.layers();
        let mut ws = Workspace {
            agg: Vec::with_capacity(lcount),
            selfr: Vec::with_capacity(lcount),
            z: Vec::with_capacity(lcount),
            h: Vec::with_capacity(lcount),
            dz: Vec::with_capacity(lcount),
            dx: Vec::with_capacity(lcount),
            dx2: Vec::with_capacity(lcount),
            rows: dims.caps.clone(),
        };
        for l in 1..=lcount {
            let rows = dims.caps[l];
            let (fin, fout) = (dims.f[l - 1], dims.f[l]);
            ws.agg.push(vec![0.0; rows * fin]);
            ws.selfr.push(if sage { vec![0.0; rows * fin] } else { Vec::new() });
            ws.z.push(vec![0.0; rows * fout]);
            ws.h.push(if l < lcount { vec![0.0; rows * fout] } else { Vec::new() });
            ws.dz.push(vec![0.0; rows * fout]);
            ws.dx.push(if l > 1 { vec![0.0; rows * fin] } else { Vec::new() });
            ws.dx2.push(if sage && l > 1 { vec![0.0; rows * fin] } else { Vec::new() });
        }
        ws
    }

    /// Total resident bytes (observability; the arena never grows).
    pub fn bytes(&self) -> usize {
        let lanes = [&self.agg, &self.selfr, &self.z, &self.h, &self.dz, &self.dx, &self.dx2];
        lanes
            .iter()
            .map(|lane| lane.iter().map(|b| b.len() * 4).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ArtifactDims {
        ArtifactDims::from_batch(8, &[3, 2], &[6, 5, 4])
    }

    #[test]
    fn gcn_workspace_shapes_follow_the_dims() {
        let d = dims();
        let ws = Workspace::new(&d, false);
        assert_eq!(ws.agg[0].len(), d.caps[1] * d.f[0]);
        assert_eq!(ws.agg[1].len(), d.caps[2] * d.f[1]);
        assert_eq!(ws.z[1].len(), d.b * d.classes());
        assert_eq!(ws.dz[1].len(), d.b * d.classes());
        assert_eq!(ws.h[0].len(), d.caps[1] * d.f[1]);
        assert!(ws.h[1].is_empty(), "no relu after the output layer");
        assert!(ws.selfr.iter().all(|b| b.is_empty()), "selfr is SAGE-only");
        assert!(ws.dx[0].is_empty(), "layer 1 has no input gradient");
        assert_eq!(ws.dx[1].len(), d.caps[2] * d.f[1]);
        assert!(ws.bytes() > 0);
    }

    #[test]
    fn sage_workspace_adds_self_and_second_scratch_lanes() {
        let d = dims();
        let ws = Workspace::new(&d, true);
        assert_eq!(ws.selfr[0].len(), d.caps[1] * d.f[0]);
        assert_eq!(ws.dx2[1].len(), d.caps[2] * d.f[1]);
        assert!(ws.dx2[0].is_empty());
        assert!(ws.bytes() > Workspace::new(&d, false).bytes());
    }
}
