//! Shape-keyed scratch arena for the reference executor.
//!
//! Every intermediate a train/predict step needs — per-layer
//! aggregations, pre- and post-activations, backward gradients and the
//! matmul scratch they flow through — is allocated **once** per
//! [`RefModel`](super::reference::RefModel) from the artifact's static
//! [`ArtifactDims`], then rewritten in place on every step. Gradients
//! leave through a recycled `GradBuffers` the trainer pools, so the
//! reference executor's steady state is fully allocation-free — the
//! executor half of the zero-allocation hot path (DESIGN.md §Hot-path
//! memory & kernels and §SIMD dispatch & gradient sync).
//!
//! Which lanes exist is driven by the model's [`LaneSpec`] (DESIGN.md
//! §Model zoo): each `ModelOps` implementation declares the scratch it
//! needs and the arena sizes exactly those lanes, so e.g. a GCN
//! workspace carries no attention lanes and a GAT workspace no
//! aggregation lane.
//!
//! Ownership map (layer l = 1..=L stored at index l-1; shapes are the
//! padded wire-format capacities, but kernels only touch the batch's
//! real row counts; `k_l = fanouts[l-1] + 1` is the padded list width):
//!
//! | buffer          | shape                 | role                               |
//! |-----------------|-----------------------|------------------------------------|
//! | `agg[l-1]`      | `[caps[l], f[l-1]]`   | neighbor aggregation input         |
//! | `selfr[l-1]`    | `[caps[l], f[l-1]]`   | gathered self rows (SAGE/GIN)      |
//! | `z[l-1]`        | `[caps[l], f[l]]`     | pre-activation; `z[L-1]` = logits  |
//! | `h[l-1]`        | `[caps[l], f[l]]`     | post-relu activation (l < L)       |
//! | `dz[l-1]`       | `[caps[l], f[l]]`     | ∂loss/∂z; `dz[L-1]` starts as dlogits |
//! | `dx[l-1]`       | `[caps[l], f[l-1]]`   | backward matmul scratch (l > 1; GIN all l) |
//! | `dx2[l-1]`      | `[caps[l], f[l-1]]`   | second scratch (SAGE ∂nbr, l > 1)  |
//! | `att_ht[l-1]`   | `[caps[l-1], f[l]]`   | GAT transformed below-level rows   |
//! | `att_dht[l-1]`  | `[caps[l-1], f[l]]`   | GAT ∂loss/∂ht accumulator          |
//! | `att_sself[l-1]`| `[caps[l-1]]`         | GAT per-vertex self scores (bwd: ∂scores) |
//! | `att_snbr[l-1]` | `[caps[l-1]]`         | GAT per-vertex nbr scores (bwd: ∂scores) |
//! | `att_alpha[l-1]`| `[caps[l], k_l]`      | GAT per-edge attention weights     |
//! | `att_dalpha[l-1]`| `[caps[l], k_l]`     | GAT per-edge gradient lane         |
//! | `mlp_z1[l-1]`   | `[caps[l], f[l]]`     | GIN MLP hidden pre-activation      |
//! | `mlp_h1[l-1]`   | `[caps[l], f[l]]`     | GIN MLP hidden activation          |
//! | `mlp_dh1[l-1]`  | `[caps[l], f[l]]`     | GIN MLP hidden gradient            |

use super::manifest::ArtifactDims;

/// Which scratch lanes a model's forward/backward stages touch — the
/// model-ops layer's declaration the arena sizes from. All-false plus
/// struct-update syntax keeps each model's spec to the lanes it names.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaneSpec {
    /// Neighbor-aggregation input lane (`agg`).
    pub agg: bool,
    /// Gathered self rows (`selfr`) — SAGE's W_self input, GIN's
    /// (1+ε)-weighted self path.
    pub selfr: bool,
    /// Backward input-gradient scratch (`dx`) at layers l > 1.
    pub dx: bool,
    /// `dx` also at layer 1 (GIN: ∂ε needs the layer-1 ∂aggregate).
    pub dx_at_layer1: bool,
    /// Second backward scratch (`dx2`) at layers l > 1 (SAGE ∂nbr).
    pub dx2: bool,
    /// GAT attention lanes (`att_*`).
    pub attention: bool,
    /// GIN 2-layer-MLP update lanes (`mlp_*`).
    pub mlp: bool,
}

/// Pre-sized executor scratch; see the module docs for the ownership map.
pub struct Workspace {
    pub agg: Vec<Vec<f32>>,
    pub selfr: Vec<Vec<f32>>,
    pub z: Vec<Vec<f32>>,
    pub h: Vec<Vec<f32>>,
    pub dz: Vec<Vec<f32>>,
    pub dx: Vec<Vec<f32>>,
    pub dx2: Vec<Vec<f32>>,
    pub att_ht: Vec<Vec<f32>>,
    pub att_dht: Vec<Vec<f32>>,
    pub att_sself: Vec<Vec<f32>>,
    pub att_snbr: Vec<Vec<f32>>,
    pub att_alpha: Vec<Vec<f32>>,
    pub att_dalpha: Vec<Vec<f32>>,
    pub mlp_z1: Vec<Vec<f32>>,
    pub mlp_h1: Vec<Vec<f32>>,
    pub mlp_dh1: Vec<Vec<f32>>,
    /// Per-level row counts the current step computes (`n` clamped to the
    /// capacities for training; the full capacities for prediction).
    /// Lives in the workspace so a step allocates nothing but its
    /// gradient output.
    pub rows: Vec<usize>,
}

fn lane(on: bool, len: usize) -> Vec<f32> {
    if on {
        vec![0.0; len]
    } else {
        Vec::new()
    }
}

impl Workspace {
    /// Allocate every buffer an L-layer model of these dims will touch,
    /// per the model's [`LaneSpec`].
    pub fn new(dims: &ArtifactDims, spec: LaneSpec) -> Workspace {
        let lcount = dims.layers();
        let mut ws = Workspace {
            agg: Vec::with_capacity(lcount),
            selfr: Vec::with_capacity(lcount),
            z: Vec::with_capacity(lcount),
            h: Vec::with_capacity(lcount),
            dz: Vec::with_capacity(lcount),
            dx: Vec::with_capacity(lcount),
            dx2: Vec::with_capacity(lcount),
            att_ht: Vec::with_capacity(lcount),
            att_dht: Vec::with_capacity(lcount),
            att_sself: Vec::with_capacity(lcount),
            att_snbr: Vec::with_capacity(lcount),
            att_alpha: Vec::with_capacity(lcount),
            att_dalpha: Vec::with_capacity(lcount),
            mlp_z1: Vec::with_capacity(lcount),
            mlp_h1: Vec::with_capacity(lcount),
            mlp_dh1: Vec::with_capacity(lcount),
            rows: dims.caps.clone(),
        };
        for l in 1..=lcount {
            let rows = dims.caps[l];
            let below = dims.caps[l - 1];
            let k = dims.fanouts[l - 1] + 1;
            let (fin, fout) = (dims.f[l - 1], dims.f[l]);
            ws.agg.push(lane(spec.agg, rows * fin));
            ws.selfr.push(lane(spec.selfr, rows * fin));
            ws.z.push(vec![0.0; rows * fout]);
            ws.h.push(lane(l < lcount, rows * fout));
            ws.dz.push(vec![0.0; rows * fout]);
            ws.dx.push(lane(
                (spec.dx && l > 1) || (spec.dx_at_layer1 && l == 1),
                rows * fin,
            ));
            ws.dx2.push(lane(spec.dx2 && l > 1, rows * fin));
            ws.att_ht.push(lane(spec.attention, below * fout));
            ws.att_dht.push(lane(spec.attention, below * fout));
            ws.att_sself.push(lane(spec.attention, below));
            ws.att_snbr.push(lane(spec.attention, below));
            ws.att_alpha.push(lane(spec.attention, rows * k));
            ws.att_dalpha.push(lane(spec.attention, rows * k));
            ws.mlp_z1.push(lane(spec.mlp, rows * fout));
            ws.mlp_h1.push(lane(spec.mlp, rows * fout));
            ws.mlp_dh1.push(lane(spec.mlp, rows * fout));
        }
        ws
    }

    /// Total resident bytes (observability; the arena never grows).
    pub fn bytes(&self) -> usize {
        let lanes = [
            &self.agg,
            &self.selfr,
            &self.z,
            &self.h,
            &self.dz,
            &self.dx,
            &self.dx2,
            &self.att_ht,
            &self.att_dht,
            &self.att_sself,
            &self.att_snbr,
            &self.att_alpha,
            &self.att_dalpha,
            &self.mlp_z1,
            &self.mlp_h1,
            &self.mlp_dh1,
        ];
        lanes
            .iter()
            .map(|lane| lane.iter().map(|b| b.len() * 4).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ArtifactDims {
        ArtifactDims::from_batch(8, &[3, 2], &[6, 5, 4])
    }

    fn gcn_spec() -> LaneSpec {
        LaneSpec { agg: true, dx: true, ..LaneSpec::default() }
    }

    fn sage_spec() -> LaneSpec {
        LaneSpec { agg: true, selfr: true, dx: true, dx2: true, ..LaneSpec::default() }
    }

    #[test]
    fn gcn_workspace_shapes_follow_the_dims() {
        let d = dims();
        let ws = Workspace::new(&d, gcn_spec());
        assert_eq!(ws.agg[0].len(), d.caps[1] * d.f[0]);
        assert_eq!(ws.agg[1].len(), d.caps[2] * d.f[1]);
        assert_eq!(ws.z[1].len(), d.b * d.classes());
        assert_eq!(ws.dz[1].len(), d.b * d.classes());
        assert_eq!(ws.h[0].len(), d.caps[1] * d.f[1]);
        assert!(ws.h[1].is_empty(), "no relu after the output layer");
        assert!(ws.selfr.iter().all(|b| b.is_empty()), "selfr is SAGE/GIN-only");
        assert!(ws.dx[0].is_empty(), "layer 1 has no input gradient");
        assert_eq!(ws.dx[1].len(), d.caps[2] * d.f[1]);
        assert!(ws.att_alpha.iter().all(|b| b.is_empty()), "attention lanes are GAT-only");
        assert!(ws.mlp_z1.iter().all(|b| b.is_empty()), "MLP lanes are GIN-only");
        assert!(ws.bytes() > 0);
    }

    #[test]
    fn sage_workspace_adds_self_and_second_scratch_lanes() {
        let d = dims();
        let ws = Workspace::new(&d, sage_spec());
        assert_eq!(ws.selfr[0].len(), d.caps[1] * d.f[0]);
        assert_eq!(ws.dx2[1].len(), d.caps[2] * d.f[1]);
        assert!(ws.dx2[0].is_empty());
        assert!(ws.bytes() > Workspace::new(&d, gcn_spec()).bytes());
    }

    #[test]
    fn attention_lanes_follow_the_edge_shapes() {
        let d = dims();
        let spec = LaneSpec { attention: true, ..LaneSpec::default() };
        let ws = Workspace::new(&d, spec);
        // ht/dht live on the below level with the layer's output width
        assert_eq!(ws.att_ht[0].len(), d.caps[0] * d.f[1]);
        assert_eq!(ws.att_dht[1].len(), d.caps[1] * d.f[2]);
        // per-vertex scores are one scalar per below-level row
        assert_eq!(ws.att_sself[0].len(), d.caps[0]);
        assert_eq!(ws.att_snbr[1].len(), d.caps[1]);
        // alpha is per padded edge: rows × (fanout + 1)
        assert_eq!(ws.att_alpha[0].len(), d.caps[1] * (d.fanouts[0] + 1));
        assert_eq!(ws.att_dalpha[1].len(), d.caps[2] * (d.fanouts[1] + 1));
        // GAT needs neither the aggregation lane nor the dx scratch
        assert!(ws.agg.iter().all(|b| b.is_empty()));
        assert!(ws.dx.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn gin_spec_sizes_mlp_lanes_and_layer1_dx() {
        let d = dims();
        let spec = LaneSpec {
            agg: true,
            selfr: true,
            dx: true,
            dx_at_layer1: true,
            mlp: true,
            ..LaneSpec::default()
        };
        let ws = Workspace::new(&d, spec);
        assert_eq!(ws.mlp_z1[0].len(), d.caps[1] * d.f[1]);
        assert_eq!(ws.mlp_h1[1].len(), d.caps[2] * d.f[2]);
        assert_eq!(ws.mlp_dh1[1].len(), d.caps[2] * d.f[2]);
        // unlike GCN/SAGE, dx exists at layer 1 too (∂ε needs ∂agg)
        assert_eq!(ws.dx[0].len(), d.caps[1] * d.f[0]);
        assert_eq!(ws.dx[1].len(), d.caps[2] * d.f[1]);
    }
}
