//! The sampled mini-batch container and its aggregation-weight modes.

/// Static capacities of the padded wire format (must match the AOT
/// artifact's shapes), generalized to arbitrary depth L.
///
/// Levels are numbered 0..=L: level L holds the targets, level 0 the
/// input-feature rows. Layer l (1-based) aggregates level l-1 into level
/// l. The fanout-vector order is defined **once** in DESIGN.md
/// §Mini-batch wire format: `fanouts[l-1]` is the layer-l fanout, so the
/// input-side hop comes first and the target-side hop last (DistDGL's
/// `--fan-out 15,10,5` order; the paper's 2-layer default is `[25, 10]`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchDims {
    /// Target capacity (batch size B = |V^L| capacity).
    pub b: usize,
    /// Per-layer fanouts; length L (see the type-level docs for order).
    pub fanouts: Vec<usize>,
    /// Per-level vertex capacities: `caps[L] = b` and
    /// `caps[l-1] = caps[l]·(fanouts[l-1]+1)`.
    pub caps: Vec<usize>,
}

impl BatchDims {
    /// Number of GNN layers L.
    pub fn layers(&self) -> usize {
        self.fanouts.len()
    }

    /// Level-0 (input) vertex capacity — the feature-gather buffer rows.
    pub fn v0_cap(&self) -> usize {
        self.caps[0]
    }

    /// Width of layer l's idx/w rows: fanout plus the self column.
    pub fn row_width(&self, l: usize) -> usize {
        self.fanouts[l - 1] + 1
    }
}

/// How aggregation weights are computed from the sampled block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightMode {
    /// GCN: symmetric normalisation 1/√(d̂(v)·d̂(u)) with self edge,
    /// using full-graph degrees (+1 for the self loop).
    GcnNorm,
    /// GraphSAGE-mean: neighbor columns weighted 1/k_real, self column
    /// weight 1 (consumed by the separate W_self path in the model).
    SageMean,
    /// Unit weights on every real entry (self column included): the model
    /// computes its own edge coefficients — GAT's learned attention, GIN's
    /// ε-weighted sum — so the wire weights only mark real vs padding.
    Unit,
}

impl WeightMode {
    pub fn for_model(model: &str) -> anyhow::Result<WeightMode> {
        match model.to_ascii_lowercase().as_str() {
            "gcn" => Ok(WeightMode::GcnNorm),
            "graphsage" | "sage" | "gsg" => Ok(WeightMode::SageMean),
            "gat" | "gin" => Ok(WeightMode::Unit),
            _ => anyhow::bail!(
                "unknown model '{model}', expected one of {} (graphsage/gsg alias sage)",
                crate::runtime::model_ops::MODEL_NAMES.join("|")
            ),
        }
    }
}

/// One sampled mini-batch in fixed-shape padded form.
///
/// Index arrays use `i32` (what the HLO gather expects); padding rows/
/// columns carry index 0 and weight 0 so they contribute nothing. Field
/// layout follows DESIGN.md §Mini-batch wire format.
#[derive(Clone, Debug)]
pub struct MiniBatch {
    pub dims: BatchDims,
    /// Partition this batch was sampled from (scheduler bookkeeping).
    pub part_id: usize,
    /// Monotonic production index within the epoch (scheduler ordering).
    pub seq: usize,

    /// Real counts per level (`n[l]` ≤ `dims.caps[l]`); `n[L]` targets.
    pub n: Vec<usize>,
    /// Global vertex ids per level, padded to `caps[l]` with id 0.
    /// `v[L]` are the targets, `v[0]` the feature-gather rows.
    pub v: Vec<Vec<u32>>,
    /// `idx[l-1]`: `[caps[l], fanouts[l-1]+1]` row-major positions into
    /// level (l-1)'s list; column 0 = self.
    pub idx: Vec<Vec<i32>>,
    /// Matching aggregation weights (zero = padding).
    pub w: Vec<Vec<f32>>,

    /// Per-target class labels and loss mask (0 for padding rows).
    pub labels: Vec<u32>,
    pub mask: Vec<f32>,
}

impl MiniBatch {
    /// An all-padding batch with capacity-sized buffers — the recyclable
    /// carcass `Sampler::sample_into` writes into. Level lists are empty
    /// (capacity reserved), index/weight blocks zeroed; `validate` only
    /// holds after a sample pass fills it.
    pub fn empty(dims: BatchDims) -> MiniBatch {
        let lcount = dims.layers();
        let v = dims.caps.iter().map(|&c| Vec::with_capacity(c)).collect();
        let idx = (1..=lcount).map(|l| vec![0i32; dims.caps[l] * dims.row_width(l)]).collect();
        let w = (1..=lcount).map(|l| vec![0f32; dims.caps[l] * dims.row_width(l)]).collect();
        MiniBatch {
            part_id: 0,
            seq: 0,
            n: vec![0; lcount + 1],
            v,
            idx,
            w,
            labels: vec![0; dims.b],
            mask: vec![0.0; dims.b],
            dims,
        }
    }

    /// Number of GNN layers L.
    pub fn layers(&self) -> usize {
        self.dims.layers()
    }

    /// Real target count (`n[L]`).
    pub fn n_targets(&self) -> usize {
        self.n[self.dims.layers()]
    }

    /// The real (unpadded) level-0 vertex ids — what the comm layer
    /// accounts feature traffic for.
    pub fn level0(&self) -> &[u32] {
        &self.v[0][..self.n[0]]
    }

    /// Sum over levels of sampled-vertex counts — the unit of the paper's
    /// NVTPS throughput metric (Eq. 3 numerator, per batch).
    pub fn vertices_traversed(&self) -> usize {
        self.n.iter().sum()
    }

    /// Edges in layer l's sampled adjacency (|A^l|), self edges included —
    /// drives the aggregation compute term (Eq. 8).
    pub fn edges(&self, l: usize) -> usize {
        self.w[l - 1].iter().filter(|&&w| w != 0.0).count()
    }

    /// Structural invariants; used by tests and debug assertions.
    pub fn validate(&self) -> anyhow::Result<()> {
        let d = &self.dims;
        let lcount = d.layers();
        anyhow::ensure!(lcount >= 1, "batch needs at least one layer");
        anyhow::ensure!(d.caps.len() == lcount + 1 && d.caps[lcount] == d.b, "caps shape");
        anyhow::ensure!(self.v.len() == lcount + 1 && self.n.len() == lcount + 1, "level count");
        anyhow::ensure!(self.idx.len() == lcount && self.w.len() == lcount, "layer count");
        for l in 0..=lcount {
            anyhow::ensure!(self.v[l].len() == d.caps[l], "v[{l}] len");
            anyhow::ensure!(self.n[l] <= d.caps[l], "n[{l}] exceeds capacity");
        }
        anyhow::ensure!(self.labels.len() == d.b && self.mask.len() == d.b, "label/mask len");
        for l in 1..=lcount {
            let k = d.row_width(l);
            anyhow::ensure!(self.idx[l - 1].len() == d.caps[l] * k, "idx[{}] len", l - 1);
            anyhow::ensure!(self.w[l - 1].len() == self.idx[l - 1].len(), "w[{}] len", l - 1);
            let below = self.n[l - 1].max(1);
            for (i, &ix) in self.idx[l - 1].iter().enumerate() {
                anyhow::ensure!(
                    (ix as usize) < below,
                    "idx[{}][{i}]={ix} out of range (n[{}]={})",
                    l - 1,
                    l - 1,
                    self.n[l - 1]
                );
            }
        }
        for t in self.n[lcount]..d.b {
            anyhow::ensure!(self.mask[t] == 0.0, "padding target {t} not masked");
        }
        Ok(())
    }

    /// Host-side reference forward aggregation for layer `l` (used by
    /// integration tests to cross-check the compiled kernel): given
    /// `h [n rows of level l-1, f]`, produce `[caps[l], f]`.
    pub fn aggregate_ref(&self, l: usize, h: &[f32], f: usize) -> Vec<f32> {
        let d = &self.dims;
        let k = d.row_width(l);
        let rows = d.caps[l];
        let (idx, w) = (&self.idx[l - 1], &self.w[l - 1]);
        let mut out = vec![0.0f32; rows * f];
        for r in 0..rows {
            for c in 0..k {
                let weight = w[r * k + c];
                if weight == 0.0 {
                    continue;
                }
                let src = idx[r * k + c] as usize;
                for j in 0..f {
                    out[r * f + j] += weight * h[src * f + j];
                }
            }
        }
        out
    }
}
