//! The sampled mini-batch container and its aggregation-weight modes.

/// Static capacities of the padded wire format (must match the AOT
/// artifact's shapes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchDims {
    /// Target capacity (batch size B = |V^2| capacity).
    pub b: usize,
    /// Layer-1 vertex capacity (B·(k2+1)).
    pub v1_cap: usize,
    /// Layer-0 vertex capacity (v1_cap·(k1+1)).
    pub v0_cap: usize,
    pub k1: usize,
    pub k2: usize,
}

/// How aggregation weights are computed from the sampled block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightMode {
    /// GCN: symmetric normalisation 1/√(d̂(v)·d̂(u)) with self edge,
    /// using full-graph degrees (+1 for the self loop).
    GcnNorm,
    /// GraphSAGE-mean: neighbor columns weighted 1/k_real, self column
    /// weight 1 (consumed by the separate W_self path in the model).
    SageMean,
}

impl WeightMode {
    pub fn for_model(model: &str) -> anyhow::Result<WeightMode> {
        match model.to_ascii_lowercase().as_str() {
            "gcn" => Ok(WeightMode::GcnNorm),
            "graphsage" | "sage" | "gsg" => Ok(WeightMode::SageMean),
            _ => anyhow::bail!("unknown model '{model}' (gcn|graphsage)"),
        }
    }
}

/// One sampled mini-batch in fixed-shape padded form.
///
/// Index arrays use `i32` (what the HLO gather expects); padding rows/
/// columns carry index 0 and weight 0 so they contribute nothing.
#[derive(Clone, Debug)]
pub struct MiniBatch {
    pub dims: BatchDims,
    /// Partition this batch was sampled from (scheduler bookkeeping).
    pub part_id: usize,
    /// Monotonic production index within the epoch (scheduler ordering).
    pub seq: usize,

    /// Real counts (≤ the corresponding capacity).
    pub n_targets: usize,
    pub n_v1: usize,
    pub n_v0: usize,

    /// Global vertex ids per layer; entries ≥ the real count are padding
    /// (id 0). `v2` are the targets.
    pub v2: Vec<u32>,
    pub v1: Vec<u32>,
    pub v0: Vec<u32>,

    /// `[v1_cap, k1+1]` row-major positions into `v0`; col 0 = self.
    pub idx1: Vec<i32>,
    pub w1: Vec<f32>,
    /// `[b, k2+1]` row-major positions into `v1`; col 0 = self.
    pub idx2: Vec<i32>,
    pub w2: Vec<f32>,

    /// Per-target class labels and loss mask (0 for padding rows).
    pub labels: Vec<u32>,
    pub mask: Vec<f32>,
}

impl MiniBatch {
    /// Sum over layers of sampled-vertex counts — the unit of the paper's
    /// NVTPS throughput metric (Eq. 3 numerator, per batch).
    pub fn vertices_traversed(&self) -> usize {
        self.n_targets + self.n_v1 + self.n_v0
    }

    /// Edges in each sampled adjacency (|A^l|), self edges included —
    /// drives the aggregation compute term (Eq. 8).
    pub fn edges_layer1(&self) -> usize {
        self.w1.iter().filter(|&&w| w != 0.0).count()
    }
    pub fn edges_layer2(&self) -> usize {
        self.w2.iter().filter(|&&w| w != 0.0).count()
    }

    /// Structural invariants; used by tests and debug assertions.
    pub fn validate(&self) -> anyhow::Result<()> {
        let d = &self.dims;
        anyhow::ensure!(self.v2.len() == d.b, "v2 len");
        anyhow::ensure!(self.v1.len() == d.v1_cap, "v1 len");
        anyhow::ensure!(self.v0.len() == d.v0_cap, "v0 len");
        anyhow::ensure!(self.idx1.len() == d.v1_cap * (d.k1 + 1), "idx1 len");
        anyhow::ensure!(self.w1.len() == self.idx1.len(), "w1 len");
        anyhow::ensure!(self.idx2.len() == d.b * (d.k2 + 1), "idx2 len");
        anyhow::ensure!(self.w2.len() == self.idx2.len(), "w2 len");
        anyhow::ensure!(self.labels.len() == d.b && self.mask.len() == d.b, "label/mask len");
        anyhow::ensure!(
            self.n_targets <= d.b && self.n_v1 <= d.v1_cap && self.n_v0 <= d.v0_cap,
            "counts exceed capacity"
        );
        for (i, &ix) in self.idx1.iter().enumerate() {
            anyhow::ensure!(
                (ix as usize) < self.n_v0.max(1),
                "idx1[{i}]={ix} out of range (n_v0={})",
                self.n_v0
            );
        }
        for (i, &ix) in self.idx2.iter().enumerate() {
            anyhow::ensure!(
                (ix as usize) < self.n_v1.max(1),
                "idx2[{i}]={ix} out of range (n_v1={})",
                self.n_v1
            );
        }
        for t in self.n_targets..d.b {
            anyhow::ensure!(self.mask[t] == 0.0, "padding target {t} not masked");
        }
        Ok(())
    }

    /// Host-side reference forward aggregation for layer 1 (used by
    /// integration tests to cross-check the compiled kernel): given
    /// `feat0 [n rows of v0, f]`, produce `[v1_cap, f]`.
    pub fn aggregate1_ref(&self, feat0: &[f32], f: usize) -> Vec<f32> {
        let d = &self.dims;
        let k = d.k1 + 1;
        let mut out = vec![0.0f32; d.v1_cap * f];
        for r in 0..d.v1_cap {
            for c in 0..k {
                let w = self.w1[r * k + c];
                if w == 0.0 {
                    continue;
                }
                let src = self.idx1[r * k + c] as usize;
                for j in 0..f {
                    out[r * f + j] += w * feat0[src * f + j];
                }
            }
        }
        out
    }
}
