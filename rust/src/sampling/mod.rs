//! Mini-batch neighbor sampling (the paper's sampling stage, host-side).
//!
//! Layer-wise fanout sampling exactly as DistDGL/PaGraph/P3 do for
//! GraphSAGE-style training: B target vertices, fanout `k2` at layer 2 and
//! `k1` at layer 1 (paper: B=1024, fanouts 25 and 10). The sampled block
//! is emitted in the **fixed-degree padded format** the AOT-compiled
//! kernels consume (DESIGN.md §Mini-batch wire format):
//!
//! - `v1`, `v0`: deduplicated global-vertex lists per layer (layer L's
//!   list is the targets themselves);
//! - `idx_l`: `[|V^l|, k+1]` neighbor positions into layer (l-1)'s list,
//!   column 0 = the vertex itself (self edge);
//! - `w_l`: matching aggregation weights (zero = padding).
//!
//! Sampling runs on the CPU and is overlapped with FPGA compute (Eq. 5),
//! so the implementation avoids per-batch allocation: a [`Sampler`] holds
//! stamped scratch arrays and is reused across batches.

pub mod batch;
pub mod sampler;

pub use batch::{BatchDims, MiniBatch, WeightMode};
pub use sampler::{EpochPlan, Sampler};

/// Fanout configuration (paper defaults: B=1024, fanouts 25 and 10).
#[derive(Clone, Copy, Debug)]
pub struct FanoutConfig {
    pub batch_size: usize,
    /// Layer-1 fanout (neighbors sampled for every layer-1 vertex).
    pub k1: usize,
    /// Layer-2 fanout (neighbors sampled for every target).
    pub k2: usize,
}

impl FanoutConfig {
    pub const PAPER: FanoutConfig = FanoutConfig { batch_size: 1024, k1: 25, k2: 10 };

    /// Fixed capacities of the padded wire format.
    pub fn dims(&self) -> BatchDims {
        let b = self.batch_size;
        let v1_cap = b * (self.k2 + 1);
        let v0_cap = v1_cap * (self.k1 + 1);
        BatchDims { b, v1_cap, v0_cap, k1: self.k1, k2: self.k2 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_dims() {
        let d = FanoutConfig::PAPER.dims();
        assert_eq!(d.b, 1024);
        assert_eq!(d.v1_cap, 1024 * 11);
        assert_eq!(d.v0_cap, 1024 * 11 * 26);
    }
}
