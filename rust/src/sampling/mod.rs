//! Mini-batch neighbor sampling (the paper's sampling stage, host-side).
//!
//! Layer-wise fanout sampling exactly as DistDGL/PaGraph/P3 do for
//! GraphSAGE-style training, generalized to arbitrary depth L: B target
//! vertices and one fanout per layer (paper default: B=1024, fanouts
//! `[25, 10]`). The fanout-vector order and the padded wire format are
//! defined **once** in DESIGN.md §Mini-batch wire format — in short:
//! `fanouts[l-1]` is the layer-l fanout, input-side hop first, target-side
//! hop last (DistDGL's `--fan-out 15,10,5` order). The sampled block is
//! emitted in the fixed-degree padded format the AOT-compiled kernels
//! consume:
//!
//! - `v[l]`: deduplicated global-vertex lists per level 0..=L (level L's
//!   list is the targets themselves);
//! - `idx[l-1]`: `[caps[l], fanouts[l-1]+1]` neighbor positions into level
//!   (l-1)'s list, column 0 = the vertex itself (self edge);
//! - `w[l-1]`: matching aggregation weights (zero = padding).
//!
//! Sampling runs on the CPU and is overlapped with FPGA compute (Eq. 5),
//! so the implementation avoids per-batch allocation: a [`Sampler`] holds
//! stamped scratch arrays and is reused across batches.

pub mod batch;
pub mod sampler;

pub use batch::{BatchDims, MiniBatch, WeightMode};
pub use sampler::{EpochPlan, Sampler};

/// The paper's evaluation fanouts (2-layer GraphSAGE recipe, layer order
/// per DESIGN.md §Mini-batch wire format).
pub const PAPER_FANOUTS: [usize; 2] = [25, 10];

/// Sanity bound on the level-0 (feature-gather) capacity: deep fanout
/// products grow geometrically and a padded batch buffer beyond this many
/// rows cannot fit host or device memory at any Table-4 feature width.
pub const MAX_V0_CAP: usize = 1 << 24;

/// Fanout configuration: batch size plus one fanout per layer (see the
/// module docs / DESIGN.md for the vector order; paper default B=1024,
/// fanouts `[25, 10]`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FanoutConfig {
    pub batch_size: usize,
    pub fanouts: Vec<usize>,
}

impl FanoutConfig {
    pub fn new(batch_size: usize, fanouts: &[usize]) -> FanoutConfig {
        FanoutConfig { batch_size, fanouts: fanouts.to_vec() }
    }

    /// The paper's evaluation configuration (B=1024, fanouts [25, 10]).
    pub fn paper() -> FanoutConfig {
        FanoutConfig::new(1024, &PAPER_FANOUTS)
    }

    /// Number of GNN layers L.
    pub fn layers(&self) -> usize {
        self.fanouts.len()
    }

    /// Reject configurations every entry point must refuse: empty fanout
    /// lists, zero fanouts, zero batch size, and fanout products whose
    /// padded level-0 buffer exceeds [`MAX_V0_CAP`] rows.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.batch_size >= 1, "batch size must be >= 1");
        anyhow::ensure!(
            !self.fanouts.is_empty(),
            "fanout list must name at least one layer (e.g. --fanouts 25,10)"
        );
        anyhow::ensure!(
            self.fanouts.iter().all(|&k| k >= 1),
            "every fanout must be >= 1 (got {:?})",
            self.fanouts
        );
        let caps = self.try_caps()?;
        anyhow::ensure!(
            caps[0] <= MAX_V0_CAP,
            "level-0 capacity {} exceeds the sane memory bound {} \
             (batch {} × fanouts {:?}); lower the batch size or fanouts",
            caps[0],
            MAX_V0_CAP,
            self.batch_size,
            self.fanouts
        );
        Ok(())
    }

    fn try_caps(&self) -> anyhow::Result<Vec<usize>> {
        let lcount = self.fanouts.len();
        let mut caps = vec![0usize; lcount + 1];
        caps[lcount] = self.batch_size;
        for l in (1..=lcount).rev() {
            caps[l - 1] = caps[l].checked_mul(self.fanouts[l - 1] + 1).ok_or_else(|| {
                anyhow::anyhow!(
                    "fanout capacities overflow usize (batch {} × fanouts {:?})",
                    self.batch_size,
                    self.fanouts
                )
            })?;
        }
        Ok(caps)
    }

    /// Fixed capacities of the padded wire format.
    pub fn dims(&self) -> BatchDims {
        let caps = self
            .try_caps()
            .expect("fanout capacities overflow usize — FanoutConfig::validate rejects these");
        BatchDims { b: self.batch_size, fanouts: self.fanouts.clone(), caps }
    }
}

/// Parse a `--fanouts 15,10,5`-style list (layer order per DESIGN.md
/// §Mini-batch wire format: input-side hop first, target hop last).
pub fn parse_fanouts(s: &str) -> anyhow::Result<Vec<usize>> {
    let fanouts: Vec<usize> = s
        .split(',')
        .map(|t| {
            let t = t.trim();
            t.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("--fanouts '{s}': bad entry '{t}': {e}"))
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(
        fanouts.iter().all(|&k| k >= 1),
        "--fanouts '{s}': every fanout must be >= 1"
    );
    Ok(fanouts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_dims() {
        let d = FanoutConfig::paper().dims();
        assert_eq!(d.b, 1024);
        assert_eq!(d.layers(), 2);
        assert_eq!(d.caps[2], 1024);
        assert_eq!(d.caps[1], 1024 * 11);
        assert_eq!(d.caps[0], 1024 * 11 * 26);
        assert_eq!(d.v0_cap(), d.caps[0]);
    }

    #[test]
    fn three_layer_dims_follow_the_recurrence() {
        let d = FanoutConfig::new(1024, &[15, 10, 5]).dims();
        assert_eq!(d.layers(), 3);
        assert_eq!(d.caps[3], 1024);
        assert_eq!(d.caps[2], 1024 * 6);
        assert_eq!(d.caps[1], 1024 * 6 * 11);
        assert_eq!(d.caps[0], 1024 * 6 * 11 * 16);
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        assert!(FanoutConfig::new(0, &[5]).validate().is_err(), "zero batch");
        assert!(FanoutConfig::new(32, &[]).validate().is_err(), "empty fanouts");
        assert!(FanoutConfig::new(32, &[5, 0]).validate().is_err(), "zero fanout");
        // geometric blowup beyond the memory bound
        assert!(FanoutConfig::new(1024, &[63, 63, 63, 63]).validate().is_err());
        // overflow-sized fanouts are an error, not a panic
        assert!(FanoutConfig::new(usize::MAX / 2, &[3, 3]).validate().is_err());
        assert!(FanoutConfig::paper().validate().is_ok());
        assert!(FanoutConfig::new(1024, &[15, 10, 5]).validate().is_ok());
    }

    #[test]
    fn parse_fanouts_accepts_lists_and_rejects_garbage() {
        assert_eq!(parse_fanouts("25,10").unwrap(), vec![25, 10]);
        assert_eq!(parse_fanouts("15, 10, 5").unwrap(), vec![15, 10, 5]);
        assert_eq!(parse_fanouts("4").unwrap(), vec![4]);
        assert!(parse_fanouts("").is_err());
        assert!(parse_fanouts("a,b").is_err());
        assert!(parse_fanouts("10,,5").is_err());
        assert!(parse_fanouts("0,5").is_err());
    }
}
