//! The neighbor sampler and per-epoch batch planning.

use super::batch::{BatchDims, MiniBatch, WeightMode};
use super::FanoutConfig;
use crate::graph::{Csr, Dataset};
use crate::util::rng::{hash64, Rng};

/// Reusable sampler with stamped scratch arrays (no per-batch allocation
/// of |V|-sized structures; sampling sits on the Eq. 5 critical path).
///
/// RNG model: each `sample(part, seq)` call derives its generator from
/// `(stream, part, seq)` rather than consuming a persistent stream, so a
/// batch's content depends only on its identity — never on which host
/// thread prepares it or in what order (the pipeline determinism
/// requirement, DESIGN.md §Host pipeline). Any two samplers built with
/// the same `seed` are interchangeable.
///
/// Depth: the sampler is fully generic over the fanout vector (see
/// DESIGN.md §Mini-batch wire format for the layer order); at
/// `fanouts = [k1, k2]` it consumes the RNG stream in exactly the order
/// the seed's 2-layer implementation did, so the generalization is a
/// provable no-op at L = 2 (`tests/golden_equivalence.rs`).
pub struct Sampler {
    /// Wire-format capacities, fixed at construction (no per-batch
    /// recomputation — the caps vector would allocate).
    dims: BatchDims,
    mode: WeightMode,
    /// Base of the per-(part, seq) RNG streams.
    stream: u64,
    rng: Rng,
    /// stamp[v] == tag  ⇒  v already placed in the current level list.
    stamp: Vec<u32>,
    /// position of v in the current level list (valid when stamped).
    pos: Vec<i32>,
    tag: u32,
    /// scratch for neighbor sampling without replacement
    pick: Vec<u32>,
    /// scratch for Floyd's distinct-index draw (capacity = max fanout)
    pick_idx: Vec<usize>,
}

impl Sampler {
    pub fn new(cfg: FanoutConfig, mode: WeightMode, num_vertices: usize, seed: u64) -> Sampler {
        let kmax = cfg.fanouts.iter().copied().max().unwrap_or(0);
        Sampler {
            dims: cfg.dims(),
            mode,
            stream: seed,
            rng: Rng::new(seed),
            stamp: vec![0; num_vertices],
            pos: vec![0; num_vertices],
            tag: 0,
            pick: Vec::with_capacity(kmax),
            pick_idx: Vec::with_capacity(kmax),
        }
    }

    /// A fresh all-padding batch matching this sampler's wire format —
    /// the carcass [`Sampler::sample_into`] recycles.
    pub fn new_batch(&self) -> MiniBatch {
        MiniBatch::empty(self.dims.clone())
    }

    /// Re-key the RNG stream base (e.g. per epoch) without reallocating
    /// the |V|-sized scratch arrays.
    pub fn set_stream(&mut self, stream: u64) {
        self.stream = stream;
    }

    /// Sample the L-layer block for `targets` (≤ batch_size) from `data`.
    /// `seq` is the batch's per-partition sequence number; together with
    /// `part_id` it keys the RNG stream (see the type-level docs).
    pub fn sample(
        &mut self,
        data: &Dataset,
        targets: &[u32],
        part_id: usize,
        seq: usize,
    ) -> MiniBatch {
        let mut mb = self.new_batch();
        self.sample_into(&mut mb, data, targets, part_id, seq);
        mb
    }

    /// [`Sampler::sample`] into a recycled [`MiniBatch`] — the
    /// zero-allocation hot path (DESIGN.md §Hot-path memory & kernels).
    /// Every field of `mb` is fully overwritten (level lists cleared and
    /// re-padded, index/weight blocks zeroed before writing), so batch
    /// content still depends only on `(stream, part, seq)` — recycling is
    /// observationally invisible, preserving the determinism law.
    pub fn sample_into(
        &mut self,
        mb: &mut MiniBatch,
        data: &Dataset,
        targets: &[u32],
        part_id: usize,
        seq: usize,
    ) {
        self.rng = Rng::new(hash64(self.stream ^ ((part_id as u64) << 32) ^ (seq as u64)));
        let lcount = self.dims.layers();
        assert!(targets.len() <= self.dims.b, "targets exceed batch capacity");
        assert_eq!(mb.dims, self.dims, "recycled batch dims mismatch");
        let g = &data.graph;
        mb.part_id = part_id;
        mb.seq = seq;

        // fully reset the carcass: no state may survive from a previous
        // batch (padding rows/columns must read as index 0 / weight 0)
        for list in mb.v.iter_mut() {
            list.clear();
        }
        for block in mb.idx.iter_mut() {
            block.fill(0);
        }
        for block in mb.w.iter_mut() {
            block.fill(0.0);
        }
        mb.n.fill(0);

        mb.n[lcount] = targets.len();
        mb.v[lcount].extend_from_slice(targets);

        // ---- layers L..1: level l → level l-1 ---------------------------
        // Level l-1 begins with level l's vertices themselves (self
        // positions), then deduplicated sampled neighbors — the same
        // two-phase structure (and therefore RNG order) as the seed's
        // explicit layer-2/layer-1 code. idx[l-1] / w[l-1] describe layer
        // l (positions into level l-1).
        for l in (1..=lcount).rev() {
            let k = self.dims.fanouts[l - 1];
            let kw = k + 1;
            self.bump_tag();
            let (lower, upper) = mb.v.split_at_mut(l);
            let cur = &upper[0];
            let dst = &mut lower[l - 1];
            for &vv in cur.iter() {
                self.place(vv, dst);
            }
            for (r, &vv) in cur.iter().enumerate() {
                let row = r * kw;
                mb.idx[l - 1][row] = self.pos[vv as usize];
                let k_real = self.sample_neighbors(g, vv, k);
                let picks = std::mem::take(&mut self.pick);
                mb.w[l - 1][row] = self.self_weight(g, vv);
                for (c, &u) in picks.iter().enumerate() {
                    let p = self.place(u, dst);
                    mb.idx[l - 1][row + 1 + c] = p;
                    mb.w[l - 1][row + 1 + c] = self.neighbor_weight(g, vv, u, k_real);
                }
                self.pick = picks;
            }
            mb.n[l - 1] = dst.len();
            assert!(mb.n[l - 1] <= self.dims.caps[l - 1]);
        }

        // ---- labels / mask ------------------------------------------------
        mb.labels.fill(0);
        mb.mask.fill(0.0);
        for (r, &t) in targets.iter().enumerate() {
            mb.labels[r] = data.features.label(t);
            mb.mask[r] = 1.0;
        }

        // pad vertex lists to capacity with id 0 (weight-0 rows ignore them)
        for (list, &cap) in mb.v.iter_mut().zip(self.dims.caps.iter()) {
            list.resize(cap, 0);
        }
    }

    /// Advance the level stamp. On u32 wrap-around the stamp array is
    /// cleared and the counter restarts at 1, so a stale stamp from ~2^32
    /// levels ago can never alias the fresh one and corrupt the dedup
    /// (`comm::IterDedup::next_iteration` applies the same protocol).
    #[inline]
    fn bump_tag(&mut self) {
        self.tag = self.tag.wrapping_add(1);
        if self.tag == 0 {
            self.stamp.fill(0);
            self.tag = 1;
        }
    }

    /// Place `v` in `list` if not already present this level; return its
    /// position.
    #[inline]
    fn place(&mut self, v: u32, list: &mut Vec<u32>) -> i32 {
        let vi = v as usize;
        if self.stamp[vi] == self.tag {
            return self.pos[vi];
        }
        self.stamp[vi] = self.tag;
        let p = list.len() as i32;
        self.pos[vi] = p;
        list.push(v);
        p
    }

    /// Sample up to `k` distinct neighbors of `v` into `self.pick`;
    /// returns the *actual* neighbor count used for mean weighting.
    fn sample_neighbors(&mut self, g: &Csr, v: u32, k: usize) -> usize {
        let nbrs = g.neighbors(v);
        self.pick.clear();
        if nbrs.is_empty() {
            return 0;
        }
        if nbrs.len() <= k {
            self.pick.extend_from_slice(nbrs);
        } else {
            // Floyd's algorithm over index space, into the persistent
            // scratch (same draw sequence as `Rng::sample_distinct`)
            self.rng.sample_distinct_into(nbrs.len(), k, &mut self.pick_idx);
            self.pick.extend(self.pick_idx.iter().map(|&i| nbrs[i]));
        }
        self.pick.len()
    }

    /// Test hook: force the level stamp near the wrap-around boundary.
    #[cfg(test)]
    fn force_tag(&mut self, tag: u32) {
        self.tag = tag;
    }

    #[inline]
    fn self_weight(&self, g: &Csr, v: u32) -> f32 {
        match self.mode {
            // GCN Â with self loop: ŵ(v,v) = 1/(deg+1)
            WeightMode::GcnNorm => 1.0 / (g.degree(v) as f32 + 1.0),
            // SAGE: the self column feeds the W_self path at weight 1
            WeightMode::SageMean => 1.0,
            // GAT/GIN compute their own coefficients; 1 marks "real"
            WeightMode::Unit => 1.0,
        }
    }

    #[inline]
    fn neighbor_weight(&self, g: &Csr, v: u32, u: u32, k_real: usize) -> f32 {
        match self.mode {
            WeightMode::GcnNorm => {
                1.0 / (((g.degree(v) as f32 + 1.0) * (g.degree(u) as f32 + 1.0)).sqrt())
            }
            WeightMode::SageMean => 1.0 / k_real as f32,
            WeightMode::Unit => 1.0,
        }
    }
}

/// Per-epoch batch plan: shuffled training targets per partition, consumed
/// batch by batch (the two-stage scheduler asks for "next batch from
/// partition j" — Algorithm 3's `Sample(V[j], E[j])`).
pub struct EpochPlan {
    batch_size: usize,
    order: Vec<Vec<u32>>,
    cursor: Vec<usize>,
}

impl EpochPlan {
    pub fn new(train_parts: &[Vec<u32>], batch_size: usize, rng: &mut Rng) -> EpochPlan {
        let mut order: Vec<Vec<u32>> = train_parts.to_vec();
        for part in order.iter_mut() {
            rng.shuffle(part);
        }
        EpochPlan { batch_size, order, cursor: vec![0; train_parts.len()] }
    }

    /// Batches remaining in partition `i`.
    pub fn remaining(&self, i: usize) -> usize {
        let left = self.order[i].len() - self.cursor[i];
        (left + self.batch_size - 1) / self.batch_size
    }

    /// Total batches remaining.
    pub fn total_remaining(&self) -> usize {
        (0..self.order.len()).map(|i| self.remaining(i)).sum()
    }

    /// Take the next target slice from partition `i` (None if exhausted).
    pub fn next_targets(&mut self, i: usize) -> Option<&[u32]> {
        self.next_targets_seq(i).map(|(_, t)| t)
    }

    /// Like [`EpochPlan::next_targets`], but also returns the batch's
    /// per-partition sequence number — the RNG-stream key the pipeline's
    /// planning stage hands to whichever prep thread samples the batch.
    pub fn next_targets_seq(&mut self, i: usize) -> Option<(usize, &[u32])> {
        let left = self.order[i].len() - self.cursor[i];
        if left == 0 {
            return None;
        }
        let take = left.min(self.batch_size);
        let start = self.cursor[i];
        // every earlier take was a full batch, so this is the batch index
        let seq = start / self.batch_size;
        self.cursor[i] += take;
        Some((seq, &self.order[i][start..start + take]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn data() -> Dataset {
        datasets::lookup("reddit").unwrap().build(8, 17)
    }

    fn cfg() -> FanoutConfig {
        FanoutConfig::new(64, &[5, 3])
    }

    #[test]
    fn sampled_batch_is_structurally_valid() {
        let d = data();
        let mut s = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 1);
        let targets: Vec<u32> = d.train_vertices[..64].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        mb.validate().unwrap();
        assert_eq!(mb.n_targets(), 64);
        assert!(mb.n[1] >= 64); // at least the targets themselves
        assert!(mb.n[0] >= mb.n[1]);
    }

    #[test]
    fn depth_one_and_three_batches_validate() {
        let d = data();
        for fanouts in [vec![4], vec![4, 3, 2]] {
            let cfg = FanoutConfig::new(32, &fanouts);
            let mut s = Sampler::new(cfg, WeightMode::GcnNorm, d.graph.num_vertices(), 9);
            let targets: Vec<u32> = d.train_vertices[..32].to_vec();
            let mb = s.sample(&d, &targets, 0, 0);
            mb.validate().unwrap();
            assert_eq!(mb.layers(), fanouts.len());
            assert_eq!(mb.n_targets(), 32);
            // each level holds at least the level above (self placement)
            for l in (1..=mb.layers()).rev() {
                assert!(mb.n[l - 1] >= mb.n[l], "level {l}: {:?}", mb.n);
            }
        }
    }

    #[test]
    fn short_final_batch_masks_padding() {
        let d = data();
        let mut s = Sampler::new(cfg(), WeightMode::SageMean, d.graph.num_vertices(), 1);
        let targets: Vec<u32> = d.train_vertices[..10].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        mb.validate().unwrap();
        assert_eq!(mb.n_targets(), 10);
        assert_eq!(mb.mask.iter().filter(|&&m| m == 1.0).count(), 10);
    }

    #[test]
    fn layer_lists_deduplicate() {
        let d = data();
        let mut s = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 2);
        let targets: Vec<u32> = d.train_vertices[..64].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        for l in 0..mb.layers() {
            let uniq: std::collections::HashSet<u32> =
                mb.v[l][..mb.n[l]].iter().copied().collect();
            assert_eq!(uniq.len(), mb.n[l], "v[{l}] contains duplicates");
        }
    }

    #[test]
    fn self_column_points_to_self() {
        let d = data();
        let mut s = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 3);
        let targets: Vec<u32> = d.train_vertices[..32].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        for l in 1..=mb.layers() {
            let k = mb.dims.row_width(l);
            for r in 0..mb.n[l] {
                let p = mb.idx[l - 1][r * k] as usize;
                assert_eq!(mb.v[l - 1][p], mb.v[l][r], "self column of level-{l} row {r}");
            }
        }
    }

    #[test]
    fn sage_mean_weights_sum_to_one_over_neighbors() {
        let d = data();
        let mut s = Sampler::new(cfg(), WeightMode::SageMean, d.graph.num_vertices(), 4);
        let targets: Vec<u32> = d.train_vertices[..16].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        let l = mb.layers();
        let k2 = mb.dims.row_width(l);
        let w2 = &mb.w[l - 1];
        for r in 0..mb.n_targets() {
            let nbr_sum: f32 = w2[r * k2 + 1..(r + 1) * k2].iter().sum();
            let has_nbrs = w2[r * k2 + 1..(r + 1) * k2].iter().any(|&w| w != 0.0);
            if has_nbrs {
                assert!((nbr_sum - 1.0).abs() < 1e-5, "row {r}: {nbr_sum}");
            }
            assert_eq!(w2[r * k2], 1.0); // self column
        }
    }

    #[test]
    fn unit_weights_are_one_on_real_entries_and_zero_on_padding() {
        let d = data();
        let mut s = Sampler::new(cfg(), WeightMode::Unit, d.graph.num_vertices(), 6);
        let targets: Vec<u32> = d.train_vertices[..16].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        mb.validate().unwrap();
        for l in 1..=mb.layers() {
            let k = mb.dims.row_width(l);
            let w = &mb.w[l - 1];
            for r in 0..mb.dims.caps[l] {
                for c in 0..k {
                    let val = w[r * k + c];
                    assert!(
                        val == 1.0 || val == 0.0,
                        "level-{l} row {r} col {c}: weight {val} not in {{0, 1}}"
                    );
                    if r >= mb.n[l] {
                        assert_eq!(val, 0.0, "padding row {r} must carry weight 0");
                    } else if c == 0 {
                        assert_eq!(val, 1.0, "self column of real row {r}");
                    }
                }
            }
        }
    }

    #[test]
    fn gcn_weights_match_degree_formula() {
        let d = data();
        let mut s = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 5);
        let targets: Vec<u32> = d.train_vertices[..8].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        let l = mb.layers();
        let k2 = mb.dims.row_width(l);
        for (r, &t) in targets.iter().enumerate() {
            let dv = d.graph.degree(t) as f32 + 1.0;
            assert!((mb.w[l - 1][r * k2] - 1.0 / dv).abs() < 1e-6);
            for c in 1..k2 {
                let w = mb.w[l - 1][r * k2 + c];
                if w != 0.0 {
                    let u = mb.v[l - 1][mb.idx[l - 1][r * k2 + c] as usize];
                    let du = d.graph.degree(u) as f32 + 1.0;
                    assert!((w - 1.0 / (dv * du).sqrt()).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let d = data();
        let targets: Vec<u32> = d.train_vertices[..32].to_vec();
        let mut s1 = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 7);
        let mut s2 = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 7);
        let a = s1.sample(&d, &targets, 0, 0);
        let b = s2.sample(&d, &targets, 0, 0);
        assert_eq!(a.v[0], b.v[0]);
        assert_eq!(a.idx[0], b.idx[0]);
        assert_eq!(a.w[1], b.w[1]);
    }

    #[test]
    fn sampling_is_independent_of_call_order() {
        // pipeline determinism: a batch's content depends only on
        // (seed, part, seq), not on what the sampler did before
        let d = data();
        let t1: Vec<u32> = d.train_vertices[..32].to_vec();
        let t2: Vec<u32> = d.train_vertices[32..64].to_vec();
        let mut a = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 7);
        let mut b = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 7);
        // a: (0,0) then (1,5); b: (1,5) then (0,0) — pairwise identical
        let a00 = a.sample(&d, &t1, 0, 0);
        let a15 = a.sample(&d, &t2, 1, 5);
        let b15 = b.sample(&d, &t2, 1, 5);
        let b00 = b.sample(&d, &t1, 0, 0);
        assert_eq!(a00.v[0], b00.v[0]);
        assert_eq!(a00.idx[0], b00.idx[0]);
        assert_eq!(a15.v[0], b15.v[0]);
        assert_eq!(a15.w[1], b15.w[1]);
        // distinct (part, seq) keys give distinct batches
        assert_ne!(a00.v[0], a15.v[0]);
    }

    #[test]
    fn epoch_plan_seq_numbers_batches_per_partition() {
        let d = data();
        let parts = vec![d.train_vertices[..100].to_vec()];
        let mut rng = Rng::new(3);
        let mut plan = EpochPlan::new(&parts, 32, &mut rng);
        let mut seqs = Vec::new();
        while let Some((seq, t)) = plan.next_targets_seq(0) {
            assert!(!t.is_empty());
            seqs.push(seq);
        }
        assert_eq!(seqs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn epoch_plan_covers_all_targets_once() {
        let d = data();
        let parts = vec![
            d.train_vertices[..100].to_vec(),
            d.train_vertices[100..150].to_vec(),
        ];
        let mut rng = Rng::new(9);
        let mut plan = EpochPlan::new(&parts, 32, &mut rng);
        assert_eq!(plan.remaining(0), 4); // ceil(100/32)
        assert_eq!(plan.remaining(1), 2);
        let mut seen = Vec::new();
        while let Some(t) = plan.next_targets(0) {
            seen.extend_from_slice(t);
        }
        assert_eq!(seen.len(), 100);
        let set: std::collections::HashSet<u32> = seen.iter().copied().collect();
        assert_eq!(set.len(), 100);
        assert_eq!(plan.remaining(0), 0);
        assert_eq!(plan.total_remaining(), 2);
    }

    fn assert_batches_identical(a: &MiniBatch, b: &MiniBatch, tag: &str) {
        let bits = |w: &[f32]| w.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(a.n, b.n, "{tag}: n");
        assert_eq!(a.v, b.v, "{tag}: v");
        assert_eq!(a.idx, b.idx, "{tag}: idx");
        for (l, (aw, bw)) in a.w.iter().zip(&b.w).enumerate() {
            assert_eq!(bits(aw), bits(bw), "{tag}: w[{l}]");
        }
        assert_eq!(a.labels, b.labels, "{tag}: labels");
        assert_eq!(bits(&a.mask), bits(&b.mask), "{tag}: mask");
        assert_eq!((a.part_id, a.seq), (b.part_id, b.seq), "{tag}: identity");
    }

    #[test]
    fn sample_into_recycled_batch_is_fully_overwritten() {
        // a dirty carcass from a *different* (longer) batch must produce
        // bit-identical content to a fresh sample of the same (part, seq)
        let d = data();
        let long: Vec<u32> = d.train_vertices[..64].to_vec();
        let short: Vec<u32> = d.train_vertices[64..74].to_vec();
        let mut s = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 13);
        let mut mb = s.new_batch();
        s.sample_into(&mut mb, &d, &long, 0, 0);
        s.sample_into(&mut mb, &d, &short, 1, 4);
        let mut fresh = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 13);
        let expect = fresh.sample(&d, &short, 1, 4);
        mb.validate().unwrap();
        assert_batches_identical(&mb, &expect, "recycled vs fresh");
    }

    #[test]
    fn tag_wraparound_clears_stale_stamps() {
        // regression (ISSUE 5 satellite): the u32 level stamp wrapping
        // past 0 used to leave stale stamp entries that alias the fresh
        // tag and corrupt level dedup. After the fix a sampler driven
        // across the wrap produces bit-identical batches to a fresh one.
        let d = data();
        let targets: Vec<u32> = d.train_vertices[..32].to_vec();
        let mut near = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 7);
        let _ = near.sample(&d, &targets, 0, 0); // populate stamp/pos scratch
        near.force_tag(u32::MAX - 1); // L=2 levels: tags MAX, then wrap → 1
        let wrapped = near.sample(&d, &targets, 1, 3);
        wrapped.validate().unwrap();
        let mut fresh = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 7);
        let expect = fresh.sample(&d, &targets, 1, 3);
        assert_batches_identical(&wrapped, &expect, "across tag wrap");
        // and the sampler keeps working after the wrap
        let after = near.sample(&d, &targets, 0, 9);
        let expect = fresh.sample(&d, &targets, 0, 9);
        assert_batches_identical(&after, &expect, "after tag wrap");
    }

    #[test]
    fn vertices_traversed_counts_all_levels() {
        let d = data();
        let mut s = Sampler::new(cfg(), WeightMode::GcnNorm, d.graph.num_vertices(), 11);
        let targets: Vec<u32> = d.train_vertices[..64].to_vec();
        let mb = s.sample(&d, &targets, 0, 0);
        assert_eq!(mb.vertices_traversed(), mb.n[0] + mb.n[1] + mb.n[2]);
    }
}
